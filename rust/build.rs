//! Build script: stamp the crate with a fingerprint of its own source
//! tree.
//!
//! The persistent report cache (`sweep::store`) content-addresses cached
//! simulation reports by *config*, but a report is only reusable while the
//! simulator that produced it is unchanged — a cache entry computed by an
//! older build of the model must read as stale, not as truth. Hashing the
//! `src/` tree at compile time gives every build an identity
//! (`DLPIM_SRC_FINGERPRINT`) that cache entries embed and verify, so a
//! `target/` directory restored by CI caching across commits can never
//! serve reports from a different simulator.
//!
//! No dependencies, no network: a plain FNV-1a over the sorted file list
//! (paths + contents).

use std::fs;
use std::path::{Path, PathBuf};

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn main() {
    // Any source change re-runs this script (cargo tracks directories
    // recursively), so the fingerprint can never go stale.
    println!("cargo:rerun-if-changed=src");
    println!("cargo:rerun-if-changed=build.rs");

    let mut files: Vec<PathBuf> = Vec::new();
    collect(Path::new("src"), &mut files);
    files.sort();

    let mut h = FNV_OFFSET;
    for path in &files {
        for &b in path.to_string_lossy().as_bytes() {
            h = fnv_step(h, b);
        }
        h = fnv_step(h, 0);
        for &b in &fs::read(path).unwrap_or_default() {
            h = fnv_step(h, b);
        }
    }
    println!("cargo:rustc-env=DLPIM_SRC_FINGERPRINT={h:016x}");
}

fn fnv_step(h: u64, b: u8) -> u64 {
    (h ^ b as u64).wrapping_mul(FNV_PRIME)
}

fn collect(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else {
        return;
    };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect(&path, out);
        } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(path);
        }
    }
}

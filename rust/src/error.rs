//! Minimal error handling (`anyhow` is unavailable offline): a boxed-free
//! message chain with `context`/`with_context` adapters and the `err!` /
//! `bail!` macros, mirroring the subset of the `anyhow` API this crate
//! uses.

use std::fmt;

/// An error: the outermost context first, the root cause last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg(msg: impl fmt::Display) -> Self {
        Error { chain: vec![msg.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context(mut self, ctx: impl fmt::Display) -> Self {
        self.chain.insert(0, ctx.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

// `Error` deliberately does not implement `std::error::Error`: that keeps
// this blanket conversion coherent (the same trick `anyhow` uses), so `?`
// works on `io::Result` and friends inside functions returning our
// `Result`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// Crate-wide result type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context adapters for `Result` and `Option`.
pub trait Context<T> {
    /// Attach a fixed context message to the error case.
    fn context(self, ctx: impl fmt::Display) -> Result<T>;

    /// Attach a lazily-built context message to the error case.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context(self, ctx: impl fmt::Display) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, ctx: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string or any displayable expression.
macro_rules! err {
    ($fmt:literal $(, $arg:expr)* $(,)?) => {{
        #[allow(clippy::useless_format)]
        let msg = format!($fmt $(, $arg)*);
        $crate::error::Error::msg(msg)
    }};
    ($e:expr) => {
        $crate::error::Error::msg($e)
    };
}

/// Return early with an [`Error`] built like [`err!`].
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::error::err!($($t)*))
    };
}

pub use bail;
pub use err;

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn context_chains_outermost_first() {
        let e = Error::msg("root").context("middle").context("outer");
        assert_eq!(e.to_string(), "outer: middle: root");
        assert_eq!(e.chain().len(), 3);
    }

    #[test]
    fn result_and_option_context() {
        let r: std::result::Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "gone",
        ));
        let e = r.context("opening").unwrap_err();
        assert!(e.to_string().starts_with("opening: "));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
    }

    #[test]
    fn macros_build_messages() {
        let e = err!("bad value {}", 42);
        assert_eq!(e.to_string(), "bad value 42");
        let msg = String::from("plain");
        let e = err!(msg);
        assert_eq!(e.to_string(), "plain");

        fn bails() -> Result<()> {
            bail!("nope: {}", 1);
        }
        assert_eq!(bails().unwrap_err().to_string(), "nope: 1");
    }
}

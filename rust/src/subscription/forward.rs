//! Holder forwarding (§III-B): the home vault redirects a demand request
//! to the vault currently holding the block in its reserved space.

use crate::memsys::{MemorySystem, ServedRequest};
use crate::sim::PacketKind;
use crate::subscription::protocol::{Access, SubSystem};
use crate::{Cycle, VaultId};

impl MemorySystem {
    /// Home has redirected the request to the holder vault `s`.
    pub(crate) fn forward_to_holder(
        &mut self,
        req: Access,
        at: Cycle,
        home: VaultId,
        s: VaultId,
        set: u32,
        out: &mut ServedRequest,
    ) -> ServedRequest {
        let r = req.requester;
        let block = req.block;
        let (fwd_kind, fwd_flits) = if req.write {
            (PacketKind::MemWriteFwd, self.subs.k)
        } else {
            (PacketKind::MemReadReq, 1)
        };
        let f = self.send(fwd_kind, fwd_flits, home, s, at);
        out.network += f.network;
        out.queued += f.queued;
        out.queued_net += f.queued;
        out.actual_hops += f.hops;

        // Reuse bookkeeping on the holder's entry; its slot addresses the
        // reserved-space access.
        let slot = self.subs.tables[s as usize].lookup(set, block, f.arrive);
        let addr = match slot {
            Some(i) => SubSystem::reserved_slot_addr(i),
            None => SubSystem::home_addr(block), // directory raced; charge a row
        };
        let acc = self.vaults.access(s, addr, f.arrive);
        out.queued += acc.queued;
        out.array += acc.array;
        out.served_by = s;
        self.stats.demand.record(s);
        if let Some(i) = slot {
            self.subs.tables[s as usize].touch(i, f.arrive);
            if req.write {
                self.subs.tables[s as usize].entry_mut(i).dirty = true;
            }
        }
        if s == r {
            self.stats.reuse.on_local_hit();
            self.stats.local_requests += 1;
        } else {
            self.stats.reuse.on_remote_hit();
        }

        if req.write {
            out.done = acc.done;
        } else {
            let t2 = self.send(PacketKind::MemReadResp, self.subs.k, s, r, acc.done);
            out.network += t2.network;
            out.queued += t2.queued;
            out.queued_net += t2.queued;
            out.actual_hops += t2.hops;
            out.done = t2.arrive;
        }
        *out
    }

    /// Home-vault access to its own block that is subscribed away.
    pub(crate) fn serve_via_holder(
        &mut self,
        req: Access,
        now: Cycle,
        home: VaultId,
        holder: VaultId,
        set: u32,
        out: &mut ServedRequest,
    ) -> ServedRequest {
        out.subscribed_path = true;
        self.forward_to_holder(req, now, home, holder, set, out)
    }
}

//! The demand-serve path (§III-B serve flows): every memory request enters
//! the system through [`MemorySystem::serve`], which resolves it against
//! the distributed subscription directory and dispatches to the local,
//! home or remote path. The holder-forwarding leg lives in
//! [`super::forward`], the subscription handshakes in [`super::subscribe`]
//! and the eviction/return flows in [`super::evict`].

use crate::memsys::{MemorySystem, ServePrep, ServedRequest};
use crate::policy::PolicyRuntime;
use crate::sim::PacketKind;
use crate::subscription::protocol::{Access, SubSystem};
use crate::subscription::table::{Role, SubState};
use crate::{Cycle, VaultId};

impl MemorySystem {
    /// Serve one demand access end to end. The driver is responsible for
    /// recording the returned breakdown and feeding the policy registers.
    ///
    /// Composes the pure address resolution ([`MemorySystem::prepare`])
    /// with the stateful pass ([`MemorySystem::serve_prepared`]); the
    /// batched driver calls the two halves separately.
    pub fn serve(
        &mut self,
        req: Access,
        now: Cycle,
        policy: &PolicyRuntime,
    ) -> ServedRequest {
        let prep = self.prepare(req.requester, req.block);
        self.serve_prepared(req, now, policy, prep)
    }

    /// The stateful serve pass, taking the address-derived values as an
    /// argument. Must be fed `prepare(req.requester, req.block)` — the
    /// batched driver computes the [`ServePrep`]s for a whole admission
    /// window up front, then runs this pass in event order.
    pub fn serve_prepared(
        &mut self,
        req: Access,
        now: Cycle,
        policy: &PolicyRuntime,
        prep: ServePrep,
    ) -> ServedRequest {
        let block = req.block;
        let r = req.requester;
        let ServePrep { home, set, baseline_hops } = prep;

        let mut out = ServedRequest {
            set,
            baseline_hops,
            served_by: home,
            ..Default::default()
        };

        // ---- Fast path: block parked in this vault's reserved space. ----
        if home != r {
            if let Some(i) = self.subs.tables[r as usize].lookup(set, block, now) {
                let e = *self.subs.tables[r as usize].entry(i);
                if e.role == Role::Holder
                    && e.state == SubState::Subscribed
                    && e.ready_at <= now
                {
                    let acc =
                        self.vaults.access(r, SubSystem::reserved_slot_addr(i), now);
                    self.subs.tables[r as usize].touch(i, now);
                    if req.write {
                        self.subs.tables[r as usize].entry_mut(i).dirty = true;
                    }
                    self.stats.reuse.on_local_hit();
                    self.stats.demand.record(r);
                    self.stats.local_requests += 1;
                    out.done = acc.done;
                    out.queued = acc.queued;
                    out.array = acc.array;
                    out.served_by = r;
                    out.local = true;
                    out.subscribed_path = true;
                    return out;
                }
                // Pending entry: the move is in flight. The request follows
                // the normal remote path; no new subscription is started
                // (the in-flight one will land).
                return self.serve_remote(req, now, home, set, &mut out);
            }
        }

        // ---- Home-local access (requester is the home vault). ----
        if home == r {
            if let Some(i) = self.subs.tables[r as usize].lookup(set, block, now) {
                let e = *self.subs.tables[r as usize].entry(i);
                if e.role == Role::Home && !e.is_invalid() {
                    // Block subscribed away; §III-D4's special case — the
                    // home vault itself needs it back. Serve via the holder
                    // and (policy permitting) pull it home (unsubscribe).
                    let holder = e.peer;
                    let res =
                        self.serve_via_holder(req, now, home, holder, set, &mut out);
                    if e.state == SubState::Subscribed
                        && e.ready_at <= now
                        && policy.enabled(r, set, now)
                    {
                        self.unsubscribe_home_initiated(home, block, set, now);
                    }
                    return res;
                }
            }
            // Plain local access at home.
            let acc = self.vaults.access(r, SubSystem::home_addr(block), now);
            self.stats.demand.record(r);
            self.stats.local_requests += 1;
            out.done = acc.done;
            out.queued = acc.queued;
            out.array = acc.array;
            out.served_by = r;
            out.local = true;
            return out;
        }

        // ---- Remote access through the home vault. ----
        // Writes never subscribe from the writer side (§III-C: "the
        // requester vault writes the data to the original vault", which
        // forwards to the holder if any). Only reads subscribe — their
        // data transfer is the one the baseline already pays, so the
        // subscription piggybacks for free (§IV-B1). A block made hot by
        // read-fills parks locally; later writebacks then hit the fast
        // path above with zero network cost.
        let res = self.serve_remote(req, now, home, set, &mut out);
        let enabled = policy.enabled(r, set, now);
        if !req.write && enabled && self.subs.count_filter(block) {
            // Piggybacked subscription: the demand response already moved
            // the block to the requester (§III-A's combined packet format);
            // only the acknowledgements travel separately.
            self.subscribe_piggyback(r, block, home, set, now, res.done);
        } else if !enabled && res.subscribed_path && !res.local {
            // Subscriptions are off for this set but the block is still
            // parked remotely, taxing every access with the three-leg
            // indirection. Drain it home — the home-initiated
            // unsubscription of §III-B4, triggered by the epoch decision
            // instead of a home access.
            self.unsubscribe_home_initiated(home, block, set, res.done);
        }
        res
    }

    /// Remote demand path: requester → home (→ holder) → requester.
    pub(crate) fn serve_remote(
        &mut self,
        req: Access,
        now: Cycle,
        home: VaultId,
        set: u32,
        out: &mut ServedRequest,
    ) -> ServedRequest {
        let r = req.requester;
        let block = req.block;

        // Leg 1: request (reads: 1 FLIT; writes carry the block: k FLITs).
        let (req_kind, req_flits) = if req.write {
            (PacketKind::MemWrite, self.subs.k)
        } else {
            (PacketKind::MemReadReq, 1)
        };
        let t1 = self.send(req_kind, req_flits, r, home, now);
        out.network += t1.network;
        out.queued += t1.queued;
        out.queued_net += t1.queued;
        out.actual_hops += t1.hops;

        // Home-side directory lookup.
        let holder = match self.subs.tables[home as usize].lookup(set, block, t1.arrive)
        {
            Some(i) => {
                let e = *self.subs.tables[home as usize].entry(i);
                match (e.role, e.state) {
                    (Role::Home, SubState::Subscribed) if e.ready_at <= t1.arrive => {
                        Some(e.peer)
                    }
                    // Pending resubscription: old holder still owns the
                    // data (peer field) until the move commits.
                    (Role::Home, SubState::PendingResub) => Some(e.peer),
                    // Subscription data still in flight: home copy valid.
                    (Role::Home, SubState::PendingSub) => None,
                    // Returning home: the home copy is already valid for
                    // clean blocks (the dirty hint is recorded when the
                    // unsubscription starts); only dirty returns must be
                    // waited for.
                    (Role::Home, SubState::PendingUnsub) => {
                        if e.dirty && t1.arrive < e.ready_at {
                            out.queued += e.ready_at - t1.arrive;
                        }
                        None
                    }
                    _ => None,
                }
            }
            None => None,
        };

        match holder {
            None => {
                // Serve at home (after any pending-unsubscription wait that
                // was already added to out.queued above).
                let wait_extra = out.queued - t1.queued;
                let acc = self
                    .vaults
                    .access(home, SubSystem::home_addr(block), t1.arrive + wait_extra);
                out.queued += acc.queued;
                out.array += acc.array;
                out.served_by = home;
                self.stats.demand.record(home);
                if req.write {
                    out.done = acc.done;
                } else {
                    let t2 = self.send(
                        PacketKind::MemReadResp,
                        self.subs.k,
                        home,
                        r,
                        acc.done,
                    );
                    out.network += t2.network;
                    out.queued += t2.queued;
                    out.queued_net += t2.queued;
                    out.actual_hops += t2.hops;
                    out.done = t2.arrive;
                }
                *out
            }
            Some(s) => {
                out.subscribed_path = true;
                self.forward_to_holder(req, t1.arrive, home, s, set, out)
            }
        }
    }
}

//! The distributed subscription directory (§III-A): per-vault tables,
//! buffers and the optional count table, plus the cross-vault consistency
//! invariant.
//!
//! This module holds *state only*. The packet flows that act on it — the
//! demand-serve path, holder forwarding, the subscription/resubscription
//! handshakes and the unsubscription/eviction flows — live in the sibling
//! handler modules ([`super::serve`], [`super::forward`],
//! [`super::subscribe`], [`super::evict`]) as `impl` blocks on
//! [`crate::memsys::MemorySystem`], the facade that owns this directory
//! together with the interconnect, the vault DRAM and the statistics.
//!
//! Timing follows the paper's cost model exactly:
//! * baseline read: request (1 FLIT) requester→home, data (k FLITs) back —
//!   `(k+1)·h_ro` uncontended;
//! * DL-PIM read of a block subscribed elsewhere: `h_ro + h_so + k·h_rs`
//!   (request to home, forward to holder, data to requester);
//! * local read of a block subscribed *here*: no network at all — the whole
//!   point of the architecture.
//!
//! Subscription flows run *off* the demand critical path (the block is
//! moved in the background) but their packets contend for the same links
//! and their copies occupy the same banks, which is how always-subscribe
//! manages to hurt low-reuse workloads (Fig 9, PLYgemm / PLY3mm).

use crate::config::SimConfig;
use crate::sim::AddressMap;
use crate::subscription::buffer::SubBuffer;
use crate::subscription::count_table::CountTable;
use crate::subscription::table::{Role, SubState, SubTable};
use crate::{Cycle, VaultId};

/// Reserved-space addresses live far above the interleaved heap so bank
/// and row-buffer behaviour of parked blocks is modeled but never collides
/// with home addresses.
const RESERVED_BASE: u64 = 1 << 40;

/// One demand access from a PIM core.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Access {
    pub requester: VaultId,
    pub block: u64,
    pub write: bool,
}

/// The distributed subscription directory: one table + buffer per vault.
pub struct SubSystem {
    pub(crate) tables: Vec<SubTable>,
    pub(crate) buffers: Vec<SubBuffer>,
    pub(crate) counts: Option<CountTable>,
    pub(crate) map: AddressMap,
    pub(crate) k: u32,
    pub(crate) flit_bytes: u32,
    pub(crate) count_threshold: u32,
}

impl SubSystem {
    pub fn new(cfg: &SimConfig) -> Self {
        let n = cfg.n_vaults as usize;
        SubSystem {
            tables: (0..n)
                .map(|_| SubTable::new(cfg.sub_table_sets, cfg.sub_table_ways))
                .collect(),
            buffers: (0..n).map(|_| SubBuffer::new(cfg.sub_buffer_entries)).collect(),
            counts: if cfg.count_threshold > 0 {
                Some(CountTable::new(8192))
            } else {
                None
            },
            map: AddressMap::new(cfg),
            k: cfg.data_packet_flits(),
            flit_bytes: cfg.flit_bytes,
            count_threshold: cfg.count_threshold,
        }
    }

    pub fn reset(&mut self) {
        for t in &mut self.tables {
            t.reset();
        }
        for b in &mut self.buffers {
            b.reset();
        }
        if let Some(c) = &mut self.counts {
            c.reset();
        }
    }

    pub fn table(&self, v: VaultId) -> &SubTable {
        &self.tables[v as usize]
    }

    pub fn map(&self) -> &AddressMap {
        &self.map
    }

    #[inline]
    pub(crate) fn home_addr(block: u64) -> u64 {
        block << 6 // only row/bank mapping matters; 64 B blocks
    }

    /// Address of a reserved-space slot. The reserved area is a small
    /// *contiguous* region (8192 slots x 64 B = 512 KB per vault, §IV-C),
    /// addressed by table slot — so churning subscriptions sweep compact
    /// rows (four slots per 256 B row buffer) instead of scattering
    /// row-misses across the address space.
    #[inline]
    pub(crate) fn reserved_slot_addr(entry_idx: usize) -> u64 {
        RESERVED_BASE + ((entry_idx as u64) << 6)
    }

    /// Count-threshold filter (ablation §III-A); true = may subscribe.
    pub(crate) fn count_filter(&mut self, block: u64) -> bool {
        if self.count_threshold == 0 {
            return true;
        }
        match &mut self.counts {
            Some(c) => {
                c.bump(block);
                c.over_threshold(block, self.count_threshold)
            }
            None => true,
        }
    }

    /// Global invariant check (used by property tests and the driver's
    /// debug-build measure-window assertions): for every committed Home
    /// entry at vault H pointing to S there is a matching committed Holder
    /// entry at S pointing back to H, and vice versa. Pending entries are
    /// exempt (their peers commit at different cycles).
    pub fn directory_consistent(&self, now: Cycle) -> Result<(), String> {
        self.scan_directory(now, false)
    }

    /// Like [`Self::directory_consistent`], but tolerant of the protocol's
    /// own §III-B4 eager-eviction race: a committed Home entry whose peer
    /// has no entry (a fresh holder victimized inside the handshake-ack
    /// window leaves the home side to commit against an already-invalidated
    /// peer). That signature is modeled hardware behavior, present since
    /// the original monolith; every *other* inconsistency still errors, and
    /// the scan keeps going past tolerated orphans so they cannot mask a
    /// genuine corruption elsewhere. The driver's measure-window boundary
    /// check uses this variant.
    pub fn directory_consistent_modeled(&self, now: Cycle) -> Result<(), String> {
        self.scan_directory(now, true)
    }

    fn scan_directory(&self, now: Cycle, tolerate_home_orphans: bool) -> Result<(), String> {
        for (h, table) in self.tables.iter().enumerate() {
            let ways = table.ways();
            for idx in 0..table.num_sets() as usize * ways {
                let e = table.entry(idx);
                if e.is_invalid() || e.state != SubState::Subscribed || e.ready_at > now {
                    continue;
                }
                let peer_table = &self.tables[e.peer as usize];
                let set = self.map.set_of_block(e.block);
                let mut found = false;
                for w in 0..ways {
                    let pe = peer_table.entry(set as usize * ways + w);
                    if !pe.is_invalid() && pe.block == e.block {
                        found = true;
                        let want = match e.role {
                            Role::Home => Role::Holder,
                            Role::Holder => Role::Home,
                        };
                        if pe.role != want && pe.state == SubState::Subscribed {
                            return Err(format!(
                                "vault {h} block {} role mismatch at peer {}",
                                e.block, e.peer
                            ));
                        }
                    }
                }
                if !found {
                    if tolerate_home_orphans && e.role == Role::Home {
                        continue;
                    }
                    return Err(format!(
                        "vault {h} block {} ({:?}) has no peer entry at {}",
                        e.block, e.role, e.peer
                    ));
                }
            }
        }
        Ok(())
    }

    /// Age every vault's LFU counters (called at epoch boundaries).
    pub fn decay_all(&mut self) {
        for t in &mut self.tables {
            t.decay();
        }
    }

    /// [`Self::decay_all`] partitioned over up to `threads` OS threads in
    /// home-vault chunks — the event kernel's epoch-barrier fan-out. Each
    /// vault's table is touched by exactly one thread and `decay` reads
    /// and writes only that table's own counters, so the result is
    /// identical at any thread count (disjoint state, no ordering).
    pub fn decay_partitioned(&mut self, threads: usize) {
        let threads = threads.clamp(1, self.tables.len().max(1));
        if threads <= 1 {
            self.decay_all();
            return;
        }
        let per = self.tables.len().div_ceil(threads);
        std::thread::scope(|scope| {
            for chunk in self.tables.chunks_mut(per) {
                scope.spawn(move || {
                    for t in chunk {
                        t.decay();
                    }
                });
            }
        });
    }

    /// Sum of holder occupancies (blocks parked anywhere).
    pub fn total_parked(&self) -> u64 {
        self.tables.iter().map(|t| t.holder_occupancy() as u64).sum()
    }

    /// Commit every pending state transition that has completed by `now`.
    /// State commits are otherwise lazy (applied on the next lookup of the
    /// entry's set); tests and end-of-run reports call this to observe the
    /// settled directory.
    pub fn settle(&mut self, now: Cycle) {
        for table in &mut self.tables {
            let (sets, ways) = (table.num_sets(), table.ways());
            for set in 0..sets {
                for w in 0..ways {
                    let idx = set as usize * ways + w;
                    let e = table.entry(idx);
                    if !e.is_invalid() {
                        // Re-drive the lazy commit through lookup.
                        let block = e.block;
                        table.lookup(set, block, now);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsys::{MemorySystem, ServedRequest};
    use crate::policy::{PolicyKind, PolicyRuntime};

    struct Rig {
        mem: MemorySystem,
        policy: PolicyRuntime,
    }

    fn rig(kind: PolicyKind) -> Rig {
        let mut cfg = SimConfig::hmc();
        cfg.policy = kind;
        Rig { mem: MemorySystem::new(&cfg), policy: PolicyRuntime::new(&cfg) }
    }

    fn small_rig(kind: PolicyKind, sets: u32, ways: u16) -> Rig {
        let mut cfg = SimConfig::hmc();
        cfg.policy = kind;
        cfg.sub_table_sets = sets;
        cfg.sub_table_ways = ways;
        Rig { mem: MemorySystem::new(&cfg), policy: PolicyRuntime::new(&cfg) }
    }

    fn read(rig: &mut Rig, requester: VaultId, block: u64, now: Cycle) -> ServedRequest {
        rig.mem.serve(Access { requester, block, write: false }, now, &rig.policy)
    }

    fn write(rig: &mut Rig, requester: VaultId, block: u64, now: Cycle) -> ServedRequest {
        rig.mem.serve(Access { requester, block, write: true }, now, &rig.policy)
    }

    #[test]
    fn local_home_access_has_no_network() {
        let mut r = rig(PolicyKind::Never);
        // Block 5 is homed at vault 5.
        let res = read(&mut r, 5, 5, 0);
        assert!(res.local);
        assert_eq!(res.network, 0);
        assert_eq!(res.served_by, 5);
        assert!(!res.subscribed_path);
    }

    #[test]
    fn baseline_remote_read_costs_k_plus_one_times_h() {
        let mut r = rig(PolicyKind::Never);
        // Requester 0 reads block homed at vault 31.
        let res = read(&mut r, 0, 31, 0);
        let h = r.mem.hops(0, 31) as u64;
        assert_eq!(res.network, (5 + 1) * h);
        assert_eq!(res.served_by, 31);
        assert!(!res.local);
        assert!(res.array > 0);
    }

    #[test]
    fn never_policy_never_subscribes() {
        let mut r = rig(PolicyKind::Never);
        for t in 0..10 {
            read(&mut r, 0, 31, t * 1000);
        }
        assert_eq!(r.mem.stats().subscriptions, 0);
        assert_eq!(r.mem.total_parked(), 0);
    }

    #[test]
    fn always_policy_subscribes_on_first_access() {
        let mut r = rig(PolicyKind::Always);
        read(&mut r, 0, 31, 0);
        assert_eq!(r.mem.stats().subscriptions, 1);
        // After the transfer settles, the block is parked at vault 0.
        let res = read(&mut r, 0, 31, 100_000);
        assert!(res.local, "second access must hit reserved space");
        assert!(res.subscribed_path);
        assert_eq!(res.served_by, 0);
        assert_eq!(r.mem.stats().reuse.local_hits, 1);
    }

    #[test]
    fn subscription_is_off_critical_path() {
        let mut base = rig(PolicyKind::Never);
        let mut sub = rig(PolicyKind::Always);
        let b = read(&mut base, 0, 31, 0);
        let s = read(&mut sub, 0, 31, 0);
        // First access latency identical: the block moves in background.
        assert_eq!(b.done, s.done);
    }

    #[test]
    fn remote_access_to_subscribed_block_takes_three_legs() {
        let mut r = rig(PolicyKind::Always);
        read(&mut r, 0, 31, 0); // vault 0 subscribes block 31
        let t = 100_000;
        let res = read(&mut r, 2, 31, t);
        // Path: 2 -> 31 (home) -> 0 (holder) -> 2.
        assert_eq!(res.served_by, 0);
        assert!(res.subscribed_path);
        let h_ro = r.mem.hops(2, 31);
        let h_so = r.mem.hops(31, 0);
        let h_rs = r.mem.hops(0, 2);
        assert_eq!(res.actual_hops, h_ro + h_so + h_rs);
        assert_eq!(res.network as u32, h_ro + h_so + 5 * h_rs);
        assert_eq!(r.mem.stats().reuse.remote_hits, 1);
    }

    #[test]
    fn resubscription_moves_block_between_holders() {
        let mut r = rig(PolicyKind::Always);
        read(&mut r, 0, 31, 0);
        // Vault 2's access triggers a resubscription pulling it from 0.
        read(&mut r, 2, 31, 100_000);
        assert_eq!(r.mem.stats().resubscriptions, 1);
        let res = read(&mut r, 2, 31, 200_000);
        assert!(res.local, "block must now live at vault 2");
        r.mem.directory_consistent(300_000).unwrap();
        assert_eq!(r.mem.total_parked(), 1, "exactly one copy exists");
    }

    #[test]
    fn writes_set_dirty_and_unsub_ships_data() {
        let mut r = small_rig(PolicyKind::Always, 1, 1);
        // One set, one way per vault: second subscription evicts the first.
        read(&mut r, 0, 31, 0); // read-fill subscribes block 31 to vault 0
        let t = 100_000;
        // Writeback hits the parked copy locally and sets dirty.
        let res = write(&mut r, 0, 31, t);
        assert!(res.local);
        let sub_bytes_before = r.mem.stats().traffic.subscription_bytes;
        // Subscribe a different block: same set -> victim unsub of block 31.
        read(&mut r, 0, 63, 2 * t);
        assert!(r.mem.stats().unsubscriptions >= 1);
        let delta = r.mem.stats().traffic.subscription_bytes - sub_bytes_before;
        // Dirty unsub must carry a k-FLIT payload home: >= 5 flits * 16 B *
        // hops(0,31).
        let h = r.mem.hops(0, 31) as u64;
        assert!(delta as u64 >= 5 * 16 * h, "dirty data must travel, delta={delta}");
    }

    #[test]
    fn clean_unsub_sends_ack_only() {
        let mut r = small_rig(PolicyKind::Always, 1, 1);
        read(&mut r, 0, 31, 0); // clean subscription
        let before = r.mem.stats().traffic.subscription_bytes;
        read(&mut r, 0, 63, 100_000); // evicts block 31, clean
        let delta = r.mem.stats().traffic.subscription_bytes - before;
        // Unsub leg for clean block: 1 FLIT + 1 FLIT ack, plus the new
        // subscription's own packets (1 + 5 + 1 over h hops).
        let h = r.mem.hops(0, 31) as u64;
        let dirty_cost = 5 * 16 * h;
        assert!(
            (delta as u64) < dirty_cost + (1 + 5 + 1) * 16 * h,
            "clean unsub must not ship the block (delta={delta})"
        );
        assert_eq!(r.mem.stats().unsubscriptions, 1);
    }

    #[test]
    fn home_vault_pulls_its_block_back() {
        let mut r = rig(PolicyKind::Always);
        read(&mut r, 0, 31, 0); // parked at 0
        // Home vault 31 accesses its own block -> served via holder, then
        // unsubscribed home.
        let res = read(&mut r, 31, 31, 100_000);
        assert!(res.subscribed_path);
        assert_eq!(res.served_by, 0);
        assert_eq!(r.mem.stats().unsubscriptions, 1);
        // After the recall completes the access is plain local again.
        let res = read(&mut r, 31, 31, 300_000);
        assert!(res.local);
        assert!(!res.subscribed_path);
        r.mem.settle(400_000);
        assert_eq!(r.mem.total_parked(), 0);
    }

    #[test]
    fn directory_stays_consistent_under_churn() {
        let mut r = rig(PolicyKind::Always);
        let mut t = 0u64;
        for i in 0..500u64 {
            let requester = (i * 7 % 32) as u16;
            let block = i * 13 % 256;
            read(&mut r, requester, block, t);
            t += 500;
        }
        r.mem.directory_consistent(t + 1_000_000).unwrap();
    }

    #[test]
    fn nack_when_set_fully_pending() {
        let mut r = small_rig(PolicyKind::Always, 1, 1);
        read(&mut r, 0, 31, 0); // pending subscription fills the only way
        // Immediately request another block in the same set: victim is
        // pending -> NACK.
        read(&mut r, 0, 63, 1);
        assert!(r.mem.stats().sub_nacks >= 1);
    }

    #[test]
    fn reuse_counters_split_local_remote() {
        let mut r = rig(PolicyKind::Always);
        read(&mut r, 0, 31, 0);
        let t = 100_000;
        read(&mut r, 0, 31, t); // local
        read(&mut r, 1, 31, t + 1000); // remote (and triggers resub)
        assert_eq!(r.mem.stats().reuse.subscriptions, 2); // original + resub
        assert_eq!(r.mem.stats().reuse.local_hits, 1);
        assert_eq!(r.mem.stats().reuse.remote_hits, 1);
    }

    #[test]
    fn subscribed_local_hits_count_demand_at_holder() {
        let mut r = rig(PolicyKind::Always);
        read(&mut r, 0, 31, 0);
        let before = r.mem.stats().demand.counts()[0];
        read(&mut r, 0, 31, 100_000);
        assert_eq!(r.mem.stats().demand.counts()[0], before + 1);
    }
}

//! The subscription protocol engine (§III-B / §III-C): serves every demand
//! request through the distributed subscription tables and runs the
//! subscription / resubscription / unsubscription packet flows.
//!
//! Timing follows the paper's cost model exactly:
//! * baseline read: request (1 FLIT) requester→home, data (k FLITs) back —
//!   `(k+1)·h_ro` uncontended;
//! * DL-PIM read of a block subscribed elsewhere: `h_ro + h_so + k·h_rs`
//!   (request to home, forward to holder, data to requester);
//! * local read of a block subscribed *here*: no network at all — the whole
//!   point of the architecture.
//!
//! Subscription flows run *off* the demand critical path (the block is
//! moved in the background) but their packets contend for the same links
//! and their copies occupy the same banks, which is how always-subscribe
//! manages to hurt low-reuse workloads (Fig 9, PLYgemm / PLY3mm).

use crate::config::SimConfig;
use crate::policy::PolicyRuntime;
use crate::sim::{AddressMap, Mesh, PacketKind, VaultMem};
use crate::stats::SimStats;
use crate::subscription::buffer::SubBuffer;
use crate::subscription::count_table::CountTable;
use crate::subscription::table::{Role, SubState, SubTable};
use crate::{Cycle, VaultId};

/// Reserved-space addresses live far above the interleaved heap so bank
/// and row-buffer behaviour of parked blocks is modeled but never collides
/// with home addresses.
const RESERVED_BASE: u64 = 1 << 40;

/// One demand access from a PIM core.
#[derive(Clone, Copy, Debug)]
pub struct Access {
    pub requester: VaultId,
    pub block: u64,
    pub write: bool,
}

/// Timing/result decomposition of one served demand access.
#[derive(Clone, Copy, Debug, Default)]
pub struct RequestResult {
    /// Completion cycle.
    pub done: Cycle,
    /// Pure transfer cycles (FLIT serialization x hops).
    pub network: u64,
    /// Waits: busy links, controller port, busy banks, pending states.
    pub queued: u64,
    /// Portion of `queued` spent waiting on busy mesh links.
    pub queued_net: u64,
    /// DRAM array cycles.
    pub array: u64,
    /// Vault whose memory served the data.
    pub served_by: VaultId,
    /// True if no packet left the requester vault.
    pub local: bool,
    /// Hops actually traversed by all legs of this request.
    pub actual_hops: u32,
    /// One-way requester→home distance (the unsubscribed estimate).
    pub baseline_hops: u32,
    /// True if a subscription-table redirect or holder hit was involved.
    pub subscribed_path: bool,
    /// Subscription-table set of the accessed block.
    pub set: u32,
}

/// The distributed subscription system: one table + buffer per vault.
pub struct SubSystem {
    tables: Vec<SubTable>,
    buffers: Vec<SubBuffer>,
    counts: Option<CountTable>,
    map: AddressMap,
    k: u32,
    flit_bytes: u32,
    count_threshold: u32,
}

impl SubSystem {
    pub fn new(cfg: &SimConfig) -> Self {
        let n = cfg.n_vaults as usize;
        SubSystem {
            tables: (0..n)
                .map(|_| SubTable::new(cfg.sub_table_sets, cfg.sub_table_ways))
                .collect(),
            buffers: (0..n).map(|_| SubBuffer::new(cfg.sub_buffer_entries)).collect(),
            counts: if cfg.count_threshold > 0 {
                Some(CountTable::new(8192))
            } else {
                None
            },
            map: AddressMap::new(cfg),
            k: cfg.data_packet_flits(),
            flit_bytes: cfg.flit_bytes,
            count_threshold: cfg.count_threshold,
        }
    }

    pub fn reset(&mut self) {
        for t in &mut self.tables {
            t.reset();
        }
        for b in &mut self.buffers {
            b.reset();
        }
        if let Some(c) = &mut self.counts {
            c.reset();
        }
    }

    pub fn table(&self, v: VaultId) -> &SubTable {
        &self.tables[v as usize]
    }

    pub fn map(&self) -> &AddressMap {
        &self.map
    }

    #[inline]
    fn home_addr(block: u64) -> u64 {
        block << 6 // only row/bank mapping matters; 64 B blocks
    }

    /// Address of a reserved-space slot. The reserved area is a small
    /// *contiguous* region (8192 slots x 64 B = 512 KB per vault, §IV-C),
    /// addressed by table slot — so churning subscriptions sweep compact
    /// rows (four slots per 256 B row buffer) instead of scattering
    /// row-misses across the address space.
    #[inline]
    fn reserved_slot_addr(entry_idx: usize) -> u64 {
        RESERVED_BASE + ((entry_idx as u64) << 6)
    }

    /// Ship one packet and record its traffic.
    fn send(
        &mut self,
        mesh: &mut Mesh,
        stats: &mut SimStats,
        kind: PacketKind,
        flits: u32,
        from: VaultId,
        to: VaultId,
        at: Cycle,
    ) -> crate::sim::Transfer {
        let tr = mesh.transfer(from, to, flits, at);
        stats
            .traffic
            .record(flits, tr.hops, self.flit_bytes, kind.is_subscription_traffic());
        tr
    }

    /// Serve one demand access end to end. The driver is responsible for
    /// recording the returned breakdown and feeding the policy registers.
    pub fn serve(
        &mut self,
        req: Access,
        now: Cycle,
        mesh: &mut Mesh,
        vaults: &mut [VaultMem],
        stats: &mut SimStats,
        policy: &PolicyRuntime,
    ) -> RequestResult {
        let block = req.block;
        let r = req.requester;
        let home = self.map.home_of_block(block);
        let set = self.map.set_of_block(block);
        let baseline_hops = mesh.hops(r, home);

        let mut out = RequestResult {
            set,
            baseline_hops,
            served_by: home,
            ..Default::default()
        };

        // ---- Fast path: block parked in this vault's reserved space. ----
        if home != r {
            if let Some(i) = self.tables[r as usize].lookup(set, block, now) {
                let e = *self.tables[r as usize].entry(i);
                if e.role == Role::Holder
                    && e.state == SubState::Subscribed
                    && e.ready_at <= now
                {
                    let acc =
                        vaults[r as usize].access(Self::reserved_slot_addr(i), now);
                    self.tables[r as usize].touch(i, now);
                    if req.write {
                        self.tables[r as usize].entry_mut(i).dirty = true;
                    }
                    stats.reuse.on_local_hit();
                    stats.demand.record(r);
                    stats.local_requests += 1;
                    out.done = acc.done;
                    out.queued = acc.queued;
                    out.array = acc.array;
                    out.served_by = r;
                    out.local = true;
                    out.subscribed_path = true;
                    return out;
                }
                // Pending entry: the move is in flight. The request follows
                // the normal remote path; no new subscription is started
                // (the in-flight one will land).
                return self.serve_remote(req, now, home, set, mesh, vaults, stats, &mut out);
            }
        }

        // ---- Home-local access (requester is the home vault). ----
        if home == r {
            if let Some(i) = self.tables[r as usize].lookup(set, block, now) {
                let e = *self.tables[r as usize].entry(i);
                if e.role == Role::Home && !e.is_invalid() {
                    // Block subscribed away; §III-D4's special case — the
                    // home vault itself needs it back. Serve via the holder
                    // and (policy permitting) pull it home (unsubscribe).
                    let holder = e.peer;
                    let res = self.serve_via_holder(
                        req, now, home, holder, set, mesh, vaults, stats, &mut out,
                    );
                    if e.state == SubState::Subscribed
                        && e.ready_at <= now
                        && policy.enabled(r, set, now)
                    {
                        self.unsubscribe_home_initiated(home, block, set, now, mesh, vaults, stats);
                    }
                    return res;
                }
            }
            // Plain local access at home.
            let acc = vaults[r as usize].access(Self::home_addr(block), now);
            stats.demand.record(r);
            stats.local_requests += 1;
            out.done = acc.done;
            out.queued = acc.queued;
            out.array = acc.array;
            out.served_by = r;
            out.local = true;
            return out;
        }

        // ---- Remote access through the home vault. ----
        // Writes never subscribe from the writer side (§III-C: "the
        // requester vault writes the data to the original vault", which
        // forwards to the holder if any). Only reads subscribe — their
        // data transfer is the one the baseline already pays, so the
        // subscription piggybacks for free (§IV-B1). A block made hot by
        // read-fills parks locally; later writebacks then hit the fast
        // path above with zero network cost.
        let res = self.serve_remote(req, now, home, set, mesh, vaults, stats, &mut out);
        let enabled = policy.enabled(r, set, now);
        if !req.write && enabled && self.count_filter(block) {
            // Piggybacked subscription: the demand response already moved
            // the block to the requester (§III-A's combined packet format);
            // only the acknowledgements travel separately.
            self.subscribe_piggyback(r, block, home, set, now, res.done, mesh, vaults, stats);
        } else if !enabled && res.subscribed_path && !res.local {
            // Subscriptions are off for this set but the block is still
            // parked remotely, taxing every access with the three-leg
            // indirection. Drain it home — the home-initiated
            // unsubscription of §III-B4, triggered by the epoch decision
            // instead of a home access.
            self.unsubscribe_home_initiated(home, block, set, res.done, mesh, vaults, stats);
        }
        res
    }

    /// Count-threshold filter (ablation §III-A); true = may subscribe.
    fn count_filter(&mut self, block: u64) -> bool {
        if self.count_threshold == 0 {
            return true;
        }
        match &mut self.counts {
            Some(c) => {
                c.bump(block);
                c.over_threshold(block, self.count_threshold)
            }
            None => true,
        }
    }

    /// Remote demand path: requester → home (→ holder) → requester.
    #[allow(clippy::too_many_arguments)]
    fn serve_remote(
        &mut self,
        req: Access,
        now: Cycle,
        home: VaultId,
        set: u32,
        mesh: &mut Mesh,
        vaults: &mut [VaultMem],
        stats: &mut SimStats,
        out: &mut RequestResult,
    ) -> RequestResult {
        let r = req.requester;
        let block = req.block;

        // Leg 1: request (reads: 1 FLIT; writes carry the block: k FLITs).
        let (req_kind, req_flits) = if req.write {
            (PacketKind::MemWrite, self.k)
        } else {
            (PacketKind::MemReadReq, 1)
        };
        let t1 = self.send(mesh, stats, req_kind, req_flits, r, home, now);
        out.network += t1.network;
        out.queued += t1.queued;
        out.queued_net += t1.queued;
        out.actual_hops += t1.hops;

        // Home-side directory lookup.
        let holder = match self.tables[home as usize].lookup(set, block, t1.arrive) {
            Some(i) => {
                let e = *self.tables[home as usize].entry(i);
                match (e.role, e.state) {
                    (Role::Home, SubState::Subscribed) if e.ready_at <= t1.arrive => {
                        Some(e.peer)
                    }
                    // Pending resubscription: old holder still owns the
                    // data (peer field) until the move commits.
                    (Role::Home, SubState::PendingResub) => Some(e.peer),
                    // Subscription data still in flight: home copy valid.
                    (Role::Home, SubState::PendingSub) => None,
                    // Returning home: the home copy is already valid for
                    // clean blocks (the dirty hint is recorded when the
                    // unsubscription starts); only dirty returns must be
                    // waited for.
                    (Role::Home, SubState::PendingUnsub) => {
                        if e.dirty && t1.arrive < e.ready_at {
                            out.queued += e.ready_at - t1.arrive;
                        }
                        None
                    }
                    _ => None,
                }
            }
            None => None,
        };

        match holder {
            None => {
                // Serve at home (after any pending-unsubscription wait that
                // was already added to out.queued above).
                let wait_extra = out.queued - t1.queued;
                let acc =
                    vaults[home as usize].access(Self::home_addr(block), t1.arrive + wait_extra);
                out.queued += acc.queued;
                out.array += acc.array;
                out.served_by = home;
                stats.demand.record(home);
                if req.write {
                    out.done = acc.done;
                } else {
                    let t2 = self.send(
                        mesh,
                        stats,
                        PacketKind::MemReadResp,
                        self.k,
                        home,
                        r,
                        acc.done,
                    );
                    out.network += t2.network;
                    out.queued += t2.queued;
                    out.queued_net += t2.queued;
                    out.actual_hops += t2.hops;
                    out.done = t2.arrive;
                }
                *out
            }
            Some(s) => {
                out.subscribed_path = true;
                self.forward_to_holder(req, t1.arrive, home, s, set, mesh, vaults, stats, out)
            }
        }
    }

    /// Home has redirected the request to the holder vault `s`.
    #[allow(clippy::too_many_arguments)]
    fn forward_to_holder(
        &mut self,
        req: Access,
        at: Cycle,
        home: VaultId,
        s: VaultId,
        set: u32,
        mesh: &mut Mesh,
        vaults: &mut [VaultMem],
        stats: &mut SimStats,
        out: &mut RequestResult,
    ) -> RequestResult {
        let r = req.requester;
        let block = req.block;
        let (fwd_kind, fwd_flits) = if req.write {
            (PacketKind::MemWriteFwd, self.k)
        } else {
            (PacketKind::MemReadReq, 1)
        };
        let f = self.send(mesh, stats, fwd_kind, fwd_flits, home, s, at);
        out.network += f.network;
        out.queued += f.queued;
        out.queued_net += f.queued;
        out.actual_hops += f.hops;

        // Reuse bookkeeping on the holder's entry; its slot addresses the
        // reserved-space access.
        let slot = self.tables[s as usize].lookup(set, block, f.arrive);
        let addr = match slot {
            Some(i) => Self::reserved_slot_addr(i),
            None => Self::home_addr(block), // directory raced; charge a row
        };
        let acc = vaults[s as usize].access(addr, f.arrive);
        out.queued += acc.queued;
        out.array += acc.array;
        out.served_by = s;
        stats.demand.record(s);
        if let Some(i) = slot {
            self.tables[s as usize].touch(i, f.arrive);
            if req.write {
                self.tables[s as usize].entry_mut(i).dirty = true;
            }
        }
        if s == r {
            stats.reuse.on_local_hit();
            stats.local_requests += 1;
        } else {
            stats.reuse.on_remote_hit();
        }

        if req.write {
            out.done = acc.done;
        } else {
            let t2 = self.send(mesh, stats, PacketKind::MemReadResp, self.k, s, r, acc.done);
            out.network += t2.network;
            out.queued += t2.queued;
            out.queued_net += t2.queued;
            out.actual_hops += t2.hops;
            out.done = t2.arrive;
        }
        *out
    }

    /// Home-vault access to its own block that is subscribed away.
    #[allow(clippy::too_many_arguments)]
    fn serve_via_holder(
        &mut self,
        req: Access,
        now: Cycle,
        home: VaultId,
        holder: VaultId,
        set: u32,
        mesh: &mut Mesh,
        vaults: &mut [VaultMem],
        stats: &mut SimStats,
        out: &mut RequestResult,
    ) -> RequestResult {
        out.subscribed_path = true;
        self.forward_to_holder(req, now, home, holder, set, mesh, vaults, stats, out)
    }

    // ------------------------------------------------------------------
    // Subscription flows (§III-B)
    // ------------------------------------------------------------------

    /// Allocate a requester-side way for a new holder entry, evicting (and
    /// unsubscribing) a victim if needed. Returns `(way, usable_at)` or
    /// `None` on NACK.
    fn alloc_requester_way(
        &mut self,
        r: VaultId,
        set: u32,
        now: Cycle,
        mesh: &mut Mesh,
        vaults: &mut [VaultMem],
        stats: &mut SimStats,
    ) -> Option<(usize, Cycle)> {
        match self.tables[r as usize].free_way(set) {
            Some(w) => Some((w, now)),
            None => {
                let v = self.tables[r as usize].victim(set)?;
                let t_free = self.unsubscribe_victim(r, v, now, mesh, vaults, stats);
                if !self.buffers[r as usize].try_push(now, t_free) {
                    return None; // subscription buffer full (§III-B3)
                }
                // The way is architecturally free at t_free: materialize
                // the eviction now (the flow's packets are in flight; the
                // peer side commits lazily) and reuse the slot.
                self.tables[r as usize].invalidate(v);
                Some((v, t_free))
            }
        }
    }

    /// Subscribe `block` to `r` piggybacked on a completed demand read:
    /// the data already travelled home→requester (or holder→requester) in
    /// the demand response, so only table updates and 1-FLIT acks move.
    /// `data_at` is the demand response arrival (when the holder copy
    /// becomes usable).
    #[allow(clippy::too_many_arguments)]
    fn subscribe_piggyback(
        &mut self,
        r: VaultId,
        block: u64,
        home: VaultId,
        set: u32,
        now: Cycle,
        data_at: Cycle,
        mesh: &mut Mesh,
        vaults: &mut [VaultMem],
        stats: &mut SimStats,
    ) {
        // Already tracked (any state) at the requester? Nothing to do.
        if self.tables[r as usize].lookup(set, block, now).is_some() {
            return;
        }
        let Some((way_r, usable)) =
            self.alloc_requester_way(r, set, now, mesh, vaults, stats)
        else {
            stats.sub_nacks += 1;
            return;
        };

        // Home-side directory update (the request travelled inside the
        // demand packet — §III-A's extended packet format).
        match self.tables[home as usize].lookup(set, block, now) {
            None => {
                let way_h = match self.home_way(home, set, now, mesh, vaults, stats) {
                    Some(w) => w,
                    None => {
                        self.nack(mesh, stats, home, r, now);
                        return;
                    }
                };
                // Both sides acknowledge (§III-B1): one control packet each
                // way, off the demand critical path.
                let ack = self.send(
                    mesh,
                    stats,
                    PacketKind::SubscriptionTransferAck,
                    1,
                    r,
                    home,
                    data_at,
                );
                self.tables[home as usize].install(
                    way_h,
                    block,
                    Role::Home,
                    r,
                    SubState::PendingSub,
                    ack.arrive,
                    now,
                );
                self.tables[r as usize].install(
                    way_r,
                    block,
                    Role::Holder,
                    home,
                    SubState::PendingSub,
                    usable.max(data_at),
                    now,
                );
                stats.subscriptions += 1;
                stats.reuse.on_subscribe();
            }
            Some(i) => {
                let e = *self.tables[home as usize].entry(i);
                if e.state != SubState::Subscribed || e.ready_at > now {
                    // Mid-handshake with another vault: NACK (§III-B3).
                    self.nack(mesh, stats, home, r, now);
                    return;
                }
                let s = e.peer;
                if s == r {
                    return; // already ours (raced with the fast path)
                }
                self.resubscribe(r, block, home, s, i, set, now, data_at, false, mesh, vaults, stats, way_r, usable);
            }
        }
    }


    /// Home-side way allocation (§III-B1's original-vault space check).
    fn home_way(
        &mut self,
        home: VaultId,
        set: u32,
        at: Cycle,
        mesh: &mut Mesh,
        vaults: &mut [VaultMem],
        stats: &mut SimStats,
    ) -> Option<usize> {
        match self.tables[home as usize].free_way(set) {
            Some(w) => Some(w),
            None => {
                let v = self.tables[home as usize].victim(set)?;
                let t_free = self.unsubscribe_victim(home, v, at, mesh, vaults, stats);
                if !self.buffers[home as usize].try_push(at, t_free) {
                    return None;
                }
                self.tables[home as usize].invalidate(v);
                Some(v)
            }
        }
    }

    /// Resubscription (§III-B2): the block moves from holder `s` to the
    /// new requester `r`. On the read path the data travelled in the
    /// demand response; on the write path (`write_in_place`) the requester
    /// already has it — either way only control packets move here: the
    /// forward notification home→old-holder and the two acknowledgements.
    #[allow(clippy::too_many_arguments)]
    fn resubscribe(
        &mut self,
        r: VaultId,
        block: u64,
        home: VaultId,
        s: VaultId,
        home_idx: usize,
        set: u32,
        at: Cycle,
        data_at: Cycle,
        write_in_place: bool,
        mesh: &mut Mesh,
        _vaults: &mut [VaultMem],
        stats: &mut SimStats,
        way_r: usize,
        usable: Cycle,
    ) {
        let fwd = self.send(mesh, stats, PacketKind::SubscriptionRequest, 1, home, s, at);
        // Holder-side entry moves to PendingResub.
        let dirty = match self.tables[s as usize].lookup(set, block, fwd.arrive) {
            Some(j) => {
                let es = self.tables[s as usize].entry_mut(j);
                if es.state != SubState::Subscribed {
                    // Holder busy with another flow: NACK back to the
                    // requester (its way was never installed; any victim
                    // eviction already in flight simply completes).
                    self.nack(mesh, stats, s, r, fwd.arrive);
                    return;
                }
                es.state = SubState::PendingResub;
                es.dirty
            }
            None => false, // directory raced; treat as clean
        };
        // Two acks: to the home (directory update) and to the old holder
        // (eviction) — §III-B2; the dirty bit rides the misc bits.
        let ack_h =
            self.send(mesh, stats, PacketKind::SubscriptionTransferAck, 1, r, home, data_at);
        let ack_s =
            self.send(mesh, stats, PacketKind::SubscriptionTransferAck, 1, r, s, data_at);
        {
            let eh = self.tables[home as usize].entry_mut(home_idx);
            eh.state = SubState::PendingResub;
            eh.peer_next = r;
            eh.ready_at = ack_h.arrive;
        }
        if let Some(j) = self.tables[s as usize].lookup(set, block, fwd.arrive) {
            let es = self.tables[s as usize].entry_mut(j);
            if es.state == SubState::PendingResub {
                es.ready_at = ack_s.arrive;
            }
        }
        self.tables[r as usize].install(
            way_r,
            block,
            Role::Holder,
            home,
            SubState::PendingSub,
            usable.max(data_at),
            data_at,
        );
        self.tables[r as usize].entry_mut(way_r).dirty = dirty || write_in_place;
        stats.resubscriptions += 1;
        stats.subscriptions += 1;
        stats.reuse.on_subscribe();
    }

    fn nack(
        &mut self,
        mesh: &mut Mesh,
        stats: &mut SimStats,
        from: VaultId,
        to: VaultId,
        at: Cycle,
    ) {
        self.send(mesh, stats, PacketKind::SubscriptionNack, 1, from, to, at);
        stats.sub_nacks += 1;
    }

    /// Unsubscribe the victim entry `idx` of vault `v` (capacity eviction).
    /// Returns the cycle at which `v`'s way is free again.
    fn unsubscribe_victim(
        &mut self,
        v: VaultId,
        idx: usize,
        now: Cycle,
        mesh: &mut Mesh,
        vaults: &mut [VaultMem],
        stats: &mut SimStats,
    ) -> Cycle {
        let e = *self.tables[v as usize].entry(idx);
        debug_assert_eq!(e.state, SubState::Subscribed);
        let set = self.map.set_of_block(e.block);
        match e.role {
            // Holder-initiated return (§III-B4, "subscribed vault wanting
            // to return the data"): data (or clean ack) home, ack back.
            Role::Holder => {
                let home = e.peer;
                // Read the parked block out of reserved space if dirty.
                let depart = if e.dirty {
                    vaults[v as usize].access(Self::reserved_slot_addr(idx), now).done
                } else {
                    now
                };
                let kind = PacketKind::UnsubscriptionData { dirty: e.dirty };
                let flits = if e.dirty { self.k } else { 1 };
                let data = self.send(mesh, stats, kind, flits, v, home, depart);
                if e.dirty {
                    vaults[home as usize].access(Self::home_addr(e.block), data.arrive);
                }
                let ack = self.send(
                    mesh,
                    stats,
                    PacketKind::UnsubscriptionTransferAck,
                    1,
                    home,
                    v,
                    data.arrive,
                );
                self.tables[v as usize].begin_unsub(idx, ack.arrive);
                // Free the home's directory entry when the data lands,
                // recording whether a dirty block is in flight (clean
                // returns leave the home copy servable immediately).
                if let Some(j) = self.tables[home as usize].lookup(set, e.block, now) {
                    if self.tables[home as usize].entry(j).state == SubState::Subscribed {
                        self.tables[home as usize].entry_mut(j).dirty = e.dirty;
                        self.tables[home as usize].begin_unsub(j, data.arrive);
                    }
                }
                stats.unsubscriptions += 1;
                ack.arrive
            }
            // Home-initiated recall (§III-B4, "original vault wanting the
            // data back"): request to the holder, data returns.
            Role::Home => {
                let holder = e.peer;
                let req = self.send(
                    mesh,
                    stats,
                    PacketKind::UnsubscriptionRequest,
                    1,
                    v,
                    holder,
                    now,
                );
                let mut dirty = false;
                if let Some(j) = self.tables[holder as usize].lookup(set, e.block, req.arrive)
                {
                    let eh = self.tables[holder as usize].entry(j);
                    if eh.state == SubState::Subscribed {
                        dirty = eh.dirty;
                    }
                }
                let depart = if dirty {
                    let j = self.tables[holder as usize]
                        .lookup(set, e.block, req.arrive)
                        .expect("dirty holder entry present");
                    vaults[holder as usize]
                        .access(Self::reserved_slot_addr(j), req.arrive)
                        .done
                } else {
                    req.arrive
                };
                let kind = PacketKind::UnsubscriptionData { dirty };
                let flits = if dirty { self.k } else { 1 };
                let data = self.send(mesh, stats, kind, flits, holder, v, depart);
                if dirty {
                    vaults[v as usize].access(Self::home_addr(e.block), data.arrive);
                }
                let ack = self.send(
                    mesh,
                    stats,
                    PacketKind::UnsubscriptionTransferAck,
                    1,
                    v,
                    holder,
                    data.arrive,
                );
                self.tables[v as usize].entry_mut(idx).dirty = dirty;
                self.tables[v as usize].begin_unsub(idx, data.arrive);
                if let Some(j) = self.tables[holder as usize].lookup(set, e.block, req.arrive)
                {
                    if self.tables[holder as usize].entry(j).state == SubState::Subscribed {
                        self.tables[holder as usize].begin_unsub(j, ack.arrive);
                    }
                }
                stats.unsubscriptions += 1;
                data.arrive
            }
        }
    }

    /// §III-B4 special case: the home vault needs its own block back — the
    /// subscription request "converts into an unsubscription request".
    #[allow(clippy::too_many_arguments)]
    fn unsubscribe_home_initiated(
        &mut self,
        home: VaultId,
        block: u64,
        set: u32,
        now: Cycle,
        mesh: &mut Mesh,
        vaults: &mut [VaultMem],
        stats: &mut SimStats,
    ) {
        if let Some(i) = self.tables[home as usize].lookup(set, block, now) {
            let e = *self.tables[home as usize].entry(i);
            if e.role == Role::Home && e.state == SubState::Subscribed && e.ready_at <= now {
                self.unsubscribe_victim(home, i, now, mesh, vaults, stats);
            }
        }
    }

    /// Global invariant check (used by property tests): for every committed
    /// Home entry at vault H pointing to S there is a matching committed
    /// Holder entry at S pointing back to H, and vice versa. Pending entries
    /// are exempt (their peers commit at different cycles).
    pub fn directory_consistent(&self, now: Cycle) -> Result<(), String> {
        for (h, table) in self.tables.iter().enumerate() {
            let ways = table.ways();
            for idx in 0..table.num_sets() as usize * ways {
                let e = table.entry(idx);
                if e.is_invalid() || e.state != SubState::Subscribed || e.ready_at > now {
                    continue;
                }
                let peer_table = &self.tables[e.peer as usize];
                let set = self.map.set_of_block(e.block);
                let mut found = false;
                for w in 0..ways {
                    let pe = peer_table.entry(set as usize * ways + w);
                    if !pe.is_invalid() && pe.block == e.block {
                        found = true;
                        let want = match e.role {
                            Role::Home => Role::Holder,
                            Role::Holder => Role::Home,
                        };
                        if pe.role != want && pe.state == SubState::Subscribed {
                            return Err(format!(
                                "vault {h} block {} role mismatch at peer {}",
                                e.block, e.peer
                            ));
                        }
                    }
                }
                if !found {
                    return Err(format!(
                        "vault {h} block {} ({:?}) has no peer entry at {}",
                        e.block, e.role, e.peer
                    ));
                }
            }
        }
        Ok(())
    }

    /// Age every vault's LFU counters (called at epoch boundaries).
    pub fn decay_all(&mut self) {
        for t in &mut self.tables {
            t.decay();
        }
    }

    /// Sum of holder occupancies (blocks parked anywhere).
    pub fn total_parked(&self) -> u64 {
        self.tables.iter().map(|t| t.holder_occupancy() as u64).sum()
    }

    /// Commit every pending state transition that has completed by `now`.
    /// State commits are otherwise lazy (applied on the next lookup of the
    /// entry's set); tests and end-of-run reports call this to observe the
    /// settled directory.
    pub fn settle(&mut self, now: Cycle) {
        for table in &mut self.tables {
            let (sets, ways) = (table.num_sets(), table.ways());
            for set in 0..sets {
                for w in 0..ways {
                    let idx = set as usize * ways + w;
                    let e = table.entry(idx);
                    if !e.is_invalid() {
                        // Re-drive the lazy commit through lookup.
                        let block = e.block;
                        table.lookup(set, block, now);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::PolicyKind;

    struct Rig {
        sys: SubSystem,
        mesh: Mesh,
        vaults: Vec<VaultMem>,
        stats: SimStats,
        policy: PolicyRuntime,
    }

    fn rig(kind: PolicyKind) -> Rig {
        let mut cfg = SimConfig::hmc();
        cfg.policy = kind;
        let mesh = Mesh::new(&cfg);
        Rig {
            sys: SubSystem::new(&cfg),
            mesh,
            vaults: (0..cfg.n_vaults).map(|_| VaultMem::new(&cfg)).collect(),
            stats: SimStats::new(cfg.n_vaults),
            policy: PolicyRuntime::new(&cfg),
        }
    }

    fn small_rig(kind: PolicyKind, sets: u32, ways: u16) -> (Rig, SimConfig) {
        let mut cfg = SimConfig::hmc();
        cfg.policy = kind;
        cfg.sub_table_sets = sets;
        cfg.sub_table_ways = ways;
        let mesh = Mesh::new(&cfg);
        (
            Rig {
                sys: SubSystem::new(&cfg),
                mesh,
                vaults: (0..cfg.n_vaults).map(|_| VaultMem::new(&cfg)).collect(),
                stats: SimStats::new(cfg.n_vaults),
                policy: PolicyRuntime::new(&cfg),
            },
            cfg,
        )
    }

    fn read(rig: &mut Rig, requester: VaultId, block: u64, now: Cycle) -> RequestResult {
        rig.sys.serve(
            Access { requester, block, write: false },
            now,
            &mut rig.mesh,
            &mut rig.vaults,
            &mut rig.stats,
            &rig.policy,
        )
    }

    fn write(rig: &mut Rig, requester: VaultId, block: u64, now: Cycle) -> RequestResult {
        rig.sys.serve(
            Access { requester, block, write: true },
            now,
            &mut rig.mesh,
            &mut rig.vaults,
            &mut rig.stats,
            &rig.policy,
        )
    }

    #[test]
    fn local_home_access_has_no_network() {
        let mut r = rig(PolicyKind::Never);
        // Block 5 is homed at vault 5.
        let res = read(&mut r, 5, 5, 0);
        assert!(res.local);
        assert_eq!(res.network, 0);
        assert_eq!(res.served_by, 5);
        assert!(!res.subscribed_path);
    }

    #[test]
    fn baseline_remote_read_costs_k_plus_one_times_h() {
        let mut r = rig(PolicyKind::Never);
        // Requester 0 reads block homed at vault 31.
        let res = read(&mut r, 0, 31, 0);
        let h = r.mesh.hops(0, 31) as u64;
        assert_eq!(res.network, (5 + 1) * h);
        assert_eq!(res.served_by, 31);
        assert!(!res.local);
        assert!(res.array > 0);
    }

    #[test]
    fn never_policy_never_subscribes() {
        let mut r = rig(PolicyKind::Never);
        for t in 0..10 {
            read(&mut r, 0, 31, t * 1000);
        }
        assert_eq!(r.stats.subscriptions, 0);
        assert_eq!(r.sys.total_parked(), 0);
    }

    #[test]
    fn always_policy_subscribes_on_first_access() {
        let mut r = rig(PolicyKind::Always);
        read(&mut r, 0, 31, 0);
        assert_eq!(r.stats.subscriptions, 1);
        // After the transfer settles, the block is parked at vault 0.
        let res = read(&mut r, 0, 31, 100_000);
        assert!(res.local, "second access must hit reserved space");
        assert!(res.subscribed_path);
        assert_eq!(res.served_by, 0);
        assert_eq!(r.stats.reuse.local_hits, 1);
    }

    #[test]
    fn subscription_is_off_critical_path() {
        let mut base = rig(PolicyKind::Never);
        let mut sub = rig(PolicyKind::Always);
        let b = read(&mut base, 0, 31, 0);
        let s = read(&mut sub, 0, 31, 0);
        // First access latency identical: the block moves in background.
        assert_eq!(b.done, s.done);
    }

    #[test]
    fn remote_access_to_subscribed_block_takes_three_legs() {
        let mut r = rig(PolicyKind::Always);
        read(&mut r, 0, 31, 0); // vault 0 subscribes block 31
        let t = 100_000;
        let res = read(&mut r, 2, 31, t);
        // Path: 2 -> 31 (home) -> 0 (holder) -> 2.
        assert_eq!(res.served_by, 0);
        assert!(res.subscribed_path);
        let h_ro = r.mesh.hops(2, 31);
        let h_so = r.mesh.hops(31, 0);
        let h_rs = r.mesh.hops(0, 2);
        assert_eq!(res.actual_hops, h_ro + h_so + h_rs);
        assert_eq!(res.network as u32, h_ro + h_so + 5 * h_rs);
        assert_eq!(r.stats.reuse.remote_hits, 1);
    }

    #[test]
    fn resubscription_moves_block_between_holders() {
        let mut r = rig(PolicyKind::Always);
        read(&mut r, 0, 31, 0);
        // Vault 2's access triggers a resubscription pulling it from 0.
        read(&mut r, 2, 31, 100_000);
        assert_eq!(r.stats.resubscriptions, 1);
        let res = read(&mut r, 2, 31, 200_000);
        assert!(res.local, "block must now live at vault 2");
        r.sys.directory_consistent(300_000).unwrap();
        assert_eq!(r.sys.total_parked(), 1, "exactly one copy exists");
    }

    #[test]
    fn writes_set_dirty_and_unsub_ships_data() {
        let (mut r, _cfg) = small_rig(PolicyKind::Always, 1, 1);
        // One set, one way per vault: second subscription evicts the first.
        read(&mut r, 0, 31, 0); // read-fill subscribes block 31 to vault 0
        let t = 100_000;
        // Writeback hits the parked copy locally and sets dirty.
        let res = write(&mut r, 0, 31, t);
        assert!(res.local);
        let sub_bytes_before = r.stats.traffic.subscription_bytes;
        // Subscribe a different block: same set -> victim unsub of block 31.
        read(&mut r, 0, 63, 2 * t);
        assert!(r.stats.unsubscriptions >= 1);
        let delta = r.stats.traffic.subscription_bytes - sub_bytes_before;
        // Dirty unsub must carry a k-FLIT payload home: >= 5 flits * 16 B *
        // hops(0,31).
        let h = r.mesh.hops(0, 31) as u64;
        assert!(delta as u64 >= 5 * 16 * h, "dirty data must travel, delta={delta}");
    }

    #[test]
    fn clean_unsub_sends_ack_only() {
        let (mut r, _cfg) = small_rig(PolicyKind::Always, 1, 1);
        read(&mut r, 0, 31, 0); // clean subscription
        let before = r.stats.traffic.subscription_bytes;
        read(&mut r, 0, 63, 100_000); // evicts block 31, clean
        let delta = r.stats.traffic.subscription_bytes - before;
        // Unsub leg for clean block: 1 FLIT + 1 FLIT ack, plus the new
        // subscription's own packets (1 + 5 + 1 over h hops).
        let h = r.mesh.hops(0, 31) as u64;
        let dirty_cost = 5 * 16 * h;
        assert!(
            (delta as u64) < dirty_cost + (1 + 5 + 1) * 16 * h,
            "clean unsub must not ship the block (delta={delta})"
        );
        assert_eq!(r.stats.unsubscriptions, 1);
    }

    #[test]
    fn home_vault_pulls_its_block_back() {
        let mut r = rig(PolicyKind::Always);
        read(&mut r, 0, 31, 0); // parked at 0
        // Home vault 31 accesses its own block -> served via holder, then
        // unsubscribed home.
        let res = read(&mut r, 31, 31, 100_000);
        assert!(res.subscribed_path);
        assert_eq!(res.served_by, 0);
        assert_eq!(r.stats.unsubscriptions, 1);
        // After the recall completes the access is plain local again.
        let res = read(&mut r, 31, 31, 300_000);
        assert!(res.local);
        assert!(!res.subscribed_path);
        r.sys.settle(400_000);
        assert_eq!(r.sys.total_parked(), 0);
    }

    #[test]
    fn directory_stays_consistent_under_churn() {
        let mut r = rig(PolicyKind::Always);
        let mut t = 0u64;
        for i in 0..500u64 {
            let requester = (i * 7 % 32) as u16;
            let block = i * 13 % 256;
            read(&mut r, requester, block, t);
            t += 500;
        }
        r.sys.directory_consistent(t + 1_000_000).unwrap();
    }

    #[test]
    fn nack_when_set_fully_pending() {
        let (mut r, _cfg) = small_rig(PolicyKind::Always, 1, 1);
        read(&mut r, 0, 31, 0); // pending subscription fills the only way
        // Immediately request another block in the same set: victim is
        // pending -> NACK.
        read(&mut r, 0, 63, 1);
        assert!(r.stats.sub_nacks >= 1);
    }

    #[test]
    fn reuse_counters_split_local_remote() {
        let mut r = rig(PolicyKind::Always);
        read(&mut r, 0, 31, 0);
        let t = 100_000;
        read(&mut r, 0, 31, t); // local
        read(&mut r, 1, 31, t + 1000); // remote (and triggers resub)
        assert_eq!(r.stats.reuse.subscriptions, 2); // original + resub
        assert_eq!(r.stats.reuse.local_hits, 1);
        assert_eq!(r.stats.reuse.remote_hits, 1);
    }

    #[test]
    fn subscribed_local_hits_count_demand_at_holder() {
        let mut r = rig(PolicyKind::Always);
        read(&mut r, 0, 31, 0);
        let before = r.stats.demand.counts()[0];
        read(&mut r, 0, 31, 100_000);
        assert_eq!(r.stats.demand.counts()[0], before + 1);
    }
}

//! Subscription and resubscription handshakes (§III-B1 / §III-B2): table
//! way allocation on both sides, the piggybacked data transfer, the
//! acknowledgement packets and the NACK path (§III-B3).

use crate::memsys::MemorySystem;
use crate::sim::PacketKind;
use crate::subscription::table::{Role, SubState};
use crate::{Cycle, VaultId};

impl MemorySystem {
    /// Allocate a requester-side way for a new holder entry, evicting (and
    /// unsubscribing) a victim if needed. Returns `(way, usable_at)` or
    /// `None` on NACK.
    pub(crate) fn alloc_requester_way(
        &mut self,
        r: VaultId,
        set: u32,
        now: Cycle,
    ) -> Option<(usize, Cycle)> {
        match self.subs.tables[r as usize].free_way(set) {
            Some(w) => Some((w, now)),
            None => {
                let v = self.subs.tables[r as usize].victim(set)?;
                let t_free = self.unsubscribe_victim(r, v, now);
                if !self.subs.buffers[r as usize].try_push(now, t_free) {
                    return None; // subscription buffer full (§III-B3)
                }
                // The way is architecturally free at t_free: materialize
                // the eviction now (the flow's packets are in flight; the
                // peer side commits lazily) and reuse the slot.
                self.subs.tables[r as usize].invalidate(v);
                Some((v, t_free))
            }
        }
    }

    /// Subscribe `block` to `r` piggybacked on a completed demand read:
    /// the data already travelled home→requester (or holder→requester) in
    /// the demand response, so only table updates and 1-FLIT acks move.
    /// `data_at` is the demand response arrival (when the holder copy
    /// becomes usable).
    pub(crate) fn subscribe_piggyback(
        &mut self,
        r: VaultId,
        block: u64,
        home: VaultId,
        set: u32,
        now: Cycle,
        data_at: Cycle,
    ) {
        // Already tracked (any state) at the requester? Nothing to do.
        if self.subs.tables[r as usize].lookup(set, block, now).is_some() {
            return;
        }
        let Some((way_r, usable)) = self.alloc_requester_way(r, set, now) else {
            self.stats.sub_nacks += 1;
            return;
        };

        // Home-side directory update (the request travelled inside the
        // demand packet — §III-A's extended packet format).
        match self.subs.tables[home as usize].lookup(set, block, now) {
            None => {
                let way_h = match self.home_way(home, set, now) {
                    Some(w) => w,
                    None => {
                        self.nack(home, r, now);
                        return;
                    }
                };
                // Both sides acknowledge (§III-B1): one control packet each
                // way, off the demand critical path.
                let ack = self.send(
                    PacketKind::SubscriptionTransferAck,
                    1,
                    r,
                    home,
                    data_at,
                );
                self.subs.tables[home as usize].install(
                    way_h,
                    block,
                    Role::Home,
                    r,
                    SubState::PendingSub,
                    ack.arrive,
                    now,
                );
                self.subs.tables[r as usize].install(
                    way_r,
                    block,
                    Role::Holder,
                    home,
                    SubState::PendingSub,
                    usable.max(data_at),
                    now,
                );
                self.stats.subscriptions += 1;
                self.stats.reuse.on_subscribe();
            }
            Some(i) => {
                let e = *self.subs.tables[home as usize].entry(i);
                if e.state != SubState::Subscribed || e.ready_at > now {
                    // Mid-handshake with another vault: NACK (§III-B3).
                    self.nack(home, r, now);
                    return;
                }
                let s = e.peer;
                if s == r {
                    return; // already ours (raced with the fast path)
                }
                self.resubscribe(r, block, home, s, i, set, now, data_at, false, way_r, usable);
            }
        }
    }

    /// Home-side way allocation (§III-B1's original-vault space check).
    pub(crate) fn home_way(
        &mut self,
        home: VaultId,
        set: u32,
        at: Cycle,
    ) -> Option<usize> {
        match self.subs.tables[home as usize].free_way(set) {
            Some(w) => Some(w),
            None => {
                let v = self.subs.tables[home as usize].victim(set)?;
                let t_free = self.unsubscribe_victim(home, v, at);
                if !self.subs.buffers[home as usize].try_push(at, t_free) {
                    return None;
                }
                self.subs.tables[home as usize].invalidate(v);
                Some(v)
            }
        }
    }

    /// Resubscription (§III-B2): the block moves from holder `s` to the
    /// new requester `r`. On the read path the data travelled in the
    /// demand response; on the write path (`write_in_place`) the requester
    /// already has it — either way only control packets move here: the
    /// forward notification home→old-holder and the two acknowledgements.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn resubscribe(
        &mut self,
        r: VaultId,
        block: u64,
        home: VaultId,
        s: VaultId,
        home_idx: usize,
        set: u32,
        at: Cycle,
        data_at: Cycle,
        write_in_place: bool,
        way_r: usize,
        usable: Cycle,
    ) {
        let fwd = self.send(PacketKind::SubscriptionRequest, 1, home, s, at);
        // Holder-side entry moves to PendingResub.
        let dirty = match self.subs.tables[s as usize].lookup(set, block, fwd.arrive) {
            Some(j) => {
                let es = self.subs.tables[s as usize].entry_mut(j);
                if es.state != SubState::Subscribed {
                    // Holder busy with another flow: NACK back to the
                    // requester (its way was never installed; any victim
                    // eviction already in flight simply completes).
                    self.nack(s, r, fwd.arrive);
                    return;
                }
                es.state = SubState::PendingResub;
                es.dirty
            }
            None => false, // directory raced; treat as clean
        };
        // Two acks: to the home (directory update) and to the old holder
        // (eviction) — §III-B2; the dirty bit rides the misc bits.
        let ack_h = self.send(PacketKind::SubscriptionTransferAck, 1, r, home, data_at);
        let ack_s = self.send(PacketKind::SubscriptionTransferAck, 1, r, s, data_at);
        {
            let eh = self.subs.tables[home as usize].entry_mut(home_idx);
            eh.state = SubState::PendingResub;
            eh.peer_next = r;
            eh.ready_at = ack_h.arrive;
        }
        if let Some(j) = self.subs.tables[s as usize].lookup(set, block, fwd.arrive) {
            let es = self.subs.tables[s as usize].entry_mut(j);
            if es.state == SubState::PendingResub {
                es.ready_at = ack_s.arrive;
            }
        }
        self.subs.tables[r as usize].install(
            way_r,
            block,
            Role::Holder,
            home,
            SubState::PendingSub,
            usable.max(data_at),
            data_at,
        );
        self.subs.tables[r as usize].entry_mut(way_r).dirty = dirty || write_in_place;
        self.stats.resubscriptions += 1;
        self.stats.subscriptions += 1;
        self.stats.reuse.on_subscribe();
    }

    pub(crate) fn nack(&mut self, from: VaultId, to: VaultId, at: Cycle) {
        self.send(PacketKind::SubscriptionNack, 1, from, to, at);
        self.stats.sub_nacks += 1;
    }
}

//! Unsubscription flows (§III-B4 / §III-B5): capacity evictions returning
//! parked blocks home, home-initiated recalls, and the dirty-bit
//! optimization that lets clean blocks return as a bare acknowledgement.

use crate::memsys::MemorySystem;
use crate::sim::PacketKind;
use crate::subscription::protocol::SubSystem;
use crate::subscription::table::{Role, SubState};
use crate::{Cycle, VaultId};

impl MemorySystem {
    /// Unsubscribe the victim entry `idx` of vault `v` (capacity eviction).
    /// Returns the cycle at which `v`'s way is free again.
    pub(crate) fn unsubscribe_victim(
        &mut self,
        v: VaultId,
        idx: usize,
        now: Cycle,
    ) -> Cycle {
        let e = *self.subs.tables[v as usize].entry(idx);
        debug_assert_eq!(e.state, SubState::Subscribed);
        let set = self.subs.map.set_of_block(e.block);
        match e.role {
            // Holder-initiated return (§III-B4, "subscribed vault wanting
            // to return the data"): data (or clean ack) home, ack back.
            Role::Holder => {
                let home = e.peer;
                // Read the parked block out of reserved space if dirty.
                let depart = if e.dirty {
                    self.vaults.access(v, SubSystem::reserved_slot_addr(idx), now).done
                } else {
                    now
                };
                let kind = PacketKind::UnsubscriptionData { dirty: e.dirty };
                let flits = if e.dirty { self.subs.k } else { 1 };
                let data = self.send(kind, flits, v, home, depart);
                if e.dirty {
                    self.vaults.access(home, SubSystem::home_addr(e.block), data.arrive);
                }
                let ack = self.send(
                    PacketKind::UnsubscriptionTransferAck,
                    1,
                    home,
                    v,
                    data.arrive,
                );
                self.subs.tables[v as usize].begin_unsub(idx, ack.arrive);
                // Free the home's directory entry when the data lands,
                // recording whether a dirty block is in flight (clean
                // returns leave the home copy servable immediately).
                if let Some(j) =
                    self.subs.tables[home as usize].lookup(set, e.block, now)
                {
                    if self.subs.tables[home as usize].entry(j).state
                        == SubState::Subscribed
                    {
                        self.subs.tables[home as usize].entry_mut(j).dirty = e.dirty;
                        self.subs.tables[home as usize].begin_unsub(j, data.arrive);
                    }
                }
                self.stats.unsubscriptions += 1;
                ack.arrive
            }
            // Home-initiated recall (§III-B4, "original vault wanting the
            // data back"): request to the holder, data returns.
            Role::Home => {
                let holder = e.peer;
                let req = self.send(
                    PacketKind::UnsubscriptionRequest,
                    1,
                    v,
                    holder,
                    now,
                );
                let mut dirty = false;
                if let Some(j) =
                    self.subs.tables[holder as usize].lookup(set, e.block, req.arrive)
                {
                    let eh = self.subs.tables[holder as usize].entry(j);
                    if eh.state == SubState::Subscribed {
                        dirty = eh.dirty;
                    }
                }
                let depart = if dirty {
                    let j = self.subs.tables[holder as usize]
                        .lookup(set, e.block, req.arrive)
                        .expect("dirty holder entry present");
                    self.vaults
                        .access(holder, SubSystem::reserved_slot_addr(j), req.arrive)
                        .done
                } else {
                    req.arrive
                };
                let kind = PacketKind::UnsubscriptionData { dirty };
                let flits = if dirty { self.subs.k } else { 1 };
                let data = self.send(kind, flits, holder, v, depart);
                if dirty {
                    self.vaults.access(v, SubSystem::home_addr(e.block), data.arrive);
                }
                let ack = self.send(
                    PacketKind::UnsubscriptionTransferAck,
                    1,
                    v,
                    holder,
                    data.arrive,
                );
                self.subs.tables[v as usize].entry_mut(idx).dirty = dirty;
                self.subs.tables[v as usize].begin_unsub(idx, data.arrive);
                if let Some(j) =
                    self.subs.tables[holder as usize].lookup(set, e.block, req.arrive)
                {
                    if self.subs.tables[holder as usize].entry(j).state
                        == SubState::Subscribed
                    {
                        self.subs.tables[holder as usize].begin_unsub(j, ack.arrive);
                    }
                }
                self.stats.unsubscriptions += 1;
                data.arrive
            }
        }
    }

    /// §III-B4 special case: the home vault needs its own block back — the
    /// subscription request "converts into an unsubscription request".
    pub(crate) fn unsubscribe_home_initiated(
        &mut self,
        home: VaultId,
        block: u64,
        set: u32,
        now: Cycle,
    ) {
        if let Some(i) = self.subs.tables[home as usize].lookup(set, block, now) {
            let e = *self.subs.tables[home as usize].entry(i);
            if e.role == Role::Home && e.state == SubState::Subscribed && e.ready_at <= now
            {
                self.unsubscribe_victim(home, i, now);
            }
        }
    }
}

//! DL-PIM's contribution: the distributed subscription machinery that
//! "attracts" memory blocks to the vault that accesses them (§III).
//!
//! Per vault (Fig 7):
//! * a **subscription table** ([`table::SubTable`]) — 4-way x 2048-set
//!   cache-style lookup table mapping a block's original address to its
//!   current location, with the five protocol states;
//! * a **subscription buffer** ([`buffer::SubBuffer`]) — 32-entry fully
//!   associative staging area for subscriptions waiting on an eviction;
//! * **reserved space** ([`reserved`]) in vault memory holding subscribed
//!   blocks (one block per table entry, 0.125% of a 4 GB vault at the
//!   default 8192 entries).
//!
//! The protocol engine is split by flow, each handler an `impl` block on
//! [`crate::memsys::MemorySystem`] — the facade that owns the directory
//! state ([`protocol::SubSystem`]) together with the interconnect, the
//! vault DRAM and the statistics, so no handler threads
//! `&mut Mesh, &mut Vec<VaultMem>, &mut SimStats` through its signature:
//! * [`serve`] — the demand path ([`crate::memsys::MemorySystem::serve`]),
//! * [`forward`] — home→holder redirection of demand requests,
//! * [`subscribe`] — subscription/resubscription handshakes and NACKs,
//! * [`evict`] — unsubscription flows and the dirty-bit optimization.
//!
//! The abandoned count-threshold design (§III-A) is kept as
//! [`count_table::CountTable`] for the ablation bench (fig17).

pub mod buffer;
pub mod count_table;
pub mod evict;
pub mod forward;
pub mod protocol;
pub mod reserved;
pub mod serve;
pub mod subscribe;
pub mod table;

pub use buffer::SubBuffer;
pub use count_table::CountTable;
pub use protocol::{Access, SubSystem};
pub use table::{Role, SubState, SubTable};

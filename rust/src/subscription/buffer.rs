//! The subscription buffer: a 32-entry fully-associative staging cache
//! (§III-A).
//!
//! When a subscription needs a table way that is still being freed by an
//! in-flight unsubscription, the request parks here until the way opens.
//! If the buffer itself is full the subscription is negatively acknowledged
//! (§III-B3). In the resource-reservation model an entry is simply the
//! completion time of the eviction it waits on; entries whose wait has
//! elapsed are garbage-collected lazily.

use crate::Cycle;

/// Per-vault subscription buffer.
#[derive(Clone, Debug)]
pub struct SubBuffer {
    cap: usize,
    /// Completion times of the unsubscriptions being waited on.
    waiting: Vec<Cycle>,
    /// High-water mark, for reports.
    pub peak: usize,
    /// Total NACKs caused by buffer exhaustion.
    pub nacks: u64,
}

impl SubBuffer {
    pub fn new(cap: u32) -> Self {
        SubBuffer { cap: cap as usize, waiting: Vec::new(), peak: 0, nacks: 0 }
    }

    pub fn reset(&mut self) {
        self.waiting.clear();
        self.peak = 0;
        self.nacks = 0;
    }

    fn gc(&mut self, now: Cycle) {
        self.waiting.retain(|&t| t > now);
    }

    /// Try to park a subscription waiting until `ready_at`. Returns `false`
    /// (and counts a NACK) if the buffer is full.
    pub fn try_push(&mut self, now: Cycle, ready_at: Cycle) -> bool {
        self.gc(now);
        if self.waiting.len() >= self.cap {
            self.nacks += 1;
            return false;
        }
        self.waiting.push(ready_at);
        self.peak = self.peak.max(self.waiting.len());
        true
    }

    pub fn occupancy(&mut self, now: Cycle) -> usize {
        self.gc(now);
        self.waiting.len()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accepts_until_capacity() {
        let mut b = SubBuffer::new(2);
        assert!(b.try_push(0, 100));
        assert!(b.try_push(0, 100));
        assert!(!b.try_push(0, 100), "third must NACK");
        assert_eq!(b.nacks, 1);
    }

    #[test]
    fn frees_after_wait_elapses() {
        let mut b = SubBuffer::new(1);
        assert!(b.try_push(0, 50));
        assert!(!b.try_push(10, 60));
        assert!(b.try_push(50, 90), "entry expired at 50");
    }

    #[test]
    fn occupancy_reflects_gc() {
        let mut b = SubBuffer::new(4);
        b.try_push(0, 10);
        b.try_push(0, 20);
        assert_eq!(b.occupancy(15), 1);
        assert_eq!(b.occupancy(25), 0);
    }

    #[test]
    fn peak_tracks_high_water() {
        let mut b = SubBuffer::new(8);
        for _ in 0..5 {
            b.try_push(0, 100);
        }
        assert_eq!(b.peak, 5);
    }
}

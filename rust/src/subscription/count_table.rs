//! The abandoned count-threshold filter (§III-A), kept for the ablation.
//!
//! A direct-mapped table of 8192 entries, 32 bits each: an 8-bit access
//! counter and a 24-bit tag. On tag mismatch the entry is reset to the new
//! tag with count zero. The paper found that a 0-count threshold (subscribe
//! on first access) matches or beats any positive threshold for
//! subscription-friendly workloads — fig17_ablation_threshold reproduces
//! that finding, which is why DL-PIM proper has no count table.

/// Direct-mapped access-count table.
pub struct CountTable {
    entries: Vec<(u32, u8)>, // (24-bit tag, 8-bit count)
    mask: u64,
}

impl CountTable {
    /// `entries` must be a power of two (8192 in the paper).
    pub fn new(entries: u32) -> Self {
        assert!(entries.is_power_of_two());
        CountTable { entries: vec![(u32::MAX, 0); entries as usize], mask: (entries - 1) as u64 }
    }

    pub fn reset(&mut self) {
        self.entries.fill((u32::MAX, 0));
    }

    /// Record an access to `block`; returns the access count *after* this
    /// access for the (possibly just-reset) entry.
    pub fn bump(&mut self, block: u64) -> u8 {
        let idx = (block & self.mask) as usize;
        let tag = ((block >> self.mask.count_ones()) & 0x00ff_ffff) as u32;
        let e = &mut self.entries[idx];
        if e.0 != tag {
            // Evict-and-replace on mismatch, counter restarts.
            *e = (tag, 1);
        } else {
            e.1 = e.1.saturating_add(1);
        }
        e.1
    }

    /// Whether `block` has crossed `threshold` accesses (call after bump).
    pub fn over_threshold(&self, block: u64, threshold: u32) -> bool {
        let idx = (block & self.mask) as usize;
        let tag = ((block >> self.mask.count_ones()) & 0x00ff_ffff) as u32;
        let e = self.entries[idx];
        e.0 == tag && e.1 as u32 > threshold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_repeat_accesses() {
        let mut t = CountTable::new(8192);
        assert_eq!(t.bump(42), 1);
        assert_eq!(t.bump(42), 2);
        assert_eq!(t.bump(42), 3);
        assert!(t.over_threshold(42, 2));
        assert!(!t.over_threshold(42, 3));
    }

    #[test]
    fn conflicting_tag_resets_counter() {
        let mut t = CountTable::new(8);
        t.bump(0);
        t.bump(0);
        // Same index (block % 8 == 0), different tag.
        assert_eq!(t.bump(8), 1, "conflict resets to the incoming entry");
        assert!(!t.over_threshold(0, 0), "old entry evicted");
    }

    #[test]
    fn counter_saturates() {
        let mut t = CountTable::new(8);
        for _ in 0..300 {
            t.bump(1);
        }
        assert_eq!(t.bump(1), 255);
    }

    #[test]
    #[should_panic]
    fn non_pow2_rejected() {
        CountTable::new(100);
    }
}

//! Reserved-space sizing: each vault sets aside memory to hold subscribed
//! blocks — one block per subscription-table entry.
//!
//! §IV-C: 8192 entries x 64 B = 512 KB per vault, i.e. 0.125% of a 4 GB
//! vault ("0.125% state overhead relative to the 4GB vault memory size").
//! Occupancy itself is tracked by the table's holder count; this module
//! centralizes the arithmetic so configs, docs and tests agree.

use crate::config::SimConfig;

/// Bytes of reserved space per vault for a given configuration.
pub fn reserved_bytes_per_vault(cfg: &SimConfig) -> u64 {
    cfg.sub_table_entries() as u64 * cfg.block_bytes as u64
}

/// State overhead of the reserved space relative to a vault of
/// `vault_capacity_bytes` (the paper quotes 4 GB vaults).
// lint:allow(D4) -- derived capacity ratio for docs/tables (the paper's
// "0.125%"); read-out only, never accumulated into simulation state.
pub fn state_overhead(cfg: &SimConfig, vault_capacity_bytes: u64) -> f64 {
    // lint:allow(D4) -- same read-out ratio as the signature.
    reserved_bytes_per_vault(cfg) as f64 / vault_capacity_bytes as f64
}

/// Subscription-table SRAM cost in bits: each entry stores the original
/// and subscribed addresses plus three state bits (§III-A).
pub fn table_bits(cfg: &SimConfig, addr_bits: u32) -> u64 {
    cfg.sub_table_entries() as u64 * (2 * addr_bits as u64 + 3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_overhead_figure() {
        // 8192 entries x 64 B = 512 KiB; / 4 GiB = 0.0125% ... the paper
        // says 0.125%; with their 10x larger effective footprint (640 B per
        // entry incl. metadata rows) the claim brackets ours — we assert
        // our exact arithmetic and that it stays well under 1%.
        let cfg = SimConfig::hmc();
        let ov = state_overhead(&cfg, 4 << 30);
        assert!((ov - 512.0 * 1024.0 / (4.0 * 1024.0 * 1024.0 * 1024.0)).abs() < 1e-12);
        assert!(ov < 0.01);
    }

    #[test]
    fn reserved_scales_with_table() {
        let mut cfg = SimConfig::hmc();
        let base = reserved_bytes_per_vault(&cfg);
        cfg.sub_table_sets *= 2;
        assert_eq!(reserved_bytes_per_vault(&cfg), base * 2);
    }

    #[test]
    fn table_bits_formula() {
        let cfg = SimConfig::hmc();
        // 8192 x (2*32 + 3) bits with 32-bit block addresses.
        assert_eq!(table_bits(&cfg, 32), 8192 * 67);
    }
}

//! The subscription table (ST): a 4-way set-associative hardware lookup
//! table with 2048 sets per vault (8192 entries), §III-A.
//!
//! Each vault's table plays two roles at once:
//! * **Home role** — "local blocks that moved to remote vaults": the entry
//!   maps a block homed here to the vault currently holding it, redirecting
//!   incoming demand.
//! * **Holder role** — "remote blocks that moved to the current vault": the
//!   entry marks a block parked in this vault's reserved space (and carries
//!   its dirty bit).
//!
//! Victim selection is least-frequently-used, ties broken by
//! least-recently-used (§III-A). Pending entries are never victimized —
//! their protocol exchange is in flight.

use crate::{Cycle, VaultId};

/// Protocol state of a table entry (§III-A lists exactly these five).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubState {
    /// Unsubscribed / empty way.
    Invalid,
    /// Subscription handshake in flight.
    PendingSub,
    /// Block is parked at (holder role) / redirected to (home role) `peer`.
    Subscribed,
    /// Resubscription to a new vault in flight.
    PendingResub,
    /// Block returning to its home vault.
    PendingUnsub,
}

/// Which side of a subscription this entry represents.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    /// This vault is the block's home; `peer` holds it.
    Home,
    /// This vault holds the block; `peer` is its home.
    Holder,
}

/// One table way.
#[derive(Clone, Copy, Debug)]
pub struct Entry {
    pub block: u64,
    pub state: SubState,
    pub role: Role,
    pub peer: VaultId,
    /// During `PendingResub` at the home vault: the incoming holder. The
    /// current `peer` (old holder) still owns the data until `ready_at`.
    pub peer_next: VaultId,
    pub dirty: bool,
    /// LFU counter (saturating).
    pub freq: u32,
    /// LRU timestamp.
    pub last_use: Cycle,
    /// Cycle at which the pending protocol exchange completes.
    pub ready_at: Cycle,
}

impl Entry {
    fn empty() -> Self {
        Entry {
            block: u64::MAX,
            state: SubState::Invalid,
            role: Role::Home,
            peer: 0,
            peer_next: 0,
            dirty: false,
            freq: 0,
            last_use: 0,
            ready_at: 0,
        }
    }

    /// Commit a pending transition whose exchange has completed by `now`.
    /// Returns `true` if the entry became Invalid (way freed).
    pub fn commit(&mut self, now: Cycle) -> bool {
        if now < self.ready_at {
            return false;
        }
        match self.state {
            SubState::PendingSub => {
                self.state = SubState::Subscribed;
                false
            }
            SubState::PendingResub => match self.role {
                // Home side: redirect target switches to the new holder.
                Role::Home => {
                    self.peer = self.peer_next;
                    self.state = SubState::Subscribed;
                    false
                }
                // Old holder side: entry is evicted once the move finishes.
                Role::Holder => {
                    *self = Entry::empty();
                    true
                }
            },
            SubState::PendingUnsub => {
                *self = Entry::empty();
                true
            }
            _ => false,
        }
    }

    pub fn is_invalid(&self) -> bool {
        self.state == SubState::Invalid
    }

    pub fn is_pending(&self, now: Cycle) -> bool {
        !self.is_invalid() && self.state != SubState::Subscribed && now < self.ready_at
    }
}

/// Hot-array sentinel for an invalid way (mirrors `Entry::empty().block`).
const TAG_EMPTY: u64 = u64::MAX;

/// A per-vault subscription table.
///
/// ## Hot/cold struct-of-arrays split
///
/// `lookup` is on the serve hot path and, in the common all-miss case,
/// only needs to answer "does any way of this set hold `block`?". The
/// `tags` array carries exactly that: one `u64` per way — the entry's
/// block when the way is valid, [`TAG_EMPTY`] when invalid — so a 4-way
/// probe reads 32 contiguous bytes instead of four 56-byte [`Entry`]
/// structs. The cold `entries` array keeps the full protocol state and is
/// only touched for ways whose tag is live.
///
/// Coherence invariant: `tags[i] == entries[i].block` whenever
/// `entries[i]` is valid, `TAG_EMPTY` otherwise. The four mutation points
/// (`install`, `invalidate`, the lazy `commit` inside `lookup`, `reset`)
/// maintain it. **`entry_mut` callers must not change an entry's `block`
/// or make it Invalid directly** — the protocol handlers only mutate
/// `state`/`dirty`/`ready_at`/`peer`/`peer_next`/LFU fields, and
/// `debug_assert_tags_coherent` enforces the invariant in tests.
pub struct SubTable {
    ways: usize,
    /// Hot array: block tag per way, [`TAG_EMPTY`] when the way is free.
    tags: Vec<u64>,
    entries: Vec<Entry>,
    /// Holder-role entries currently valid (reserved-space occupancy).
    holder_count: u32,
}

impl SubTable {
    pub fn new(sets: u32, ways: u16) -> Self {
        let n = sets as usize * ways as usize;
        SubTable {
            ways: ways as usize,
            tags: vec![TAG_EMPTY; n],
            entries: vec![Entry::empty(); n],
            holder_count: 0,
        }
    }

    pub fn reset(&mut self) {
        self.tags.fill(TAG_EMPTY);
        self.entries.fill(Entry::empty());
        self.holder_count = 0;
    }

    #[inline]
    fn set_range(&self, set: u32) -> std::ops::Range<usize> {
        let base = set as usize * self.ways;
        base..base + self.ways
    }

    /// Commit any completed pending transitions in `set`, then look up
    /// `block`. Returns the way index.
    ///
    /// The probe walks the hot `tags` array; invalid ways are skipped on a
    /// tag read alone (a commit attempt on an Invalid entry is a no-op and
    /// an Invalid entry never matches, so skipping is exactly the scalar
    /// behaviour). Only ways with a live tag touch the cold `entries`.
    pub fn lookup(&mut self, set: u32, block: u64, now: Cycle) -> Option<usize> {
        for i in self.set_range(set) {
            if self.tags[i] == TAG_EMPTY {
                continue;
            }
            let e = &mut self.entries[i];
            if e.ready_at <= now && e.state != SubState::Subscribed {
                let was_holder = e.role == Role::Holder
                    && matches!(e.state, SubState::PendingResub | SubState::PendingUnsub);
                if e.commit(now) {
                    self.tags[i] = TAG_EMPTY;
                    if was_holder {
                        self.holder_count -= 1;
                    }
                    continue; // a freed way cannot match
                }
            }
            if self.tags[i] == block {
                return Some(i);
            }
        }
        None
    }

    pub fn entry(&self, idx: usize) -> &Entry {
        &self.entries[idx]
    }

    pub fn entry_mut(&mut self, idx: usize) -> &mut Entry {
        &mut self.entries[idx]
    }

    /// Record a use for LFU/LRU bookkeeping.
    pub fn touch(&mut self, idx: usize, now: Cycle) {
        let e = &mut self.entries[idx];
        e.freq = e.freq.saturating_add(1);
        e.last_use = now;
    }

    /// Find a free way in `set`, if any (hot-array probe).
    pub fn free_way(&self, set: u32) -> Option<usize> {
        self.set_range(set).find(|&i| self.tags[i] == TAG_EMPTY)
    }

    /// LFU-then-LRU victim among *Subscribed* (non-pending) entries in
    /// `set`. Pending entries are protected.
    pub fn victim(&self, set: u32) -> Option<usize> {
        self.set_range(set)
            .filter(|&i| self.entries[i].state == SubState::Subscribed)
            .min_by_key(|&i| (self.entries[i].freq, self.entries[i].last_use))
    }

    /// Install an entry into a known-free way.
    #[allow(clippy::too_many_arguments)]
    pub fn install(
        &mut self,
        idx: usize,
        block: u64,
        role: Role,
        peer: VaultId,
        state: SubState,
        ready_at: Cycle,
        now: Cycle,
    ) {
        debug_assert!(self.entries[idx].is_invalid());
        debug_assert_ne!(block, TAG_EMPTY, "block id collides with the tag sentinel");
        if role == Role::Holder {
            self.holder_count += 1;
        }
        self.tags[idx] = block;
        self.entries[idx] = Entry {
            block,
            state,
            role,
            peer,
            peer_next: peer,
            dirty: false,
            freq: 1,
            last_use: now,
            ready_at,
        };
    }

    /// Invalidate a way immediately (rollback on NACK).
    pub fn invalidate(&mut self, idx: usize) {
        if self.entries[idx].role == Role::Holder && !self.entries[idx].is_invalid() {
            self.holder_count -= 1;
        }
        self.tags[idx] = TAG_EMPTY;
        self.entries[idx] = Entry::empty();
    }

    /// Mark a way pending-unsubscription; the way frees at `ready_at` via
    /// `commit` (lazily, on the next lookup of its set).
    pub fn begin_unsub(&mut self, idx: usize, ready_at: Cycle) {
        let e = &mut self.entries[idx];
        debug_assert_eq!(e.state, SubState::Subscribed);
        e.state = SubState::PendingUnsub;
        e.ready_at = ready_at;
    }

    /// Age the LFU counters (halve). Without decay, long-dead entries keep
    /// their historical frequency and pin the table while every *new*
    /// subscription (freq 1) victimizes the next new subscription — the
    /// classic LFU staleness pathology. The epoch boundary (§III-D1), which
    /// already clears the policy registers, is the natural aging point.
    pub fn decay(&mut self) {
        for e in &mut self.entries {
            e.freq >>= 1;
        }
    }

    /// Valid (non-Invalid) entries, for tests and reports.
    pub fn occupancy(&self) -> usize {
        self.entries.iter().filter(|e| !e.is_invalid()).count()
    }

    /// Holder-role occupancy = blocks in this vault's reserved space.
    pub fn holder_occupancy(&self) -> u32 {
        self.holder_count
    }

    pub fn num_sets(&self) -> u32 {
        (self.entries.len() / self.ways) as u32
    }

    pub fn ways(&self) -> usize {
        self.ways
    }

    /// Assert the hot/cold coherence invariant (see the struct docs):
    /// `tags[i]` mirrors `entries[i].block` for valid ways and is
    /// [`TAG_EMPTY`] for invalid ones. Called from tests after protocol
    /// churn; a violation means some handler mutated `block`/validity
    /// through `entry_mut` instead of `install`/`invalidate`.
    pub fn debug_assert_tags_coherent(&self) {
        for (i, e) in self.entries.iter().enumerate() {
            let want = if e.is_invalid() { TAG_EMPTY } else { e.block };
            assert_eq!(
                self.tags[i], want,
                "tag/entry divergence at way {i}: tag {:#x}, entry {:?}",
                self.tags[i], e
            );
        }
    }

    /// Count entries in every state — protocol invariants are asserted over
    /// this in tests.
    pub fn state_census(&self) -> [usize; 5] {
        let mut c = [0usize; 5];
        for e in &self.entries {
            let i = match e.state {
                SubState::Invalid => 0,
                SubState::PendingSub => 1,
                SubState::Subscribed => 2,
                SubState::PendingResub => 3,
                SubState::PendingUnsub => 4,
            };
            c[i] += 1;
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> SubTable {
        SubTable::new(8, 4)
    }

    #[test]
    fn install_and_lookup() {
        let mut t = table();
        let w = t.free_way(3).unwrap();
        t.install(w, 99, Role::Holder, 5, SubState::Subscribed, 0, 0);
        assert_eq!(t.lookup(3, 99, 10), Some(w));
        assert_eq!(t.lookup(4, 99, 10), None, "wrong set");
        assert_eq!(t.holder_occupancy(), 1);
    }

    #[test]
    fn pending_sub_commits_after_ready() {
        let mut t = table();
        let w = t.free_way(0).unwrap();
        t.install(w, 7, Role::Holder, 2, SubState::PendingSub, 100, 0);
        let i = t.lookup(0, 7, 50).unwrap();
        assert_eq!(t.entry(i).state, SubState::PendingSub);
        let i = t.lookup(0, 7, 100).unwrap();
        assert_eq!(t.entry(i).state, SubState::Subscribed);
    }

    #[test]
    fn pending_unsub_frees_way_after_ready() {
        let mut t = table();
        let w = t.free_way(0).unwrap();
        t.install(w, 7, Role::Holder, 2, SubState::Subscribed, 0, 0);
        t.begin_unsub(w, 200);
        assert!(t.lookup(0, 7, 199).is_some(), "still present while pending");
        assert!(t.lookup(0, 7, 200).is_none(), "freed at ready");
        assert_eq!(t.holder_occupancy(), 0);
        assert_eq!(t.occupancy(), 0);
    }

    #[test]
    fn victim_prefers_lfu_then_lru() {
        let mut t = table();
        for (block, freq_touches, last) in [(1u64, 3u32, 10u64), (2, 1, 20), (3, 1, 5)] {
            let w = t.free_way(0).unwrap();
            t.install(w, block, Role::Holder, 0, SubState::Subscribed, 0, 0);
            for k in 0..freq_touches {
                t.touch(w, last - k as u64);
            }
            t.entry_mut(w).last_use = last;
        }
        // blocks 2 and 3 tie on freq (1 install + 1 touch), 3 is older.
        let v = t.victim(0).unwrap();
        assert_eq!(t.entry(v).block, 3);
    }

    #[test]
    fn pending_entries_are_not_victims() {
        let mut t = table();
        let w = t.free_way(0).unwrap();
        t.install(w, 1, Role::Holder, 0, SubState::PendingSub, 1000, 0);
        assert!(t.victim(0).is_none());
    }

    #[test]
    fn resub_commit_home_switches_peer() {
        let mut t = table();
        let w = t.free_way(0).unwrap();
        t.install(w, 1, Role::Home, 4, SubState::Subscribed, 0, 0);
        {
            let e = t.entry_mut(w);
            e.state = SubState::PendingResub;
            e.peer_next = 9;
            e.ready_at = 50;
        }
        let i = t.lookup(0, 1, 49).unwrap();
        assert_eq!(t.entry(i).peer, 4, "old holder until ready");
        let i = t.lookup(0, 1, 50).unwrap();
        assert_eq!(t.entry(i).peer, 9);
        assert_eq!(t.entry(i).state, SubState::Subscribed);
    }

    #[test]
    fn resub_commit_holder_evicts() {
        let mut t = table();
        let w = t.free_way(0).unwrap();
        t.install(w, 1, Role::Holder, 4, SubState::Subscribed, 0, 0);
        {
            let e = t.entry_mut(w);
            e.state = SubState::PendingResub;
            e.ready_at = 50;
        }
        assert!(t.lookup(0, 1, 50).is_none());
        assert_eq!(t.holder_occupancy(), 0);
    }

    #[test]
    fn invalidate_rolls_back_holder_count() {
        let mut t = table();
        let w = t.free_way(0).unwrap();
        t.install(w, 1, Role::Holder, 4, SubState::PendingSub, 100, 0);
        t.invalidate(w);
        assert_eq!(t.holder_occupancy(), 0);
        assert_eq!(t.occupancy(), 0);
    }

    #[test]
    fn set_fills_to_associativity() {
        let mut t = table();
        for b in 0..4u64 {
            let w = t.free_way(1).unwrap();
            t.install(w, b, Role::Home, 0, SubState::Subscribed, 0, 0);
        }
        assert!(t.free_way(1).is_none());
        assert!(t.free_way(2).is_some(), "other sets unaffected");
    }

    #[test]
    fn tags_stay_coherent_under_churn() {
        let mut t = table();
        // Install across states, lazily commit, invalidate, reinstall —
        // the tag array must mirror entry validity at every step.
        for b in 0..4u64 {
            let w = t.free_way(0).unwrap();
            t.install(w, b, Role::Holder, 1, SubState::PendingSub, 10 * b, 0);
            t.debug_assert_tags_coherent();
        }
        for b in 0..4u64 {
            t.lookup(0, b, 100); // commits PendingSub -> Subscribed
            t.debug_assert_tags_coherent();
        }
        let v = t.victim(0).unwrap();
        t.begin_unsub(v, 200);
        t.debug_assert_tags_coherent();
        assert!(t.lookup(0, t.entry(v).block, 300).is_none(), "freed by commit");
        t.debug_assert_tags_coherent();
        let w = t.free_way(0).unwrap();
        assert_eq!(w, v, "committed unsub frees the way");
        t.install(w, 99, Role::Home, 2, SubState::Subscribed, 0, 0);
        t.invalidate(w);
        t.debug_assert_tags_coherent();
        t.reset();
        t.debug_assert_tags_coherent();
        assert_eq!(t.occupancy(), 0);
    }

    #[test]
    fn census_counts_states() {
        let mut t = table();
        let w = t.free_way(0).unwrap();
        t.install(w, 1, Role::Home, 0, SubState::PendingSub, 100, 0);
        let c = t.state_census();
        assert_eq!(c[1], 1);
        assert_eq!(c[0], 8 * 4 - 1);
    }
}

//! Passive observability core: metrics registry, span timers, exporters.
//!
//! Everything here is **provably passive**: instruments observe `u64`s
//! and can never hand a value back to the simulator, so enabling them
//! cannot perturb simulated timestamps, report-cache keys or artifact
//! bytes (the invariant rows in `docs/ARCHITECTURE.md`, pinned by
//! `tests/observability.rs` plus the metrics-on legs of the golden and
//! kernel-equivalence suites). The design splits instruments in two:
//!
//! * **Always-on counters/gauges** (store, report cache, scheduler,
//!   policy flips) — coarse-grained `Relaxed` atomic adds on paths that
//!   run at most once per job/epoch; cost is unmeasurable and keeping
//!   them unconditional keeps the call sites branch-free.
//! * **Opt-in request telemetry** ([`record_request`], span timers,
//!   occupancy) — enabled by `--metrics-out`. The per-request hot path
//!   stays branch-free when observability is off because the choice is
//!   made *once per run*: the drivers select the `_observed` code path
//!   with a recording closure only when [`enabled`] is set, otherwise
//!   the closure is a no-op the optimizer erases.
//!
//! Histograms use compile-time log2 bucket edges and commutative atomic
//! adds, so merged counts are identical at any scheduler thread count —
//! deterministic for the `_cycles` histograms (simulated time), while
//! `_ns` histograms record wall time and are inherently run-dependent.
//!
//! Naming scheme: `<subsystem>_<event>` for counters,
//! `<subsystem>_<quantity>_cycles` (simulated time) or `..._ns` (wall
//! time) for histograms. See `docs/OBSERVABILITY.md` for the registry
//! API and the rules for adding an instrument without breaking
//! bit-identity.

pub mod export;
pub mod log;
pub mod metrics;

pub use metrics::{Counter, Gauge, HistSnapshot, Histogram, MetricPoint, Snapshot, N_BUCKETS};

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Whether opt-in request telemetry (observed driver paths, span
/// timers, occupancy sampling) is active. Read once per run / job, not
/// per request.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Turn opt-in telemetry on (the `--metrics-out` switch).
pub fn enable() {
    set_enabled(true);
}

// ---------------------------------------------------------------------
// The registry. Every instrument is a static declared here; `snapshot`
// enumerates them in this order, which is therefore the export order.
// ---------------------------------------------------------------------

/// Demand requests recorded by the per-request observer (opt-in).
pub static KERNEL_REQUESTS: Counter =
    Counter::new("kernel_requests", "demand requests observed by the metrics hook");
/// Content-addressed disk store outcomes (always on).
pub static STORE_HIT: Counter =
    Counter::new("store_hit", "disk store loads that returned a cached report");
pub static STORE_MISS: Counter =
    Counter::new("store_miss", "disk store loads with no entry on disk");
pub static STORE_STALE: Counter =
    Counter::new("store_stale", "disk store entries rejected for a stale build fingerprint");
pub static STORE_POISONED: Counter =
    Counter::new("store_poisoned", "disk store entries rejected as corrupt");
/// In-memory report cache outcomes (always on).
pub static CACHE_HIT: Counter =
    Counter::new("cache_hit", "in-memory report cache hits");
pub static CACHE_MISS: Counter =
    Counter::new("cache_miss", "in-memory report cache misses");
/// Sweep scheduler activity (always on).
pub static SCHED_JOBS: Counter =
    Counter::new("sched_jobs", "sweep jobs executed");
pub static SCHED_PARKS: Counter =
    Counter::new("sched_parks", "times a sweep worker parked on the empty injector");
pub static SCHED_WAKES: Counter =
    Counter::new("sched_wakes", "times a parked sweep worker woke");
pub static SCHED_PANICKED_JOBS: Counter =
    Counter::new("sched_panicked_jobs", "sweep jobs that panicked");
/// Policy-layer activity (always on).
pub static POLICY_FLIPS: Counter =
    Counter::new("policy_flips", "global indirection enable/disable transitions");
/// Sharded-sweep claim protocol (always on; see `sweep::shard`).
pub static SHARD_CLAIMS: Counter =
    Counter::new("shard_claims", "sweep points claimed fresh by this process");
pub static SHARD_RECLAIMS: Counter =
    Counter::new("shard_reclaims", "stale claims taken over by this process");
pub static SHARD_LEASE_EXPIRED: Counter =
    Counter::new("shard_lease_expired", "claim leases observed past their TTL");

/// Deepest injector queue observed (high-water mark; scheduling-timing
/// dependent, excluded from determinism pins).
pub static SCHED_QUEUE_DEPTH_MAX: Gauge =
    Gauge::new("sched_queue_depth_max", "deepest sweep injector queue observed");
/// Points simulated by shard workers (high-water mark across this
/// process's workers; wall-clock-path accounting, excluded from
/// determinism pins).
pub static SHARD_POINTS_SIMULATED: Gauge =
    Gauge::new("shard_points_simulated", "sweep points simulated under shard claims");

/// Per-request latency decomposition (simulated cycles; deterministic).
pub static REQUEST_TRANSFER_CYCLES: Histogram =
    Histogram::new("request_transfer_cycles", "pure network transfer cycles per request");
pub static REQUEST_QUEUE_NET_CYCLES: Histogram =
    Histogram::new("request_queue_net_cycles", "interconnect queue-wait cycles per request");
pub static REQUEST_QUEUE_MEM_CYCLES: Histogram =
    Histogram::new("request_queue_mem_cycles", "controller/bank queue-wait cycles per request");
pub static REQUEST_SERVICE_CYCLES: Histogram =
    Histogram::new("request_service_cycles", "DRAM array service cycles per request");
/// Blocks parked in subscription tables at end of run (deterministic).
pub static SUBSCRIPTION_OCCUPANCY: Histogram =
    Histogram::new("subscription_occupancy", "blocks parked in subscription tables at end of run");

/// Wall-clock histograms (nanoseconds; inherently nondeterministic).
pub static SCHED_JOB_WALL_NS: Histogram =
    Histogram::new("sched_job_wall_ns", "wall time per sweep job");
pub static SPAN_SPEC_EXPAND_NS: Histogram =
    Histogram::new("span_spec_expand_ns", "wall time expanding an experiment spec");
pub static SPAN_QUEUE_WAIT_NS: Histogram =
    Histogram::new("span_queue_wait_ns", "wall time sweep workers spent parked waiting for jobs");
pub static SPAN_STORE_LOOKUP_NS: Histogram =
    Histogram::new("span_store_lookup_ns", "wall time per disk store load");
pub static SPAN_KERNEL_RUN_NS: Histogram =
    Histogram::new("span_kernel_run_ns", "wall time simulating one sweep point");
pub static SPAN_RENDER_NS: Histogram =
    Histogram::new("span_render_ns", "wall time rendering rows and artifacts");

/// Record one served request's latency decomposition. Only called from
/// the `_observed` driver paths, which are selected when [`enabled`] is
/// set — the plain paths carry no observer and no branch.
pub fn record_request(network: u64, queued_net: u64, queued_mem: u64, array: u64) {
    KERNEL_REQUESTS.inc();
    REQUEST_TRANSFER_CYCLES.observe(network);
    REQUEST_QUEUE_NET_CYCLES.observe(queued_net);
    REQUEST_QUEUE_MEM_CYCLES.observe(queued_mem);
    REQUEST_SERVICE_CYCLES.observe(array);
}

/// A scope timer feeding a wall-time histogram on drop. Free when
/// telemetry is off: no clock is read and the drop is a no-op.
pub struct SpanTimer {
    start: Option<Instant>,
    hist: &'static Histogram,
}

/// Start timing a pipeline stage (if telemetry is enabled).
pub fn span(hist: &'static Histogram) -> SpanTimer {
    SpanTimer { start: if enabled() { Some(Instant::now()) } else { None }, hist }
}

impl Drop for SpanTimer {
    fn drop(&mut self) {
        if let Some(t0) = self.start {
            self.hist.observe(t0.elapsed().as_nanos() as u64);
        }
    }
}

/// Snapshot the whole registry in declaration (= export) order.
pub fn snapshot() -> Snapshot {
    Snapshot {
        counters: vec![
            KERNEL_REQUESTS.point(),
            STORE_HIT.point(),
            STORE_MISS.point(),
            STORE_STALE.point(),
            STORE_POISONED.point(),
            CACHE_HIT.point(),
            CACHE_MISS.point(),
            SCHED_JOBS.point(),
            SCHED_PARKS.point(),
            SCHED_WAKES.point(),
            SCHED_PANICKED_JOBS.point(),
            POLICY_FLIPS.point(),
            SHARD_CLAIMS.point(),
            SHARD_RECLAIMS.point(),
            SHARD_LEASE_EXPIRED.point(),
        ],
        gauges: vec![SCHED_QUEUE_DEPTH_MAX.point(), SHARD_POINTS_SIMULATED.point()],
        hists: vec![
            REQUEST_TRANSFER_CYCLES.snap(),
            REQUEST_QUEUE_NET_CYCLES.snap(),
            REQUEST_QUEUE_MEM_CYCLES.snap(),
            REQUEST_SERVICE_CYCLES.snap(),
            SUBSCRIPTION_OCCUPANCY.snap(),
            SCHED_JOB_WALL_NS.snap(),
            SPAN_SPEC_EXPAND_NS.snap(),
            SPAN_QUEUE_WAIT_NS.snap(),
            SPAN_STORE_LOOKUP_NS.snap(),
            SPAN_KERNEL_RUN_NS.snap(),
            SPAN_RENDER_NS.snap(),
        ],
    }
}

/// Zero every instrument (test isolation; the CLI never resets).
pub fn reset() {
    KERNEL_REQUESTS.reset();
    STORE_HIT.reset();
    STORE_MISS.reset();
    STORE_STALE.reset();
    STORE_POISONED.reset();
    CACHE_HIT.reset();
    CACHE_MISS.reset();
    SCHED_JOBS.reset();
    SCHED_PARKS.reset();
    SCHED_WAKES.reset();
    SCHED_PANICKED_JOBS.reset();
    POLICY_FLIPS.reset();
    SHARD_CLAIMS.reset();
    SHARD_RECLAIMS.reset();
    SHARD_LEASE_EXPIRED.reset();
    SCHED_QUEUE_DEPTH_MAX.reset();
    SHARD_POINTS_SIMULATED.reset();
    REQUEST_TRANSFER_CYCLES.reset();
    REQUEST_QUEUE_NET_CYCLES.reset();
    REQUEST_QUEUE_MEM_CYCLES.reset();
    REQUEST_SERVICE_CYCLES.reset();
    SUBSCRIPTION_OCCUPANCY.reset();
    SCHED_JOB_WALL_NS.reset();
    SPAN_SPEC_EXPAND_NS.reset();
    SPAN_QUEUE_WAIT_NS.reset();
    SPAN_STORE_LOOKUP_NS.reset();
    SPAN_KERNEL_RUN_NS.reset();
    SPAN_RENDER_NS.reset();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_orders_match_and_names_are_unique() {
        let s = snapshot();
        let mut names: Vec<&str> = s
            .counters
            .iter()
            .chain(s.gauges.iter())
            .map(|p| p.name)
            .chain(s.hists.iter().map(|h| h.name))
            .collect();
        let total = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), total, "duplicate metric name in the registry");
        assert_eq!(s.counters[0].name, "kernel_requests");
        assert!(s.counters.iter().any(|c| c.name == "store_hit"));
    }

    // Counter-value assertions live in tests/observability.rs: the
    // registry is process-global and this module's tests share the lib
    // test binary with code that legitimately bumps these counters.
}

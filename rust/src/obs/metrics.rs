//! Typed instruments of the passive observability core.
//!
//! Three instrument kinds, all lock-free over `AtomicU64` with `Relaxed`
//! ordering. Every mutation is a commutative add (or an idempotent
//! `fetch_max`), so the totals visible after the scheduler joins are
//! independent of thread interleaving — the property the histogram-merge
//! determinism test pins across 1/2/4/8 sweep threads. [`Histogram`]
//! bucket edges are compile-time constants (`le = 2^0 .. 2^31`, then
//! `+Inf`), so merged output never depends on runtime configuration.
//!
//! Instruments carry their own name and help text; the registry in
//! [`crate::obs`] enumerates them in a fixed order and [`Snapshot`] is
//! the plain-data view the exporters render. Nothing in this module
//! reads or writes simulation state: an instrument can observe a value
//! but can never hand one back to the simulator.

use std::sync::atomic::{AtomicU64, Ordering};

/// Bucket count of every [`Histogram`]: `le = 2^0 .. 2^31` plus `+Inf`.
pub const N_BUCKETS: usize = 33;

/// A monotonically increasing event count.
pub struct Counter {
    name: &'static str,
    help: &'static str,
    v: AtomicU64,
}

impl Counter {
    pub const fn new(name: &'static str, help: &'static str) -> Counter {
        Counter { name, help, v: AtomicU64::new(0) }
    }

    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.v.store(0, Ordering::Relaxed);
    }

    pub fn point(&self) -> MetricPoint {
        MetricPoint { name: self.name, help: self.help, value: self.get() }
    }
}

/// A sampled value. [`Gauge::set_max`] keeps a high-water mark with an
/// idempotent `fetch_max`, the only gauge mutation safe under the
/// scheduler's nondeterministic interleaving.
pub struct Gauge {
    name: &'static str,
    help: &'static str,
    v: AtomicU64,
}

impl Gauge {
    pub const fn new(name: &'static str, help: &'static str) -> Gauge {
        Gauge { name, help, v: AtomicU64::new(0) }
    }

    pub fn set(&self, v: u64) {
        self.v.store(v, Ordering::Relaxed);
    }

    pub fn set_max(&self, v: u64) {
        self.v.fetch_max(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.v.store(0, Ordering::Relaxed);
    }

    pub fn point(&self) -> MetricPoint {
        MetricPoint { name: self.name, help: self.help, value: self.get() }
    }
}

/// A fixed-log2-bucket histogram: bucket `i < 32` counts observations
/// `v <= 2^i`, the last bucket is `+Inf`. Edges are compile-time
/// constants and per-bucket counts are commutative atomic adds, so two
/// exports of the same set of observations are byte-identical no matter
/// how many threads produced them.
pub struct Histogram {
    name: &'static str,
    help: &'static str,
    buckets: [AtomicU64; N_BUCKETS],
    sum: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    pub const fn new(name: &'static str, help: &'static str) -> Histogram {
        const Z: AtomicU64 = AtomicU64::new(0);
        Histogram {
            name,
            help,
            buckets: [Z; N_BUCKETS],
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    /// Index of the bucket holding `v`: the smallest `i` with `v <= 2^i`,
    /// clamped into the `+Inf` bucket past `2^31`.
    pub fn bucket_index(v: u64) -> usize {
        if v <= 1 {
            0
        } else {
            ((64 - (v - 1).leading_zeros()) as usize).min(N_BUCKETS - 1)
        }
    }

    /// Upper edge of bucket `i`; `None` is the `+Inf` bucket.
    pub fn le(i: usize) -> Option<u64> {
        if i < N_BUCKETS - 1 {
            Some(1u64 << i)
        } else {
            None
        }
    }

    pub fn observe(&self, v: u64) {
        self.buckets[Self::bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.sum.store(0, Ordering::Relaxed);
        self.count.store(0, Ordering::Relaxed);
    }

    pub fn snap(&self) -> HistSnapshot {
        let mut buckets = [0u64; N_BUCKETS];
        for (i, b) in self.buckets.iter().enumerate() {
            buckets[i] = b.load(Ordering::Relaxed);
        }
        HistSnapshot {
            name: self.name,
            help: self.help,
            buckets,
            sum: self.sum.load(Ordering::Relaxed),
            count: self.count.load(Ordering::Relaxed),
        }
    }
}

/// One exported counter or gauge sample.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MetricPoint {
    pub name: &'static str,
    pub help: &'static str,
    pub value: u64,
}

/// Plain-data view of one histogram (raw per-bucket counts; the
/// Prometheus exporter derives the cumulative form).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistSnapshot {
    pub name: &'static str,
    pub help: &'static str,
    pub buckets: [u64; N_BUCKETS],
    pub sum: u64,
    pub count: u64,
}

/// A consistent-enough point-in-time view of the whole registry: the
/// input both exporters render. Ordering is the registry's declaration
/// order, fixed across runs.
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub struct Snapshot {
    pub counters: Vec<MetricPoint>,
    pub gauges: Vec<MetricPoint>,
    pub hists: Vec<HistSnapshot>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_covers_the_edges() {
        assert_eq!(Histogram::bucket_index(0), 0);
        assert_eq!(Histogram::bucket_index(1), 0);
        assert_eq!(Histogram::bucket_index(2), 1);
        assert_eq!(Histogram::bucket_index(3), 2);
        assert_eq!(Histogram::bucket_index(4), 2);
        assert_eq!(Histogram::bucket_index(5), 3);
        assert_eq!(Histogram::bucket_index(1 << 31), 31);
        assert_eq!(Histogram::bucket_index((1 << 31) + 1), N_BUCKETS - 1);
        assert_eq!(Histogram::bucket_index(u64::MAX), N_BUCKETS - 1);
        // Every value lands in the bucket whose edge first covers it.
        for i in 0..N_BUCKETS {
            if let Some(edge) = Histogram::le(i) {
                assert_eq!(Histogram::bucket_index(edge), i, "edge 2^{i}");
            }
        }
    }

    #[test]
    fn histogram_accumulates_sum_count_and_buckets() {
        let h = Histogram::new("t", "test");
        for v in [0, 1, 2, 4, 1000, u64::MAX] {
            h.observe(v);
        }
        let s = h.snap();
        assert_eq!(s.count, 6);
        assert_eq!(s.sum, 0u64.wrapping_add(1 + 2 + 4 + 1000).wrapping_add(u64::MAX));
        assert_eq!(s.buckets[0], 2); // the observations 0 and 1
        assert_eq!(s.buckets[1], 1); // the observation 2
        assert_eq!(s.buckets[N_BUCKETS - 1], 1); // u64::MAX overflows to +Inf
        assert_eq!(s.buckets.iter().sum::<u64>(), 6);
    }

    #[test]
    fn counter_and_gauge_semantics() {
        let c = Counter::new("c", "count");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        c.reset();
        assert_eq!(c.get(), 0);

        let g = Gauge::new("g", "gauge");
        g.set_max(7);
        g.set_max(3);
        assert_eq!(g.get(), 7, "high-water mark keeps the max");
        g.set(2);
        assert_eq!(g.get(), 2);
    }
}

//! Exporters for the observability registry.
//!
//! Two renderings of one [`Snapshot`], both hand-rolled (the crate is
//! dependency-free by design) and both exact: every value is a `u64`
//! emitted as its full decimal expansion, never routed through `f64` —
//! the same encoding rule the report store enforces for cached stats.
//!
//! * [`prometheus`] — the text exposition format (`# HELP` / `# TYPE`
//!   headers, cumulative `_bucket{le="..."}` series, `_sum`/`_count`).
//! * [`json`] — the `target/repro/metrics.json` artifact: one object
//!   with `counters`, `gauges` and `histograms` maps in fixed registry
//!   order, raw (non-cumulative) bucket counts.
//!
//! Rendering is a pure function of the snapshot, so the round-trip
//! tests can pin bytes without touching the global registry.

use super::metrics::{HistSnapshot, Histogram, MetricPoint, Snapshot, N_BUCKETS};
use std::path::{Path, PathBuf};

fn push_u64(out: &mut String, v: u64) {
    let mut buf = [0u8; 20];
    let mut i = buf.len();
    let mut v = v;
    loop {
        i -= 1;
        buf[i] = b'0' + (v % 10) as u8;
        v /= 10;
        if v == 0 {
            break;
        }
    }
    out.push_str(std::str::from_utf8(&buf[i..]).expect("ascii digits"));
}

fn prom_header(out: &mut String, name: &str, help: &str, kind: &str) {
    out.push_str("# HELP ");
    out.push_str(name);
    out.push(' ');
    out.push_str(help);
    out.push_str("\n# TYPE ");
    out.push_str(name);
    out.push(' ');
    out.push_str(kind);
    out.push('\n');
}

fn prom_point(out: &mut String, p: &MetricPoint, kind: &str) {
    prom_header(out, p.name, p.help, kind);
    out.push_str(p.name);
    out.push(' ');
    push_u64(out, p.value);
    out.push('\n');
}

fn prom_hist(out: &mut String, h: &HistSnapshot) {
    prom_header(out, h.name, h.help, "histogram");
    let mut cum = 0u64;
    for i in 0..N_BUCKETS {
        cum += h.buckets[i];
        out.push_str(h.name);
        out.push_str("_bucket{le=\"");
        match Histogram::le(i) {
            Some(edge) => push_u64(out, edge),
            None => out.push_str("+Inf"),
        }
        out.push_str("\"} ");
        push_u64(out, cum);
        out.push('\n');
    }
    out.push_str(h.name);
    out.push_str("_sum ");
    push_u64(out, h.sum);
    out.push('\n');
    out.push_str(h.name);
    out.push_str("_count ");
    push_u64(out, h.count);
    out.push('\n');
}

/// Render the snapshot in the Prometheus text exposition format.
pub fn prometheus(s: &Snapshot) -> String {
    let mut out = String::new();
    for c in &s.counters {
        prom_point(&mut out, c, "counter");
    }
    for g in &s.gauges {
        prom_point(&mut out, g, "gauge");
    }
    for h in &s.hists {
        prom_hist(&mut out, h);
    }
    out
}

fn json_map<T>(out: &mut String, key: &str, items: &[T], mut one: impl FnMut(&mut String, &T)) {
    out.push('"');
    out.push_str(key);
    out.push_str("\":{");
    for (i, item) in items.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        one(out, item);
    }
    out.push('}');
}

/// Render the snapshot as the `metrics.json` artifact: exact `u64`
/// decimals throughout, keys in fixed registry order.
pub fn json(s: &Snapshot) -> String {
    let mut out = String::from("{\"format\":1,");
    json_map(&mut out, "counters", &s.counters, |out, c: &MetricPoint| {
        out.push('"');
        out.push_str(c.name);
        out.push_str("\":");
        push_u64(out, c.value);
    });
    out.push(',');
    json_map(&mut out, "gauges", &s.gauges, |out, g: &MetricPoint| {
        out.push('"');
        out.push_str(g.name);
        out.push_str("\":");
        push_u64(out, g.value);
    });
    out.push(',');
    json_map(&mut out, "histograms", &s.hists, |out, h: &HistSnapshot| {
        out.push('"');
        out.push_str(h.name);
        out.push_str("\":{\"buckets\":[");
        for (i, b) in h.buckets.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            push_u64(out, *b);
        }
        out.push_str("],\"sum\":");
        push_u64(out, h.sum);
        out.push_str(",\"count\":");
        push_u64(out, h.count);
        out.push('}');
    });
    out.push('}');
    out
}

/// Write both exports: the JSON artifact at `json_path` and the
/// Prometheus text next to it with a `.prom` extension. Returns the
/// Prometheus path. Parent directories are created as needed.
pub fn write_files(s: &Snapshot, json_path: &Path) -> Result<PathBuf, String> {
    if let Some(dir) = json_path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .map_err(|e| format!("create {}: {e}", dir.display()))?;
        }
    }
    std::fs::write(json_path, json(s))
        .map_err(|e| format!("write {}: {e}", json_path.display()))?;
    let prom_path = json_path.with_extension("prom");
    std::fs::write(&prom_path, prometheus(s))
        .map_err(|e| format!("write {}: {e}", prom_path.display()))?;
    Ok(prom_path)
}

/// Parse every sample line (`name value` / `name{labels} value`) back
/// out of a Prometheus exposition, ignoring comments. Test support for
/// the round-trip pin; labels are kept as part of the name.
pub fn parse_samples(text: &str) -> Vec<(String, u64)> {
    text.lines()
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .filter_map(|l| {
            let (name, value) = l.rsplit_once(' ')?;
            Some((name.to_string(), value.parse::<u64>().ok()?))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synthetic() -> Snapshot {
        let h = Histogram::new("lat_cycles", "synthetic latency");
        h.observe(1);
        h.observe(3);
        h.observe(u64::MAX);
        Snapshot {
            counters: vec![
                MetricPoint { name: "store_hit", help: "disk store hits", value: 31 },
                MetricPoint { name: "kernel_requests", help: "requests observed", value: u64::MAX },
            ],
            gauges: vec![MetricPoint {
                name: "sched_queue_depth_max",
                help: "deepest queue",
                value: 7,
            }],
            hists: vec![h.snap()],
        }
    }

    #[test]
    fn prometheus_round_trips_every_sample() {
        let snap = synthetic();
        let text = prometheus(&snap);
        let samples = parse_samples(&text);
        let get = |name: &str| -> u64 {
            samples
                .iter()
                .find(|(n, _)| n == name)
                .unwrap_or_else(|| panic!("missing sample {name}"))
                .1
        };
        assert_eq!(get("store_hit"), 31);
        assert_eq!(get("kernel_requests"), u64::MAX, "u64::MAX survives exactly");
        assert_eq!(get("sched_queue_depth_max"), 7);
        assert_eq!(get("lat_cycles_count"), 3);
        assert_eq!(get("lat_cycles_sum"), 3u64.wrapping_add(u64::MAX).wrapping_add(1));
        // Cumulative buckets: le="1" holds the 1, le="2" still 1 (3 is in
        // le="4"), +Inf holds everything.
        assert_eq!(get("lat_cycles_bucket{le=\"1\"}"), 1);
        assert_eq!(get("lat_cycles_bucket{le=\"2\"}"), 1);
        assert_eq!(get("lat_cycles_bucket{le=\"4\"}"), 2);
        assert_eq!(get("lat_cycles_bucket{le=\"+Inf\"}"), 3);
        // Sample count: 3 scalars + 33 buckets + sum + count.
        assert_eq!(samples.len(), 3 + N_BUCKETS + 2);
    }

    #[test]
    fn json_bytes_are_pinned_and_exact() {
        let mut snap = synthetic();
        snap.hists.clear(); // keep the pinned literal reviewable
        let text = json(&snap);
        assert_eq!(
            text,
            "{\"format\":1,\
             \"counters\":{\"store_hit\":31,\"kernel_requests\":18446744073709551615},\
             \"gauges\":{\"sched_queue_depth_max\":7},\
             \"histograms\":{}}"
        );
    }

    #[test]
    fn json_histograms_carry_raw_buckets() {
        let snap = synthetic();
        let text = json(&snap);
        assert!(text.contains("\"lat_cycles\":{\"buckets\":[1,0,1,"));
        assert!(text.contains(",\"count\":3}"));
        assert!(
            text.contains(&format!(
                "\"sum\":{}",
                3u64.wrapping_add(u64::MAX).wrapping_add(1)
            )),
            "sum is the exact wrapped u64"
        );
        // 33 comma-separated buckets inside the array.
        let arr = text.split("\"buckets\":[").nth(1).unwrap();
        let arr = arr.split(']').next().unwrap();
        assert_eq!(arr.split(',').count(), N_BUCKETS);
    }
}

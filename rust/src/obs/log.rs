//! Leveled progress logging for the `repro` CLI.
//!
//! Three levels: `Quiet` (errors only — errors go through `main`'s
//! `eprintln`, not this module), `Info` (the default: exactly the
//! progress lines the CLI has always printed, byte for byte — CI greps
//! the summary lines, so the default level must never reword them) and
//! `Debug` (extra diagnostics). Selected by `--quiet` / `--v` (or
//! `--verbose`), falling back to the `REPRO_LOG` environment variable
//! (`quiet` | `info` | `debug`, or `0` | `1` | `2`), defaulting to
//! `Info`.
//!
//! Call sites use the [`log_info!`](crate::log_info) /
//! [`log_debug!`](crate::log_debug) macros, which check the level and
//! forward to `println!` — stdout, same stream as before, so piping
//! behavior is unchanged at the default level.

use std::sync::atomic::{AtomicU8, Ordering};

/// Verbosity of CLI progress output.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Quiet = 0,
    Info = 1,
    Debug = 2,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// The active level.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Quiet,
        2 => Level::Debug,
        _ => Level::Info,
    }
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Whether messages at `l` should print.
pub fn enabled(l: Level) -> bool {
    level() >= l
}

fn parse(v: &str) -> Option<Level> {
    match v.trim().to_ascii_lowercase().as_str() {
        "quiet" | "0" => Some(Level::Quiet),
        "info" | "1" => Some(Level::Info),
        "debug" | "2" | "v" => Some(Level::Debug),
        _ => None,
    }
}

/// Resolve the level from explicit CLI flags, then `REPRO_LOG`, then the
/// `Info` default. `--quiet` wins over `--v` when both are given.
pub fn init(quiet: bool, verbose: bool) {
    let l = if quiet {
        Level::Quiet
    } else if verbose {
        Level::Debug
    } else {
        std::env::var("REPRO_LOG").ok().and_then(|v| parse(&v)).unwrap_or(Level::Info)
    };
    set_level(l);
}

/// The one sanctioned stdout writer in the library: the log macros below
/// funnel here, so `clippy::print_stdout` stays deniable crate-wide
/// without sprinkling allows at every call site.
#[allow(clippy::print_stdout)]
pub fn emit(args: std::fmt::Arguments<'_>) {
    println!("{args}");
}

/// Print at `Info` level (the CLI's default progress stream).
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Info) {
            $crate::obs::log::emit(format_args!($($arg)*));
        }
    };
}

/// Print at `Debug` level (`--v` / `REPRO_LOG=debug` diagnostics).
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Debug) {
            $crate::obs::log::emit(format_args!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order_and_parse() {
        assert!(Level::Quiet < Level::Info);
        assert!(Level::Info < Level::Debug);
        assert_eq!(parse("quiet"), Some(Level::Quiet));
        assert_eq!(parse("INFO"), Some(Level::Info));
        assert_eq!(parse("debug"), Some(Level::Debug));
        assert_eq!(parse("2"), Some(Level::Debug));
        assert_eq!(parse("nonsense"), None);
    }

    // No set_level/init tests here: the level is process-global state and
    // the test harness runs modules in parallel; tests/observability.rs
    // exercises init in its own process.
}

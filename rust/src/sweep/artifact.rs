//! JSON artifact emission: every figure target writes a machine-readable
//! report to `target/repro/<name>.json` (override the directory with
//! `REPRO_ARTIFACT_DIR`).
//!
//! The artifacts are the contract between the sweep engine and everything
//! downstream: the CI figure-smoke job asserts each one is non-empty,
//! `repro artifacts` lists them, and plotting scripts consume them without
//! re-running simulations.

use std::io;
use std::path::{Path, PathBuf};

use super::json::JsonValue;

/// Directory artifacts are written to: `REPRO_ARTIFACT_DIR` or the
/// default `target/repro`.
pub fn artifact_dir() -> PathBuf {
    crate::config::env::artifact_dir().unwrap_or_else(|| PathBuf::from("target/repro"))
}

/// Path of the artifact named `name` (no extension) under `dir`.
pub fn path_in(dir: &Path, name: &str) -> PathBuf {
    dir.join(format!("{name}.json"))
}

/// Write `value` as `<dir>/<name>.json`, creating the directory. The
/// write is atomic (temp + rename): sharded sweeps can have several
/// worker processes rendering the same figure, and a reader must see a
/// complete artifact from one of them, never a torn interleaving.
pub fn write_json_to(dir: &Path, name: &str, value: &JsonValue) -> io::Result<PathBuf> {
    std::fs::create_dir_all(dir)?;
    let path = path_in(dir, name);
    super::store::write_atomic(&path, (value.render() + "\n").as_bytes())?;
    Ok(path)
}

/// Write `value` as `<artifact_dir>/<name>.json`.
pub fn write_figure_json(name: &str, value: &JsonValue) -> io::Result<PathBuf> {
    write_json_to(&artifact_dir(), name, value)
}

/// Sorted `*.json` artifacts under `dir`; empty when the directory does
/// not exist yet.
pub fn list_in(dir: &Path) -> io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(out),
        Err(e) => return Err(e),
    };
    for entry in entries {
        let path = entry?.path();
        if path.extension().and_then(|e| e.to_str()) == Some("json") {
            out.push(path);
        }
    }
    out.sort();
    Ok(out)
}

/// Sorted artifacts under the default artifact directory.
pub fn list() -> io::Result<Vec<PathBuf>> {
    list_in(&artifact_dir())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("dlpim-artifact-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn write_then_list_round_trips() {
        let dir = tmp_dir("roundtrip");
        let doc = JsonValue::obj(vec![("figure", JsonValue::str("fig99"))]);
        let path = write_json_to(&dir, "fig99", &doc).unwrap();
        assert_eq!(path, path_in(&dir, "fig99"));
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "{\"figure\":\"fig99\"}\n");
        let listed = list_in(&dir).unwrap();
        assert_eq!(listed, vec![path]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn listing_a_missing_dir_is_empty_not_an_error() {
        let dir = tmp_dir("missing");
        assert_eq!(list_in(&dir).unwrap(), Vec::<PathBuf>::new());
    }

    #[test]
    fn listing_ignores_non_json() {
        let dir = tmp_dir("mixed");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("notes.txt"), "x").unwrap();
        write_json_to(&dir, "a", &JsonValue::Null).unwrap();
        let listed = list_in(&dir).unwrap();
        assert_eq!(listed.len(), 1);
        assert!(listed[0].ends_with("a.json"));
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

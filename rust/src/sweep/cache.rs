//! Process-wide report cache keyed by config hash, with a persistent
//! second level on disk.
//!
//! A figure suite re-runs the same (workload, config) points many times —
//! fig 9 and fig 10 share the always-subscribe HMC runs, every HMC figure
//! shares the baseline, and `repro all-figures` revisits them all. The
//! cache memoizes each point's [`SimReport`] under an FNV-1a hash of the
//! workload name and the *fully rendered* config, so any field difference
//! (policy, table geometry, scale knobs, seed) yields a distinct key while
//! repeated figure targets reuse results for free. Reports are
//! deterministic functions of their point, so reuse is transparent.
//!
//! The in-memory map here is the first level; [`super::store::DiskStore`]
//! persists the same keyed reports across processes (warm `repro` reruns,
//! interrupted sweeps, CI matrix legs). This module owns the *process
//! defaults* for that second level: the directory (`REPRO_CACHE_DIR`, or
//! `target/repro/cache`) and the kill switches (`--no-disk-cache` via
//! [`set_disk_cache_enabled`], or `REPRO_NO_DISK_CACHE=1`).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

use super::store::DiskStore;
use crate::config::{presets, SimConfig};
use crate::coordinator::report::SimReport;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

// BTreeMap, not HashMap (lint D1): nothing iterates this map today, but
// a determinism-critical module must not keep a hash-ordered collection
// around for a future `.iter()` to leak nondeterminism through.
static CACHE: OnceLock<Mutex<BTreeMap<u64, SimReport>>> = OnceLock::new();
static HITS: AtomicU64 = AtomicU64::new(0);
static MISSES: AtomicU64 = AtomicU64::new(0);

fn cache() -> &'static Mutex<BTreeMap<u64, SimReport>> {
    CACHE.get_or_init(|| Mutex::new(BTreeMap::new()))
}

#[inline]
fn fnv_step(h: u64, b: u8) -> u64 {
    (h ^ b as u64).wrapping_mul(FNV_PRIME)
}

/// Cache key of one sweep point: FNV-1a over the workload name and the
/// rendered `key = value` form of the config (which covers every tunable).
/// Trace-backed points additionally hash the trace file's *contents*, so
/// re-recording or transforming a trace in place invalidates cached
/// reports even though the path is unchanged.
pub fn config_key(workload: &str, cfg: &SimConfig) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in workload.as_bytes() {
        h = fnv_step(h, b);
    }
    h = fnv_step(h, 0);
    for &b in presets::render(cfg).as_bytes() {
        h = fnv_step(h, b);
    }
    if let Some(path) = &cfg.trace {
        h = fnv_step(h, 1);
        // An unreadable file still yields a deterministic key (the job
        // itself will fail loudly when it tries to open the trace).
        let payload = std::fs::read(path).unwrap_or_else(|e| e.to_string().into_bytes());
        for &b in &payload {
            h = fnv_step(h, b);
        }
    }
    h
}

/// Cached report for `key`, if any.
pub fn lookup(key: u64) -> Option<SimReport> {
    let hit = lock_cache().get(&key).cloned();
    if hit.is_some() {
        HITS.fetch_add(1, Ordering::SeqCst);
        crate::obs::CACHE_HIT.inc();
    } else {
        MISSES.fetch_add(1, Ordering::SeqCst);
        crate::obs::CACHE_MISS.inc();
    }
    hit
}

fn lock_cache() -> std::sync::MutexGuard<'static, BTreeMap<u64, SimReport>> {
    // A panic while holding this lock means a panic mid-`get`/`insert`
    // on plain data — nothing to recover; poisoning is fatal by design.
    cache().lock().expect("report cache mutex poisoned")
}

/// Store a computed report under `key`.
pub fn store(key: u64, report: &SimReport) {
    lock_cache().insert(key, report.clone());
}

/// Lifetime hit count (for tests and the CLI's cache report).
pub fn hits() -> u64 {
    HITS.load(Ordering::SeqCst)
}

/// Lifetime miss count.
pub fn misses() -> u64 {
    MISSES.load(Ordering::SeqCst)
}

/// Number of cached reports.
pub fn entries() -> usize {
    lock_cache().len()
}

/// Drop every cached report (tests; long-lived tools sweeping huge grids).
/// Only the in-memory level — the on-disk store is managed by
/// `repro cache clear|gc`.
pub fn clear() {
    lock_cache().clear();
}

// ---------------------------------------------------------------------
// Process defaults for the persistent second level.
// ---------------------------------------------------------------------

static DISK_DISABLED: AtomicBool = AtomicBool::new(false);

/// Enable/disable the process-default disk store (the CLI's
/// `--no-disk-cache`). Sweeps that were handed an explicit store are not
/// affected.
pub fn set_disk_cache_enabled(yes: bool) {
    DISK_DISABLED.store(!yes, Ordering::SeqCst);
}

/// The directory the process-default disk store lives in:
/// `REPRO_CACHE_DIR`, or `target/repro/cache`.
pub fn default_cache_dir() -> PathBuf {
    crate::config::env::cache_dir().unwrap_or_else(|| PathBuf::from("target/repro/cache"))
}

/// The process-default disk store, or `None` when persistence is turned
/// off (`--no-disk-cache`, or `REPRO_NO_DISK_CACHE=1` in the environment).
pub fn default_disk_store() -> Option<DiskStore> {
    if DISK_DISABLED.load(Ordering::SeqCst) {
        return None;
    }
    if crate::config::env::no_disk_cache() {
        return None;
    }
    Some(DiskStore::at(default_cache_dir()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::report::RunReport;
    use crate::policy::PolicyKind;
    use crate::stats::SimStats;

    fn dummy_report(cycles: u64) -> SimReport {
        SimReport {
            workload: "test".into(),
            policy: "never",
            runs: vec![RunReport {
                cycles,
                stats: SimStats::new(4),
                decisions: vec![],
                exhausted: false,
            }],
        }
    }

    #[test]
    fn key_depends_on_workload_and_config() {
        let cfg = SimConfig::hmc();
        let a = config_key("STRAdd", &cfg);
        assert_eq!(a, config_key("STRAdd", &cfg), "key must be stable");
        assert_ne!(a, config_key("STRCpy", &cfg), "workload must matter");
        let mut other = cfg.clone();
        other.policy = PolicyKind::Always;
        assert_ne!(a, config_key("STRAdd", &other), "policy must matter");
        let mut seeded = cfg.clone();
        seeded.seed ^= 1;
        assert_ne!(a, config_key("STRAdd", &seeded), "seed must matter");
    }

    #[test]
    fn trace_backed_key_hashes_file_contents() {
        let dir = std::env::temp_dir().join(format!("dlpim-cache-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("k.dlpt");
        let mut cfg = SimConfig::hmc();
        cfg.trace = Some(path.to_string_lossy().into_owned());
        std::fs::write(&path, b"v1").unwrap();
        let k1 = config_key("MIX", &cfg);
        assert_eq!(k1, config_key("MIX", &cfg), "stable for unchanged contents");
        std::fs::write(&path, b"v2").unwrap();
        assert_ne!(k1, config_key("MIX", &cfg), "contents must matter");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn store_then_lookup_round_trips() {
        // A key no simulation can produce: derived from a unique string.
        let key = config_key("cache-unit-test", &SimConfig::hmc()) ^ 0xDEAD;
        assert!(lookup(key).is_none());
        store(key, &dummy_report(321));
        let got = lookup(key).expect("cached");
        assert_eq!(got.runs[0].cycles, 321);
        assert!(hits() >= 1);
        assert!(misses() >= 1);
    }
}

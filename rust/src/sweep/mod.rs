//! The parallel sweep-execution engine behind the whole figure suite.
//!
//! Every figure of the paper is a sweep over `workload x config x policy`
//! points; DL-PIM's own evaluation is 31 DAMOV workloads crossed with
//! policies and two memory kinds. This module turns that matrix into one
//! engine:
//!
//! * a **condvar-parked scheduler** ([`scheduler`]) — one shared injector
//!   queue feeding all workers, idle workers parked on a condvar rather
//!   than polling — that saturates all cores regardless of how unevenly
//!   the points' simulation costs are distributed;
//! * **deterministic per-job seeding** — each point's PRNG seed is a pure
//!   function of the point, never of scheduling, so a sweep's reports are
//!   bit-identical at 1 thread and N threads;
//! * **panic isolation** — a poisoned workload takes down its own job
//!   ([`JobOutcome::result`] carries the panic message) and nothing else;
//! * a **report cache** ([`cache`]) keyed by config hash, so the many
//!   figure targets that share points (every HMC figure reuses the
//!   baseline runs) compute each point once per process;
//! * a **persistent content-addressed store** ([`store`]) under the same
//!   keys: before a job is scheduled the engine checks
//!   `target/repro/cache/<key>.json`, and every computed report is flushed
//!   there as its job completes — so a warm rerun of the whole figure
//!   suite schedules zero simulations, and an interrupted sweep resumes
//!   from its completed points;
//! * **JSON artifact emission** ([`artifact`]) to `target/repro/*.json`,
//!   consumed by the CLI, the benches and the CI figure-smoke job.

pub mod artifact;
pub mod cache;
pub mod json;
pub mod scheduler;
pub mod shard;
pub mod store;

use std::panic::{AssertUnwindSafe, catch_unwind};
use std::path::PathBuf;

use crate::config::SimConfig;
use crate::coordinator::driver::{simulate, simulate_observed};
use crate::coordinator::report::SimReport;
use crate::obs;
use crate::workloads::build_source;
use store::DiskStore;

/// One (workload, config) point of a sweep.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub workload: String,
    pub cfg: SimConfig,
}

impl SweepPoint {
    pub fn new(workload: impl Into<String>, cfg: SimConfig) -> Self {
        SweepPoint { workload: workload.into(), cfg }
    }

    /// The config this job actually simulates: the seed is re-derived
    /// deterministically from the base seed and the workload name, so
    /// every workload of a sweep draws an independent stream regardless
    /// of scheduling, while policy-vs-baseline comparisons of the same
    /// workload keep identical seeds (the paper's paired methodology).
    pub fn job_cfg(&self) -> SimConfig {
        let mut cfg = self.cfg.clone();
        cfg.seed = derive_seed(self.cfg.seed, &self.workload);
        cfg
    }

    /// Report-cache key of this point.
    pub fn key(&self) -> u64 {
        cache::config_key(&self.workload, &self.job_cfg())
    }
}

/// Mix the base seed with an FNV-1a hash of the workload name, finished
/// with a SplitMix64 avalanche. Stable across runs, platforms and thread
/// counts.
fn derive_seed(base: u64, workload: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in workload.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    let mut z = base ^ h;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Result of one sweep job, in submission order.
#[derive(Clone, Debug)]
pub struct JobOutcome {
    pub workload: String,
    /// The report, or the panic/build error message of a poisoned job.
    pub result: Result<SimReport, String>,
    /// True when the report came from the process-wide cache.
    pub from_cache: bool,
}

impl JobOutcome {
    /// The report; panics with the job's error for poisoned jobs (the
    /// strict accessor the figure harness uses — a figure with a missing
    /// bar is worse than a loud failure).
    pub fn report(&self) -> &SimReport {
        match &self.result {
            Ok(r) => r,
            Err(e) => panic!("sweep job {:?} failed: {e}", self.workload),
        }
    }

    /// Consume the outcome, yielding the report; panics like [`Self::report`]
    /// for poisoned jobs.
    pub fn into_report(self) -> SimReport {
        match self.result {
            Ok(r) => r,
            Err(e) => panic!("sweep job {:?} failed: {e}", self.workload),
        }
    }
}

/// Which persistent store a sweep consults (the in-memory level is
/// always first).
#[derive(Clone, Debug, Default)]
pub enum DiskCache {
    /// The process default: `REPRO_CACHE_DIR` or `target/repro/cache`,
    /// unless disabled (`--no-disk-cache` / `REPRO_NO_DISK_CACHE=1`).
    #[default]
    Default,
    /// In-memory caching only; nothing persists.
    Off,
    /// An explicit store directory (hermetic tests, tools managing
    /// several stores).
    Dir(PathBuf),
}

/// Builder for a parallel sweep.
pub struct Sweep {
    points: Vec<SweepPoint>,
    threads: Option<usize>,
    use_cache: bool,
    disk: DiskCache,
}

impl Sweep {
    pub fn new(points: Vec<SweepPoint>) -> Self {
        Sweep { points, threads: None, use_cache: true, disk: DiskCache::Default }
    }

    /// The full cross product `names x cfgs`, in `[workload][config]`
    /// order.
    pub fn over(names: &[&str], cfgs: &[SimConfig]) -> Self {
        let points = names
            .iter()
            .flat_map(|n| cfgs.iter().map(move |c| SweepPoint::new(*n, c.clone())))
            .collect();
        Sweep::new(points)
    }

    /// Worker-thread count. Defaults to `REPRO_THREADS` or the machine's
    /// available parallelism.
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = Some(n.max(1));
        self
    }

    /// Enable/disable the report cache for this sweep (on by default;
    /// determinism tests turn it off to force recomputation). Disabling
    /// it also disables disk persistence.
    pub fn use_cache(mut self, yes: bool) -> Self {
        self.use_cache = yes;
        self
    }

    /// Choose the persistent store for this sweep (defaults to the
    /// process-wide store; see [`DiskCache`]).
    pub fn disk_cache(mut self, disk: DiskCache) -> Self {
        self.disk = disk;
        self
    }

    /// Run every point; outcomes come back in submission order.
    pub fn run(self) -> Vec<JobOutcome> {
        let n = self.points.len();
        let mut outcomes: Vec<Option<JobOutcome>> = (0..n).map(|_| None).collect();

        let disk: Option<DiskStore> = if self.use_cache {
            match &self.disk {
                DiskCache::Default => cache::default_disk_store(),
                DiskCache::Off => None,
                DiskCache::Dir(dir) => Some(DiskStore::at(dir.clone())),
            }
        } else {
            None
        };

        // Each point's key is computed once and reused by the cache pass
        // and the job's store/flush — trace-backed keys hash the trace
        // file's contents, so recomputing per use would re-read the file.
        let keys: Vec<u64> = if self.use_cache {
            self.points.iter().map(|p| p.key()).collect()
        } else {
            vec![0; n]
        };

        // Cache pass: satisfy what we can without scheduling a job —
        // first the in-memory level, then the persistent store (which is
        // what makes an interrupted sweep resume from completed points).
        let mut live: Vec<usize> = Vec::with_capacity(n);
        for (i, p) in self.points.iter().enumerate() {
            if self.use_cache {
                let key = keys[i];
                let hit = cache::lookup(key).or_else(|| {
                    disk.as_ref().and_then(|d| d.load(key)).map(|rep| {
                        // Promote so later figures in this process skip
                        // the file read too.
                        cache::store(key, &rep);
                        rep
                    })
                });
                if let Some(rep) = hit {
                    outcomes[i] = Some(JobOutcome {
                        workload: p.workload.clone(),
                        result: Ok(rep),
                        from_cache: true,
                    });
                    continue;
                }
            }
            live.push(i);
        }

        let threads = self.threads.unwrap_or_else(scheduler::default_threads);
        let points = &self.points;
        let keys = &keys;
        let use_cache = self.use_cache;
        let disk_ref = disk.as_ref();
        let computed = scheduler::run_jobs(live.len(), threads, |k| {
            run_point(&points[live[k]], keys[live[k]], use_cache, disk_ref)
        });
        for (slot, outcome) in live.iter().zip(computed) {
            outcomes[*slot] = Some(outcome);
        }
        outcomes.into_iter().map(|o| o.expect("outcome per point")).collect()
    }
}

/// Simulate one point with panic isolation: the shared job body of the
/// in-process sweep ([`run_point`]) and the cross-process shard workers
/// ([`shard::ShardRunner`]). A workload that panics (or that does not
/// exist) yields `Err` with the panic message, never tears anything down.
pub(crate) fn simulate_point(point: &SweepPoint) -> Result<SimReport, String> {
    let cfg = point.job_cfg();
    let name = point.workload.as_str();
    let result = catch_unwind(AssertUnwindSafe(|| {
        // Trace-backed configs replay their file; generator configs build
        // the named Table III workload. Errors (unknown workload, corrupt
        // trace) poison only this job.
        let w = build_source(Some(name), &cfg).unwrap_or_else(|e| panic!("{e}"));
        let _t = obs::span(&obs::SPAN_KERNEL_RUN_NS);
        // The telemetry fork happens once per job, never per request: the
        // observed path threads a read-only recording closure through the
        // kernel, the plain path carries no observer at all. Reports are
        // identical either way (pinned by tests/observability.rs).
        if obs::enabled() {
            simulate_observed(&cfg, w, |_, r| {
                obs::record_request(r.network, r.queued_net, r.queued_mem(), r.array)
            })
        } else {
            simulate(&cfg, w)
        }
    }));
    result.map_err(|payload| {
        obs::SCHED_PANICKED_JOBS.inc();
        panic_message(payload.as_ref())
    })
}

/// Execute one point with panic isolation: a workload that panics (or that
/// does not exist) poisons only its own job. `key` is the point's cache
/// key, computed once by the caller (meaningless when `use_cache` is off).
fn run_point(point: &SweepPoint, key: u64, use_cache: bool, disk: Option<&DiskStore>) -> JobOutcome {
    let name = point.workload.clone();
    match simulate_point(point) {
        Ok(report) => {
            if use_cache {
                cache::store(key, &report);
                // Flush to disk as the job completes (not at sweep end),
                // so a killed sweep keeps everything it finished. A failed
                // write only costs a future recompute — never the job.
                if let Some(d) = disk {
                    let _ = d.save(key, &report);
                }
            }
            JobOutcome { workload: name, result: Ok(report), from_cache: false }
        }
        Err(e) => JobOutcome { workload: name, result: Err(e), from_cache: false },
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "job panicked".to_string()
    }
}

/// Run `names x cfgs` and return reports in `[workload][config]` order,
/// panicking if any job failed — the strict entry point the figure
/// harness and benches use.
pub fn run_matrix(names: &[&str], cfgs: &[SimConfig]) -> Vec<Vec<SimReport>> {
    let mut outcomes = Sweep::over(names, cfgs).run().into_iter();
    names
        .iter()
        .map(|_| {
            cfgs.iter()
                .map(|_| outcomes.next().expect("one outcome per point").into_report())
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::PolicyKind;

    fn tiny(policy: PolicyKind) -> SimConfig {
        let mut cfg = SimConfig::hmc();
        cfg.policy = policy;
        cfg.warmup_requests = 100;
        cfg.measure_requests = 800;
        cfg.epoch_cycles = 5_000;
        cfg
    }

    #[test]
    fn over_orders_workload_major() {
        let cfgs = [tiny(PolicyKind::Never), tiny(PolicyKind::Always)];
        let s = Sweep::over(&["STRAdd", "STRCpy"], &cfgs);
        let order: Vec<(&str, PolicyKind)> =
            s.points.iter().map(|p| (p.workload.as_str(), p.cfg.policy)).collect();
        assert_eq!(
            order,
            vec![
                ("STRAdd", PolicyKind::Never),
                ("STRAdd", PolicyKind::Always),
                ("STRCpy", PolicyKind::Never),
                ("STRCpy", PolicyKind::Always),
            ]
        );
    }

    #[test]
    fn job_seed_is_per_workload_not_per_policy() {
        let a = SweepPoint::new("STRAdd", tiny(PolicyKind::Never));
        let b = SweepPoint::new("STRCpy", tiny(PolicyKind::Never));
        let c = SweepPoint::new("STRAdd", tiny(PolicyKind::Always));
        assert_ne!(a.job_cfg().seed, b.job_cfg().seed, "workloads decorrelate");
        assert_eq!(a.job_cfg().seed, c.job_cfg().seed, "paired comparisons share seeds");
    }

    #[test]
    fn run_matrix_shape_and_names() {
        let cfgs = [tiny(PolicyKind::Never), tiny(PolicyKind::Never)];
        let out = run_matrix(&["STRAdd", "STRCpy"], &cfgs);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].len(), 2);
        assert_eq!(out[0][0].workload, "STRAdd");
        assert_eq!(out[1][1].workload, "STRCpy");
    }

    #[test]
    #[should_panic(expected = "unknown workload")]
    fn run_matrix_panics_on_unknown_workload() {
        run_matrix(&["NOPE"], &[tiny(PolicyKind::Never)]);
    }

    #[test]
    fn unknown_workload_is_an_err_outcome_not_a_crash() {
        let out = Sweep::new(vec![SweepPoint::new("NOPE", tiny(PolicyKind::Never))])
            .use_cache(false)
            .run();
        assert_eq!(out.len(), 1);
        let err = out[0].result.as_ref().unwrap_err();
        assert!(err.contains("unknown workload"), "got {err:?}");
    }
}

//! Work-stealing job scheduler for sweep points.
//!
//! Jobs are dealt round-robin onto per-worker deques; a worker drains its
//! own deque from the front and, when empty, steals from the back of its
//! siblings' deques (classic Chase-Lev shape, implemented with mutexed
//! deques — at sweep granularity a job is a whole simulation, thousands of
//! times longer than a lock, so contention is irrelevant while the
//! imbalance between a 31-workload figure's fast and slow jobs is not).
//! Results come back in submission order regardless of which worker ran
//! which job, and no job output depends on scheduling, so sweeps are
//! deterministic for any thread count.

use std::collections::VecDeque;
use std::sync::Mutex;

/// Worker-thread count: `REPRO_THREADS` overrides the machine's available
/// parallelism (useful for CI determinism checks and sizing experiments).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("REPRO_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4)
}

/// Run `f(0..n_jobs)` across `threads` workers with work stealing; returns
/// the results in job order. `f` must be safe to call from any worker (the
/// sweep layer wraps each job in `catch_unwind`, so `f` itself never
/// unwinds).
pub fn run_jobs<T, F>(n_jobs: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n_jobs == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n_jobs);
    if threads == 1 {
        return (0..n_jobs).map(f).collect();
    }

    let queues: Vec<Mutex<VecDeque<usize>>> = (0..threads)
        .map(|w| Mutex::new((0..n_jobs).filter(|j| j % threads == w).collect()))
        .collect();
    let results: Vec<Mutex<Option<T>>> = (0..n_jobs).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for w in 0..threads {
            let queues = &queues;
            let results = &results;
            let f = &f;
            scope.spawn(move || {
                // No job enqueues further jobs, so once every deque is
                // empty all work has been claimed and this worker is done.
                while let Some(j) = pop_own(&queues[w]).or_else(|| steal(queues, w)) {
                    let out = f(j);
                    *results[j].lock().unwrap() = Some(out);
                }
            });
        }
    });

    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("every job ran"))
        .collect()
}

fn pop_own(q: &Mutex<VecDeque<usize>>) -> Option<usize> {
    q.lock().unwrap().pop_front()
}

fn steal(queues: &[Mutex<VecDeque<usize>>], me: usize) -> Option<usize> {
    let n = queues.len();
    for off in 1..n {
        if let Some(j) = queues[(me + off) % n].lock().unwrap().pop_back() {
            return Some(j);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn results_come_back_in_submission_order() {
        let out = run_jobs(17, 4, |j| j * 10);
        assert_eq!(out, (0..17).map(|j| j * 10).collect::<Vec<_>>());
    }

    #[test]
    fn every_job_runs_exactly_once() {
        let counter = AtomicUsize::new(0);
        let out = run_jobs(100, 8, |j| {
            counter.fetch_add(1, Ordering::SeqCst);
            j
        });
        assert_eq!(counter.load(Ordering::SeqCst), 100);
        assert_eq!(out.len(), 100);
    }

    #[test]
    fn more_threads_than_jobs_is_fine() {
        assert_eq!(run_jobs(2, 64, |j| j + 1), vec![1, 2]);
        assert_eq!(run_jobs(0, 4, |j| j), Vec::<usize>::new());
    }

    #[test]
    fn skewed_job_durations_still_complete() {
        // Worker 0's local queue holds all the slow jobs; the others must
        // steal them for the run to finish promptly — either way, every
        // result must land.
        let out = run_jobs(24, 4, |j| {
            if j % 4 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            j
        });
        assert_eq!(out, (0..24).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_runs_inline() {
        let out = run_jobs(5, 1, |j| j * j);
        assert_eq!(out, vec![0, 1, 4, 9, 16]);
    }

    #[test]
    fn default_threads_is_positive() {
        assert!(default_threads() >= 1);
    }
}

//! Condvar-parked job scheduler for sweep points.
//!
//! One shared injector queue feeds all workers: an idle worker **parks on
//! a condvar** and is woken by exactly the submission (or close) that
//! concerns it — no sleep-poll loop, no busy-wait core burned while a
//! skewed sweep drains its last slow jobs. At sweep granularity a job is
//! a whole simulation, thousands of times longer than a lock, so a single
//! mutexed `VecDeque` outperforms anything cleverer while keeping the
//! semantics obvious. Results come back in submission order regardless of
//! which worker ran which job, and no job output depends on scheduling,
//! so sweeps are deterministic for any thread count.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// Worker-thread count: `REPRO_THREADS` overrides the machine's available
/// parallelism (useful for CI determinism checks and sizing experiments).
pub fn default_threads() -> usize {
    crate::config::env::threads().unwrap_or_else(|| {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4)
    })
}

/// The shared injector: a FIFO of job indices plus the closed flag, with
/// a condvar that parks idle workers until either changes.
struct Injector {
    q: Mutex<InjectorState>,
    cv: Condvar,
}

struct InjectorState {
    jobs: VecDeque<usize>,
    closed: bool,
}

impl Injector {
    fn new() -> Self {
        Injector {
            q: Mutex::new(InjectorState { jobs: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue one job and wake one parked worker.
    fn submit(&self, job: usize) {
        let depth = {
            let mut state = self.q.lock().expect("injector mutex poisoned");
            state.jobs.push_back(job);
            state.jobs.len()
        };
        crate::obs::SCHED_QUEUE_DEPTH_MAX.set_max(depth as u64);
        self.cv.notify_one();
    }

    /// No more submissions: wake *every* parked worker so all can observe
    /// the close and exit once the queue drains.
    fn close(&self) {
        self.q.lock().expect("injector mutex poisoned").closed = true;
        self.cv.notify_all();
    }

    /// Claim the next job, parking on the condvar while the queue is empty
    /// but still open. `None` means closed-and-drained: the worker exits.
    fn next_job(&self) -> Option<usize> {
        let mut state = self.q.lock().expect("injector mutex poisoned");
        // Span timing starts at the first park, so a worker that claims
        // immediately records nothing (and reads no clock).
        let mut parked_at: Option<std::time::Instant> = None;
        loop {
            if let Some(j) = state.jobs.pop_front() {
                if let Some(t0) = parked_at {
                    crate::obs::SPAN_QUEUE_WAIT_NS.observe(t0.elapsed().as_nanos() as u64);
                }
                return Some(j);
            }
            if state.closed {
                return None;
            }
            if parked_at.is_none() && crate::obs::enabled() {
                // lint:allow(D2) -- queue-wait telemetry only, and only when
                // `--metrics-out` opted in; the claimed job sequence (what
                // determinism depends on) never reads this clock.
                parked_at = Some(std::time::Instant::now());
            }
            crate::obs::SCHED_PARKS.inc();
            state = self.cv.wait(state).expect("injector mutex poisoned");
            crate::obs::SCHED_WAKES.inc();
        }
    }
}

/// Execute one job, counting it and (when telemetry is on) recording its
/// wall time — the same accounting on the inline single-thread path and
/// the worker loop, so `sched_jobs` totals match at any thread count.
fn run_one<T, F: Fn(usize) -> T>(f: &F, j: usize) -> T {
    crate::obs::SCHED_JOBS.inc();
    let _t = crate::obs::span(&crate::obs::SCHED_JOB_WALL_NS);
    f(j)
}

/// Run `f(0..n_jobs)` across `threads` condvar-parked workers; returns the
/// results in job order. `f` must be safe to call from any worker (the
/// sweep layer wraps each job in `catch_unwind`, so `f` itself never
/// unwinds).
pub fn run_jobs<T, F>(n_jobs: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n_jobs == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n_jobs);
    if threads == 1 {
        return (0..n_jobs).map(|j| run_one(&f, j)).collect();
    }

    let injector = Injector::new();
    let results: Vec<Mutex<Option<T>>> = (0..n_jobs).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            let injector = &injector;
            let results = &results;
            let f = &f;
            scope.spawn(move || {
                while let Some(j) = injector.next_job() {
                    let out = run_one(f, j);
                    *results[j].lock().expect("result slot mutex poisoned") = Some(out);
                }
            });
        }
        // Submit after spawning so the park/wake path is exercised on
        // every run, then close so drained workers exit instead of
        // parking forever.
        for j in 0..n_jobs {
            injector.submit(j);
        }
        injector.close();
    });

    results
        .into_iter()
        .map(|m| m.into_inner().expect("result slot mutex poisoned").expect("every job ran"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    #[test]
    fn results_come_back_in_submission_order() {
        let out = run_jobs(17, 4, |j| j * 10);
        assert_eq!(out, (0..17).map(|j| j * 10).collect::<Vec<_>>());
    }

    #[test]
    fn every_job_runs_exactly_once() {
        let counter = AtomicUsize::new(0);
        let out = run_jobs(100, 8, |j| {
            counter.fetch_add(1, Ordering::SeqCst);
            j
        });
        assert_eq!(counter.load(Ordering::SeqCst), 100);
        assert_eq!(out.len(), 100);
    }

    #[test]
    fn more_threads_than_jobs_is_fine() {
        assert_eq!(run_jobs(2, 64, |j| j + 1), vec![1, 2]);
        assert_eq!(run_jobs(0, 4, |j| j), Vec::<usize>::new());
    }

    #[test]
    fn skewed_job_durations_still_complete() {
        // A quarter of the jobs are slow; fast workers must keep claiming
        // from the shared injector (not spin on a private queue) for the
        // run to finish promptly — either way, every result must land.
        let out = run_jobs(24, 4, |j| {
            if j % 4 == 0 {
                std::thread::sleep(Duration::from_millis(5));
            }
            j
        });
        assert_eq!(out, (0..24).collect::<Vec<_>>());
    }

    #[test]
    fn parked_worker_wakes_on_late_submission() {
        // Drive the injector directly: a worker that finds the queue empty
        // parks on the condvar; a submission milliseconds later must wake
        // it (a sleep-poll loop would also pass, but the run_jobs path
        // contains no sleeps — this pins the handoff itself).
        let injector = Injector::new();
        let got = Mutex::new(Vec::new());
        std::thread::scope(|scope| {
            let injector = &injector;
            let got = &got;
            scope.spawn(move || {
                while let Some(j) = injector.next_job() {
                    got.lock().unwrap().push(j);
                }
            });
            std::thread::sleep(Duration::from_millis(10));
            injector.submit(7);
            std::thread::sleep(Duration::from_millis(10));
            injector.submit(8);
            injector.close();
        });
        assert_eq!(*got.lock().unwrap(), vec![7, 8]);
    }

    #[test]
    fn close_releases_parked_workers() {
        // Workers parked on an empty injector must all exit on close
        // without any job ever being submitted.
        let injector = Injector::new();
        std::thread::scope(|scope| {
            let injector = &injector;
            for _ in 0..4 {
                scope.spawn(move || {
                    assert_eq!(injector.next_job(), None);
                });
            }
            std::thread::sleep(Duration::from_millis(5));
            injector.close();
        });
    }

    #[test]
    fn single_thread_runs_inline() {
        let out = run_jobs(5, 1, |j| j * j);
        assert_eq!(out, vec![0, 1, 4, 9, 16]);
    }

    #[test]
    fn default_threads_is_positive() {
        assert!(default_threads() >= 1);
    }
}

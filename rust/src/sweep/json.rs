//! Minimal JSON document builder (`serde_json` is unavailable offline).
//!
//! Only what the figure artifacts need: objects with ordered keys, arrays,
//! strings, numbers, booleans, null. Rendering is compact (no whitespace)
//! so the CI smoke job can grep artifacts with fixed patterns.

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<JsonValue>),
    Obj(Vec<(String, JsonValue)>),
}

impl JsonValue {
    pub fn str(s: impl Into<String>) -> Self {
        JsonValue::Str(s.into())
    }

    pub fn num(x: impl Into<f64>) -> Self {
        JsonValue::Num(x.into())
    }

    /// Object from (key, value) pairs, preserving order.
    pub fn obj(pairs: Vec<(&str, JsonValue)>) -> Self {
        JsonValue::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Render to a compact JSON string.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            JsonValue::Null => out.push_str("null"),
            JsonValue::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            JsonValue::Num(x) => {
                // JSON has no NaN/Inf; emit null like serde_json's lossy mode.
                if x.is_finite() {
                    out.push_str(&format!("{x}"));
                } else {
                    out.push_str("null");
                }
            }
            JsonValue::Str(s) => {
                out.push('"');
                escape_into(s, out);
                out.push('"');
            }
            JsonValue::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            JsonValue::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('"');
                    escape_into(k, out);
                    out.push_str("\":");
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// JSON string escaping (shared with the disk cache's entry writer).
pub(crate) fn escape_into(s: &str, out: &mut String) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested_document() {
        let doc = JsonValue::obj(vec![
            ("figure", JsonValue::str("fig09")),
            (
                "rows",
                JsonValue::Arr(vec![JsonValue::obj(vec![
                    ("workload", JsonValue::str("SPLRad")),
                    ("speedup", JsonValue::num(2.05)),
                ])]),
            ),
        ]);
        assert_eq!(
            doc.render(),
            r#"{"figure":"fig09","rows":[{"workload":"SPLRad","speedup":2.05}]}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let v = JsonValue::str("a\"b\\c\nd");
        assert_eq!(v.render(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn whole_numbers_render_without_fraction() {
        assert_eq!(JsonValue::num(15.0).render(), "15");
        assert_eq!(JsonValue::num(0.5).render(), "0.5");
    }

    #[test]
    fn non_finite_numbers_become_null() {
        assert_eq!(JsonValue::Num(f64::NAN).render(), "null");
        assert_eq!(JsonValue::Num(f64::INFINITY).render(), "null");
    }

    #[test]
    fn scalars() {
        assert_eq!(JsonValue::Null.render(), "null");
        assert_eq!(JsonValue::Bool(true).render(), "true");
        assert_eq!(JsonValue::Arr(vec![]).render(), "[]");
    }
}

//! Sharded sweep execution: a work-claiming protocol over the store
//! directory that lets N independent `repro` processes cooperatively
//! execute one sweep — with byte-identical artifacts at any worker count.
//!
//! ## The protocol
//!
//! Workers share nothing but the store directory. For each pending
//! [`SweepPoint`](super::SweepPoint), in point order:
//!
//! 1. **Probe** — `store.load(key)`: if the report is already present
//!    (this sweep's or any earlier run's), the point is done.
//! 2. **Claim** — atomically create `<key>.claim` next to the entry
//!    (write the lease to a uniquely named temp file, `hard_link` it
//!    into place — creation with full contents is a single atomic step,
//!    same discipline as [`super::store::write_atomic`]). The lease
//!    carries the worker id, pid, build fingerprint, a unique nonce and
//!    a heartbeat timestamp a background thread refreshes on a coarse
//!    interval (TTL/3).
//! 3. **Simulate + flush** — the existing `Sweep`/`Kernel` job body
//!    ([`super::simulate_point`]), then `store.save(key, report)`.
//! 4. **Release** — remove the claim file (only if the lease is still
//!    ours: a peer may have legitimately reclaimed it after a heartbeat
//!    stall).
//!
//! A point whose claim is held by a *live* peer is skipped and revisited
//! on the next pass; a worker with nothing claimable sleeps briefly and
//! re-polls. Every worker loops until all reports are present, so the
//! globally last worker to finish always observes a complete point set —
//! which is what makes "any process can render; last-to-finish renders"
//! safe without any coordinator.
//!
//! ## Crash recovery
//!
//! A killed worker's heartbeat stops; once it is older than the TTL
//! (`REPRO_LEASE_TTL_MS`, default 30 s) any peer may **reclaim** the
//! lease: atomically overwrite the claim with its own lease, then read
//! it back — two racing reclaimers are serialized by the rename, and the
//! nonce read-back tells each whether it won. The loser treats the point
//! as held. An unreadable (torn mid-write) lease falls back to file
//! mtime, which a torn write has just refreshed — so corruption never
//! causes premature reclaim, only a full TTL wait.
//!
//! ## Why artifact bytes cannot depend on interleaving
//!
//! Reports are deterministic functions of their point (seeds derive from
//! the point, never from scheduling), saves are atomic renames of
//! identical bytes, and the renderer reads every report back from the
//! store in registry order ([`crate::exp::run_spec_sharded`]). Duplicate
//! simulation — two workers racing the same point through the ABA window
//! between a stale read and a reclaim — is therefore benign: both flush
//! the same bytes. Claims only ever gate *who computes*, never *what is
//! rendered*. `tests/shard_sweep.rs` pins 1-vs-N byte identity,
//! including under a mid-claim worker crash.

use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, SystemTime, UNIX_EPOCH};

use super::store::{self, DiskStore};
use super::SweepPoint;
use crate::obs;

/// Default lease TTL before a silent worker's claims become reclaimable.
/// Coarse on purpose: heartbeats are cheap (one small atomic write per
/// TTL/3), and a too-small TTL risks reclaiming a merely slow worker.
pub const DEFAULT_TTL: Duration = Duration::from_secs(30);

/// The lease TTL: `REPRO_LEASE_TTL_MS` or [`DEFAULT_TTL`].
pub fn default_ttl() -> Duration {
    std::env::var("REPRO_LEASE_TTL_MS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .filter(|&ms| ms > 0)
        .map(Duration::from_millis)
        .unwrap_or(DEFAULT_TTL)
}

fn now_ms() -> u64 {
    SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default().as_millis() as u64
}

/// Process-unique claim nonce: pid, a process-wide sequence and the
/// clock, avalanched. Nonces never reach reports or artifacts — they
/// only disambiguate who holds a claim file.
fn fresh_nonce() -> u64 {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let mut z = (std::process::id() as u64)
        ^ now_ms().rotate_left(20)
        // lint:allow(D3) -- nonce entropy: any distinct value works, no
        // cross-thread ordering is observable (nonces never reach reports
        // or artifacts, per the doc above).
        ^ (SEQ.fetch_add(1, Ordering::Relaxed) << 48);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// One claim lease, as stored in `<key>.claim`. Plain `key = value`
/// lines — human-readable in a debugging session, no JSON machinery.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Lease {
    /// Worker id (`--worker-id`, default `w<pid>`).
    pub worker: String,
    pub pid: u32,
    /// Build fingerprint of the claimant (diagnostic only: a claim is
    /// honored whatever build wrote it — reclaim is by heartbeat age).
    pub build: String,
    /// Unique per claim; the ownership check for release and reclaim.
    pub nonce: u64,
    /// Epoch milliseconds of the last heartbeat refresh.
    pub heartbeat_ms: u64,
}

impl Lease {
    /// A fresh lease for `worker` with the current heartbeat.
    pub fn new(worker: &str, heartbeat_ms: u64) -> Lease {
        Lease {
            worker: worker.to_string(),
            pid: std::process::id(),
            build: store::build_fingerprint().to_string(),
            nonce: fresh_nonce(),
            heartbeat_ms,
        }
    }

    pub fn render(&self) -> String {
        format!(
            "worker = {}\npid = {}\nbuild = {}\nnonce = {}\nheartbeat_ms = {}\n",
            self.worker, self.pid, self.build, self.nonce, self.heartbeat_ms
        )
    }

    /// Parse a lease; `None` for torn or foreign content (the staleness
    /// check then falls back to file mtime).
    pub fn parse(text: &str) -> Option<Lease> {
        let (mut worker, mut pid, mut build, mut nonce, mut hb) = (None, None, None, None, None);
        for line in text.lines() {
            let Some((k, v)) = line.split_once('=') else { continue };
            match (k.trim(), v.trim()) {
                ("worker", v) => worker = Some(v.to_string()),
                ("pid", v) => pid = v.parse().ok(),
                ("build", v) => build = Some(v.to_string()),
                ("nonce", v) => nonce = v.parse().ok(),
                ("heartbeat_ms", v) => hb = v.parse().ok(),
                _ => {}
            }
        }
        Some(Lease {
            worker: worker?,
            pid: pid?,
            build: build?,
            nonce: nonce?,
            heartbeat_ms: hb?,
        })
    }

    /// Read and parse the lease at `path`.
    pub fn read(path: &Path) -> Option<Lease> {
        Lease::parse(&std::fs::read_to_string(path).ok()?)
    }

    /// Whether this lease's heartbeat is older than `ttl` at `now_ms`.
    pub fn is_stale(&self, ttl: Duration, now_ms: u64) -> bool {
        now_ms.saturating_sub(self.heartbeat_ms) > ttl.as_millis() as u64
    }
}

/// Whether the claim file at `path` is reclaimable: its lease heartbeat
/// (or, for an unreadable lease, the file's mtime — which a torn write
/// has just refreshed, so corruption waits out the full TTL) is older
/// than `ttl`. A vanished file is not stale — the claim was released and
/// the caller should re-probe.
pub fn claim_is_stale(path: &Path, ttl: Duration) -> bool {
    match Lease::read(path) {
        Some(lease) => lease.is_stale(ttl, now_ms()),
        None => std::fs::metadata(path)
            .and_then(|m| m.modified())
            .ok()
            .and_then(|m| SystemTime::now().duration_since(m).ok())
            .map(|age| age > ttl)
            .unwrap_or(false),
    }
}

/// A held claim. Deliberately **not** released on drop: a worker that
/// panics mid-simulation must leave its claim file behind so the TTL
/// reclaim path — not unwind cleanup — is what recovers the point
/// (crash fidelity; the claim of a worker killed by SIGKILL gets no
/// destructor either). Call [`ShardRunner::release`] explicitly.
#[derive(Debug)]
pub struct Claim {
    key: u64,
    nonce: u64,
    /// True when this claim took over a stale lease.
    pub reclaimed: bool,
}

/// Per-worker accounting of one [`ShardRunner::run`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShardOutcome {
    /// Points this worker simulated under a fresh claim.
    pub claimed: usize,
    /// Points this worker simulated after reclaiming a stale lease.
    pub reclaimed: usize,
    /// Points whose report another worker (or an earlier run) had
    /// already flushed when this worker probed them.
    pub present: usize,
}

impl ShardOutcome {
    /// Points this worker simulated itself.
    pub fn simulated(&self) -> usize {
        self.claimed + self.reclaimed
    }
}

type ClaimHook = Box<dyn FnMut(u64) + Send>;

/// Shared state between a runner and its heartbeat thread. The mutex is
/// the serialization point between refresh and release: the heartbeat
/// rewrites the lease only while holding it, and release clears
/// `current` under the same lock before removing the file, so a
/// released claim can never be resurrected by a late refresh.
struct Beat {
    state: Mutex<BeatState>,
    cv: Condvar,
}

struct BeatState {
    current: Option<(PathBuf, Lease)>,
    stop: bool,
}

fn lock_recover<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    // A hook panic (the crash-injection tests) poisons its mutex; the
    // data is a plain Option either way, so recovery is always safe.
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// One cooperating worker: claims, simulates and flushes points of a
/// shared sweep. Owns a background heartbeat thread that keeps the
/// currently held claim's lease fresh (a runner holds at most one claim
/// at a time — [`Self::run`] releases each point before the next).
pub struct ShardRunner {
    store: DiskStore,
    worker: String,
    ttl: Duration,
    beat: Arc<Beat>,
    thread: Option<std::thread::JoinHandle<()>>,
    hook: Mutex<Option<ClaimHook>>,
}

impl ShardRunner {
    /// A worker named `worker` over `store`, with lease TTL `ttl`.
    pub fn new(store: DiskStore, worker: impl Into<String>, ttl: Duration) -> ShardRunner {
        let beat = Arc::new(Beat {
            state: Mutex::new(BeatState { current: None, stop: false }),
            cv: Condvar::new(),
        });
        // Refresh well inside the TTL so one missed wakeup cannot make a
        // live worker look dead.
        let interval = (ttl / 3).max(Duration::from_millis(5));
        let thread_beat = Arc::clone(&beat);
        let thread = std::thread::spawn(move || {
            let mut st = lock_recover(&thread_beat.state);
            loop {
                if st.stop {
                    return;
                }
                if let Some((path, lease)) = st.current.as_mut() {
                    lease.heartbeat_ms = now_ms();
                    // Best-effort: a failed refresh only risks an early
                    // reclaim, which duplicates work, never corrupts it.
                    let _ = store::write_atomic(path, lease.render().as_bytes());
                }
                st = match thread_beat.cv.wait_timeout(st, interval) {
                    Ok((g, _)) => g,
                    Err(p) => p.into_inner().0,
                };
            }
        });
        ShardRunner {
            store,
            worker: worker.into(),
            ttl,
            beat,
            thread: Some(thread),
            hook: Mutex::new(None),
        }
    }

    /// [`Self::new`] with the environment TTL and a `w<pid>` default id.
    pub fn with_defaults(store: DiskStore, worker_id: Option<String>) -> ShardRunner {
        let id = worker_id.unwrap_or_else(|| format!("w{}", std::process::id()));
        ShardRunner::new(store, id, default_ttl())
    }

    pub fn store(&self) -> &DiskStore {
        &self.store
    }

    pub fn worker_id(&self) -> &str {
        &self.worker
    }

    /// Test-only injection point: called with the point's key right
    /// after a claim is acquired, before simulation. A hook that panics
    /// models a worker dying mid-claim (the claim file stays behind —
    /// see [`Claim`] — and peers must reclaim it after the TTL).
    pub fn on_claim(&mut self, hook: impl FnMut(u64) + Send + 'static) {
        *lock_recover(&self.hook) = Some(Box::new(hook));
    }

    /// Try to claim `key`: `Ok(Some)` on acquisition (fresh or via
    /// stale-lease takeover), `Ok(None)` when a live peer holds it.
    pub fn try_claim(&self, key: u64) -> io::Result<Option<Claim>> {
        std::fs::create_dir_all(self.store.dir())?;
        let path = self.store.claim_path(key);
        let lease = Lease::new(&self.worker, now_ms());
        // Atomic create-with-contents: link a fully written temp file
        // into place. Either the link lands (we own the claim) or the
        // name exists (someone else does) — no torn intermediate.
        let tmp = path.with_file_name(format!(
            ".{:016x}.claim.{}.{}.tmp",
            key,
            std::process::id(),
            lease.nonce
        ));
        std::fs::write(&tmp, lease.render())?;
        let linked = std::fs::hard_link(&tmp, &path);
        let _ = std::fs::remove_file(&tmp);
        match linked {
            Ok(()) => {
                self.register(path, lease.clone());
                obs::SHARD_CLAIMS.inc();
                Ok(Some(Claim { key, nonce: lease.nonce, reclaimed: false }))
            }
            Err(e) if e.kind() == io::ErrorKind::AlreadyExists => {
                if !claim_is_stale(&path, self.ttl) {
                    return Ok(None);
                }
                obs::SHARD_LEASE_EXPIRED.inc();
                // Takeover: atomically replace the stale lease, then read
                // back — racing reclaimers are serialized by the rename,
                // and the nonce tells each whether it won.
                let mut fresh = lease;
                fresh.heartbeat_ms = now_ms();
                store::write_atomic(&path, fresh.render().as_bytes())?;
                match Lease::read(&path) {
                    Some(cur) if cur.nonce == fresh.nonce => {
                        let nonce = fresh.nonce;
                        self.register(path, fresh);
                        obs::SHARD_RECLAIMS.inc();
                        Ok(Some(Claim { key, nonce, reclaimed: true }))
                    }
                    _ => Ok(None),
                }
            }
            Err(e) => Err(e),
        }
    }

    /// Release a held claim: deregister it from the heartbeat, then
    /// remove the file — but only while the lease is still ours (after a
    /// heartbeat stall a peer may have reclaimed the point; their
    /// release handles it then).
    pub fn release(&self, claim: Claim) {
        let mut st = lock_recover(&self.beat.state);
        st.current = None;
        let path = self.store.claim_path(claim.key);
        if Lease::read(&path).map(|l| l.nonce == claim.nonce).unwrap_or(false) {
            let _ = std::fs::remove_file(&path);
        }
    }

    fn register(&self, path: PathBuf, lease: Lease) {
        lock_recover(&self.beat.state).current = Some((path, lease));
    }

    /// Work the point set until every report is present in the store.
    /// Passes over the points in order: probe, claim, simulate, flush,
    /// release. Points held by live peers are revisited; a pass that
    /// made no progress sleeps briefly before re-polling.
    ///
    /// A deterministic simulation failure (unknown workload, poisoned
    /// trace) releases the claim and fails this worker loudly — peers
    /// retry the same point immediately and fail the same way, so no
    /// worker wedges waiting on a TTL that cannot help.
    pub fn run(&self, points: &[SweepPoint]) -> Result<ShardOutcome, String> {
        let keys: Vec<u64> = points.iter().map(|p| p.key()).collect();
        let mut done = vec![false; points.len()];
        let mut out = ShardOutcome::default();
        let poll = (self.ttl / 5).clamp(Duration::from_millis(10), Duration::from_secs(1));
        loop {
            let mut progress = false;
            for (i, point) in points.iter().enumerate() {
                if done[i] {
                    continue;
                }
                if self.store.load(keys[i]).is_some() {
                    done[i] = true;
                    out.present += 1;
                    progress = true;
                    continue;
                }
                let claim = self
                    .try_claim(keys[i])
                    .map_err(|e| format!("{}: claim {:016x}: {e}", self.worker, keys[i]))?;
                let Some(claim) = claim else {
                    continue; // held by a live peer; revisit next pass
                };
                if let Some(hook) = lock_recover(&self.hook).as_mut() {
                    hook(keys[i]);
                }
                let reclaimed = claim.reclaimed;
                match super::simulate_point(point) {
                    Ok(report) => {
                        let saved = self.store.save(keys[i], &report);
                        self.release(claim);
                        saved.map_err(|e| {
                            format!("{}: flush {:016x}: {e}", self.worker, keys[i])
                        })?;
                        done[i] = true;
                        if reclaimed {
                            out.reclaimed += 1;
                        } else {
                            out.claimed += 1;
                        }
                        obs::SHARD_POINTS_SIMULATED.set_max(out.simulated() as u64);
                        progress = true;
                    }
                    Err(e) => {
                        self.release(claim);
                        return Err(format!(
                            "{}: point {} ({:016x}) failed: {e}",
                            self.worker, point.workload, keys[i]
                        ));
                    }
                }
            }
            if done.iter().all(|&d| d) {
                return Ok(out);
            }
            if !progress {
                std::thread::sleep(poll);
            }
        }
    }
}

impl Drop for ShardRunner {
    fn drop(&mut self) {
        {
            let mut st = lock_recover(&self.beat.state);
            st.stop = true;
            // The held claim (if any) is deliberately left on disk: a
            // dropped-while-holding runner is a crashed worker, and the
            // TTL reclaim path is the recovery mechanism under test.
        }
        self.beat.cv.notify_all();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_store(tag: &str) -> DiskStore {
        let dir = std::env::temp_dir()
            .join(format!("dlpim-shard-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        DiskStore::at(dir)
    }

    #[test]
    fn lease_renders_and_parses_round_trip() {
        let lease = Lease::new("w-test", 1_234_567);
        let got = Lease::parse(&lease.render()).expect("parses back");
        assert_eq!(got, lease);
        assert_eq!(got.build, store::build_fingerprint());
        assert!(Lease::parse("").is_none());
        assert!(Lease::parse("worker = a\npid = x\n").is_none(), "bad pid");
        assert!(Lease::parse("not a lease at all").is_none());
    }

    #[test]
    fn staleness_is_heartbeat_age_against_ttl() {
        let lease = Lease::new("w", 10_000);
        let ttl = Duration::from_millis(500);
        assert!(!lease.is_stale(ttl, 10_400), "within TTL");
        assert!(lease.is_stale(ttl, 10_501), "past TTL");
        assert!(!lease.is_stale(ttl, 9_000), "clock skew backwards is fresh");
    }

    #[test]
    fn claim_contention_and_release_cycle() {
        let store = tmp_store("contend");
        let a = ShardRunner::new(store.clone(), "a", Duration::from_secs(30));
        let b = ShardRunner::new(store.clone(), "b", Duration::from_secs(30));
        let c = a.try_claim(7).unwrap().expect("free key is claimable");
        assert!(!c.reclaimed);
        assert!(b.try_claim(7).unwrap().is_none(), "live lease is held");
        a.release(c);
        assert!(!store.claim_path(7).exists(), "release removes the file");
        let c2 = b.try_claim(7).unwrap().expect("released key is claimable");
        assert!(!c2.reclaimed);
        b.release(c2);
        std::fs::remove_dir_all(store.dir()).unwrap();
    }

    #[test]
    fn stale_lease_is_reclaimed_fresh_lease_is_not() {
        let store = tmp_store("reclaim");
        std::fs::create_dir_all(store.dir()).unwrap();
        // An ancient heartbeat, written as if by a long-dead worker.
        let dead = Lease::new("w-dead", 1);
        std::fs::write(store.claim_path(9), dead.render()).unwrap();
        let b = ShardRunner::new(store.clone(), "b", Duration::from_millis(50));
        let c = b.try_claim(9).unwrap().expect("stale lease is reclaimable");
        assert!(c.reclaimed);
        let cur = Lease::read(&store.claim_path(9)).unwrap();
        assert_eq!(cur.worker, "b", "reclaim rewrote the lease");
        b.release(c);
        std::fs::remove_dir_all(store.dir()).unwrap();
    }

    #[test]
    fn heartbeat_keeps_a_slow_worker_live() {
        let store = tmp_store("heartbeat");
        let ttl = Duration::from_millis(400);
        let a = ShardRunner::new(store.clone(), "a", ttl);
        let c = a.try_claim(3).unwrap().expect("claimable");
        // Sleep several TTLs: without refreshes the lease would be long
        // stale, but the heartbeat thread rewrites it every TTL/3.
        std::thread::sleep(Duration::from_millis(1200));
        let b = ShardRunner::new(store.clone(), "b", ttl);
        assert!(b.try_claim(3).unwrap().is_none(), "heartbeat kept the lease fresh");
        a.release(c);
        std::fs::remove_dir_all(store.dir()).unwrap();
    }

    #[test]
    fn torn_lease_falls_back_to_mtime_and_stays_held() {
        let store = tmp_store("torn");
        std::fs::create_dir_all(store.dir()).unwrap();
        // Unparseable content with a fresh mtime: must read as held.
        std::fs::write(store.claim_path(5), "garbage").unwrap();
        assert!(!claim_is_stale(&store.claim_path(5), Duration::from_secs(30)));
        std::fs::remove_dir_all(store.dir()).unwrap();
    }
}

//! The persistent, content-addressed report store behind the sweep
//! engine's disk cache.
//!
//! Every completed [`SweepPoint`](super::SweepPoint) result can be written
//! to `<cache dir>/<key>.json`, where `key` is the existing
//! [`super::cache::config_key`] — an FNV-1a hash over the workload name,
//! the fully rendered config and (for trace-backed points) the trace
//! file's *contents*. Because reports are deterministic functions of their
//! point, a stored entry is valid for any later process running the same
//! build, which is what makes warm `repro figure` / `repro sweep` reruns
//! free and interrupted sweeps resumable.
//!
//! ## Entry format
//!
//! One JSON object per entry, with a header that must validate before the
//! body is trusted:
//!
//! ```text
//! {"format":1,                 file-format version (FORMAT_VERSION)
//!  "build":"<16 hex>",         fingerprint of the src/ tree that wrote it
//!  "key":"<16 hex>",           the content-addressed cache key
//!  "body_hash":"<16 hex>",     FNV-1a of the canonical body encoding
//!  "report":{"workload":…, "policy":…, "runs":[…]}}   the SimReport
//! ```
//!
//! `body_hash` is verified against the *re-encoding* of the decoded
//! report, so corruption that still parses as JSON (a flipped digit in a
//! counter) is rejected as corrupt instead of being served as a wrong
//! figure value.
//!
//! `build` embeds [`build_fingerprint`] — a compile-time hash of the
//! crate's own `src/` tree (see `build.rs`) — so entries written by a
//! *different simulator* (e.g. a CI-cached `target/` restored across
//! commits) are stale, never wrong answers. All integers are written as
//! exact decimal JSON integers (no f64 round-trip), so a warm run's
//! artifacts are byte-identical to the cold run's.
//!
//! ## Crash and corruption behaviour
//!
//! * Writes go to a hidden `.*.tmp` file in the same directory and are
//!   published with an atomic `rename`, so concurrent readers (another
//!   `repro` process sharing the store) never observe a torn entry.
//! * Reads treat *any* defect — unreadable file, truncated/garbage JSON,
//!   format-version or build-fingerprint mismatch, key mismatch — as a
//!   plain cache miss: the point is recomputed and the entry rewritten.
//!   A poisoned cache can cost time, never correctness, and never panics.
//! * `repro cache stats|clear|gc` manages the store; `gc` removes stale
//!   and corrupt entries (plus temp files old enough to only be crash
//!   leftovers, never a live writer's) while keeping current entries.
//!
//! ## Claims
//!
//! Sharded sweeps ([`super::shard`]) coordinate through `<key>.claim`
//! lease files in the same directory. `gc` and `clear` are lease-aware:
//! they never reap an entry or temp file belonging to a claim whose
//! heartbeat is within the TTL (the claimant is about to overwrite it),
//! and they remove stale claim files (a crashed worker's leftovers)
//! while leaving live ones alone.

use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::coordinator::report::{RunReport, SimReport};
use crate::policy::{EpochDecision, PolicyKind};
use crate::stats::{LatencyBreakdown, ReuseStats, SimStats, TrafficStats, VaultDemand};

/// On-disk entry format version; bump on any layout change so old entries
/// read as stale instead of misparsing.
pub const FORMAT_VERSION: u32 = 1;

/// Compile-time fingerprint of this build's `src/` tree (see `build.rs`).
/// Entries written by a different fingerprint are stale.
pub fn build_fingerprint() -> &'static str {
    env!("DLPIM_SRC_FINGERPRINT")
}

/// A persistent report store rooted at one directory. Cheap to clone and
/// `Sync`: all state lives in the filesystem.
#[derive(Clone, Debug)]
pub struct DiskStore {
    dir: PathBuf,
}

/// What a scan of the store directory found.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Entries readable by this build.
    pub current: usize,
    /// Well-formed entries from another format version or build.
    pub stale: usize,
    /// Unparseable or mis-keyed entries.
    pub corrupt: usize,
    /// Leftover temporary files (a crashed writer).
    pub tmp: usize,
    /// Shard claim files with a live heartbeat (a worker is simulating
    /// that point right now).
    pub claims_active: usize,
    /// Shard claim files past the lease TTL (a crashed worker's).
    pub claims_stale: usize,
    /// Total bytes across all of the above.
    pub bytes: u64,
}

impl StoreStats {
    pub fn entries(&self) -> usize {
        self.current + self.stale + self.corrupt
    }
}

/// What `gc` removed and kept.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GcOutcome {
    pub kept: usize,
    pub removed_stale: usize,
    pub removed_corrupt: usize,
    pub removed_tmp: usize,
    /// Stale shard claim files removed (live claims are never touched).
    pub removed_claims: usize,
}

impl GcOutcome {
    pub fn removed(&self) -> usize {
        self.removed_stale + self.removed_corrupt + self.removed_tmp + self.removed_claims
    }
}

/// Why an entry failed to decode: stale entries are *expected* (another
/// build wrote them); corrupt ones indicate truncation or tampering. Both
/// read as cache misses; `gc`/`stats` report them separately. The
/// messages exist for debugging sessions; no caller reads them.
enum DecodeError {
    Stale(#[allow(dead_code)] String),
    Corrupt(#[allow(dead_code)] String),
}

impl DiskStore {
    /// A store rooted at `dir` (created lazily on first save).
    pub fn at(dir: impl Into<PathBuf>) -> Self {
        DiskStore { dir: dir.into() }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Path of the entry for `key`.
    pub fn entry_path(&self, key: u64) -> PathBuf {
        self.dir.join(format!("{key:016x}.json"))
    }

    /// Path of the shard claim lease for `key` (see [`super::shard`]).
    pub fn claim_path(&self, key: u64) -> PathBuf {
        self.dir.join(format!("{key:016x}.claim"))
    }

    /// Load the report stored under `key`, or `None` on any miss, defect
    /// or mismatch. Never panics: a poisoned entry is just a recompute.
    /// Outcomes feed the always-on observability counters (`store_hit`,
    /// `store_miss`, `store_stale`, `store_poisoned`) — `load` is the
    /// only place stale/corrupt can be told apart, because both collapse
    /// to `None` here by design.
    pub fn load(&self, key: u64) -> Option<SimReport> {
        let _t = crate::obs::span(&crate::obs::SPAN_STORE_LOOKUP_NS);
        let Ok(text) = std::fs::read_to_string(self.entry_path(key)) else {
            crate::obs::STORE_MISS.inc();
            return None;
        };
        match decode(&text, key) {
            Ok(report) => {
                crate::obs::STORE_HIT.inc();
                Some(report)
            }
            Err(DecodeError::Stale(_)) => {
                crate::obs::STORE_STALE.inc();
                None
            }
            Err(DecodeError::Corrupt(_)) => {
                crate::obs::STORE_POISONED.inc();
                None
            }
        }
    }

    /// Persist `report` under `key`: serialize, write to a same-directory
    /// temp file, publish with an atomic rename. Concurrent writers of the
    /// same key race benignly (identical content, last rename wins).
    pub fn save(&self, key: u64, report: &SimReport) -> io::Result<PathBuf> {
        std::fs::create_dir_all(&self.dir)?;
        let path = self.entry_path(key);
        write_atomic(&path, encode(key, report).as_bytes())?;
        Ok(path)
    }

    /// Classify everything in the store directory. A missing directory is
    /// an empty store.
    pub fn scan(&self) -> io::Result<StoreStats> {
        let mut stats = StoreStats::default();
        for (path, kind) in self.classify_dir()? {
            match kind {
                FileKind::Current => stats.current += 1,
                FileKind::Stale => stats.stale += 1,
                FileKind::Corrupt => stats.corrupt += 1,
                FileKind::Tmp => stats.tmp += 1,
                FileKind::ClaimLive => stats.claims_active += 1,
                FileKind::ClaimStale => stats.claims_stale += 1,
                FileKind::Foreign => continue,
            }
            stats.bytes += std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
        }
        Ok(stats)
    }

    /// Remove every entry, temp file and stale claim (files this store
    /// did not write — wrong name shape — are left alone). Returns the
    /// number removed. Lease-aware: an entry, temp file or claim
    /// belonging to a claim with a live heartbeat survives — a worker in
    /// another process is mid-flight on that point, and `clear` must not
    /// yank its lease or in-flight publish out from under it.
    pub fn clear(&self) -> io::Result<usize> {
        let live = self.live_claim_keys()?;
        let mut removed = 0;
        let entries = match std::fs::read_dir(&self.dir) {
            Ok(e) => e,
            Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(0),
            Err(e) => return Err(e),
        };
        for entry in entries {
            let path = entry?.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
                continue;
            };
            let owner = entry_key(name)
                .or_else(|| claim_key(name))
                .or_else(|| tmp_key(name));
            if owner.is_some_and(|k| live.contains(&k)) {
                continue;
            }
            let ours = entry_key(name).is_some()
                || claim_key(name).is_some()
                || (name.starts_with('.') && name.ends_with(".tmp"));
            if ours && std::fs::remove_file(&path).is_ok() {
                removed += 1;
            }
        }
        Ok(removed)
    }

    /// Keys of claim files whose heartbeat is within the shard TTL.
    fn live_claim_keys(&self) -> io::Result<std::collections::BTreeSet<u64>> {
        let ttl = super::shard::default_ttl();
        let mut live = std::collections::BTreeSet::new();
        let entries = match std::fs::read_dir(&self.dir) {
            Ok(e) => e,
            Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(live),
            Err(e) => return Err(e),
        };
        for entry in entries {
            let path = entry?.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
                continue;
            };
            if let Some(key) = claim_key(name) {
                if !super::shard::claim_is_stale(&path, ttl) {
                    live.insert(key);
                }
            }
        }
        Ok(live)
    }

    /// Remove stale and corrupt entries, keep entries this build can
    /// still serve. Temp files are removed only once they are older than
    /// an hour — a *live* writer's temp file (another process's sweep
    /// mid-publish) must survive a concurrent `repro cache gc`; only a
    /// crashed writer leaves temp files that old.
    pub fn gc(&self) -> io::Result<GcOutcome> {
        self.gc_with_tmp_age(std::time::Duration::from_secs(3600))
    }

    /// [`Self::gc`] with an explicit temp-file age threshold (tests).
    /// Lease-aware: files belonging to a live claim — the entry being
    /// rewritten, a temp file mid-publish, the claim itself — are kept
    /// whatever their classification; stale claims are removed.
    pub fn gc_with_tmp_age(&self, tmp_older_than: std::time::Duration) -> io::Result<GcOutcome> {
        let live = self.live_claim_keys()?;
        let mut out = GcOutcome::default();
        for (path, kind) in self.classify_dir()? {
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
            let claimed = entry_key(name)
                .or_else(|| tmp_key(name))
                .is_some_and(|k| live.contains(&k));
            match kind {
                FileKind::Current => out.kept += 1,
                FileKind::Foreign | FileKind::ClaimLive => {}
                FileKind::ClaimStale => {
                    if std::fs::remove_file(&path).is_ok() {
                        out.removed_claims += 1;
                    }
                }
                FileKind::Stale => {
                    if claimed {
                        out.kept += 1;
                    } else if std::fs::remove_file(&path).is_ok() {
                        out.removed_stale += 1;
                    }
                }
                FileKind::Corrupt => {
                    if claimed {
                        out.kept += 1;
                    } else if std::fs::remove_file(&path).is_ok() {
                        out.removed_corrupt += 1;
                    }
                }
                FileKind::Tmp => {
                    if claimed {
                        continue;
                    }
                    let age = std::fs::metadata(&path)
                        .and_then(|m| m.modified())
                        .ok()
                        // lint:allow(D2) -- GC lease protocol: tmp-file age vs the
                        // wall clock decides *whether stale files are deleted*,
                        // never a simulation result or an artifact byte.
                        .and_then(|m| std::time::SystemTime::now().duration_since(m).ok())
                        .unwrap_or_default();
                    if age >= tmp_older_than && std::fs::remove_file(&path).is_ok() {
                        out.removed_tmp += 1;
                    }
                }
            }
        }
        Ok(out)
    }

    fn classify_dir(&self) -> io::Result<Vec<(PathBuf, FileKind)>> {
        let mut out = Vec::new();
        let entries = match std::fs::read_dir(&self.dir) {
            Ok(e) => e,
            Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(out),
            Err(e) => return Err(e),
        };
        for entry in entries {
            let path = entry?.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
                continue;
            };
            let kind = if name.starts_with('.') && name.ends_with(".tmp") {
                FileKind::Tmp
            } else if claim_key(name).is_some() {
                if super::shard::claim_is_stale(&path, super::shard::default_ttl()) {
                    FileKind::ClaimStale
                } else {
                    FileKind::ClaimLive
                }
            } else if let Some(key) = entry_key(name) {
                match std::fs::read_to_string(&path) {
                    Err(_) => FileKind::Corrupt,
                    Ok(text) => match decode(&text, key) {
                        Ok(_) => FileKind::Current,
                        Err(DecodeError::Stale(_)) => FileKind::Stale,
                        Err(DecodeError::Corrupt(_)) => FileKind::Corrupt,
                    },
                }
            } else {
                // Not a name this store writes; never touch it.
                FileKind::Foreign
            };
            out.push((path, kind));
        }
        Ok(out)
    }
}

enum FileKind {
    Current,
    Stale,
    Corrupt,
    Tmp,
    ClaimLive,
    ClaimStale,
    Foreign,
}

/// Publish `bytes` at `path` via a uniquely named same-directory temp
/// file (`.{name}.{pid}.{seq}.tmp` — pid *and* a process-wide sequence,
/// so concurrent threads of one process never share a temp file) and an
/// atomic rename. Shared by the report store and the trace writers.
pub(crate) fn write_atomic(path: &Path, bytes: &[u8]) -> io::Result<()> {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let name = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_default();
    let mut tmp = path.to_path_buf();
    tmp.set_file_name(format!(
        ".{name}.{}.{}.tmp",
        std::process::id(),
        // lint:allow(D3) -- the counter only makes tmp names unique within
        // this process; no ordering between threads is observable (each
        // value is used once, and the rename target is the same either way).
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    std::fs::write(&tmp, bytes)?;
    match std::fs::rename(&tmp, path) {
        Ok(()) => Ok(()),
        Err(e) => {
            let _ = std::fs::remove_file(&tmp);
            Err(e)
        }
    }
}

/// `<16 hex>.json` → the key; anything else is not ours.
fn entry_key(name: &str) -> Option<u64> {
    let stem = name.strip_suffix(".json")?;
    if stem.len() != 16 {
        return None;
    }
    u64::from_str_radix(stem, 16).ok()
}

/// `<16 hex>.claim` → the key of a shard claim lease.
fn claim_key(name: &str) -> Option<u64> {
    let stem = name.strip_suffix(".claim")?;
    if stem.len() != 16 {
        return None;
    }
    u64::from_str_radix(stem, 16).ok()
}

/// Map a temp-file name (`.{16 hex}.json.{pid}.{seq}.tmp`) back to the
/// entry key it was publishing, or `None` for non-entry temps (claim
/// temps, trace sidecars).
fn tmp_key(name: &str) -> Option<u64> {
    let rest = name.strip_prefix('.')?.strip_suffix(".tmp")?;
    let dot = rest.find(".json")?;
    entry_key(&rest[..dot + ".json".len()])
}

// ---------------------------------------------------------------------
// Serialization. Hand-rolled (like sweep::json) because the cache needs
// *exact* u64 round-trips: JsonValue renders through f64, which silently
// rounds counters above 2^53. Integers are written as plain decimal JSON
// integers and parsed back with `u64::from_str`, so a disk round-trip is
// lossless and warm artifacts stay byte-identical.
// ---------------------------------------------------------------------

fn push_str(out: &mut String, s: &str) {
    out.push('"');
    super::json::escape_into(s, out);
    out.push('"');
}

// lint:allow(D4) -- generic JSON float support for *parsing foreign
// fields*; every report counter goes through the exact-u64 path above.
fn push_f64(out: &mut String, v: f64) {
    // Rust's f64 Display is the shortest representation that parses back
    // to the same bits, so finite values round-trip exactly.
    if v.is_finite() {
        out.push_str(&format!("{v}"));
    } else {
        out.push_str("null");
    }
}

fn push_u64s(out: &mut String, vs: &[u64]) {
    out.push('[');
    for (i, v) in vs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&v.to_string());
    }
    out.push(']');
}

/// FNV-1a over a byte string (the body-integrity hash).
fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Canonical body encoding of a report — the hashed portion of an entry.
/// Deterministic and round-trip-stable: `encode_body(decode(x)) ==
/// encode_body(original)` iff the decoded report equals the original
/// (integers are exact; f64 uses the shortest round-trip form).
fn encode_body(report: &SimReport) -> String {
    let mut s = String::with_capacity(1024);
    s.push_str("{\"workload\":");
    push_str(&mut s, &report.workload);
    s.push_str(",\"policy\":");
    push_str(&mut s, report.policy);
    s.push_str(",\"runs\":[");
    for (i, run) in report.runs.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        encode_run(&mut s, run);
    }
    s.push_str("]}");
    s
}

/// Serialize one cache entry.
pub(crate) fn encode(key: u64, report: &SimReport) -> String {
    let body = encode_body(report);
    format!(
        "{{\"format\":{FORMAT_VERSION},\"build\":\"{}\",\"key\":\"{key:016x}\",\
         \"body_hash\":\"{:016x}\",\"report\":{body}}}\n",
        build_fingerprint(),
        fnv64(body.as_bytes())
    )
}

fn encode_run(s: &mut String, run: &RunReport) {
    s.push_str("{\"cycles\":");
    s.push_str(&run.cycles.to_string());
    s.push_str(",\"exhausted\":");
    s.push_str(if run.exhausted { "true" } else { "false" });
    s.push_str(",\"decisions\":[");
    for (i, d) in run.decisions.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push('[');
        s.push_str(&d.epoch.to_string());
        s.push(',');
        s.push_str(&d.at.to_string());
        s.push(',');
        s.push_str(if d.enabled { "true" } else { "false" });
        s.push(',');
        s.push_str(&d.vaults_enabled.to_string());
        s.push(',');
        match d.avg_latency {
            Some(v) => push_f64(s, v),
            None => s.push_str("null"),
        }
        s.push(']');
    }
    s.push_str("],\"stats\":{\"latency\":");
    let l = &run.stats.latency;
    push_u64s(s, &[l.network, l.queue, l.array, l.requests]);
    s.push_str(",\"demand\":");
    push_u64s(s, run.stats.demand.counts());
    s.push_str(",\"traffic\":");
    push_u64s(s, &[run.stats.traffic.demand_bytes, run.stats.traffic.subscription_bytes]);
    s.push_str(",\"reuse\":");
    let r = &run.stats.reuse;
    push_u64s(s, &[r.subscriptions, r.local_hits, r.remote_hits]);
    s.push_str(",\"counters\":");
    push_u64s(
        s,
        &[
            run.stats.requests,
            run.stats.queue_net,
            run.stats.queue_mem,
            run.stats.l1_hits,
            run.stats.local_requests,
            run.stats.subscriptions,
            run.stats.sub_nacks,
            run.stats.unsubscriptions,
            run.stats.resubscriptions,
        ],
    );
    s.push_str("}}");
}

/// Parse + validate one entry against the key it claims to serve.
fn decode(text: &str, expected_key: u64) -> Result<SimReport, DecodeError> {
    let doc = parse::parse(text).map_err(DecodeError::Corrupt)?;
    let top = doc.obj().map_err(DecodeError::Corrupt)?;

    // Header first: version and build gate everything else.
    let format = field(top, "format").map_err(DecodeError::Corrupt)?;
    let format = format.u64().map_err(DecodeError::Corrupt)?;
    if format != FORMAT_VERSION as u64 {
        return Err(DecodeError::Stale(format!(
            "entry format v{format}, this build reads v{FORMAT_VERSION}"
        )));
    }
    let build = field(top, "build")
        .and_then(|v| v.str())
        .map_err(DecodeError::Corrupt)?;
    if build != build_fingerprint() {
        return Err(DecodeError::Stale(format!(
            "entry written by build {build}, this build is {}",
            build_fingerprint()
        )));
    }
    let key = field(top, "key").and_then(|v| v.str()).map_err(DecodeError::Corrupt)?;
    if key != format!("{expected_key:016x}") {
        return Err(DecodeError::Corrupt(format!(
            "entry claims key {key}, expected {expected_key:016x}"
        )));
    }

    let report = (|| -> Result<SimReport, String> {
        let body = field(top, "report")?.obj()?;
        let workload = field(body, "workload")?.str()?.to_string();
        let policy_name = field(body, "policy")?.str()?;
        let policy = PolicyKind::parse(policy_name)
            .ok_or_else(|| format!("unknown policy {policy_name:?}"))?
            .as_str();
        let runs = field(body, "runs")?
            .arr()?
            .iter()
            .map(decode_run)
            .collect::<Result<Vec<_>, String>>()?;
        Ok(SimReport { workload, policy, runs })
    })()
    .map_err(DecodeError::Corrupt)?;

    // Body integrity: the stored hash must match the canonical
    // re-encoding of what we just decoded, so corruption that still
    // parses (a flipped digit) cannot surface as a wrong figure value.
    let stored = field(top, "body_hash")
        .and_then(|v| v.str())
        .map_err(DecodeError::Corrupt)?;
    let actual = format!("{:016x}", fnv64(encode_body(&report).as_bytes()));
    if stored != actual {
        return Err(DecodeError::Corrupt(format!(
            "body hash mismatch: entry says {stored}, body is {actual}"
        )));
    }
    Ok(report)
}

fn decode_run(v: &parse::Jv) -> Result<RunReport, String> {
    let run = v.obj()?;
    let cycles = field(run, "cycles")?.u64()?;
    let exhausted = field(run, "exhausted")?.boolean()?;
    let decisions = field(run, "decisions")?
        .arr()?
        .iter()
        .map(|d| {
            let d = d.arr()?;
            if d.len() != 5 {
                return Err(format!("decision tuple has {} fields, expected 5", d.len()));
            }
            Ok(EpochDecision {
                epoch: d[0].u64()?,
                at: d[1].u64()?,
                enabled: d[2].boolean()?,
                vaults_enabled: u32::try_from(d[3].u64()?)
                    .map_err(|_| "vaults_enabled out of range".to_string())?,
                avg_latency: match &d[4] {
                    parse::Jv::Null => None,
                    // lint:allow(D4) -- decodes a policy decision's recorded
                    // float; never accumulated, round-trips losslessly.
                    other => Some(other.f64()?),
                },
            })
        })
        .collect::<Result<Vec<_>, String>>()?;

    let stats_obj = field(run, "stats")?.obj()?;
    let lat = u64s(field(stats_obj, "latency")?, 4)?;
    let demand = field(stats_obj, "demand")?
        .arr()?
        .iter()
        .map(|v| v.u64())
        .collect::<Result<Vec<_>, String>>()?;
    if demand.len() > u16::MAX as usize {
        return Err(format!("demand counts {} vaults (max {})", demand.len(), u16::MAX));
    }
    let traffic = u64s(field(stats_obj, "traffic")?, 2)?;
    let reuse = u64s(field(stats_obj, "reuse")?, 3)?;
    let c = u64s(field(stats_obj, "counters")?, 9)?;

    let stats = SimStats {
        latency: LatencyBreakdown {
            network: lat[0],
            queue: lat[1],
            array: lat[2],
            requests: lat[3],
        },
        demand: VaultDemand::from_counts(demand),
        traffic: TrafficStats { demand_bytes: traffic[0], subscription_bytes: traffic[1] },
        reuse: ReuseStats {
            subscriptions: reuse[0],
            local_hits: reuse[1],
            remote_hits: reuse[2],
        },
        requests: c[0],
        queue_net: c[1],
        queue_mem: c[2],
        l1_hits: c[3],
        local_requests: c[4],
        subscriptions: c[5],
        sub_nacks: c[6],
        unsubscriptions: c[7],
        resubscriptions: c[8],
    };
    Ok(RunReport { cycles, stats, decisions, exhausted })
}

fn field<'a>(obj: &'a [(String, parse::Jv)], key: &str) -> Result<&'a parse::Jv, String> {
    obj.iter()
        .find(|(k, _)| k == key)
        .map(|(_, v)| v)
        .ok_or_else(|| format!("missing field {key:?}"))
}

fn u64s(v: &parse::Jv, expect: usize) -> Result<Vec<u64>, String> {
    let arr = v.arr()?;
    if arr.len() != expect {
        return Err(format!("array has {} values, expected {expect}", arr.len()));
    }
    arr.iter().map(|v| v.u64()).collect()
}

/// Minimal JSON parser for cache entries (the crate's `sweep::json` is a
/// writer only). Numbers are kept as raw text so integers convert without
/// an f64 round-trip; all errors are `String`s — the store maps them to
/// cache misses, never panics.
mod parse {
    /// A parsed JSON value; numbers stay raw until a type is requested.
    #[derive(Clone, Debug, PartialEq)]
    pub(super) enum Jv {
        Null,
        Bool(bool),
        Num(String),
        Str(String),
        Arr(Vec<Jv>),
        Obj(Vec<(String, Jv)>),
    }

    impl Jv {
        pub(super) fn obj(&self) -> Result<&[(String, Jv)], String> {
            match self {
                Jv::Obj(kvs) => Ok(kvs),
                other => Err(format!("expected object, got {}", kind(other))),
            }
        }

        pub(super) fn arr(&self) -> Result<&[Jv], String> {
            match self {
                Jv::Arr(vs) => Ok(vs),
                other => Err(format!("expected array, got {}", kind(other))),
            }
        }

        pub(super) fn str(&self) -> Result<&str, String> {
            match self {
                Jv::Str(s) => Ok(s),
                other => Err(format!("expected string, got {}", kind(other))),
            }
        }

        pub(super) fn boolean(&self) -> Result<bool, String> {
            match self {
                Jv::Bool(b) => Ok(*b),
                other => Err(format!("expected bool, got {}", kind(other))),
            }
        }

        pub(super) fn u64(&self) -> Result<u64, String> {
            match self {
                Jv::Num(raw) => raw
                    .parse::<u64>()
                    .map_err(|_| format!("expected unsigned integer, got {raw:?}")),
                other => Err(format!("expected number, got {}", kind(other))),
            }
        }

        // lint:allow(D4) -- typed read-out for JSON floats (decisions'
        // avg_latency); report counters use the exact `u64` reader above.
        pub(super) fn f64(&self) -> Result<f64, String> {
            match self {
                Jv::Num(raw) => {
                    // lint:allow(D4) -- same justification as the signature.
                    raw.parse::<f64>().map_err(|_| format!("bad number {raw:?}"))
                }
                other => Err(format!("expected number, got {}", kind(other))),
            }
        }
    }

    fn kind(v: &Jv) -> &'static str {
        match v {
            Jv::Null => "null",
            Jv::Bool(_) => "bool",
            Jv::Num(_) => "number",
            Jv::Str(_) => "string",
            Jv::Arr(_) => "array",
            Jv::Obj(_) => "object",
        }
    }

    /// Deep-nesting guard: no legitimate entry nests past a handful of
    /// levels, and a hostile `[[[[…` must not blow the stack.
    const MAX_DEPTH: u32 = 64;

    pub(super) fn parse(text: &str) -> Result<Jv, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing bytes at offset {}", p.i));
        }
        Ok(v)
    }

    struct Parser<'a> {
        b: &'a [u8],
        i: usize,
    }

    impl Parser<'_> {
        fn skip_ws(&mut self) {
            while matches!(self.b.get(self.i), Some(b' ' | b'\t' | b'\n' | b'\r')) {
                self.i += 1;
            }
        }

        fn value(&mut self, depth: u32) -> Result<Jv, String> {
            if depth > MAX_DEPTH {
                return Err("nesting too deep".into());
            }
            match self.b.get(self.i) {
                None => Err("unexpected end of input".into()),
                Some(b'{') => self.object(depth),
                Some(b'[') => self.array(depth),
                Some(b'"') => Ok(Jv::Str(self.string()?)),
                Some(b't') => self.literal("true", Jv::Bool(true)),
                Some(b'f') => self.literal("false", Jv::Bool(false)),
                Some(b'n') => self.literal("null", Jv::Null),
                Some(c) if c.is_ascii_digit() || *c == b'-' => self.number(),
                Some(c) => Err(format!("unexpected byte {:?} at offset {}", *c as char, self.i)),
            }
        }

        fn literal(&mut self, word: &str, v: Jv) -> Result<Jv, String> {
            if self.b[self.i..].starts_with(word.as_bytes()) {
                self.i += word.len();
                Ok(v)
            } else {
                Err(format!("bad literal at offset {}", self.i))
            }
        }

        fn number(&mut self) -> Result<Jv, String> {
            let start = self.i;
            while matches!(
                self.b.get(self.i),
                Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
            ) {
                self.i += 1;
            }
            let raw = std::str::from_utf8(&self.b[start..self.i])
                .expect("number token bytes are ASCII");
            // Validate now so a malformed token fails the parse, not a
            // later typed read.
            // lint:allow(D4) -- syntax validation of a JSON number token;
            // the parsed value is discarded (Jv keeps the raw digits).
            raw.parse::<f64>().map_err(|_| format!("bad number {raw:?}"))?;
            Ok(Jv::Num(raw.to_string()))
        }

        fn string(&mut self) -> Result<String, String> {
            self.i += 1; // opening quote
            let mut out = String::new();
            loop {
                match self.b.get(self.i) {
                    None => return Err("unterminated string".into()),
                    Some(b'"') => {
                        self.i += 1;
                        return Ok(out);
                    }
                    Some(b'\\') => {
                        self.i += 1;
                        match self.b.get(self.i) {
                            Some(b'"') => out.push('"'),
                            Some(b'\\') => out.push('\\'),
                            Some(b'/') => out.push('/'),
                            Some(b'n') => out.push('\n'),
                            Some(b'r') => out.push('\r'),
                            Some(b't') => out.push('\t'),
                            Some(b'b') => out.push('\u{8}'),
                            Some(b'f') => out.push('\u{c}'),
                            Some(b'u') => {
                                let hex = self
                                    .b
                                    .get(self.i + 1..self.i + 5)
                                    .ok_or("truncated \\u escape")?;
                                let code = u32::from_str_radix(
                                    std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                                    16,
                                )
                                .map_err(|_| "bad \\u escape")?;
                                out.push(
                                    char::from_u32(code).ok_or("bad \\u code point")?,
                                );
                                self.i += 4;
                            }
                            _ => return Err("bad escape".into()),
                        }
                        self.i += 1;
                    }
                    Some(_) => {
                        // Consume one UTF-8 scalar (entries are valid UTF-8:
                        // read_to_string already validated).
                        let rest = std::str::from_utf8(&self.b[self.i..])
                            .map_err(|_| "invalid UTF-8")?;
                        let c = rest.chars().next().expect("non-empty slice");
                        out.push(c);
                        self.i += c.len_utf8();
                    }
                }
            }
        }

        fn array(&mut self, depth: u32) -> Result<Jv, String> {
            self.i += 1; // '['
            let mut out = Vec::new();
            self.skip_ws();
            if self.b.get(self.i) == Some(&b']') {
                self.i += 1;
                return Ok(Jv::Arr(out));
            }
            loop {
                self.skip_ws();
                out.push(self.value(depth + 1)?);
                self.skip_ws();
                match self.b.get(self.i) {
                    Some(b',') => self.i += 1,
                    Some(b']') => {
                        self.i += 1;
                        return Ok(Jv::Arr(out));
                    }
                    _ => return Err(format!("expected ',' or ']' at offset {}", self.i)),
                }
            }
        }

        fn object(&mut self, depth: u32) -> Result<Jv, String> {
            self.i += 1; // '{'
            let mut out = Vec::new();
            self.skip_ws();
            if self.b.get(self.i) == Some(&b'}') {
                self.i += 1;
                return Ok(Jv::Obj(out));
            }
            loop {
                self.skip_ws();
                if self.b.get(self.i) != Some(&b'"') {
                    return Err(format!("expected object key at offset {}", self.i));
                }
                let key = self.string()?;
                self.skip_ws();
                if self.b.get(self.i) != Some(&b':') {
                    return Err(format!("expected ':' at offset {}", self.i));
                }
                self.i += 1;
                self.skip_ws();
                let value = self.value(depth + 1)?;
                out.push((key, value));
                self.skip_ws();
                match self.b.get(self.i) {
                    Some(b',') => self.i += 1,
                    Some(b'}') => {
                        self.i += 1;
                        return Ok(Jv::Obj(out));
                    }
                    _ => return Err(format!("expected ',' or '}}' at offset {}", self.i)),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_store(tag: &str) -> DiskStore {
        let dir = std::env::temp_dir()
            .join(format!("dlpim-store-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        DiskStore::at(dir)
    }

    /// A report exercising every serialized field, including values that
    /// do not survive an f64 round-trip.
    fn thorny_report() -> SimReport {
        let mut stats = SimStats::new(4);
        stats.latency = LatencyBreakdown {
            network: u64::MAX,
            queue: (1 << 53) + 1, // not representable as f64
            array: 3,
            requests: 7,
        };
        stats.demand = VaultDemand::from_counts(vec![0, u64::MAX, 42, 1]);
        stats.traffic = TrafficStats { demand_bytes: 123, subscription_bytes: 456 };
        stats.reuse = ReuseStats { subscriptions: 1, local_hits: 2, remote_hits: 3 };
        stats.requests = 9;
        stats.queue_net = 10;
        stats.queue_mem = 11;
        stats.l1_hits = 12;
        stats.local_requests = 13;
        stats.subscriptions = 14;
        stats.sub_nacks = 15;
        stats.unsubscriptions = 16;
        stats.resubscriptions = 17;
        SimReport {
            workload: "mix(SPL+\"quoted\")".into(),
            policy: "adaptive-hops",
            runs: vec![
                RunReport {
                    cycles: (1 << 60) + 3,
                    stats,
                    decisions: vec![
                        EpochDecision {
                            epoch: 1,
                            at: 1_000_000,
                            enabled: true,
                            vaults_enabled: 32,
                            avg_latency: Some(0.1 + 0.2),
                        },
                        EpochDecision {
                            epoch: 2,
                            at: 2_000_000,
                            enabled: false,
                            vaults_enabled: 0,
                            avg_latency: None,
                        },
                    ],
                    exhausted: true,
                },
                RunReport {
                    cycles: 0,
                    stats: SimStats::new(2),
                    decisions: vec![],
                    exhausted: false,
                },
            ],
        }
    }

    #[test]
    fn save_load_round_trips_every_field() {
        let store = tmp_store("roundtrip");
        let report = thorny_report();
        let key = 0xDEAD_BEEF_0000_0001;
        store.save(key, &report).unwrap();
        let got = store.load(key).expect("entry readable");
        assert_eq!(got, report);
        std::fs::remove_dir_all(store.dir()).unwrap();
    }

    #[test]
    fn save_leaves_no_temp_files(){
        let store = tmp_store("atomic");
        store.save(7, &thorny_report()).unwrap();
        let names: Vec<String> = std::fs::read_dir(store.dir())
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names, vec!["0000000000000007.json".to_string()]);
        std::fs::remove_dir_all(store.dir()).unwrap();
    }

    #[test]
    fn missing_entry_and_missing_dir_are_misses() {
        let store = tmp_store("missing");
        assert!(store.load(1).is_none(), "missing dir");
        assert_eq!(store.scan().unwrap(), StoreStats::default());
        store.save(1, &thorny_report()).unwrap();
        assert!(store.load(2).is_none(), "missing entry");
        std::fs::remove_dir_all(store.dir()).unwrap();
    }

    #[test]
    fn renamed_entry_is_rejected_by_key_check() {
        let store = tmp_store("renamed");
        store.save(0xAA, &thorny_report()).unwrap();
        std::fs::copy(store.entry_path(0xAA), store.entry_path(0xBB)).unwrap();
        assert!(store.load(0xBB).is_none(), "key mismatch must read as a miss");
        std::fs::remove_dir_all(store.dir()).unwrap();
    }

    #[test]
    fn corrupt_truncated_and_stale_entries_are_misses() {
        let store = tmp_store("poison");
        let key = 0xF00D;
        store.save(key, &thorny_report()).unwrap();
        let path = store.entry_path(key);
        let good = std::fs::read_to_string(&path).unwrap();

        for (label, bad) in [
            ("truncated", good[..good.len() / 2].to_string()),
            ("garbage", "not json at all".to_string()),
            ("empty", String::new()),
            ("deep-nesting", format!("{}1{}", "[".repeat(500), "]".repeat(500))),
            ("future-version", good.replacen("\"format\":1", "\"format\":999", 1)),
            ("other-build", good.replacen(build_fingerprint(), "0123456789abcdef", 1)),
            // Still-parseable corruption: a flipped digit must fail the
            // body hash, not surface as a wrong figure value.
            ("flipped-digit", good.replacen("\"cycles\":0", "\"cycles\":7", 1)),
        ] {
            std::fs::write(&path, &bad).unwrap();
            assert!(store.load(key).is_none(), "{label} must be a miss, not a panic");
        }

        // And a rewrite recovers the entry.
        store.save(key, &thorny_report()).unwrap();
        assert!(store.load(key).is_some());
        std::fs::remove_dir_all(store.dir()).unwrap();
    }

    #[test]
    fn scan_gc_and_clear_classify_correctly() {
        let store = tmp_store("gc");
        let report = thorny_report();
        store.save(1, &report).unwrap();
        store.save(2, &report).unwrap();
        // Stale: a valid entry from a "different build".
        let stale = encode(3, &report).replacen(build_fingerprint(), "ffffffffffffffff", 1);
        std::fs::write(store.entry_path(3), stale).unwrap();
        // Corrupt: truncated.
        std::fs::write(store.entry_path(4), "{\"format\":1").unwrap();
        // Leftover tmp from a crashed writer + a foreign file.
        std::fs::write(store.dir().join(".0000000000000005.99.0.tmp"), "x").unwrap();
        std::fs::write(store.dir().join("notes.json"), "{}").unwrap();

        let stats = store.scan().unwrap();
        assert_eq!(
            (stats.current, stats.stale, stats.corrupt, stats.tmp),
            (2, 1, 1, 1),
            "{stats:?}"
        );
        assert!(stats.bytes > 0);

        // A default gc must NOT remove the (fresh) temp file — it could
        // belong to a live writer in another process.
        let gc = store.gc().unwrap();
        assert_eq!(gc.kept, 2);
        assert_eq!((gc.removed_stale, gc.removed_corrupt, gc.removed_tmp), (1, 1, 0));
        assert!(store.dir().join(".0000000000000005.99.0.tmp").exists());
        // With the age threshold collapsed, it goes too.
        let gc = store.gc_with_tmp_age(std::time::Duration::ZERO).unwrap();
        assert_eq!((gc.kept, gc.removed_tmp), (2, 1));
        assert!(store.load(1).is_some() && store.load(2).is_some());
        assert!(store.dir().join("notes.json").exists(), "foreign files untouched");

        let removed = store.clear().unwrap();
        assert_eq!(removed, 2);
        assert!(store.load(1).is_none());
        assert!(store.dir().join("notes.json").exists(), "clear keeps foreign files");
        std::fs::remove_dir_all(store.dir()).unwrap();
    }

    #[test]
    fn concurrent_saves_and_loads_never_tear() {
        let store = tmp_store("race");
        let report = thorny_report();
        let key = 0xACE;
        std::thread::scope(|scope| {
            let writer_store = store.clone();
            let writer_report = report.clone();
            scope.spawn(move || {
                for _ in 0..200 {
                    writer_store.save(key, &writer_report).unwrap();
                }
            });
            let reader_store = store.clone();
            let reader_report = report.clone();
            scope.spawn(move || {
                for _ in 0..200 {
                    if let Some(got) = reader_store.load(key) {
                        assert_eq!(got, reader_report, "torn read");
                    }
                }
            });
        });
        std::fs::remove_dir_all(store.dir()).unwrap();
    }

    #[test]
    fn entry_key_only_accepts_store_names() {
        assert_eq!(entry_key("0000000000000007.json"), Some(7));
        assert_eq!(entry_key("00000000000000ZZ.json"), None);
        assert_eq!(entry_key("7.json"), None, "short stems are foreign");
        assert_eq!(entry_key("fig09.json"), None);
        assert_eq!(entry_key("0000000000000007.txt"), None);
    }

    #[test]
    fn claim_and_tmp_keys_parse_store_names_only() {
        assert_eq!(claim_key("0000000000000007.claim"), Some(7));
        assert_eq!(claim_key("7.claim"), None);
        assert_eq!(claim_key("0000000000000007.json"), None);
        assert_eq!(tmp_key(".0000000000000007.json.99.0.tmp"), Some(7));
        assert_eq!(tmp_key(".0000000000000007.claim.99.0.tmp"), None, "claim temps carry no entry");
        assert_eq!(tmp_key(".notes.json.99.0.tmp"), None);
        assert_eq!(tmp_key("0000000000000007.json"), None);
    }

    #[test]
    fn gc_and_clear_respect_live_claims() {
        use super::super::shard::Lease;
        let now_ms = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_millis() as u64;
        let store = tmp_store("claims");
        let report = thorny_report();
        // Key 1: current entry under a live claim. Key 2: *stale* entry
        // under a live claim (its worker is about to rewrite it). Key 3:
        // only a stale claim (crashed worker). Key 4: live claim plus an
        // in-flight publish temp file. Key 5: plain unclaimed entry.
        store.save(1, &report).unwrap();
        let stale = encode(2, &report).replacen(build_fingerprint(), "ffffffffffffffff", 1);
        std::fs::write(store.entry_path(2), stale).unwrap();
        store.save(5, &report).unwrap();
        for key in [1u64, 2, 4] {
            std::fs::write(store.claim_path(key), Lease::new("w-live", now_ms).render())
                .unwrap();
        }
        std::fs::write(store.claim_path(3), Lease::new("w-dead", 1).render()).unwrap();
        let tmp4 = store.dir().join(".0000000000000004.json.99.0.tmp");
        std::fs::write(&tmp4, "x").unwrap();

        let stats = store.scan().unwrap();
        assert_eq!(
            (stats.current, stats.stale, stats.tmp, stats.claims_active, stats.claims_stale),
            (2, 1, 1, 3, 1),
            "{stats:?}"
        );

        // Even with the temp-age threshold collapsed, gc must keep the
        // stale entry and the temp file under live claims, and must keep
        // the live claims themselves — only the dead worker's claim goes.
        let gc = store.gc_with_tmp_age(std::time::Duration::ZERO).unwrap();
        assert_eq!(
            (gc.kept, gc.removed_stale, gc.removed_tmp, gc.removed_claims),
            (3, 0, 0, 1),
            "{gc:?}"
        );
        assert!(store.entry_path(2).exists(), "claimed stale entry survives gc");
        assert!(tmp4.exists(), "claimed tmp survives gc");
        assert!(store.claim_path(1).exists() && !store.claim_path(3).exists());

        // Clear removes only what no live claim owns: the unclaimed
        // entry 5. Everything mid-flight survives.
        let removed = store.clear().unwrap();
        assert_eq!(removed, 1, "only the unclaimed entry");
        assert!(store.entry_path(1).exists() && store.entry_path(2).exists());
        assert!(!store.entry_path(5).exists());
        assert!(tmp4.exists() && store.claim_path(4).exists());
        std::fs::remove_dir_all(store.dir()).unwrap();
    }
}

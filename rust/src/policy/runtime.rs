//! Epoch-granular adaptive policy engine (§III-D).
//!
//! Execution is divided into epochs (10^6 cycles in the paper; scaled by
//! config). During an epoch every vault accumulates its registers; at the
//! epoch boundary a decision is made for the next epoch:
//!
//! * **hops-based** — each vault keeps subscription on iff its feedback
//!   register (benefit minus cost in hop counts) is non-negative;
//! * **latency-based** — the central vault compares the epoch's global
//!   average latency to the previous epoch's (2% threshold) and reverses
//!   the policy when latency regressed; the broadcast takes ~1000 cycles
//!   to reach all vaults;
//! * **leading-set sampling** — two sampled set groups run always-on and
//!   always-off permanently; followers adopt whichever leader saw lower
//!   average latency (§III-D5), solving the always-unsubscription problem.

use super::registers::{FeedbackRegister, LatencyRegisters};
use super::PolicyKind;
use crate::config::SimConfig;
use crate::{Cycle, VaultId};

/// Leading-set classification of a subscription-table set (§III-D5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SetGroup {
    /// Subscription always enabled for these sets.
    LeadAlways,
    /// Subscription always disabled for these sets.
    LeadNever,
    /// Follows the epoch decision.
    Follower,
}

/// One epoch-boundary decision (logged for tests, figures and the CLI).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EpochDecision {
    pub epoch: u64,
    pub at: Cycle,
    /// Global (or follower-group) subscription setting for the next epoch.
    pub enabled: bool,
    /// Number of vaults individually enabled (hops-based policy).
    pub vaults_enabled: u32,
    /// Global average latency observed in the closing epoch.
    pub avg_latency: Option<f64>,
}

/// Runtime state of the active policy.
pub struct PolicyRuntime {
    kind: PolicyKind,
    n_vaults: usize,

    feedback: Vec<FeedbackRegister>,
    vault_latency: Vec<LatencyRegisters>,
    vault_enabled: Vec<bool>,

    global_enabled: bool,
    prev_global_enabled: bool,
    global_effective_at: Cycle,
    prev_avg_latency: Option<f64>,

    lead_always: LatencyRegisters,
    lead_never: LatencyRegisters,
    lead_stride: u32,

    /// Most recent global average latency observed in an epoch that ran
    /// with subscription ON / OFF (the central vault's memory across
    /// epochs). Leading sets alone cannot see *global* damage — e.g. a
    /// zero-reuse workload whose subscription traffic slows every set
    /// equally — so the follower decision also compares these.
    last_on_avg: Option<f64>,
    last_off_avg: Option<f64>,
    /// Epochs since the losing setting was last tried; forces periodic
    /// re-exploration so phase changes are noticed (§III-D5's concern).
    epochs_since_flip: u32,
    /// The epoch now ending began right after a policy flip: its latency
    /// sample is a transient (e.g. the unsubscription drain after turning
    /// off) and must not be recorded as that setting's steady state.
    transient: bool,

    epoch_cycles: Cycle,
    next_epoch_end: Cycle,
    epoch_index: u64,
    threshold_pct: f64,
    broadcast_lat: Cycle,

    /// Decision log (one per completed epoch).
    pub decisions: Vec<EpochDecision>,
}

impl PolicyRuntime {
    pub fn new(cfg: &SimConfig) -> Self {
        let n = cfg.n_vaults as usize;
        let lead_stride = if cfg.kind_uses_sampling() && cfg.leading_sets > 0 {
            (cfg.sub_table_sets / cfg.leading_sets).max(2)
        } else {
            0
        };
        PolicyRuntime {
            kind: cfg.policy,
            n_vaults: n,
            feedback: vec![FeedbackRegister::default(); n],
            vault_latency: vec![LatencyRegisters::default(); n],
            // "In the first epoch, we turn on subscription across all
            // vaults" (§III-D2).
            vault_enabled: vec![true; n],
            global_enabled: true,
            prev_global_enabled: true,
            global_effective_at: 0,
            prev_avg_latency: None,
            lead_always: LatencyRegisters::default(),
            lead_never: LatencyRegisters::default(),
            lead_stride,
            last_on_avg: None,
            last_off_avg: None,
            epochs_since_flip: 0,
            transient: false,
            epoch_cycles: cfg.epoch_cycles,
            next_epoch_end: cfg.epoch_cycles,
            epoch_index: 0,
            threshold_pct: cfg.latency_threshold_pct,
            broadcast_lat: cfg.global_broadcast_lat as Cycle,
            decisions: Vec::new(),
        }
    }

    pub fn kind(&self) -> PolicyKind {
        self.kind
    }

    /// Leading-set classification for a table set index.
    #[inline]
    pub fn group(&self, set: u32) -> SetGroup {
        if self.lead_stride == 0 {
            return SetGroup::Follower;
        }
        match set % self.lead_stride {
            0 => SetGroup::LeadAlways,
            1 => SetGroup::LeadNever,
            _ => SetGroup::Follower,
        }
    }

    #[inline]
    fn global_at(&self, now: Cycle) -> bool {
        if now >= self.global_effective_at {
            self.global_enabled
        } else {
            self.prev_global_enabled
        }
    }

    /// Should vault `v` subscribe a block living in table set `set` at
    /// `now`?
    #[inline]
    pub fn enabled(&self, v: VaultId, set: u32, now: Cycle) -> bool {
        match self.kind {
            PolicyKind::Never => false,
            PolicyKind::Always => true,
            PolicyKind::AdaptiveHops => self.vault_enabled[v as usize],
            PolicyKind::AdaptiveLatency => self.global_at(now),
            PolicyKind::Adaptive => match self.group(set) {
                SetGroup::LeadAlways => true,
                SetGroup::LeadNever => false,
                SetGroup::Follower => self.global_at(now),
            },
        }
    }

    /// Feed one completed demand request into the epoch registers.
    #[allow(clippy::too_many_arguments)]
    pub fn on_request(
        &mut self,
        requester: VaultId,
        served_by: VaultId,
        subscribed_path: bool,
        actual_hops: u32,
        baseline_hops: u32,
        latency: u64,
        set: u32,
        _now: Cycle,
    ) {
        self.vault_latency[requester as usize].record(latency);
        if self.kind == PolicyKind::Adaptive {
            match self.group(set) {
                SetGroup::LeadAlways => self.lead_always.record(latency),
                SetGroup::LeadNever => self.lead_never.record(latency),
                SetGroup::Follower => {}
            }
        }
        if subscribed_path {
            // Hops estimate without subscription: request + data straight
            // between requester and home, i.e. 2 x baseline one-way hops.
            let est = 2 * baseline_hops;
            if est > actual_hops {
                self.feedback[requester as usize].benefit();
            } else if actual_hops > est {
                self.feedback[requester as usize].cost();
                if served_by != requester {
                    // Subscription-away fix (§III-D4): the vault holding the
                    // block also pays.
                    self.feedback[served_by as usize].cost();
                }
            }
        }
    }

    /// Advance the epoch clock to `now`; returns decisions for every epoch
    /// boundary crossed (normally 0 or 1).
    pub fn tick(&mut self, now: Cycle) -> Vec<EpochDecision> {
        let mut out = Vec::new();
        while now >= self.next_epoch_end {
            let at = self.next_epoch_end;
            out.push(self.decide(at));
            self.next_epoch_end += self.epoch_cycles;
        }
        out
    }

    fn global_avg(&self) -> Option<f64> {
        let (sum, count) = self
            .vault_latency
            .iter()
            .fold((0u64, 0u64), |(s, c), r| (s + r.latency_sum, c + r.requests));
        if count == 0 {
            None
        } else {
            Some(sum as f64 / count as f64)
        }
    }

    fn decide(&mut self, at: Cycle) -> EpochDecision {
        self.epoch_index += 1;
        let avg = self.global_avg();

        match self.kind {
            PolicyKind::Never | PolicyKind::Always => {}
            PolicyKind::AdaptiveHops => {
                for v in 0..self.n_vaults {
                    self.vault_enabled[v] = self.feedback[v].is_positive();
                }
            }
            PolicyKind::AdaptiveLatency => {
                let next = match (self.prev_avg_latency, avg) {
                    (Some(prev), Some(cur)) => {
                        // Reverse the decision when latency regressed by
                        // more than the threshold (§III-D3).
                        if cur > prev * (1.0 + self.threshold_pct / 100.0) {
                            !self.global_enabled
                        } else {
                            self.global_enabled
                        }
                    }
                    // Initial epochs: fall back to the hops feedback sign.
                    _ => {
                        let total: i64 =
                            self.feedback.iter().map(|f| f.value()).sum();
                        total >= 0
                    }
                };
                self.apply_global(next, at);
                if avg.is_some() {
                    self.prev_avg_latency = avg;
                }
            }
            PolicyKind::Adaptive => {
                let thr = self.threshold_pct / 100.0;
                let setting = self.global_at(at);
                // Remember the epoch's global latency under its setting —
                // steady-state epochs only (the first epoch after a flip is
                // a transient: e.g. the unsubscription drain right after
                // turning off).
                if let (Some(cur), false) = (avg, self.transient) {
                    if setting {
                        self.last_on_avg = Some(cur);
                    } else {
                        self.last_off_avg = Some(cur);
                    }
                }
                // Global on-vs-off comparison (central vault memory across
                // epochs), exploring the untried setting first.
                let mut next = match (self.last_on_avg, self.last_off_avg) {
                    (Some(on), Some(off)) => on <= off * (1.0 + thr),
                    (Some(_), None) => false, // try off once
                    (None, Some(_)) => true,  // try on once
                    (None, None) => self.global_enabled,
                };
                // Strong per-set evidence from the leading sets overrides:
                // they see the *locality* benefit directly (§III-D5).
                if let (Some(a), Some(n)) = (self.lead_always.avg(), self.lead_never.avg())
                {
                    if a < n * (1.0 - thr) {
                        next = true;
                    } else if n < a * (1.0 - thr) {
                        next = false;
                    }
                }
                // Periodic re-exploration of the losing setting so phase
                // changes are detected.
                self.epochs_since_flip += 1;
                if next == self.global_enabled && self.epochs_since_flip >= 24 {
                    next = !next;
                    // Forget the stale sample so the refreshed measurement
                    // (after its transient) decides.
                    if next {
                        self.last_on_avg = None;
                    } else {
                        self.last_off_avg = None;
                    }
                }
                if next != self.global_enabled {
                    self.epochs_since_flip = 0;
                }
                self.transient = next != setting;
                self.apply_global(next, at);
            }
        }

        let decision = EpochDecision {
            epoch: self.epoch_index,
            at,
            enabled: self.global_enabled,
            vaults_enabled: self.vault_enabled.iter().filter(|&&e| e).count() as u32,
            avg_latency: avg,
        };
        self.decisions.push(decision);

        // Epoch registers restart (§III-D1).
        for f in &mut self.feedback {
            f.clear();
        }
        for r in &mut self.vault_latency {
            r.clear();
        }
        self.lead_always.clear();
        self.lead_never.clear();
        decision
    }

    fn apply_global(&mut self, next: bool, at: Cycle) {
        if next != self.global_enabled {
            // Observability only: a one-way atomic count of actual
            // enable/disable transitions (the adaptive policies call
            // apply_global every epoch, changed or not). Nothing flows
            // back into the runtime, the decision log or the report.
            crate::obs::POLICY_FLIPS.inc();
        }
        self.prev_global_enabled = self.global_at(at);
        self.global_enabled = next;
        // Central-vault computation + broadcast (§III-D4).
        self.global_effective_at = at + self.broadcast_lat;
    }

    /// Number of epochs completed so far.
    pub fn epochs(&self) -> u64 {
        self.epoch_index
    }
}

impl SimConfig {
    /// Internal helper: does the configured policy use leading sets?
    pub(crate) fn kind_uses_sampling(&self) -> bool {
        self.policy == PolicyKind::Adaptive
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(kind: PolicyKind) -> SimConfig {
        let mut c = SimConfig::hmc();
        c.policy = kind;
        c.epoch_cycles = 1000;
        c
    }

    #[test]
    fn never_and_always_are_constant() {
        let never = PolicyRuntime::new(&cfg(PolicyKind::Never));
        let always = PolicyRuntime::new(&cfg(PolicyKind::Always));
        for set in [0u32, 1, 7, 2047] {
            assert!(!never.enabled(0, set, 0));
            assert!(always.enabled(0, set, 0));
        }
    }

    #[test]
    fn hops_policy_disables_negative_vault() {
        let mut p = PolicyRuntime::new(&cfg(PolicyKind::AdaptiveHops));
        assert!(p.enabled(3, 0, 0), "first epoch all-on");
        // Vault 3 sees pure cost this epoch.
        for _ in 0..10 {
            p.on_request(3, 5, true, 10, 2, 100, 0, 0);
        }
        p.tick(1000);
        assert!(!p.enabled(3, 0, 1001));
        assert!(p.enabled(2, 0, 1001), "other vaults unaffected");
    }

    #[test]
    fn subscription_away_charges_holder_vault() {
        let mut p = PolicyRuntime::new(&cfg(PolicyKind::AdaptiveHops));
        // Requester 1 pays extra hops; holder 9 must also be charged.
        for _ in 0..5 {
            p.on_request(1, 9, true, 12, 2, 100, 0, 0);
        }
        p.tick(1000);
        assert!(!p.enabled(1, 0, 1001));
        assert!(!p.enabled(9, 0, 1001));
    }

    #[test]
    fn latency_policy_reverses_on_regression() {
        let mut p = PolicyRuntime::new(&cfg(PolicyKind::AdaptiveLatency));
        // Epoch 1: avg 100 (first epoch decided by feedback sign = on).
        for _ in 0..10 {
            p.on_request(0, 0, false, 0, 0, 100, 0, 0);
        }
        p.tick(1000);
        assert!(p.enabled(0, 0, 3000));
        // Epoch 2: avg 100 -> within threshold, keep.
        for _ in 0..10 {
            p.on_request(0, 0, false, 0, 0, 100, 0, 1500);
        }
        p.tick(2000);
        assert!(p.enabled(0, 0, 4000));
        // Epoch 3: avg 200 -> regression beyond 2%, reverse to off.
        for _ in 0..10 {
            p.on_request(0, 0, false, 0, 0, 200, 0, 2500);
        }
        p.tick(3000);
        assert!(!p.enabled(0, 0, 5000));
    }

    #[test]
    fn broadcast_latency_delays_effect() {
        let mut p = PolicyRuntime::new(&cfg(PolicyKind::AdaptiveLatency));
        for _ in 0..10 {
            p.on_request(0, 0, false, 0, 0, 100, 0, 0);
        }
        p.tick(1000);
        for _ in 0..10 {
            p.on_request(0, 0, false, 0, 0, 500, 0, 1500);
        }
        p.tick(2000); // decision: off, effective at 3000
        assert!(p.enabled(0, 0, 2500), "old policy until broadcast lands");
        assert!(!p.enabled(0, 0, 3000));
    }

    #[test]
    fn sampling_leaders_are_fixed() {
        let p = PolicyRuntime::new(&cfg(PolicyKind::Adaptive));
        // stride = 2048/32 = 64.
        assert_eq!(p.group(0), SetGroup::LeadAlways);
        assert_eq!(p.group(1), SetGroup::LeadNever);
        assert_eq!(p.group(2), SetGroup::Follower);
        assert_eq!(p.group(64), SetGroup::LeadAlways);
        assert_eq!(p.group(65), SetGroup::LeadNever);
        assert!(p.enabled(0, 0, 0));
        assert!(!p.enabled(0, 1, 0));
    }

    #[test]
    fn sampling_followers_adopt_cheaper_leader() {
        let mut p = PolicyRuntime::new(&cfg(PolicyKind::Adaptive));
        // Always-leader sets see low latency, never-leader sets high.
        for _ in 0..10 {
            p.on_request(0, 0, false, 0, 0, 50, 0, 0); // set 0: LeadAlways
            p.on_request(0, 0, false, 0, 0, 500, 1, 0); // set 1: LeadNever
        }
        p.tick(1000);
        assert!(p.enabled(0, 2, 3000), "followers go always");
        // Next epoch the tables turn.
        for _ in 0..10 {
            p.on_request(0, 0, false, 0, 0, 900, 0, 1500);
            p.on_request(0, 0, false, 0, 0, 90, 1, 1500);
        }
        p.tick(2000);
        assert!(!p.enabled(0, 2, 4000), "followers go never");
        // Leaders never move.
        assert!(p.enabled(0, 0, 4000));
        assert!(!p.enabled(0, 1, 4000));
    }

    #[test]
    fn tick_crosses_multiple_epochs() {
        let mut p = PolicyRuntime::new(&cfg(PolicyKind::Adaptive));
        let ds = p.tick(3500);
        assert_eq!(ds.len(), 3);
        assert_eq!(p.epochs(), 3);
    }

    #[test]
    fn decisions_are_logged() {
        let mut p = PolicyRuntime::new(&cfg(PolicyKind::AdaptiveHops));
        p.tick(1000);
        p.tick(2000);
        assert_eq!(p.decisions.len(), 2);
        assert_eq!(p.decisions[0].epoch, 1);
        assert_eq!(p.decisions[1].at, 2000);
    }
}

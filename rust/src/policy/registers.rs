//! The hardware registers of Fig 7: per-vault feedback registers (hops
//! cost/benefit) and latency/request accumulators, plus the central vault's
//! previous-epoch latency register.

/// Hops-based feedback register (§III-D2). Saturating signed counter:
/// positive = subscriptions shortened paths this epoch.
#[derive(Clone, Copy, Debug, Default)]
pub struct FeedbackRegister {
    value: i64,
}

impl FeedbackRegister {
    /// A subscribed request travelled fewer hops than its unsubscribed
    /// estimate.
    pub fn benefit(&mut self) {
        self.value = self.value.saturating_add(1);
    }

    /// A subscribed request travelled more hops (charged to the requester
    /// *and* to the subscribed vault — the "subscription away" fix,
    /// §III-D4).
    pub fn cost(&mut self) {
        self.value = self.value.saturating_sub(1);
    }

    pub fn value(&self) -> i64 {
        self.value
    }

    pub fn is_positive(&self) -> bool {
        self.value >= 0
    }

    pub fn clear(&mut self) {
        self.value = 0;
    }
}

/// Latency + request-count accumulators for one vault or one leading-set
/// group (§III-D3).
#[derive(Clone, Copy, Debug, Default)]
pub struct LatencyRegisters {
    pub latency_sum: u64,
    pub requests: u64,
}

impl LatencyRegisters {
    pub fn record(&mut self, latency: u64) {
        self.latency_sum += latency;
        self.requests += 1;
    }

    /// Average latency per request this epoch; `None` with no requests.
    pub fn avg(&self) -> Option<f64> {
        if self.requests == 0 {
            None
        } else {
            Some(self.latency_sum as f64 / self.requests as f64)
        }
    }

    pub fn clear(&mut self) {
        *self = LatencyRegisters::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn feedback_counts_signed() {
        let mut f = FeedbackRegister::default();
        f.benefit();
        f.benefit();
        f.cost();
        assert_eq!(f.value(), 1);
        assert!(f.is_positive());
        f.cost();
        f.cost();
        assert_eq!(f.value(), -1);
        assert!(!f.is_positive());
    }

    #[test]
    fn latency_avg() {
        let mut r = LatencyRegisters::default();
        assert!(r.avg().is_none());
        r.record(10);
        r.record(30);
        assert_eq!(r.avg(), Some(20.0));
        r.clear();
        assert!(r.avg().is_none());
    }
}

//! Subscription policies (§III-D): the binary always/never configurations
//! and the adaptive mechanisms that turn subscription on or off at epoch
//! granularity based on measured cost/benefit.

pub mod registers;
pub mod runtime;

pub use registers::{FeedbackRegister, LatencyRegisters};
pub use runtime::{EpochDecision, PolicyRuntime, SetGroup};

/// Which subscription policy a run uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    /// Baseline: no subscriptions ever (the speedup denominator).
    Never,
    /// Subscribe on first access, unconditionally (Fig 9).
    Always,
    /// Hops-based adaptive (§III-D2): per-vault feedback registers compare
    /// actual vs estimated-unsubscribed hop counts.
    AdaptiveHops,
    /// Latency-based adaptive (§III-D3): global epoch-over-epoch average
    /// latency comparison with a 2% threshold, decided at the central vault.
    AdaptiveLatency,
    /// The paper's headline *adaptive* policy: latency-based global decision
    /// with leading-set dynamic set sampling (§III-D5) to escape the
    /// always-unsubscription problem.
    Adaptive,
}

impl PolicyKind {
    pub fn as_str(self) -> &'static str {
        match self {
            PolicyKind::Never => "never",
            PolicyKind::Always => "always",
            PolicyKind::AdaptiveHops => "adaptive-hops",
            PolicyKind::AdaptiveLatency => "adaptive-latency",
            PolicyKind::Adaptive => "adaptive",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "never" | "baseline" => Some(PolicyKind::Never),
            "always" | "always-subscribe" => Some(PolicyKind::Always),
            "adaptive-hops" | "hops" => Some(PolicyKind::AdaptiveHops),
            "adaptive-latency" | "latency" => Some(PolicyKind::AdaptiveLatency),
            "adaptive" => Some(PolicyKind::Adaptive),
            _ => None,
        }
    }

    /// Does this policy ever subscribe?
    pub fn can_subscribe(self) -> bool {
        self != PolicyKind::Never
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for k in [
            PolicyKind::Never,
            PolicyKind::Always,
            PolicyKind::AdaptiveHops,
            PolicyKind::AdaptiveLatency,
            PolicyKind::Adaptive,
        ] {
            assert_eq!(PolicyKind::parse(k.as_str()), Some(k));
        }
    }

    #[test]
    fn parse_aliases() {
        assert_eq!(PolicyKind::parse("baseline"), Some(PolicyKind::Never));
        assert_eq!(PolicyKind::parse("always-subscribe"), Some(PolicyKind::Always));
        assert_eq!(PolicyKind::parse("nope"), None);
    }
}

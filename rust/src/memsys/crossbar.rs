//! HBM's pseudo-channel switch as an [`Interconnect`]: a non-blocking
//! crossbar with one ingress and one egress port per channel and a uniform
//! one-hop switch latency (§V's HBM model).
//!
//! Cost model: every remote pair is one hop apart, so an uncontended
//! k-FLIT packet costs exactly `k` cycles — the switch is cut-through, the
//! FLIT stream occupies the source's egress port and the destination's
//! ingress port in overlapping windows. Contention is what distinguishes
//! channels: a hot channel's ingress port serializes every packet headed
//! for it, which is the crossbar's analogue of the mesh's congested links
//! around a hot vault.

use crate::config::SimConfig;
use crate::memsys::Interconnect;
use crate::sim::network::LinkCal;
use crate::sim::Transfer;
use crate::{Cycle, VaultId};

/// Per-channel-port crossbar.
pub struct CrossbarInterconnect {
    n: u16,
    /// One egress (channel -> switch) port calendar per channel.
    egress: Vec<LinkCal>,
    /// One ingress (switch -> channel) port calendar per channel.
    ingress: Vec<LinkCal>,
}

impl CrossbarInterconnect {
    pub fn new(cfg: &SimConfig) -> Self {
        assert!(
            cfg.n_vaults.is_power_of_two(),
            "crossbar needs a power-of-two vault count (cfg.validate enforces this)"
        );
        CrossbarInterconnect {
            n: cfg.n_vaults,
            egress: vec![LinkCal::default(); cfg.n_vaults as usize],
            ingress: vec![LinkCal::default(); cfg.n_vaults as usize],
        }
    }
}

impl Interconnect for CrossbarInterconnect {
    fn name(&self) -> &'static str {
        "crossbar"
    }

    fn n_vaults(&self) -> u16 {
        self.n
    }

    #[inline]
    fn hops(&self, a: VaultId, b: VaultId) -> u32 {
        u32::from(a != b)
    }

    fn transfer(
        &mut self,
        from: VaultId,
        to: VaultId,
        flits: u32,
        depart: Cycle,
    ) -> Transfer {
        if from == to {
            return Transfer { arrive: depart, ..Transfer::default() };
        }
        let f = flits as u64;
        // Egress first (head-of-line at the source port), then the
        // destination's ingress port from the cycle the stream enters the
        // switch; the two occupancies overlap (cut-through), so one hop
        // serializes the packet exactly once.
        let e_start = self.egress[from as usize].reserve(depart, f);
        let i_start = self.ingress[to as usize].reserve(e_start, f);
        Transfer {
            arrive: i_start + f,
            network: f,
            queued: i_start - depart,
            hops: 1,
        }
    }

    fn central_vault(&self) -> VaultId {
        // Every channel is equidistant from every other; channel 0 hosts
        // the policy's decision logic by convention.
        0
    }

    fn reset(&mut self) {
        for p in self.egress.iter_mut().chain(self.ingress.iter_mut()) {
            p.clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xbar() -> CrossbarInterconnect {
        CrossbarInterconnect::new(&SimConfig::hbm())
    }

    #[test]
    fn uniform_one_hop() {
        let net = xbar();
        for a in 0..8u16 {
            for b in 0..8u16 {
                assert_eq!(net.hops(a, b), u32::from(a != b));
            }
        }
    }

    #[test]
    fn uncontended_transfer_costs_flits_cycles() {
        let mut net = xbar();
        let tr = net.transfer(0, 7, 5, 100);
        assert_eq!(tr, Transfer { arrive: 105, network: 5, queued: 0, hops: 1 });
    }

    #[test]
    fn hot_ingress_port_serializes() {
        let mut net = xbar();
        // Three channels fire at channel 0's ingress port at once.
        let a = net.transfer(1, 0, 5, 0);
        let b = net.transfer(2, 0, 5, 0);
        let c = net.transfer(3, 0, 5, 0);
        assert_eq!(a.queued, 0);
        assert_eq!(b.queued, 5);
        assert_eq!(c.queued, 10);
        assert_eq!(c.arrive, 15);
    }

    #[test]
    fn distinct_pairs_do_not_contend() {
        let mut net = xbar();
        let a = net.transfer(0, 1, 5, 0);
        let b = net.transfer(2, 3, 5, 0);
        assert_eq!(a.queued, 0);
        assert_eq!(b.queued, 0);
    }

    #[test]
    fn egress_port_is_also_contended() {
        let mut net = xbar();
        let a = net.transfer(0, 1, 5, 0);
        let b = net.transfer(0, 2, 5, 0); // same source, different sink
        assert_eq!(a.queued, 0);
        assert_eq!(b.queued, 5, "one egress port per channel");
    }

    #[test]
    fn self_transfer_is_free() {
        let mut net = xbar();
        let tr = net.transfer(4, 4, 9, 77);
        assert_eq!(tr, Transfer { arrive: 77, network: 0, queued: 0, hops: 0 });
    }
}

//! HMC's 2-D vault mesh as an [`Interconnect`]: XY (dimension-ordered)
//! routing over directed links with FLIT serialization and contention —
//! the same cost model as [`crate::sim::Mesh`], with one §Perf change:
//! every (source, destination) pair's route (the exact sequence of
//! directed-link indices the XY walk visits) and hop count are precomputed
//! in [`MeshInterconnect::new`], so the transfer hot path walks a slice
//! instead of re-deriving coordinates and directions per hop. Timing is
//! bit-identical to the legacy walk (asserted by tests below); only the
//! instruction count shrinks.

use crate::config::SimConfig;
use crate::memsys::interconnect::{Interconnect, walk_route};
use crate::sim::network::{DIR_E, DIR_N, DIR_S, DIR_W, LinkCal, place_vaults};
use crate::sim::Transfer;
use crate::{Cycle, VaultId};

/// The mesh topology with precomputed per-pair routes.
pub struct MeshInterconnect {
    n: u16,
    central: VaultId,
    /// `hops[a * n + b]` — Manhattan distance between vaults `a` and `b`.
    hop_table: Vec<u32>,
    /// `routes[a * n + b]` — directed-link indices (`node * 4 + dir`) the
    /// XY walk from `a` to `b` reserves, in order.
    routes: Vec<Vec<u32>>,
    /// Busy calendar per directed link, indexed `node * 4 + dir`.
    links: Vec<LinkCal>,
}

impl MeshInterconnect {
    pub fn new(cfg: &SimConfig) -> Self {
        let (w, h) = (cfg.net_w, cfg.net_h);
        let nodes = w as usize * h as usize;
        let vault_node = place_vaults(w, h, cfg.n_vaults);
        assert_eq!(vault_node.len(), cfg.n_vaults as usize);
        let xy = |node: u16| -> (u16, u16) { (node % w, node / w) };

        let n = cfg.n_vaults as usize;
        let mut hop_table = vec![0u32; n * n];
        let mut routes = vec![Vec::new(); n * n];
        for a in 0..n {
            for b in 0..n {
                if a == b {
                    continue;
                }
                let dst = vault_node[b];
                let (dx, dy) = xy(dst);
                let mut cur = vault_node[a];
                let route = &mut routes[a * n + b];
                while cur != dst {
                    let (cx, cy) = xy(cur);
                    let (dir, next) = if cx != dx {
                        if cx < dx {
                            (DIR_E, cur + 1)
                        } else {
                            (DIR_W, cur - 1)
                        }
                    } else if cy < dy {
                        (DIR_S, cur + w)
                    } else {
                        (DIR_N, cur - w)
                    };
                    route.push(cur as u32 * 4 + dir as u32);
                    cur = next;
                }
                hop_table[a * n + b] = route.len() as u32;
            }
        }

        // The vault nearest the geometric mesh center (§III-D4), computed
        // exactly as the legacy `sim::Mesh` did.
        let cx = (w - 1) as f64 / 2.0;
        let cy = (h - 1) as f64 / 2.0;
        let mut central = 0u16;
        let mut best_d = f64::MAX;
        for (v, &node) in vault_node.iter().enumerate() {
            let (x, y) = xy(node);
            let d = (x as f64 - cx).abs() + (y as f64 - cy).abs();
            if d < best_d {
                best_d = d;
                central = v as u16;
            }
        }

        MeshInterconnect {
            n: cfg.n_vaults,
            central,
            hop_table,
            routes,
            links: vec![LinkCal::default(); nodes * 4],
        }
    }
}

impl Interconnect for MeshInterconnect {
    fn name(&self) -> &'static str {
        "mesh"
    }

    fn n_vaults(&self) -> u16 {
        self.n
    }

    #[inline]
    fn hops(&self, a: VaultId, b: VaultId) -> u32 {
        self.hop_table[a as usize * self.n as usize + b as usize]
    }

    fn transfer(
        &mut self,
        from: VaultId,
        to: VaultId,
        flits: u32,
        depart: Cycle,
    ) -> Transfer {
        walk_route(
            &mut self.links,
            &self.routes[from as usize * self.n as usize + to as usize],
            flits,
            depart,
        )
    }

    fn central_vault(&self) -> VaultId {
        self.central
    }

    fn reset(&mut self) {
        for l in &mut self.links {
            l.clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Mesh;

    #[test]
    fn hops_match_legacy_mesh() {
        let cfg = SimConfig::hmc();
        let net = MeshInterconnect::new(&cfg);
        let legacy = Mesh::new(&cfg);
        for a in 0..cfg.n_vaults {
            for b in 0..cfg.n_vaults {
                assert_eq!(net.hops(a, b), legacy.hops(a, b), "({a},{b})");
            }
        }
        assert_eq!(net.central_vault(), legacy.central_vault());
    }

    #[test]
    fn transfers_bit_identical_to_legacy_mesh() {
        // Replay a deterministic pseudo-random transfer history through
        // both implementations: every Transfer must agree exactly — this
        // is what keeps HMC figure artifacts bit-identical across the
        // facade refactor.
        let cfg = SimConfig::hmc();
        let mut net = MeshInterconnect::new(&cfg);
        let mut legacy = Mesh::new(&cfg);
        let mut x = 0x5eed_1234_u64;
        let mut t = 0u64;
        for _ in 0..5000 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let a = ((x >> 33) % 32) as u16;
            let b = ((x >> 13) % 32) as u16;
            let flits = ((x >> 53) % 9 + 1) as u32;
            t += x % 40;
            assert_eq!(
                net.transfer(a, b, flits, t),
                legacy.transfer(a, b, flits, t),
                "history diverged at t={t} ({a}->{b}, {flits} flits)"
            );
        }
    }

    #[test]
    fn self_transfer_is_free() {
        let mut net = MeshInterconnect::new(&SimConfig::hmc());
        let tr = net.transfer(7, 7, 5, 42);
        assert_eq!(tr, Transfer { arrive: 42, network: 0, queued: 0, hops: 0 });
    }

    #[test]
    fn reset_clears_reservations() {
        let mut net = MeshInterconnect::new(&SimConfig::hmc());
        net.transfer(0, 31, 9, 0);
        net.reset();
        assert_eq!(net.transfer(0, 31, 9, 0).queued, 0);
    }
}

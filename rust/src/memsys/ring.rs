//! A bidirectional ring as an [`Interconnect`] — the sensitivity-study
//! topology: cheaper to lay out than a mesh (2 links per vault instead of
//! 4) but with an average distance that grows linearly in the vault count,
//! so it brackets the mesh from below on wiring cost and from above on
//! hop count.
//!
//! Routing is shortest-direction (ties go clockwise, deterministically);
//! per-pair routes are precomputed at construction like the mesh's.

use crate::config::SimConfig;
use crate::memsys::interconnect::{Interconnect, walk_route};
use crate::sim::network::LinkCal;
use crate::sim::Transfer;
use crate::{Cycle, VaultId};

const DIR_CW: usize = 0;
const DIR_CCW: usize = 1;

/// Bidirectional ring with precomputed shortest-direction routes.
pub struct RingInterconnect {
    n: u16,
    /// `hops[a * n + b]` — ring distance (shorter arc).
    hop_table: Vec<u32>,
    /// `routes[a * n + b]` — directed-link indices (`node * 2 + dir`).
    routes: Vec<Vec<u32>>,
    links: Vec<LinkCal>,
}

impl RingInterconnect {
    pub fn new(cfg: &SimConfig) -> Self {
        let n = cfg.n_vaults as usize;
        assert!(n >= 2, "ring needs at least 2 vaults (cfg.validate enforces this)");
        let mut hop_table = vec![0u32; n * n];
        let mut routes = vec![Vec::new(); n * n];
        for a in 0..n {
            for b in 0..n {
                if a == b {
                    continue;
                }
                let d_cw = (b + n - a) % n;
                let d_ccw = (a + n - b) % n;
                let route = &mut routes[a * n + b];
                if d_cw <= d_ccw {
                    let mut cur = a;
                    for _ in 0..d_cw {
                        route.push(cur as u32 * 2 + DIR_CW as u32);
                        cur = (cur + 1) % n;
                    }
                } else {
                    let mut cur = a;
                    for _ in 0..d_ccw {
                        route.push(cur as u32 * 2 + DIR_CCW as u32);
                        cur = (cur + n - 1) % n;
                    }
                }
                hop_table[a * n + b] = d_cw.min(d_ccw) as u32;
            }
        }
        RingInterconnect {
            n: cfg.n_vaults,
            hop_table,
            routes,
            links: vec![LinkCal::default(); n * 2],
        }
    }
}

impl Interconnect for RingInterconnect {
    fn name(&self) -> &'static str {
        "ring"
    }

    fn n_vaults(&self) -> u16 {
        self.n
    }

    #[inline]
    fn hops(&self, a: VaultId, b: VaultId) -> u32 {
        self.hop_table[a as usize * self.n as usize + b as usize]
    }

    fn transfer(
        &mut self,
        from: VaultId,
        to: VaultId,
        flits: u32,
        depart: Cycle,
    ) -> Transfer {
        walk_route(
            &mut self.links,
            &self.routes[from as usize * self.n as usize + to as usize],
            flits,
            depart,
        )
    }

    fn central_vault(&self) -> VaultId {
        // A ring is vertex-transitive: every vault is a center. Vault 0
        // hosts the policy's decision logic by convention.
        0
    }

    fn reset(&mut self) {
        for l in &mut self.links {
            l.clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ring() -> RingInterconnect {
        RingInterconnect::new(&SimConfig::hmc()) // 32 vaults
    }

    #[test]
    fn hops_take_the_shorter_arc() {
        let net = ring();
        assert_eq!(net.hops(0, 1), 1);
        assert_eq!(net.hops(0, 31), 1, "wraps around");
        assert_eq!(net.hops(0, 16), 16, "antipode");
        assert_eq!(net.hops(3, 10), 7);
    }

    #[test]
    fn hops_symmetric_and_zero_on_self() {
        let net = ring();
        for a in 0..32u16 {
            for b in 0..32u16 {
                assert_eq!(net.hops(a, b), net.hops(b, a));
            }
            assert_eq!(net.hops(a, a), 0);
        }
    }

    #[test]
    fn uncontended_transfer_costs_flits_times_hops() {
        let mut net = ring();
        let h = net.hops(0, 5);
        let tr = net.transfer(0, 5, 5, 100);
        assert_eq!(tr.hops, h);
        assert_eq!(tr.network, 5 * h as u64);
        assert_eq!(tr.queued, 0);
        assert_eq!(tr.arrive, 100 + 5 * h as u64);
    }

    #[test]
    fn opposite_directions_do_not_contend() {
        let mut net = ring();
        let a = net.transfer(0, 4, 5, 0); // clockwise over links 0..4
        let b = net.transfer(4, 0, 5, 0); // counter-clockwise back
        assert_eq!(a.queued, 0);
        assert_eq!(b.queued, 0);
    }

    #[test]
    fn shared_direction_contends() {
        let mut net = ring();
        let a = net.transfer(0, 4, 5, 0);
        let b = net.transfer(0, 4, 5, 0);
        assert_eq!(a.queued, 0);
        assert_eq!(b.queued, 5, "same first link, same direction");
    }

    #[test]
    fn two_vault_ring_works() {
        let mut cfg = SimConfig::hmc();
        cfg.n_vaults = 2;
        let mut net = RingInterconnect::new(&cfg);
        assert_eq!(net.hops(0, 1), 1);
        let tr = net.transfer(1, 0, 3, 10);
        assert_eq!(tr.arrive, 13);
    }
}

//! The [`Interconnect`] abstraction: every topology the memory system can
//! route over, behind one trait.
//!
//! Implementations precompute their per-pair hop counts and routes at
//! construction ([`MeshInterconnect`](super::MeshInterconnect) and
//! [`RingInterconnect`](super::RingInterconnect) store explicit link-index
//! routes; [`CrossbarInterconnect`](super::CrossbarInterconnect) is
//! uniformly one hop), so the transfer hot path never recomputes routing —
//! it walks a precomputed slice and reserves link calendars.

use crate::config::{SimConfig, Topology};
use crate::sim::network::LinkCal;
use crate::sim::Transfer;
use crate::{Cycle, VaultId};

/// One inter-vault network topology.
///
/// The contract every implementation upholds (checked by the
/// `interconnect_props` property tests):
/// * `hops(a, b) == hops(b, a)` and `hops(a, a) == 0`;
/// * a self-transfer is free: `transfer(a, a, f, t)` arrives at `t` with
///   zero hops, network and queueing;
/// * `transfer(..).arrive >= depart`, and the decomposition is exact:
///   `arrive == depart + network + queued`;
/// * uncontended transfers cost `flits * hops(a, b)` cycles (the paper's
///   §III-C cost model).
///
/// `Send + Sync` because the event kernel fills the hop LUT by sharing
/// `&dyn Interconnect` across its partition threads (a pure read of the
/// precomputed hop tables); every implementation is plain owned data, so
/// both bounds auto-derive.
pub trait Interconnect: Send + Sync {
    /// Short name for reports ("mesh" | "crossbar" | "ring").
    fn name(&self) -> &'static str;

    /// Number of vaults/channels attached to this network.
    fn n_vaults(&self) -> u16;

    /// Topological distance between two vaults (the paper's `h` terms).
    fn hops(&self, a: VaultId, b: VaultId) -> u32;

    /// Send a `flits`-sized packet from `from` to `to`, departing no
    /// earlier than `depart`; reserves every contended resource on the
    /// path and returns the timing decomposition.
    fn transfer(&mut self, from: VaultId, to: VaultId, flits: u32, depart: Cycle)
        -> Transfer;

    /// The vault hosting the global adaptive policy's decision logic
    /// (§III-D4) — the topological center of the network.
    fn central_vault(&self) -> VaultId;

    /// Clear all link/port reservations (between runs).
    fn reset(&mut self);
}

/// Walk a precomputed route, reserving each directed link/port calendar in
/// order — the shared transfer hot path of the route-table topologies
/// (mesh, ring, and any future one). An empty route (self-transfer) yields
/// a free, instantaneous [`Transfer`], so implementations need no separate
/// same-vault guard.
pub(crate) fn walk_route(
    links: &mut [LinkCal],
    route: &[u32],
    flits: u32,
    depart: Cycle,
) -> Transfer {
    let f = flits as u64;
    let mut t = depart;
    let mut queued = 0u64;
    for &link in route {
        let start = links[link as usize].reserve(t, f);
        queued += start - t;
        t = start + f;
    }
    let hops = route.len() as u32;
    Transfer { arrive: t, network: f * hops as u64, queued, hops }
}

/// Build the interconnect selected by `cfg.topology`.
pub fn build_interconnect(cfg: &SimConfig) -> Box<dyn Interconnect> {
    match cfg.topology {
        Topology::Mesh => Box::new(super::MeshInterconnect::new(cfg)),
        Topology::Crossbar => Box::new(super::CrossbarInterconnect::new(cfg)),
        Topology::Ring => Box::new(super::RingInterconnect::new(cfg)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_matches_config_topology() {
        for (t, name) in [
            (Topology::Mesh, "mesh"),
            (Topology::Crossbar, "crossbar"),
            (Topology::Ring, "ring"),
        ] {
            let mut cfg = SimConfig::hmc();
            cfg.topology = t;
            let net = build_interconnect(&cfg);
            assert_eq!(net.name(), name);
            assert_eq!(net.n_vaults(), cfg.n_vaults);
        }
    }

    #[test]
    fn all_topologies_honor_the_paper_cost_model_uncontended() {
        // (k+1) * h_ro: 1-FLIT request one way, k-FLIT response back.
        for t in [Topology::Mesh, Topology::Crossbar, Topology::Ring] {
            let mut cfg = SimConfig::hmc();
            cfg.topology = t;
            let mut net = build_interconnect(&cfg);
            let (r, o) = (0u16, 31u16);
            let h = net.hops(r, o) as u64;
            let req = net.transfer(r, o, 1, 0);
            let resp = net.transfer(o, r, 5, req.arrive);
            assert_eq!(resp.arrive, (5 + 1) * h, "{t:?}");
        }
    }
}

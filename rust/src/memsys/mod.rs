//! The memory-system facade: one object that owns everything a memory
//! request touches — the interconnect, the per-vault DRAM, the distributed
//! subscription directory and the run statistics — behind a narrow
//! `serve(Access, now) -> ServedRequest` API.
//!
//! ## Why a facade
//!
//! Before this layer existed, every protocol handler threaded
//! `&mut Mesh, &mut Vec<VaultMem>, &mut SimStats` through its signature and
//! the driver pattern-matched four nearly identical request paths. The
//! facade collapses that: the driver (and any test or bench) issues
//! [`Access`]es and reads [`ServedRequest`] decompositions; *which*
//! interconnect carries the packets is a [`SimConfig::topology`] decision
//! made once in [`MemorySystem::new`].
//!
//! ## Layout
//!
//! * [`interconnect`] — the [`Interconnect`] trait and
//!   [`build_interconnect`], the topology selector;
//! * [`mesh`] / [`crossbar`] / [`ring`] — the three implementations
//!   (HMC's vault mesh, HBM's pseudo-channel switch, and the ring used by
//!   sensitivity studies), each with per-pair hop/route tables precomputed
//!   at construction;
//! * the protocol handlers themselves live in
//!   [`crate::subscription`]'s `serve` / `forward` / `subscribe` / `evict`
//!   submodules as `impl MemorySystem` blocks — they are the only code
//!   that reaches through the facade's crate-private fields.
//!
//! ## The serve hot path
//!
//! [`MemorySystem::serve`] is the innermost per-request operation of every
//! simulation (directory lookup → route → link/bank reservation → stats),
//! so its state is laid out data-oriented — flat arrays indexed by vault
//! and by `vault × bank`, not vectors of per-vault objects:
//!
//! * vault DRAM tails live in one [`crate::sim::VaultArray`]
//!   (struct-of-arrays; see its docs for the exact layout);
//! * pairwise hop counts are flattened into an `n × n` lookup table at
//!   construction ([`MemorySystem::prepare`] reads it instead of making a
//!   virtual [`Interconnect::hops`] call);
//! * the subscription directory keeps a dense tag array beside its entry
//!   structs ([`crate::subscription::table::SubTable`]), so a lookup scans
//!   8 contiguous words per set and touches an
//!   [`Entry`](crate::subscription::table::Entry) only on a match.
//!
//! `serve` itself splits into a pure [`MemorySystem::prepare`] (address →
//! home vault, set, baseline hops) and the stateful
//! `serve_prepared`, which lets the batched driver
//! ([`crate::coordinator::driver`]) resolve a whole admission window of
//! addresses before running the stateful pass. Every layout change here is
//! value-preserving by construction: `tests/batched_equivalence.rs` and
//! `tests/golden_artifacts.rs` pin the equivalence. The request lifecycle
//! end-to-end is diagrammed in `rust/docs/ARCHITECTURE.md`.
//!
//! ## Adding a fourth topology
//!
//! 1. Create `memsys/<name>.rs` implementing [`Interconnect`]; model each
//!    contended port or link with a [`crate::sim::network::LinkCal`] and
//!    precompute hop/route tables in `new` (the transfer path should only
//!    walk slices and reserve calendars).
//! 2. Add a variant to [`crate::config::Topology`] (`as_str` + `parse`),
//!    wire it into [`build_interconnect`], and teach
//!    `SimConfig::validate` its structural constraints.
//! 3. Extend the `interconnect_props` property tests' topology list — hop
//!    symmetry, free self-transfer, no-early-completion and determinism
//!    come for free.

pub mod crossbar;
pub mod interconnect;
pub mod mesh;
pub mod ring;

pub use crossbar::CrossbarInterconnect;
pub use interconnect::{build_interconnect, Interconnect};
pub use mesh::MeshInterconnect;
pub use ring::RingInterconnect;

pub use crate::subscription::protocol::Access;

use crate::config::SimConfig;
use crate::policy::EpochDecision;
use crate::sim::{PacketKind, Transfer, VaultArray};
use crate::stats::SimStats;
use crate::subscription::protocol::SubSystem;
use crate::{Cycle, VaultId};

/// Timing/result decomposition of one served demand access.
///
/// Invariant: `queued_net <= queued` — the network share is a *subset* of
/// the total queue wait, never an independent counter. Every protocol
/// handler that accumulates a link wait into `queued_net` must add the
/// same cycles to `queued`; [`ServedRequest::queued_mem`] enforces the
/// invariant in debug builds and splits saturating in release, so a
/// protocol bug degrades one stats line instead of panicking mid-figure.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServedRequest {
    /// Completion cycle.
    pub done: Cycle,
    /// Pure transfer cycles (FLIT serialization x hops).
    pub network: u64,
    /// Waits: busy links, controller port, busy banks, pending states.
    pub queued: u64,
    /// Portion of `queued` spent waiting on busy interconnect links/ports
    /// (see the struct invariant: always `<= queued`).
    pub queued_net: u64,
    /// DRAM array cycles.
    pub array: u64,
    /// Vault whose memory served the data.
    pub served_by: VaultId,
    /// True if no packet left the requester vault.
    pub local: bool,
    /// Hops actually traversed by all legs of this request.
    pub actual_hops: u32,
    /// One-way requester→home distance (the unsubscribed estimate).
    pub baseline_hops: u32,
    /// True if a subscription-table redirect or holder hit was involved.
    pub subscribed_path: bool,
    /// Subscription-table set of the accessed block.
    pub set: u32,
}

impl ServedRequest {
    /// Queue cycles spent at vault controllers / banks: the complement of
    /// `queued_net` within `queued`. Debug builds assert the struct
    /// invariant (`queued_net <= queued`); release builds saturate, so a
    /// violating request can skew one queue-split line but never panic or
    /// underflow mid-figure.
    pub fn queued_mem(&self) -> u64 {
        debug_assert!(
            self.queued_net <= self.queued,
            "ServedRequest invariant violated: queued_net {} > queued {}",
            self.queued_net,
            self.queued
        );
        self.queued.saturating_sub(self.queued_net)
    }
}

/// Pure, state-independent preparation of one demand access: everything
/// `serve` derives from the address alone, hoisted out so the batched
/// driver can compute it for a whole admission window before the stateful
/// pass runs. `serve(req, ..) ==
/// serve_prepared(req, .., prepare(req.requester, req.block))` by
/// construction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServePrep {
    /// The block's home vault (address-map interleave).
    pub home: VaultId,
    /// Subscription-table set of the block.
    pub set: u32,
    /// One-way requester→home hop count (the unsubscribed estimate).
    pub baseline_hops: u32,
}

/// The complete memory system of one simulation run.
///
/// Owns the interconnect, the vault DRAM state, the subscription directory
/// and the statistics; all demand traffic enters through
/// [`MemorySystem::serve`] (defined with the protocol handlers in
/// [`crate::subscription`]).
///
/// ## Data-oriented hot-path state
///
/// Two serve-path structures are struct-of-arrays rather than
/// vectors-of-objects (see `docs/ARCHITECTURE.md` for the full layout):
///
/// * `vaults` is a [`VaultArray`] — all controller-port and bank tails in
///   three flat arrays instead of a `Vec<VaultMem>` of per-vault heap
///   objects;
/// * `hop_lut` flattens the interconnect's pairwise hop counts into one
///   `n × n` array filled from [`Interconnect::hops`] at construction, so
///   the per-request baseline-hops read is an indexed load instead of a
///   virtual call.
///
/// Both hold exactly the state/values of the structures they replaced, so
/// every request decomposition is bit-identical.
pub struct MemorySystem {
    pub(crate) cfg: SimConfig,
    pub(crate) net: Box<dyn Interconnect>,
    pub(crate) vaults: VaultArray,
    pub(crate) subs: SubSystem,
    pub(crate) stats: SimStats,
    /// Pairwise hop counts, `a * n_vaults + b` (values from `net.hops`).
    hop_lut: Vec<u32>,
    /// Cached `cfg.n_vaults as usize` for `hop_lut` indexing.
    n: usize,
}

impl MemorySystem {
    pub fn new(cfg: &SimConfig) -> Self {
        Self::new_with_threads(cfg, 1)
    }

    /// [`MemorySystem::new`] with the n×n `hop_lut` fill partitioned over
    /// up to `threads` OS threads (the event kernel's construction path).
    /// Each source vault's row is an independent pure read of the
    /// interconnect's precomputed hop tables, so the filled LUT is
    /// identical at any thread count.
    pub fn new_with_threads(cfg: &SimConfig, threads: usize) -> Self {
        let net = build_interconnect(cfg);
        let n = cfg.n_vaults as usize;
        let mut hop_lut = vec![0u32; n * n];
        let threads = threads.clamp(1, n.max(1));
        if threads <= 1 {
            for a in 0..n {
                for b in 0..n {
                    hop_lut[a * n + b] = net.hops(a as VaultId, b as VaultId);
                }
            }
        } else {
            let rows_per = n.div_ceil(threads);
            let net_ref: &dyn Interconnect = net.as_ref();
            std::thread::scope(|scope| {
                for (chunk_i, chunk) in hop_lut.chunks_mut(rows_per * n).enumerate() {
                    scope.spawn(move || {
                        for (ra, row) in chunk.chunks_mut(n).enumerate() {
                            let a = (chunk_i * rows_per + ra) as VaultId;
                            for (b, h) in row.iter_mut().enumerate() {
                                *h = net_ref.hops(a, b as VaultId);
                            }
                        }
                    });
                }
            });
        }
        MemorySystem {
            net,
            vaults: VaultArray::new(cfg),
            subs: SubSystem::new(cfg),
            stats: SimStats::new(cfg.n_vaults),
            cfg: cfg.clone(),
            hop_lut,
            n,
        }
    }

    /// Resolve the address-dependent part of a demand access (home vault,
    /// table set, baseline hops). Pure: no interconnect, DRAM or directory
    /// state is read or written, so the batched driver may call this for
    /// many queued accesses in any order.
    #[inline]
    pub fn prepare(&self, requester: VaultId, block: u64) -> ServePrep {
        let home = self.subs.map.home_of_block(block);
        ServePrep {
            home,
            set: self.subs.map.set_of_block(block),
            baseline_hops: self.hop_lut[requester as usize * self.n + home as usize],
        }
    }

    /// The configuration this system was built from.
    pub fn config(&self) -> &SimConfig {
        &self.cfg
    }

    /// The interconnect carrying this system's packets.
    pub fn interconnect(&self) -> &dyn Interconnect {
        self.net.as_ref()
    }

    /// Vaults/channels attached to the system.
    pub fn n_vaults(&self) -> u16 {
        self.net.n_vaults()
    }

    /// Topological distance between two vaults on the active interconnect
    /// (indexed read of the LUT filled from [`Interconnect::hops`]).
    pub fn hops(&self, a: VaultId, b: VaultId) -> u32 {
        self.hop_lut[a as usize * self.n + b as usize]
    }

    /// The vault hosting the global policy's decision logic (§III-D4).
    pub fn central_vault(&self) -> VaultId {
        self.net.central_vault()
    }

    /// Run statistics accumulated since the last reset.
    pub fn stats(&self) -> &SimStats {
        &self.stats
    }

    /// Mutable statistics access (the driver resets them after warmup and
    /// counts L1 hits that never enter the memory system).
    pub fn stats_mut(&mut self) -> &mut SimStats {
        &mut self.stats
    }

    /// Consume the system, yielding its statistics (end of a run).
    pub fn into_stats(self) -> SimStats {
        self.stats
    }

    /// Read access to the subscription directory (tests, reports).
    pub fn directory(&self) -> &SubSystem {
        &self.subs
    }

    /// Check the distributed-directory invariant; see
    /// [`SubSystem::directory_consistent`].
    pub fn directory_consistent(&self, now: Cycle) -> Result<(), String> {
        self.subs.directory_consistent(now)
    }

    /// The race-tolerant variant the driver's debug boundary check uses;
    /// see [`SubSystem::directory_consistent_modeled`].
    pub fn directory_consistent_modeled(&self, now: Cycle) -> Result<(), String> {
        self.subs.directory_consistent_modeled(now)
    }

    /// Commit every pending directory transition completed by `now`.
    pub fn settle(&mut self, now: Cycle) {
        self.subs.settle(now);
    }

    /// Blocks currently parked in any vault's reserved space.
    pub fn total_parked(&self) -> u64 {
        self.subs.total_parked()
    }

    /// Age the subscription tables' LFU counters (epoch boundaries).
    pub fn decay_tables(&mut self) {
        self.subs.decay_all();
    }

    /// Clear all dynamic state (reservations, directory, stats) so the
    /// system can be reused for another run.
    pub fn reset(&mut self) {
        self.net.reset();
        self.vaults.reset();
        self.subs.reset();
        self.stats.reset();
    }

    /// Broadcast one epoch decision from the central vault (§III-D4): the
    /// per-vault stats reports travel in, the on/off packets travel out,
    /// all contending with demand traffic like any other packets; the
    /// tables' LFU counters age at the same boundary.
    pub fn broadcast_decision(&mut self, d: &EpochDecision) {
        self.broadcast_decision_partitioned(d, 1);
    }

    /// [`MemorySystem::broadcast_decision`] with the directory's LFU aging
    /// fanned out over up to `threads` OS threads in home-vault chunks
    /// (see [`SubSystem::decay_partitioned`]). The packet sends stay
    /// serial: they reserve shared link calendars in vault order, and that
    /// order is part of the pinned cost model. Bit-identical at any
    /// thread count.
    pub fn broadcast_decision_partitioned(&mut self, d: &EpochDecision, threads: usize) {
        self.subs.decay_partitioned(threads);
        let central = self.net.central_vault();
        let kind = if d.enabled {
            PacketKind::TurnOnSubscription
        } else {
            PacketKind::TurnOffSubscription
        };
        let flits = kind.flits(&self.cfg);
        for v in 0..self.net.n_vaults() {
            if v == central {
                continue;
            }
            self.send(PacketKind::StatsReport, 1, v, central, d.at);
            self.send(kind, flits, central, v, d.at);
        }
    }

    /// Ship one packet over the interconnect and record its traffic.
    pub(crate) fn send(
        &mut self,
        kind: PacketKind,
        flits: u32,
        from: VaultId,
        to: VaultId,
        at: Cycle,
    ) -> Transfer {
        let tr = self.net.transfer(from, to, flits, at);
        self.stats.traffic.record(
            flits,
            tr.hops,
            self.subs.flit_bytes,
            kind.is_subscription_traffic(),
        );
        tr
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Topology;
    use crate::policy::{PolicyKind, PolicyRuntime};

    #[test]
    fn facade_serves_over_every_topology() {
        for t in [Topology::Mesh, Topology::Crossbar, Topology::Ring] {
            let mut cfg = SimConfig::hmc();
            cfg.topology = t;
            cfg.policy = PolicyKind::Never;
            let policy = PolicyRuntime::new(&cfg);
            let mut mem = MemorySystem::new(&cfg);
            let res = mem.serve(
                Access { requester: 0, block: 31, write: false },
                0,
                &policy,
            );
            assert_eq!(res.served_by, 31);
            let h = mem.hops(0, 31) as u64;
            assert_eq!(res.network, (5 + 1) * h, "{t:?}");
            assert_eq!(mem.stats().demand.total(), 1);
        }
    }

    #[test]
    fn broadcast_decision_records_traffic() {
        let cfg = SimConfig::hmc();
        let mut mem = MemorySystem::new(&cfg);
        let before = mem.stats().traffic.total_bytes();
        let d = EpochDecision {
            epoch: 1,
            at: 1000,
            enabled: true,
            vaults_enabled: 32,
            avg_latency: None,
        };
        mem.broadcast_decision(&d);
        assert!(mem.stats().traffic.total_bytes() > before);
    }

    #[test]
    fn queued_mem_is_the_non_network_share() {
        let res = ServedRequest { queued: 7, queued_net: 3, ..Default::default() };
        assert_eq!(res.queued_mem(), 4);
        let all_net = ServedRequest { queued: 5, queued_net: 5, ..Default::default() };
        assert_eq!(all_net.queued_mem(), 0);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "queued_net")]
    fn queued_mem_invariant_violation_panics_in_debug() {
        let bad = ServedRequest { queued: 1, queued_net: 2, ..Default::default() };
        let _ = bad.queued_mem();
    }

    #[test]
    #[cfg(not(debug_assertions))]
    fn queued_mem_saturates_in_release() {
        let bad = ServedRequest { queued: 1, queued_net: 2, ..Default::default() };
        assert_eq!(bad.queued_mem(), 0);
    }

    #[test]
    fn reset_restores_a_clean_system() {
        let cfg = SimConfig::hmc();
        let policy = PolicyRuntime::new(&cfg);
        let mut mem = MemorySystem::new(&cfg);
        mem.serve(Access { requester: 0, block: 31, write: false }, 0, &policy);
        mem.reset();
        assert_eq!(mem.stats().requests, 0);
        assert_eq!(mem.total_parked(), 0);
        let res =
            mem.serve(Access { requester: 0, block: 31, write: false }, 0, &policy);
        assert_eq!(res.queued_net, 0, "no stale link reservations");
    }
}

//! Figure-harness compatibility layer.
//!
//! The per-figure imperative harness that used to live here is gone: every
//! figure is now a *data entry* in [`crate::exp::registry`], executed by
//! the one generic [`crate::exp::run_spec`] path and rendered by
//! [`crate::exp::output`] (artifact bytes pinned by the `golden_artifacts`
//! test). This module keeps the small helpers external callers still use —
//! scale/env handling, the strict sweep wrapper, geometric means and
//! artifact emission by figure id.

use std::path::PathBuf;

use crate::config::SimConfig;
use crate::coordinator::driver::simulate;
use crate::coordinator::report::SimReport;
use crate::sweep;

pub use crate::exp::output::geomean;
pub use crate::exp::registry::{FIG16_WORKLOADS, FIG19_TENANTS};
pub use crate::exp::spec::{cfg_for, scaled};

/// Run one workload (or the config's trace) under one config.
pub fn run(cfg: &SimConfig, workload: &str) -> SimReport {
    let w = crate::workloads::build_source(Some(workload), cfg)
        .unwrap_or_else(|e| panic!("{e}"));
    simulate(cfg, w)
}

/// Run `names x configs` on the parallel sweep engine ([`crate::sweep`]):
/// a shared injector queue across all cores, per-point result caching,
/// deterministic per-job seeding. Returns results in `[workload][config]` order; panics
/// if any job failed (a figure with a silently missing bar is worse than a
/// loud failure).
pub fn run_matrix(names: &[&str], cfgs: &[SimConfig]) -> Vec<Vec<SimReport>> {
    sweep::run_matrix(names, cfgs)
}

/// The canonical artifact name of a figure id ("9" -> "fig09").
pub fn artifact_name(which: &str) -> String {
    format!("fig{which:0>2}")
}

/// Compute figure `which` through the spec registry (cache-cheap when its
/// points were already computed in this process) and write its JSON
/// artifact. Returns `None` for an unknown figure id; panics on failure
/// (CI must see it).
pub fn emit_artifact(which: &str) -> Option<PathBuf> {
    let spec = crate::exp::registry::by_figure(which)?;
    let run = crate::exp::run_spec(&spec).unwrap_or_else(|e| panic!("{e}"));
    Some(crate::exp::emit_artifact(&spec, &run).unwrap_or_else(|e| panic!("{e}")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MemKind;
    use crate::policy::PolicyKind;

    #[test]
    fn cfg_for_sets_policy_and_mem() {
        let c = cfg_for(MemKind::Hbm, PolicyKind::Adaptive);
        assert_eq!(c.mem, MemKind::Hbm);
        assert_eq!(c.policy, PolicyKind::Adaptive);
    }

    #[test]
    fn artifact_names_are_zero_padded() {
        assert_eq!(artifact_name("9"), "fig09");
        assert_eq!(artifact_name("19"), "fig19");
    }

    #[test]
    fn run_matrix_shape() {
        let mut cfg = cfg_for(MemKind::Hmc, PolicyKind::Never);
        cfg.warmup_requests = 200;
        cfg.measure_requests = 1000;
        let out = run_matrix(&["STRAdd", "STRCpy"], &[cfg.clone(), cfg]);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].len(), 2);
        assert_eq!(out[0][0].workload, "STRAdd");
        assert_eq!(out[1][1].workload, "STRCpy");
    }
}

//! Figure regeneration harness: one function per figure/table of the
//! paper's evaluation, shared by the `cargo bench` targets and the
//! `repro` CLI.
//!
//! Absolute numbers differ from the paper (our substrate is our own
//! simulator, not the authors' DAMOV testbed); the *shape* — who wins, by
//! roughly what factor, where the crossovers fall — is the reproduction
//! target (see EXPERIMENTS.md for paper-vs-measured).

use std::path::PathBuf;

use crate::config::{MemKind, SimConfig};
use crate::coordinator::driver::simulate;
use crate::coordinator::report::SimReport;
use crate::policy::PolicyKind;
use crate::sweep;
use crate::sweep::json::JsonValue;
use crate::workloads::catalog;

/// Scale knobs, overridable from the environment:
/// `REPRO_WARMUP` / `REPRO_MEASURE` / `REPRO_RUNS` / `REPRO_EPOCH`, plus
/// `REPRO_TOPOLOGY` to force one interconnect across the whole suite
/// (the CI smoke job's topology axis).
pub fn scaled(mut cfg: SimConfig) -> SimConfig {
    fn env_u64(key: &str) -> Option<u64> {
        std::env::var(key).ok()?.parse().ok()
    }
    if let Some(v) = env_u64("REPRO_WARMUP") {
        cfg.warmup_requests = v;
    }
    if let Some(v) = env_u64("REPRO_MEASURE") {
        cfg.measure_requests = v;
    }
    if let Some(v) = env_u64("REPRO_RUNS") {
        cfg.runs = v as u32;
    }
    if let Some(v) = env_u64("REPRO_EPOCH") {
        cfg.epoch_cycles = v;
    }
    if let Ok(t) = std::env::var("REPRO_TOPOLOGY") {
        cfg.topology = crate::config::Topology::parse(&t)
            .unwrap_or_else(|| panic!("unknown REPRO_TOPOLOGY {t:?} (mesh|crossbar|ring)"));
    }
    cfg
}

/// Base config for a memory kind with a policy, at harness scale.
pub fn cfg_for(mem: MemKind, policy: PolicyKind) -> SimConfig {
    let mut cfg = match mem {
        MemKind::Hmc => SimConfig::hmc(),
        MemKind::Hbm => SimConfig::hbm(),
    };
    cfg.policy = policy;
    scaled(cfg)
}

/// Run one workload (or the config's trace) under one config.
pub fn run(cfg: &SimConfig, workload: &str) -> SimReport {
    let w = crate::workloads::build_source(Some(workload), cfg)
        .unwrap_or_else(|e| panic!("{e}"));
    simulate(cfg, w)
}

/// Run `names x configs` on the parallel sweep engine ([`crate::sweep`]):
/// work-stealing across all cores, per-point result caching, deterministic
/// per-job seeding. Returns results in `[workload][config]` order; panics
/// if any job failed (a figure with a silently missing bar is worse than a
/// loud failure).
pub fn run_matrix(names: &[&str], cfgs: &[SimConfig]) -> Vec<Vec<SimReport>> {
    sweep::run_matrix(names, cfgs)
}

// ---------------------------------------------------------------------
// Figure rows
// ---------------------------------------------------------------------

/// Figs 1 & 2: latency breakdown per workload under the baseline.
pub struct BreakdownRow {
    pub workload: &'static str,
    pub network: f64,
    pub queue: f64,
    pub array: f64,
    pub avg_latency: f64,
}

pub fn fig_latency_breakdown(mem: MemKind) -> Vec<BreakdownRow> {
    let cfg = cfg_for(mem, PolicyKind::Never);
    let reports = run_matrix(&catalog::ALL_NAMES, std::slice::from_ref(&cfg));
    catalog::ALL_NAMES
        .iter()
        .zip(reports)
        .map(|(name, mut r)| {
            let rep = r.remove(0);
            let (n, q, a) = rep.latency_fractions();
            BreakdownRow {
                workload: name,
                network: n,
                queue: q,
                array: a,
                avg_latency: rep.avg_latency(),
            }
        })
        .collect()
}

/// Figs 3 & 4: baseline CoV per workload.
pub fn fig_cov(mem: MemKind) -> Vec<(&'static str, f64)> {
    let cfg = cfg_for(mem, PolicyKind::Never);
    let reports = run_matrix(&catalog::ALL_NAMES, std::slice::from_ref(&cfg));
    catalog::ALL_NAMES
        .iter()
        .zip(reports)
        .map(|(name, mut r)| (*name, r.remove(0).cov()))
        .collect()
}

/// Fig 9: always-subscribe speedup over baseline, all 31 workloads (HMC).
pub struct SpeedupRow {
    pub workload: &'static str,
    pub speedup: f64,
    pub latency_improvement: f64,
}

pub fn fig9_always_subscribe() -> Vec<SpeedupRow> {
    let base = cfg_for(MemKind::Hmc, PolicyKind::Never);
    let always = cfg_for(MemKind::Hmc, PolicyKind::Always);
    let reports = run_matrix(&catalog::ALL_NAMES, &[base, always]);
    catalog::ALL_NAMES
        .iter()
        .zip(reports)
        .map(|(name, r)| SpeedupRow {
            workload: name,
            speedup: r[1].speedup_vs(&r[0]),
            latency_improvement: r[1].latency_improvement_vs(&r[0]),
        })
        .collect()
}

/// Fig 10: reuse per subscription under always-subscribe (HMC).
pub fn fig10_reuse() -> Vec<(&'static str, f64, f64)> {
    let always = cfg_for(MemKind::Hmc, PolicyKind::Always);
    let reports = run_matrix(&catalog::ALL_NAMES, std::slice::from_ref(&always));
    catalog::ALL_NAMES
        .iter()
        .zip(reports)
        .map(|(name, mut r)| {
            let (l, rm) = r.remove(0).reuse();
            (*name, l, rm)
        })
        .collect()
}

/// Fig 11: selected workloads, always vs adaptive speedup + adaptive
/// latency improvement (HMC).
pub struct AdaptiveRow {
    pub workload: &'static str,
    pub always_speedup: f64,
    pub adaptive_speedup: f64,
    pub latency_improvement: f64,
}

pub fn fig11_adaptive() -> Vec<AdaptiveRow> {
    let cfgs = [
        cfg_for(MemKind::Hmc, PolicyKind::Never),
        cfg_for(MemKind::Hmc, PolicyKind::Always),
        cfg_for(MemKind::Hmc, PolicyKind::Adaptive),
    ];
    let reports = run_matrix(&catalog::SELECTED, &cfgs);
    catalog::SELECTED
        .iter()
        .zip(reports)
        .map(|(name, r)| AdaptiveRow {
            workload: name,
            always_speedup: r[1].speedup_vs(&r[0]),
            adaptive_speedup: r[2].speedup_vs(&r[0]),
            latency_improvement: r[2].latency_improvement_vs(&r[0]),
        })
        .collect()
}

/// Fig 12 (HMC) / Fig 13 (HBM): CoV under baseline / always / adaptive.
pub fn fig_cov_policies(mem: MemKind, include_always: bool) -> Vec<(&'static str, Vec<f64>)> {
    let mut cfgs = vec![cfg_for(mem, PolicyKind::Never)];
    if include_always {
        cfgs.push(cfg_for(mem, PolicyKind::Always));
    }
    cfgs.push(cfg_for(mem, PolicyKind::Adaptive));
    let reports = run_matrix(&catalog::SELECTED, &cfgs);
    catalog::SELECTED
        .iter()
        .zip(reports)
        .map(|(name, r)| (*name, r.iter().map(|x| x.cov()).collect()))
        .collect()
}

/// Fig 14: traffic (bytes/cycle) under baseline / always / adaptive (HMC).
pub fn fig14_traffic() -> Vec<(&'static str, f64, f64, f64)> {
    let cfgs = [
        cfg_for(MemKind::Hmc, PolicyKind::Never),
        cfg_for(MemKind::Hmc, PolicyKind::Always),
        cfg_for(MemKind::Hmc, PolicyKind::Adaptive),
    ];
    let reports = run_matrix(&catalog::SELECTED, &cfgs);
    catalog::SELECTED
        .iter()
        .zip(reports)
        .map(|(name, r)| {
            (
                *name,
                r[0].bytes_per_cycle(),
                r[1].bytes_per_cycle(),
                r[2].bytes_per_cycle(),
            )
        })
        .collect()
}

/// Fig 15: HBM latency baseline vs adaptive + speedup, all 31 workloads.
pub struct HbmRow {
    pub workload: &'static str,
    pub base_latency: f64,
    pub adaptive_latency: f64,
    pub speedup: f64,
}

pub fn fig15_hbm_adaptive() -> Vec<HbmRow> {
    let cfgs =
        [cfg_for(MemKind::Hbm, PolicyKind::Never), cfg_for(MemKind::Hbm, PolicyKind::Adaptive)];
    let reports = run_matrix(&catalog::ALL_NAMES, &cfgs);
    catalog::ALL_NAMES
        .iter()
        .zip(reports)
        .map(|(name, r)| HbmRow {
            workload: name,
            base_latency: r[0].avg_latency(),
            adaptive_latency: r[1].avg_latency(),
            speedup: r[1].speedup_vs(&r[0]),
        })
        .collect()
}

/// Fig 16: adaptive speedup vs subscription-table size, table-sensitive
/// workloads.
pub const FIG16_WORKLOADS: [&str; 4] = ["PLYDoitgen", "PHELinReg", "SPLRad", "CHABsBez"];

pub fn fig16_table_size() -> Vec<(&'static str, Vec<(u32, f64)>)> {
    let base = cfg_for(MemKind::Hmc, PolicyKind::Never);
    let mut cfgs = vec![base];
    for entries in crate::config::presets::TABLE_SIZE_SWEEP {
        let mut c = crate::config::presets::hmc_adaptive_with_table_entries(entries);
        c = scaled(c);
        cfgs.push(c);
    }
    let reports = run_matrix(&FIG16_WORKLOADS, &cfgs);
    FIG16_WORKLOADS
        .iter()
        .zip(reports)
        .map(|(name, r)| {
            let series = crate::config::presets::TABLE_SIZE_SWEEP
                .iter()
                .enumerate()
                .map(|(i, &entries)| (entries, r[i + 1].speedup_vs(&r[0])))
                .collect();
            (*name, series)
        })
        .collect()
}

/// Fig 17 (ablation): count-threshold filter vs subscribe-on-first-access.
pub fn fig17_threshold_ablation() -> Vec<(&'static str, Vec<(u32, f64)>)> {
    const THRESHOLDS: [u32; 4] = [0, 1, 4, 16];
    let names = ["SPLRad", "PHELinReg", "PLYgemm", "HSJNPO"];
    let base = cfg_for(MemKind::Hmc, PolicyKind::Never);
    let mut cfgs = vec![base];
    for t in THRESHOLDS {
        let mut c = cfg_for(MemKind::Hmc, PolicyKind::Always);
        c.count_threshold = t;
        cfgs.push(c);
    }
    let reports = run_matrix(&names, &cfgs);
    names
        .iter()
        .zip(reports)
        .map(|(name, r)| {
            let series = THRESHOLDS
                .iter()
                .enumerate()
                .map(|(i, &t)| (t, r[i + 1].speedup_vs(&r[0])))
                .collect();
            (*name, series)
        })
        .collect()
}

/// Fig 18 (ablation): adaptive-policy variants.
pub fn fig18_policy_ablation() -> Vec<(&'static str, Vec<(&'static str, f64)>)> {
    const POLICIES: [PolicyKind; 4] = [
        PolicyKind::Always,
        PolicyKind::AdaptiveHops,
        PolicyKind::AdaptiveLatency,
        PolicyKind::Adaptive,
    ];
    let names = ["SPLRad", "PHELinReg", "PLYgemm", "PLY3mm", "STRTriad"];
    let mut cfgs = vec![cfg_for(MemKind::Hmc, PolicyKind::Never)];
    for p in POLICIES {
        cfgs.push(cfg_for(MemKind::Hmc, p));
    }
    let reports = run_matrix(&names, &cfgs);
    names
        .iter()
        .zip(reports)
        .map(|(name, r)| {
            let series = POLICIES
                .iter()
                .enumerate()
                .map(|(i, p)| (p.as_str(), r[i + 1].speedup_vs(&r[0])))
                .collect();
            (*name, series)
        })
        .collect()
}

/// Fig 19 (extension): adaptive DL-PIM under multi-tenant trace mixes —
/// the serving-consolidation scenario no single Table III generator
/// produces. Each tenant is a recorded baseline trace; mixes interleave
/// them over one memory system with per-tenant address-space offsets, so
/// tenants' hot home vaults collide (see [`crate::trace::transform::mix`]).
#[derive(Clone)]
pub struct MultiTenantRow {
    pub scenario: &'static str,
    pub tenants: usize,
    pub always_speedup: f64,
    pub adaptive_speedup: f64,
    pub latency_improvement: f64,
    pub base_cov: f64,
    pub adaptive_cov: f64,
}

/// Tenant workloads, chosen for clashing home-vault footprints: two
/// single-hot-vault tile reusers, one multi-lane reuser, one shared-panel
/// thrasher.
pub const FIG19_TENANTS: [&str; 4] = ["SPLRad", "PHELinReg", "CHABsBez", "PLYgemm"];

pub fn fig19_multi_tenant() -> Vec<MultiTenantRow> {
    // Memoized per process: the tenant *recording* runs bypass the sweep
    // report cache (they go through `record_run`, not the engine), and
    // every entry point computes the rows twice (once to print, once for
    // the JSON artifact) — without this the 4 recordings would re-run.
    static ROWS: std::sync::OnceLock<Vec<MultiTenantRow>> = std::sync::OnceLock::new();
    ROWS.get_or_init(fig19_compute).clone()
}

fn fig19_compute() -> Vec<MultiTenantRow> {
    let dir = sweep::artifact::artifact_dir().join("traces");
    let rec_cfg = cfg_for(MemKind::Hmc, PolicyKind::Never);
    let tenants: Vec<crate::trace::TraceData> = FIG19_TENANTS
        .iter()
        .map(|name| {
            let path = dir.join(format!("{name}.dlpt"));
            crate::trace::record_run(&rec_cfg, name, &path)
                .unwrap_or_else(|e| panic!("record tenant {name}: {e}"));
            crate::trace::TraceData::load(&path).unwrap_or_else(|e| panic!("{e}"))
        })
        .collect();

    [("mix2", 2usize), ("mix4", 4usize)]
        .iter()
        .map(|&(label, k)| {
            let mixed =
                crate::trace::transform::mix(&tenants[..k], &vec![1; k], rec_cfg.n_vaults)
                    .unwrap_or_else(|e| panic!("{label}: {e}"));
            let path = dir.join(format!("{label}.dlpt"));
            mixed.save(&path).unwrap_or_else(|e| panic!("{label}: {e}"));
            let cfgs: Vec<SimConfig> = [PolicyKind::Never, PolicyKind::Always, PolicyKind::Adaptive]
                .iter()
                .map(|&p| {
                    let mut c = cfg_for(MemKind::Hmc, p);
                    c.trace = Some(path.to_string_lossy().into_owned());
                    c
                })
                .collect();
            let r = run_matrix(&[label], &cfgs).remove(0);
            MultiTenantRow {
                scenario: label,
                tenants: k,
                always_speedup: r[1].speedup_vs(&r[0]),
                adaptive_speedup: r[2].speedup_vs(&r[0]),
                latency_improvement: r[2].latency_improvement_vs(&r[0]),
                base_cov: r[0].cov(),
                adaptive_cov: r[2].cov(),
            }
        })
        .collect()
}

// ---------------------------------------------------------------------
// JSON artifacts
// ---------------------------------------------------------------------

fn row_obj(workload: &str, cols: &[(&str, f64)]) -> JsonValue {
    let mut pairs = vec![("workload", JsonValue::str(workload))];
    pairs.extend(cols.iter().map(|(k, v)| (*k, JsonValue::num(*v))));
    JsonValue::obj(pairs)
}

fn series_obj(workload: &str, key: &str, series: &[(String, f64)]) -> JsonValue {
    JsonValue::obj(vec![
        ("workload", JsonValue::str(workload)),
        (
            "series",
            JsonValue::Arr(
                series
                    .iter()
                    .map(|(x, s)| {
                        JsonValue::obj(vec![
                            (key, JsonValue::str(x.clone())),
                            ("speedup", JsonValue::num(*s)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// The canonical artifact name of a figure id ("9" -> "fig09").
pub fn artifact_name(which: &str) -> String {
    format!("fig{which:0>2}")
}

/// Build the JSON artifact body for one figure. Thanks to the sweep
/// engine's report cache this is nearly free when the figure was already
/// computed in this process (e.g. right after printing it).
pub fn figure_json(which: &str) -> Option<JsonValue> {
    let rows: Vec<JsonValue> = match which {
        "1" | "2" => {
            let mem = if which == "1" { MemKind::Hmc } else { MemKind::Hbm };
            fig_latency_breakdown(mem)
                .iter()
                .map(|r| {
                    row_obj(
                        r.workload,
                        &[
                            ("network", r.network),
                            ("queue", r.queue),
                            ("array", r.array),
                            ("avg_latency", r.avg_latency),
                        ],
                    )
                })
                .collect()
        }
        "3" | "4" => {
            let mem = if which == "3" { MemKind::Hmc } else { MemKind::Hbm };
            fig_cov(mem).iter().map(|(w, cov)| row_obj(w, &[("cov", *cov)])).collect()
        }
        "9" => fig9_always_subscribe()
            .iter()
            .map(|r| {
                row_obj(
                    r.workload,
                    &[
                        ("speedup", r.speedup),
                        ("latency_improvement", r.latency_improvement),
                    ],
                )
            })
            .collect(),
        "10" => fig10_reuse()
            .iter()
            .map(|(w, l, r)| row_obj(w, &[("local", *l), ("remote", *r)]))
            .collect(),
        "11" => fig11_adaptive()
            .iter()
            .map(|r| {
                row_obj(
                    r.workload,
                    &[
                        ("always", r.always_speedup),
                        ("adaptive", r.adaptive_speedup),
                        ("latency_improvement", r.latency_improvement),
                    ],
                )
            })
            .collect(),
        "12" => fig_cov_policies(MemKind::Hmc, true)
            .iter()
            .map(|(w, covs)| {
                row_obj(
                    w,
                    &[("baseline", covs[0]), ("always", covs[1]), ("adaptive", covs[2])],
                )
            })
            .collect(),
        "13" => fig_cov_policies(MemKind::Hbm, false)
            .iter()
            .map(|(w, covs)| row_obj(w, &[("baseline", covs[0]), ("adaptive", covs[1])]))
            .collect(),
        "14" => fig14_traffic()
            .iter()
            .map(|(w, b, a, d)| {
                row_obj(w, &[("baseline", *b), ("always", *a), ("adaptive", *d)])
            })
            .collect(),
        "15" => fig15_hbm_adaptive()
            .iter()
            .map(|r| {
                row_obj(
                    r.workload,
                    &[
                        ("base_latency", r.base_latency),
                        ("adaptive_latency", r.adaptive_latency),
                        ("speedup", r.speedup),
                    ],
                )
            })
            .collect(),
        "16" => fig16_table_size()
            .iter()
            .map(|(w, series)| {
                let s: Vec<(String, f64)> =
                    series.iter().map(|(e, sp)| (e.to_string(), *sp)).collect();
                series_obj(w, "entries", &s)
            })
            .collect(),
        "17" => fig17_threshold_ablation()
            .iter()
            .map(|(w, series)| {
                let s: Vec<(String, f64)> =
                    series.iter().map(|(t, sp)| (t.to_string(), *sp)).collect();
                series_obj(w, "threshold", &s)
            })
            .collect(),
        "18" => fig18_policy_ablation()
            .iter()
            .map(|(w, series)| {
                let s: Vec<(String, f64)> =
                    series.iter().map(|(p, sp)| (p.to_string(), *sp)).collect();
                series_obj(w, "policy", &s)
            })
            .collect(),
        "19" => fig19_multi_tenant()
            .iter()
            .map(|r| {
                row_obj(
                    r.scenario,
                    &[
                        ("tenants", r.tenants as f64),
                        ("always", r.always_speedup),
                        ("adaptive", r.adaptive_speedup),
                        ("latency_improvement", r.latency_improvement),
                        ("base_cov", r.base_cov),
                        ("adaptive_cov", r.adaptive_cov),
                    ],
                )
            })
            .collect(),
        _ => return None,
    };
    Some(JsonValue::obj(vec![
        ("figure", JsonValue::str(artifact_name(which))),
        ("rows", JsonValue::Arr(rows)),
    ]))
}

/// Compute figure `which` (cache-cheap when already computed) and write
/// its JSON artifact to the sweep artifact directory. Returns `None` for
/// an unknown figure id; panics on I/O failure (CI must see it).
pub fn emit_artifact(which: &str) -> Option<PathBuf> {
    let value = figure_json(which)?;
    let name = artifact_name(which);
    Some(
        sweep::artifact::write_figure_json(&name, &value)
            .unwrap_or_else(|e| panic!("write figure artifact {name}: {e}")),
    )
}

/// Geometric mean (the paper's averages over workloads).
pub fn geomean(xs: impl Iterator<Item = f64>) -> f64 {
    let (mut logsum, mut n) = (0.0, 0usize);
    for x in xs {
        if x > 0.0 {
            logsum += x.ln();
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        (logsum / n as f64).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_of_constants() {
        assert!((geomean([2.0, 2.0, 2.0].into_iter()) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_ignores_nonpositive() {
        assert!((geomean([4.0, 0.0, -1.0].into_iter()) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn cfg_for_sets_policy_and_mem() {
        let c = cfg_for(MemKind::Hbm, PolicyKind::Adaptive);
        assert_eq!(c.mem, MemKind::Hbm);
        assert_eq!(c.policy, PolicyKind::Adaptive);
    }

    #[test]
    fn run_matrix_shape() {
        let mut cfg = cfg_for(MemKind::Hmc, PolicyKind::Never);
        cfg.warmup_requests = 200;
        cfg.measure_requests = 1000;
        let out = run_matrix(&["STRAdd", "STRCpy"], &[cfg.clone(), cfg]);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].len(), 2);
        assert_eq!(out[0][0].workload, "STRAdd");
        assert_eq!(out[1][1].workload, "STRCpy");
    }
}

//! Coefficient of variation of per-vault demand (Figs 3/4/12/13).
//!
//! Each demand access is attributed to the vault that *served* it (the
//! home vault in the baseline; the subscribed vault when a block has
//! moved). High CoV = a few vaults carry most of the demand = deep queues
//! at those vaults — the imbalance DL-PIM's subscriptions flatten.

/// Per-vault served-request counters.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct VaultDemand {
    counts: Vec<u64>,
}

impl VaultDemand {
    pub fn new(n_vaults: u16) -> Self {
        VaultDemand { counts: vec![0; n_vaults as usize] }
    }

    /// Rebuild from previously captured per-vault counts (the disk cache's
    /// deserializer). The vault count is the vector's length.
    pub fn from_counts(counts: Vec<u64>) -> Self {
        VaultDemand { counts }
    }

    #[inline]
    pub fn record(&mut self, vault: u16) {
        self.counts[vault as usize] += 1;
    }

    pub fn n_vaults(&self) -> u16 {
        self.counts.len() as u16
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Population coefficient of variation: sigma / mu. Zero when no
    /// accesses were recorded (or a single vault).
    pub fn cov(&self) -> f64 {
        let n = self.counts.len() as f64;
        if n < 2.0 {
            return 0.0;
        }
        let total: u64 = self.total();
        if total == 0 {
            return 0.0;
        }
        let mean = total as f64 / n;
        let var = self
            .counts
            .iter()
            .map(|&c| {
                let d = c as f64 - mean;
                d * d
            })
            .sum::<f64>()
            / n;
        var.sqrt() / mean
    }

    pub fn merge(&mut self, other: &VaultDemand) {
        assert_eq!(self.counts.len(), other.counts.len());
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_demand_has_zero_cov() {
        let mut d = VaultDemand::new(8);
        for v in 0..8 {
            for _ in 0..100 {
                d.record(v);
            }
        }
        assert!(d.cov() < 1e-12);
    }

    #[test]
    fn single_hot_vault_has_high_cov() {
        let mut d = VaultDemand::new(32);
        for _ in 0..1000 {
            d.record(0);
        }
        // All mass on one of 32 vaults: CoV = sqrt(n-1) ~ 5.57.
        assert!((d.cov() - (31f64).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn empty_demand_is_zero() {
        assert_eq!(VaultDemand::new(32).cov(), 0.0);
    }

    #[test]
    fn cov_is_scale_invariant() {
        let mut a = VaultDemand::new(4);
        let mut b = VaultDemand::new(4);
        for (v, n) in [(0u16, 1u32), (1, 2), (2, 3), (3, 4)] {
            for _ in 0..n {
                a.record(v);
            }
            for _ in 0..n * 10 {
                b.record(v);
            }
        }
        assert!((a.cov() - b.cov()).abs() < 1e-12);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = VaultDemand::new(2);
        a.record(0);
        let mut b = VaultDemand::new(2);
        b.record(1);
        a.merge(&b);
        assert_eq!(a.counts(), &[1, 1]);
        assert!(a.cov() < 1e-12);
    }
}

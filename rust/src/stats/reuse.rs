//! Reuse-per-subscription accounting (Fig 10).
//!
//! For every subscription, count how many times the moved block is accessed
//! afterwards: *locally* by the PIM core of the subscribed vault (the
//! accesses the move made cheap) and *remotely* by other vaults (the
//! accesses the move made more expensive). A workload with near-zero reuse
//! gains nothing from always-subscribe — the crossover the paper highlights
//! between Fig 9 winners and the flat middle of the plot.

/// Aggregate reuse counters over all completed subscriptions.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ReuseStats {
    /// Completed subscriptions (denominator of Fig 10).
    pub subscriptions: u64,
    /// Post-subscription accesses from the subscribed (local) vault.
    pub local_hits: u64,
    /// Post-subscription accesses from any other vault.
    pub remote_hits: u64,
}

impl ReuseStats {
    pub fn on_subscribe(&mut self) {
        self.subscriptions += 1;
    }

    pub fn on_local_hit(&mut self) {
        self.local_hits += 1;
    }

    pub fn on_remote_hit(&mut self) {
        self.remote_hits += 1;
    }

    /// Average local reuses per subscription (dark-blue bars of Fig 10).
    pub fn avg_local(&self) -> f64 {
        if self.subscriptions == 0 {
            0.0
        } else {
            self.local_hits as f64 / self.subscriptions as f64
        }
    }

    /// Average remote accesses per subscription (light-blue bars).
    pub fn avg_remote(&self) -> f64 {
        if self.subscriptions == 0 {
            0.0
        } else {
            self.remote_hits as f64 / self.subscriptions as f64
        }
    }

    /// Total average reuse; the paper's "non-negligible reuse" selector for
    /// the Fig 11 workload subset.
    pub fn avg_total(&self) -> f64 {
        self.avg_local() + self.avg_remote()
    }

    pub fn merge(&mut self, other: &ReuseStats) {
        self.subscriptions += other.subscriptions;
        self.local_hits += other.local_hits;
        self.remote_hits += other.remote_hits;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn averages_divide_by_subscriptions() {
        let mut r = ReuseStats::default();
        r.on_subscribe();
        r.on_subscribe();
        for _ in 0..6 {
            r.on_local_hit();
        }
        r.on_remote_hit();
        assert!((r.avg_local() - 3.0).abs() < 1e-12);
        assert!((r.avg_remote() - 0.5).abs() < 1e-12);
        assert!((r.avg_total() - 3.5).abs() < 1e-12);
    }

    #[test]
    fn zero_subscriptions_zero_reuse() {
        let r = ReuseStats::default();
        assert_eq!(r.avg_total(), 0.0);
    }
}

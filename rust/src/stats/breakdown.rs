//! Per-request latency decomposition into the three components of
//! Figs 1 and 2: data-transfer (network), queuing, and array access.

/// Accumulated latency components over all measured demand requests.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencyBreakdown {
    pub network: u64,
    pub queue: u64,
    pub array: u64,
    pub requests: u64,
}

impl LatencyBreakdown {
    pub fn record(&mut self, network: u64, queue: u64, array: u64) {
        self.network += network;
        self.queue += queue;
        self.array += array;
        self.requests += 1;
    }

    pub fn total(&self) -> u64 {
        self.network + self.queue + self.array
    }

    /// Average end-to-end memory latency per request (cycles).
    pub fn avg(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.total() as f64 / self.requests as f64
        }
    }

    /// Fractions (network, queue, array) of total latency — the stacked
    /// bars of Fig 1/2. Sums to 1 when any latency was recorded.
    pub fn fractions(&self) -> (f64, f64, f64) {
        let t = self.total();
        if t == 0 {
            return (0.0, 0.0, 0.0);
        }
        let t = t as f64;
        (
            self.network as f64 / t,
            self.queue as f64 / t,
            self.array as f64 / t,
        )
    }

    /// The paper's "remote overhead": share of latency that is *not* array
    /// access (53% HMC / 43% HBM on average in Figs 1/2).
    pub fn remote_overhead_fraction(&self) -> f64 {
        let (n, q, _) = self.fractions();
        n + q
    }

    pub fn merge(&mut self, other: &LatencyBreakdown) {
        self.network += other.network;
        self.queue += other.queue;
        self.array += other.array;
        self.requests += other.requests;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fractions_sum_to_one() {
        let mut b = LatencyBreakdown::default();
        b.record(10, 30, 60);
        let (n, q, a) = b.fractions();
        assert!((n + q + a - 1.0).abs() < 1e-12);
        assert!((n - 0.1).abs() < 1e-12);
        assert!((q - 0.3).abs() < 1e-12);
        assert!((a - 0.6).abs() < 1e-12);
    }

    #[test]
    fn avg_counts_requests() {
        let mut b = LatencyBreakdown::default();
        b.record(5, 5, 10);
        b.record(0, 0, 20);
        assert!((b.avg() - 20.0).abs() < 1e-12);
    }

    #[test]
    fn empty_breakdown_is_zero() {
        let b = LatencyBreakdown::default();
        assert_eq!(b.avg(), 0.0);
        assert_eq!(b.fractions(), (0.0, 0.0, 0.0));
    }

    #[test]
    fn remote_overhead_excludes_array() {
        let mut b = LatencyBreakdown::default();
        b.record(25, 28, 47);
        assert!((b.remote_overhead_fraction() - 0.53).abs() < 1e-12);
    }

    #[test]
    fn merge_adds_componentwise() {
        let mut a = LatencyBreakdown::default();
        a.record(1, 2, 3);
        let mut b = LatencyBreakdown::default();
        b.record(10, 20, 30);
        a.merge(&b);
        assert_eq!(a.requests, 2);
        assert_eq!(a.total(), 66);
    }
}

//! Measurement machinery for everything the paper's figures plot:
//! latency breakdowns (Figs 1/2/11/15), per-vault demand CoV (Figs 3/4/12/
//! 13), network traffic (Fig 14), and reuse-per-subscription (Fig 10).

pub mod breakdown;
pub mod cov;
pub mod reuse;
pub mod traffic;

pub use breakdown::LatencyBreakdown;
pub use cov::VaultDemand;
pub use reuse::ReuseStats;
pub use traffic::TrafficStats;

/// All per-run statistics, reset together after warmup.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SimStats {
    pub latency: LatencyBreakdown,
    pub demand: VaultDemand,
    pub traffic: TrafficStats,
    pub reuse: ReuseStats,
    /// Demand requests completed since last reset.
    pub requests: u64,
    /// Queue cycles spent on busy mesh links (subset of latency.queue).
    pub queue_net: u64,
    /// Queue cycles spent at vault controllers / banks (subset).
    pub queue_mem: u64,
    /// L1 hits (served without entering the memory system).
    pub l1_hits: u64,
    /// Requests served entirely within the requester's local vault.
    pub local_requests: u64,
    /// Subscriptions successfully initiated / nacked / unsubscribed.
    pub subscriptions: u64,
    pub sub_nacks: u64,
    pub unsubscriptions: u64,
    pub resubscriptions: u64,
}

impl SimStats {
    pub fn new(n_vaults: u16) -> Self {
        SimStats { demand: VaultDemand::new(n_vaults), ..Default::default() }
    }

    /// Reset all counters (end of warmup) while keeping vault count.
    pub fn reset(&mut self) {
        let n = self.demand.n_vaults();
        *self = SimStats::new(n);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reset_preserves_vault_count() {
        let mut s = SimStats::new(32);
        s.requests = 10;
        s.demand.record(3);
        s.reset();
        assert_eq!(s.requests, 0);
        assert_eq!(s.demand.n_vaults(), 32);
        assert_eq!(s.demand.total(), 0);
    }
}

//! Network traffic accounting (Fig 14): bytes crossing mesh links per
//! cycle, split into demand traffic and subscription-protocol traffic.
//!
//! A packet of `f` FLITs crossing `h` hops moves `f * 16 B` over `h`
//! links, so it contributes `f * h * flit_bytes` link-bytes — the same
//! quantity a per-link hardware counter would sum.

/// Byte counters by traffic class.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TrafficStats {
    pub demand_bytes: u64,
    pub subscription_bytes: u64,
}

impl TrafficStats {
    #[inline]
    pub fn record(&mut self, flits: u32, hops: u32, flit_bytes: u32, subscription: bool) {
        let bytes = flits as u64 * hops as u64 * flit_bytes as u64;
        if subscription {
            self.subscription_bytes += bytes;
        } else {
            self.demand_bytes += bytes;
        }
    }

    pub fn total_bytes(&self) -> u64 {
        self.demand_bytes + self.subscription_bytes
    }

    /// Bytes per cycle over an execution window — Fig 14's y-axis.
    pub fn bytes_per_cycle(&self, cycles: u64) -> f64 {
        if cycles == 0 {
            0.0
        } else {
            self.total_bytes() as f64 / cycles as f64
        }
    }

    pub fn merge(&mut self, other: &TrafficStats) {
        self.demand_bytes += other.demand_bytes;
        self.subscription_bytes += other.subscription_bytes;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_split_by_class() {
        let mut t = TrafficStats::default();
        t.record(5, 3, 16, false); // demand: 5*3*16 = 240
        t.record(1, 3, 16, true); // subscription: 48
        assert_eq!(t.demand_bytes, 240);
        assert_eq!(t.subscription_bytes, 48);
        assert_eq!(t.total_bytes(), 288);
    }

    #[test]
    fn bytes_per_cycle_normalizes() {
        let mut t = TrafficStats::default();
        t.record(5, 4, 16, false);
        assert!((t.bytes_per_cycle(160) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn zero_hops_is_free() {
        let mut t = TrafficStats::default();
        t.record(5, 0, 16, false);
        assert_eq!(t.total_bytes(), 0);
    }

    #[test]
    fn zero_cycles_guard() {
        assert_eq!(TrafficStats::default().bytes_per_cycle(0), 0.0);
    }
}

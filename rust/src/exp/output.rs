//! Render a completed spec run: the JSON artifact (byte-identical to the
//! pre-registry harness for every figure schema — pinned by the
//! `golden_artifacts` test), the printed row table, and the CSV the bench
//! shims write.

use super::run::{RowResult, SpecRun};
use super::spec::{Agg, Column, ExperimentSpec, Extract, Metric, OutputSchema};
use crate::sweep::json::JsonValue;

/// Evaluate one extractor over a row's per-config reports.
pub fn extract(ex: Extract, row: &RowResult) -> f64 {
    match ex {
        Extract::Metric { cfg, metric } => {
            let r = &row.reports[cfg];
            match metric {
                Metric::AvgLatency => r.avg_latency(),
                Metric::Cov => r.cov(),
                Metric::BytesPerCycle => r.bytes_per_cycle(),
                Metric::NetworkFraction => r.latency_fractions().0,
                Metric::QueueFraction => r.latency_fractions().1,
                Metric::QueueNetFraction => r.queue_fractions().0,
                Metric::QueueMemFraction => r.queue_fractions().1,
                Metric::ArrayFraction => r.latency_fractions().2,
                Metric::RemoteOverhead => {
                    let (n, q, _) = r.latency_fractions();
                    n + q
                }
                Metric::ReuseLocal => r.reuse().0,
                Metric::ReuseRemote => r.reuse().1,
            }
        }
        Extract::Speedup { cfg } => row.reports[cfg].speedup_vs(&row.reports[0]),
        Extract::LatencyImprovement { cfg } => {
            row.reports[cfg].latency_improvement_vs(&row.reports[0])
        }
        Extract::Tenants => row.tenants.unwrap_or(0) as f64,
    }
}

fn row_obj(label: &str, cols: &[(&str, f64)]) -> JsonValue {
    let mut pairs = vec![("workload", JsonValue::str(label))];
    pairs.extend(cols.iter().map(|(k, v)| (*k, JsonValue::num(*v))));
    JsonValue::obj(pairs)
}

fn series_obj(label: &str, key: &str, series: &[(String, f64)]) -> JsonValue {
    JsonValue::obj(vec![
        ("workload", JsonValue::str(label)),
        (
            "series",
            JsonValue::Arr(
                series
                    .iter()
                    .map(|(x, s)| {
                        JsonValue::obj(vec![
                            (key, JsonValue::str(x.clone())),
                            ("speedup", JsonValue::num(*s)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

fn columns_of(row: &RowResult, cols: &[Column]) -> Vec<(&'static str, f64)> {
    cols.iter().map(|c| (c.name, extract(c.extract, row))).collect()
}

/// The per-config series of a [`OutputSchema::Series`] row: axis label +
/// speedup vs config 0, over configs `1..`.
fn series_of(run: &SpecRun, row: &RowResult, axis: super::spec::SeriesAxis) -> Vec<(String, f64)> {
    run.configs[1..]
        .iter()
        .enumerate()
        .map(|(i, cp)| (axis.label(cp), extract(Extract::Speedup { cfg: i + 1 }, row)))
        .collect()
}

/// The long-form row of one (workload × config) point.
fn long_obj(run: &SpecRun, row: &RowResult, cfg_idx: usize) -> JsonValue {
    let cp = &run.configs[cfg_idx];
    let rep = &row.reports[cfg_idx];
    let (network, queue, array) = rep.latency_fractions();
    let (reuse_local, reuse_remote) = rep.reuse();
    let mut pairs = vec![
        ("workload", JsonValue::str(row.label.clone())),
        ("config", JsonValue::str(cp.label.clone())),
        ("policy", JsonValue::str(cp.policy.as_str())),
        ("mem", JsonValue::str(cp.cfg.mem.as_str())),
        ("topology", JsonValue::str(cp.cfg.topology.as_str())),
        ("table_entries", JsonValue::num(cp.cfg.sub_table_entries() as f64)),
        ("count_threshold", JsonValue::num(cp.cfg.count_threshold as f64)),
        ("epoch_cycles", JsonValue::num(cp.cfg.epoch_cycles as f64)),
    ];
    if let Some(t) = &row.trace {
        pairs.push(("trace", JsonValue::str(t.clone())));
    }
    if let Some(k) = row.tenants {
        pairs.push(("tenants", JsonValue::num(k as f64)));
    }
    pairs.extend([
        ("cycles", JsonValue::num(rep.cycles())),
        ("avg_latency", JsonValue::num(rep.avg_latency())),
        ("cov", JsonValue::num(rep.cov())),
        ("bytes_per_cycle", JsonValue::num(rep.bytes_per_cycle())),
        ("network_frac", JsonValue::num(network)),
        ("queue_frac", JsonValue::num(queue)),
        ("array_frac", JsonValue::num(array)),
        ("reuse_local", JsonValue::num(reuse_local)),
        ("reuse_remote", JsonValue::num(reuse_remote)),
        ("local_fraction", JsonValue::num(rep.local_fraction())),
        ("speedup", JsonValue::num(extract(Extract::Speedup { cfg: cfg_idx }, row))),
        (
            "latency_improvement",
            JsonValue::num(extract(Extract::LatencyImprovement { cfg: cfg_idx }, row)),
        ),
    ]);
    JsonValue::obj(pairs)
}

/// Build the JSON artifact body for a completed run.
pub fn render_json(spec: &ExperimentSpec, run: &SpecRun) -> JsonValue {
    let rows: Vec<JsonValue> = match &spec.output {
        OutputSchema::Columns(cols) => run
            .rows
            .iter()
            .map(|row| {
                let cols = columns_of(row, cols);
                row_obj(&row.label, &cols)
            })
            .collect(),
        OutputSchema::Series(axis) => run
            .rows
            .iter()
            .map(|row| series_obj(&row.label, axis.key(), &series_of(run, row, *axis)))
            .collect(),
        OutputSchema::Long => run
            .rows
            .iter()
            .flat_map(|row| (0..run.configs.len()).map(move |i| long_obj(run, row, i)))
            .collect(),
    };
    JsonValue::obj(vec![
        ("figure", JsonValue::str(spec.artifact_name())),
        ("rows", JsonValue::Arr(rows)),
    ])
}

/// Print the run as aligned `name | row | col value | …` lines, plus a
/// geomean summary for speedup-bearing schemas (the paper averages over
/// workloads geometrically). Rows go through the leveled logger: the
/// default (`Info`) output is byte-identical to the historic prints,
/// `--quiet` suppresses them.
pub fn print_rows(spec: &ExperimentSpec, run: &SpecRun) {
    let name = spec.artifact_name();
    match &spec.output {
        OutputSchema::Columns(cols) => {
            for row in &run.rows {
                let rendered: Vec<String> = columns_of(row, cols)
                    .iter()
                    .map(|(k, v)| format!("{k} {v:.3}"))
                    .collect();
                crate::log_info!("{name} | {:<12} | {}", row.label, rendered.join(" | "));
            }
        }
        OutputSchema::Series(axis) => {
            for row in &run.rows {
                let rendered: Vec<String> = series_of(run, row, *axis)
                    .iter()
                    .map(|(x, s)| format!("{x}:{s:.3}"))
                    .collect();
                crate::log_info!("{name} | {:<12} | {}", row.label, rendered.join(" | "));
            }
        }
        OutputSchema::Long => {
            for row in &run.rows {
                for (i, cp) in run.configs.iter().enumerate() {
                    let rep = &row.reports[i];
                    crate::log_info!(
                        "{name} | {:<12} | {:<24} | cycles {:>12.0} | avg_lat {:>8.1} | \
                         cov {:.3} | speedup {:.3}",
                        row.label,
                        cp.label,
                        rep.cycles(),
                        rep.avg_latency(),
                        rep.cov(),
                        extract(Extract::Speedup { cfg: i }, row),
                    );
                }
            }
        }
    }
    // The paper-comparison aggregates (declared per spec, like everything
    // else about a figure).
    for s in &spec.summaries {
        let value = match s.agg {
            Agg::Geomean => {
                format!("{:.3}", geomean(run.rows.iter().map(|r| extract(s.of, r))))
            }
            Agg::MeanPct => {
                let sum: f64 = run.rows.iter().map(|r| extract(s.of, r)).sum();
                format!("{:.1}%", sum / run.rows.len().max(1) as f64 * 100.0)
            }
            Agg::SumRatioPct { vs } => {
                let a: f64 = run.rows.iter().map(|r| extract(s.of, r)).sum();
                let b: f64 = run.rows.iter().map(|r| extract(vs, r)).sum();
                format!("{:+.0}%", (a / b - 1.0) * 100.0)
            }
        };
        if s.paper.is_empty() {
            crate::log_info!("{name} | {} = {value}", s.label);
        } else {
            crate::log_info!("{name} | {} = {value} (paper: {})", s.label, s.paper);
        }
    }
}

/// CSV rendering for the bench shims (`target/figures/<name>.csv`):
/// header + one line per row (Columns), per series point (Series), or
/// per point (Long).
pub fn render_csv(spec: &ExperimentSpec, run: &SpecRun) -> Vec<String> {
    let mut lines = Vec::new();
    match &spec.output {
        OutputSchema::Columns(cols) => {
            let header: Vec<&str> = cols.iter().map(|c| c.name).collect();
            lines.push(format!("workload,{}", header.join(",")));
            for row in &run.rows {
                let vals: Vec<String> = columns_of(row, cols)
                    .iter()
                    .map(|(_, v)| format!("{v:.4}"))
                    .collect();
                lines.push(format!("{},{}", row.label, vals.join(",")));
            }
        }
        OutputSchema::Series(axis) => {
            lines.push(format!("workload,{},speedup", axis.key()));
            for row in &run.rows {
                for (x, s) in series_of(run, row, *axis) {
                    lines.push(format!("{},{x},{s:.4}", row.label));
                }
            }
        }
        OutputSchema::Long => {
            lines.push(
                "workload,config,policy,mem,topology,table_entries,count_threshold,\
                 epoch_cycles,cycles,avg_latency,cov,bytes_per_cycle,speedup"
                    .to_string(),
            );
            for row in &run.rows {
                for (i, cp) in run.configs.iter().enumerate() {
                    let rep = &row.reports[i];
                    lines.push(format!(
                        "{},{},{},{},{},{},{},{},{:.0},{:.4},{:.4},{:.4},{:.4}",
                        row.label,
                        cp.label,
                        cp.policy.as_str(),
                        cp.cfg.mem.as_str(),
                        cp.cfg.topology.as_str(),
                        cp.cfg.sub_table_entries(),
                        cp.cfg.count_threshold,
                        cp.cfg.epoch_cycles,
                        rep.cycles(),
                        rep.avg_latency(),
                        rep.cov(),
                        rep.bytes_per_cycle(),
                        extract(Extract::Speedup { cfg: i }, row),
                    ));
                }
            }
        }
    }
    lines
}

/// Geometric mean over positive values (the paper's workload averages).
pub fn geomean(xs: impl Iterator<Item = f64>) -> f64 {
    let (mut logsum, mut n) = (0.0, 0usize);
    for x in xs {
        if x > 0.0 {
            logsum += x.ln();
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        (logsum / n as f64).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::coordinator::report::{RunReport, SimReport};
    use crate::exp::spec::{ConfigPoint, SeriesAxis};
    use crate::policy::PolicyKind;
    use crate::stats::SimStats;

    fn report(cycles: u64) -> SimReport {
        SimReport {
            workload: "t".into(),
            policy: "never",
            runs: vec![RunReport {
                cycles,
                stats: SimStats::new(4),
                decisions: vec![],
                exhausted: false,
            }],
        }
    }

    fn point(label: &str, policy: PolicyKind) -> ConfigPoint {
        let mut cfg = SimConfig::hmc();
        cfg.policy = policy;
        ConfigPoint {
            label: label.into(),
            policy,
            table_entries: None,
            threshold: Some(4),
            epoch: None,
            is_baseline: false,
            cfg,
        }
    }

    fn fake_run() -> SpecRun {
        SpecRun {
            configs: vec![point("never", PolicyKind::Never), point("always", PolicyKind::Always)],
            rows: vec![RowResult {
                label: "STRAdd".into(),
                tenants: None,
                trace: None,
                reports: vec![report(2000), report(1000)],
            }],
            from_cache: 0,
            simulated: 2,
        }
    }

    #[test]
    fn columns_render_matches_legacy_row_shape() {
        let mut spec = ExperimentSpec::adhoc("figXX");
        spec.output = OutputSchema::Columns(vec![Column::new(
            "speedup",
            Extract::Speedup { cfg: 1 },
        )]);
        let json = render_json(&spec, &fake_run());
        assert_eq!(
            json.render(),
            r#"{"figure":"figXX","rows":[{"workload":"STRAdd","speedup":2}]}"#
        );
    }

    #[test]
    fn series_render_matches_legacy_shape() {
        let mut spec = ExperimentSpec::adhoc("figYY");
        spec.output = OutputSchema::Series(SeriesAxis::Threshold);
        let json = render_json(&spec, &fake_run());
        assert_eq!(
            json.render(),
            r#"{"figure":"figYY","rows":[{"workload":"STRAdd","series":[{"threshold":"4","speedup":2}]}]}"#
        );
    }

    #[test]
    fn long_rows_carry_axis_coordinates() {
        let spec = ExperimentSpec::adhoc("sweepZZ");
        let json = render_json(&spec, &fake_run()).render();
        assert!(json.contains("\"config\":\"always\""), "{json}");
        assert!(json.contains("\"policy\":\"always\""), "{json}");
        assert!(json.contains("\"speedup\":2"), "{json}");
    }

    #[test]
    fn csv_headers_per_schema() {
        let run = fake_run();
        let mut spec = ExperimentSpec::adhoc("s");
        spec.output = OutputSchema::Series(SeriesAxis::Threshold);
        assert_eq!(render_csv(&spec, &run)[0], "workload,threshold,speedup");
        spec.output = OutputSchema::Long;
        assert!(render_csv(&spec, &run)[0].starts_with("workload,config,policy"));
    }

    #[test]
    fn geomean_of_constants() {
        assert!((geomean([2.0, 2.0, 2.0].into_iter()) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_ignores_nonpositive() {
        assert!((geomean([4.0, 0.0, -1.0].into_iter()) - 4.0).abs() < 1e-12);
    }
}

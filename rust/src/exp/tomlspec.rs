//! Parse an ad-hoc [`ExperimentSpec`] from a TOML-subset file (`repro
//! sweep --spec my.toml`) or from CLI flags — arbitrary new scenarios
//! (ring-topology threshold sweeps over traced multi-tenant mixes, …)
//! without touching Rust.
//!
//! The file format is the same `key = value` TOML subset the config
//! parser reads ([`crate::config::parse::KvFile`]): `#` comments,
//! last-assignment-wins, quoted values allowed. Schema (all keys
//! optional unless noted):
//!
//! ```text
//! name          = ring-threshold-mix     # artifact stem (default "sweep")
//! title         = free text
//! memory        = hmc | hbm
//! topology      = mesh | crossbar | ring # default: preset topology
//! workloads     = all | selected | CSV of Table III short names
//! policies      = CSV of never|always|adaptive|adaptive-hops|adaptive-latency
//! baseline      = true | false           # prepend a default-knob baseline
//! table_entries = CSV of u32             # subscription-table size axis
//! thresholds    = CSV of u32             # count-threshold axis
//! epochs        = CSV of u64             # epoch-length axis
//! trace         = FILE.dlpt              # replay one recorded trace
//! trace_mix     = CSV of short names     # record tenants + mix them
//! mixes         = label:k[,label:k...]   # scenarios over trace_mix
//! warmup        = u64                    # scale overrides
//! measure       = u64
//! runs          = u32
//! seed          = u64
//! ```
//!
//! `trace` and `trace_mix` are mutually exclusive; the output schema of
//! an ad-hoc sweep is always the long form (one JSON row per point with
//! full axis coordinates).

use super::spec::{ExperimentSpec, MixScenario, ScaleOverride, TraceSource, WorkloadSet};
use crate::cli::{suggest, Cli};
use crate::config::parse::KvFile;
use crate::config::{MemKind, Topology};
use crate::policy::PolicyKind;

/// Every key the spec file understands (typos get a did-you-mean).
const KNOWN_KEYS: &[&str] = &[
    "name", "title", "memory", "topology", "workloads", "policies", "baseline",
    "table_entries", "thresholds", "epochs", "trace", "trace_mix", "mixes", "warmup",
    "measure", "runs", "seed",
];

/// Parse a spec file's text.
pub fn from_text(text: &str) -> Result<ExperimentSpec, String> {
    let kv = KvFile::parse(text).map_err(|(l, m)| format!("line {l}: {m}"))?;
    for key in kv.keys() {
        if !KNOWN_KEYS.contains(&key) {
            let hint = match suggest(key, KNOWN_KEYS.iter().copied()) {
                Some(s) => format!("; did you mean {s:?}?"),
                None => String::new(),
            };
            return Err(format!("unknown spec key {key:?}{hint}"));
        }
    }
    build(|key| kv.get(key).map(|v| v.to_string()))
}

/// Build a spec from `repro sweep` CLI flags (`--policies a,b`, …).
/// Flag names use dashes where the file uses underscores.
pub fn from_cli(cli: &Cli) -> Result<ExperimentSpec, String> {
    build(|key| cli.flag(&key.replace('_', "-")).map(|v| v.to_string()))
}

/// Assemble + validate from a key lookup (file or flags).
fn build(get: impl Fn(&str) -> Option<String>) -> Result<ExperimentSpec, String> {
    let mut spec = ExperimentSpec::adhoc(get("name").unwrap_or_else(|| "sweep".into()));
    if let Some(t) = get("title") {
        spec.title = t;
    }
    if let Some(m) = get("memory") {
        spec.mem = match m.as_str() {
            "hmc" => MemKind::Hmc,
            "hbm" => MemKind::Hbm,
            _ => return Err(format!("unknown memory {m:?} (hmc|hbm)")),
        };
    }
    if let Some(t) = get("topology") {
        spec.topology = Some(
            Topology::parse(&t).ok_or(format!("unknown topology {t:?} (mesh|crossbar|ring)"))?,
        );
    }
    if let Some(w) = get("workloads") {
        spec.workloads = match w.as_str() {
            "all" => WorkloadSet::All,
            "selected" => WorkloadSet::Selected,
            list => WorkloadSet::Named(csv(list)),
        };
    }
    if let Some(p) = get("policies") {
        spec.policies = csv(&p)
            .iter()
            .map(|s| {
                PolicyKind::parse(s).ok_or(format!(
                    "unknown policy {s:?} (never|always|adaptive|adaptive-hops|adaptive-latency)"
                ))
            })
            .collect::<Result<_, _>>()?;
    }
    if let Some(b) = get("baseline") {
        spec.baseline = parse_bool("baseline", &b)?;
    }
    if let Some(v) = get("table_entries") {
        spec.table_entries = csv_nums("table_entries", &v)?;
    }
    if let Some(v) = get("thresholds") {
        spec.thresholds = csv_nums("thresholds", &v)?;
    }
    if let Some(v) = get("epochs") {
        spec.epochs = csv_nums("epochs", &v)?;
    }
    spec.scale = ScaleOverride {
        warmup: opt_num("warmup", &get)?,
        measure: opt_num("measure", &get)?,
        runs: opt_num("runs", &get)?,
        seed: opt_num("seed", &get)?,
    };

    let workloads_given = get("workloads").is_some();
    match (get("trace"), get("trace_mix")) {
        (Some(_), Some(_)) => {
            return Err(
                "trace and trace_mix are conflicting traffic sources; pick one".into(),
            )
        }
        // A trace source replaces the workload row axis entirely — a
        // spec naming both would silently drop the workloads, so reject.
        (Some(_), None) | (None, Some(_)) if workloads_given => {
            return Err(
                "workloads conflicts with trace/trace_mix (a trace defines the row \
                 axis); drop one"
                    .into(),
            )
        }
        (Some(path), None) => spec.trace = TraceSource::File(path),
        (None, Some(tenants)) => {
            let tenants = csv(&tenants);
            let mixes = match get("mixes") {
                Some(m) => parse_mixes(&m)?,
                // Default: one scenario mixing every tenant.
                None => vec![MixScenario {
                    label: format!("mix{}", tenants.len()),
                    tenants: tenants.len(),
                }],
            };
            spec.trace = TraceSource::TenantMixes { tenants, mixes };
        }
        (None, None) => {
            if get("mixes").is_some() {
                return Err("mixes requires trace_mix (the tenants to record)".into());
            }
        }
    }

    // An ad-hoc sweep writing `fig11.json` would silently clobber a
    // registry figure's artifact in the shared artifact directory.
    if super::registry::by_figure(&spec.name).is_some() {
        return Err(format!(
            "name {:?} collides with a registry figure artifact; pick another name",
            spec.name
        ));
    }

    // Surface axis errors now, with the file/flag context, instead of at
    // run time.
    spec.expand()?;
    spec.row_labels()?;
    Ok(spec)
}

fn csv(s: &str) -> Vec<String> {
    s.split(',').map(|x| x.trim().to_string()).filter(|x| !x.is_empty()).collect()
}

fn csv_nums<T: std::str::FromStr>(key: &str, s: &str) -> Result<Vec<T>, String> {
    csv(s)
        .iter()
        .map(|x| {
            x.replace('_', "")
                .parse::<T>()
                .map_err(|_| format!("{key}: bad numeric value {x:?}"))
        })
        .collect()
}

fn opt_num<T: std::str::FromStr>(
    key: &str,
    get: &impl Fn(&str) -> Option<String>,
) -> Result<Option<T>, String> {
    match get(key) {
        None => Ok(None),
        Some(v) => v
            .replace('_', "")
            .parse::<T>()
            .map(Some)
            .map_err(|_| format!("{key}: bad numeric value {v:?}")),
    }
}

fn parse_bool(key: &str, v: &str) -> Result<bool, String> {
    match v {
        "true" => Ok(true),
        "false" => Ok(false),
        _ => Err(format!("{key} expects true|false, got {v:?}")),
    }
}

/// `label:k[,label:k...]`
fn parse_mixes(s: &str) -> Result<Vec<MixScenario>, String> {
    csv(s)
        .iter()
        .map(|part| {
            let (label, k) = part
                .split_once(':')
                .ok_or(format!("mixes expects label:k entries, got {part:?}"))?;
            let tenants = k
                .trim()
                .parse::<usize>()
                .map_err(|_| format!("mixes: bad tenant count in {part:?}"))?;
            Ok(MixScenario { label: label.trim().to_string(), tenants })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_spec_file() {
        let spec = from_text(
            "# ad-hoc sweep\n\
             name = ring-thr\n\
             memory = hmc\n\
             topology = ring\n\
             policies = never, always, adaptive\n\
             thresholds = 0, 4\n\
             trace_mix = SPLRad,PHELinReg,CHABsBez,PLYgemm\n\
             mixes = mix4:4\n\
             warmup = 1_000\n\
             measure = 5000\n",
        )
        .unwrap();
        assert_eq!(spec.name, "ring-thr");
        assert_eq!(spec.topology, Some(Topology::Ring));
        assert_eq!(spec.policies.len(), 3);
        assert_eq!(spec.thresholds, vec![0, 4]);
        assert_eq!(spec.scale.warmup, Some(1000));
        match &spec.trace {
            TraceSource::TenantMixes { tenants, mixes } => {
                assert_eq!(tenants.len(), 4);
                assert_eq!(mixes[0].label, "mix4");
                assert_eq!(mixes[0].tenants, 4);
            }
            other => panic!("{other:?}"),
        }
        // 1 mix row x (3 policies x 2 thresholds) configs.
        assert_eq!(spec.point_count().unwrap(), 6);
    }

    #[test]
    fn unknown_key_gets_suggestion() {
        let err = from_text("policees = always\n").unwrap_err();
        assert!(err.contains("policees") && err.contains("policies"), "{err}");
    }

    #[test]
    fn trace_and_mix_conflict() {
        let err = from_text("trace = a.dlpt\ntrace_mix = SPLRad,PLYgemm\n").unwrap_err();
        assert!(err.contains("conflicting"), "{err}");
    }

    #[test]
    fn registry_artifact_names_are_reserved() {
        for name in ["fig01", "fig19", "11"] {
            let err = from_text(&format!("name = {name}\n")).unwrap_err();
            assert!(err.contains("collides"), "{name}: {err}");
        }
    }

    #[test]
    fn workloads_conflict_with_trace_sources() {
        // The trace defines the row axis; silently dropping a named
        // workload list would be the silent-shadowing failure mode this
        // parser exists to prevent.
        let err = from_text("workloads = SPLRad\ntrace_mix = SPLRad,PLYgemm\n").unwrap_err();
        assert!(err.contains("workloads"), "{err}");
        let err = from_text("workloads = SPLRad\ntrace = a.dlpt\n").unwrap_err();
        assert!(err.contains("workloads"), "{err}");
    }

    #[test]
    fn axis_errors_surface_at_parse_time() {
        let err = from_text("epochs = 0\n").unwrap_err();
        assert!(err.contains("epoch"), "{err}");
        let err = from_text("workloads = SPLRod\n").unwrap_err();
        assert!(err.contains("SPLRad"), "{err}");
    }

    #[test]
    fn default_mix_covers_all_tenants() {
        let spec = from_text("trace_mix = SPLRad,PLYgemm\n").unwrap();
        match &spec.trace {
            TraceSource::TenantMixes { mixes, .. } => {
                assert_eq!(mixes[0].label, "mix2");
                assert_eq!(mixes[0].tenants, 2);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn from_cli_mirrors_file_keys() {
        let args: Vec<String> = [
            "sweep",
            "--name",
            "cli-sweep",
            "--policies",
            "never,adaptive",
            "--workloads",
            "STRAdd,STRCpy",
            "--table-entries",
            "1024,4096",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let cli = Cli::parse(&args).unwrap();
        let spec = from_cli(&cli).unwrap();
        assert_eq!(spec.name, "cli-sweep");
        assert_eq!(spec.table_entries, vec![1024, 4096]);
        assert_eq!(spec.point_count().unwrap(), 2 * 4);
    }

    #[test]
    fn defaults_are_sane() {
        let spec = from_text("").unwrap();
        assert_eq!(spec.name, "sweep");
        assert_eq!(spec.mem, MemKind::Hmc);
        assert!(spec.point_count().unwrap() > 0);
    }
}

//! The declarative experiment specification: every evaluation in this
//! repo — paper figure, bench target, ad-hoc CLI sweep — is one
//! [`ExperimentSpec`] value describing *axes* (workload set, policy set,
//! topology, memory preset, knob overrides, trace source) and an *output
//! schema* (how sweep reports become rows/series/values). Running a spec
//! is generic ([`super::run_spec`]); adding a scenario is adding data.
//!
//! Expansion is a pure cartesian product over the config axes:
//!
//! ```text
//! configs = [baseline?] ++ policies × table_entries × thresholds × epochs
//! points  = workloads (or trace scenarios) × configs
//! ```
//!
//! Every expanded config passes [`SimConfig::validate`]; an invalid
//! combination is rejected at expansion time with the offending axis
//! value in the error message. Expansion is deterministic and
//! duplicate-free (pinned by the `exp_spec_props` property tests), so
//! sweep-engine cache keys are a pure function of the spec.

use crate::config::{MemKind, SimConfig, Topology};
use crate::policy::PolicyKind;
use crate::workloads::catalog;

/// Scale knobs, overridable from the environment:
/// `REPRO_WARMUP` / `REPRO_MEASURE` / `REPRO_RUNS` / `REPRO_EPOCH`, plus
/// `REPRO_TOPOLOGY` to force one interconnect across the whole suite
/// (the CI smoke job's topology axis).
pub fn scaled(mut cfg: SimConfig) -> SimConfig {
    use crate::config::env;
    if let Some(v) = env::warmup_requests() {
        cfg.warmup_requests = v;
    }
    if let Some(v) = env::measure_requests() {
        cfg.measure_requests = v;
    }
    if let Some(v) = env::runs() {
        cfg.runs = v as u32;
    }
    if let Some(v) = env::epoch_cycles() {
        cfg.epoch_cycles = v;
    }
    if let Some(t) = env::topology() {
        cfg.topology = t;
    }
    cfg
}

/// Base config for a memory kind with a policy, at harness scale.
pub fn cfg_for(mem: MemKind, policy: PolicyKind) -> SimConfig {
    let mut cfg = match mem {
        MemKind::Hmc => SimConfig::hmc(),
        MemKind::Hbm => SimConfig::hbm(),
    };
    cfg.policy = policy;
    scaled(cfg)
}

/// Which workloads a spec sweeps (the row axis for generator-driven
/// specs; trace-driven specs derive their rows from [`TraceSource`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WorkloadSet {
    /// All 31 Table III workloads.
    All,
    /// The paper's non-negligible-reuse subset (Figs 11/12/14).
    Selected,
    /// An explicit list of Table III short names.
    Named(Vec<String>),
}

/// Where a spec's memory traffic comes from.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TraceSource {
    /// The named Table III generators (the [`WorkloadSet`] axis).
    Generators,
    /// Every point replays one recorded `.dlpt` trace file.
    File(String),
    /// Multi-tenant scenarios: record each tenant's baseline traffic,
    /// compose k-tenant mixes, sweep the mixes (Fig 19's shape).
    TenantMixes {
        /// Table III short names recorded as tenant baselines.
        tenants: Vec<String>,
        /// Scenarios: each mixes the first `tenants` recordings.
        mixes: Vec<MixScenario>,
    },
}

/// One multi-tenant scenario of a [`TraceSource::TenantMixes`] spec.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MixScenario {
    /// Scenario label (also the mixed trace's file stem).
    pub label: String,
    /// How many of the spec's tenants participate (a prefix).
    pub tenants: usize,
}

/// Explicit scale overrides, applied after the environment knobs.
/// Registry figures leave these unset (the `REPRO_*` env contract);
/// ad-hoc specs and the golden tests pin scale explicitly.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ScaleOverride {
    pub warmup: Option<u64>,
    pub measure: Option<u64>,
    pub runs: Option<u32>,
    pub seed: Option<u64>,
}

impl ScaleOverride {
    fn apply(&self, cfg: &mut SimConfig) {
        if let Some(v) = self.warmup {
            cfg.warmup_requests = v;
        }
        if let Some(v) = self.measure {
            cfg.measure_requests = v;
        }
        if let Some(v) = self.runs {
            cfg.runs = v;
        }
        if let Some(v) = self.seed {
            cfg.seed = v;
        }
    }
}

/// How a spec's sweep reports become its JSON artifact (and printed
/// rows). The vocabulary is small and closed: every figure of the paper
/// is expressible, and the renderer guarantees the exact artifact bytes
/// the pre-registry harness emitted.
#[derive(Clone, Debug, PartialEq)]
pub enum OutputSchema {
    /// One row per workload: named value columns extracted from the
    /// row's per-config reports.
    Columns(Vec<Column>),
    /// One row per workload holding a series over configs `1..` (the
    /// sweep-axis figures 16/17/18): each series point is the config's
    /// axis label and its speedup vs config 0.
    Series(SeriesAxis),
    /// One row per (workload × config) point carrying the full axis
    /// coordinates and the standard metric set — the ad-hoc `repro
    /// sweep` long form.
    Long,
}

/// One named output column of an [`OutputSchema::Columns`] spec.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Column {
    pub name: &'static str,
    pub extract: Extract,
}

impl Column {
    pub fn new(name: &'static str, extract: Extract) -> Self {
        Column { name, extract }
    }
}

/// A value extractor over one row's per-config reports.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Extract {
    /// A raw metric of config `cfg`'s report.
    Metric { cfg: usize, metric: Metric },
    /// Speedup of config `cfg` vs config 0 (`cycles0 / cycles`).
    Speedup { cfg: usize },
    /// Memory-latency improvement of config `cfg` vs config 0.
    LatencyImprovement { cfg: usize },
    /// The scenario's tenant count (multi-tenant trace rows only).
    Tenants,
}

/// Raw report metrics the output schema can name.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    AvgLatency,
    Cov,
    BytesPerCycle,
    NetworkFraction,
    QueueFraction,
    /// Interconnect-link share of the queue fraction (the
    /// `latency-breakdown` telemetry row splits `QueueFraction` into
    /// this plus [`Metric::QueueMemFraction`]).
    QueueNetFraction,
    /// Vault controller/bank share of the queue fraction.
    QueueMemFraction,
    ArrayFraction,
    /// Network + queue latency fractions — the paper's "remote access
    /// overhead" headline of Figs 1/2.
    RemoteOverhead,
    ReuseLocal,
    ReuseRemote,
}

/// A cross-row aggregate printed after the rows (the paper-comparison
/// lines: geomean speedups, average improvements, traffic increases).
/// Print-only — never part of the JSON artifact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Summary {
    /// Printed label, e.g. `GEOMEAN speedup`.
    pub label: &'static str,
    pub agg: Agg,
    pub of: Extract,
    /// The paper's value for the at-a-glance comparison (empty to omit).
    pub paper: &'static str,
}

impl Summary {
    pub fn new(label: &'static str, agg: Agg, of: Extract, paper: &'static str) -> Self {
        Summary { label, agg, of, paper }
    }
}

/// How a [`Summary`] aggregates its extractor over rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Agg {
    /// Geometric mean over rows.
    Geomean,
    /// Arithmetic mean over rows, printed as a percentage.
    MeanPct,
    /// `sum(of) / sum(vs) - 1`, printed as a signed percentage (Fig 14's
    /// average traffic increase).
    SumRatioPct { vs: Extract },
}

/// Which config axis labels the x-values of an [`OutputSchema::Series`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeriesAxis {
    TableEntries,
    Threshold,
    Policy,
}

impl SeriesAxis {
    /// The JSON key of a series point's x-value.
    pub fn key(self) -> &'static str {
        match self {
            SeriesAxis::TableEntries => "entries",
            SeriesAxis::Threshold => "threshold",
            SeriesAxis::Policy => "policy",
        }
    }

    /// The x-label of one expanded config.
    pub fn label(self, point: &ConfigPoint) -> String {
        match self {
            SeriesAxis::TableEntries => {
                point.table_entries.expect("entries axis config").to_string()
            }
            SeriesAxis::Threshold => point.threshold.expect("threshold axis config").to_string(),
            SeriesAxis::Policy => point.policy.as_str().to_string(),
        }
    }
}

/// A declarative experiment: axes + output schema. See the module docs
/// for the expansion rule; [`super::registry`] holds every paper figure
/// as one of these values.
#[derive(Clone, Debug)]
pub struct ExperimentSpec {
    /// Registry/artifact name (`fig11`, or an ad-hoc sweep's name).
    pub name: String,
    /// Paper figure number (`"11"`) when this spec is a figure.
    pub figure: Option<String>,
    /// One-line description (shown by `repro figure --list`).
    pub title: String,
    /// Memory preset the configs start from.
    pub mem: MemKind,
    /// Explicit interconnect override; `None` keeps the preset default
    /// (and the `REPRO_TOPOLOGY` environment override).
    pub topology: Option<Topology>,
    /// Row axis for generator-driven specs.
    pub workloads: WorkloadSet,
    /// Prepend a default-knob never-subscribe baseline as config 0 (the
    /// speedup denominator of knob-sweep figures).
    pub baseline: bool,
    /// Policy axis (must be non-empty).
    pub policies: Vec<PolicyKind>,
    /// Subscription-table size axis (total entries/vault); empty keeps
    /// the preset geometry.
    pub table_entries: Vec<u32>,
    /// Count-threshold axis; empty keeps the preset threshold.
    pub thresholds: Vec<u32>,
    /// Epoch-length axis (cycles); empty keeps the preset epoch.
    pub epochs: Vec<u64>,
    /// Traffic source.
    pub trace: TraceSource,
    /// Explicit scale overrides (applied last).
    pub scale: ScaleOverride,
    /// How results render.
    pub output: OutputSchema,
    /// Paper-comparison aggregate lines printed after the rows.
    pub summaries: Vec<Summary>,
}

/// One expanded config of a spec, with its axis coordinates.
#[derive(Clone, Debug)]
pub struct ConfigPoint {
    /// Short label: `baseline`, `adaptive`, `always thr=4`, …
    pub label: String,
    pub policy: PolicyKind,
    /// Table-entries axis value, when that axis is active.
    pub table_entries: Option<u32>,
    /// Threshold axis value, when that axis is active.
    pub threshold: Option<u32>,
    /// Epoch axis value, when that axis is active.
    pub epoch: Option<u64>,
    /// True for the prepended baseline config.
    pub is_baseline: bool,
    /// The fully resolved simulation config.
    pub cfg: SimConfig,
}

impl ExperimentSpec {
    /// A minimal ad-hoc spec: HMC, all workloads, baseline-vs-adaptive,
    /// long-form output. The TOML/CLI parsers start from this.
    pub fn adhoc(name: impl Into<String>) -> Self {
        ExperimentSpec {
            name: name.into(),
            figure: None,
            title: String::new(),
            mem: MemKind::Hmc,
            topology: None,
            workloads: WorkloadSet::All,
            baseline: false,
            policies: vec![PolicyKind::Never, PolicyKind::Adaptive],
            table_entries: Vec::new(),
            thresholds: Vec::new(),
            epochs: Vec::new(),
            trace: TraceSource::Generators,
            scale: ScaleOverride::default(),
            output: OutputSchema::Long,
            summaries: Vec::new(),
        }
    }

    /// The artifact file stem this spec writes (`<name>.json`).
    pub fn artifact_name(&self) -> &str {
        &self.name
    }

    /// Resolve the row labels (workload short names, a trace file's
    /// label, or the mix scenario labels), validating names against the
    /// Table III catalog with a did-you-mean.
    pub fn row_labels(&self) -> Result<Vec<String>, String> {
        match &self.trace {
            TraceSource::Generators => {
                let names: Vec<String> = match &self.workloads {
                    WorkloadSet::All => {
                        catalog::ALL_NAMES.iter().map(|s| s.to_string()).collect()
                    }
                    WorkloadSet::Selected => {
                        catalog::SELECTED.iter().map(|s| s.to_string()).collect()
                    }
                    WorkloadSet::Named(v) => {
                        for n in v {
                            check_workload(n)?;
                        }
                        v.clone()
                    }
                };
                if names.is_empty() {
                    return Err("workloads axis must not be empty".into());
                }
                no_dupes("workloads", names.iter())?;
                Ok(names)
            }
            TraceSource::File(path) => {
                let stem = std::path::Path::new(path)
                    .file_stem()
                    .and_then(|s| s.to_str())
                    .unwrap_or("trace");
                Ok(vec![stem.to_string()])
            }
            TraceSource::TenantMixes { tenants, mixes } => {
                if tenants.len() < 2 {
                    return Err(format!(
                        "trace mix needs at least 2 tenants, got {}",
                        tenants.len()
                    ));
                }
                for t in tenants {
                    check_workload(t)?;
                }
                no_dupes("tenants", tenants.iter())?;
                if mixes.is_empty() {
                    return Err("trace mix needs at least one scenario".into());
                }
                for m in mixes {
                    check_file_stem("mix label", &m.label)?;
                    if m.tenants < 2 || m.tenants > tenants.len() {
                        return Err(format!(
                            "mix {:?} wants {} tenants but the spec records {} \
                             (each mix takes a 2..=len prefix)",
                            m.label,
                            m.tenants,
                            tenants.len()
                        ));
                    }
                }
                no_dupes("mixes", mixes.iter().map(|m| &m.label))?;
                Ok(mixes.iter().map(|m| m.label.clone()).collect())
            }
        }
    }

    /// The baseline config (config 0 when [`Self::baseline`], and the
    /// recording config of a [`TraceSource::TenantMixes`] spec): the
    /// memory preset under never-subscribe with default knobs.
    pub fn base_cfg(&self) -> SimConfig {
        let mut cfg = cfg_for(self.mem, PolicyKind::Never);
        if let Some(t) = self.topology {
            cfg.topology = t;
        }
        self.scale.apply(&mut cfg);
        cfg
    }

    /// Expand the config axes into the full cartesian product. Errors
    /// name the offending axis value (invalid combination, duplicate,
    /// empty axis).
    pub fn expand(&self) -> Result<Vec<ConfigPoint>, String> {
        check_file_stem("spec name", &self.name)?;
        if self.policies.is_empty() {
            return Err("policies axis must not be empty".into());
        }
        no_dupes("policies", self.policies.iter().map(|p| p.as_str()))?;
        no_dupes("table_entries", self.table_entries.iter())?;
        no_dupes("thresholds", self.thresholds.iter())?;
        no_dupes("epochs", self.epochs.iter())?;

        let ways = self.base_cfg().sub_table_ways as u32;
        for &e in &self.table_entries {
            if e == 0 || e % ways != 0 {
                return Err(format!(
                    "table_entries={e}: must be a positive multiple of the \
                     {ways}-way associativity"
                ));
            }
        }

        let mut out = Vec::new();
        if self.baseline {
            let cfg = self.base_cfg();
            cfg.validate()
                .map_err(|errs| format!("invalid baseline config: {}", errs.join("; ")))?;
            out.push(ConfigPoint {
                label: "baseline".into(),
                policy: PolicyKind::Never,
                table_entries: None,
                threshold: None,
                epoch: None,
                is_baseline: true,
                cfg,
            });
        }

        // Cartesian product, policy-major, each optional axis defaulting
        // to a single "preset" value.
        let entries_axis: Vec<Option<u32>> = axis_or_default(&self.table_entries);
        let thr_axis: Vec<Option<u32>> = axis_or_default(&self.thresholds);
        let epoch_axis: Vec<Option<u64>> = axis_or_default(&self.epochs);
        for &policy in &self.policies {
            for &entries in &entries_axis {
                for &threshold in &thr_axis {
                    for &epoch in &epoch_axis {
                        let mut cfg = cfg_for(self.mem, policy);
                        if let Some(t) = self.topology {
                            cfg.topology = t;
                        }
                        if let Some(e) = entries {
                            cfg.sub_table_sets = (e / cfg.sub_table_ways as u32).max(1);
                        }
                        if let Some(t) = threshold {
                            cfg.count_threshold = t;
                        }
                        if let Some(e) = epoch {
                            cfg.epoch_cycles = e;
                        }
                        self.scale.apply(&mut cfg);
                        let label = point_label(policy, entries, threshold, epoch);
                        cfg.validate().map_err(|errs| {
                            format!("invalid config at axis point {label}: {}", errs.join("; "))
                        })?;
                        out.push(ConfigPoint {
                            label,
                            policy,
                            table_entries: entries,
                            threshold,
                            epoch,
                            is_baseline: false,
                            cfg,
                        });
                    }
                }
            }
        }

        // Duplicate-free across the whole expansion (e.g. `baseline`
        // plus an overlapping default-knob `never` axis point).
        let mut seen = std::collections::BTreeSet::new();
        for p in &out {
            if !seen.insert(crate::config::presets::render(&p.cfg)) {
                return Err(format!(
                    "duplicate expanded config at axis point {} (baseline and a \
                     default-knob `never` axis point coincide?)",
                    p.label
                ));
            }
        }
        self.check_output_refs(out.len())?;
        Ok(out)
    }

    /// Fail fast (here, not after an hours-long sweep) when the output
    /// schema or a summary references a config index the expansion does
    /// not produce, or a series axis that is not active.
    fn check_output_refs(&self, n_configs: usize) -> Result<(), String> {
        fn cfg_of(ex: Extract) -> usize {
            match ex {
                Extract::Metric { cfg, .. }
                | Extract::Speedup { cfg }
                | Extract::LatencyImprovement { cfg } => cfg,
                Extract::Tenants => 0,
            }
        }
        let mut max_ref = 0usize;
        match &self.output {
            OutputSchema::Columns(cols) => {
                for c in cols {
                    max_ref = max_ref.max(cfg_of(c.extract));
                }
            }
            OutputSchema::Series(axis) => {
                if n_configs < 2 {
                    return Err(format!(
                        "series output needs at least 2 configs (config 0 is the \
                         speedup denominator), spec expands to {n_configs}"
                    ));
                }
                let active = match axis {
                    SeriesAxis::TableEntries => !self.table_entries.is_empty(),
                    SeriesAxis::Threshold => !self.thresholds.is_empty(),
                    SeriesAxis::Policy => true,
                };
                if !active {
                    return Err(format!(
                        "series axis {axis:?} has no values in this spec"
                    ));
                }
            }
            OutputSchema::Long => {}
        }
        for s in &self.summaries {
            max_ref = max_ref.max(cfg_of(s.of));
            if let Agg::SumRatioPct { vs } = s.agg {
                max_ref = max_ref.max(cfg_of(vs));
            }
        }
        if max_ref >= n_configs {
            return Err(format!(
                "output schema references config {max_ref} but the spec expands \
                 to only {n_configs} configs"
            ));
        }
        Ok(())
    }

    /// Total sweep points this spec expands to (rows × configs).
    pub fn point_count(&self) -> Result<usize, String> {
        Ok(self.row_labels()?.len() * self.expand()?.len())
    }

    /// Compact one-line axes summary (`repro figure --list`).
    pub fn axes_summary(&self) -> String {
        let workloads = match &self.trace {
            TraceSource::Generators => match &self.workloads {
                WorkloadSet::All => "all".to_string(),
                WorkloadSet::Selected => "selected".to_string(),
                WorkloadSet::Named(v) => format!("{} named", v.len()),
            },
            TraceSource::File(p) => format!("trace {p}"),
            TraceSource::TenantMixes { tenants, mixes } => {
                format!("{} tenants, {} mixes", tenants.len(), mixes.len())
            }
        };
        let mut parts = vec![
            format!("mem={}", self.mem.as_str()),
            format!(
                "topology={}",
                self.topology.map_or("preset", |t| t.as_str())
            ),
            format!("workloads={workloads}"),
            format!(
                "policies={}",
                self.policies.iter().map(|p| p.as_str()).collect::<Vec<_>>().join("/")
            ),
        ];
        if self.baseline {
            parts.insert(3, "baseline".to_string());
        }
        if !self.table_entries.is_empty() {
            parts.push(format!("entries={:?}", self.table_entries));
        }
        if !self.thresholds.is_empty() {
            parts.push(format!("thresholds={:?}", self.thresholds));
        }
        if !self.epochs.is_empty() {
            parts.push(format!("epochs={:?}", self.epochs));
        }
        parts.join(" ")
    }
}

/// Names that become file stems (the spec/artifact name, mix scenario
/// labels) must not smuggle path components: `name = ../../x` would
/// write outside the artifact directory.
fn check_file_stem(kind: &str, s: &str) -> Result<(), String> {
    let ok = !s.is_empty()
        && !s.starts_with('.')
        && s.chars().all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.'));
    if ok {
        Ok(())
    } else {
        Err(format!(
            "{kind} {s:?} names a file: use only [A-Za-z0-9._-], not starting with '.'"
        ))
    }
}

/// Validate one Table III short name, suggesting the nearest on a miss.
fn check_workload(name: &str) -> Result<(), String> {
    if catalog::ALL_NAMES.contains(&name) {
        return Ok(());
    }
    let hint = match crate::cli::suggest(name, catalog::ALL_NAMES.iter().copied()) {
        Some(s) => format!("; did you mean {s:?}?"),
        None => String::new(),
    };
    Err(format!("unknown workload {name:?} in workload axis{hint}"))
}

/// An optional axis: explicit values, or one "keep the preset" slot.
fn axis_or_default<T: Copy>(values: &[T]) -> Vec<Option<T>> {
    if values.is_empty() {
        vec![None]
    } else {
        values.iter().map(|&v| Some(v)).collect()
    }
}

fn no_dupes<T: std::fmt::Debug + PartialEq>(
    axis: &str,
    values: impl Iterator<Item = T>,
) -> Result<(), String> {
    let mut seen: Vec<T> = Vec::new();
    for v in values {
        if seen.contains(&v) {
            return Err(format!("duplicate {axis} axis value {v:?}"));
        }
        seen.push(v);
    }
    Ok(())
}

fn point_label(
    policy: PolicyKind,
    entries: Option<u32>,
    threshold: Option<u32>,
    epoch: Option<u64>,
) -> String {
    let mut label = policy.as_str().to_string();
    if let Some(e) = entries {
        label.push_str(&format!(" entries={e}"));
    }
    if let Some(t) = threshold {
        label.push_str(&format!(" thr={t}"));
    }
    if let Some(e) = epoch {
        label.push_str(&format!(" epoch={e}"));
    }
    label
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cfg_for_sets_policy_and_mem() {
        let c = cfg_for(MemKind::Hbm, PolicyKind::Adaptive);
        assert_eq!(c.mem, MemKind::Hbm);
        assert_eq!(c.policy, PolicyKind::Adaptive);
    }

    #[test]
    fn baseline_plus_axis_expansion_order() {
        let mut spec = ExperimentSpec::adhoc("t");
        spec.baseline = true;
        spec.policies = vec![PolicyKind::Adaptive];
        spec.table_entries = vec![1024, 2048];
        let pts = spec.expand().unwrap();
        assert_eq!(pts.len(), 3);
        assert!(pts[0].is_baseline);
        assert_eq!(pts[0].policy, PolicyKind::Never);
        assert_eq!(pts[1].table_entries, Some(1024));
        assert_eq!(pts[2].table_entries, Some(2048));
        assert_eq!(pts[1].cfg.sub_table_sets, 1024 / 4);
        assert_eq!(pts[2].cfg.sub_table_entries(), 2048);
    }

    #[test]
    fn empty_policy_axis_rejected() {
        let mut spec = ExperimentSpec::adhoc("t");
        spec.policies = Vec::new();
        assert!(spec.expand().unwrap_err().contains("policies"));
    }

    #[test]
    fn duplicate_axis_value_rejected() {
        let mut spec = ExperimentSpec::adhoc("t");
        spec.policies = vec![PolicyKind::Never, PolicyKind::Never];
        assert!(spec.expand().unwrap_err().contains("duplicate"));
    }

    #[test]
    fn invalid_epoch_axis_names_offender() {
        let mut spec = ExperimentSpec::adhoc("t");
        spec.epochs = vec![0];
        let err = spec.expand().unwrap_err();
        assert!(err.contains("epoch=0"), "{err}");
        assert!(err.contains("epoch_cycles"), "{err}");
    }

    #[test]
    fn bad_table_entries_named() {
        let mut spec = ExperimentSpec::adhoc("t");
        spec.table_entries = vec![7];
        let err = spec.expand().unwrap_err();
        assert!(err.contains("table_entries=7"), "{err}");
    }

    #[test]
    fn unknown_workload_gets_suggestion() {
        let mut spec = ExperimentSpec::adhoc("t");
        spec.workloads = WorkloadSet::Named(vec!["SPLRod".into()]);
        let err = spec.row_labels().unwrap_err();
        assert!(err.contains("SPLRod") && err.contains("SPLRad"), "{err}");
    }

    #[test]
    fn mix_scenarios_validated() {
        let mut spec = ExperimentSpec::adhoc("t");
        spec.trace = TraceSource::TenantMixes {
            tenants: vec!["SPLRad".into(), "PLYgemm".into()],
            mixes: vec![MixScenario { label: "mix9".into(), tenants: 9 }],
        };
        let err = spec.row_labels().unwrap_err();
        assert!(err.contains("mix9"), "{err}");
    }

    #[test]
    fn output_refs_validated_at_expansion_time() {
        let mut spec = ExperimentSpec::adhoc("t");
        spec.policies = vec![PolicyKind::Never, PolicyKind::Adaptive];
        spec.output =
            OutputSchema::Columns(vec![Column::new("x", Extract::Speedup { cfg: 2 })]);
        let err = spec.expand().unwrap_err();
        assert!(err.contains("config 2"), "{err}");

        // A series over an axis the spec never sweeps.
        spec.output = OutputSchema::Series(SeriesAxis::TableEntries);
        let err = spec.expand().unwrap_err();
        assert!(err.contains("series axis"), "{err}");

        // Summaries are checked too.
        spec.output = OutputSchema::Long;
        spec.summaries =
            vec![Summary::new("g", Agg::Geomean, Extract::Speedup { cfg: 9 }, "")];
        let err = spec.expand().unwrap_err();
        assert!(err.contains("config 9"), "{err}");
    }

    #[test]
    fn path_smuggling_names_rejected() {
        let mut spec = ExperimentSpec::adhoc("../../etc-x");
        assert!(spec.expand().unwrap_err().contains("spec name"), "traversal");
        spec.name = "ok-name".into();
        spec.trace = TraceSource::TenantMixes {
            tenants: vec!["SPLRad".into(), "PLYgemm".into()],
            mixes: vec![MixScenario { label: "../evil".into(), tenants: 2 }],
        };
        assert!(spec.row_labels().unwrap_err().contains("mix label"));
    }

    #[test]
    fn series_axis_labels() {
        let mut spec = ExperimentSpec::adhoc("t");
        spec.policies = vec![PolicyKind::Always];
        spec.thresholds = vec![4];
        let pts = spec.expand().unwrap();
        assert_eq!(SeriesAxis::Threshold.label(&pts[0]), "4");
        assert_eq!(SeriesAxis::Policy.label(&pts[0]), "always");
    }
}

//! The declarative experiment engine: figures, benches and ad-hoc sweeps
//! are *data* over one spec registry.
//!
//! DL-PIM's whole evaluation has one shape — a sweep over `workload ×
//! policy × memory-kind × knob` rendered as per-figure artifacts. This
//! module encodes that shape once:
//!
//! * [`spec`] — [`ExperimentSpec`]: the axes (workload set, policy set,
//!   topology, memory preset, table-size/threshold/epoch overrides,
//!   trace source) plus an output schema naming the series/group/value
//!   extractors; cartesian-product expansion into sweep points.
//! * [`registry`] — every figure of the paper (1–19) as a pure data
//!   entry. `repro figure`, `repro all-figures`, the bench shims and the
//!   CI smoke matrix all enumerate this table.
//! * [`run`] — the one generic execution path through the parallel sweep
//!   engine (report-cache keys unchanged for unchanged configs),
//!   including the record-and-mix preparation of multi-tenant trace
//!   scenarios.
//! * [`output`] — renders a completed run as the figure's JSON artifact
//!   (byte-identical to the pre-registry harness), printed rows, and the
//!   bench CSV.
//! * [`tomlspec`] — `repro sweep`: parse an ad-hoc spec from a TOML
//!   file or CLI flags, so new scenarios cost a table row, not Rust.
//!
//! ```no_run
//! use dlpim::exp;
//!
//! // A paper figure is a registry lookup:
//! let spec = exp::registry::by_figure("11").unwrap();
//! let run = exp::run_spec(&spec).unwrap();
//! exp::print_rows(&spec, &run);
//! exp::emit_artifact(&spec, &run).unwrap();
//!
//! // A novel scenario is data, not code:
//! let spec = exp::tomlspec::from_text(
//!     "name = ring-thr\n\
//!      topology = ring\n\
//!      policies = never,adaptive\n\
//!      thresholds = 0,4\n\
//!      trace_mix = SPLRad,PHELinReg,CHABsBez,PLYgemm\n",
//! )
//! .unwrap();
//! let run = exp::run_spec(&spec).unwrap();
//! ```

pub mod output;
pub mod registry;
pub mod run;
pub mod spec;
pub mod tomlspec;

pub use output::{geomean, print_rows, render_csv, render_json};
pub use run::{
    emit_artifact, run_spec, run_spec_checked, run_spec_sharded, RowResult, SpecFailure, SpecRun,
};
pub use spec::{cfg_for, scaled, ExperimentSpec, OutputSchema, TraceSource, WorkloadSet};

use std::path::PathBuf;

/// The one run → print → (CSV) → artifact pipeline shared by the bench
/// shims, `repro figure`/`all-figures` and `repro sweep`. Prints the
/// rows, the declared paper-comparison summaries and the artifact path;
/// writes `target/figures/<name>.csv` when `write_csv` is set (the bench
/// plotting contract).
pub fn run_and_emit(spec: &ExperimentSpec, write_csv: bool) -> Result<PathBuf, String> {
    let run = match run_spec_checked(spec) {
        Ok(run) => run,
        Err(fail) => {
            // A failed spec still prints its accounting line — with the
            // panic count — and emits *no* artifact: a partial figure
            // JSON would silently poison downstream plots, so the caller
            // gets an error (and the CLI a non-zero exit) instead.
            crate::log_info!(
                "{} | points {} | cached {} | simulated {} | panicked {}",
                spec.artifact_name(),
                fail.from_cache + fail.simulated + fail.panicked,
                fail.from_cache,
                fail.simulated,
                fail.panicked
            );
            return Err(fail.joined());
        }
    };
    let _render = crate::obs::span(&crate::obs::SPAN_RENDER_NS);
    print_rows(spec, &run);
    // The warm-rerun contract (asserted by CI's cold-vs-warm check): a
    // fully cached figure prints `simulated 0` and scheduled no jobs.
    crate::log_info!(
        "{} | points {} | cached {} | simulated {}",
        spec.artifact_name(),
        run.from_cache + run.simulated,
        run.from_cache,
        run.simulated
    );
    if write_csv {
        let csv = render_csv(spec, &run).join("\n") + "\n";
        let path = format!("target/figures/{}.csv", spec.artifact_name());
        if let Some(dir) = std::path::Path::new(&path).parent() {
            std::fs::create_dir_all(dir)
                .map_err(|e| format!("create {}: {e}", dir.display()))?;
        }
        std::fs::write(&path, csv).map_err(|e| format!("write {path}: {e}"))?;
    }
    let artifact = emit_artifact(spec, &run)?;
    crate::log_info!("{} | artifact: {}", spec.artifact_name(), artifact.display());
    Ok(artifact)
}

/// [`run_and_emit`] through the shard claim protocol: run the spec
/// cooperatively on `runner`, print the worker's accounting (how many
/// points it found present, claimed fresh, reclaimed from stale leases),
/// and render the artifact from the shared store. Every worker renders
/// once its view of the grid is complete; the writes are atomic and the
/// bytes interleaving-independent, so concurrent renders are benign and
/// the last-to-finish worker always leaves a complete artifact behind.
pub fn run_and_emit_sharded(
    spec: &ExperimentSpec,
    runner: &crate::sweep::shard::ShardRunner,
) -> Result<PathBuf, String> {
    let (run, outcome) = run_spec_sharded(spec, runner)?;
    let _render = crate::obs::span(&crate::obs::SPAN_RENDER_NS);
    print_rows(spec, &run);
    crate::log_info!(
        "{} | points {} | present {} | claimed {} | reclaimed {}",
        spec.artifact_name(),
        outcome.present + outcome.simulated(),
        outcome.present,
        outcome.claimed,
        outcome.reclaimed
    );
    let artifact = emit_artifact(spec, &run)?;
    crate::log_info!("{} | artifact: {}", spec.artifact_name(), artifact.display());
    Ok(artifact)
}

/// Bench-shim entry point: [`run_and_emit`] on a registry spec, with a
/// wallclock line. Panics on failure — a bench with a silently missing
/// figure is worse than a loud one.
pub fn run_named_figure(name: &str) -> PathBuf {
    // lint:allow(D2) -- wallclock for the human progress line only; the
    // artifact bytes are produced before the elapsed time is read.
    let t0 = std::time::Instant::now();
    let spec = registry::by_figure(name)
        .unwrap_or_else(|| panic!("no spec named {name:?} in the figure registry"));
    let artifact = run_and_emit(&spec, true).unwrap_or_else(|e| panic!("{e}"));
    crate::log_info!("{} | wallclock {:.1}s", spec.artifact_name(), t0.elapsed().as_secs_f64());
    artifact
}

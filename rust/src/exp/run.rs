//! The one generic execution path behind every spec: expand the axes,
//! prepare the traffic source (recording + mixing tenant traces when the
//! spec asks for them), run all points on the parallel sweep engine, and
//! hand the per-row reports to the output renderer.
//!
//! Cache behaviour is identical to the pre-registry harness: points go
//! through [`crate::sweep::SweepPoint`] unchanged, so the report-cache
//! key of an unchanged expanded config is unchanged, and figure targets
//! sharing points (every HMC figure reuses the baseline runs) still
//! compute each point once per process.

use std::path::PathBuf;

use super::spec::{ConfigPoint, ExperimentSpec, TraceSource};
use crate::coordinator::report::SimReport;
use crate::sweep::{self, Sweep, SweepPoint};
use crate::trace::{self, TraceData, TraceMeta};

/// One row (workload or trace scenario) of a completed spec run.
#[derive(Clone, Debug)]
pub struct RowResult {
    /// Workload short name, trace label, or mix scenario label.
    pub label: String,
    /// Tenant count for multi-tenant scenario rows.
    pub tenants: Option<usize>,
    /// The trace file this row replayed, if any.
    pub trace: Option<String>,
    /// One report per expanded config, in config order.
    pub reports: Vec<SimReport>,
}

/// A completed spec run: the expanded configs and every row's reports.
#[derive(Clone, Debug)]
pub struct SpecRun {
    pub configs: Vec<ConfigPoint>,
    pub rows: Vec<RowResult>,
    /// Points satisfied by the report cache (memory or disk) without
    /// scheduling a simulation job.
    pub from_cache: usize,
    /// Points that actually simulated (a fully warm rerun reports 0).
    pub simulated: usize,
}

/// A row to simulate: its label and optional trace file.
struct Row {
    label: String,
    tenants: Option<usize>,
    trace: Option<String>,
}

/// Aggregated failure of a spec run: *every* failed point's labelled
/// message, plus the accounting of the points that did complete, so the
/// caller can report how much of the figure survived before refusing to
/// emit a partial artifact.
#[derive(Clone, Debug)]
pub struct SpecFailure {
    /// Points satisfied by the report cache before anything failed.
    pub from_cache: usize,
    /// Points that simulated to completion.
    pub simulated: usize,
    /// Points whose job panicked inside the sweep engine (the sweep's
    /// `catch_unwind` converts both build errors and simulator panics
    /// into per-job failures rather than tearing down the process).
    pub panicked: usize,
    /// One labelled message per failed point. Spec-level failures
    /// (axis expansion, trace preparation) produce a single message with
    /// zero panic accounting.
    pub messages: Vec<String>,
}

impl SpecFailure {
    fn spec_level(msg: String) -> Self {
        SpecFailure { from_cache: 0, simulated: 0, panicked: 0, messages: vec![msg] }
    }

    /// All failure messages as one `; `-joined string (the legacy
    /// [`run_spec`] error shape).
    pub fn joined(&self) -> String {
        self.messages.join("; ")
    }
}

/// Run a spec end-to-end on the sweep engine. Errors carry the failing
/// axis value, workload or trace step. Kept as the `String`-error shape
/// most callers want; [`run_spec_checked`] exposes the per-point panic
/// accounting behind it.
pub fn run_spec(spec: &ExperimentSpec) -> Result<SpecRun, String> {
    run_spec_checked(spec).map_err(|f| f.joined())
}

/// [`run_spec`] with aggregated failure accounting: instead of stopping
/// at the first failed point, runs the whole grid and reports *all*
/// failures plus how many points were cached / simulated / panicked.
pub fn run_spec_checked(spec: &ExperimentSpec) -> Result<SpecRun, SpecFailure> {
    let (configs, rows) = {
        let _t = crate::obs::span(&crate::obs::SPAN_SPEC_EXPAND_NS);
        let configs = spec.expand().map_err(SpecFailure::spec_level)?;
        let rows = prepare_rows(spec).map_err(SpecFailure::spec_level)?;
        (configs, rows)
    };

    let mut points = Vec::with_capacity(rows.len() * configs.len());
    for row in &rows {
        for cp in &configs {
            let mut cfg = cp.cfg.clone();
            if let Some(t) = &row.trace {
                cfg.trace = Some(t.clone());
            }
            points.push(SweepPoint::new(row.label.clone(), cfg));
        }
    }
    let mut outcomes = Sweep::new(points).run().into_iter();

    let mut results = Vec::with_capacity(rows.len());
    let (mut from_cache, mut simulated, mut panicked) = (0usize, 0usize, 0usize);
    let mut messages = Vec::new();
    for row in rows {
        let mut reports: Vec<SimReport> = Vec::with_capacity(configs.len());
        for cp in &configs {
            let outcome = outcomes.next().expect("one outcome per point");
            match outcome.result {
                Ok(rep) => {
                    if outcome.from_cache {
                        from_cache += 1;
                    } else {
                        simulated += 1;
                    }
                    reports.push(rep);
                }
                Err(e) => {
                    // Every sweep-level failure is a caught panic: the
                    // job wrapper converts build errors into panics and
                    // `catch_unwind` converts panics into this arm.
                    panicked += 1;
                    messages.push(format!(
                        "{}: job ({} x {}) failed: {e}",
                        spec.name, row.label, cp.label
                    ));
                }
            }
        }
        results.push(RowResult {
            label: row.label,
            tenants: row.tenants,
            trace: row.trace,
            reports,
        });
    }
    if messages.is_empty() {
        Ok(SpecRun { configs, rows: results, from_cache, simulated })
    } else {
        Err(SpecFailure { from_cache, simulated, panicked, messages })
    }
}

/// Run a spec cooperatively with other worker processes through the
/// shard claim protocol (see [`crate::sweep::shard`]): the expansion is
/// identical to [`run_spec_checked`] — same configs, same rows, same
/// point order — but instead of scheduling jobs on the in-process sweep
/// engine, every point is claimed / simulated / flushed to the shared
/// disk store by whichever worker gets there first. After the grid is
/// complete this worker reads every report back from the store **in
/// expansion order**, so the assembled run (and therefore the artifact
/// bytes) cannot depend on which worker simulated which point.
pub fn run_spec_sharded(
    spec: &ExperimentSpec,
    runner: &sweep::shard::ShardRunner,
) -> Result<(SpecRun, sweep::shard::ShardOutcome), String> {
    let (configs, rows) = {
        let _t = crate::obs::span(&crate::obs::SPAN_SPEC_EXPAND_NS);
        let configs = spec.expand()?;
        let rows = prepare_rows(spec)?;
        (configs, rows)
    };

    let mut points = Vec::with_capacity(rows.len() * configs.len());
    for row in &rows {
        for cp in &configs {
            let mut cfg = cp.cfg.clone();
            if let Some(t) = &row.trace {
                cfg.trace = Some(t.clone());
            }
            points.push(SweepPoint::new(row.label.clone(), cfg));
        }
    }
    let outcome = runner.run(&points)?;

    // Read back in expansion order. A vanished report means someone
    // cleared the store between completion and render — fail loudly
    // rather than emit a partial figure.
    let mut reports = points.iter().map(|p| {
        runner.store().load(p.key()).ok_or_else(|| {
            format!(
                "{}: report for {} ({:016x}) vanished from the store after completion",
                spec.name,
                p.workload,
                p.key()
            )
        })
    });
    let mut results = Vec::with_capacity(rows.len());
    for row in rows {
        let row_reports = (&mut reports)
            .take(configs.len())
            .collect::<Result<Vec<SimReport>, String>>()?;
        results.push(RowResult {
            label: row.label,
            tenants: row.tenants,
            trace: row.trace,
            reports: row_reports,
        });
    }
    let run = SpecRun {
        configs,
        rows: results,
        from_cache: outcome.present,
        simulated: outcome.simulated(),
    };
    Ok((run, outcome))
}

/// Resolve the row axis, materializing trace files where needed.
fn prepare_rows(spec: &ExperimentSpec) -> Result<Vec<Row>, String> {
    let labels = spec.row_labels()?;
    match &spec.trace {
        TraceSource::Generators => Ok(labels
            .into_iter()
            .map(|label| Row { label, tenants: None, trace: None })
            .collect()),
        TraceSource::File(path) => {
            // Fail early with a labelled error instead of poisoning every
            // sweep job on the same unreadable file.
            TraceData::load(std::path::Path::new(path))?;
            Ok(labels
                .into_iter()
                .map(|label| Row { label, tenants: None, trace: Some(path.clone()) })
                .collect())
        }
        TraceSource::TenantMixes { tenants, mixes } => {
            let dir = trace_dir();
            std::fs::create_dir_all(&dir)
                .map_err(|e| format!("create trace dir {}: {e}", dir.display()))?;
            // Record every tenant's baseline traffic under the spec's
            // base config (never-subscribe, default knobs). Recording is
            // itself a simulation, so a warm rerun skips it when the
            // on-disk trace already matches what this config would record
            // — the header carries the recording config's hash and seed,
            // and recording is deterministic. The header cannot see
            // *generator code* changes, though, so reuse is additionally
            // gated on a build-fingerprint sidecar (`<name>.dlpt.src`):
            // a trace recorded by a different simulator build re-records,
            // exactly like a stale report-store entry recomputes.
            let rec_cfg = spec.base_cfg();
            let data: Vec<TraceData> = tenants
                .iter()
                .map(|name| {
                    let path = dir.join(format!("{name}.dlpt"));
                    let stamp = dir.join(format!("{name}.dlpt.src"));
                    let same_build = std::fs::read_to_string(&stamp)
                        .map(|s| s.trim() == sweep::store::build_fingerprint())
                        .unwrap_or(false);
                    if same_build {
                        let want = TraceMeta::for_recording(name, &rec_cfg);
                        if let Ok(existing) = TraceData::load(&path) {
                            if existing.meta == want {
                                return Ok(existing);
                            }
                        }
                    }
                    trace::record_run(&rec_cfg, name, &path)
                        .map_err(|e| format!("record tenant {name}: {e}"))?;
                    // Best-effort: a missing stamp only costs a re-record.
                    let _ = sweep::store::write_atomic(
                        &stamp,
                        sweep::store::build_fingerprint().as_bytes(),
                    );
                    TraceData::load(&path)
                })
                .collect::<Result<_, String>>()?;
            mixes
                .iter()
                .map(|m| {
                    let mixed =
                        trace::transform::mix(&data[..m.tenants], &vec![1; m.tenants], rec_cfg.n_vaults)
                            .map_err(|e| format!("{}: {e}", m.label))?;
                    let path = dir.join(format!("{}.dlpt", m.label));
                    mixed.save(&path).map_err(|e| format!("{}: {e}", m.label))?;
                    Ok(Row {
                        label: m.label.clone(),
                        tenants: Some(m.tenants),
                        trace: Some(path.to_string_lossy().into_owned()),
                    })
                })
                .collect()
        }
    }
}

/// Where recorded/mixed tenant traces land (uploaded by CI alongside the
/// figure JSON).
pub fn trace_dir() -> PathBuf {
    sweep::artifact::artifact_dir().join("traces")
}

/// Render a completed run and write `<artifact_dir>/<name>.json`.
pub fn emit_artifact(spec: &ExperimentSpec, run: &SpecRun) -> Result<PathBuf, String> {
    let value = super::output::render_json(spec, run);
    sweep::artifact::write_figure_json(spec.artifact_name(), &value)
        .map_err(|e| format!("write artifact {}: {e}", spec.artifact_name()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MemKind;
    use crate::exp::spec::{OutputSchema, ScaleOverride, WorkloadSet};
    use crate::policy::PolicyKind;

    fn tiny(name: &str) -> ExperimentSpec {
        let mut spec = ExperimentSpec::adhoc(name);
        spec.mem = MemKind::Hmc;
        spec.workloads = WorkloadSet::Named(vec!["STRAdd".into(), "STRCpy".into()]);
        spec.policies = vec![PolicyKind::Never, PolicyKind::Always];
        spec.scale = ScaleOverride {
            warmup: Some(100),
            measure: Some(800),
            runs: Some(1),
            seed: None,
        };
        spec.output = OutputSchema::Long;
        spec
    }

    #[test]
    fn run_spec_shape_matches_expansion() {
        let spec = tiny("unit-sweep");
        let run = run_spec(&spec).unwrap();
        assert_eq!(run.configs.len(), 2);
        assert_eq!(run.rows.len(), 2);
        assert_eq!(run.rows[0].label, "STRAdd");
        assert_eq!(run.rows[0].reports.len(), 2);
        assert_eq!(run.rows[1].reports[1].workload, "STRCpy");
        // Every point is accounted either to the cache or to a job
        // (which bucket depends on what earlier runs left in the store).
        assert_eq!(run.from_cache + run.simulated, 4);
    }

    #[test]
    fn run_spec_reports_failures_with_labels() {
        let mut spec = tiny("unit-sweep-bad");
        // Bypass row_labels validation to force a sweep-level failure.
        spec.workloads = WorkloadSet::Named(vec!["STRAdd".into()]);
        spec.trace = crate::exp::spec::TraceSource::File("/nonexistent/x.dlpt".into());
        let err = run_spec(&spec).unwrap_err();
        assert!(err.contains("x.dlpt") || err.contains("No such file"), "{err}");
    }

    #[test]
    fn spec_level_failures_carry_no_panic_accounting() {
        let mut spec = tiny("unit-sweep-bad-checked");
        spec.workloads = WorkloadSet::Named(vec!["STRAdd".into()]);
        spec.trace = crate::exp::spec::TraceSource::File("/nonexistent/x.dlpt".into());
        let fail = run_spec_checked(&spec).unwrap_err();
        assert_eq!(fail.panicked, 0, "prepare failure is not a job panic");
        assert_eq!(fail.from_cache + fail.simulated, 0);
        assert_eq!(fail.messages.len(), 1);
        assert_eq!(fail.joined(), fail.messages[0]);
    }
}

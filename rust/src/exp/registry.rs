//! The figure registry: every figure of the paper's evaluation is one
//! [`ExperimentSpec`] data entry. Adding a figure is adding a table row
//! here — no imperative harness code, no new bench binary logic, no CLI
//! dispatch arm, no CI list edit (CI derives its matrix from
//! `repro figure --list`, which enumerates this table).
//!
//! The output schemas below are pinned byte-for-byte against the
//! pre-registry harness by the `golden_artifacts` integration test.

use super::spec::{
    Agg, Column, ExperimentSpec, Extract, Metric, MixScenario, OutputSchema, ScaleOverride,
    SeriesAxis, Summary, TraceSource, WorkloadSet,
};
use crate::config::MemKind;
use crate::policy::PolicyKind;

/// Fig 16's table-sensitive workloads.
pub const FIG16_WORKLOADS: [&str; 4] = ["PLYDoitgen", "PHELinReg", "SPLRad", "CHABsBez"];

/// Fig 19's tenant workloads, chosen for clashing home-vault footprints:
/// two single-hot-vault tile reusers, one multi-lane reuser, one
/// shared-panel thrasher.
pub const FIG19_TENANTS: [&str; 4] = ["SPLRad", "PHELinReg", "CHABsBez", "PLYgemm"];

fn named(names: &[&str]) -> WorkloadSet {
    WorkloadSet::Named(names.iter().map(|s| s.to_string()).collect())
}

/// The skeleton every figure entry starts from.
fn figure(id: &str, title: &str, mem: MemKind) -> ExperimentSpec {
    ExperimentSpec {
        name: format!("fig{id:0>2}"),
        figure: Some(id.to_string()),
        title: title.to_string(),
        mem,
        topology: None,
        workloads: WorkloadSet::All,
        baseline: false,
        policies: vec![PolicyKind::Never],
        table_entries: Vec::new(),
        thresholds: Vec::new(),
        epochs: Vec::new(),
        trace: TraceSource::Generators,
        scale: ScaleOverride::default(),
        output: OutputSchema::Long,
        summaries: Vec::new(),
    }
}

fn metric(cfg: usize, metric: Metric) -> Extract {
    Extract::Metric { cfg, metric }
}

/// Figs 1/2: latency breakdown per workload under the baseline.
fn breakdown(id: &str, mem: MemKind, paper_overhead: &'static str) -> ExperimentSpec {
    let mut s = figure(id, &format!("latency breakdown ({})", mem.as_str()), mem);
    s.output = OutputSchema::Columns(vec![
        Column::new("network", metric(0, Metric::NetworkFraction)),
        Column::new("queue", metric(0, Metric::QueueFraction)),
        Column::new("array", metric(0, Metric::ArrayFraction)),
        Column::new("avg_latency", metric(0, Metric::AvgLatency)),
    ]);
    s.summaries = vec![Summary::new(
        "AVG remote overhead (network+queue)",
        Agg::MeanPct,
        metric(0, Metric::RemoteOverhead),
        paper_overhead,
    )];
    s
}

/// Figs 3/4: baseline CoV of per-vault demand.
fn cov(id: &str, mem: MemKind) -> ExperimentSpec {
    let mut s = figure(id, &format!("CoV of per-vault demand ({})", mem.as_str()), mem);
    s.output = OutputSchema::Columns(vec![Column::new("cov", metric(0, Metric::Cov))]);
    s
}

/// Every figure of the evaluation, in figure order.
pub fn figures() -> Vec<ExperimentSpec> {
    let mut specs = vec![
        breakdown("1", MemKind::Hmc, "~53%"),
        breakdown("2", MemKind::Hbm, "~43%"),
        cov("3", MemKind::Hmc),
        cov("4", MemKind::Hbm),
    ];

    // Fig 9: always-subscribe speedup over baseline, all 31 workloads.
    let mut f9 = figure("9", "always-subscribe speedup (HMC)", MemKind::Hmc);
    f9.policies = vec![PolicyKind::Never, PolicyKind::Always];
    f9.output = OutputSchema::Columns(vec![
        Column::new("speedup", Extract::Speedup { cfg: 1 }),
        Column::new("latency_improvement", Extract::LatencyImprovement { cfg: 1 }),
    ]);
    f9.summaries = vec![Summary::new(
        "GEOMEAN speedup",
        Agg::Geomean,
        Extract::Speedup { cfg: 1 },
        "~1.06",
    )];
    specs.push(f9);

    // Fig 10: reuse per subscription under always-subscribe.
    let mut f10 = figure("10", "reuse per subscription under always-subscribe", MemKind::Hmc);
    f10.policies = vec![PolicyKind::Always];
    f10.output = OutputSchema::Columns(vec![
        Column::new("local", metric(0, Metric::ReuseLocal)),
        Column::new("remote", metric(0, Metric::ReuseRemote)),
    ]);
    specs.push(f10);

    // Fig 11: always vs adaptive on the non-negligible-reuse workloads.
    let mut f11 = figure("11", "always vs adaptive on reuse workloads (HMC)", MemKind::Hmc);
    f11.workloads = WorkloadSet::Selected;
    f11.policies = vec![PolicyKind::Never, PolicyKind::Always, PolicyKind::Adaptive];
    f11.output = OutputSchema::Columns(vec![
        Column::new("always", Extract::Speedup { cfg: 1 }),
        Column::new("adaptive", Extract::Speedup { cfg: 2 }),
        Column::new("latency_improvement", Extract::LatencyImprovement { cfg: 2 }),
    ]);
    f11.summaries = vec![
        Summary::new("GEOMEAN always", Agg::Geomean, Extract::Speedup { cfg: 1 }, "~1.14"),
        Summary::new("GEOMEAN adaptive", Agg::Geomean, Extract::Speedup { cfg: 2 }, "~1.15"),
        Summary::new(
            "AVG latency improvement",
            Agg::MeanPct,
            Extract::LatencyImprovement { cfg: 2 },
            "~54%",
        ),
    ];
    specs.push(f11);

    // Fig 12 (HMC, incl. always) / Fig 13 (HBM): CoV by policy.
    let mut f12 = figure("12", "CoV by policy (hmc)", MemKind::Hmc);
    f12.workloads = WorkloadSet::Selected;
    f12.policies = vec![PolicyKind::Never, PolicyKind::Always, PolicyKind::Adaptive];
    f12.output = OutputSchema::Columns(vec![
        Column::new("baseline", metric(0, Metric::Cov)),
        Column::new("always", metric(1, Metric::Cov)),
        Column::new("adaptive", metric(2, Metric::Cov)),
    ]);
    specs.push(f12);

    let mut f13 = figure("13", "CoV by policy (hbm)", MemKind::Hbm);
    f13.workloads = WorkloadSet::Selected;
    f13.policies = vec![PolicyKind::Never, PolicyKind::Adaptive];
    f13.output = OutputSchema::Columns(vec![
        Column::new("baseline", metric(0, Metric::Cov)),
        Column::new("adaptive", metric(1, Metric::Cov)),
    ]);
    specs.push(f13);

    // Fig 14: network traffic under baseline / always / adaptive.
    let mut f14 = figure("14", "network traffic (B/cycle)", MemKind::Hmc);
    f14.workloads = WorkloadSet::Selected;
    f14.policies = vec![PolicyKind::Never, PolicyKind::Always, PolicyKind::Adaptive];
    f14.output = OutputSchema::Columns(vec![
        Column::new("baseline", metric(0, Metric::BytesPerCycle)),
        Column::new("always", metric(1, Metric::BytesPerCycle)),
        Column::new("adaptive", metric(2, Metric::BytesPerCycle)),
    ]);
    f14.summaries = vec![
        Summary::new(
            "AVG traffic increase (always)",
            Agg::SumRatioPct { vs: metric(0, Metric::BytesPerCycle) },
            metric(1, Metric::BytesPerCycle),
            "+88%",
        ),
        Summary::new(
            "AVG traffic increase (adaptive)",
            Agg::SumRatioPct { vs: metric(0, Metric::BytesPerCycle) },
            metric(2, Metric::BytesPerCycle),
            "+14%",
        ),
    ];
    specs.push(f14);

    // Fig 15: HBM latency baseline vs adaptive, all 31 workloads.
    let mut f15 = figure("15", "HBM latency baseline vs adaptive", MemKind::Hbm);
    f15.policies = vec![PolicyKind::Never, PolicyKind::Adaptive];
    f15.output = OutputSchema::Columns(vec![
        Column::new("base_latency", metric(0, Metric::AvgLatency)),
        Column::new("adaptive_latency", metric(1, Metric::AvgLatency)),
        Column::new("speedup", Extract::Speedup { cfg: 1 }),
    ]);
    f15.summaries = vec![
        Summary::new(
            "AVG latency improvement",
            Agg::MeanPct,
            Extract::LatencyImprovement { cfg: 1 },
            "~50%",
        ),
        Summary::new("GEOMEAN speedup", Agg::Geomean, Extract::Speedup { cfg: 1 }, "~1.03"),
    ];
    specs.push(f15);

    // Fig 16: adaptive speedup vs subscription-table size.
    let mut f16 = figure("16", "adaptive speedup vs subscription-table entries", MemKind::Hmc);
    f16.workloads = named(&FIG16_WORKLOADS);
    f16.baseline = true;
    f16.policies = vec![PolicyKind::Adaptive];
    f16.table_entries = crate::config::presets::TABLE_SIZE_SWEEP.to_vec();
    f16.output = OutputSchema::Series(SeriesAxis::TableEntries);
    specs.push(f16);

    // Fig 17 (ablation): count-threshold filter under always-subscribe.
    let mut f17 = figure("17", "count-threshold filter ablation (always-subscribe)", MemKind::Hmc);
    f17.workloads = named(&["SPLRad", "PHELinReg", "PLYgemm", "HSJNPO"]);
    f17.baseline = true;
    f17.policies = vec![PolicyKind::Always];
    f17.thresholds = vec![0, 1, 4, 16];
    f17.output = OutputSchema::Series(SeriesAxis::Threshold);
    specs.push(f17);

    // Fig 18 (ablation): adaptive-policy variants.
    let mut f18 = figure("18", "adaptive-policy variant ablation", MemKind::Hmc);
    f18.workloads = named(&["SPLRad", "PHELinReg", "PLYgemm", "PLY3mm", "STRTriad"]);
    f18.baseline = true;
    f18.policies = vec![
        PolicyKind::Always,
        PolicyKind::AdaptiveHops,
        PolicyKind::AdaptiveLatency,
        PolicyKind::Adaptive,
    ];
    f18.output = OutputSchema::Series(SeriesAxis::Policy);
    specs.push(f18);

    // Fig 19 (extension): adaptive DL-PIM under multi-tenant trace mixes.
    let mut f19 = figure("19", "adaptive DL-PIM under multi-tenant trace mixes", MemKind::Hmc);
    f19.policies = vec![PolicyKind::Never, PolicyKind::Always, PolicyKind::Adaptive];
    f19.trace = TraceSource::TenantMixes {
        tenants: FIG19_TENANTS.iter().map(|s| s.to_string()).collect(),
        mixes: vec![
            MixScenario { label: "mix2".into(), tenants: 2 },
            MixScenario { label: "mix4".into(), tenants: 4 },
        ],
    };
    f19.output = OutputSchema::Columns(vec![
        Column::new("tenants", Extract::Tenants),
        Column::new("always", Extract::Speedup { cfg: 1 }),
        Column::new("adaptive", Extract::Speedup { cfg: 2 }),
        Column::new("latency_improvement", Extract::LatencyImprovement { cfg: 2 }),
        Column::new("base_cov", metric(0, Metric::Cov)),
        Column::new("adaptive_cov", metric(2, Metric::Cov)),
    ]);
    // Extension figure: no paper value to compare against.
    f19.summaries = vec![Summary::new(
        "GEOMEAN adaptive speedup over mixes",
        Agg::Geomean,
        Extract::Speedup { cfg: 2 },
        "",
    )];
    specs.push(f19);

    // Telemetry row (not a paper figure): the full four-way latency
    // decomposition under the HMC baseline — transfer vs interconnect
    // queueing vs vault queueing vs array service. Same base config and
    // workload set as Fig 1, so its sweep points cache-share with the
    // Fig 1 runs (a warm `repro figure latency-breakdown` after `repro
    // figure 1` simulates nothing).
    let mut lb = ExperimentSpec {
        name: "latency-breakdown".to_string(),
        figure: None,
        ..figure("1", "four-way latency decomposition (HMC baseline)", MemKind::Hmc)
    };
    lb.output = OutputSchema::Columns(vec![
        Column::new("transfer", metric(0, Metric::NetworkFraction)),
        Column::new("queue_net", metric(0, Metric::QueueNetFraction)),
        Column::new("queue_mem", metric(0, Metric::QueueMemFraction)),
        Column::new("service", metric(0, Metric::ArrayFraction)),
        Column::new("avg_latency", metric(0, Metric::AvgLatency)),
    ]);
    specs.push(lb);

    specs
}

/// Figure ids in figure order (`"1"`, `"2"`, … `"19"`).
pub fn figure_ids() -> Vec<String> {
    figures().into_iter().filter_map(|s| s.figure).collect()
}

/// Look a spec up by figure id (`"11"`) or registry name (`"fig11"`).
pub fn by_figure(which: &str) -> Option<ExperimentSpec> {
    figures()
        .into_iter()
        .find(|s| s.figure.as_deref() == Some(which) || s.name == which)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_all_nineteen_figures() {
        let ids = figure_ids();
        assert_eq!(
            ids,
            ["1", "2", "3", "4", "9", "10", "11", "12", "13", "14", "15", "16", "17", "18", "19"]
        );
    }

    #[test]
    fn names_match_artifact_convention() {
        for s in figures() {
            // Telemetry rows (figure: None) pick their own names.
            let Some(id) = s.figure.as_ref() else { continue };
            assert_eq!(s.name, format!("fig{id:0>2}"));
        }
    }

    #[test]
    fn lookup_by_id_and_name() {
        assert_eq!(by_figure("11").unwrap().name, "fig11");
        assert_eq!(by_figure("fig09").unwrap().figure.as_deref(), Some("9"));
        assert!(by_figure("20").is_none());
    }

    #[test]
    fn latency_breakdown_row_shares_fig1_points() {
        let lb = by_figure("latency-breakdown").unwrap();
        assert_eq!(lb.figure, None, "telemetry row, not a paper figure");
        let f1 = by_figure("1").unwrap();
        // Same expanded configs as Fig 1 ⇒ same report-cache keys.
        let render = |s: &ExperimentSpec| {
            s.expand()
                .unwrap()
                .iter()
                .map(|p| crate::config::presets::render(&p.cfg))
                .collect::<Vec<_>>()
        };
        assert_eq!(render(&lb), render(&f1));
        assert_eq!(lb.row_labels().unwrap(), f1.row_labels().unwrap());
    }

    #[test]
    fn every_figure_expands_cleanly() {
        for s in figures() {
            let configs = s.expand().unwrap_or_else(|e| panic!("{}: {e}", s.name));
            assert!(!configs.is_empty(), "{}", s.name);
            s.row_labels().unwrap_or_else(|e| panic!("{}: {e}", s.name));
        }
    }

    #[test]
    fn fig16_matches_legacy_shape() {
        let s = by_figure("16").unwrap();
        let cfgs = s.expand().unwrap();
        assert_eq!(cfgs.len(), 1 + crate::config::presets::TABLE_SIZE_SWEEP.len());
        assert!(cfgs[0].is_baseline);
        assert_eq!(cfgs[1].cfg.sub_table_entries(), 1024);
    }
}

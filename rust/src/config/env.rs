//! The one place `REPRO_*` environment variables are read.
//!
//! Lint rule D2 (see `rust/docs/LINTING.md`) bans `std::env::var` in the
//! simulation and sweep layers: an env read buried in a hot path is an
//! undocumented input that can silently change results between runs.
//! Every knob gets a named reader here instead — callers receive a typed
//! `Option` and decide their own default, and the full inventory of
//! environment inputs is this file.
//!
//! (`REPRO_LOG` is read by `obs::log` and `REPRO_BENCH_SKIP` by the
//! bench harness — both layers are on the D2 allowlist because they
//! cannot affect simulation results by construction.)
//!
//! The readers are thin wrappers over pure `parse_*` helpers; the tests
//! exercise the helpers, because mutating process-global environment
//! state from parallel unit tests is exactly the kind of hazard this
//! module exists to fence off.

use std::path::PathBuf;

use crate::config::Topology;

fn var(key: &str) -> Option<String> {
    std::env::var(key).ok()
}

/// `REPRO_THREADS`: worker-thread count for sweeps and the run command's
/// kernel fan-out. `Some(n)` only for a parseable value >= 1.
pub fn threads() -> Option<usize> {
    parse_threads(&var("REPRO_THREADS")?)
}

fn parse_threads(v: &str) -> Option<usize> {
    v.parse::<usize>().ok().filter(|&n| n >= 1)
}

/// `REPRO_CACHE_DIR`: where the persistent report cache lives.
pub fn cache_dir() -> Option<PathBuf> {
    var("REPRO_CACHE_DIR").map(PathBuf::from)
}

/// `REPRO_NO_DISK_CACHE`: `1`/`true` disables the persistent report cache.
pub fn no_disk_cache() -> bool {
    var("REPRO_NO_DISK_CACHE").as_deref().is_some_and(parse_switch)
}

fn parse_switch(v: &str) -> bool {
    v == "1" || v.eq_ignore_ascii_case("true")
}

/// `REPRO_ARTIFACT_DIR`: where figure JSON artifacts land.
pub fn artifact_dir() -> Option<PathBuf> {
    var("REPRO_ARTIFACT_DIR").map(PathBuf::from)
}

/// `REPRO_WARMUP`: warmup request count override.
pub fn warmup_requests() -> Option<u64> {
    var("REPRO_WARMUP")?.parse().ok()
}

/// `REPRO_MEASURE`: measured request count override.
pub fn measure_requests() -> Option<u64> {
    var("REPRO_MEASURE")?.parse().ok()
}

/// `REPRO_RUNS`: per-point run count override.
pub fn runs() -> Option<u64> {
    var("REPRO_RUNS")?.parse().ok()
}

/// `REPRO_EPOCH`: adaptive-policy epoch length override, in cycles.
pub fn epoch_cycles() -> Option<u64> {
    var("REPRO_EPOCH")?.parse().ok()
}

/// `REPRO_TOPOLOGY`: force one interconnect across the whole suite.
/// Panics on an unparseable value — a typo'd topology must not silently
/// run the preset default (same contract as the old inline read).
pub fn topology() -> Option<Topology> {
    var("REPRO_TOPOLOGY").map(|t| parse_topology(&t))
}

fn parse_topology(t: &str) -> Topology {
    Topology::parse(t)
        .unwrap_or_else(|| panic!("unknown REPRO_TOPOLOGY {t:?} (mesh|crossbar|ring)"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_counts_reject_zero_and_garbage() {
        assert_eq!(parse_threads("3"), Some(3));
        assert_eq!(parse_threads("1"), Some(1));
        assert_eq!(parse_threads("0"), None);
        assert_eq!(parse_threads("lots"), None);
        assert_eq!(parse_threads(""), None);
    }

    #[test]
    fn switch_accepts_1_and_true_only() {
        assert!(parse_switch("1"));
        assert!(parse_switch("true"));
        assert!(parse_switch("TRUE"));
        assert!(!parse_switch("0"));
        assert!(!parse_switch("yes"));
        assert!(!parse_switch(""));
    }

    #[test]
    fn topology_parses_the_three_interconnects() {
        assert_eq!(parse_topology("mesh"), Topology::Mesh);
        assert_eq!(parse_topology("crossbar"), Topology::Crossbar);
        assert_eq!(parse_topology("ring"), Topology::Ring);
    }

    #[test]
    #[should_panic(expected = "unknown REPRO_TOPOLOGY")]
    fn topology_rejects_typos_loudly() {
        parse_topology("mseh");
    }
}

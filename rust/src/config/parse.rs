//! Minimal `key = value` config-file parser (TOML subset).
//!
//! `serde`/`toml` are unavailable offline (see DESIGN.md), so run
//! configurations are plain text files of `key = value` lines with `#`
//! comments. Every tunable of [`SimConfig`](crate::config::SimConfig) is
//! addressable by its field name; `preset` selects the base.
//!
//! ```text
//! # dlpim run config
//! preset = hmc
//! policy = adaptive
//! sub_table_sets = 4096
//! measure_requests = 500000
//! ```

use super::{MemKind, SimConfig, Topology};
use crate::policy::PolicyKind;

/// A parsed `key = value` file.
#[derive(Debug, Default, Clone)]
pub struct KvFile {
    pairs: Vec<(String, String)>,
}

impl KvFile {
    /// Parse the text of a config file. Returns `Err(line_no, message)` on
    /// the first malformed line.
    pub fn parse(text: &str) -> Result<Self, (usize, String)> {
        let mut pairs = Vec::new();
        for (i, raw) in text.lines().enumerate() {
            let line = match raw.find('#') {
                Some(p) => &raw[..p],
                None => raw,
            }
            .trim();
            if line.is_empty() {
                continue;
            }
            let Some(eq) = line.find('=') else {
                return Err((i + 1, format!("expected `key = value`, got {line:?}")));
            };
            let key = line[..eq].trim();
            let val = line[eq + 1..].trim().trim_matches('"');
            if key.is_empty() {
                return Err((i + 1, "empty key".to_string()));
            }
            if val.is_empty() {
                return Err((i + 1, format!("empty value for key {key:?}")));
            }
            pairs.push((key.to_string(), val.to_string()));
        }
        Ok(KvFile { pairs })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        // Last occurrence wins, like TOML re-assignment in our subset.
        self.pairs
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.pairs.iter().map(|(k, _)| k.as_str())
    }
}

/// Apply a parsed file on top of its preset and return the final config.
pub fn config_from_text(text: &str) -> Result<SimConfig, String> {
    let kv = KvFile::parse(text).map_err(|(l, m)| format!("line {l}: {m}"))?;
    let mut cfg = match kv.get("preset") {
        Some(p) => SimConfig::preset(p).ok_or(format!("unknown preset {p:?}"))?,
        None => SimConfig::hmc(),
    };
    apply(&mut cfg, &kv)?;
    cfg.validate()
        .map_err(|errs| format!("invalid config: {}", errs.join("; ")))?;
    Ok(cfg)
}

fn parse_num<T: std::str::FromStr>(key: &str, v: &str) -> Result<T, String> {
    v.replace('_', "")
        .parse::<T>()
        .map_err(|_| format!("bad numeric value {v:?} for {key}"))
}

/// Apply every recognized key; unknown keys are an error (catches typos).
pub fn apply(cfg: &mut SimConfig, kv: &KvFile) -> Result<(), String> {
    for key in kv.keys().collect::<Vec<_>>() {
        let v = kv.get(key).expect("iterating the file's own keys");
        match key {
            "preset" => {} // handled by caller
            "mem" => {
                cfg.mem = match v {
                    "hmc" => MemKind::Hmc,
                    "hbm" => MemKind::Hbm,
                    _ => return Err(format!("unknown mem {v:?}")),
                }
            }
            "policy" => {
                cfg.policy =
                    PolicyKind::parse(v).ok_or(format!("unknown policy {v:?}"))?
            }
            "topology" => {
                cfg.topology = Topology::parse(v)
                    .ok_or(format!("unknown topology {v:?} (mesh|crossbar|ring)"))?
            }
            "net_w" => cfg.net_w = parse_num(key, v)?,
            "net_h" => cfg.net_h = parse_num(key, v)?,
            "n_vaults" => cfg.n_vaults = parse_num(key, v)?,
            "block_bytes" => cfg.block_bytes = parse_num(key, v)?,
            "flit_bytes" => cfg.flit_bytes = parse_num(key, v)?,
            "banks_per_vault" => cfg.banks_per_vault = parse_num(key, v)?,
            "row_buffer_bytes" => cfg.row_buffer_bytes = parse_num(key, v)?,
            "t_row_hit" => cfg.t_row_hit = parse_num(key, v)?,
            "t_row_miss" => cfg.t_row_miss = parse_num(key, v)?,
            "vault_service_cycles" => cfg.vault_service_cycles = parse_num(key, v)?,
            "input_buffer_entries" => cfg.input_buffer_entries = parse_num(key, v)?,
            "l1_bytes" => cfg.l1_bytes = parse_num(key, v)?,
            "l1_ways" => cfg.l1_ways = parse_num(key, v)?,
            "l1_line" => cfg.l1_line = parse_num(key, v)?,
            "mlp" => cfg.mlp = parse_num(key, v)?,
            "sub_table_sets" => cfg.sub_table_sets = parse_num(key, v)?,
            "sub_table_ways" => cfg.sub_table_ways = parse_num(key, v)?,
            "sub_buffer_entries" => cfg.sub_buffer_entries = parse_num(key, v)?,
            "count_threshold" => cfg.count_threshold = parse_num(key, v)?,
            "epoch_cycles" => cfg.epoch_cycles = parse_num(key, v)?,
            "latency_threshold_pct" => cfg.latency_threshold_pct = parse_num(key, v)?,
            "global_broadcast_lat" => cfg.global_broadcast_lat = parse_num(key, v)?,
            "leading_sets" => cfg.leading_sets = parse_num(key, v)?,
            "warmup_requests" => cfg.warmup_requests = parse_num(key, v)?,
            "measure_requests" => cfg.measure_requests = parse_num(key, v)?,
            "runs" => cfg.runs = parse_num(key, v)?,
            "seed" => cfg.seed = parse_num(key, v)?,
            "trace" => cfg.trace = Some(v.to_string()),
            "trace_loop" => {
                cfg.trace_loop = match v {
                    "true" => true,
                    "false" => false,
                    _ => return Err(format!("trace_loop expects true|false, got {v:?}")),
                }
            }
            other => return Err(format!("unknown config key {other:?}")),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_simple_file() {
        let cfg = config_from_text(
            "preset = hbm\npolicy = always\nmeasure_requests = 123_000\n",
        )
        .unwrap();
        assert_eq!(cfg.mem, MemKind::Hbm);
        assert_eq!(cfg.policy, PolicyKind::Always);
        assert_eq!(cfg.measure_requests, 123_000);
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let kv = KvFile::parse("# top\n\n a = 1 # trailing\n").unwrap();
        assert_eq!(kv.get("a"), Some("1"));
    }

    #[test]
    fn last_assignment_wins() {
        let kv = KvFile::parse("a = 1\na = 2\n").unwrap();
        assert_eq!(kv.get("a"), Some("2"));
    }

    #[test]
    fn rejects_unknown_key() {
        assert!(config_from_text("bogus_key = 3\n").is_err());
    }

    #[test]
    fn parses_topology_key() {
        let cfg = config_from_text("topology = ring\n").unwrap();
        assert_eq!(cfg.topology, Topology::Ring);
        assert!(config_from_text("topology = torus\n").is_err());
    }

    #[test]
    fn rejects_invalid_topology_combination() {
        // 24 vaults fit the 6x6 mesh but cannot form a crossbar switch.
        let err =
            config_from_text("topology = crossbar\nn_vaults = 24\n").unwrap_err();
        assert!(err.contains("crossbar"), "{err}");
    }

    #[test]
    fn rejects_missing_equals() {
        assert!(KvFile::parse("justakey\n").is_err());
    }

    #[test]
    fn rejects_bad_number() {
        assert!(config_from_text("net_w = six\n").is_err());
    }

    #[test]
    fn rejects_invalid_final_config() {
        // 64 vaults cannot fit the default 6x6 mesh.
        assert!(config_from_text("n_vaults = 64\n").is_err());
    }

    #[test]
    fn parses_trace_keys() {
        let cfg =
            config_from_text("trace = target/repro/a.dlpt\ntrace_loop = false\n").unwrap();
        assert_eq!(cfg.trace.as_deref(), Some("target/repro/a.dlpt"));
        assert!(!cfg.trace_loop);
        assert!(config_from_text("trace_loop = maybe\n").is_err());
    }

    #[test]
    fn quoted_values_accepted() {
        let cfg = config_from_text("preset = \"hmc\"\n").unwrap();
        assert_eq!(cfg.mem, MemKind::Hmc);
    }
}

//! Named experiment presets: one per paper configuration that the
//! evaluation section exercises, so benches and the CLI share exact setups.

use super::SimConfig;
use crate::policy::PolicyKind;

/// All policy configurations compared in the paper's figures.
pub const POLICY_SET: [PolicyKind; 3] =
    [PolicyKind::Never, PolicyKind::Always, PolicyKind::Adaptive];

/// Baseline (never-subscribe) HMC — the denominator of every HMC speedup.
pub fn hmc_baseline() -> SimConfig {
    SimConfig::hmc()
}

/// Always-subscribe HMC (Fig 9).
pub fn hmc_always() -> SimConfig {
    let mut c = SimConfig::hmc();
    c.policy = PolicyKind::Always;
    c
}

/// Adaptive HMC (Fig 11/12/14): latency-based global decision with
/// leading-set sampling — the paper's headline configuration.
pub fn hmc_adaptive() -> SimConfig {
    let mut c = SimConfig::hmc();
    c.policy = PolicyKind::Adaptive;
    c
}

/// Baseline HBM (Fig 2/4/13/15).
pub fn hbm_baseline() -> SimConfig {
    SimConfig::hbm()
}

/// Adaptive HBM (Fig 13/15).
pub fn hbm_adaptive() -> SimConfig {
    let mut c = SimConfig::hbm();
    c.policy = PolicyKind::Adaptive;
    c
}

/// Fig 16 sweep: subscription-table sizes (total entries per vault).
pub const TABLE_SIZE_SWEEP: [u32; 5] = [1024, 2048, 4096, 8192, 16384];

/// Build an adaptive-HMC config with the given total table entries,
/// preserving 4-way associativity.
pub fn hmc_adaptive_with_table_entries(entries: u32) -> SimConfig {
    let mut c = hmc_adaptive();
    c.sub_table_sets = (entries / c.sub_table_ways as u32).max(1);
    c
}

/// Render a config as the `key = value` text our parser reads back —
/// `repro config` uses this to print Table I / Table II equivalents.
pub fn render(cfg: &SimConfig) -> String {
    let mut s = String::new();
    let mut kv = |k: &str, v: String| {
        s.push_str(k);
        s.push_str(" = ");
        s.push_str(&v);
        s.push('\n');
    };
    kv("mem", cfg.mem.as_str().to_string());
    kv("topology", cfg.topology.as_str().to_string());
    kv("policy", cfg.policy.as_str().to_string());
    kv("net_w", cfg.net_w.to_string());
    kv("net_h", cfg.net_h.to_string());
    kv("n_vaults", cfg.n_vaults.to_string());
    kv("block_bytes", cfg.block_bytes.to_string());
    kv("flit_bytes", cfg.flit_bytes.to_string());
    kv("banks_per_vault", cfg.banks_per_vault.to_string());
    kv("row_buffer_bytes", cfg.row_buffer_bytes.to_string());
    kv("t_row_hit", cfg.t_row_hit.to_string());
    kv("t_row_miss", cfg.t_row_miss.to_string());
    kv("vault_service_cycles", cfg.vault_service_cycles.to_string());
    kv("input_buffer_entries", cfg.input_buffer_entries.to_string());
    kv("l1_bytes", cfg.l1_bytes.to_string());
    kv("l1_ways", cfg.l1_ways.to_string());
    kv("l1_line", cfg.l1_line.to_string());
    kv("mlp", cfg.mlp.to_string());
    kv("sub_table_sets", cfg.sub_table_sets.to_string());
    kv("sub_table_ways", cfg.sub_table_ways.to_string());
    kv("sub_buffer_entries", cfg.sub_buffer_entries.to_string());
    kv("count_threshold", cfg.count_threshold.to_string());
    kv("epoch_cycles", cfg.epoch_cycles.to_string());
    kv("latency_threshold_pct", cfg.latency_threshold_pct.to_string());
    kv("global_broadcast_lat", cfg.global_broadcast_lat.to_string());
    kv("leading_sets", cfg.leading_sets.to_string());
    kv("warmup_requests", cfg.warmup_requests.to_string());
    kv("measure_requests", cfg.measure_requests.to_string());
    kv("runs", cfg.runs.to_string());
    kv("seed", cfg.seed.to_string());
    if let Some(trace) = &cfg.trace {
        kv("trace", trace.clone());
        kv("trace_loop", cfg.trace_loop.to_string());
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::parse::config_from_text;

    #[test]
    fn render_roundtrips_through_parser() {
        for cfg in [hmc_adaptive(), hbm_baseline(), hmc_always()] {
            let text = render(&cfg);
            let back = config_from_text(&text).unwrap();
            assert_eq!(back.mem, cfg.mem);
            assert_eq!(back.topology, cfg.topology);
            assert_eq!(back.policy, cfg.policy);
            assert_eq!(back.n_vaults, cfg.n_vaults);
            assert_eq!(back.sub_table_sets, cfg.sub_table_sets);
            assert_eq!(back.epoch_cycles, cfg.epoch_cycles);
        }
    }

    #[test]
    fn render_roundtrips_the_trace_axis() {
        let mut cfg = hmc_baseline();
        cfg.trace = Some("target/repro/x.dlpt".into());
        cfg.trace_loop = false;
        let back = config_from_text(&render(&cfg)).unwrap();
        assert_eq!(back.trace, cfg.trace);
        assert_eq!(back.trace_loop, cfg.trace_loop);
    }

    #[test]
    fn table_sweep_preserves_ways() {
        for e in TABLE_SIZE_SWEEP {
            let c = hmc_adaptive_with_table_entries(e);
            assert_eq!(c.sub_table_entries(), e);
            assert_eq!(c.sub_table_ways, 4);
        }
    }
}

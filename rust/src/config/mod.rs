//! System configuration: memory-technology presets (Table I / Table II of
//! the paper) plus every tunable the evaluation sweeps over.

pub mod env;
pub mod parse;
pub mod presets;

use crate::policy::PolicyKind;
use crate::Cycle;

/// Which 3-D stacked memory the mesh models.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemKind {
    /// Hybrid Memory Cube: 6x6 mesh, 32 vaults, 8 banks/vault (Table I).
    Hmc,
    /// High Bandwidth Memory: 4x2 mesh, 8 channels, 4 bank groups x 4 banks
    /// (Table II).
    Hbm,
}

impl MemKind {
    pub fn as_str(self) -> &'static str {
        match self {
            MemKind::Hmc => "hmc",
            MemKind::Hbm => "hbm",
        }
    }
}

/// Which inter-vault interconnect the memory system routes over (the
/// [`crate::memsys::Interconnect`] implementation built for a run).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// 2-D mesh with XY routing — HMC's vault network (Fig 8a).
    Mesh,
    /// Non-blocking crossbar with per-channel ports and a uniform 1-hop
    /// switch latency — HBM's pseudo-channel switch (§V).
    Crossbar,
    /// Bidirectional ring, shortest-direction routing — the extra
    /// sensitivity-study topology.
    Ring,
}

impl Topology {
    pub fn as_str(self) -> &'static str {
        match self {
            Topology::Mesh => "mesh",
            Topology::Crossbar => "crossbar",
            Topology::Ring => "ring",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "mesh" => Some(Topology::Mesh),
            "crossbar" | "xbar" => Some(Topology::Crossbar),
            "ring" => Some(Topology::Ring),
            _ => None,
        }
    }
}

/// Complete configuration of one simulation run.
///
/// Defaults come from the paper's Table I / Table II and §III; anything the
/// evaluation sweeps (policy, subscription-table geometry, epoch length) is
/// a plain public field.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub mem: MemKind,
    /// Interconnect topology (mesh for HMC, crossbar for HBM's
    /// pseudo-channels, ring for sensitivity studies).
    pub topology: Topology,
    /// Mesh width (6 for HMC, 4 for HBM). Ignored by non-mesh topologies.
    pub net_w: u16,
    /// Mesh height (6 for HMC, 2 for HBM). Ignored by non-mesh topologies.
    pub net_h: u16,
    /// Number of active vaults/channels (32 for HMC on the 6x6 grid with the
    /// four corner routers acting as host-interface nodes; 8 for HBM).
    pub n_vaults: u16,

    /// Memory block (subscription granularity), bytes. HMC supports
    /// 16/32/64/128 B blocks; DAMOV and our model use 64 B.
    pub block_bytes: u32,
    /// FLIT size, bytes (128-bit FLITs in the HMC spec).
    pub flit_bytes: u32,

    /// DRAM banks per vault (8 in HMC; 16 = 4 bank groups x 4 in HBM2).
    pub banks_per_vault: u16,
    /// Row-buffer size, bytes (256 B in Table I).
    pub row_buffer_bytes: u32,
    /// Array access latency on a row-buffer hit, core cycles.
    pub t_row_hit: u32,
    /// Array access latency on a row-buffer miss (precharge + activate +
    /// access), core cycles.
    pub t_row_miss: u32,
    /// Vault-controller occupancy per request: "each vault can only serve
    /// one location per cycle" (§II-C).
    pub vault_service_cycles: u32,
    /// Router input-buffer capacity in FLITs-worth of packets (16 entries in
    /// §II-C); bounds how far ahead a link can be reserved before the sender
    /// stalls (backpressure).
    pub input_buffer_entries: u32,

    /// Per-PIM-core L1 size in bytes (32 KB in the baseline).
    pub l1_bytes: u32,
    pub l1_ways: u16,
    pub l1_line: u32,
    /// Maximum outstanding L1 misses per in-order PIM core (bounded MLP).
    pub mlp: u16,

    /// Subscription policy for this run.
    pub policy: PolicyKind,
    /// Subscription-table sets per vault (2048 in §III-A, swept by Fig 16).
    pub sub_table_sets: u32,
    /// Subscription-table associativity (4-way in §III-A).
    pub sub_table_ways: u16,
    /// Subscription-buffer entries (32, fully associative, §III-A).
    pub sub_buffer_entries: u32,
    /// Access-count threshold before subscribing. The paper found 0 (first
    /// access) optimal and dropped the count table; kept for the ablation.
    pub count_threshold: u32,

    /// Epoch length in cycles. Paper: 1e6. Our default scales to 20k so the
    /// adaptive machinery sees tens of epochs within benchmark-sized runs
    /// (the paper's runs span hundreds of 1e6-cycle epochs);
    /// `--paper-scale` restores 1e6.
    pub epoch_cycles: Cycle,
    /// Latency-based adaptive threshold, percent (2% in §III-D3).
    pub latency_threshold_pct: f64,
    /// Latency of the central vault's global decision + broadcast (~1000
    /// cycles, §III-D4).
    pub global_broadcast_lat: u32,
    /// Leading-set dynamic set sampling (§III-D5). Number of leading sets
    /// *per group* (always-on group and always-off group).
    pub leading_sets: u32,

    /// Requests to simulate before statistics reset (cache & table warmup).
    /// Paper: 1e6; default scaled for benchmark turnaround.
    pub warmup_requests: u64,
    /// Requests measured after warmup.
    pub measure_requests: u64,
    /// Independent repetitions averaged per data point (5 in §IV-A).
    pub runs: u32,
    /// Base PRNG seed; run `i` uses `seed + i`.
    pub seed: u64,

    /// Traffic source: `None` drives the named Table III generator;
    /// `Some(path)` replays a recorded `.dlpt` trace file instead (the
    /// trace axis — see [`crate::trace`]). Trace-backed sweep jobs hash
    /// the file's *contents* into the report-cache key.
    pub trace: Option<String>,
    /// Whether a replayed trace restarts when a core's stream ends
    /// (loop-around). Ignored when `trace` is `None`.
    pub trace_loop: bool,
}

impl SimConfig {
    /// Table I baseline: HMC v2.0, 32 vaults on a 6x6 mesh.
    pub fn hmc() -> Self {
        SimConfig {
            mem: MemKind::Hmc,
            topology: Topology::Mesh,
            net_w: 6,
            net_h: 6,
            n_vaults: 32,
            block_bytes: 64,
            flit_bytes: 16,
            banks_per_vault: 8,
            row_buffer_bytes: 256,
            t_row_hit: 14,
            t_row_miss: 38,
            vault_service_cycles: 1,
            input_buffer_entries: 16,
            l1_bytes: 32 * 1024,
            l1_ways: 4,
            l1_line: 64,
            mlp: 4,
            policy: PolicyKind::Never,
            sub_table_sets: 2048,
            sub_table_ways: 4,
            sub_buffer_entries: 32,
            count_threshold: 0,
            epoch_cycles: 20_000,
            latency_threshold_pct: 2.0,
            global_broadcast_lat: 1000,
            leading_sets: 32,
            warmup_requests: 50_000,
            measure_requests: 300_000,
            runs: 1,
            seed: 0x5eed_d1b1,
            trace: None,
            trace_loop: true,
        }
    }

    /// Table II baseline: HBM2, 8 pseudo-channels behind a crossbar switch
    /// (the 4x2 grid remains the fallback when `--topology mesh` is forced).
    pub fn hbm() -> Self {
        SimConfig {
            mem: MemKind::Hbm,
            topology: Topology::Crossbar,
            net_w: 4,
            net_h: 2,
            n_vaults: 8,
            banks_per_vault: 16, // 4 bank groups x 4 banks
            ..Self::hmc()
        }
    }

    /// Preset by name ("hmc" | "hbm").
    pub fn preset(name: &str) -> Option<Self> {
        match name {
            "hmc" => Some(Self::hmc()),
            "hbm" => Some(Self::hbm()),
            _ => None,
        }
    }

    /// Restore the paper's unscaled epoch/warmup parameters (slow).
    pub fn paper_scale(mut self) -> Self {
        self.epoch_cycles = 1_000_000;
        self.warmup_requests = 1_000_000;
        self.measure_requests = 4_000_000;
        self.runs = 5;
        self
    }

    /// Scale request counts for fast CI/bench runs, preserving the
    /// warmup:measure ratio.
    pub fn quick(mut self) -> Self {
        self.warmup_requests = 10_000;
        self.measure_requests = 60_000;
        self.epoch_cycles = 10_000;
        self
    }

    /// Total subscription-table entries per vault.
    pub fn sub_table_entries(&self) -> u32 {
        self.sub_table_sets * self.sub_table_ways as u32
    }

    /// FLITs in a data-bearing packet: 1 header FLIT + ceil(block/flit).
    /// 64 B block / 16 B FLIT -> k = 5, matching the paper's "between 2 and
    /// 9 FLITs" range for 16..128 B blocks.
    pub fn data_packet_flits(&self) -> u32 {
        1 + self.block_bytes.div_ceil(self.flit_bytes)
    }

    /// Validate internal consistency; returns a human-readable error list.
    pub fn validate(&self) -> Result<(), Vec<String>> {
        let mut errs = Vec::new();
        if self.n_vaults == 0 {
            errs.push("n_vaults must be >= 1".into());
        }
        match self.topology {
            Topology::Mesh => {
                if (self.net_w as u32) * (self.net_h as u32) < self.n_vaults as u32 {
                    errs.push(format!(
                        "mesh {}x{} cannot host {} vaults",
                        self.net_w, self.net_h, self.n_vaults
                    ));
                }
            }
            Topology::Crossbar => {
                if !self.n_vaults.is_power_of_two() {
                    errs.push(format!(
                        "crossbar topology needs a power-of-two vault count \
                         (pseudo-channel ports pair into a square switch), got {}; \
                         adjust n_vaults or pick --topology mesh/ring",
                        self.n_vaults
                    ));
                }
            }
            Topology::Ring => {
                if self.n_vaults < 2 {
                    errs.push(format!(
                        "ring topology needs at least 2 vaults, got {}",
                        self.n_vaults
                    ));
                }
            }
        }
        if !self.block_bytes.is_power_of_two() {
            errs.push("block_bytes must be a power of two".into());
        }
        if !self.sub_table_sets.is_power_of_two() {
            errs.push("sub_table_sets must be a power of two".into());
        }
        if self.l1_line != self.block_bytes {
            errs.push("l1_line must equal block_bytes (DAMOV model)".into());
        }
        if self.mlp == 0 {
            errs.push("mlp must be >= 1".into());
        }
        if self.epoch_cycles == 0 {
            errs.push("epoch_cycles must be >= 1".into());
        }
        if let Some(path) = &self.trace {
            if path.trim().is_empty() {
                errs.push("trace path must not be empty (unset it to use a generator)".into());
            }
        }
        if errs.is_empty() {
            Ok(())
        } else {
            Err(errs)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hmc_preset_matches_table1() {
        let c = SimConfig::hmc();
        assert_eq!(c.n_vaults, 32);
        assert_eq!((c.net_w, c.net_h), (6, 6));
        assert_eq!(c.banks_per_vault, 8);
        assert_eq!(c.row_buffer_bytes, 256);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn hbm_preset_matches_table2() {
        let c = SimConfig::hbm();
        assert_eq!(c.n_vaults, 8);
        assert_eq!((c.net_w, c.net_h), (4, 2));
        assert_eq!(c.banks_per_vault, 16);
        assert_eq!(c.topology, Topology::Crossbar, "HBM routes over its switch");
        assert!(c.validate().is_ok());
    }

    #[test]
    fn topology_parse_roundtrips() {
        for t in [Topology::Mesh, Topology::Crossbar, Topology::Ring] {
            assert_eq!(Topology::parse(t.as_str()), Some(t));
        }
        assert_eq!(Topology::parse("torus"), None);
    }

    #[test]
    fn validate_rejects_non_pow2_crossbar() {
        let mut c = SimConfig::hmc();
        c.topology = Topology::Crossbar;
        c.n_vaults = 24; // fits the 6x6 grid but is not a power of two
        let errs = c.validate().unwrap_err();
        assert!(errs.iter().any(|e| e.contains("crossbar")), "{errs:?}");
    }

    #[test]
    fn validate_accepts_ring_and_crossbar_presets() {
        let mut c = SimConfig::hmc();
        c.topology = Topology::Ring;
        assert!(c.validate().is_ok());
        c.topology = Topology::Crossbar; // 32 vaults: power of two
        assert!(c.validate().is_ok());
    }

    #[test]
    fn validate_rejects_tiny_ring() {
        let mut c = SimConfig::hmc();
        c.topology = Topology::Ring;
        c.n_vaults = 1;
        assert!(c.validate().is_err());
    }

    #[test]
    fn data_packet_is_five_flits_for_64b_blocks() {
        assert_eq!(SimConfig::hmc().data_packet_flits(), 5);
    }

    #[test]
    fn sixteen_byte_blocks_need_two_flits() {
        let mut c = SimConfig::hmc();
        c.block_bytes = 16;
        assert_eq!(c.data_packet_flits(), 2);
    }

    #[test]
    fn hundred_twenty_eight_byte_blocks_need_nine_flits() {
        let mut c = SimConfig::hmc();
        c.block_bytes = 128;
        assert_eq!(c.data_packet_flits(), 9);
    }

    #[test]
    fn validate_rejects_overfull_mesh() {
        let mut c = SimConfig::hmc();
        c.n_vaults = 64;
        assert!(c.validate().is_err());
    }

    #[test]
    fn validate_rejects_non_pow2_block() {
        let mut c = SimConfig::hmc();
        c.block_bytes = 48;
        c.l1_line = 48;
        assert!(c.validate().is_err());
    }

    #[test]
    fn paper_scale_restores_epoch() {
        let c = SimConfig::hmc().paper_scale();
        assert_eq!(c.epoch_cycles, 1_000_000);
        assert_eq!(c.runs, 5);
    }

    #[test]
    fn table_entries_product() {
        let c = SimConfig::hmc();
        assert_eq!(c.sub_table_entries(), 8192);
    }
}

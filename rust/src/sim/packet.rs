//! Packet taxonomy and FLIT sizing.
//!
//! HMC is packet-based: every transfer is a sequence of 128-bit (16 B)
//! FLITs. A data-bearing packet carries `ceil(block/flit)` payload FLITs
//! plus one header/tail FLIT; a control packet is a single FLIT. With the
//! 64 B blocks used throughout the evaluation, data packets are k = 5
//! FLITs, inside the spec's 2..9 FLIT envelope.
//!
//! §III-B defines the subscription request types; we add the two memory
//! demand types and the epoch-control broadcasts of §III-D.

use crate::config::SimConfig;

/// Every packet kind that crosses the vault mesh.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PacketKind {
    /// Demand read request (no payload).
    MemReadReq,
    /// Demand read response (carries one block).
    MemReadResp,
    /// Demand write (carries one block).
    MemWrite,
    /// Write forwarded from original to subscribed vault (carries block).
    MemWriteFwd,
    /// Request to subscribe a block (control).
    SubscriptionRequest,
    /// Negative acknowledgement: subscription cannot complete (control).
    SubscriptionNack,
    /// The subscribed block moving to the requester vault (data).
    SubscriptionDataTransfer,
    /// Ack that subscription data arrived (control).
    SubscriptionTransferAck,
    /// Request to return a block to its original vault (control).
    UnsubscriptionRequest,
    /// Block (if dirty) or bare ack (if clean) returning home. Sized by
    /// [`PacketKind::flits`] according to the dirty flag at send time.
    UnsubscriptionData { dirty: bool },
    /// Ack that an unsubscription completed (control).
    UnsubscriptionTransferAck,
    /// Epoch broadcast: enable subscriptions (control).
    TurnOnSubscription,
    /// Epoch broadcast: disable subscriptions (control).
    TurnOffSubscription,
    /// Per-vault statistics report to the central vault (control).
    StatsReport,
}

impl PacketKind {
    /// FLITs this packet occupies on every link it crosses.
    pub fn flits(self, cfg: &SimConfig) -> u32 {
        let k = cfg.data_packet_flits();
        match self {
            PacketKind::MemReadReq => 1,
            PacketKind::MemReadResp => k,
            PacketKind::MemWrite => k,
            PacketKind::MemWriteFwd => k,
            PacketKind::SubscriptionRequest => 1,
            PacketKind::SubscriptionNack => 1,
            PacketKind::SubscriptionDataTransfer => k,
            PacketKind::SubscriptionTransferAck => 1,
            PacketKind::UnsubscriptionRequest => 1,
            // Dirty-bit optimization (§III-B5): clean blocks return as a
            // bare 1-FLIT ack because the original vault still has the data.
            PacketKind::UnsubscriptionData { dirty } => if dirty { k } else { 1 },
            PacketKind::UnsubscriptionTransferAck => 1,
            PacketKind::TurnOnSubscription
            | PacketKind::TurnOffSubscription
            | PacketKind::StatsReport => 1,
        }
    }

    /// True for packets created by the subscription machinery rather than
    /// by demand accesses — Fig 14 splits traffic along this line.
    pub fn is_subscription_traffic(self) -> bool {
        !matches!(
            self,
            PacketKind::MemReadReq
                | PacketKind::MemReadResp
                | PacketKind::MemWrite
                | PacketKind::MemWriteFwd
        )
    }

    /// True for data-bearing packets (used in tests and traffic accounting).
    pub fn carries_block(self, cfg: &SimConfig) -> bool {
        self.flits(cfg) > 1
    }
}

/// All kinds, for exhaustive tests/sweeps.
pub const ALL_KINDS: [PacketKind; 15] = [
    PacketKind::MemReadReq,
    PacketKind::MemReadResp,
    PacketKind::MemWrite,
    PacketKind::MemWriteFwd,
    PacketKind::SubscriptionRequest,
    PacketKind::SubscriptionNack,
    PacketKind::SubscriptionDataTransfer,
    PacketKind::SubscriptionTransferAck,
    PacketKind::UnsubscriptionRequest,
    PacketKind::UnsubscriptionData { dirty: true },
    PacketKind::UnsubscriptionData { dirty: false },
    PacketKind::UnsubscriptionTransferAck,
    PacketKind::TurnOnSubscription,
    PacketKind::TurnOffSubscription,
    PacketKind::StatsReport,
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn control_packets_are_one_flit() {
        let cfg = SimConfig::hmc();
        for k in [
            PacketKind::MemReadReq,
            PacketKind::SubscriptionRequest,
            PacketKind::SubscriptionNack,
            PacketKind::SubscriptionTransferAck,
            PacketKind::UnsubscriptionRequest,
            PacketKind::UnsubscriptionTransferAck,
            PacketKind::TurnOnSubscription,
            PacketKind::TurnOffSubscription,
            PacketKind::StatsReport,
        ] {
            assert_eq!(k.flits(&cfg), 1, "{k:?}");
        }
    }

    #[test]
    fn data_packets_are_k_flits() {
        let cfg = SimConfig::hmc();
        assert_eq!(PacketKind::MemReadResp.flits(&cfg), 5);
        assert_eq!(PacketKind::SubscriptionDataTransfer.flits(&cfg), 5);
        assert_eq!(PacketKind::MemWrite.flits(&cfg), 5);
    }

    #[test]
    fn dirty_bit_suppresses_unsub_payload() {
        let cfg = SimConfig::hmc();
        assert_eq!(PacketKind::UnsubscriptionData { dirty: true }.flits(&cfg), 5);
        assert_eq!(PacketKind::UnsubscriptionData { dirty: false }.flits(&cfg), 1);
    }

    #[test]
    fn traffic_classification_split() {
        let demand = ALL_KINDS.iter().filter(|k| !k.is_subscription_traffic());
        assert_eq!(demand.count(), 4);
    }

    #[test]
    fn flit_envelope_matches_hmc_spec() {
        // 16..128 B blocks -> 2..9 FLITs per data packet (§II-C).
        for (block, expect) in [(16u32, 2u32), (32, 3), (64, 5), (128, 9)] {
            let mut cfg = SimConfig::hmc();
            cfg.block_bytes = block;
            assert_eq!(PacketKind::MemReadResp.flits(&cfg), expect);
        }
    }
}

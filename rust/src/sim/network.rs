//! The inter-vault mesh: XY (dimension-ordered) routing over directed
//! links with FLIT serialization and contention.
//!
//! Fig 8 of the paper fixes the two topologies: a 6x6 mesh hosting HMC's 32
//! vaults (the four corner routers are host-interface nodes, matching the
//! figure's 32-on-36 layout) and a 4x2 mesh hosting HBM's 8 channels.
//!
//! Cost model (§III-C): a k-FLIT packet occupies each link on its path for
//! k cycles, so an uncontended transfer from `a` to `b` costs
//! `k * manhattan(a, b)` cycles — the paper's `(k+1)h_ro` read round trip
//! falls out as `1*h` for the request plus `k*h` for the response.
//! Contention appears as waits on the per-link `next_free` horizon and is
//! reported separately so the latency breakdown of Fig 1/2 can attribute it
//! to queuing rather than transfer.

use crate::config::SimConfig;
use crate::{Cycle, VaultId};

/// Result of pushing one packet through the mesh.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Transfer {
    /// Cycle at which the last FLIT arrives at the destination router.
    pub arrive: Cycle,
    /// Pure serialization cycles (flits x hops) — "data transfer latency".
    pub network: u64,
    /// Cycles spent waiting for busy links — part of "queuing delay".
    pub queued: u64,
    /// Hops traversed (Manhattan distance between the endpoints).
    pub hops: u32,
}

pub(crate) const DIR_E: usize = 0;
pub(crate) const DIR_W: usize = 1;
pub(crate) const DIR_N: usize = 2;
pub(crate) const DIR_S: usize = 3;

/// Busy-interval calendar for one directed link.
///
/// Reservations are made at arbitrary (often future) cycles — a response
/// leg books its links at the cycle the bank access completes. A single
/// `next_free` horizon would let one far-future reservation block every
/// earlier packet from an *idle* link, so each link keeps its pending busy
/// intervals and packets backfill the gaps, exactly like FLIT slots in
/// real wormhole arbitration. Intervals are pruned once they fall behind
/// the reservation front.
///
/// Shared crate-wide: every [`crate::memsys::Interconnect`] implementation
/// (mesh, crossbar, ring) models its contended ports/links with the same
/// calendar, so contention semantics are identical across topologies.
/// Public so the `perf_hotpath` bench can drive the backfill path with
/// out-of-order reservation storms directly.
#[derive(Clone, Debug, Default)]
pub struct LinkCal {
    /// Sorted, non-overlapping (start, end) busy windows.
    iv: Vec<(Cycle, Cycle)>,
}

/// How far behind the newest reservation an interval must fall before it
/// can be pruned. Out-of-order arrivals come only from the driver heap's
/// bounded disorder (one op-chain extends at most a few hundred cycles
/// past "now"), so a small window suffices — and it bounds the calendar
/// length, keeping `reserve` effectively O(1) (§Perf: a 100k-cycle lag
/// made this O(n²) and dominated whole-figure runtimes).
const PRUNE_LAG: Cycle = 2_000;

impl LinkCal {
    /// Reserve `f` cycles at or after `t`; returns the start cycle.
    pub fn reserve(&mut self, t: Cycle, f: Cycle) -> Cycle {
        // Fast path: reservation at/after the calendar tail (the common
        // case, since the driver processes events in near-time-order).
        if let Some(last) = self.iv.last_mut() {
            if t >= last.1 {
                let start = t;
                if start == last.1 {
                    last.1 += f; // contiguous: extend instead of insert
                } else {
                    self.prune(start);
                    self.iv.push((start, start + f));
                }
                return start;
            }
        } else {
            self.iv.push((t, t + f));
            return t;
        }
        // Slow path: first-fit gap search from `t` (backfill). Intervals
        // are sorted with strictly increasing end cycles, so the ones
        // ending at or before `t` can never constrain the gap — seed the
        // scan past them with a binary search instead of walking the
        // whole calendar front (under an out-of-order reservation storm
        // that linear prefix dominated the scan).
        let first = self.iv.partition_point(|&(_, e)| e <= t);
        let mut cur = t;
        let mut pos = self.iv.len();
        for (i, &(s, e)) in self.iv.iter().enumerate().skip(first) {
            if s >= cur + f {
                pos = i;
                break;
            }
            cur = e;
            pos = i + 1;
        }
        // Merge with the predecessor when contiguous; insert otherwise.
        if pos > 0 && self.iv[pos - 1].1 == cur {
            self.iv[pos - 1].1 += f;
        } else {
            self.iv.insert(pos, (cur, cur + f));
        }
        cur
    }

    /// Drop intervals too old to interact with future reservations.
    fn prune(&mut self, front: Cycle) {
        if front > PRUNE_LAG {
            let min = front - PRUNE_LAG;
            if self.iv.first().is_some_and(|&(_, e)| e <= min) {
                self.iv.retain(|&(_, e)| e > min);
            }
        }
    }

    pub fn clear(&mut self) {
        self.iv.clear();
    }
}

/// The vault mesh. One instance per simulation; `reset` reuses allocations
/// across runs.
pub struct Mesh {
    w: u16,
    h: u16,
    /// vault id -> router node index.
    vault_node: Vec<u16>,
    /// node index -> (x, y), precomputed (a div/mod per hop is measurable
    /// on the transfer hot path — §Perf).
    node_xy: Vec<(u16, u16)>,
    /// Busy calendar per directed link, indexed `node * 4 + dir`.
    links: Vec<LinkCal>,
}

impl Mesh {
    pub fn new(cfg: &SimConfig) -> Self {
        let (w, h) = (cfg.net_w, cfg.net_h);
        let nodes = w as usize * h as usize;
        let vault_node = place_vaults(w, h, cfg.n_vaults);
        assert_eq!(vault_node.len(), cfg.n_vaults as usize);
        let node_xy = (0..nodes as u16).map(|n| (n % w, n / w)).collect();
        Mesh { w, h, vault_node, node_xy, links: vec![LinkCal::default(); nodes * 4] }
    }

    /// Clear all link reservations (between runs).
    pub fn reset(&mut self) {
        for l in &mut self.links {
            l.clear();
        }
    }

    #[inline]
    pub fn node_of(&self, v: VaultId) -> u16 {
        self.vault_node[v as usize]
    }

    #[inline]
    fn xy(&self, node: u16) -> (u16, u16) {
        self.node_xy[node as usize]
    }

    /// Manhattan distance between two vaults (the paper's `h` terms).
    #[inline]
    pub fn hops(&self, a: VaultId, b: VaultId) -> u32 {
        let (ax, ay) = self.xy(self.node_of(a));
        let (bx, by) = self.xy(self.node_of(b));
        (ax.abs_diff(bx) + ay.abs_diff(by)) as u32
    }

    /// The vault nearest the geometric mesh center — the "central vault" of
    /// the global adaptive policy (§III-D4).
    pub fn central_vault(&self) -> VaultId {
        let cx = (self.w - 1) as f64 / 2.0;
        let cy = (self.h - 1) as f64 / 2.0;
        let mut best = 0u16;
        let mut best_d = f64::MAX;
        for (v, &node) in self.vault_node.iter().enumerate() {
            let (x, y) = self.xy(node);
            let d = (x as f64 - cx).abs() + (y as f64 - cy).abs();
            if d < best_d {
                best_d = d;
                best = v as u16;
            }
        }
        best
    }

    /// Send a `flits`-sized packet from `from` to `to`, departing no earlier
    /// than `depart`. Reserves every link on the XY path and returns the
    /// timing decomposition. A self-transfer is free and instantaneous.
    pub fn transfer(
        &mut self,
        from: VaultId,
        to: VaultId,
        flits: u32,
        depart: Cycle,
    ) -> Transfer {
        if from == to {
            return Transfer { arrive: depart, ..Transfer::default() };
        }
        let dst = self.node_of(to);
        let (dx, dy) = self.xy(dst);
        let mut cur = self.node_of(from);
        let mut t = depart;
        let mut network = 0u64;
        let mut queued = 0u64;
        let mut hops = 0u32;
        let f = flits as u64;
        while cur != dst {
            let (cx, cy) = self.xy(cur);
            let (dir, next) = if cx != dx {
                if cx < dx {
                    (DIR_E, cur + 1)
                } else {
                    (DIR_W, cur - 1)
                }
            } else if cy < dy {
                (DIR_S, cur + self.w)
            } else {
                (DIR_N, cur - self.w)
            };
            let link = cur as usize * 4 + dir;
            let start = self.links[link].reserve(t, f);
            queued += start - t;
            t = start + f;
            network += f;
            hops += 1;
            cur = next;
        }
        Transfer { arrive: t, network, queued, hops }
    }

    pub fn n_vaults(&self) -> u16 {
        self.vault_node.len() as u16
    }

    pub fn dims(&self) -> (u16, u16) {
        (self.w, self.h)
    }
}

/// Place `n` vaults on a `w x h` grid. When the grid has exactly four spare
/// nodes (HMC: 36 nodes, 32 vaults) the corners are reserved for the host
/// links per Fig 8a; otherwise vaults fill the grid row-major. Shared with
/// [`crate::memsys`]'s mesh interconnect so both agree on the layout.
pub(crate) fn place_vaults(w: u16, h: u16, n: u16) -> Vec<u16> {
    let nodes = w * h;
    assert!(n <= nodes, "mesh too small");
    let spare = nodes - n;
    let corners = [0, w - 1, (h - 1) * w, h * w - 1];
    let skip_corners = spare == 4 && w >= 2 && h >= 2;
    let mut placed = Vec::with_capacity(n as usize);
    for node in 0..nodes {
        if skip_corners && corners.contains(&node) {
            continue;
        }
        if placed.len() < n as usize {
            placed.push(node);
        }
    }
    placed
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hmc_mesh() -> Mesh {
        Mesh::new(&SimConfig::hmc())
    }

    #[test]
    fn hmc_places_32_vaults_skipping_corners() {
        let m = hmc_mesh();
        assert_eq!(m.n_vaults(), 32);
        let nodes: Vec<u16> = (0..32).map(|v| m.node_of(v)).collect();
        for corner in [0u16, 5, 30, 35] {
            assert!(!nodes.contains(&corner), "corner {corner} must be host node");
        }
    }

    #[test]
    fn hbm_fills_grid() {
        let m = Mesh::new(&SimConfig::hbm());
        assert_eq!(m.n_vaults(), 8);
        let nodes: Vec<u16> = (0..8).map(|v| m.node_of(v)).collect();
        assert_eq!(nodes, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn hops_is_manhattan_and_symmetric() {
        let m = hmc_mesh();
        for a in 0..32u16 {
            for b in 0..32u16 {
                assert_eq!(m.hops(a, b), m.hops(b, a));
                if a == b {
                    assert_eq!(m.hops(a, b), 0);
                }
            }
        }
    }

    #[test]
    fn uncontended_transfer_costs_flits_times_hops() {
        let mut m = hmc_mesh();
        let h = m.hops(0, 31);
        let tr = m.transfer(0, 31, 5, 100);
        assert_eq!(tr.hops, h);
        assert_eq!(tr.network, 5 * h as u64);
        assert_eq!(tr.queued, 0);
        assert_eq!(tr.arrive, 100 + 5 * h as u64);
    }

    #[test]
    fn self_transfer_is_free() {
        let mut m = hmc_mesh();
        let tr = m.transfer(7, 7, 5, 42);
        assert_eq!(tr, Transfer { arrive: 42, network: 0, queued: 0, hops: 0 });
    }

    #[test]
    fn contention_queues_second_packet() {
        let mut m = hmc_mesh();
        // Two packets over the same first link at the same cycle.
        let a = m.transfer(0, 1, 5, 0);
        let b = m.transfer(0, 1, 5, 0);
        assert_eq!(a.queued, 0);
        assert_eq!(b.queued, 5);
        assert_eq!(b.arrive, a.arrive + 5);
    }

    #[test]
    fn opposite_directions_do_not_contend() {
        let mut m = hmc_mesh();
        let a = m.transfer(0, 1, 5, 0);
        let b = m.transfer(1, 0, 5, 0);
        assert_eq!(a.queued, 0);
        assert_eq!(b.queued, 0);
    }

    #[test]
    fn reset_clears_reservations() {
        let mut m = hmc_mesh();
        m.transfer(0, 31, 9, 0);
        m.reset();
        let tr = m.transfer(0, 31, 9, 0);
        assert_eq!(tr.queued, 0);
    }

    #[test]
    fn central_vault_is_interior_hmc() {
        let m = hmc_mesh();
        let c = m.central_vault();
        // Must be one of the four center nodes of the 6x6 grid.
        let node = m.node_of(c);
        let (x, y) = (node % 6, node / 6);
        assert!((2..=3).contains(&x) && (2..=3).contains(&y), "({x},{y})");
    }

    /// Brute-force reference for `LinkCal::reserve`'s slow path: scan the
    /// whole calendar linearly (the pre-`partition_point` behaviour).
    fn reserve_reference(iv: &mut Vec<(u64, u64)>, t: u64, f: u64) -> u64 {
        let mut cur = t;
        let mut pos = iv.len();
        for (i, &(s, e)) in iv.iter().enumerate() {
            if e <= cur {
                continue;
            }
            if s >= cur + f {
                pos = i;
                break;
            }
            cur = e;
            pos = i + 1;
        }
        if pos > 0 && iv[pos - 1].1 == cur {
            iv[pos - 1].1 += f;
        } else {
            iv.insert(pos, (cur, cur + f));
        }
        cur
    }

    #[test]
    fn backfill_seeded_scan_matches_linear_reference() {
        // An out-of-order reservation storm: starts jump between the past
        // and the far future, sizes vary, so the slow path sees long
        // calendars with stale prefixes. The seeded scan must make
        // byte-identical decisions to the full linear scan.
        let mut cal = LinkCal::default();
        let mut reference: Vec<(u64, u64)> = Vec::new();
        let mut state = 0x5eed_1234_u64;
        let mut rng = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        for _ in 0..5_000 {
            // Stay below PRUNE_LAG so the fast path's pruning (which the
            // reference deliberately lacks) never fires.
            let t = rng() % 1_500;
            let f = 1 + rng() % 9;
            let got = cal.reserve(t, f);
            let want = reserve_reference(&mut reference, t, f);
            assert_eq!(got, want, "divergence at t={t} f={f}");
            assert_eq!(cal.iv, reference, "calendar divergence at t={t} f={f}");
        }
    }

    #[test]
    fn backfill_fills_earliest_gap_after_t() {
        let mut cal = LinkCal::default();
        // Build [10,15) [20,25) [40,45) via out-of-order reserves.
        assert_eq!(cal.reserve(40, 5), 40);
        assert_eq!(cal.reserve(10, 5), 10);
        assert_eq!(cal.reserve(20, 5), 20);
        // A 5-cycle packet at t=0 fits before the first interval.
        assert_eq!(cal.reserve(0, 5), 0);
        // A 5-cycle packet at t=11 must backfill the [15,20) gap.
        assert_eq!(cal.reserve(11, 5), 15);
        // The next one is pushed past the merged [10,25) block.
        assert_eq!(cal.reserve(11, 5), 25);
    }

    #[test]
    fn read_round_trip_matches_paper_cost_model() {
        // (k+1) * h_ro: 1-FLIT request one way, k-FLIT response back.
        let mut m = hmc_mesh();
        let (r, o) = (0u16, 31u16);
        let h = m.hops(r, o) as u64;
        let req = m.transfer(r, o, 1, 0);
        let resp = m.transfer(o, r, 5, req.arrive);
        assert_eq!(resp.arrive, (5 + 1) * h);
    }
}

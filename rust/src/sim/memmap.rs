//! Physical address map: block granularity and vault interleaving.
//!
//! DAMOV's HMC default interleaving distributes consecutive memory blocks
//! round-robin across vaults (Table I), which is what spreads a streaming
//! access pattern evenly over the mesh — and what concentrates a hot
//! shared structure onto a few *home* vaults, producing the per-vault
//! demand imbalance (CoV) the paper measures in Figs 3/4.

use crate::config::SimConfig;
use crate::{Addr, VaultId};

/// Address decomposition helper, cheap to copy around.
#[derive(Clone, Copy, Debug)]
pub struct AddressMap {
    block_shift: u32,
    n_vaults: u64,
    /// Set mask of the per-vault subscription table (sets are a power of 2).
    set_mask: u64,
}

impl AddressMap {
    pub fn new(cfg: &SimConfig) -> Self {
        debug_assert!(cfg.block_bytes.is_power_of_two());
        debug_assert!(cfg.sub_table_sets.is_power_of_two());
        AddressMap {
            block_shift: cfg.block_bytes.trailing_zeros(),
            n_vaults: cfg.n_vaults as u64,
            set_mask: (cfg.sub_table_sets - 1) as u64,
        }
    }

    /// Global block index of a byte address.
    #[inline]
    pub fn block_of(&self, addr: Addr) -> u64 {
        addr >> self.block_shift
    }

    /// Home vault of a block (round-robin interleave).
    #[inline]
    pub fn home_of_block(&self, block: u64) -> VaultId {
        (block % self.n_vaults) as VaultId
    }

    /// Home vault of a byte address.
    #[inline]
    pub fn home_of(&self, addr: Addr) -> VaultId {
        self.home_of_block(self.block_of(addr))
    }

    /// Subscription-table set index for a block: XOR-folded hash.
    ///
    /// Neither plain `block % sets` nor `block / n_vaults % sets` works:
    /// the former leaves a home vault's own blocks (which share their low
    /// interleave bits) crowded into 1/n_vaults of the sets; the latter
    /// collapses *contiguous* runs — a holder parking a 1024-block private
    /// tile would get only `tile/n_vaults` distinct sets. Folding the high
    /// bits over the low bits spreads both patterns (the same trick real
    /// cache indexing uses against power-of-two strides).
    #[inline]
    pub fn set_of_block(&self, block: u64) -> u32 {
        ((block ^ (block >> 11) ^ (block >> 22)) & self.set_mask) as u32
    }

    #[inline]
    pub fn n_vaults(&self) -> u16 {
        self.n_vaults as u16
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn map() -> AddressMap {
        AddressMap::new(&SimConfig::hmc())
    }

    #[test]
    fn consecutive_blocks_interleave_round_robin() {
        let m = map();
        for b in 0..64u64 {
            assert_eq!(m.home_of_block(b), (b % 32) as u16);
        }
    }

    #[test]
    fn addresses_within_a_block_share_a_home() {
        let m = map();
        let base = 4096u64;
        let home = m.home_of(base);
        for off in 0..64 {
            assert_eq!(m.home_of(base + off), home);
        }
    }

    #[test]
    fn set_index_spreads_same_home_blocks() {
        let m = map();
        // Blocks homed at vault 3: 3, 35, 67, ... must spread over many
        // distinct sets, not crowd into 1/n_vaults of them.
        let sets: std::collections::HashSet<u32> =
            (0..256).map(|i| m.set_of_block(3 + 32 * i)).collect();
        assert!(sets.len() > 200, "only {} distinct sets", sets.len());
    }

    #[test]
    fn set_index_spreads_contiguous_runs() {
        let m = map();
        // A contiguous 1024-block tile (a holder's private working set)
        // must hash across ~1024 sets so a 4-way table can park it.
        let sets: std::collections::HashSet<u32> =
            (0..1024).map(|b| m.set_of_block(900_000 + b)).collect();
        assert!(sets.len() > 900, "only {} distinct sets", sets.len());
    }

    #[test]
    fn set_index_is_in_range() {
        let m = map();
        let mask = SimConfig::hmc().sub_table_sets - 1;
        for b in (0..100_000u64).step_by(97) {
            assert!(m.set_of_block(b) <= mask);
        }
    }

    #[test]
    fn streaming_sweep_covers_all_vaults_evenly() {
        let m = map();
        let mut counts = [0u32; 32];
        for addr in (0..32 * 64 * 100).step_by(64) {
            counts[m.home_of(addr) as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 100));
    }
}

//! Vault-local DRAM timing: single-ported vault controller in front of a
//! set of banks with an open-page row-buffer policy.
//!
//! Table I: 8 banks/vault, 256 B row buffer, open-page policy. The vault
//! controller accepts one request per cycle (§II-C: "each vault can only
//! serve one location per cycle"); a request then occupies its bank for the
//! row-hit or row-miss array time. Waits at the controller port and at a
//! busy bank are *queuing delay*; the array time itself is the third
//! component of the paper's latency breakdown.

use crate::config::SimConfig;
use crate::{Addr, Cycle, VaultId};

/// Timing decomposition of one array access.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MemAccess {
    /// Cycle at which the data is available (read) or committed (write).
    pub done: Cycle,
    /// Cycles spent queued at the controller port and at a busy bank.
    pub queued: u64,
    /// Array access cycles (row hit or row miss).
    pub array: u64,
    /// Whether the access hit the open row.
    pub row_hit: bool,
}

#[derive(Clone, Copy, Debug)]
struct Bank {
    busy_until: Cycle,
    open_row: u64,
}

/// One vault's memory: controller port + banks.
pub struct VaultMem {
    banks: Vec<Bank>,
    ctrl_free: Cycle,
    t_hit: u64,
    t_miss: u64,
    ctrl_occupancy: u64,
    row_bytes: u64,
    /// Row-hit / total counters (for reports and tests).
    pub hits: u64,
    pub accesses: u64,
}

impl VaultMem {
    pub fn new(cfg: &SimConfig) -> Self {
        VaultMem {
            banks: vec![
                Bank { busy_until: 0, open_row: u64::MAX };
                cfg.banks_per_vault as usize
            ],
            ctrl_free: 0,
            t_hit: cfg.t_row_hit as u64,
            t_miss: cfg.t_row_miss as u64,
            ctrl_occupancy: cfg.vault_service_cycles as u64,
            row_bytes: cfg.row_buffer_bytes as u64,
            hits: 0,
            accesses: 0,
        }
    }

    pub fn reset(&mut self) {
        for b in &mut self.banks {
            b.busy_until = 0;
            b.open_row = u64::MAX;
        }
        self.ctrl_free = 0;
        self.hits = 0;
        self.accesses = 0;
    }

    /// Serve one block access arriving at the vault at cycle `at`.
    pub fn access(&mut self, addr: Addr, at: Cycle) -> MemAccess {
        // Controller port: single request per service slot.
        let ctrl_start = at.max(self.ctrl_free);
        self.ctrl_free = ctrl_start + self.ctrl_occupancy;

        let row = addr / self.row_bytes;
        // XOR-folded bank index (standard bank hashing): plain `row % n`
        // degenerates under the vault interleave — a core's stream touches
        // this vault every `n_vaults` blocks, a row stride that is a
        // multiple of the bank count, serializing on one bank.
        let bank_idx = ((row ^ (row >> 3) ^ (row >> 7)) % self.banks.len() as u64) as usize;
        let bank = &mut self.banks[bank_idx];

        let bank_start = ctrl_start.max(bank.busy_until);
        let row_hit = bank.open_row == row;
        let array = if row_hit { self.t_hit } else { self.t_miss };
        let done = bank_start + array;
        bank.busy_until = done;
        bank.open_row = row;

        self.accesses += 1;
        if row_hit {
            self.hits += 1;
        }
        MemAccess {
            done,
            queued: (ctrl_start - at) + (bank_start - ctrl_start),
            array,
            row_hit,
        }
    }

    /// Fraction of accesses that hit the open row so far.
    pub fn row_hit_rate(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.hits as f64 / self.accesses as f64
        }
    }
}

/// Struct-of-arrays timing state for *all* vaults of one memory system.
///
/// [`VaultMem`] models one vault behind one `Vec<Bank>` allocation; a
/// 32-vault system built as `Vec<VaultMem>` scatters 33 small allocations
/// across the heap and every serve-path access chases two pointers. This
/// type flattens the same state into three dense arrays indexed by
/// `vault * banks_per_vault + bank`, so the hot path touches one cache
/// line per access in the common case.
///
/// Bit-identity contract: [`VaultArray::access`] performs *exactly* the
/// arithmetic of [`VaultMem::access`] on the same state, so any access
/// sequence produces identical [`MemAccess`] results (asserted by the
/// `vault_array_matches_vault_mem_*` differential tests below).
pub struct VaultArray {
    n_banks: usize,
    /// Controller-port queue tail, one per vault.
    ctrl_free: Vec<Cycle>,
    /// Bank busy tails, `vault * n_banks + bank`.
    bank_busy: Vec<Cycle>,
    /// Open row per bank, same indexing (`u64::MAX` = closed).
    bank_row: Vec<u64>,
    t_hit: u64,
    t_miss: u64,
    ctrl_occupancy: u64,
    row_bytes: u64,
    /// Row hits per vault (reports and tests).
    hits: Vec<u64>,
    /// Total accesses per vault.
    accesses: Vec<u64>,
}

impl VaultArray {
    pub fn new(cfg: &SimConfig) -> Self {
        let n = cfg.n_vaults as usize;
        let n_banks = cfg.banks_per_vault as usize;
        VaultArray {
            n_banks,
            ctrl_free: vec![0; n],
            bank_busy: vec![0; n * n_banks],
            bank_row: vec![u64::MAX; n * n_banks],
            t_hit: cfg.t_row_hit as u64,
            t_miss: cfg.t_row_miss as u64,
            ctrl_occupancy: cfg.vault_service_cycles as u64,
            row_bytes: cfg.row_buffer_bytes as u64,
            hits: vec![0; n],
            accesses: vec![0; n],
        }
    }

    pub fn n_vaults(&self) -> usize {
        self.ctrl_free.len()
    }

    pub fn reset(&mut self) {
        self.ctrl_free.fill(0);
        self.bank_busy.fill(0);
        self.bank_row.fill(u64::MAX);
        self.hits.fill(0);
        self.accesses.fill(0);
    }

    /// Serve one block access at vault `v` arriving at cycle `at`.
    /// Same arithmetic as [`VaultMem::access`], on flat state.
    pub fn access(&mut self, v: VaultId, addr: Addr, at: Cycle) -> MemAccess {
        let vi = v as usize;
        let ctrl_start = at.max(self.ctrl_free[vi]);
        self.ctrl_free[vi] = ctrl_start + self.ctrl_occupancy;

        let row = addr / self.row_bytes;
        let bank_idx = ((row ^ (row >> 3) ^ (row >> 7)) % self.n_banks as u64) as usize;
        let b = vi * self.n_banks + bank_idx;

        let bank_start = ctrl_start.max(self.bank_busy[b]);
        let row_hit = self.bank_row[b] == row;
        let array = if row_hit { self.t_hit } else { self.t_miss };
        let done = bank_start + array;
        self.bank_busy[b] = done;
        self.bank_row[b] = row;

        self.accesses[vi] += 1;
        self.hits[vi] += u64::from(row_hit);
        MemAccess {
            done,
            queued: (ctrl_start - at) + (bank_start - ctrl_start),
            array,
            row_hit,
        }
    }

    /// Fraction of vault `v`'s accesses that hit the open row so far.
    pub fn row_hit_rate(&self, v: VaultId) -> f64 {
        let vi = v as usize;
        if self.accesses[vi] == 0 {
            0.0
        } else {
            self.hits[vi] as f64 / self.accesses[vi] as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mem() -> VaultMem {
        VaultMem::new(&SimConfig::hmc())
    }

    #[test]
    fn first_access_is_row_miss() {
        let mut m = mem();
        let a = m.access(0, 0);
        assert!(!a.row_hit);
        assert_eq!(a.array, 38);
        assert_eq!(a.done, 38);
    }

    #[test]
    fn same_row_hits_after_open() {
        let mut m = mem();
        let first = m.access(0, 0);
        let second = m.access(64, first.done); // same 256 B row
        assert!(second.row_hit);
        assert_eq!(second.array, 14);
    }

    /// Bank index for a row under the XOR fold (mirrors `access`).
    fn bank_of(row: u64, nbanks: u64) -> u64 {
        (row ^ (row >> 3) ^ (row >> 7)) % nbanks
    }

    #[test]
    fn different_row_same_bank_queues_and_misses() {
        let mut m = mem();
        let n = m.banks.len() as u64;
        // Find another row that hashes to bank_of(row 0).
        let target = bank_of(0, n);
        let row2 = (1..512).find(|&r| bank_of(r, n) == target).unwrap();
        let a = m.access(0, 0);
        let b = m.access(256 * row2, 1);
        assert!(!b.row_hit);
        assert!(b.queued > 0, "must wait for busy bank");
        assert_eq!(b.done, a.done + 38);
    }

    #[test]
    fn different_banks_overlap() {
        let mut m = mem();
        let n = m.banks.len() as u64;
        let b0 = bank_of(0, n);
        let row2 = (1..512).find(|&r| bank_of(r, n) != b0).unwrap();
        let a = m.access(0, 0);
        let b = m.access(256 * row2, 1); // different bank
        // b waits only for the controller slot, not for bank 0.
        assert_eq!(b.queued, 0);
        assert!(b.done < a.done + 38);
    }

    #[test]
    fn controller_serializes_same_cycle_arrivals() {
        let mut m = mem();
        let a = m.access(0, 0);
        let b = m.access(256, 0); // different bank, same arrival cycle
        assert_eq!(a.queued, 0);
        assert_eq!(b.queued, 1, "one-per-cycle controller port");
    }

    #[test]
    fn hit_rate_tracks() {
        let mut m = mem();
        m.access(0, 0);
        m.access(0, 100);
        m.access(0, 200);
        assert!((m.row_hit_rate() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn reset_restores_cold_state() {
        let mut m = mem();
        m.access(0, 0);
        m.reset();
        let a = m.access(0, 0);
        assert!(!a.row_hit);
        assert_eq!(m.accesses, 1);
    }

    /// Deterministic access storm: interleaved vaults, clustered rows (to
    /// provoke row hits and bank conflicts) and non-monotone arrival
    /// jitter per vault.
    fn storm(n_vaults: u16) -> Vec<(u16, u64, u64)> {
        let mut s = 0x9e3779b97f4a7c15u64;
        let mut out = Vec::with_capacity(4000);
        let mut t = 0u64;
        for i in 0..4000u64 {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let v = (s >> 33) as u16 % n_vaults;
            // Small row space so open rows get re-hit and banks collide.
            let addr = ((s >> 17) % 64) * 256 + (s % 4) * 64;
            t += i % 3; // arrivals drift forward with jitter
            out.push((v, addr, t));
        }
        out
    }

    #[test]
    fn vault_array_matches_vault_mem_results() {
        let cfg = SimConfig::hmc();
        let mut soa = VaultArray::new(&cfg);
        let mut aos: Vec<VaultMem> =
            (0..cfg.n_vaults).map(|_| VaultMem::new(&cfg)).collect();
        for (v, addr, at) in storm(cfg.n_vaults) {
            let a = aos[v as usize].access(addr, at);
            let b = soa.access(v, addr, at);
            assert_eq!(a, b, "vault {v} addr {addr:#x} at {at}");
        }
        for v in 0..cfg.n_vaults {
            assert_eq!(aos[v as usize].accesses, {
                let vi = v as usize;
                soa.accesses[vi]
            });
            assert!(
                (aos[v as usize].row_hit_rate() - soa.row_hit_rate(v)).abs() < 1e-12
            );
        }
    }

    #[test]
    fn vault_array_matches_vault_mem_after_reset() {
        let cfg = SimConfig::hbm();
        let mut soa = VaultArray::new(&cfg);
        let mut aos: Vec<VaultMem> =
            (0..cfg.n_vaults).map(|_| VaultMem::new(&cfg)).collect();
        let accs = storm(cfg.n_vaults);
        for &(v, addr, at) in &accs {
            aos[v as usize].access(addr, at);
            soa.access(v, addr, at);
        }
        soa.reset();
        for m in &mut aos {
            m.reset();
        }
        for (v, addr, at) in accs {
            let a = aos[v as usize].access(addr, at);
            let b = soa.access(v, addr, at);
            assert_eq!(a, b, "post-reset divergence at vault {v}");
        }
    }
}

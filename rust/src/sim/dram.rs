//! Vault-local DRAM timing: single-ported vault controller in front of a
//! set of banks with an open-page row-buffer policy.
//!
//! Table I: 8 banks/vault, 256 B row buffer, open-page policy. The vault
//! controller accepts one request per cycle (§II-C: "each vault can only
//! serve one location per cycle"); a request then occupies its bank for the
//! row-hit or row-miss array time. Waits at the controller port and at a
//! busy bank are *queuing delay*; the array time itself is the third
//! component of the paper's latency breakdown.

use crate::config::SimConfig;
use crate::{Addr, Cycle};

/// Timing decomposition of one array access.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MemAccess {
    /// Cycle at which the data is available (read) or committed (write).
    pub done: Cycle,
    /// Cycles spent queued at the controller port and at a busy bank.
    pub queued: u64,
    /// Array access cycles (row hit or row miss).
    pub array: u64,
    /// Whether the access hit the open row.
    pub row_hit: bool,
}

#[derive(Clone, Copy, Debug)]
struct Bank {
    busy_until: Cycle,
    open_row: u64,
}

/// One vault's memory: controller port + banks.
pub struct VaultMem {
    banks: Vec<Bank>,
    ctrl_free: Cycle,
    t_hit: u64,
    t_miss: u64,
    ctrl_occupancy: u64,
    row_bytes: u64,
    /// Row-hit / total counters (for reports and tests).
    pub hits: u64,
    pub accesses: u64,
}

impl VaultMem {
    pub fn new(cfg: &SimConfig) -> Self {
        VaultMem {
            banks: vec![
                Bank { busy_until: 0, open_row: u64::MAX };
                cfg.banks_per_vault as usize
            ],
            ctrl_free: 0,
            t_hit: cfg.t_row_hit as u64,
            t_miss: cfg.t_row_miss as u64,
            ctrl_occupancy: cfg.vault_service_cycles as u64,
            row_bytes: cfg.row_buffer_bytes as u64,
            hits: 0,
            accesses: 0,
        }
    }

    pub fn reset(&mut self) {
        for b in &mut self.banks {
            b.busy_until = 0;
            b.open_row = u64::MAX;
        }
        self.ctrl_free = 0;
        self.hits = 0;
        self.accesses = 0;
    }

    /// Serve one block access arriving at the vault at cycle `at`.
    pub fn access(&mut self, addr: Addr, at: Cycle) -> MemAccess {
        // Controller port: single request per service slot.
        let ctrl_start = at.max(self.ctrl_free);
        self.ctrl_free = ctrl_start + self.ctrl_occupancy;

        let row = addr / self.row_bytes;
        // XOR-folded bank index (standard bank hashing): plain `row % n`
        // degenerates under the vault interleave — a core's stream touches
        // this vault every `n_vaults` blocks, a row stride that is a
        // multiple of the bank count, serializing on one bank.
        let bank_idx = ((row ^ (row >> 3) ^ (row >> 7)) % self.banks.len() as u64) as usize;
        let bank = &mut self.banks[bank_idx];

        let bank_start = ctrl_start.max(bank.busy_until);
        let row_hit = bank.open_row == row;
        let array = if row_hit { self.t_hit } else { self.t_miss };
        let done = bank_start + array;
        bank.busy_until = done;
        bank.open_row = row;

        self.accesses += 1;
        if row_hit {
            self.hits += 1;
        }
        MemAccess {
            done,
            queued: (ctrl_start - at) + (bank_start - ctrl_start),
            array,
            row_hit,
        }
    }

    /// Fraction of accesses that hit the open row so far.
    pub fn row_hit_rate(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.hits as f64 / self.accesses as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mem() -> VaultMem {
        VaultMem::new(&SimConfig::hmc())
    }

    #[test]
    fn first_access_is_row_miss() {
        let mut m = mem();
        let a = m.access(0, 0);
        assert!(!a.row_hit);
        assert_eq!(a.array, 38);
        assert_eq!(a.done, 38);
    }

    #[test]
    fn same_row_hits_after_open() {
        let mut m = mem();
        let first = m.access(0, 0);
        let second = m.access(64, first.done); // same 256 B row
        assert!(second.row_hit);
        assert_eq!(second.array, 14);
    }

    /// Bank index for a row under the XOR fold (mirrors `access`).
    fn bank_of(row: u64, nbanks: u64) -> u64 {
        (row ^ (row >> 3) ^ (row >> 7)) % nbanks
    }

    #[test]
    fn different_row_same_bank_queues_and_misses() {
        let mut m = mem();
        let n = m.banks.len() as u64;
        // Find another row that hashes to bank_of(row 0).
        let target = bank_of(0, n);
        let row2 = (1..512).find(|&r| bank_of(r, n) == target).unwrap();
        let a = m.access(0, 0);
        let b = m.access(256 * row2, 1);
        assert!(!b.row_hit);
        assert!(b.queued > 0, "must wait for busy bank");
        assert_eq!(b.done, a.done + 38);
    }

    #[test]
    fn different_banks_overlap() {
        let mut m = mem();
        let n = m.banks.len() as u64;
        let b0 = bank_of(0, n);
        let row2 = (1..512).find(|&r| bank_of(r, n) != b0).unwrap();
        let a = m.access(0, 0);
        let b = m.access(256 * row2, 1); // different bank
        // b waits only for the controller slot, not for bank 0.
        assert_eq!(b.queued, 0);
        assert!(b.done < a.done + 38);
    }

    #[test]
    fn controller_serializes_same_cycle_arrivals() {
        let mut m = mem();
        let a = m.access(0, 0);
        let b = m.access(256, 0); // different bank, same arrival cycle
        assert_eq!(a.queued, 0);
        assert_eq!(b.queued, 1, "one-per-cycle controller port");
    }

    #[test]
    fn hit_rate_tracks() {
        let mut m = mem();
        m.access(0, 0);
        m.access(0, 100);
        m.access(0, 200);
        assert!((m.row_hit_rate() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn reset_restores_cold_state() {
        let mut m = mem();
        m.access(0, 0);
        m.reset();
        let a = m.access(0, 0);
        assert!(!a.row_hit);
        assert_eq!(m.accesses, 1);
    }
}

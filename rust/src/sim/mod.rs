//! The memory-system substrate: FLIT packets, DRAM bank timing, the
//! physical address map, and the shared link-calendar primitive (plus the
//! legacy standalone [`Mesh`]).
//!
//! Simulations do not use these pieces directly any more: they are owned
//! and orchestrated by [`crate::memsys::MemorySystem`], and the network is
//! abstracted behind [`crate::memsys::Interconnect`] (mesh, crossbar or
//! ring, selected by `SimConfig::topology`). What remains here is the
//! physics — [`network::LinkCal`]'s busy-interval reservation that all
//! topologies share, [`VaultMem`]'s controller/bank model and the
//! [`AddressMap`]. [`Mesh`] is kept as the reference implementation of the
//! XY walk; `memsys::MeshInterconnect` precomputes its routes and is
//! asserted bit-identical against it.
//!
//! ## Simulation model
//!
//! This is a *resource-reservation* discrete-event model (in the LogGOPSim
//! family): every contended resource — a directed mesh link, a vault
//! controller port, a DRAM bank — carries a `next_free` cycle counter.
//! A memory request is simulated as a chain of resource acquisitions; each
//! acquisition starts at `max(now, resource.next_free)` and bumps the
//! counter by the resource's occupancy (FLIT serialization for links,
//! one cycle for the single-ported vault controller, the array access time
//! for banks). The driver processes core events in global time order, so
//! reservations are causally consistent.
//!
//! The model reproduces exactly the three latency components the paper
//! decomposes (Fig 1 / Fig 2):
//! * **data-transfer (network) latency** — FLIT serialization x hops,
//! * **queuing delay** — waits on busy links / controllers / banks,
//! * **array access latency** — row-hit or row-miss bank time.
//!
//! Finite router input buffers (16 entries, §II-C) appear as the growing
//! `next_free` horizon of a congested link: senders queue behind it, which
//! is the same first-order effect as credit-based backpressure. The paper's
//! per-hop cost model — a k-FLIT packet costs k cycles per hop, so a read
//! costs `(k+1)·h_ro` uncontended (§III-C) — is matched exactly.

pub mod dram;
pub mod memmap;
pub mod network;
pub mod packet;

pub use dram::{VaultArray, VaultMem};
pub use memmap::AddressMap;
pub use network::{Mesh, Transfer};
pub use packet::PacketKind;

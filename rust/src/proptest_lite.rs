//! Property-testing harness (proptest is unavailable offline).
//!
//! A seeded generator + predicate runner: properties are checked over
//! thousands of pseudo-random scenarios; on failure the harness reports
//! the failing case number and seed so the exact scenario replays
//! deterministically (`Runner::new(seed).case(n)`).

use crate::rng::Rng;

/// Configuration of one property run.
pub struct Runner {
    seed: u64,
    cases: usize,
}

impl Runner {
    pub fn new(seed: u64) -> Self {
        Runner { seed, cases: 256 }
    }

    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    /// The RNG for case `i` (replays a failure in isolation).
    pub fn case(&self, i: usize) -> Rng {
        Rng::new(self.seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Check `prop` over all cases; panics with the case index and seed on
    /// the first failure.
    pub fn run<F>(&self, name: &str, mut prop: F)
    where
        F: FnMut(&mut Rng) -> Result<(), String>,
    {
        for i in 0..self.cases {
            let mut rng = self.case(i);
            if let Err(msg) = prop(&mut rng) {
                panic!(
                    "property {name:?} failed at case {i} (seed {:#x}): {msg}",
                    self.seed
                );
            }
        }
    }
}

/// Generator helpers over [`Rng`].
pub mod gen {
    use crate::rng::Rng;

    pub fn usize_in(r: &mut Rng, lo: usize, hi: usize) -> usize {
        r.range(lo as u64, hi as u64) as usize
    }

    pub fn u64_in(r: &mut Rng, lo: u64, hi: u64) -> u64 {
        r.range(lo, hi)
    }

    pub fn pick<'a, T>(r: &mut Rng, xs: &'a [T]) -> &'a T {
        &xs[r.below(xs.len() as u64) as usize]
    }

    pub fn bool_p(r: &mut Rng, p: f64) -> bool {
        r.chance(p)
    }

    /// A vector of `n` draws.
    pub fn vec_of<T>(r: &mut Rng, n: usize, mut f: impl FnMut(&mut Rng) -> T) -> Vec<T> {
        (0..n).map(|_| f(r)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        Runner::new(1).cases(100).run("x<=x", |r| {
            let x = r.next_u64();
            if x <= x {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "failed at case")]
    fn reports_failing_case() {
        Runner::new(2).cases(50).run("always-false", |_r| Err("nope".into()));
    }

    #[test]
    fn cases_are_deterministic() {
        let r = Runner::new(3);
        let a = r.case(7).next_u64();
        let b = r.case(7).next_u64();
        assert_eq!(a, b);
    }

    #[test]
    fn gen_helpers_in_range() {
        let mut rng = Runner::new(4).case(0);
        for _ in 0..100 {
            let v = gen::usize_in(&mut rng, 3, 9);
            assert!((3..9).contains(&v));
        }
        let xs = [1, 2, 3];
        assert!(xs.contains(gen::pick(&mut rng, &xs)));
    }
}

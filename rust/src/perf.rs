//! The pinned performance trajectory: the end-to-end serve-throughput
//! benchmark behind `repro bench` and the `BENCH_*.json` artifacts.
//!
//! Every figure is a sweep over millions of `MemorySystem::serve` calls,
//! so the simulator's own speed is a first-class artifact. This module
//! pins one benchmark — fixed seed, fixed scale, fixed workload, one run
//! per topology — and renders the result as a small JSON document
//! (`BENCH_<PR>.json`) that CI uploads and diffs against the checked-in
//! baseline at the repository root. The gate fails a PR whose headline
//! `serve_ops_per_sec` regresses by more than
//! [`DEFAULT_REGRESSION_PCT`] percent; `docs/BENCHMARKING.md` describes
//! the workflow, the schema and how to update a legitimate change.
//!
//! Nothing here feeds figures: the simulated *results* are governed by
//! the bit-identity tests; this module only measures wall-clock.

use crate::benchkit::{self, Timing};
use crate::config::{SimConfig, Topology};
use crate::coordinator::driver::simulate_once;
use crate::coordinator::kernel::Kernel;
use crate::policy::PolicyKind;
use crate::sweep::shard::ShardRunner;
use crate::sweep::store::DiskStore;
use crate::sweep::SweepPoint;
use crate::workloads::catalog;

/// Format version of the emitted JSON document (2 added the
/// `threads`/`thread_scaling` kernel-scaling series; 3 added the
/// `workers`/`shard_scaling` multi-worker sweep series).
pub const SCHEMA_VERSION: u32 = 3;
/// The checked-in baseline at the repository root that `repro bench
/// --promote` rewrites and CI gates against.
pub const BASELINE_FILE: &str = "BENCH_8.json";
/// Fixed seed: the trajectory must measure the same simulated work in
/// every PR.
pub const BENCH_SEED: u64 = 0xD11;
/// Warmup requests per point (served through the same hot path; the
/// boundary only resets counters, so they count as served work).
pub const BENCH_WARMUP: u64 = 5_000;
/// Measured requests per point.
pub const BENCH_MEASURE: u64 = 50_000;
/// Timed iterations per point (median taken).
pub const BENCH_ITERS: usize = 5;
/// The pinned workload (high reuse, exercises the subscription protocol).
pub const BENCH_WORKLOAD: &str = "SPLRad";
/// CI gate: maximum tolerated `serve_ops_per_sec` drop, in percent.
pub const DEFAULT_REGRESSION_PCT: f64 = 10.0;
/// Environment variable that skips the bench entirely (underpowered or
/// noisy runners).
pub const SKIP_ENV: &str = "REPRO_BENCH_SKIP";
/// Kernel thread counts of the scaling series.
pub const THREAD_COUNTS: &[usize] = &[1, 2, 4, 8];
/// Warmup requests per run in the thread-scaling series (smaller than the
/// serve-hotpath points: the series multiplies by runs and thread counts).
pub const THREAD_BENCH_WARMUP: u64 = 2_000;
/// Measured requests per run in the thread-scaling series.
pub const THREAD_BENCH_MEASURE: u64 = 20_000;
/// Independent runs fanned across the kernel's threads per timed
/// iteration (the unit of parallelism being measured).
pub const THREAD_BENCH_RUNS: u32 = 8;
/// Timed iterations per thread count (median taken).
pub const THREAD_BENCH_ITERS: usize = 3;
/// Worker counts of the shard-scaling series.
pub const SHARD_WORKER_COUNTS: &[usize] = &[1, 2, 4];
/// Workloads of the pinned shard-scaling sweep (crossed with the
/// never/adaptive policy pair → 6 points per timed iteration).
pub const SHARD_BENCH_WORKLOADS: &[&str] = &["SPLRad", "PHELinReg", "STRTriad"];
/// Warmup requests per point in the shard-scaling series (small: the
/// series multiplies by points and worker counts).
pub const SHARD_BENCH_WARMUP: u64 = 1_000;
/// Measured requests per point in the shard-scaling series.
pub const SHARD_BENCH_MEASURE: u64 = 10_000;
/// Timed iterations per worker count (median taken).
pub const SHARD_BENCH_ITERS: usize = 3;

/// One measured (topology, policy) point of the trajectory.
pub struct BenchPoint {
    pub topology: &'static str,
    pub policy: &'static str,
    /// Memory requests served per iteration (measured + warmup).
    pub requests: u64,
    pub timing: Timing,
}

impl BenchPoint {
    pub fn ops_per_sec(&self) -> f64 {
        if self.timing.median_ns <= 0.0 {
            return 0.0;
        }
        self.requests as f64 / (self.timing.median_ns / 1e9)
    }

    pub fn ns_per_access(&self) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        self.timing.median_ns / self.requests as f64
    }
}

/// One thread count of the kernel-scaling series: [`THREAD_BENCH_RUNS`]
/// independent runs fanned across `threads` via
/// [`Kernel::simulate_runs`], timed end to end.
pub struct ThreadPoint {
    pub threads: usize,
    /// Runs per timed iteration (each is one full simulation).
    pub runs: u32,
    pub timing: Timing,
}

impl ThreadPoint {
    /// Full simulations completed per second at this thread count.
    pub fn sims_per_sec(&self) -> f64 {
        if self.timing.median_ns <= 0.0 {
            return 0.0;
        }
        self.runs as f64 / (self.timing.median_ns / 1e9)
    }
}

/// One worker count of the shard-scaling series: the pinned sweep grid
/// executed cooperatively by `workers` in-process shard runners over a
/// fresh store, timed end to end (claims, simulations and report
/// flushes included — the protocol overhead is what the series exists
/// to watch).
pub struct ShardPoint {
    pub workers: usize,
    /// Sweep points per timed iteration.
    pub points: usize,
    pub timing: Timing,
}

impl ShardPoint {
    /// Sweep points completed per second at this worker count.
    pub fn points_per_sec(&self) -> f64 {
        if self.timing.median_ns <= 0.0 {
            return 0.0;
        }
        self.points as f64 / (self.timing.median_ns / 1e9)
    }
}

/// The full trajectory measurement (one [`BenchPoint`] per config, plus
/// the kernel thread-scaling and shard worker-scaling series — empty
/// when only the serve-hotpath points were measured, e.g. from
/// [`run_with_scale`]).
pub struct BenchReport {
    pub points: Vec<BenchPoint>,
    pub threads: Vec<ThreadPoint>,
    pub shards: Vec<ShardPoint>,
    pub warmup_requests: u64,
    pub measure_requests: u64,
}

impl BenchReport {
    /// Headline number: total requests over total median wall time.
    pub fn serve_ops_per_sec(&self) -> f64 {
        let reqs: f64 = self.points.iter().map(|p| p.requests as f64).sum();
        let secs: f64 = self.points.iter().map(|p| p.timing.median_ns / 1e9).sum();
        if secs <= 0.0 {
            0.0
        } else {
            reqs / secs
        }
    }

    pub fn ns_per_access(&self) -> f64 {
        let ops = self.serve_ops_per_sec();
        if ops <= 0.0 {
            0.0
        } else {
            1e9 / ops
        }
    }

    /// Render the `BENCH_*.json` document (hand-rolled: the crate is
    /// dependency-free). The headline keys come before `points`, so the
    /// first `serve_ops_per_sec` occurrence in the text is the headline —
    /// [`parse_baseline`] relies on that.
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(1024);
        s.push_str("{\n");
        s.push_str(&format!("  \"schema\": {SCHEMA_VERSION},\n"));
        s.push_str("  \"bench\": \"serve_hotpath\",\n");
        s.push_str(&format!("  \"workload\": \"{BENCH_WORKLOAD}\",\n"));
        s.push_str(&format!("  \"seed\": {BENCH_SEED},\n"));
        s.push_str(&format!("  \"warmup_requests\": {},\n", self.warmup_requests));
        s.push_str(&format!("  \"measure_requests\": {},\n", self.measure_requests));
        s.push_str(&format!("  \"iters\": {BENCH_ITERS},\n"));
        s.push_str("  \"provisional\": false,\n");
        s.push_str(&format!(
            "  \"serve_ops_per_sec\": {},\n",
            json_num(self.serve_ops_per_sec())
        ));
        s.push_str(&format!("  \"ns_per_access\": {},\n", json_num(self.ns_per_access())));
        s.push_str("  \"points\": [\n");
        for (i, p) in self.points.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"topology\": \"{}\", \"policy\": \"{}\", \"requests\": {}, \
                 \"median_ms\": {}, \"mad_ms\": {}, \"serve_ops_per_sec\": {}, \
                 \"ns_per_access\": {}}}{}\n",
                p.topology,
                p.policy,
                p.requests,
                json_num(p.timing.median_ns / 1e6),
                json_num(p.timing.mad_ns / 1e6),
                json_num(p.ops_per_sec()),
                json_num(p.ns_per_access()),
                if i + 1 == self.points.len() { "" } else { "," }
            ));
        }
        s.push_str("  ],\n");
        s.push_str(&format!(
            "  \"threads\": [{}],\n",
            THREAD_COUNTS.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(", ")
        ));
        s.push_str("  \"thread_scaling\": [\n");
        for (i, p) in self.threads.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"threads\": {}, \"runs\": {}, \"median_ms\": {}, \
                 \"mad_ms\": {}, \"sims_per_sec\": {}}}{}\n",
                p.threads,
                p.runs,
                json_num(p.timing.median_ns / 1e6),
                json_num(p.timing.mad_ns / 1e6),
                json_num(p.sims_per_sec()),
                if i + 1 == self.threads.len() { "" } else { "," }
            ));
        }
        s.push_str("  ],\n");
        s.push_str(&format!(
            "  \"workers\": [{}],\n",
            SHARD_WORKER_COUNTS.iter().map(|w| w.to_string()).collect::<Vec<_>>().join(", ")
        ));
        // Rows use `points_per_sec`, never `serve_ops_per_sec`: the
        // first occurrence of the headline key in the document must stay
        // the headline ([`parse_baseline`] takes the first match).
        s.push_str("  \"shard_scaling\": [\n");
        for (i, p) in self.shards.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"workers\": {}, \"points\": {}, \"median_ms\": {}, \
                 \"mad_ms\": {}, \"points_per_sec\": {}}}{}\n",
                p.workers,
                p.points,
                json_num(p.timing.median_ns / 1e6),
                json_num(p.timing.mad_ns / 1e6),
                json_num(p.points_per_sec()),
                if i + 1 == self.shards.len() { "" } else { "," }
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }
}

/// Finite-and-plain float formatting (JSON has no NaN/Inf).
fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.3}")
    } else {
        "0.000".to_string()
    }
}

fn bench_cfg(topology: Topology, policy: PolicyKind, warmup: u64, measure: u64) -> SimConfig {
    let mut cfg = SimConfig::hmc();
    cfg.topology = topology;
    cfg.policy = policy;
    cfg.seed = BENCH_SEED;
    cfg.warmup_requests = warmup;
    cfg.measure_requests = measure;
    cfg.runs = 1;
    cfg
}

/// Measure one (topology, policy) point: `iters` timed full simulations
/// (workload reseed included — it is part of driving the hot path).
fn measure_point(
    topology: Topology,
    policy: PolicyKind,
    warmup: u64,
    measure: u64,
    iters: usize,
) -> BenchPoint {
    let cfg = bench_cfg(topology, policy, warmup, measure);
    debug_assert!(cfg.validate().is_ok());
    let mut w = catalog::build(BENCH_WORKLOAD, &cfg).expect("pinned workload exists");
    let mut requests = 0u64;
    let timing = benchkit::time(1, iters, || {
        w.reset(cfg.seed);
        let rep = simulate_once(&cfg, w.as_mut());
        // Warmup requests went through the same serve path; the boundary
        // reset only wiped their counters.
        requests = rep.stats.requests + cfg.warmup_requests;
    });
    BenchPoint {
        topology: topology.as_str(),
        policy: policy.as_str(),
        requests,
        timing,
    }
}

/// The pinned trajectory: mesh baseline (no subscriptions) plus the
/// adaptive policy over all three topologies, on the HMC preset —
/// followed by the kernel thread-scaling series at [`THREAD_COUNTS`].
pub fn run_trajectory() -> BenchReport {
    let mut rep = run_with_scale(BENCH_WARMUP, BENCH_MEASURE, BENCH_ITERS);
    rep.threads = thread_scaling(
        THREAD_BENCH_WARMUP,
        THREAD_BENCH_MEASURE,
        THREAD_BENCH_RUNS,
        THREAD_BENCH_ITERS,
    );
    rep.shards = shard_scaling(SHARD_BENCH_WARMUP, SHARD_BENCH_MEASURE, SHARD_BENCH_ITERS);
    rep
}

/// Measure the shard protocol's worker scaling: for each entry of
/// [`SHARD_WORKER_COUNTS`], time the pinned sweep grid executed
/// cooperatively by that many in-process [`ShardRunner`]s over one
/// fresh store directory per iteration. In-process workers keep the
/// measurement hermetic (no subprocess spawn noise) and the shard run
/// path never consults the in-memory report cache, so every iteration
/// simulates the full grid from scratch; cross-*process* correctness is
/// covered by `tests/shard_sweep.rs` and CI's `--workers 3` figure leg.
pub fn shard_scaling(warmup: u64, measure: u64, iters: usize) -> Vec<ShardPoint> {
    let mut points = Vec::new();
    for wl in SHARD_BENCH_WORKLOADS {
        for policy in [PolicyKind::Never, PolicyKind::Adaptive] {
            let cfg = bench_cfg(Topology::Mesh, policy, warmup, measure);
            debug_assert!(cfg.validate().is_ok());
            points.push(SweepPoint::new(*wl, cfg));
        }
    }
    static DIR_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    SHARD_WORKER_COUNTS
        .iter()
        .map(|&workers| {
            let timing = benchkit::time(1, iters, || {
                let dir = std::env::temp_dir().join(format!(
                    "dlpim-shardbench-{}-{}",
                    std::process::id(),
                    DIR_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
                ));
                std::thread::scope(|s| {
                    for i in 0..workers {
                        let store = DiskStore::at(dir.as_path());
                        let points = &points;
                        s.spawn(move || {
                            let runner = ShardRunner::new(
                                store,
                                format!("bench-{i}"),
                                crate::sweep::shard::DEFAULT_TTL,
                            );
                            runner.run(points).expect("shard bench sweep");
                        });
                    }
                });
                let _ = std::fs::remove_dir_all(&dir);
            });
            ShardPoint { workers, points: points.len(), timing }
        })
        .collect()
}

/// Measure the kernel's run-level scaling: for each entry of
/// [`THREAD_COUNTS`], time `runs` independent simulations fanned across
/// that many threads via [`Kernel::simulate_runs`] (mesh/adaptive, the
/// most protocol-heavy pinned point). Simulated results are bit-identical
/// at every thread count — `tests/kernel_equivalence.rs` pins that — so
/// this series measures wall-clock only.
pub fn thread_scaling(warmup: u64, measure: u64, runs: u32, iters: usize) -> Vec<ThreadPoint> {
    let mut cfg = bench_cfg(Topology::Mesh, PolicyKind::Adaptive, warmup, measure);
    cfg.runs = runs;
    debug_assert!(cfg.validate().is_ok());
    THREAD_COUNTS
        .iter()
        .map(|&t| {
            let kernel = Kernel::new(t);
            let timing = benchkit::time(1, iters, || {
                let rep = kernel.simulate_runs(&cfg, BENCH_WORKLOAD, || {
                    catalog::build(BENCH_WORKLOAD, &cfg).expect("pinned workload exists")
                });
                assert_eq!(rep.runs.len(), runs as usize);
            });
            ThreadPoint { threads: t, runs, timing }
        })
        .collect()
}

/// [`run_trajectory`] at an explicit scale (tests and the `perf_hotpath`
/// bench use smaller/faster settings; `BENCH_*.json` artifacts must come
/// from the pinned constants).
pub fn run_with_scale(warmup: u64, measure: u64, iters: usize) -> BenchReport {
    let mut points = vec![measure_point(
        Topology::Mesh,
        PolicyKind::Never,
        warmup,
        measure,
        iters,
    )];
    for topo in [Topology::Mesh, Topology::Crossbar, Topology::Ring] {
        points.push(measure_point(topo, PolicyKind::Adaptive, warmup, measure, iters));
    }
    BenchReport {
        points,
        threads: Vec::new(),
        shards: Vec::new(),
        warmup_requests: warmup,
        measure_requests: measure,
    }
}

/// The comparison-relevant part of a checked-in `BENCH_*.json`.
pub struct Baseline {
    pub serve_ops_per_sec: f64,
    /// A provisional baseline records the schema without a trusted
    /// measurement (e.g. first commit from an environment that cannot
    /// run the bench); the gate records but does not compare.
    pub provisional: bool,
}

/// Extract the first numeric value of `"key": <number>` in `text`.
/// A full JSON parser is not needed: the schema is flat, emitted by
/// [`BenchReport::to_json`], and the headline keys precede `points`.
pub fn extract_number(text: &str, key: &str) -> Option<f64> {
    let needle = format!("\"{key}\"");
    let at = text.find(&needle)? + needle.len();
    let rest = text[at..].trim_start().strip_prefix(':')?.trim_start();
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || c == '.' || c == '-' || c == '+' || c == 'e' || c == 'E'))
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// Parse the baseline fields out of a checked-in `BENCH_*.json`.
pub fn parse_baseline(text: &str) -> Result<Baseline, String> {
    let ops = extract_number(text, "serve_ops_per_sec")
        .ok_or_else(|| "baseline has no serve_ops_per_sec".to_string())?;
    let provisional = extract_bool(text, "provisional").unwrap_or(false);
    Ok(Baseline { serve_ops_per_sec: ops, provisional })
}

fn extract_bool(text: &str, key: &str) -> Option<bool> {
    let needle = format!("\"{key}\"");
    let at = text.find(&needle)? + needle.len();
    let rest = text[at..].trim_start().strip_prefix(':')?.trim_start();
    if rest.starts_with("true") {
        Some(true)
    } else if rest.starts_with("false") {
        Some(false)
    } else {
        None
    }
}

/// The CI regression gate: compare a fresh headline against the baseline.
/// `Ok` carries a status line to print; `Err` carries the failure text.
/// Provisional or non-positive baselines record without comparing (there
/// is nothing trustworthy to compare against).
pub fn check_regression(
    current_ops: f64,
    baseline: &Baseline,
    threshold_pct: f64,
) -> Result<String, String> {
    if baseline.provisional || baseline.serve_ops_per_sec <= 0.0 {
        return Ok(format!(
            "baseline is provisional — record-only, gate skipped \
             (recorded {current_ops:.0} ops/s; promote the baseline per \
             docs/BENCHMARKING.md to arm the gate)"
        ));
    }
    let delta_pct = (current_ops / baseline.serve_ops_per_sec - 1.0) * 100.0;
    if delta_pct < -threshold_pct {
        Err(format!(
            "serve_ops_per_sec {current_ops:.0} is {:.1}% below baseline {:.0} \
             (threshold {threshold_pct:.0}%)",
            -delta_pct, baseline.serve_ops_per_sec
        ))
    } else {
        Ok(format!(
            "{delta_pct:+.1}% vs baseline {:.0} ops/s (threshold -{threshold_pct:.0}%)",
            baseline.serve_ops_per_sec
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn micro_trajectory_measures_and_serializes() {
        // A tiny-scale run: the pinned constants are too slow for unit
        // tests, but the machinery is identical.
        let rep = run_with_scale(100, 500, 1);
        assert_eq!(rep.points.len(), 4);
        for p in &rep.points {
            assert!(p.requests >= 600, "{}/{}: {}", p.topology, p.policy, p.requests);
            assert!(p.ops_per_sec() > 0.0);
        }
        assert!(rep.serve_ops_per_sec() > 0.0);
        let json = rep.to_json();
        for key in [
            "\"schema\"",
            "\"serve_ops_per_sec\"",
            "\"ns_per_access\"",
            "\"points\"",
            "\"topology\": \"mesh\"",
            "\"topology\": \"crossbar\"",
            "\"topology\": \"ring\"",
            "\"threads\": [1, 2, 4, 8]",
            "\"thread_scaling\"",
            "\"workers\": [1, 2, 4]",
            "\"shard_scaling\"",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        // Round-trip: the emitted headline parses back as a baseline.
        let base = parse_baseline(&json).unwrap();
        assert!(!base.provisional);
        assert!((base.serve_ops_per_sec - rep.serve_ops_per_sec()).abs()
            / rep.serve_ops_per_sec()
            < 0.01);
    }

    #[test]
    fn micro_thread_scaling_measures_every_count() {
        // Tiny scale again: the series' shape and serialization, not its
        // wall-clock, are what unit tests can check.
        let pts = thread_scaling(50, 200, 2, 1);
        assert_eq!(pts.len(), THREAD_COUNTS.len());
        for p in &pts {
            assert!(p.sims_per_sec() > 0.0, "threads={}", p.threads);
            assert_eq!(p.runs, 2);
        }
        let rep = BenchReport {
            points: Vec::new(),
            threads: pts,
            shards: Vec::new(),
            warmup_requests: 50,
            measure_requests: 200,
        };
        let json = rep.to_json();
        for t in THREAD_COUNTS {
            assert!(json.contains(&format!("\"threads\": {t},")), "row for {t}");
        }
    }

    #[test]
    fn micro_shard_scaling_measures_every_worker_count() {
        // Tiny scale: shape and serialization, not wall-clock. Each
        // iteration runs the full pinned grid on a fresh store.
        let pts = shard_scaling(50, 200, 1);
        assert_eq!(pts.len(), SHARD_WORKER_COUNTS.len());
        for p in &pts {
            assert_eq!(p.points, SHARD_BENCH_WORKLOADS.len() * 2);
            assert!(p.points_per_sec() > 0.0, "workers={}", p.workers);
        }
        let rep = BenchReport {
            points: Vec::new(),
            threads: Vec::new(),
            shards: pts,
            warmup_requests: 50,
            measure_requests: 200,
        };
        let json = rep.to_json();
        for w in SHARD_WORKER_COUNTS {
            assert!(json.contains(&format!("{{\"workers\": {w},")), "row for {w}");
        }
        assert!(json.contains("\"points_per_sec\""));
    }

    #[test]
    fn extractors_read_flat_json() {
        let text = "{\n  \"provisional\": true,\n  \"serve_ops_per_sec\": 1234.5,\n}";
        assert_eq!(extract_number(text, "serve_ops_per_sec"), Some(1234.5));
        assert_eq!(extract_bool(text, "provisional"), Some(true));
        assert_eq!(extract_number(text, "missing"), None);
        let b = parse_baseline(text).unwrap();
        assert!(b.provisional);
    }

    #[test]
    fn regression_gate_logic() {
        let base = Baseline { serve_ops_per_sec: 1000.0, provisional: false };
        assert!(check_regression(990.0, &base, 10.0).is_ok(), "-1% passes");
        assert!(check_regression(1500.0, &base, 10.0).is_ok(), "faster passes");
        assert!(check_regression(905.0, &base, 10.0).is_ok(), "-9.5% passes");
        assert!(check_regression(850.0, &base, 10.0).is_err(), "-15% fails");
        let prov = Baseline { serve_ops_per_sec: 0.0, provisional: true };
        assert!(check_regression(1.0, &prov, 10.0).is_ok(), "provisional never gates");
    }
}

//! `repro` — the DL-PIM launcher: run simulations, regenerate paper
//! figures, inspect configs and artifacts.

use std::path::Path;

use dlpim::cli::{self, Cli, HELP};
use dlpim::config::{presets, MemKind, SimConfig, Topology};
use dlpim::coordinator::driver::simulate;
use dlpim::coordinator::report::SimReport;
use dlpim::error::{bail, err, Result};
use dlpim::figures;
use dlpim::policy::PolicyKind;
use dlpim::runtime::ArtifactStore;
use dlpim::sweep;
use dlpim::trace::{self, transform, TraceData};
use dlpim::workloads::{self, catalog};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &[String]) -> Result<()> {
    let cli = Cli::parse(args).map_err(|e| err!(e))?;
    if matches!(cli.command.as_str(), "" | "help" | "--help" | "-h") {
        print!("{HELP}");
        return Ok(());
    }
    // Every known (sub)command declares its flag set; a typo'd flag fails
    // loudly with a did-you-mean instead of silently running defaults.
    let sub = (cli.command == "trace")
        .then(|| cli.positional.first().map(|s| s.as_str()))
        .flatten();
    if let Some(known) = cli::known_flags(&cli.command, sub) {
        cli.reject_unknown_flags(known).map_err(|e| err!(e))?;
    }
    match cli.command.as_str() {
        "run" => cmd_run(&cli),
        "figure" => cmd_figure(&cli),
        "all-figures" => cmd_all_figures(),
        "workloads" => cmd_workloads(),
        "config" => cmd_config(&cli),
        "trace" => cmd_trace(&cli),
        "artifacts" => cmd_artifacts(),
        other => bail!("unknown command {other:?}; try `repro help`"),
    }
}

fn config_from_cli(cli: &Cli) -> Result<SimConfig> {
    let mut cfg = if let Some(path) = cli.flag("config") {
        let text = std::fs::read_to_string(path)?;
        dlpim::config::parse::config_from_text(&text).map_err(|e| err!(e))?
    } else {
        let mem = cli.flag_or("memory", "hmc");
        SimConfig::preset(mem).ok_or_else(|| err!("unknown memory {mem:?}"))?
    };
    if let Some(p) = cli.flag("policy") {
        cfg.policy = PolicyKind::parse(p).ok_or_else(|| err!("unknown policy {p:?}"))?;
    }
    if let Some(t) = cli.flag("topology") {
        cfg.topology = Topology::parse(t)
            .ok_or_else(|| err!("unknown topology {t:?} (mesh|crossbar|ring)"))?;
    }
    if cli.has("quick") {
        cfg = cfg.quick();
    }
    if cli.has("paper-scale") {
        cfg = cfg.paper_scale();
    }
    if let Some(v) = cli.flag_u64("warmup").map_err(|e| err!(e))? {
        cfg.warmup_requests = v;
    }
    if let Some(v) = cli.flag_u64("measure").map_err(|e| err!(e))? {
        cfg.measure_requests = v;
    }
    if let Some(v) = cli.flag_u64("runs").map_err(|e| err!(e))? {
        cfg.runs = v as u32;
    }
    if let Some(v) = cli.flag_u64("seed").map_err(|e| err!(e))? {
        cfg.seed = v;
    }
    if let Some(v) = cli.flag_u64("epoch").map_err(|e| err!(e))? {
        cfg.epoch_cycles = v;
    }
    if let Some(t) = cli.flag("trace") {
        cfg.trace = Some(t.to_string());
    }
    if cli.has("no-loop") {
        cfg.trace_loop = false;
    }
    cfg.validate().map_err(|e| err!("invalid config: {}", e.join("; ")))?;
    Ok(cfg)
}

fn cmd_run(cli: &Cli) -> Result<()> {
    let cfg = config_from_cli(cli)?;
    let t0 = std::time::Instant::now();
    let (name, rep) = if let Some(out) = cli.flag("record") {
        if cfg.trace.is_some() {
            bail!("--record captures a generator run; drop --trace (that file already is a recording)");
        }
        let name = cli
            .flag("workload")
            .ok_or_else(|| err!("--record requires --workload NAME"))?;
        let rep = trace::record_run(&cfg, name, Path::new(out)).map_err(|e| err!(e))?;
        println!("recorded        {out}");
        (name.to_string(), rep)
    } else {
        if cfg.trace.is_some() && cli.flag("workload").is_some() {
            bail!(
                "--workload and --trace are conflicting traffic sources; drop one \
                 (a trace file already names its recorded workload)"
            );
        }
        let w = workloads::build_source(cli.flag("workload"), &cfg).map_err(|e| err!(e))?;
        let name = w.name().to_string();
        (name, simulate(&cfg, w))
    };
    let dt = t0.elapsed();
    print_report(&name, &cfg, &rep);
    println!("wallclock       {:.2}s", dt.as_secs_f64());
    Ok(())
}

fn print_report(name: &str, cfg: &SimConfig, rep: &SimReport) {
    let (n, q, a) = rep.latency_fractions();
    println!("workload        {name}");
    println!("memory/policy   {}/{}", cfg.mem.as_str(), cfg.policy.as_str());
    println!("topology        {}", cfg.topology.as_str());
    println!("runs            {}", rep.runs.len());
    println!("cycles          {:.0}", rep.cycles());
    println!("avg latency     {:.1} cycles/request", rep.avg_latency());
    println!(
        "breakdown       network {:.1}% | queue {:.1}% | array {:.1}%",
        n * 100.0,
        q * 100.0,
        a * 100.0
    );
    let r0q = &rep.runs[0].stats;
    if r0q.queue_net + r0q.queue_mem > 0 {
        println!(
            "queue split     links {:.1}% | vault mem {:.1}%",
            r0q.queue_net as f64 / (r0q.queue_net + r0q.queue_mem) as f64 * 100.0,
            r0q.queue_mem as f64 / (r0q.queue_net + r0q.queue_mem) as f64 * 100.0
        );
    }
    println!("CoV             {:.3}", rep.cov());
    println!("traffic         {:.2} B/cycle", rep.bytes_per_cycle());
    let (rl, rr) = rep.reuse();
    println!("reuse/sub       local {rl:.2} remote {rr:.2}");
    println!("local fraction  {:.1}%", rep.local_fraction() * 100.0);
    let r0 = &rep.runs[0];
    println!(
        "protocol        subs {} | resubs {} | unsubs {} | nacks {}",
        r0.stats.subscriptions,
        r0.stats.resubscriptions,
        r0.stats.unsubscriptions,
        r0.stats.sub_nacks
    );
    println!("epochs          {}", r0.decisions.len());
}

fn cmd_workloads() -> Result<()> {
    println!("{:<10} {:<26} {:<36} {}", "Suite", "Benchmark", "Function", "Short");
    for e in &catalog::TABLE3 {
        println!("{:<10} {:<26} {:<36} {}", e.suite, e.benchmark, e.function, e.short);
    }
    println!("\nselected (non-negligible reuse): {}", catalog::SELECTED.join(" "));
    Ok(())
}

fn cmd_config(cli: &Cli) -> Result<()> {
    let cfg = config_from_cli(cli)?;
    print!("{}", presets::render(&cfg));
    Ok(())
}

/// `repro trace <record|replay|info|mix|dilate|remap>` — the trace
/// pipeline (see `dlpim::trace` for the format spec).
fn cmd_trace(cli: &Cli) -> Result<()> {
    let sub = cli.positional.first().map(|s| s.as_str()).unwrap_or("");
    match sub {
        "record" => {
            let mut cfg = config_from_cli(cli)?;
            let name = cli
                .flag("workload")
                .ok_or_else(|| err!("usage: repro trace record --workload NAME --out FILE"))?;
            let out = cli.flag("out").ok_or_else(|| err!("--out FILE required"))?;
            cfg.runs = 1; // the format stores one seed, one stream set
            let rep = trace::record_run(&cfg, name, Path::new(out)).map_err(|e| err!(e))?;
            let data = TraceData::load(Path::new(out)).map_err(|e| err!(e))?;
            println!("recorded        {name} -> {out}");
            println!(
                "captured        {} ops over {} cores ({} body bytes, {:.2} B/op)",
                data.total_ops(),
                data.n_cores(),
                data.body_bytes(),
                data.body_bytes() as f64 / data.total_ops().max(1) as f64
            );
            println!("served          {} memory requests", rep.runs[0].stats.requests);
            Ok(())
        }
        "replay" => {
            let file = cli
                .positional
                .get(1)
                .ok_or_else(|| err!("usage: repro trace replay FILE [config flags]"))?;
            let mut cfg = config_from_cli(cli)?;
            cfg.trace = Some(file.clone());
            let t0 = std::time::Instant::now();
            let w = workloads::build_source(None, &cfg).map_err(|e| err!(e))?;
            let name = w.name().to_string();
            let rep = simulate(&cfg, w);
            print_report(&name, &cfg, &rep);
            println!("wallclock       {:.2}s", t0.elapsed().as_secs_f64());
            Ok(())
        }
        "info" => {
            let file = cli
                .positional
                .get(1)
                .ok_or_else(|| err!("usage: repro trace info FILE"))?;
            let data = TraceData::load(Path::new(file)).map_err(|e| err!(e))?;
            let ops: Vec<u64> = (0..data.n_cores()).map(|c| data.core_ops(c)).collect();
            println!("trace           {file}");
            println!("format          DLPT v{}", dlpim::trace::VERSION);
            println!("workload        {}", data.meta.workload);
            println!(
                "recorded on     {}/{} with {} cores",
                data.meta.mem, data.meta.topology, data.meta.n_cores
            );
            println!("block bytes     {}", data.meta.block_bytes);
            println!("seed            {:#x}", data.meta.seed);
            println!("config hash     {:#018x}", data.meta.config_hash);
            println!(
                "ops             {} total | per core min {} max {}",
                data.total_ops(),
                ops.iter().min().unwrap(),
                ops.iter().max().unwrap()
            );
            println!(
                "encoded         {} body bytes ({:.2} B/op)",
                data.body_bytes(),
                data.body_bytes() as f64 / data.total_ops().max(1) as f64
            );
            Ok(())
        }
        "mix" => {
            let inputs = &cli.positional[1..];
            if inputs.len() < 2 {
                bail!("usage: repro trace mix IN1 IN2 [IN...] --out FILE [--weights A,B,..] [--cores N]");
            }
            let out = cli.flag("out").ok_or_else(|| err!("--out FILE required"))?;
            let weights: Vec<u64> = match cli.flag("weights") {
                None => vec![1; inputs.len()],
                Some(s) => s
                    .split(',')
                    .map(|x| {
                        x.trim()
                            .parse()
                            .map_err(|_| err!("--weights expects comma-separated integers, got {x:?}"))
                    })
                    .collect::<Result<_>>()?,
            };
            let data: Vec<TraceData> = inputs
                .iter()
                .map(|p| TraceData::load(Path::new(p)))
                .collect::<Result<_, String>>()
                .map_err(|e| err!(e))?;
            let cores = match cli.flag_u64("cores").map_err(|e| err!(e))? {
                Some(n) => u16::try_from(n)
                    .map_err(|_| err!("--cores {n} out of range (max {})", u16::MAX))?,
                None => data.iter().map(|d| d.n_cores()).max().unwrap(),
            };
            let mixed = transform::mix(&data, &weights, cores).map_err(|e| err!(e))?;
            mixed.save(Path::new(out)).map_err(|e| err!(e))?;
            println!(
                "mixed           {} tenants -> {out} ({} cores, {} ops)",
                inputs.len(),
                mixed.n_cores(),
                mixed.total_ops()
            );
            Ok(())
        }
        "dilate" => {
            let (input, out) = two_files(cli, "repro trace dilate IN OUT --factor F")?;
            let factor: f64 = cli
                .flag("factor")
                .ok_or_else(|| err!("--factor F required (e.g. 2.0 doubles compute gaps)"))?
                .parse()
                .map_err(|_| err!("--factor expects a number"))?;
            let data = TraceData::load(Path::new(input)).map_err(|e| err!(e))?;
            let dilated = transform::dilate(&data, factor).map_err(|e| err!(e))?;
            dilated.save(Path::new(out)).map_err(|e| err!(e))?;
            println!("dilated         {input} x{factor} -> {out}");
            Ok(())
        }
        "remap" => {
            let (input, out) = two_files(cli, "repro trace remap IN OUT --vaults N")?;
            let vaults = cli
                .flag_u64("vaults")
                .map_err(|e| err!(e))?
                .ok_or_else(|| err!("--vaults N required"))?;
            let vaults = u16::try_from(vaults)
                .map_err(|_| err!("--vaults {vaults} out of range (max {})", u16::MAX))?;
            let data = TraceData::load(Path::new(input)).map_err(|e| err!(e))?;
            let remapped = transform::remap(&data, vaults).map_err(|e| err!(e))?;
            remapped.save(Path::new(out)).map_err(|e| err!(e))?;
            println!(
                "remapped        {input} ({} cores) -> {out} ({} cores)",
                data.n_cores(),
                remapped.n_cores()
            );
            Ok(())
        }
        "" => bail!("usage: repro trace <record|replay|info|mix|dilate|remap>"),
        other => bail!("unknown trace subcommand {other:?} (record|replay|info|mix|dilate|remap)"),
    }
}

/// The `IN OUT` positional pair of a trace transform.
fn two_files<'a>(cli: &'a Cli, usage: &str) -> Result<(&'a str, &'a str)> {
    match (cli.positional.get(1), cli.positional.get(2)) {
        (Some(a), Some(b)) => Ok((a, b)),
        _ => bail!("usage: {usage}"),
    }
}

fn cmd_artifacts() -> Result<()> {
    // Figure JSON artifacts written by the sweep engine.
    let dir = sweep::artifact::artifact_dir();
    println!("figure artifacts ({}):", dir.display());
    let figure_artifacts = sweep::artifact::list()?;
    if figure_artifacts.is_empty() {
        println!("  (none — run `repro all-figures` or `repro figure <N>`)");
    }
    for path in figure_artifacts {
        let bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
        println!("  {} ({bytes} bytes)", path.display());
    }

    // AOT-compiled HLO artifacts (PJRT runtime).
    match ArtifactStore::discover() {
        Ok(mut store) => {
            println!("platform: {}", store.platform());
            for name in store.list()? {
                let exe = store.get(&name)?;
                println!("compiled: {}", exe.name);
            }
        }
        Err(e) => println!("AOT artifacts unavailable: {e}"),
    }
    Ok(())
}

fn cmd_figure(cli: &Cli) -> Result<()> {
    let which = cli
        .positional
        .first()
        .ok_or_else(|| err!("usage: repro figure <N>"))?
        .as_str();
    print_figure(which)
}

fn cmd_all_figures() -> Result<()> {
    for f in ["1", "2", "3", "4", "9", "10", "11", "12", "13", "14", "15", "16", "17", "18", "19"] {
        print_figure(f)?;
        println!();
    }
    Ok(())
}

fn print_figure(which: &str) -> Result<()> {
    match which {
        "1" | "2" => {
            let mem = if which == "1" { MemKind::Hmc } else { MemKind::Hbm };
            println!("Figure {which}: latency breakdown ({})", mem.as_str());
            let rows = figures::fig_latency_breakdown(mem);
            let mut overhead = Vec::new();
            for r in &rows {
                println!(
                    "fig{which:0>2} | {:<12} | network {:.3} | queue {:.3} | array {:.3} | avg {:.1}",
                    r.workload, r.network, r.queue, r.array, r.avg_latency
                );
                overhead.push(r.network + r.queue);
            }
            println!(
                "fig{which:0>2} | AVG remote overhead (network+queue) = {:.1}% (paper: {}%)",
                overhead.iter().sum::<f64>() / overhead.len() as f64 * 100.0,
                if which == "1" { 53 } else { 43 }
            );
        }
        "3" | "4" => {
            let mem = if which == "3" { MemKind::Hmc } else { MemKind::Hbm };
            println!("Figure {which}: CoV of per-vault demand ({})", mem.as_str());
            for (name, cov) in figures::fig_cov(mem) {
                println!("fig{which:0>2} | {name:<12} | cov {cov:.3}");
            }
        }
        "9" => {
            println!("Figure 9: always-subscribe speedup (HMC)");
            let rows = figures::fig9_always_subscribe();
            for r in &rows {
                println!("fig09 | {:<12} | speedup {:.3}", r.workload, r.speedup);
            }
            println!(
                "fig09 | GEOMEAN speedup = {:.3} (paper: ~1.06)",
                figures::geomean(rows.iter().map(|r| r.speedup))
            );
        }
        "10" => {
            println!("Figure 10: reuse per subscription under always-subscribe");
            for (name, l, r) in figures::fig10_reuse() {
                println!(
                    "fig10 | {name:<12} | local {l:.2} | remote {r:.2} | total {:.2}",
                    l + r
                );
            }
        }
        "11" => {
            println!("Figure 11: always vs adaptive on reuse workloads (HMC)");
            let rows = figures::fig11_adaptive();
            for r in &rows {
                println!(
                    "fig11 | {:<12} | always {:.3} | adaptive {:.3} | latency impr {:.1}%",
                    r.workload,
                    r.always_speedup,
                    r.adaptive_speedup,
                    r.latency_improvement * 100.0
                );
            }
            println!(
                "fig11 | GEOMEAN always {:.3} adaptive {:.3} | AVG latency impr {:.1}% (paper: ~1.14 / ~1.15 / 54%)",
                figures::geomean(rows.iter().map(|r| r.always_speedup)),
                figures::geomean(rows.iter().map(|r| r.adaptive_speedup)),
                rows.iter().map(|r| r.latency_improvement).sum::<f64>() / rows.len() as f64
                    * 100.0
            );
        }
        "12" | "13" => {
            let (mem, always) =
                if which == "12" { (MemKind::Hmc, true) } else { (MemKind::Hbm, false) };
            println!("Figure {which}: CoV by policy ({})", mem.as_str());
            for (name, covs) in figures::fig_cov_policies(mem, always) {
                let cols: Vec<String> = covs.iter().map(|c| format!("{c:.3}")).collect();
                let labels: &[&str] =
                    if always { &["base", "always", "adaptive"] } else { &["base", "adaptive"] };
                let joined: Vec<String> = labels
                    .iter()
                    .zip(&cols)
                    .map(|(l, c)| format!("{l} {c}"))
                    .collect();
                println!("fig{which} | {name:<12} | {}", joined.join(" | "));
            }
        }
        "14" => {
            println!("Figure 14: network traffic (B/cycle)");
            let rows = figures::fig14_traffic();
            let (mut sb, mut sa, mut sd) = (0.0, 0.0, 0.0);
            for (name, b, a, d) in &rows {
                println!("fig14 | {name:<12} | base {b:.2} | always {a:.2} | adaptive {d:.2}");
                sb += b;
                sa += a;
                sd += d;
            }
            println!(
                "fig14 | AVG increase: always {:+.0}% adaptive {:+.0}% (paper: +88% / +14%)",
                (sa / sb - 1.0) * 100.0,
                (sd / sb - 1.0) * 100.0
            );
        }
        "15" => {
            println!("Figure 15: HBM latency baseline vs adaptive");
            let rows = figures::fig15_hbm_adaptive();
            let mut impr = Vec::new();
            for r in &rows {
                println!(
                    "fig15 | {:<12} | base {:.1} | adaptive {:.1} | speedup {:.3}",
                    r.workload, r.base_latency, r.adaptive_latency, r.speedup
                );
                if r.base_latency > 0.0 {
                    impr.push(1.0 - r.adaptive_latency / r.base_latency);
                }
            }
            println!(
                "fig15 | AVG latency improvement = {:.1}% | GEOMEAN speedup {:.3} (paper: ~50% / ~1.03)",
                impr.iter().sum::<f64>() / impr.len() as f64 * 100.0,
                figures::geomean(rows.iter().map(|r| r.speedup))
            );
        }
        "16" => {
            println!("Figure 16: adaptive speedup vs subscription-table entries");
            for (name, series) in figures::fig16_table_size() {
                let cols: Vec<String> =
                    series.iter().map(|(e, s)| format!("{e}:{s:.3}")).collect();
                println!("fig16 | {name:<12} | {}", cols.join(" | "));
            }
        }
        "17" => {
            println!("Figure 17 (ablation): count-threshold filter (always-subscribe)");
            for (name, series) in figures::fig17_threshold_ablation() {
                let cols: Vec<String> =
                    series.iter().map(|(t, s)| format!("thr{t}:{s:.3}")).collect();
                println!("fig17 | {name:<12} | {}", cols.join(" | "));
            }
        }
        "18" => {
            println!("Figure 18 (ablation): adaptive-policy variants");
            for (name, series) in figures::fig18_policy_ablation() {
                let cols: Vec<String> =
                    series.iter().map(|(p, s)| format!("{p}:{s:.3}")).collect();
                println!("fig18 | {name:<12} | {}", cols.join(" | "));
            }
        }
        "19" => {
            println!("Figure 19 (new): adaptive DL-PIM under multi-tenant trace mixes");
            for r in figures::fig19_multi_tenant() {
                println!(
                    "fig19 | {:<10} | {} tenants | always {:.3} | adaptive {:.3} | \
                     latency impr {:.1}% | cov base {:.3} -> adaptive {:.3}",
                    r.scenario,
                    r.tenants,
                    r.always_speedup,
                    r.adaptive_speedup,
                    r.latency_improvement * 100.0,
                    r.base_cov,
                    r.adaptive_cov
                );
            }
        }
        other => bail!("unknown figure {other:?} (1-4, 9-19)"),
    }
    // Every simulate call above went through the sweep engine's report
    // cache, so assembling the JSON artifact re-runs nothing.
    if let Some(path) = figures::emit_artifact(which) {
        println!("fig{which:0>2} | artifact: {}", path.display());
    }
    Ok(())
}

//! `repro` — the DL-PIM launcher: run simulations, regenerate paper
//! figures, inspect configs and artifacts.

// The binary is the process boundary: stdout/stderr are its product.
// The clippy policy (rust/docs/LINTING.md) still bans `dbg!` leftovers
// and bare `unwrap` outside tests.
#![warn(clippy::dbg_macro)]
#![cfg_attr(not(test), warn(clippy::unwrap_used))]

use std::path::Path;

use dlpim::cli::{self, Cli, HELP};
use dlpim::config::{presets, SimConfig, Topology};
use dlpim::coordinator::driver::{simulate, simulate_observed};
use dlpim::coordinator::kernel::Kernel;
use dlpim::coordinator::report::SimReport;
use dlpim::error::{bail, err, Result};
use dlpim::exp;
use dlpim::log_info;
use dlpim::obs;
use dlpim::policy::PolicyKind;
use dlpim::runtime::ArtifactStore;
use dlpim::sweep;
use dlpim::trace::{self, transform, TraceData};
use dlpim::workloads::{self, catalog};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &[String]) -> Result<()> {
    let cli = Cli::parse(args).map_err(|e| err!(e))?;
    if matches!(cli.command.as_str(), "" | "help" | "--help" | "-h") {
        print!("{HELP}");
        return Ok(());
    }
    // Every known (sub)command declares its flag set; a typo'd flag fails
    // loudly with a did-you-mean instead of silently running defaults.
    let sub = matches!(cli.command.as_str(), "trace" | "cache")
        .then(|| cli.positional.first().map(|s| s.as_str()))
        .flatten();
    if let Some(known) = cli::known_flags(&cli.command, sub) {
        cli.reject_unknown_flags(known).map_err(|e| err!(e))?;
    }
    if cli.has("no-disk-cache") {
        sweep::cache::set_disk_cache_enabled(false);
    }
    obs::log::init(cli.has("quiet"), cli.has("v") || cli.has("verbose"));
    // `--metrics-out` opts into request telemetry before any simulation
    // starts; the snapshot is exported only after the command succeeds
    // (a failed figure leaves no half-truthful metrics artifact behind).
    let metrics_out = metrics_out_path(&cli);
    if metrics_out.is_some() {
        obs::enable();
    }
    match cli.command.as_str() {
        "run" => cmd_run(&cli),
        "figure" => cmd_figure(&cli),
        "all-figures" => cmd_all_figures(),
        "sweep" => cmd_sweep(&cli),
        "workloads" => cmd_workloads(),
        "config" => cmd_config(&cli),
        "trace" => cmd_trace(&cli),
        "cache" => cmd_cache(&cli),
        "bench" => cmd_bench(&cli),
        "artifacts" => cmd_artifacts(),
        "lint" => cmd_lint(&cli),
        other => bail!("unknown command {other:?}; try `repro help`"),
    }?;
    if let Some(path) = metrics_out {
        let prom = obs::export::write_files(&obs::snapshot(), &path).map_err(|e| err!(e))?;
        log_info!("metrics         {} (+ {})", path.display(), prom.display());
    }
    Ok(())
}

/// Whether this invocation executes its sweep through the shard claim
/// protocol (`--worker` joins one, `--workers N` also forks N local
/// worker subprocesses).
fn shard_mode(cli: &Cli) -> bool {
    cli.has("worker") || cli.has("workers")
}

/// Build this process's shard runner from the CLI flags: the shared
/// persistent store, the worker id stamped into claim leases, and the
/// stale-claim takeover TTL.
fn shard_runner(cli: &Cli) -> Result<sweep::shard::ShardRunner> {
    let store = sweep::cache::default_disk_store().ok_or_else(|| {
        err!(
            "sharded execution coordinates workers through the persistent \
             report store; drop --no-disk-cache / unset REPRO_NO_DISK_CACHE"
        )
    })?;
    let ttl = match cli.flag_u64("lease-ttl-ms").map_err(|e| err!(e))? {
        Some(0) => bail!("--lease-ttl-ms expects at least 1"),
        Some(ms) => std::time::Duration::from_millis(ms),
        None => sweep::shard::default_ttl(),
    };
    let worker = match cli.flag("worker-id") {
        Some(id) => id.to_string(),
        None => format!("w{}", std::process::id()),
    };
    Ok(sweep::shard::ShardRunner::new(store, worker, ttl))
}

/// This invocation's argv minus the orchestration/output flags, so a
/// forked subprocess re-runs the same figure or sweep as a quiet claim
/// worker (its own `--worker --worker-id ... --quiet` are appended).
fn shard_child_args() -> Vec<String> {
    const DROP: &[&str] =
        &["workers", "worker-id", "worker", "metrics-out", "quiet", "v", "verbose"];
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut out = Vec::with_capacity(args.len());
    let mut it = args.into_iter().peekable();
    while let Some(a) = it.next() {
        match a.strip_prefix("--") {
            Some(key) if DROP.contains(&key) => {
                // The parser would have bound a following non-flag token
                // to this flag as its value; drop it with the flag.
                if it.peek().is_some_and(|v| !v.starts_with("--")) {
                    it.next();
                }
            }
            _ => out.push(a),
        }
    }
    out
}

/// Run `spec` in shard mode: fork the `--workers N` subprocesses if
/// asked, then run this process's own worker loop and render. The local
/// loop doubles as the backstop — it finishes (after the lease TTL)
/// whatever a crashed subprocess left claimed, so a dead child degrades
/// throughput, never the figure.
fn run_spec_sharded_cli(spec: &exp::ExperimentSpec, cli: &Cli) -> Result<()> {
    let runner = shard_runner(cli)?;
    let mut children = Vec::new();
    if let Some(n) = cli.flag_u64("workers").map_err(|e| err!(e))? {
        if n == 0 {
            bail!("--workers expects at least 1");
        }
        let exe = std::env::current_exe()?;
        let base_args = shard_child_args();
        for i in 1..=n {
            let child = std::process::Command::new(&exe)
                .args(&base_args)
                .arg("--worker")
                .arg("--worker-id")
                .arg(format!("{}-{i}", runner.worker_id()))
                .arg("--quiet")
                .spawn()
                .map_err(|e| err!("spawn worker {i}: {e}"))?;
            children.push((i, child));
        }
    }
    let t0 = std::time::Instant::now();
    let result = exp::run_and_emit_sharded(spec, &runner).map(|_| ()).map_err(|e| err!(e));
    if result.is_ok() {
        log_info!(
            "worker {} | wallclock {:.2}s",
            runner.worker_id(),
            t0.elapsed().as_secs_f64()
        );
    }
    for (i, mut child) in children {
        match child.wait() {
            Ok(status) if status.success() => {}
            Ok(status) => {
                log_info!("worker subprocess {i} exited with {status} (surviving workers completed its points)")
            }
            Err(e) => log_info!("worker subprocess {i} unreachable: {e}"),
        }
    }
    result
}

/// The `--metrics-out` target: an explicit FILE, or the default
/// `target/repro/metrics.json` when the flag is given bare (the parser
/// assigns valueless switches "true").
fn metrics_out_path(cli: &Cli) -> Option<std::path::PathBuf> {
    match cli.flag("metrics-out") {
        None => None,
        Some("true") => Some(std::path::PathBuf::from("target/repro/metrics.json")),
        Some(p) => Some(std::path::PathBuf::from(p)),
    }
}

fn config_from_cli(cli: &Cli) -> Result<SimConfig> {
    let mut cfg = if let Some(path) = cli.flag("config") {
        let text = std::fs::read_to_string(path)?;
        dlpim::config::parse::config_from_text(&text).map_err(|e| err!(e))?
    } else {
        let mem = cli.flag_or("memory", "hmc");
        SimConfig::preset(mem).ok_or_else(|| err!("unknown memory {mem:?}"))?
    };
    if let Some(p) = cli.flag("policy") {
        cfg.policy = PolicyKind::parse(p).ok_or_else(|| err!("unknown policy {p:?}"))?;
    }
    if let Some(t) = cli.flag("topology") {
        cfg.topology = Topology::parse(t)
            .ok_or_else(|| err!("unknown topology {t:?} (mesh|crossbar|ring)"))?;
    }
    if cli.has("quick") {
        cfg = cfg.quick();
    }
    if cli.has("paper-scale") {
        cfg = cfg.paper_scale();
    }
    if let Some(v) = cli.flag_u64("warmup").map_err(|e| err!(e))? {
        cfg.warmup_requests = v;
    }
    if let Some(v) = cli.flag_u64("measure").map_err(|e| err!(e))? {
        cfg.measure_requests = v;
    }
    if let Some(v) = cli.flag_u64("runs").map_err(|e| err!(e))? {
        cfg.runs = v as u32;
    }
    if let Some(v) = cli.flag_u64("seed").map_err(|e| err!(e))? {
        cfg.seed = v;
    }
    if let Some(v) = cli.flag_u64("epoch").map_err(|e| err!(e))? {
        cfg.epoch_cycles = v;
    }
    if let Some(t) = cli.flag("trace") {
        cfg.trace = Some(t.to_string());
    }
    if cli.has("no-loop") {
        cfg.trace_loop = false;
    }
    cfg.validate().map_err(|e| err!("invalid config: {}", e.join("; ")))?;
    Ok(cfg)
}

fn cmd_run(cli: &Cli) -> Result<()> {
    let cfg = config_from_cli(cli)?;
    // Kernel threads for the run fan-out: --threads beats REPRO_THREADS
    // beats 1. Never part of SimConfig (reports are bit-identical at any
    // thread count, and the sweep cache key must not depend on it).
    let kernel = match cli.flag_u64("threads").map_err(|e| err!(e))? {
        Some(0) => bail!("--threads expects at least 1"),
        Some(n) => Kernel::new(usize::try_from(n).unwrap_or(usize::MAX)),
        None => Kernel::from_env(),
    };
    let t0 = std::time::Instant::now();
    let (name, rep) = if let Some(out) = cli.flag("record") {
        if cfg.trace.is_some() {
            bail!("--record captures a generator run; drop --trace (that file already is a recording)");
        }
        if kernel.threads() > 1 {
            bail!("--threads does not apply to --record (recording instruments a single serial run)");
        }
        let name = cli
            .flag("workload")
            .ok_or_else(|| err!("--record requires --workload NAME"))?;
        let rep = trace::record_run(&cfg, name, Path::new(out)).map_err(|e| err!(e))?;
        println!("recorded        {out}");
        (name.to_string(), rep)
    } else {
        if cfg.trace.is_some() && cli.flag("workload").is_some() {
            bail!(
                "--workload and --trace are conflicting traffic sources; drop one \
                 (a trace file already names its recorded workload)"
            );
        }
        // Build once up front so a bad workload name or trace path fails
        // with a proper error before any thread spawns.
        let w = workloads::build_source(cli.flag("workload"), &cfg).map_err(|e| err!(e))?;
        let name = w.name().to_string();
        // With `--metrics-out` the observed driver paths run instead,
        // feeding each served request's latency decomposition into the
        // histograms. Same simulation, same report bytes — the observer
        // only reads (pinned by tests/observability.rs).
        let rep = if kernel.threads() > 1 {
            let source = cli.flag("workload");
            drop(w);
            let build =
                || workloads::build_source(source, &cfg).expect("source validated above");
            if obs::enabled() {
                kernel.simulate_runs_observed(&cfg, &name, build, |_, r| {
                    obs::record_request(r.network, r.queued_net, r.queued_mem(), r.array)
                })
            } else {
                kernel.simulate_runs(&cfg, &name, build)
            }
        } else if obs::enabled() {
            simulate_observed(&cfg, w, |_, r| {
                obs::record_request(r.network, r.queued_net, r.queued_mem(), r.array)
            })
        } else {
            simulate(&cfg, w)
        };
        (name, rep)
    };
    let dt = t0.elapsed();
    print_report(&name, &cfg, &rep);
    if kernel.threads() > 1 {
        log_info!("threads         {}", kernel.threads());
    }
    log_info!("wallclock       {:.2}s", dt.as_secs_f64());
    Ok(())
}

fn print_report(name: &str, cfg: &SimConfig, rep: &SimReport) {
    let (n, q, a) = rep.latency_fractions();
    println!("workload        {name}");
    println!("memory/policy   {}/{}", cfg.mem.as_str(), cfg.policy.as_str());
    println!("topology        {}", cfg.topology.as_str());
    println!("runs            {}", rep.runs.len());
    println!("cycles          {:.0}", rep.cycles());
    println!("avg latency     {:.1} cycles/request", rep.avg_latency());
    println!(
        "breakdown       network {:.1}% | queue {:.1}% | array {:.1}%",
        n * 100.0,
        q * 100.0,
        a * 100.0
    );
    let r0q = &rep.runs[0].stats;
    if r0q.queue_net + r0q.queue_mem > 0 {
        println!(
            "queue split     links {:.1}% | vault mem {:.1}%",
            r0q.queue_net as f64 / (r0q.queue_net + r0q.queue_mem) as f64 * 100.0,
            r0q.queue_mem as f64 / (r0q.queue_net + r0q.queue_mem) as f64 * 100.0
        );
    }
    println!("CoV             {:.3}", rep.cov());
    println!("traffic         {:.2} B/cycle", rep.bytes_per_cycle());
    let (rl, rr) = rep.reuse();
    println!("reuse/sub       local {rl:.2} remote {rr:.2}");
    println!("local fraction  {:.1}%", rep.local_fraction() * 100.0);
    let r0 = &rep.runs[0];
    println!(
        "protocol        subs {} | resubs {} | unsubs {} | nacks {}",
        r0.stats.subscriptions,
        r0.stats.resubscriptions,
        r0.stats.unsubscriptions,
        r0.stats.sub_nacks
    );
    println!("epochs          {}", r0.decisions.len());
}

fn cmd_workloads() -> Result<()> {
    println!("{:<10} {:<26} {:<36} {}", "Suite", "Benchmark", "Function", "Short");
    for e in &catalog::TABLE3 {
        println!("{:<10} {:<26} {:<36} {}", e.suite, e.benchmark, e.function, e.short);
    }
    println!("\nselected (non-negligible reuse): {}", catalog::SELECTED.join(" "));
    Ok(())
}

fn cmd_config(cli: &Cli) -> Result<()> {
    let cfg = config_from_cli(cli)?;
    print!("{}", presets::render(&cfg));
    Ok(())
}

/// `repro trace <record|replay|info|mix|dilate|remap>` — the trace
/// pipeline (see `dlpim::trace` for the format spec).
fn cmd_trace(cli: &Cli) -> Result<()> {
    let sub = cli.positional.first().map(|s| s.as_str()).unwrap_or("");
    match sub {
        "record" => {
            let mut cfg = config_from_cli(cli)?;
            let name = cli
                .flag("workload")
                .ok_or_else(|| err!("usage: repro trace record --workload NAME --out FILE"))?;
            let out = cli.flag("out").ok_or_else(|| err!("--out FILE required"))?;
            cfg.runs = 1; // the format stores one seed, one stream set
            let rep = trace::record_run(&cfg, name, Path::new(out)).map_err(|e| err!(e))?;
            let data = TraceData::load(Path::new(out)).map_err(|e| err!(e))?;
            println!("recorded        {name} -> {out}");
            println!(
                "captured        {} ops over {} cores ({} body bytes, {:.2} B/op)",
                data.total_ops(),
                data.n_cores(),
                data.body_bytes(),
                data.body_bytes() as f64 / data.total_ops().max(1) as f64
            );
            println!("served          {} memory requests", rep.runs[0].stats.requests);
            Ok(())
        }
        "replay" => {
            let file = cli
                .positional
                .get(1)
                .ok_or_else(|| err!("usage: repro trace replay FILE [config flags]"))?;
            let mut cfg = config_from_cli(cli)?;
            cfg.trace = Some(file.clone());
            let t0 = std::time::Instant::now();
            let w = workloads::build_source(None, &cfg).map_err(|e| err!(e))?;
            let name = w.name().to_string();
            let rep = simulate(&cfg, w);
            print_report(&name, &cfg, &rep);
            println!("wallclock       {:.2}s", t0.elapsed().as_secs_f64());
            Ok(())
        }
        "info" => {
            let file = cli
                .positional
                .get(1)
                .ok_or_else(|| err!("usage: repro trace info FILE"))?;
            let data = TraceData::load(Path::new(file)).map_err(|e| err!(e))?;
            let ops: Vec<u64> = (0..data.n_cores()).map(|c| data.core_ops(c)).collect();
            println!("trace           {file}");
            println!("format          DLPT v{}", dlpim::trace::VERSION);
            println!("workload        {}", data.meta.workload);
            println!(
                "recorded on     {}/{} with {} cores",
                data.meta.mem, data.meta.topology, data.meta.n_cores
            );
            println!("block bytes     {}", data.meta.block_bytes);
            println!("seed            {:#x}", data.meta.seed);
            println!("config hash     {:#018x}", data.meta.config_hash);
            println!(
                "ops             {} total | per core min {} max {}",
                data.total_ops(),
                ops.iter().min().copied().unwrap_or(0),
                ops.iter().max().copied().unwrap_or(0)
            );
            println!(
                "encoded         {} body bytes ({:.2} B/op)",
                data.body_bytes(),
                data.body_bytes() as f64 / data.total_ops().max(1) as f64
            );
            Ok(())
        }
        "mix" => {
            let inputs = &cli.positional[1..];
            if inputs.len() < 2 {
                bail!("usage: repro trace mix IN1 IN2 [IN...] --out FILE [--weights A,B,..] [--cores N]");
            }
            let out = cli.flag("out").ok_or_else(|| err!("--out FILE required"))?;
            let weights: Vec<u64> = match cli.flag("weights") {
                None => vec![1; inputs.len()],
                Some(s) => s
                    .split(',')
                    .map(|x| {
                        x.trim()
                            .parse()
                            .map_err(|_| err!("--weights expects comma-separated integers, got {x:?}"))
                    })
                    .collect::<Result<_>>()?,
            };
            let data: Vec<TraceData> = inputs
                .iter()
                .map(|p| TraceData::load(Path::new(p)))
                .collect::<Result<_, String>>()
                .map_err(|e| err!(e))?;
            let cores = match cli.flag_u64("cores").map_err(|e| err!(e))? {
                Some(n) => u16::try_from(n)
                    .map_err(|_| err!("--cores {n} out of range (max {})", u16::MAX))?,
                None => data
                    .iter()
                    .map(|d| d.n_cores())
                    .max()
                    .expect("mix requires at least two inputs"),
            };
            let mixed = transform::mix(&data, &weights, cores).map_err(|e| err!(e))?;
            mixed.save(Path::new(out)).map_err(|e| err!(e))?;
            println!(
                "mixed           {} tenants -> {out} ({} cores, {} ops)",
                inputs.len(),
                mixed.n_cores(),
                mixed.total_ops()
            );
            Ok(())
        }
        "dilate" => {
            let (input, out) = two_files(cli, "repro trace dilate IN OUT --factor F")?;
            let factor: f64 = cli
                .flag("factor")
                .ok_or_else(|| err!("--factor F required (e.g. 2.0 doubles compute gaps)"))?
                .parse()
                .map_err(|_| err!("--factor expects a number"))?;
            let data = TraceData::load(Path::new(input)).map_err(|e| err!(e))?;
            let dilated = transform::dilate(&data, factor).map_err(|e| err!(e))?;
            dilated.save(Path::new(out)).map_err(|e| err!(e))?;
            println!("dilated         {input} x{factor} -> {out}");
            Ok(())
        }
        "remap" => {
            let (input, out) = two_files(cli, "repro trace remap IN OUT --vaults N")?;
            let vaults = cli
                .flag_u64("vaults")
                .map_err(|e| err!(e))?
                .ok_or_else(|| err!("--vaults N required"))?;
            let vaults = u16::try_from(vaults)
                .map_err(|_| err!("--vaults {vaults} out of range (max {})", u16::MAX))?;
            let data = TraceData::load(Path::new(input)).map_err(|e| err!(e))?;
            let remapped = transform::remap(&data, vaults).map_err(|e| err!(e))?;
            remapped.save(Path::new(out)).map_err(|e| err!(e))?;
            println!(
                "remapped        {input} ({} cores) -> {out} ({} cores)",
                data.n_cores(),
                remapped.n_cores()
            );
            Ok(())
        }
        "" => bail!("usage: repro trace <record|replay|info|mix|dilate|remap>"),
        other => bail!("unknown trace subcommand {other:?} (record|replay|info|mix|dilate|remap)"),
    }
}

/// The `IN OUT` positional pair of a trace transform.
fn two_files<'a>(cli: &'a Cli, usage: &str) -> Result<(&'a str, &'a str)> {
    match (cli.positional.get(1), cli.positional.get(2)) {
        (Some(a), Some(b)) => Ok((a, b)),
        _ => bail!("usage: {usage}"),
    }
}

/// `repro cache <stats|clear|gc>` — manage the persistent report store
/// the sweep engine shares across processes.
fn cmd_cache(cli: &Cli) -> Result<()> {
    use dlpim::sweep::store::DiskStore;
    let store = match cli.flag("dir") {
        Some(dir) => DiskStore::at(dir),
        None => DiskStore::at(sweep::cache::default_cache_dir()),
    };
    let sub = cli.positional.first().map(|s| s.as_str()).unwrap_or("");
    match sub {
        "stats" => {
            let s = store.scan()?;
            println!("cache dir       {}", store.dir().display());
            println!("build           {}", dlpim::sweep::store::build_fingerprint());
            println!(
                "entries         {} ({:.1} KiB)",
                s.entries(),
                s.bytes as f64 / 1024.0
            );
            println!("  current       {}", s.current);
            println!("  stale         {} (other build or format version)", s.stale);
            println!("  corrupt       {}", s.corrupt);
            println!("  tmp leftover  {}", s.tmp);
            println!("claims          {} active, {} stale", s.claims_active, s.claims_stale);
            Ok(())
        }
        "clear" => {
            let removed = store.clear()?;
            println!("cleared         {removed} files from {}", store.dir().display());
            Ok(())
        }
        "gc" => {
            let out = store.gc()?;
            println!(
                "gc              kept {} | removed {} (stale {}, corrupt {}, tmp {}, claims {})",
                out.kept,
                out.removed(),
                out.removed_stale,
                out.removed_corrupt,
                out.removed_tmp,
                out.removed_claims
            );
            Ok(())
        }
        "" => bail!("usage: repro cache <stats|clear|gc> [--dir DIR]"),
        other => bail!("unknown cache subcommand {other:?} (stats|clear|gc)"),
    }
}

/// `repro bench` — measure the pinned serve-throughput trajectory and
/// (optionally) emit BENCH_*.json / gate against a checked-in baseline.
/// See `docs/BENCHMARKING.md` for the schema and CI workflow.
fn cmd_bench(cli: &Cli) -> Result<()> {
    use dlpim::perf;
    let skip =
        std::env::var(perf::SKIP_ENV).map(|v| v == "1" || v == "true").unwrap_or(false);
    if skip && cli.has("promote") {
        bail!(
            "--promote refuses to run under {}=1: a promoted baseline must \
             come from a real measurement",
            perf::SKIP_ENV
        );
    }
    if skip {
        println!("bench skipped   {}=1", perf::SKIP_ENV);
        return Ok(());
    }
    let t0 = std::time::Instant::now();
    let rep = perf::run_trajectory();
    for p in &rep.points {
        println!(
            "bench | {:<8} | {:<8} | {:>8.2}M ops/s | {:>6.0} ns/access | {} req x{}",
            p.topology,
            p.policy,
            p.ops_per_sec() / 1e6,
            p.ns_per_access(),
            p.requests,
            p.timing.iters
        );
    }
    for p in &rep.threads {
        println!(
            "scale | {:>2} threads | {:>8.2} sims/s | {} runs x{}",
            p.threads,
            p.sims_per_sec(),
            p.runs,
            p.timing.iters
        );
    }
    for p in &rep.shards {
        println!(
            "shard | {:>2} workers | {:>8.2} points/s | {} points x{}",
            p.workers,
            p.points_per_sec(),
            p.points,
            p.timing.iters
        );
    }
    println!(
        "headline        serve_ops_per_sec {:.0} ({:.1} ns/access)",
        rep.serve_ops_per_sec(),
        rep.ns_per_access()
    );
    println!("wallclock       {:.2}s", t0.elapsed().as_secs_f64());
    if cli.has("json") || cli.has("out") {
        let out = cli.flag_or("out", "target/repro/BENCH_8.json");
        if let Some(dir) = Path::new(out).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(out, rep.to_json())?;
        println!("wrote           {out}");
    }
    if cli.has("promote") {
        // Promotion replaces the checked-in baseline with this machine's
        // fresh numbers; the emitter always writes `provisional: false`,
        // so the next `--check` run gates for real. Gating against the
        // file we are about to overwrite would be meaningless, so
        // --promote skips the regression check.
        let path = cli.flag("check").filter(|p| *p != "true").unwrap_or(perf::BASELINE_FILE);
        std::fs::write(path, rep.to_json())?;
        println!("promoted        {path} (provisional: false)");
        return Ok(());
    }
    if let Some(base_path) = cli.flag("check") {
        let text = std::fs::read_to_string(base_path)
            .map_err(|e| err!("read baseline {base_path}: {e}"))?;
        let baseline = perf::parse_baseline(&text).map_err(|e| err!("{base_path}: {e}"))?;
        let threshold: f64 = cli
            .flag_or("threshold", "10")
            .parse()
            .map_err(|_| err!("--threshold expects a number (percent)"))?;
        match perf::check_regression(rep.serve_ops_per_sec(), &baseline, threshold) {
            Ok(line) => println!("gate            {line}"),
            Err(e) => bail!("perf regression vs {base_path}: {e}"),
        }
    }
    Ok(())
}

fn cmd_artifacts() -> Result<()> {
    // Figure JSON artifacts written by the sweep engine.
    let dir = sweep::artifact::artifact_dir();
    println!("figure artifacts ({}):", dir.display());
    let figure_artifacts = sweep::artifact::list()?;
    if figure_artifacts.is_empty() {
        println!("  (none — run `repro all-figures` or `repro figure <N>`)");
    }
    for path in figure_artifacts {
        let bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
        println!("  {} ({bytes} bytes)", path.display());
    }

    // AOT-compiled HLO artifacts (PJRT runtime).
    match ArtifactStore::discover() {
        Ok(mut store) => {
            println!("platform: {}", store.platform());
            for name in store.list()? {
                let exe = store.get(&name)?;
                println!("compiled: {}", exe.name);
            }
        }
        Err(e) => println!("AOT artifacts unavailable: {e}"),
    }
    Ok(())
}

/// `repro lint [PATH] [--json] [--fix-allow]`: the determinism &
/// invariant static-analysis pass (rules D1–D5 + A0; docs/LINTING.md).
/// Exits non-zero on any unallowed finding; the text report is one line
/// per finding sorted by (file, line) so CI diffs are stable.
fn cmd_lint(cli: &Cli) -> Result<()> {
    let root = match cli.positional.first() {
        Some(p) => dlpim::lint::find_root(Path::new(p))?,
        None => dlpim::lint::find_root(&std::env::current_dir()?)?,
    };
    let report = dlpim::lint::run(&root)?;
    if cli.has("fix-allow") {
        let fixed = dlpim::lint::fix_allow(&root, &report)?;
        println!(
            "lint --fix-allow: annotated {fixed} file(s) with placeholder \
             allows; replace each `TODO` with the actual justification"
        );
        // Report the pre-fix findings below so the user sees what was
        // annotated; the placeholders themselves keep the tree red (A0).
    }
    if cli.has("json") {
        println!("{}", report.to_json().render());
    } else {
        print!("{}", report.render_text());
    }
    let violations = report.violations().count();
    if violations > 0 {
        bail!("lint found {violations} unallowed finding(s)");
    }
    Ok(())
}

/// `repro figure <N>` / `repro figure --list`: every figure is a data
/// entry in [`dlpim::exp::registry`]; this command only enumerates it.
fn cmd_figure(cli: &Cli) -> Result<()> {
    if cli.has("list") {
        return cmd_figure_list();
    }
    let which = cli
        .positional
        .first()
        .ok_or_else(|| err!("usage: repro figure <N> (or: repro figure --list)"))?
        .as_str();
    let spec = exp::registry::by_figure(which).ok_or_else(|| {
        err!(
            "unknown figure {which:?} (valid: {}); see `repro figure --list`",
            exp::registry::figure_ids().join(", ")
        )
    })?;
    if shard_mode(cli) {
        let id = spec.figure.as_deref().unwrap_or(&spec.name);
        log_info!("Figure {id}: {}", spec.title);
        return run_spec_sharded_cli(&spec, cli);
    }
    print_figure(&spec)
}

/// One line per registry entry: artifact name first (CI's matrix is
/// derived from this output), then figure id, point count, axes, title.
fn cmd_figure_list() -> Result<()> {
    for spec in exp::registry::figures() {
        let points = spec.point_count().map_err(|e| err!("{}: {e}", spec.name))?;
        println!(
            "{:<6} figure={:<3} points={:<4} {} | {}",
            spec.name,
            spec.figure.as_deref().unwrap_or("-"),
            points,
            spec.axes_summary(),
            spec.title
        );
    }
    Ok(())
}

fn cmd_all_figures() -> Result<()> {
    for spec in exp::registry::figures() {
        print_figure(&spec)?;
        log_info!();
    }
    Ok(())
}

fn print_figure(spec: &exp::ExperimentSpec) -> Result<()> {
    let id = spec.figure.as_deref().unwrap_or(&spec.name);
    log_info!("Figure {id}: {}", spec.title);
    exp::run_and_emit(spec, false).map_err(|e| err!(e))?;
    Ok(())
}

/// `repro sweep` — run an ad-hoc declarative spec from a TOML file
/// (`--spec FILE`) or from axis flags, through the same engine and
/// report cache as the figures. Emits a long-form JSON artifact.
fn cmd_sweep(cli: &Cli) -> Result<()> {
    let spec = if let Some(path) = cli.flag("spec") {
        // Axis flags next to --spec would be silently shadowed by the
        // file; a user who thinks they overrode an axis must hear about
        // it before a potentially hours-long sweep of the wrong configs.
        // (`--no-disk-cache`, the observability flags and the shard
        // flags are execution flags, not axes: they compose with --spec.)
        if let Some(extra) = cli::flags::SWEEP.iter().find(|f| {
            **f != "spec"
                && **f != "no-disk-cache"
                && !cli::flags::OBS.contains(f)
                && !cli::flags::SHARD.contains(f)
                && cli.has(f)
        }) {
            bail!(
                "--{extra} conflicts with --spec {path}: a spec file defines every \
                 axis; edit the file (or drop --spec) instead"
            );
        }
        let text = std::fs::read_to_string(path)
            .map_err(|e| err!("read spec {path}: {e}"))?;
        exp::tomlspec::from_text(&text).map_err(|e| err!("{path}: {e}"))?
    } else {
        exp::tomlspec::from_cli(cli).map_err(|e| err!(e))?
    };
    let t0 = std::time::Instant::now();
    let points = spec.point_count().map_err(|e| err!(e))?;
    log_info!("sweep {}: {points} points ({})", spec.name, spec.axes_summary());
    if shard_mode(cli) {
        return run_spec_sharded_cli(&spec, cli);
    }
    exp::run_and_emit(&spec, false).map_err(|e| err!(e))?;
    log_info!("wallclock       {:.2}s", t0.elapsed().as_secs_f64());
    Ok(())
}

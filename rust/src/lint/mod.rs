//! `repro lint`: a determinism & invariant static-analysis pass.
//!
//! The bit-identity guarantees this reproduction makes — byte-identical
//! figure artifacts at any thread count, with telemetry on or off, at any
//! shard-worker count — are pinned by equivalence tests, but tests only
//! catch the hazards someone thought to storm. This module mechanically
//! enforces the *preconditions* those tests rely on, at the source level:
//!
//! * **D1** — no hash-ordered collections in determinism-critical modules;
//! * **D2** — no wall-clock/randomness/env reads outside the harness
//!   allowlist;
//! * **D3** — atomics in determinism-critical modules use `SeqCst` or a
//!   justified allow;
//! * **D4** — no floating point in report-accumulation paths;
//! * **D5** — the ARCHITECTURE.md invariant tables and `rust/tests/`
//!   agree (every pinned test exists; every test is documented);
//! * **A0** — every `// lint:allow(<rule>) -- <justification>` escape
//!   hatch names real rules and carries a real justification.
//!
//! Zero dependencies, matching the crate convention: the scanner in
//! [`scan`] is a hand-rolled lexer, rules in [`rules`] are table rows,
//! and `--json` output reuses [`crate::sweep::json::JsonValue`]. See
//! `rust/docs/LINTING.md` for the rule catalogue and rationale.

pub mod rules;
pub mod scan;

use std::fs;
use std::path::{Path, PathBuf};

use crate::error::{bail, Context, Result};
use crate::sweep::json::JsonValue;

/// One finding. `allowed` carries the justification when the finding is
/// shielded by a `lint:allow`; such findings still appear in `--json`
/// output (the justification is part of the audit trail) but do not fail
/// the run.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    /// Repo-relative path with `/` separators.
    pub file: String,
    /// 1-based.
    pub line: usize,
    pub message: String,
    pub allowed: Option<String>,
}

/// Everything the rules need to see, loaded once.
pub struct Repo {
    pub root: PathBuf,
    /// `rust/src/**/*.rs`, sorted by path.
    pub sources: Vec<scan::SourceFile>,
    /// `rust/tests/*.rs` (top level only — fixture trees below are data,
    /// not targets), sorted by path.
    pub tests: Vec<scan::SourceFile>,
    /// `(rel_path, text)` for `rust/README.md`, `rust/docs/*.md` and
    /// `CHANGES.md` — the corpus D5 searches for test mentions.
    pub docs: Vec<(String, String)>,
    /// `rust/docs/ARCHITECTURE.md`, when present.
    pub architecture: Option<(String, String)>,
}

impl Repo {
    pub fn load(root: &Path) -> Result<Repo> {
        let src_dir = root.join("rust/src");
        if !src_dir.join("lib.rs").is_file() {
            bail!(
                "{} does not look like the repo root (no rust/src/lib.rs)",
                root.display()
            );
        }
        let mut src_paths = Vec::new();
        collect_rs(&src_dir, &mut src_paths)?;
        src_paths.sort();
        let sources = scan_all(root, &src_paths)?;

        let mut test_paths: Vec<PathBuf> = match fs::read_dir(root.join("rust/tests")) {
            Ok(rd) => rd
                .filter_map(|e| e.ok().map(|e| e.path()))
                .filter(|p| p.is_file() && p.extension().is_some_and(|x| x == "rs"))
                .collect(),
            Err(_) => Vec::new(),
        };
        test_paths.sort();
        let tests = scan_all(root, &test_paths)?;

        let mut docs = Vec::new();
        for p in [root.join("rust/README.md"), root.join("CHANGES.md")] {
            if let Ok(text) = fs::read_to_string(&p) {
                docs.push((rel(root, &p), text));
            }
        }
        let mut doc_paths: Vec<PathBuf> = match fs::read_dir(root.join("rust/docs")) {
            Ok(rd) => rd
                .filter_map(|e| e.ok().map(|e| e.path()))
                .filter(|p| p.is_file() && p.extension().is_some_and(|x| x == "md"))
                .collect(),
            Err(_) => Vec::new(),
        };
        doc_paths.sort();
        for p in doc_paths {
            let text = fs::read_to_string(&p)
                .with_context(|| format!("read {}", p.display()))?;
            docs.push((rel(root, &p), text));
        }
        let architecture = docs
            .iter()
            .find(|(p, _)| p.ends_with("docs/ARCHITECTURE.md"))
            .cloned();

        Ok(Repo { root: root.to_path_buf(), sources, tests, docs, architecture })
    }
}

fn scan_all(root: &Path, paths: &[PathBuf]) -> Result<Vec<scan::SourceFile>> {
    paths
        .iter()
        .map(|p| {
            let text = fs::read_to_string(p)
                .with_context(|| format!("read {}", p.display()))?;
            Ok(scan::scan_source(&rel(root, p), &text))
        })
        .collect()
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    for entry in
        fs::read_dir(dir).with_context(|| format!("read dir {}", dir.display()))?
    {
        let path = entry.with_context(|| format!("read dir {}", dir.display()))?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

fn rel(root: &Path, p: &Path) -> String {
    p.strip_prefix(root)
        .unwrap_or(p)
        .components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}

/// The lint result: all findings (allowed and not), sorted by
/// (file, line, rule, message) so output is diff-stable.
pub struct Report {
    pub findings: Vec<Finding>,
    /// Number of files scanned (sources + tests).
    pub files_scanned: usize,
}

impl Report {
    pub fn violations(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| f.allowed.is_none())
    }

    pub fn allowed(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| f.allowed.is_some())
    }

    /// One line per violation, `file:line: RULE message`, plus a summary
    /// tail. Allowed findings are not listed (see `--json` for those).
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for f in self.violations() {
            out.push_str(&format!("{}:{}: {} {}\n", f.file, f.line, f.rule, f.message));
        }
        let v = self.violations().count();
        let a = self.allowed().count();
        if v == 0 {
            out.push_str(&format!(
                "lint: clean — {} files scanned, {a} allowed exception(s)\n",
                self.files_scanned
            ));
        } else {
            out.push_str(&format!(
                "lint: {v} violation(s), {a} allowed exception(s), {} files scanned\n",
                self.files_scanned
            ));
        }
        out
    }

    /// The full report (violations *and* justified allows) as a JSON
    /// document via the crate's hand-rolled encoder.
    pub fn to_json(&self) -> JsonValue {
        let rules: Vec<JsonValue> = rules::RULES
            .iter()
            .map(|r| {
                JsonValue::obj(vec![
                    ("id", JsonValue::str(r.id)),
                    ("title", JsonValue::str(r.title)),
                ])
            })
            .chain([
                JsonValue::obj(vec![
                    ("id", JsonValue::str("D5")),
                    (
                        "title",
                        JsonValue::str(
                            "ARCHITECTURE.md invariant tables and rust/tests agree",
                        ),
                    ),
                ]),
                JsonValue::obj(vec![
                    ("id", JsonValue::str(rules::A0_ID)),
                    ("title", JsonValue::str("lint:allow annotations are well-formed")),
                ]),
            ])
            .collect();
        let findings: Vec<JsonValue> = self
            .findings
            .iter()
            .map(|f| {
                JsonValue::obj(vec![
                    ("rule", JsonValue::str(f.rule)),
                    ("file", JsonValue::str(f.file.as_str())),
                    ("line", JsonValue::Num(f.line as f64)),
                    ("message", JsonValue::str(f.message.as_str())),
                    ("allowed", JsonValue::Bool(f.allowed.is_some())),
                    (
                        "justification",
                        match &f.allowed {
                            Some(j) => JsonValue::str(j.as_str()),
                            None => JsonValue::Null,
                        },
                    ),
                ])
            })
            .collect();
        JsonValue::obj(vec![
            ("schema", JsonValue::str("repro-lint-v1")),
            ("rules", JsonValue::Arr(rules)),
            ("files_scanned", JsonValue::Num(self.files_scanned as f64)),
            ("violations", JsonValue::Num(self.violations().count() as f64)),
            ("allowed", JsonValue::Num(self.allowed().count() as f64)),
            ("findings", JsonValue::Arr(findings)),
        ])
    }
}

/// Walk up from `start` to the repo root (the directory containing
/// `rust/src/lib.rs`).
pub fn find_root(start: &Path) -> Result<PathBuf> {
    let mut dir = start.to_path_buf();
    loop {
        if dir.join("rust/src/lib.rs").is_file() {
            return Ok(dir);
        }
        if !dir.pop() {
            bail!(
                "no repo root (rust/src/lib.rs) at or above {}",
                start.display()
            );
        }
    }
}

/// Run every rule over the repo at `root`.
pub fn run(root: &Path) -> Result<Report> {
    let repo = Repo::load(root)?;
    let mut findings = Vec::new();
    for file in &repo.sources {
        findings.extend(rules::check_file(file));
    }
    for file in &repo.tests {
        // Integration tests are all-test code, so the line rules don't
        // apply — but their allow annotations (for D5) must be valid.
        findings.extend(rules::check_allows(file));
    }
    findings.extend(rules::check_cross_file(&repo));
    findings.sort_by(|a, b| {
        (&a.file, a.line, a.rule, &a.message).cmp(&(&b.file, b.line, b.rule, &b.message))
    });
    Ok(Report { findings, files_scanned: repo.sources.len() + repo.tests.len() })
}

/// `--fix-allow`: insert a placeholder
/// `// lint:allow(<rule>) -- TODO: justify this exception` above every
/// unallowed D1–D4 violation (and at the top of undocumented test files
/// for D5). The placeholder keeps the tree red via A0 until a human
/// replaces `TODO…` with the actual reason. Returns the number of files
/// rewritten.
pub fn fix_allow(root: &Path, report: &Report) -> Result<usize> {
    use std::collections::BTreeMap;
    // file -> [(line, rule)], deduped, applied bottom-up so insertions
    // don't shift later targets.
    let mut by_file: BTreeMap<&str, Vec<(usize, &str)>> = BTreeMap::new();
    for f in report.violations() {
        if f.rule == rules::A0_ID || !f.file.ends_with(".rs") {
            continue; // A0 means a human must edit; markdown rows too
        }
        let line = if f.rule == "D5" { 1 } else { f.line };
        let v = by_file.entry(f.file.as_str()).or_default();
        if !v.contains(&(line, f.rule)) {
            v.push((line, f.rule));
        }
    }
    for (file, targets) in &mut by_file {
        let path = root.join(file);
        let text = fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        let mut lines: Vec<String> = text.split('\n').map(String::from).collect();
        targets.sort();
        for &(line, rule) in targets.iter().rev() {
            let at = line.saturating_sub(1).min(lines.len());
            let indent: String = lines
                .get(at)
                .map(|l| l.chars().take_while(|c| c.is_whitespace()).collect())
                .unwrap_or_default();
            lines.insert(
                at,
                format!("{indent}// lint:allow({rule}) -- TODO: justify this exception"),
            );
        }
        fs::write(&path, lines.join("\n"))
            .with_context(|| format!("write {}", path.display()))?;
    }
    Ok(by_file.len())
}

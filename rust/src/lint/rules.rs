//! Lint rules. Line rules (D1–D4) are rows in the [`RULES`] table — adding
//! a rule means adding a row, mirroring the `exp` registry design. D5 is a
//! cross-file check over `rust/docs/ARCHITECTURE.md` and `rust/tests/`;
//! A0 validates the `lint:allow` annotations themselves.
//!
//! Rule rationale lives in `rust/docs/LINTING.md`; each rule's `title`
//! here is the one-line version of it.

use crate::lint::scan::SourceFile;
use crate::lint::{Finding, Repo};

/// Modules whose execution must be a pure function of (config, seed):
/// they feed the bit-identical artifact guarantee pinned by the golden,
/// kernel-equivalence and shard-sweep tests.
pub const DETERMINISM_CRITICAL: &[&str] = &[
    "sim",
    "memsys",
    "coordinator",
    "subscription",
    "policy",
    "exp",
    "sweep",
    "trace",
    "stats",
];

/// Modules allowed to read wall clocks, randomness and the environment:
/// the measurement harnesses (`perf`, `benchkit`), passive telemetry
/// (`obs`), the process boundary (`cli`, `main`, `config` — all `REPRO_*`
/// reads live in `config::env`), and shard identity (`sweep::shard`
/// derives worker nonces from time by design).
pub const D2_ALLOWED: &[&str] =
    &["perf", "obs", "cli", "main", "config", "benchkit", "sweep::shard"];

/// Modules that accumulate per-run statistics into reports. Floating
/// point here would make warm-cache artifacts drift; floats belong in the
/// render layer (`exp/output.rs`, `figures.rs`) or the declared derived-
/// metric read-outs in [`D4_EXEMPT_FILES`].
pub const D4_MODULES: &[&str] = &["stats", "coordinator", "subscription", "exp", "sweep"];

/// Read-out files exempt from D4: they *derive* presentation ratios from
/// already-frozen integer counters (never accumulated back into state),
/// or render/parse JSON numbers generically.
pub const D4_EXEMPT_FILES: &[&str] = &[
    "stats/breakdown.rs",
    "stats/cov.rs",
    "stats/reuse.rs",
    "stats/traffic.rs",
    "coordinator/report.rs",
    "exp/output.rs",
    "sweep/json.rs",
];

/// Reserved id for the allow-annotation checker (not a table row: it
/// guards the escape hatch itself, so it cannot be allowed away).
pub const A0_ID: &str = "A0";

/// A line-level rule: fires when any `patterns` token appears in the
/// stripped code text of a file where `applies` holds.
pub struct LineRule {
    pub id: &'static str,
    pub title: &'static str,
    pub patterns: &'static [&'static str],
    pub applies: fn(&SourceFile) -> bool,
    pub message: &'static str,
}

/// The rule registry. New rules are new rows.
pub const RULES: &[LineRule] = &[
    LineRule {
        id: "D1",
        title: "no hash-ordered collections in determinism-critical modules",
        patterns: &["HashMap", "HashSet"],
        applies: |f| module_in(&f.module, DETERMINISM_CRITICAL),
        message: "hash-ordered collection in a determinism-critical module; \
                  iteration order varies per process — use BTreeMap/BTreeSet \
                  or a sorted Vec",
    },
    LineRule {
        id: "D2",
        title: "no wall-clock/randomness/env sources outside the harness allowlist",
        patterns: &["Instant::now", "SystemTime", "thread_rng", "env::var", "env::var_os"],
        applies: |f| !module_in(&f.module, D2_ALLOWED),
        message: "nondeterministic input source outside the perf/obs/cli/config \
                  allowlist; simulation output must be a pure function of \
                  (config, seed)",
    },
    LineRule {
        id: "D3",
        title: "atomics in determinism-critical modules must be SeqCst or justified",
        patterns: &[
            "Ordering::Relaxed",
            "Ordering::Acquire",
            "Ordering::Release",
            "Ordering::AcqRel",
        ],
        applies: |f| module_in(&f.module, DETERMINISM_CRITICAL),
        message: "non-SeqCst atomic ordering in a determinism-critical module; \
                  use SeqCst or justify why the ordering cannot affect results",
    },
    LineRule {
        id: "D4",
        title: "no floating-point arithmetic in report-accumulation paths",
        patterns: &["f64", "f32"],
        applies: |f| {
            module_in(&f.module, D4_MODULES)
                && !D4_EXEMPT_FILES.iter().any(|e| f.rel_path.ends_with(e))
        },
        message: "floating-point type in a report-accumulation path; artifacts \
                  stay byte-identical only with exact integer accumulation \
                  (render floats in exp/output.rs or figures.rs)",
    },
];

/// True when `module` equals an entry or is nested under one
/// (`sweep::shard` is in `sweep`; `sweeper` is not).
pub fn module_in(module: &str, list: &[&str]) -> bool {
    list.iter().any(|e| {
        module
            .strip_prefix(e)
            .is_some_and(|rest| rest.is_empty() || rest.starts_with("::"))
    })
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Token-boundary search: `tok` must not be flanked by identifier chars,
/// so `f64` does not match `push_f64` and `env::var` does not match
/// `env::var_os`.
pub fn has_token(code: &str, tok: &str) -> bool {
    let bytes = code.as_bytes();
    let mut start = 0;
    while let Some(pos) = code[start..].find(tok) {
        let at = start + pos;
        let end = at + tok.len();
        let before_ok = at == 0 || !is_ident_byte(bytes[at - 1]);
        let after_ok = end >= bytes.len() || !is_ident_byte(bytes[end]);
        if before_ok && after_ok {
            return true;
        }
        start = at + 1;
    }
    false
}

/// Run every applicable line rule over one file, resolving `lint:allow`
/// shields, then validate the file's allow annotations (A0).
pub fn check_file(file: &SourceFile) -> Vec<Finding> {
    let mut out = Vec::new();
    for rule in RULES {
        if !(rule.applies)(file) {
            continue;
        }
        for line in &file.lines {
            if line.in_test {
                continue;
            }
            for pat in rule.patterns {
                if !has_token(&line.code, pat) {
                    continue;
                }
                let allowed = file
                    .allows_for(line.number)
                    .find(|a| a.rules.iter().any(|r| r == rule.id))
                    .and_then(|a| a.justification.clone());
                out.push(Finding {
                    rule: rule.id,
                    file: file.rel_path.clone(),
                    line: line.number,
                    message: format!("`{pat}`: {}", squeeze(rule.message)),
                    allowed,
                });
            }
        }
    }
    out.extend(check_allows(file));
    out
}

/// A0: every `lint:allow` must name known rule ids and carry a real
/// justification. `--fix-allow` inserts `TODO` placeholders, which are
/// still errors — the tree stays red until a human writes the reason.
pub fn check_allows(file: &SourceFile) -> Vec<Finding> {
    let known: Vec<&str> = RULES.iter().map(|r| r.id).chain(["D5"]).collect();
    let mut out = Vec::new();
    for (_, allow) in &file.allows {
        let at = |message: String| Finding {
            rule: A0_ID,
            file: file.rel_path.clone(),
            line: allow.line,
            message,
            allowed: None,
        };
        if allow.rules.is_empty() {
            out.push(at("lint:allow names no rule id".to_string()));
        }
        for r in &allow.rules {
            if !known.contains(&r.as_str()) {
                out.push(at(format!("lint:allow names unknown rule id `{r}`")));
            }
        }
        match &allow.justification {
            None => out.push(at(
                "lint:allow without a justification (append `-- <why>`)".to_string(),
            )),
            Some(j) if j.starts_with("TODO") => out.push(at(format!(
                "lint:allow justification is a placeholder: {j:?}"
            ))),
            Some(_) => {}
        }
    }
    out
}

// Multi-line string literals in the table above keep source lines short
// but embed the indentation; collapse runs of whitespace for reports.
fn squeeze(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut ws = false;
    for c in s.chars() {
        if c.is_whitespace() {
            ws = true;
        } else {
            if ws && !out.is_empty() {
                out.push(' ');
            }
            ws = false;
            out.push(c);
        }
    }
    out
}

// ---------------------------------------------------------------------------
// D5: the ARCHITECTURE.md invariant tables and rust/tests/ must agree.
// ---------------------------------------------------------------------------

/// What a backticked span in a "Pinned by" cell claims to name.
enum TestRef {
    /// `tests/foo.rs` — an integration test file.
    File(String),
    /// `some_test_fn` or `path::to::fn_name` or `fn_prefix*`.
    Fn { name: String, prefix: bool },
}

/// D5, both directions:
/// 1. every row of a "Pinned by" table in ARCHITECTURE.md must name at
///    least one test that exists (a `tests/*.rs` file or a `fn` defined
///    somewhere under `rust/src` or `rust/tests`);
/// 2. every `rust/tests/*.rs` file must be mentioned in at least one doc
///    (`rust/README.md`, `rust/docs/*.md`) or a CHANGES.md entry.
pub fn check_cross_file(repo: &Repo) -> Vec<Finding> {
    let mut out = Vec::new();
    if let Some((arch_path, arch_text)) = &repo.architecture {
        check_invariant_tables(repo, arch_path, arch_text, &mut out);
    }
    check_tests_documented(repo, &mut out);
    out
}

fn check_invariant_tables(
    repo: &Repo,
    arch_path: &str,
    arch_text: &str,
    out: &mut Vec<Finding>,
) {
    let mut in_table = false;
    for (idx, raw) in arch_text.lines().enumerate() {
        let line_no = idx + 1;
        let t = raw.trim();
        if !t.starts_with('|') {
            in_table = false;
            continue;
        }
        let cells = split_row(t);
        if !in_table {
            in_table = cells.last().is_some_and(|c| c.contains("Pinned by"));
            continue;
        }
        if cells.iter().all(|c| c.chars().all(|ch| matches!(ch, '-' | ':' | ' '))) {
            continue; // the |---|---| separator under the header
        }
        let Some(pinned_cell) = cells.last() else { continue };
        // Rows may carry `<!-- lint:allow(D5) -- why -->`; the allow (and
        // its A0 validation) is handled exactly like the Rust form.
        let allow = crate::lint::scan::parse_allow(raw, line_no).map(|mut a| {
            if let Some(j) = a.justification.take() {
                let j = j.trim_end_matches("-->").trim().to_string();
                a.justification = (!j.is_empty()).then_some(j);
            }
            a
        });
        let shields_d5 = allow.as_ref().is_some_and(|a| {
            a.rules.iter().any(|r| r == "D5") && a.justification.is_some()
        });
        let justification = allow.as_ref().and_then(|a| a.justification.clone());
        let finding = |message: String| Finding {
            rule: "D5",
            file: arch_path.to_string(),
            line: line_no,
            message,
            allowed: if shields_d5 { justification.clone() } else { None },
        };
        let refs = test_refs(pinned_cell);
        if refs.is_empty() {
            out.push(finding(
                "invariant row pins no test (name a `tests/*.rs` file or a \
                 `#[test]` fn in backticks in the last column)"
                    .to_string(),
            ));
        }
        for r in refs {
            match r {
                TestRef::File(rel) => {
                    if !repo.tests.iter().any(|t| t.rel_path == format!("rust/{rel}")) {
                        out.push(finding(format!(
                            "invariant row pins `{rel}`, which does not exist under rust/tests/"
                        )));
                    }
                }
                TestRef::Fn { name, prefix } => {
                    let defined = repo
                        .sources
                        .iter()
                        .chain(&repo.tests)
                        .any(|f| defines_fn(&f.raw, &name, prefix));
                    if !defined {
                        out.push(finding(format!(
                            "invariant row pins fn `{name}{}`, which is not defined \
                             under rust/src or rust/tests",
                            if prefix { "*" } else { "" }
                        )));
                    }
                }
            }
        }
    }
}

fn check_tests_documented(repo: &Repo, out: &mut Vec<Finding>) {
    for test in &repo.tests {
        let stem = test
            .rel_path
            .rsplit('/')
            .next()
            .and_then(|n| n.strip_suffix(".rs"))
            .unwrap_or(&test.rel_path);
        let documented = repo.docs.iter().any(|(_, text)| text.contains(stem));
        if documented {
            continue;
        }
        // An undocumented test can carry a justified file-level allow.
        let allow = test
            .allows
            .iter()
            .map(|(_, a)| a)
            .find(|a| a.rules.iter().any(|r| r == "D5"));
        out.push(Finding {
            rule: "D5",
            file: test.rel_path.clone(),
            line: 1,
            message: format!(
                "integration test `{stem}` is not mentioned in any doc \
                 (rust/README.md, rust/docs/*.md) or CHANGES.md entry"
            ),
            allowed: allow.and_then(|a| a.justification.clone()),
        });
    }
}

/// Split a markdown table row into trimmed cells.
fn split_row(row: &str) -> Vec<String> {
    row.trim()
        .trim_start_matches('|')
        .trim_end_matches('|')
        .split('|')
        .map(|c| c.trim().to_string())
        .collect()
}

/// Extract test references from the backticked spans of a "Pinned by"
/// cell. Spans that are neither `tests/*.rs` paths nor snake_case fn
/// names (e.g. `SimConfig`, CI job names) are ignored.
fn test_refs(cell: &str) -> Vec<TestRef> {
    let mut refs = Vec::new();
    for span in backtick_spans(cell) {
        if span.starts_with("tests/") && span.ends_with(".rs") {
            refs.push(TestRef::File(span));
        } else if span.contains('_')
            && span.chars().all(|c| c.is_ascii_alphanumeric() || matches!(c, '_' | ':' | '*'))
        {
            let prefix = span.ends_with('*');
            let name = span
                .trim_end_matches('*')
                .rsplit("::")
                .next()
                .unwrap_or(&span)
                .to_string();
            if !name.is_empty() {
                refs.push(TestRef::Fn { name, prefix });
            }
        }
    }
    refs
}

fn backtick_spans(text: &str) -> Vec<String> {
    let mut spans = Vec::new();
    let mut rest = text;
    while let Some(open) = rest.find('`') {
        let after = &rest[open + 1..];
        let Some(close) = after.find('`') else { break };
        spans.push(after[..close].to_string());
        rest = &after[close + 1..];
    }
    spans
}

/// Is `fn <name>` defined anywhere in `raw`? With `prefix`, `name` only
/// needs to start the fn identifier. Searches raw text (comments and all)
/// — test names are long snake_case strings, so collisions are unlikely
/// and this keeps the check cheap.
fn defines_fn(raw: &str, name: &str, prefix: bool) -> bool {
    let pat = format!("fn {name}");
    let bytes = raw.as_bytes();
    let mut start = 0;
    while let Some(pos) = raw[start..].find(&pat) {
        let at = start + pos;
        let end = at + pat.len();
        let before_ok = at == 0 || !is_ident_byte(bytes[at - 1]);
        let after_ok = prefix || end >= bytes.len() || !is_ident_byte(bytes[end]);
        if before_ok && after_ok {
            return true;
        }
        start = at + 1;
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::scan::scan_source;

    #[test]
    fn module_matching_is_prefix_safe() {
        assert!(module_in("sweep", DETERMINISM_CRITICAL));
        assert!(module_in("sweep::shard", DETERMINISM_CRITICAL));
        assert!(!module_in("sweeper", DETERMINISM_CRITICAL));
        assert!(!module_in("lint::rules", DETERMINISM_CRITICAL));
    }

    #[test]
    fn token_boundaries() {
        assert!(has_token("let m: HashMap<u64, u8>;", "HashMap"));
        assert!(!has_token("fn push_f64(v: u64) {}", "f64"));
        assert!(has_token("raw.parse::<f64>()", "f64"));
        assert!(!has_token("std::env::var_os(k)", "env::var"));
        assert!(has_token("std::env::var(k)", "env::var"));
        assert!(!has_token("MyHashMapLike", "HashMap"));
    }

    #[test]
    fn d1_fires_in_critical_module_and_not_in_cli() {
        let bad = scan_source("rust/src/sim/core.rs", "let m = HashMap::new();");
        assert_eq!(check_file(&bad).len(), 1);
        let ok = scan_source("rust/src/cli.rs", "let m = HashMap::new();");
        assert!(check_file(&ok).is_empty());
    }

    #[test]
    fn allow_with_justification_shields_and_without_is_a0() {
        let shielded = scan_source(
            "rust/src/sim/core.rs",
            "let m = HashMap::new(); // lint:allow(D1) -- scratch map, drained sorted",
        );
        let fs = check_file(&shielded);
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].allowed.as_deref(), Some("scratch map, drained sorted"));

        let bare = scan_source(
            "rust/src/sim/core.rs",
            "let m = HashMap::new(); // lint:allow(D1)",
        );
        let fs = check_file(&bare);
        assert!(fs.iter().any(|f| f.rule == "D1" && f.allowed.is_none()));
        assert!(fs.iter().any(|f| f.rule == A0_ID));
    }

    #[test]
    fn unknown_rule_id_is_a0() {
        let f = scan_source("rust/src/sim/core.rs", "x(); // lint:allow(D9) -- nope");
        assert!(check_file(&f).iter().any(|f| f.rule == A0_ID
            && f.message.contains("unknown rule id `D9`")));
    }

    #[test]
    fn backtick_and_ref_extraction() {
        let refs = test_refs("`tests/golden.rs`, `figure_rows_*` and `SimConfig`");
        assert_eq!(refs.len(), 2);
        assert!(matches!(&refs[0], TestRef::File(p) if p == "tests/golden.rs"));
        assert!(matches!(&refs[1], TestRef::Fn { name, prefix: true } if name == "figure_rows_"));
    }

    #[test]
    fn fn_definition_search() {
        let raw = "pub fn figure_rows_match() {}\nfn other() {}";
        assert!(defines_fn(raw, "figure_rows_match", false));
        assert!(!defines_fn(raw, "figure_rows", false));
        assert!(defines_fn(raw, "figure_rows_", true));
        assert!(!defines_fn(raw, "missing", false));
    }
}

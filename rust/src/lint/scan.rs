//! Source scanner for the lint engine: splits a Rust source file into
//! per-line *code text* with string literals, comments and `#[cfg(test)]`
//! blocks stripped, and collects `lint:allow` annotations.
//!
//! This is deliberately **not** a parser (the repo is dependency-free, so
//! `syn` is off the table) — it is a line/token-level scanner with just
//! enough lexical state to be trustworthy:
//!
//! * string literals (including multi-line and raw `r#"…"#` strings) and
//!   char literals are blanked out, so a rule pattern inside a string can
//!   never fire;
//! * `//` line comments and (nested) `/* … */` block comments are blanked
//!   out of the code text, with line-comment text kept aside for
//!   `lint:allow` parsing;
//! * `#[cfg(test)]` items are tracked by brace depth and their lines
//!   marked `in_test`, so unit-test code is never linted (the production
//!   rules exist to protect shipped determinism, not test scaffolding).
//!
//! The allow syntax is `// lint:allow(D1,D3) -- <justification>`. The
//! justification is **mandatory** — an allow without one (or with a
//! `TODO…` placeholder, which is what `repro lint --fix-allow` inserts) is
//! itself reported under rule [`A0`](crate::lint::rules::A0_ID). A
//! trailing allow applies to its own line; an allow on a line of its own
//! applies to the next line that carries code.

/// One `lint:allow(…)` annotation, parsed from a `//` comment.
#[derive(Debug, Clone, PartialEq)]
pub struct Allow {
    /// Rule ids listed inside the parentheses, e.g. `["D1", "D3"]`.
    pub rules: Vec<String>,
    /// The text after `--`, if present and non-empty.
    pub justification: Option<String>,
    /// 1-based line the annotation itself sits on.
    pub line: usize,
}

/// One physical source line after lexical stripping.
#[derive(Debug)]
pub struct Line {
    /// 1-based line number.
    pub number: usize,
    /// The line's code content; stripped spans are replaced by spaces so
    /// column arithmetic stays meaningful.
    pub code: String,
    /// True for lines inside a `#[cfg(test)]` item (the attribute line
    /// and the braced block it gates).
    pub in_test: bool,
}

/// A scanned source file.
#[derive(Debug)]
pub struct SourceFile {
    /// Repo-relative path with `/` separators, e.g. `rust/src/sweep/shard.rs`.
    pub rel_path: String,
    /// Module path derived from the file path, e.g. `sweep::shard`.
    pub module: String,
    pub lines: Vec<Line>,
    /// `(target_line, allow)` pairs: the line each annotation shields.
    pub allows: Vec<(usize, Allow)>,
    /// The raw, unstripped text (cross-file rules search it for `fn` names).
    pub raw: String,
}

impl SourceFile {
    /// Allows shielding `line`, in file order.
    pub fn allows_for(&self, line: usize) -> impl Iterator<Item = &Allow> {
        self.allows.iter().filter(move |(t, _)| *t == line).map(|(_, a)| a)
    }
}

/// Module path for a source file path: `rust/src/sweep/shard.rs` →
/// `sweep::shard`, `rust/src/config/mod.rs` → `config`, `rust/src/main.rs`
/// → `main`.
pub fn module_of(rel_path: &str) -> String {
    let p = rel_path
        .strip_prefix("rust/src/")
        .or_else(|| rel_path.strip_prefix("src/"))
        .unwrap_or(rel_path);
    let p = p.strip_suffix(".rs").unwrap_or(p);
    let p = p.strip_suffix("/mod").unwrap_or(p);
    p.replace('/', "::")
}

/// Lexer state that survives line breaks.
enum Mode {
    Code,
    /// Nested depth of `/* … */`.
    Block(u32),
    /// Inside a `"…"` string literal.
    Str,
    /// Inside an `r##"…"##` raw string with this many `#`s.
    RawStr(u32),
}

/// Scan `text` into per-line code/comment pairs and `lint:allow`s.
pub fn scan_source(rel_path: &str, text: &str) -> SourceFile {
    let module = module_of(rel_path);
    let mut mode = Mode::Code;
    // (code text, line-comment text) per physical line.
    let mut stripped: Vec<(String, String)> = Vec::new();
    for raw_line in text.split('\n') {
        let chars: Vec<char> = raw_line.chars().collect();
        let mut code = String::with_capacity(chars.len());
        let mut comment = String::new();
        let mut i = 0usize;
        while i < chars.len() {
            match mode {
                Mode::Block(depth) => {
                    if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                        mode = if depth > 1 { Mode::Block(depth - 1) } else { Mode::Code };
                        code.push_str("  ");
                        i += 2;
                    } else if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                        mode = Mode::Block(depth + 1);
                        code.push_str("  ");
                        i += 2;
                    } else {
                        code.push(' ');
                        i += 1;
                    }
                }
                Mode::Str => {
                    if chars[i] == '\\' {
                        code.push_str("  ");
                        i += 2; // escape sequence (possibly past EOL: line continuation)
                    } else if chars[i] == '"' {
                        code.push('"');
                        mode = Mode::Code;
                        i += 1;
                    } else {
                        code.push(' ');
                        i += 1;
                    }
                }
                Mode::RawStr(hashes) => {
                    if chars[i] == '"'
                        && (1..=hashes as usize)
                            .all(|k| chars.get(i + k) == Some(&'#'))
                    {
                        code.push('"');
                        for _ in 0..hashes {
                            code.push(' ');
                        }
                        mode = Mode::Code;
                        i += 1 + hashes as usize;
                    } else {
                        code.push(' ');
                        i += 1;
                    }
                }
                Mode::Code => {
                    let c = chars[i];
                    if c == '/' && chars.get(i + 1) == Some(&'/') {
                        comment = chars[i + 2..].iter().collect();
                        break; // rest of the line is comment
                    } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                        mode = Mode::Block(1);
                        code.push_str("  ");
                        i += 2;
                    } else if c == '"' {
                        code.push('"');
                        mode = Mode::Str;
                        i += 1;
                    } else if c == 'r'
                        && !prev_is_ident(&code)
                        && raw_str_hashes(&chars[i + 1..]).is_some()
                    {
                        let hashes = raw_str_hashes(&chars[i + 1..]).unwrap_or(0);
                        code.push('r');
                        for _ in 0..=hashes {
                            code.push(' ');
                        }
                        mode = Mode::RawStr(hashes);
                        i += 2 + hashes as usize;
                    } else if c == '\'' {
                        // Char literal vs lifetime: a literal closes within
                        // a couple of chars ('x', '\n', '\u{1F600}'); a
                        // lifetime ('a, 'static) never closes.
                        match char_literal_len(&chars[i..]) {
                            Some(len) => {
                                for _ in 0..len {
                                    code.push(' ');
                                }
                                i += len;
                            }
                            None => {
                                code.push('\'');
                                i += 1;
                            }
                        }
                    } else {
                        code.push(c);
                        i += 1;
                    }
                }
            }
        }
        stripped.push((code, comment));
    }

    // #[cfg(test)] tracking over the code text.
    let mut lines = Vec::with_capacity(stripped.len());
    let mut in_test = false;
    let mut pending = false; // saw the attribute, waiting for the block
    let mut depth: i64 = 0;
    for (idx, (code, _)) in stripped.iter().enumerate() {
        let mut this_is_test = in_test;
        if in_test {
            depth += brace_delta(code);
            if depth <= 0 {
                in_test = false;
            }
        } else if pending {
            this_is_test = true;
            if code.contains('{') {
                depth = brace_delta(code);
                pending = false;
                in_test = depth > 0;
            } else if code.contains(';') {
                pending = false; // attribute gated a braceless item
            }
        } else if code.contains("cfg(test)") {
            this_is_test = true;
            let rest: String =
                code[code.find("cfg(test)").unwrap_or(0)..].chars().collect();
            if let Some(b) = rest.find('{') {
                depth = brace_delta(&rest[b..]);
                in_test = depth > 0;
            } else if !rest.contains(';') {
                pending = true;
            }
        }
        lines.push(Line { number: idx + 1, code: code.clone(), in_test: this_is_test });
    }

    // lint:allow parsing + attachment.
    let mut allows = Vec::new();
    for (idx, (code, comment)) in stripped.iter().enumerate() {
        let Some(allow) = parse_allow(comment, idx + 1) else { continue };
        let target = if code.trim().is_empty() {
            // Standalone comment: shield the next line that carries code.
            stripped
                .iter()
                .enumerate()
                .skip(idx + 1)
                .find(|(_, (c, _))| !c.trim().is_empty())
                .map(|(j, _)| j + 1)
                .unwrap_or(idx + 1)
        } else {
            idx + 1
        };
        allows.push((target, allow));
    }

    SourceFile { rel_path: rel_path.to_string(), module, lines, allows, raw: text.to_string() }
}

/// Parse `lint:allow(R1,R2) -- justification` out of a comment's text.
/// Returns `None` when the comment carries no annotation at all;
/// a malformed annotation still returns (with empty rules and/or no
/// justification) so the engine can report it.
///
/// Doc comments (`///`, `//!`) are documentation, not annotations — the
/// syntax may be *described* there (as this very module does) without
/// creating an escape hatch.
pub fn parse_allow(comment: &str, line: usize) -> Option<Allow> {
    if comment.starts_with('/') || comment.starts_with('!') {
        return None;
    }
    let start = comment.find("lint:allow(")?;
    let after = &comment[start + "lint:allow(".len()..];
    let close = after.find(')');
    let (inside, rest) = match close {
        Some(c) => (&after[..c], &after[c + 1..]),
        None => (after, ""),
    };
    let rules: Vec<String> = inside
        .split(',')
        .map(|r| r.trim().to_string())
        .filter(|r| !r.is_empty())
        .collect();
    let justification = rest
        .trim_start()
        .strip_prefix("--")
        .map(|j| j.trim())
        .filter(|j| !j.is_empty())
        .map(|j| j.to_string());
    Some(Allow { rules, justification, line })
}

fn prev_is_ident(code: &str) -> bool {
    code.chars().next_back().is_some_and(|c| c.is_ascii_alphanumeric() || c == '_')
}

/// If `chars` (just past an `r`) opens a raw string, the number of `#`s.
fn raw_str_hashes(chars: &[char]) -> Option<u32> {
    let mut n = 0u32;
    for &c in chars {
        match c {
            '#' => n += 1,
            '"' => return Some(n),
            _ => return None,
        }
    }
    None
}

/// Length of a char literal starting at `chars[0] == '\''`, or `None`
/// for a lifetime.
fn char_literal_len(chars: &[char]) -> Option<usize> {
    match chars.get(1)? {
        '\\' => {
            // Escape: scan to the closing quote (bounded — a lifetime
            // can't start with a backslash, so this is always a literal).
            let close = chars.iter().skip(2).position(|&c| c == '\'')?;
            Some(close + 3)
        }
        _ => (chars.get(2) == Some(&'\'')).then_some(3),
    }
}

fn brace_delta(code: &str) -> i64 {
    let mut d = 0i64;
    for c in code.chars() {
        match c {
            '{' => d += 1,
            '}' => d -= 1,
            _ => {}
        }
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(text: &str) -> Vec<String> {
        scan_source("rust/src/sim/mod.rs", text).lines.into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn module_paths() {
        assert_eq!(module_of("rust/src/sweep/shard.rs"), "sweep::shard");
        assert_eq!(module_of("rust/src/config/mod.rs"), "config");
        assert_eq!(module_of("rust/src/main.rs"), "main");
        assert_eq!(module_of("rust/src/figures.rs"), "figures");
        assert_eq!(module_of("src/obs/log.rs"), "obs::log");
    }

    #[test]
    fn strings_are_blanked() {
        let c = codes(r#"let x = "HashMap::new()"; call(x);"#);
        assert!(!c[0].contains("HashMap"), "{:?}", c[0]);
        assert!(c[0].contains("call(x)"));
    }

    #[test]
    fn raw_strings_are_blanked_across_lines() {
        let c = codes("let x = r#\"first HashMap\nsecond SystemTime\"#;\nlet y = 1;");
        assert!(!c[0].contains("HashMap"));
        assert!(!c[1].contains("SystemTime"));
        assert!(c[2].contains("let y = 1"));
    }

    #[test]
    fn line_comments_are_blanked_but_kept_for_allows() {
        let f = scan_source(
            "rust/src/sim/mod.rs",
            "let a = 1; // HashMap here is fine\nlet b = 2; // lint:allow(D1) -- test reason",
        );
        assert!(!f.lines[0].code.contains("HashMap"));
        assert_eq!(f.allows.len(), 1);
        assert_eq!(f.allows[0].0, 2);
        assert_eq!(f.allows[0].1.rules, vec!["D1"]);
        assert_eq!(f.allows[0].1.justification.as_deref(), Some("test reason"));
    }

    #[test]
    fn block_comments_nest_and_span_lines() {
        let c = codes("a; /* x /* HashMap */ still comment */ b;\n/* open\nSystemTime\n*/ c;");
        assert!(!c[0].contains("HashMap"));
        assert!(c[0].contains("b;"));
        assert!(!c[2].contains("SystemTime"));
        assert!(c[3].contains("c;"));
    }

    #[test]
    fn char_literals_and_lifetimes() {
        let c = codes("let q = '\"'; let s = \"HashMap\"; fn f<'a>(x: &'a str) {}");
        assert!(!c[0].contains("HashMap"), "{:?}", c[0]);
        assert!(c[0].contains("fn f<'a>"), "{:?}", c[0]);
    }

    #[test]
    fn cfg_test_blocks_are_marked() {
        let f = scan_source(
            "rust/src/sim/mod.rs",
            "fn real() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x() }\n}\nfn after() {}",
        );
        let flags: Vec<bool> = f.lines.iter().map(|l| l.in_test).collect();
        assert_eq!(flags, vec![false, true, true, true, true, false]);
    }

    #[test]
    fn cfg_not_test_is_not_a_test_block() {
        let f = scan_source(
            "rust/src/sim/mod.rs",
            "#[cfg(not(test))]\nfn real() {\n    body();\n}",
        );
        assert!(f.lines.iter().all(|l| !l.in_test));
    }

    #[test]
    fn cfg_test_on_braceless_item_ends_at_semicolon() {
        let f = scan_source(
            "rust/src/sim/mod.rs",
            "#[cfg(test)]\nuse std::collections::HashMap;\nfn real() {}",
        );
        assert!(f.lines[1].in_test);
        assert!(!f.lines[2].in_test);
    }

    #[test]
    fn standalone_allow_attaches_to_next_code_line() {
        let f = scan_source(
            "rust/src/sim/mod.rs",
            "// lint:allow(D2,D3) -- both justified\n\nlet t = now();",
        );
        assert_eq!(f.allows.len(), 1);
        assert_eq!(f.allows[0].0, 3);
        assert_eq!(f.allows[0].1.rules, vec!["D2", "D3"]);
    }

    #[test]
    fn allow_without_justification_parses_as_none() {
        let a = parse_allow("lint:allow(D1)", 7).unwrap();
        assert_eq!(a.rules, vec!["D1"]);
        assert_eq!(a.justification, None);
        let b = parse_allow("lint:allow(D1) --   ", 7).unwrap();
        assert_eq!(b.justification, None);
        assert_eq!(parse_allow("no annotation here", 1), None);
    }

    #[test]
    fn doc_comments_are_not_annotations() {
        // `///` and `//!` comments reach parse_allow with a leading `/` or
        // `!`; describing the syntax in docs must not create an allow.
        assert_eq!(parse_allow("/ the syntax is `// lint:allow(D1) -- why`", 1), None);
        assert_eq!(parse_allow("! see lint:allow(D2) -- in LINTING.md", 1), None);
        let f = scan_source(
            "rust/src/sim/mod.rs",
            "/// docs: lint:allow(D1) -- example\nfn real() {}",
        );
        assert!(f.allows.is_empty());
    }
}

//! The discrete-event simulation driver: runs a workload's cores over the
//! [`MemorySystem`] facade under a policy and produces a [`SimReport`].
//!
//! Methodology follows §IV-A: a warmup of `warmup_requests` memory
//! requests (caches and subscription tables stay warm, statistics reset),
//! then a measured window of `measure_requests`, repeated `runs` times with
//! different seeds and averaged. In debug builds the distributed
//! subscription directory is consistency-checked at both measure-window
//! boundaries, so protocol regressions fail loudly in `cargo test` instead
//! of silently skewing figures.
//!
//! Two drivers share these semantics bit for bit: [`simulate_once`], which
//! delegates to the event kernel's batched data-oriented hot path
//! (cycle-window event admission, flat stats frames — see
//! [`crate::coordinator::kernel`] and [`crate::coordinator::batch`]), and
//! [`simulate_once_scalar`], the original heap-driven reference that the
//! equivalence tests diff against.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::config::SimConfig;
use crate::coordinator::batch::Frame;
use crate::coordinator::core::PimCore;
use crate::coordinator::kernel::Kernel;
use crate::coordinator::l1::L1Result;
use crate::coordinator::report::{RunReport, SimReport};
use crate::memsys::{Access, MemorySystem, ServedRequest};
use crate::policy::PolicyRuntime;
use crate::workloads::Workload;
use crate::Cycle;

/// Hard safety valve against a workload that stops missing its L1.
pub(crate) const MAX_OPS_PER_RUN: u64 = 2_000_000_000;

/// Run `cfg.runs` independent simulations of `workload` and aggregate.
pub fn simulate(cfg: &SimConfig, mut workload: Box<dyn Workload>) -> SimReport {
    let name = workload.name().to_string();
    let mut runs = Vec::with_capacity(cfg.runs as usize);
    for r in 0..cfg.runs.max(1) {
        workload.reset(cfg.seed.wrapping_add(r as u64));
        runs.push(simulate_once(cfg, workload.as_mut()));
    }
    SimReport { workload: name, policy: cfg.policy.as_str(), runs }
}

/// [`simulate`] with a per-request observer threaded through every run —
/// the sweep engine's metrics path. The observer only reads each
/// [`ServedRequest`], so the report is identical to [`simulate`] by
/// construction (pinned by `tests/observability.rs`).
pub fn simulate_observed<F: FnMut(Access, &ServedRequest)>(
    cfg: &SimConfig,
    mut workload: Box<dyn Workload>,
    mut obs: F,
) -> SimReport {
    let name = workload.name().to_string();
    let mut runs = Vec::with_capacity(cfg.runs as usize);
    for r in 0..cfg.runs.max(1) {
        workload.reset(cfg.seed.wrapping_add(r as u64));
        runs.push(simulate_once_observed(cfg, workload.as_mut(), &mut obs));
    }
    SimReport { workload: name, policy: cfg.policy.as_str(), runs }
}

/// Warmup/measure bookkeeping of one run (shared by the scalar reference
/// and the event kernel).
pub(crate) struct MeasureWindow {
    pub(crate) warmup_requests: u64,
    pub(crate) warmed: bool,
    /// Memory (post-L1) requests served, including warmup.
    pub(crate) total_requests: u64,
    /// Requests served inside the measure window.
    pub(crate) measured: u64,
    pub(crate) measure_start: Cycle,
}

impl MeasureWindow {
    pub(crate) fn new(cfg: &SimConfig) -> Self {
        MeasureWindow {
            warmup_requests: cfg.warmup_requests,
            warmed: cfg.warmup_requests == 0,
            total_requests: 0,
            measured: 0,
            measure_start: 0,
        }
    }

    /// Warmup-boundary check, run once per core op *after* all of the
    /// op's memory requests (a dirty-eviction writeback and its read fill
    /// stay in the same window).
    fn end_of_op(&mut self, mem: &mut MemorySystem, core_time: Cycle) {
        if !self.warmed && self.total_requests >= self.warmup_requests {
            debug_check_directory(mem, core_time);
            mem.stats_mut().reset();
            self.warmed = true;
            self.measure_start = core_time;
        }
    }

    /// Batched-path warmup boundary: identical to [`Self::end_of_op`],
    /// except the pending [`Frame`] is folded first so the boundary
    /// `stats.reset()` wipes the pre-warm contributions exactly as the
    /// scalar warmed-gate would have skipped them.
    pub(crate) fn end_of_op_batched(
        &mut self,
        mem: &mut MemorySystem,
        frame: &mut Frame,
        core_time: Cycle,
    ) {
        if !self.warmed && self.total_requests >= self.warmup_requests {
            frame.fold_into(mem.stats_mut());
            debug_check_directory(mem, core_time);
            mem.stats_mut().reset();
            self.warmed = true;
            self.measure_start = core_time;
        }
    }
}

/// `debug_assertions`-gated directory invariant check at measure-window
/// boundaries: cheap insurance that a protocol refactor cannot silently
/// corrupt the distributed directory mid-run. Uses the race-tolerant
/// variant (see `SubSystem::directory_consistent_modeled`) so the
/// protocol's own §III-B4 eager-eviction orphans — modeled hardware
/// behavior, present since the original monolith — do not turn into
/// deterministic test failures, while role mismatches, holder entries
/// without a home side and every other corruption still panic.
pub(crate) fn debug_check_directory(mem: &MemorySystem, now: Cycle) {
    if !cfg!(debug_assertions) {
        return;
    }
    if let Err(e) = mem.directory_consistent_modeled(now) {
        panic!(
            "subscription directory inconsistent at measure-window \
             boundary (cycle {now}): {e}"
        );
    }
}

/// Issue one memory request through the facade: serve it, stall the core's
/// MLP window, record measured statistics and feed the policy registers.
/// This single path replaces the four near-duplicated `L1Result` arms the
/// driver used to thread through `&mut Mesh, &mut Vec<VaultMem>,
/// &mut SimStats`.
fn issue_request<F: FnMut(Access, &ServedRequest)>(
    mem: &mut MemorySystem,
    policy: &mut PolicyRuntime,
    core: &mut PimCore,
    win: &mut MeasureWindow,
    obs: &mut F,
    block: u64,
    write: bool,
) {
    let requester = core.vault;
    let now = core.time;
    let req = Access { requester, block, write };
    let res = mem.serve(req, now, policy);
    obs(req, &res);
    core.note_miss(res.done);
    if win.warmed {
        let stats = mem.stats_mut();
        stats.latency.record(res.network, res.queued, res.array);
        stats.queue_net += res.queued_net;
        // `queued_mem()` asserts the `queued_net <= queued` invariant in
        // debug builds and saturates in release (a raw `queued -
        // queued_net` would panic mid-figure on a violating request).
        stats.queue_mem += res.queued_mem();
        stats.requests += 1;
        win.measured += 1;
    }
    win.total_requests += 1;
    policy.on_request(
        requester,
        res.served_by,
        res.subscribed_path,
        res.actual_hops,
        res.baseline_hops,
        res.network + res.queued + res.array,
        res.set,
        now,
    );
}

/// One simulation run over an already-seeded workload.
///
/// This is the batched data-oriented path — since the event-kernel
/// refactor a thin delegation to the sequential
/// [`Kernel`](crate::coordinator::kernel::Kernel) (cycle-window event
/// admission via `WindowQueue`, flat [`Frame`] stats folded at window
/// boundaries). It is bit-identical to [`simulate_once_scalar`] — the
/// original one-event-at-a-time driver kept as the differential
/// reference — which `tests/batched_equivalence.rs` and
/// `tests/kernel_equivalence.rs` assert request stream by request
/// stream.
pub fn simulate_once(cfg: &SimConfig, workload: &mut dyn Workload) -> RunReport {
    Kernel::single().run_once(cfg, workload)
}

/// [`simulate_once`] with an observer called on every served request in
/// issue order — the hook the scalar-vs-batched differential tests use to
/// capture and compare full `ServedRequest` streams.
pub fn simulate_once_observed<F: FnMut(Access, &ServedRequest)>(
    cfg: &SimConfig,
    workload: &mut dyn Workload,
    obs: F,
) -> RunReport {
    Kernel::single().run_once_observed(cfg, workload, obs)
}

/// The original scalar driver: one `BinaryHeap` event at a time, stats
/// gated per request on the warmup flag. Kept as the bit-identity
/// reference for the batched path (`tests/batched_equivalence.rs` drives
/// both on identical seeds and asserts identical `ServedRequest` streams
/// and reports).
pub fn simulate_once_scalar(cfg: &SimConfig, workload: &mut dyn Workload) -> RunReport {
    simulate_once_scalar_observed(cfg, workload, |_, _| {})
}

/// [`simulate_once_scalar`] with a per-request observer (see
/// [`simulate_once_observed`]).
pub fn simulate_once_scalar_observed<F: FnMut(Access, &ServedRequest)>(
    cfg: &SimConfig,
    workload: &mut dyn Workload,
    mut obs: F,
) -> RunReport {
    debug_assert!(cfg.validate().is_ok());
    let n = cfg.n_vaults;
    let mut mem = MemorySystem::new(cfg);
    let mut policy = PolicyRuntime::new(cfg);
    let mut cores: Vec<PimCore> = (0..n).map(|i| PimCore::new(i, cfg)).collect();
    let block_shift = cfg.block_bytes.trailing_zeros();

    // Event heap: (next issue time, core id), earliest first.
    let mut heap: BinaryHeap<Reverse<(Cycle, u16)>> =
        (0..n).map(|c| Reverse((0, c))).collect();

    let mut win = MeasureWindow::new(cfg);
    let mut ops: u64 = 0;
    let mut last_t: Cycle = 0;
    let mut window_end: Option<Cycle> = None;

    while let Some(Reverse((t, c))) = heap.pop() {
        last_t = last_t.max(t);

        for d in policy.tick(t) {
            mem.broadcast_decision(&d);
        }

        let Some(op) = workload.next_op(c) else {
            cores[c as usize].finished = true;
            if cores.iter().all(|k| k.finished) {
                break;
            }
            continue;
        };
        ops += 1;
        if ops > MAX_OPS_PER_RUN {
            break;
        }

        let core = &mut cores[c as usize];
        core.time = t + op.gap as Cycle;
        core.ops += 1;
        let block = op.addr >> block_shift;

        match core.l1.access(block, op.write) {
            L1Result::Hit => {
                core.time += 1; // L1 hit latency
                if win.warmed {
                    mem.stats_mut().l1_hits += 1;
                }
            }
            L1Result::WriteMiss => {
                // Streaming store: write-no-allocate, straight to memory.
                let core = &mut cores[c as usize];
                issue_request(&mut mem, &mut policy, core, &mut win, &mut obs, block, true);
                let core_time = core.time;
                win.end_of_op(&mut mem, core_time);
            }
            L1Result::Miss { writeback } => {
                // Dirty eviction: a posted write to the victim's home.
                if let Some(wb) = writeback {
                    let core = &mut cores[c as usize];
                    issue_request(&mut mem, &mut policy, core, &mut win, &mut obs, wb, true);
                }
                // Read miss: fill the line (stores to resident lines merge
                // in L1 and reach memory later as full-block writebacks).
                let core = &mut cores[c as usize];
                issue_request(&mut mem, &mut policy, core, &mut win, &mut obs, block, false);
                let core_time = core.time;
                win.end_of_op(&mut mem, core_time);
            }
        }

        if win.warmed && win.measured >= cfg.measure_requests {
            debug_check_directory(&mem, cores[c as usize].time);
            // The measured window ends when the *breaking core* finishes
            // its last measured request (including its outstanding MLP
            // misses). Other cores' clocks may sit far past this point —
            // a long compute gap is charged to `core.time` at issue — and
            // maxing over them (the old behaviour) inflated `cycles` by
            // that cross-core drift even though no measured request
            // needed those cycles.
            let breaking = &mut cores[c as usize];
            breaking.drain();
            window_end = Some(breaking.time.max(t));
            break;
        }
        let next = cores[c as usize].time;
        heap.push(Reverse((next, c)));
    }

    for core in &mut cores {
        core.drain();
        last_t = last_t.max(core.time);
    }
    let end = window_end.unwrap_or(last_t);

    RunReport {
        cycles: end.saturating_sub(win.measure_start),
        stats: mem.into_stats(),
        decisions: policy.decisions.clone(),
        exhausted: window_end.is_none() && cores.iter().any(|c| c.finished),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Topology;
    use crate::policy::PolicyKind;
    use crate::workloads::{catalog, Op, Workload};
    use crate::CoreId;

    /// Synthetic streams with per-core op budgets and compute gaps; every
    /// op is a store to a fresh block (write-no-allocate), so each op is
    /// exactly one memory request.
    struct SyntheticStreams {
        /// Remaining ops per core; `u64::MAX` means unbounded.
        left: Vec<u64>,
        next_addr: Vec<u64>,
        /// Compute gap per op, per core.
        gaps: Vec<u32>,
    }

    impl SyntheticStreams {
        fn new(left: Vec<u64>, gaps: Vec<u32>) -> Self {
            let n = left.len();
            SyntheticStreams { left, next_addr: vec![0; n], gaps }
        }
    }

    impl Workload for SyntheticStreams {
        fn name(&self) -> &'static str {
            "SyntheticStreams"
        }

        fn next_op(&mut self, core: CoreId) -> Option<Op> {
            let i = core as usize;
            if self.left[i] == 0 {
                return None;
            }
            if self.left[i] != u64::MAX {
                self.left[i] -= 1;
            }
            let addr = 0x1_0000_0000u64 * (core as u64 + 1) + self.next_addr[i];
            self.next_addr[i] += 4096; // a fresh block every op: always misses
            Some(Op::store(addr, self.gaps[i]))
        }

        fn reset(&mut self, _seed: u64) {
            for a in &mut self.next_addr {
                *a = 0;
            }
        }
    }

    #[test]
    fn measured_window_not_inflated_by_idle_cores() {
        // Core 0 streams back-to-back; every other core schedules ops with
        // a 2M-cycle compute gap, parking its clock far past the window.
        // The report's cycles must clamp to the breaking core's completion
        // time, not the idle cores' future issue times (cross-core drift).
        let mut cfg = SimConfig::hmc().quick();
        cfg.policy = PolicyKind::Never;
        cfg.warmup_requests = 0;
        cfg.measure_requests = 300;
        let n = cfg.n_vaults as usize;
        let mut gaps = vec![2_000_000u32; n];
        gaps[0] = 1;
        let mut w = SyntheticStreams::new(vec![u64::MAX; n], gaps);
        let r = simulate_once(&cfg, &mut w);
        assert!(r.stats.requests >= 300);
        assert!(
            r.cycles < 1_000_000,
            "cycles {} inflated by cores scheduled past the breaking request",
            r.cycles
        );
        assert!(!r.exhausted, "unbounded streams never exhaust");
    }

    #[test]
    fn exhausted_only_when_stream_ends_before_window_fills() {
        // All streams run dry long before the window fills: exhausted.
        let mut cfg = SimConfig::hmc().quick();
        cfg.policy = PolicyKind::Never;
        cfg.warmup_requests = 0;
        cfg.measure_requests = 100_000;
        let n = cfg.n_vaults as usize;
        let mut dry = SyntheticStreams::new(vec![10; n], vec![1; n]);
        let r = simulate_once(&cfg, &mut dry);
        assert!(r.exhausted, "streams ended at {} of 100000 requests", r.stats.requests);

        // The window fills normally even though 31 single-op streams ended
        // long before: NOT exhausted (the pre-fix `any(finished)` flagged
        // this, misreporting every staggered `--no-loop` trace replay).
        cfg.measure_requests = 300;
        let mut left = vec![1u64; n];
        left[0] = u64::MAX;
        let mut staggered = SyntheticStreams::new(left, vec![1; n]);
        let r = simulate_once(&cfg, &mut staggered);
        assert!(r.stats.requests >= 300);
        assert!(
            !r.exhausted,
            "a filled window is a valid measurement regardless of finished cores"
        );
    }

    fn quick(policy: PolicyKind, wl: &str) -> SimReport {
        let mut cfg = SimConfig::hmc().quick();
        cfg.warmup_requests = 2000;
        cfg.measure_requests = 10_000;
        cfg.policy = policy;
        let w = catalog::build(wl, &cfg).unwrap();
        simulate(&cfg, w)
    }

    #[test]
    fn batched_matches_scalar_on_a_quick_run() {
        // Cheap in-module insurance; the full stream-level differential
        // matrix lives in tests/batched_equivalence.rs.
        let mut cfg = SimConfig::hmc().quick();
        cfg.policy = PolicyKind::Adaptive;
        cfg.warmup_requests = 500;
        cfg.measure_requests = 3000;
        let mut wa = catalog::build("SPLRad", &cfg).unwrap();
        wa.reset(cfg.seed);
        let a = simulate_once(&cfg, wa.as_mut());
        let mut wb = catalog::build("SPLRad", &cfg).unwrap();
        wb.reset(cfg.seed);
        let b = simulate_once_scalar(&cfg, wb.as_mut());
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.stats, b.stats);
        assert_eq!(a.exhausted, b.exhausted);
        assert_eq!(a.decisions, b.decisions);
    }

    #[test]
    fn baseline_run_completes_and_measures() {
        let r = quick(PolicyKind::Never, "STRAdd");
        assert_eq!(r.runs.len(), 1);
        assert!(r.runs[0].stats.requests >= 10_000);
        assert!(r.runs[0].cycles > 0);
        assert!(r.avg_latency() > 0.0);
    }

    #[test]
    fn deterministic_across_identical_runs() {
        let a = quick(PolicyKind::Never, "STRCpy");
        let b = quick(PolicyKind::Never, "STRCpy");
        assert_eq!(a.runs[0].cycles, b.runs[0].cycles);
        assert_eq!(a.runs[0].stats.requests, b.runs[0].stats.requests);
        assert_eq!(a.runs[0].stats.latency, b.runs[0].stats.latency);
    }

    #[test]
    fn never_policy_does_not_subscribe() {
        let r = quick(PolicyKind::Never, "PLYgemm");
        assert_eq!(r.runs[0].stats.subscriptions, 0);
    }

    #[test]
    fn always_policy_subscribes() {
        let r = quick(PolicyKind::Always, "PLYgemm");
        assert!(r.runs[0].stats.subscriptions > 0);
    }

    #[test]
    fn adaptive_policy_makes_epoch_decisions() {
        let r = quick(PolicyKind::Adaptive, "SPLRad");
        assert!(!r.runs[0].decisions.is_empty(), "epochs must tick");
    }

    #[test]
    fn latency_breakdown_components_all_present() {
        let r = quick(PolicyKind::Never, "HSJNPO");
        let (n, q, a) = r.latency_fractions();
        assert!(n > 0.0, "network share");
        assert!(a > 0.0, "array share");
        assert!((n + q + a - 1.0).abs() < 1e-9);
    }

    #[test]
    fn multi_run_aggregates() {
        let mut cfg = SimConfig::hmc().quick();
        cfg.warmup_requests = 500;
        cfg.measure_requests = 2000;
        cfg.runs = 3;
        let w = catalog::build("STRTriad", &cfg).unwrap();
        let r = simulate(&cfg, w);
        assert_eq!(r.runs.len(), 3);
    }

    #[test]
    fn every_topology_completes_a_run() {
        for t in [Topology::Mesh, Topology::Crossbar, Topology::Ring] {
            let mut cfg = SimConfig::hmc().quick();
            cfg.topology = t;
            cfg.policy = PolicyKind::Adaptive;
            // No warmup reset: count protocol activity from cycle 0.
            cfg.warmup_requests = 0;
            cfg.measure_requests = 3000;
            let w = catalog::build("SPLRad", &cfg).unwrap();
            let r = simulate(&cfg, w);
            assert!(r.runs[0].stats.requests >= 3000, "{t:?}");
            assert!(r.runs[0].stats.subscriptions > 0, "{t:?}");
        }
    }

    #[test]
    fn crossbar_run_has_one_hop_demand_paths() {
        let mut cfg = SimConfig::hbm().quick();
        cfg.policy = PolicyKind::Never;
        cfg.warmup_requests = 200;
        cfg.measure_requests = 2000;
        let w = catalog::build("STRAdd", &cfg).unwrap();
        let r = simulate(&cfg, w);
        // Uniform 1-hop network: per-request transfer latency is bounded
        // by (k+1) cycles = 6 for remote reads.
        let s = &r.runs[0].stats;
        assert!(s.latency.network <= s.requests * 6, "crossbar hop count");
    }
}

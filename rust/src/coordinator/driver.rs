//! The discrete-event simulation driver: runs a workload's cores over the
//! memory system under a policy and produces a [`SimReport`].
//!
//! Methodology follows §IV-A: a warmup of `warmup_requests` memory
//! requests (caches and subscription tables stay warm, statistics reset),
//! then a measured window of `measure_requests`, repeated `runs` times with
//! different seeds and averaged.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::config::SimConfig;
use crate::coordinator::core::PimCore;
use crate::coordinator::l1::L1Result;
use crate::coordinator::report::{RunReport, SimReport};
use crate::policy::PolicyRuntime;
use crate::sim::{Mesh, PacketKind, VaultMem};
use crate::stats::SimStats;
use crate::subscription::protocol::{Access, SubSystem};
use crate::workloads::Workload;
use crate::Cycle;

/// Hard safety valve against a workload that stops missing its L1.
const MAX_OPS_PER_RUN: u64 = 2_000_000_000;

/// Run `cfg.runs` independent simulations of `workload` and aggregate.
pub fn simulate(cfg: &SimConfig, mut workload: Box<dyn Workload>) -> SimReport {
    let name = workload.name().to_string();
    let mut runs = Vec::with_capacity(cfg.runs as usize);
    for r in 0..cfg.runs.max(1) {
        workload.reset(cfg.seed.wrapping_add(r as u64));
        runs.push(simulate_once(cfg, workload.as_mut()));
    }
    SimReport { workload: name, policy: cfg.policy.as_str(), runs }
}

/// One simulation run over an already-seeded workload.
pub fn simulate_once(cfg: &SimConfig, workload: &mut dyn Workload) -> RunReport {
    debug_assert!(cfg.validate().is_ok());
    let n = cfg.n_vaults;
    let mut mesh = Mesh::new(cfg);
    let mut vaults: Vec<VaultMem> = (0..n).map(|_| VaultMem::new(cfg)).collect();
    let mut subs = SubSystem::new(cfg);
    let mut policy = PolicyRuntime::new(cfg);
    let mut stats = SimStats::new(n);
    let mut cores: Vec<PimCore> = (0..n).map(|i| PimCore::new(i, cfg)).collect();
    let central = mesh.central_vault();
    let flit_bytes = cfg.flit_bytes;
    let block_shift = cfg.block_bytes.trailing_zeros();

    // Event heap: (next issue time, core id), earliest first.
    let mut heap: BinaryHeap<Reverse<(Cycle, u16)>> =
        (0..n).map(|c| Reverse((0, c))).collect();

    let mut total_requests: u64 = 0; // memory (post-L1) requests, incl. warmup
    let mut measured: u64 = 0;
    let mut warmed = cfg.warmup_requests == 0;
    let mut measure_start: Cycle = 0;
    let mut decisions_seen = 0usize;
    let mut ops: u64 = 0;
    let mut last_t: Cycle = 0;

    while let Some(Reverse((t, c))) = heap.pop() {
        last_t = last_t.max(t);

        // Epoch machinery: decisions broadcast from the central vault; the
        // per-vault stats reports and policy packets contend like any
        // other traffic (§III-D4).
        for d in policy.tick(t) {
            subs.decay_all(); // LFU aging at the epoch boundary
            for v in 0..n {
                if v == central {
                    continue;
                }
                let tr = mesh.transfer(v, central, 1, d.at);
                stats.traffic.record(1, tr.hops, flit_bytes, true);
                let kind = if d.enabled {
                    PacketKind::TurnOnSubscription
                } else {
                    PacketKind::TurnOffSubscription
                };
                let tr = mesh.transfer(central, v, kind.flits(cfg), d.at);
                stats.traffic.record(1, tr.hops, flit_bytes, true);
            }
        }
        decisions_seen = policy.decisions.len();

        let Some(op) = workload.next_op(c) else {
            cores[c as usize].finished = true;
            if cores.iter().all(|k| k.finished) {
                break;
            }
            continue;
        };
        ops += 1;
        if ops > MAX_OPS_PER_RUN {
            break;
        }

        let core = &mut cores[c as usize];
        core.time = t + op.gap as Cycle;
        core.ops += 1;
        let block = op.addr >> block_shift;

        match core.l1.access(block, op.write) {
            L1Result::Hit => {
                core.time += 1; // L1 hit latency
                if warmed {
                    stats.l1_hits += 1;
                }
            }
            L1Result::WriteMiss => {
                // Streaming store: write-no-allocate, straight to memory.
                let now = core.time;
                let res = subs.serve(
                    Access { requester: c, block, write: true },
                    now,
                    &mut mesh,
                    &mut vaults,
                    &mut stats,
                    &policy,
                );
                cores[c as usize].note_miss(res.done);
                if warmed {
                    stats.latency.record(res.network, res.queued, res.array);
                    stats.queue_net += res.queued_net;
                    stats.queue_mem += res.queued - res.queued_net;
                    stats.requests += 1;
                    measured += 1;
                }
                total_requests += 1;
                policy.on_request(
                    c,
                    res.served_by,
                    res.subscribed_path,
                    res.actual_hops,
                    res.baseline_hops,
                    res.network + res.queued + res.array,
                    res.set,
                    now,
                );
                if !warmed && total_requests >= cfg.warmup_requests {
                    stats.reset();
                    warmed = true;
                    measure_start = cores[c as usize].time;
                }
            }
            L1Result::Miss { writeback } => {
                // Dirty eviction: a posted write to the victim's home.
                if let Some(wb) = writeback {
                    let now = core.time;
                    let res = subs.serve(
                        Access { requester: c, block: wb, write: true },
                        now,
                        &mut mesh,
                        &mut vaults,
                        &mut stats,
                        &policy,
                    );
                    cores[c as usize].note_miss(res.done);
                    if warmed {
                        stats.latency.record(res.network, res.queued, res.array);
                        stats.requests += 1;
                        measured += 1;
                    }
                    total_requests += 1;
                    policy.on_request(
                        c,
                        res.served_by,
                        res.subscribed_path,
                        res.actual_hops,
                        res.baseline_hops,
                        res.network + res.queued + res.array,
                        res.set,
                        now,
                    );
                }
                // Read miss: fill the line (stores to resident lines merge
                // in L1 and reach memory later as full-block writebacks).
                let core = &mut cores[c as usize];
                let now = core.time;
                let res = subs.serve(
                    Access { requester: c, block, write: false },
                    now,
                    &mut mesh,
                    &mut vaults,
                    &mut stats,
                    &policy,
                );
                cores[c as usize].note_miss(res.done);
                if warmed {
                    stats.latency.record(res.network, res.queued, res.array);
                    stats.queue_net += res.queued_net;
                    stats.queue_mem += res.queued - res.queued_net;
                    stats.requests += 1;
                    measured += 1;
                }
                total_requests += 1;
                policy.on_request(
                    c,
                    res.served_by,
                    res.subscribed_path,
                    res.actual_hops,
                    res.baseline_hops,
                    res.network + res.queued + res.array,
                    res.set,
                    now,
                );

                if !warmed && total_requests >= cfg.warmup_requests {
                    stats.reset();
                    warmed = true;
                    measure_start = cores[c as usize].time;
                }
            }
        }

        if warmed && measured >= cfg.measure_requests {
            break;
        }
        let next = cores[c as usize].time;
        heap.push(Reverse((next, c)));
    }

    let _ = decisions_seen;
    for core in &mut cores {
        core.drain();
        last_t = last_t.max(core.time);
    }

    RunReport {
        cycles: last_t.saturating_sub(measure_start),
        stats,
        decisions: policy.decisions.clone(),
        exhausted: cores.iter().any(|c| c.finished),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::PolicyKind;
    use crate::workloads::catalog;

    fn quick(policy: PolicyKind, wl: &str) -> SimReport {
        let mut cfg = SimConfig::hmc().quick();
        cfg.warmup_requests = 2000;
        cfg.measure_requests = 10_000;
        cfg.policy = policy;
        let w = catalog::build(wl, &cfg).unwrap();
        simulate(&cfg, w)
    }

    #[test]
    fn baseline_run_completes_and_measures() {
        let r = quick(PolicyKind::Never, "STRAdd");
        assert_eq!(r.runs.len(), 1);
        assert!(r.runs[0].stats.requests >= 10_000);
        assert!(r.runs[0].cycles > 0);
        assert!(r.avg_latency() > 0.0);
    }

    #[test]
    fn deterministic_across_identical_runs() {
        let a = quick(PolicyKind::Never, "STRCpy");
        let b = quick(PolicyKind::Never, "STRCpy");
        assert_eq!(a.runs[0].cycles, b.runs[0].cycles);
        assert_eq!(a.runs[0].stats.requests, b.runs[0].stats.requests);
        assert_eq!(a.runs[0].stats.latency, b.runs[0].stats.latency);
    }

    #[test]
    fn never_policy_does_not_subscribe() {
        let r = quick(PolicyKind::Never, "PLYgemm");
        assert_eq!(r.runs[0].stats.subscriptions, 0);
    }

    #[test]
    fn always_policy_subscribes() {
        let r = quick(PolicyKind::Always, "PLYgemm");
        assert!(r.runs[0].stats.subscriptions > 0);
    }

    #[test]
    fn adaptive_policy_makes_epoch_decisions() {
        let r = quick(PolicyKind::Adaptive, "SPLRad");
        assert!(!r.runs[0].decisions.is_empty(), "epochs must tick");
    }

    #[test]
    fn latency_breakdown_components_all_present() {
        let r = quick(PolicyKind::Never, "HSJNPO");
        let (n, q, a) = r.latency_fractions();
        assert!(n > 0.0, "network share");
        assert!(a > 0.0, "array share");
        assert!((n + q + a - 1.0).abs() < 1e-9);
    }

    #[test]
    fn multi_run_aggregates() {
        let mut cfg = SimConfig::hmc().quick();
        cfg.warmup_requests = 500;
        cfg.measure_requests = 2000;
        cfg.runs = 3;
        let w = catalog::build("STRTriad", &cfg).unwrap();
        let r = simulate(&cfg, w);
        assert_eq!(r.runs.len(), 3);
    }
}

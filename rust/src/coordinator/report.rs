//! Simulation reports: per-run raw numbers and multi-run averages — the
//! quantities every figure of the paper is computed from.

use crate::policy::EpochDecision;
use crate::stats::SimStats;
use crate::Cycle;

/// Raw results of a single simulation run (one seed).
#[derive(Clone, Debug, PartialEq)]
pub struct RunReport {
    /// Cycles elapsed over the measured window (fixed request count), the
    /// execution-time proxy used for speedups.
    pub cycles: Cycle,
    /// Statistics accumulated over the measured window.
    pub stats: SimStats,
    /// Epoch decisions taken during the whole run (incl. warmup).
    pub decisions: Vec<EpochDecision>,
    /// True if the workload stream ended before `measure_requests`.
    pub exhausted: bool,
}

impl RunReport {
    pub fn avg_latency(&self) -> f64 {
        self.stats.latency.avg()
    }

    pub fn bytes_per_cycle(&self) -> f64 {
        self.stats.traffic.bytes_per_cycle(self.cycles)
    }
}

/// Aggregate over `runs` independent seeds (5 in the paper's methodology;
/// every accessor reports the mean across runs).
#[derive(Clone, Debug, PartialEq)]
pub struct SimReport {
    pub workload: String,
    pub policy: &'static str,
    pub runs: Vec<RunReport>,
}

impl SimReport {
    fn mean<F: Fn(&RunReport) -> f64>(&self, f: F) -> f64 {
        if self.runs.is_empty() {
            return 0.0;
        }
        self.runs.iter().map(f).sum::<f64>() / self.runs.len() as f64
    }

    /// Mean execution cycles for the fixed measured work.
    pub fn cycles(&self) -> f64 {
        self.mean(|r| r.cycles as f64)
    }

    /// Mean memory latency per request (cycles) — the orange lines of
    /// Figs 11/15.
    pub fn avg_latency(&self) -> f64 {
        self.mean(|r| r.avg_latency())
    }

    /// Mean (network, queue, array) latency fractions — Figs 1/2.
    pub fn latency_fractions(&self) -> (f64, f64, f64) {
        (
            self.mean(|r| r.stats.latency.fractions().0),
            self.mean(|r| r.stats.latency.fractions().1),
            self.mean(|r| r.stats.latency.fractions().2),
        )
    }

    /// Mean (queue_net, queue_mem) latency fractions: the queue share of
    /// [`Self::latency_fractions`] split into interconnect-link wait and
    /// vault controller/bank wait. Per run the two add up to the queue
    /// fraction exactly (`queue_net`/`queue_mem` partition the queue
    /// cycles), so `transfer + queue_net + queue_mem + service = 1` —
    /// the latency-breakdown telemetry row's contract.
    pub fn queue_fractions(&self) -> (f64, f64) {
        let split = |r: &RunReport, part: u64| {
            let total = r.stats.queue_net + r.stats.queue_mem;
            if total == 0 {
                0.0
            } else {
                r.stats.latency.fractions().1 * part as f64 / total as f64
            }
        };
        (
            self.mean(|r| split(r, r.stats.queue_net)),
            self.mean(|r| split(r, r.stats.queue_mem)),
        )
    }

    /// Mean CoV of per-vault served demand — Figs 3/4/12/13.
    pub fn cov(&self) -> f64 {
        self.mean(|r| r.stats.demand.cov())
    }

    /// Mean network traffic in bytes/cycle — Fig 14.
    pub fn bytes_per_cycle(&self) -> f64 {
        self.mean(|r| r.bytes_per_cycle())
    }

    /// Mean local & remote reuse per subscription — Fig 10.
    pub fn reuse(&self) -> (f64, f64) {
        (
            self.mean(|r| r.stats.reuse.avg_local()),
            self.mean(|r| r.stats.reuse.avg_remote()),
        )
    }

    /// Speedup of this report relative to a baseline run of the same
    /// workload: `baseline.cycles / self.cycles` (Figs 9/11/15/16).
    pub fn speedup_vs(&self, baseline: &SimReport) -> f64 {
        let own = self.cycles();
        if own == 0.0 {
            return 1.0;
        }
        baseline.cycles() / own
    }

    /// Memory-latency improvement vs baseline: `1 - lat/lat_base`
    /// (54% HMC / 50% HBM headline numbers).
    pub fn latency_improvement_vs(&self, baseline: &SimReport) -> f64 {
        let b = baseline.avg_latency();
        if b == 0.0 {
            return 0.0;
        }
        1.0 - self.avg_latency() / b
    }

    /// Fraction of demand served without leaving the requester vault.
    pub fn local_fraction(&self) -> f64 {
        self.mean(|r| {
            if r.stats.requests == 0 {
                0.0
            } else {
                r.stats.local_requests as f64 / r.stats.requests as f64
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::SimStats;

    fn run(cycles: u64, lat_total: u64, reqs: u64) -> RunReport {
        let mut stats = SimStats::new(4);
        for _ in 0..reqs {
            stats.latency.record(0, 0, lat_total / reqs);
        }
        stats.requests = reqs;
        RunReport { cycles, stats, decisions: vec![], exhausted: false }
    }

    fn report(cycles: u64) -> SimReport {
        SimReport {
            workload: "test".into(),
            policy: "never",
            runs: vec![run(cycles, 1000, 10)],
        }
    }

    #[test]
    fn speedup_is_cycle_ratio() {
        let base = report(2000);
        let fast = report(1000);
        assert!((fast.speedup_vs(&base) - 2.0).abs() < 1e-12);
        assert!((base.speedup_vs(&base) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn latency_improvement_halved_is_50pct() {
        let mut base = report(1000);
        base.runs[0].stats.latency = Default::default();
        for _ in 0..10 {
            base.runs[0].stats.latency.record(0, 0, 100);
        }
        let mut dl = report(1000);
        dl.runs[0].stats.latency = Default::default();
        for _ in 0..10 {
            dl.runs[0].stats.latency.record(0, 0, 50);
        }
        assert!((dl.latency_improvement_vs(&base) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn queue_fractions_partition_the_queue_share() {
        let mut r = report(1000);
        r.runs[0].stats.latency = Default::default();
        // 10 requests, each 20 network + 50 queue + 30 array cycles.
        for _ in 0..10 {
            r.runs[0].stats.latency.record(20, 50, 30);
        }
        // The queue cycles split 3:2 between links and controllers.
        r.runs[0].stats.queue_net = 300;
        r.runs[0].stats.queue_mem = 200;
        let (net, mem) = r.queue_fractions();
        let queue_frac = r.latency_fractions().1;
        assert!((net + mem - queue_frac).abs() < 1e-12);
        assert!((net - 0.5 * 0.6).abs() < 1e-12);
        assert!((mem - 0.5 * 0.4).abs() < 1e-12);

        // No recorded queueing: both shares are 0, not NaN.
        let empty = report(1000);
        assert_eq!(empty.queue_fractions(), (0.0, 0.0));
    }

    #[test]
    fn means_average_across_runs() {
        let r = SimReport {
            workload: "t".into(),
            policy: "never",
            runs: vec![run(100, 100, 10), run(300, 100, 10)],
        };
        assert!((r.cycles() - 200.0).abs() < 1e-12);
    }
}

//! Layer-3 coordination: the PIM cores (one per vault logic die), their
//! L1 caches, and the discrete-event driver that runs a workload over the
//! memory system and produces a [`report::SimReport`].

pub mod batch;
pub mod core;
pub mod driver;
pub mod kernel;
pub mod l1;
pub mod report;

pub use core::PimCore;
pub use driver::{
    simulate, simulate_once, simulate_once_observed, simulate_once_scalar,
    simulate_once_scalar_observed,
};
pub use kernel::Kernel;
pub use l1::{L1Cache, L1Result};
pub use report::{RunReport, SimReport};

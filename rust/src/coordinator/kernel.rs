//! The unified event-driven simulation kernel.
//!
//! One time-ordered event loop replaces `simulate_once`'s per-core issue
//! loop over globally shared calendars: every step of a run is a typed
//! [`Event`] dispatched through a single `match`, with the happens-before
//! edges between event kinds stated explicitly (below) instead of being
//! implicit in loop structure. The kernel is the seam the parallel
//! execution hangs off: a [`Kernel`] carries a thread count (`--threads
//! N` / `REPRO_THREADS`, default 1) and uses it for the three fan-outs
//! that are **exact by construction** — run-level parallelism across
//! `cfg.runs`, partitioned epoch-barrier table decay, and partitioned
//! `hop_lut` construction — so reports are bit-identical at any thread
//! count. `tests/kernel_equivalence.rs` pins that claim against
//! [`simulate_once_scalar`](crate::coordinator::driver::simulate_once_scalar)
//! request by request and across a 1/2/4/8-thread determinism matrix.
//!
//! ## Event vocabulary and happens-before edges
//!
//! * `EpochBarrier { at } ≺ Issue { at, core }` — every epoch decision
//!   whose boundary is `<= at` broadcasts (and ages the directory's LFU
//!   counters) *before* the issue event that first observes time `at`.
//!   Barriers are **lazily gated** behind the next issue event: a
//!   boundary with no later issue event never fires, exactly as the
//!   scalar driver's `policy.tick(t)` call — firing it eagerly would
//!   diverge from the reference bit-for-bit.
//! * `Issue ≺ Serve ≺ Complete` — an issue event runs its op's L1 access
//!   and emits zero, one (write miss / clean read miss) or two (dirty
//!   eviction writeback + read fill) `Serve` events in program order;
//!   each `Serve` synchronously yields the `Complete` that stalls the
//!   issuing core's MLP window. Serve latency is computed analytically
//!   (the memory system returns the completion cycle), so `Serve` and
//!   `Complete` collapse into one dispatch chain rather than re-entering
//!   the calendar — the edge is program order, and it is explicit in the
//!   dispatcher instead of being spread over four duplicated arms.
//! * `Serve* ≺ WindowBreak` — the measured window closes only after the
//!   breaking issue's final serve completes; the break drains the
//!   breaking core's outstanding misses and clamps the run's cycle count
//!   to that core's clock (the PR 5 accounting semantics, now
//!   structural).
//! * `StreamEnd { core }` removes a core from the calendar; the run ends
//!   when the last live core ends (exhaustion) or the window breaks.
//!
//! ## Deterministic parallelism
//!
//! Request-level fan-out cannot preserve bit-identity at sane cost: the
//! mesh links, the home-interleaved directory and the global policy
//! registers make almost every request's footprint overlap its
//! neighbours' (see `docs/ARCHITECTURE.md` for the full argument). The
//! kernel therefore parallelizes only what commutes or is disjoint:
//!
//! * **Runs** — `cfg.runs` independent simulations, each worker building
//!   its own workload from a factory and seeding `seed + r`; results land
//!   in per-run slots merged in run order. Exact because the
//!   `reset(seed)` replay contract (pinned by
//!   `tests/workload_determinism.rs`) makes each run a pure function of
//!   its seed.
//! * **Epoch-barrier decay** — the per-vault `SubTable` LFU aging at a
//!   broadcast touches disjoint vault partitions; the kernel fans the
//!   tables out over a scoped pool in home-vault chunks
//!   ([`crate::subscription::protocol::SubSystem::decay_partitioned`]).
//! * **`hop_lut` rows** — each source vault's row of the n×n hop matrix
//!   is an independent pure computation
//!   ([`crate::memsys::MemorySystem::new_with_threads`]).
//!
//! Per-partition [`Frame`] stat batches stay thread-local and are folded
//! into each run's `SimStats` exactly as in the serial path; run reports
//! merge in fixed run order, so the aggregate is independent of which
//! worker finished first.

use crate::config::SimConfig;
use crate::coordinator::batch::{Frame, WindowQueue, FRAME_CAPACITY};
use crate::coordinator::core::PimCore;
use crate::coordinator::driver::{debug_check_directory, MeasureWindow, MAX_OPS_PER_RUN};
use crate::coordinator::l1::L1Result;
use crate::coordinator::report::{RunReport, SimReport};
use crate::memsys::{Access, MemorySystem, ServedRequest};
use crate::policy::PolicyRuntime;
use crate::workloads::Workload;
use crate::{CoreId, Cycle};

/// One kernel event (see the module docs for the happens-before edges).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Event {
    /// Broadcast every epoch decision with boundary `<= at` (lazily
    /// gated behind the issue event that observes `at`).
    EpochBarrier { at: Cycle },
    /// Core `core` issues its next op at cycle `at`.
    Issue { at: Cycle, core: CoreId },
    /// A memory request dispatched by an issue (post-L1).
    Serve { core: CoreId, block: u64, write: bool },
    /// The issuing core observes a request's completion (MLP window).
    Complete { core: CoreId, done: Cycle },
    /// Core `core`'s op stream ran dry.
    StreamEnd { core: CoreId },
    /// The request that filled the measured window completed.
    WindowBreak { at: Cycle, core: CoreId },
}

/// Dispatch outcome: whether the run's main loop keeps consuming events.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Flow {
    Continue,
    Stop,
}

/// Execution parameters of the kernel: how many OS threads the exact
/// fan-outs may use. `Kernel::single()` (threads = 1) is the plain
/// sequential kernel `simulate_once` delegates to.
#[derive(Clone, Copy, Debug)]
pub struct Kernel {
    threads: usize,
}

impl Default for Kernel {
    fn default() -> Self {
        Kernel::single()
    }
}

impl Kernel {
    /// A kernel using up to `threads` OS threads (clamped to >= 1).
    pub fn new(threads: usize) -> Self {
        Kernel { threads: threads.max(1) }
    }

    /// The sequential kernel (thread count 1).
    pub fn single() -> Self {
        Kernel::new(1)
    }

    /// Thread count from `REPRO_THREADS`, default 1. The default is
    /// deliberately *not* the core count: sweeps already parallelize
    /// across points, and nesting a per-run fan-out under a point
    /// fan-out would oversubscribe the machine.
    pub fn from_env() -> Self {
        Kernel::new(crate::config::env::threads().unwrap_or(1))
    }

    /// The configured thread count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// One simulation run over an already-seeded workload (the event-loop
    /// core of [`simulate_once`](crate::coordinator::driver::simulate_once)).
    pub fn run_once(&self, cfg: &SimConfig, workload: &mut dyn Workload) -> RunReport {
        self.run_once_observed(cfg, workload, |_, _| {})
    }

    /// [`Kernel::run_once`] with a per-request observer in issue order
    /// (the hook the differential tests use to diff full request
    /// streams).
    pub fn run_once_observed<F: FnMut(Access, &ServedRequest)>(
        &self,
        cfg: &SimConfig,
        workload: &mut dyn Workload,
        obs: F,
    ) -> RunReport {
        debug_assert!(cfg.validate().is_ok());
        let n = cfg.n_vaults;
        let mut run = KernelRun {
            cfg,
            threads: self.threads,
            mem: MemorySystem::new_with_threads(cfg, self.threads),
            policy: PolicyRuntime::new(cfg),
            cores: (0..n).map(|i| PimCore::new(i, cfg)).collect(),
            queue: WindowQueue::new(n as usize),
            frame: Frame::with_capacity(FRAME_CAPACITY),
            win: MeasureWindow::new(cfg),
            obs,
            block_shift: cfg.block_bytes.trailing_zeros(),
            ops: 0,
            last_t: 0,
            window_end: None,
        };
        run.event_loop(workload);
        run.finish()
    }

    /// Run `cfg.runs` independent simulations of the workload `build`
    /// constructs, in parallel across this kernel's threads, and
    /// aggregate — bit-identical to the sequential
    /// [`simulate`](crate::coordinator::driver::simulate) loop at any
    /// thread count (run `r` always seeds `cfg.seed + r`, and reports
    /// merge in run order).
    ///
    /// When the run fan-out uses fewer workers than `threads`, the
    /// remainder widens each run's partition fan-outs instead of idling.
    /// `build` runs on worker threads; a build failure (e.g. a trace
    /// file deleted mid-run) panics with its message, matching the sweep
    /// engine's poisoned-job semantics.
    pub fn simulate_runs<B>(&self, cfg: &SimConfig, name: &str, build: B) -> SimReport
    where
        B: Fn() -> Box<dyn Workload> + Sync,
    {
        self.simulate_runs_observed(cfg, name, build, |_, _| {})
    }

    /// [`Kernel::simulate_runs`] with a per-request observer shared by
    /// every run worker (`Fn + Sync`: the metrics hooks are global
    /// atomics, so one stateless closure serves all threads). The
    /// observer only reads each request, so reports stay bit-identical
    /// to the unobserved path at any thread count.
    pub fn simulate_runs_observed<B, F>(
        &self,
        cfg: &SimConfig,
        name: &str,
        build: B,
        obs: F,
    ) -> SimReport
    where
        B: Fn() -> Box<dyn Workload> + Sync,
        F: Fn(Access, &ServedRequest) + Sync,
    {
        let runs_n = cfg.runs.max(1) as usize;
        let run_workers = self.threads.min(runs_n);
        let per_run = Kernel::new(self.threads / run_workers);

        let runs: Vec<RunReport> = if run_workers <= 1 {
            let mut w = build();
            (0..runs_n)
                .map(|r| {
                    w.reset(cfg.seed.wrapping_add(r as u64));
                    per_run.run_once_observed(cfg, w.as_mut(), |a, r| obs(a, r))
                })
                .collect()
        } else {
            use std::sync::atomic::{AtomicUsize, Ordering};
            use std::sync::Mutex;
            let next = AtomicUsize::new(0);
            let slots: Vec<Mutex<Option<RunReport>>> =
                (0..runs_n).map(|_| Mutex::new(None)).collect();
            std::thread::scope(|scope| {
                for _ in 0..run_workers {
                    scope.spawn(|| loop {
                        // lint:allow(D3) -- run-claim ticket: which thread
                        // claims which run is irrelevant, because run `r` is
                        // seeded from `r` alone and lands in `slots[r]` —
                        // results are merged in run order regardless.
                        let r = next.fetch_add(1, Ordering::Relaxed);
                        if r >= runs_n {
                            break;
                        }
                        let mut w = build();
                        w.reset(cfg.seed.wrapping_add(r as u64));
                        let rep = per_run.run_once_observed(cfg, w.as_mut(), |a, q| obs(a, q));
                        *slots[r].lock().expect("run slot mutex poisoned") = Some(rep);
                    });
                }
            });
            slots
                .into_iter()
                .map(|m| {
                    m.into_inner()
                        .expect("run slot mutex poisoned")
                        .expect("every run produced a report")
                })
                .collect()
        };

        SimReport { workload: name.to_string(), policy: cfg.policy.as_str(), runs }
    }
}

/// All mutable state of one kernel run; the event dispatcher lives here.
struct KernelRun<'a, F: FnMut(Access, &ServedRequest)> {
    cfg: &'a SimConfig,
    threads: usize,
    mem: MemorySystem,
    policy: PolicyRuntime,
    cores: Vec<PimCore>,
    queue: WindowQueue,
    frame: Frame,
    win: MeasureWindow,
    obs: F,
    block_shift: u32,
    ops: u64,
    last_t: Cycle,
    /// Completion time of the request that filled the measure window;
    /// `None` when the run ended some other way (stream exhausted, op
    /// safety valve).
    window_end: Option<Cycle>,
}

impl<F: FnMut(Access, &ServedRequest)> KernelRun<'_, F> {
    /// Consume calendar events in global `(time, core)` order. Each pop
    /// fires the epoch barriers it gates, then its issue event; the loop
    /// ends on a `WindowBreak`, the op-valve, or the last `StreamEnd`.
    fn event_loop(&mut self, workload: &mut dyn Workload) {
        while let Some((at, core)) = self.queue.pop() {
            self.last_t = self.last_t.max(at);
            self.step(Event::EpochBarrier { at }, workload);
            if self.step(Event::Issue { at, core }, workload) == Flow::Stop {
                break;
            }
        }
    }

    /// The single dispatch point: every state transition of a run is one
    /// arm of this `match` (the happens-before edges are in the module
    /// docs). The recursion (`Issue` → `Serve` → `Complete`,
    /// `Issue` → `WindowBreak`) is depth-bounded and inlines away.
    fn step(&mut self, ev: Event, workload: &mut dyn Workload) -> Flow {
        match ev {
            Event::EpochBarrier { at } => {
                // Decisions broadcast from the central vault; the
                // per-vault stats reports and policy packets contend like
                // any other traffic (§III-D4). Directory aging fans out
                // over disjoint vault partitions.
                for d in self.policy.tick(at) {
                    self.mem.broadcast_decision_partitioned(&d, self.threads);
                }
                Flow::Continue
            }

            Event::Issue { at, core } => {
                let Some(op) = workload.next_op(core) else {
                    return self.step(Event::StreamEnd { core }, workload);
                };
                self.ops += 1;
                if self.ops > MAX_OPS_PER_RUN {
                    return Flow::Stop;
                }

                let c = &mut self.cores[core as usize];
                c.time = at + op.gap as Cycle;
                c.ops += 1;
                let block = op.addr >> self.block_shift;

                match c.l1.access(block, op.write) {
                    L1Result::Hit => {
                        c.time += 1; // L1 hit latency
                        self.frame.record_l1_hit();
                    }
                    L1Result::WriteMiss => {
                        // Streaming store: write-no-allocate, straight to
                        // memory.
                        self.step(Event::Serve { core, block, write: true }, workload);
                        let core_time = self.cores[core as usize].time;
                        self.win.end_of_op_batched(&mut self.mem, &mut self.frame, core_time);
                    }
                    L1Result::Miss { writeback } => {
                        // Dirty eviction: a posted write to the victim's
                        // home.
                        if let Some(wb) = writeback {
                            self.step(Event::Serve { core, block: wb, write: true }, workload);
                        }
                        // Read miss: fill the line (stores to resident
                        // lines merge in L1 and reach memory later as
                        // full-block writebacks).
                        self.step(Event::Serve { core, block, write: false }, workload);
                        let core_time = self.cores[core as usize].time;
                        self.win.end_of_op_batched(&mut self.mem, &mut self.frame, core_time);
                    }
                }
                if self.frame.is_full() {
                    self.frame.fold_into(self.mem.stats_mut());
                }

                if self.win.warmed && self.win.measured >= self.cfg.measure_requests {
                    return self.step(Event::WindowBreak { at, core }, workload);
                }
                self.queue.reissue(core, self.cores[core as usize].time);
                Flow::Continue
            }

            Event::Serve { core, block, write } => {
                let c = &mut self.cores[core as usize];
                let requester = c.vault;
                let now = c.time;
                let req = Access { requester, block, write };
                let prep = self.mem.prepare(requester, block);
                let res = self.mem.serve_prepared(req, now, &self.policy, prep);
                (self.obs)(req, &res);
                self.step(Event::Complete { core, done: res.done }, workload);
                self.frame.record(&res);
                if self.win.warmed {
                    self.win.measured += 1;
                }
                self.win.total_requests += 1;
                self.policy.on_request(
                    requester,
                    res.served_by,
                    res.subscribed_path,
                    res.actual_hops,
                    res.baseline_hops,
                    res.network + res.queued + res.array,
                    res.set,
                    now,
                );
                Flow::Continue
            }

            Event::Complete { core, done } => {
                self.cores[core as usize].note_miss(done);
                Flow::Continue
            }

            Event::StreamEnd { core } => {
                self.cores[core as usize].finished = true;
                self.queue.finish(core);
                if self.queue.live() == 0 {
                    Flow::Stop
                } else {
                    Flow::Continue
                }
            }

            Event::WindowBreak { at, core } => {
                debug_check_directory(&self.mem, self.cores[core as usize].time);
                // The measured window ends when the *breaking core*
                // finishes its last measured request (including its
                // outstanding MLP misses); see `simulate_once_scalar` for
                // the cross-core drift rationale.
                let breaking = &mut self.cores[core as usize];
                breaking.drain();
                self.window_end = Some(breaking.time.max(at));
                Flow::Stop
            }
        }
    }

    /// Fold the trailing frame, reconcile pre-warm exhaustion, drain the
    /// cores and assemble the report (identical tail to both drivers).
    fn finish(mut self) -> RunReport {
        self.frame.fold_into(self.mem.stats_mut());
        if !self.win.warmed {
            // The run ended (stream exhausted / op valve) before the
            // warmup boundary: the scalar driver's warmed gate recorded
            // none of these requests, but the frame folds did. The folded
            // fields are driver-exclusive — `serve` never touches them —
            // so zeroing them reproduces the scalar report exactly.
            let stats = self.mem.stats_mut();
            stats.latency = Default::default();
            stats.queue_net = 0;
            stats.queue_mem = 0;
            stats.requests = 0;
            stats.l1_hits = 0;
        }
        for core in &mut self.cores {
            core.drain();
            self.last_t = self.last_t.max(core.time);
        }
        let end = self.window_end.unwrap_or(self.last_t);

        // End-of-run subscription-table occupancy sample: a pure read,
        // once per run, only when telemetry is opted in. Deterministic
        // (simulated state), so it folds into the metrics determinism
        // pins; it cannot feed back into the report.
        if crate::obs::enabled() {
            crate::obs::SUBSCRIPTION_OCCUPANCY.observe(self.mem.total_parked());
        }

        RunReport {
            cycles: end.saturating_sub(self.win.measure_start),
            stats: self.mem.into_stats(),
            decisions: self.policy.decisions.clone(),
            // Only a stream that ran dry *before* the window filled is an
            // exhausted run: if the window closed normally, a core that
            // happened to finish (one tenant of a `--no-loop` replay
            // ending early) does not invalidate the measurement.
            exhausted: self.window_end.is_none() && self.cores.iter().any(|c| c.finished),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::driver::{simulate, simulate_once_scalar};
    use crate::policy::PolicyKind;
    use crate::workloads::{build_source, catalog};

    fn quick_cfg() -> SimConfig {
        let mut cfg = SimConfig::hmc().quick();
        cfg.policy = PolicyKind::Adaptive;
        cfg.warmup_requests = 500;
        cfg.measure_requests = 3_000;
        cfg
    }

    #[test]
    fn kernel_matches_scalar_on_a_quick_run() {
        // Cheap in-module insurance; the full matrix + randomized storm
        // live in tests/kernel_equivalence.rs.
        let cfg = quick_cfg();
        let mut wa = catalog::build("SPLRad", &cfg).unwrap();
        wa.reset(cfg.seed);
        let a = Kernel::new(4).run_once(&cfg, wa.as_mut());
        let mut wb = catalog::build("SPLRad", &cfg).unwrap();
        wb.reset(cfg.seed);
        let b = simulate_once_scalar(&cfg, wb.as_mut());
        assert_eq!(a, b);
    }

    #[test]
    fn parallel_runs_match_the_sequential_simulate_loop() {
        let mut cfg = quick_cfg();
        cfg.runs = 3;
        let seq = simulate(&cfg, build_source(Some("STRTriad"), &cfg).unwrap());
        for threads in [1, 2, 8] {
            let par = Kernel::new(threads).simulate_runs(&cfg, "STRTriad", || {
                build_source(Some("STRTriad"), &cfg).unwrap()
            });
            assert_eq!(par.workload, seq.workload, "threads={threads}");
            assert_eq!(par.policy, seq.policy, "threads={threads}");
            assert_eq!(par.runs, seq.runs, "threads={threads}");
        }
    }

    #[test]
    fn from_env_defaults_to_single_thread() {
        // REPRO_THREADS is unset in test runs unless a harness sets it;
        // either way the kernel is well-formed and >= 1.
        assert!(Kernel::from_env().threads() >= 1);
        assert_eq!(Kernel::new(0).threads(), 1, "clamped");
    }
}

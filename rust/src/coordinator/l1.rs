//! Per-PIM-core L1 cache (32 KB in the baseline, Table I/II).
//!
//! Write-back, write-allocate, set-associative with true-LRU. The L1
//! filters the workload's raw access stream: only misses (and dirty
//! evictions) reach the vault network, so the *post-L1* reuse of a block is
//! what the subscription machinery can exploit — exactly the quantity
//! Fig 10 plots.

/// Outcome of one L1 access.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum L1Result {
    Hit,
    /// Read miss; the line is filled. If the victim was dirty, its block
    /// must be written back.
    Miss { writeback: Option<u64> },
    /// Write miss: the store bypasses the cache (write-no-allocate, the
    /// streaming-store behaviour of simple PIM cores) and goes straight to
    /// the memory system as a full-block write.
    WriteMiss,
}

/// One core's L1 tag store.
pub struct L1Cache {
    sets: usize,
    ways: usize,
    /// tag per line; u64::MAX = invalid. Indexed set * ways + way.
    tags: Vec<u64>,
    dirty: Vec<bool>,
    lru: Vec<u64>,
    tick: u64,
}

impl L1Cache {
    /// `bytes` capacity, `ways` associativity, `line` bytes per line.
    pub fn new(bytes: u32, ways: u16, line: u32) -> Self {
        let lines = (bytes / line) as usize;
        let ways = ways as usize;
        assert!(lines % ways == 0, "capacity must divide into ways");
        let sets = lines / ways;
        assert!(sets.is_power_of_two(), "L1 sets must be a power of two");
        L1Cache {
            sets,
            ways,
            tags: vec![u64::MAX; lines],
            dirty: vec![false; lines],
            lru: vec![0; lines],
            tick: 0,
        }
    }

    pub fn reset(&mut self) {
        self.tags.fill(u64::MAX);
        self.dirty.fill(false);
        self.lru.fill(0);
        self.tick = 0;
    }

    /// Access `block` (a global block index). Returns hit/miss and fills
    /// the line on miss.
    pub fn access(&mut self, block: u64, write: bool) -> L1Result {
        self.tick += 1;
        let set = (block as usize) & (self.sets - 1);
        let base = set * self.ways;
        // Hit?
        for w in 0..self.ways {
            if self.tags[base + w] == block {
                self.lru[base + w] = self.tick;
                if write {
                    self.dirty[base + w] = true;
                }
                return L1Result::Hit;
            }
        }
        if write {
            // Write-no-allocate: the store goes straight to memory.
            return L1Result::WriteMiss;
        }
        // Read miss: pick invalid way or LRU victim and fill.
        let mut victim = base;
        let mut oldest = u64::MAX;
        for w in 0..self.ways {
            let i = base + w;
            if self.tags[i] == u64::MAX {
                victim = i;
                break;
            }
            if self.lru[i] < oldest {
                oldest = self.lru[i];
                victim = i;
            }
        }
        let writeback = if self.tags[victim] != u64::MAX && self.dirty[victim] {
            Some(self.tags[victim])
        } else {
            None
        };
        self.tags[victim] = block;
        self.dirty[victim] = false;
        self.lru[victim] = self.tick;
        L1Result::Miss { writeback }
    }

    pub fn sets(&self) -> usize {
        self.sets
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn l1() -> L1Cache {
        L1Cache::new(32 * 1024, 4, 64) // 128 sets x 4 ways
    }

    #[test]
    fn geometry() {
        assert_eq!(l1().sets(), 128);
    }

    #[test]
    fn second_access_hits() {
        let mut c = l1();
        assert!(matches!(c.access(5, false), L1Result::Miss { .. }));
        assert_eq!(c.access(5, false), L1Result::Hit);
    }

    #[test]
    fn conflict_evicts_lru() {
        let mut c = l1();
        // Five blocks in the same set (stride = sets).
        for i in 0..5u64 {
            c.access(i * 128, false);
        }
        // Block 0 (oldest) must have been evicted.
        assert!(matches!(c.access(0, false), L1Result::Miss { .. }));
        // Block 4*128 must still be resident... but the re-fill of block 0
        // evicted the next-oldest (1*128), so 4*128 hits:
        assert_eq!(c.access(4 * 128, false), L1Result::Hit);
    }

    #[test]
    fn dirty_eviction_reports_writeback() {
        let mut c = l1();
        c.access(0, false); // fill...
        c.access(0, true); // ...then dirty via a write hit
        for i in 1..=4u64 {
            let r = c.access(i * 128, false);
            if i == 4 {
                assert_eq!(r, L1Result::Miss { writeback: Some(0) });
            } else {
                assert_eq!(r, L1Result::Miss { writeback: None });
            }
        }
    }

    #[test]
    fn write_miss_bypasses_cache() {
        let mut c = l1();
        assert_eq!(c.access(0, true), L1Result::WriteMiss);
        // Not installed: the next read still misses.
        assert!(matches!(c.access(0, false), L1Result::Miss { .. }));
    }

    #[test]
    fn clean_eviction_has_no_writeback() {
        let mut c = l1();
        for i in 0..=4u64 {
            let r = c.access(i * 128, false);
            assert!(matches!(r, L1Result::Miss { writeback: None }), "i={i}");
        }
    }

    #[test]
    fn write_hit_sets_dirty() {
        let mut c = l1();
        c.access(0, false);
        assert_eq!(c.access(0, true), L1Result::Hit); // dirty via hit
        for i in 1..=4u64 {
            if let L1Result::Miss { writeback: Some(b) } = c.access(i * 128, false) {
                assert_eq!(b, 0);
                return;
            }
        }
        panic!("dirty block never written back");
    }

    #[test]
    fn streaming_never_hits() {
        let mut c = l1();
        let mut misses = 0;
        for i in 0..10_000u64 {
            if matches!(c.access(i, false), L1Result::Miss { .. }) {
                misses += 1;
            }
        }
        assert_eq!(misses, 10_000);
    }

    #[test]
    fn working_set_within_capacity_hits_forever() {
        let mut c = l1();
        let blocks: Vec<u64> = (0..512).collect(); // 32 KB exactly
        for &b in &blocks {
            c.access(b, false);
        }
        for &b in &blocks {
            assert_eq!(c.access(b, false), L1Result::Hit, "block {b}");
        }
    }
}

//! The PIM core model: a simple in-order core on each vault's logic die
//! (2.4 GHz, 32 KB L1, Table I) with a bounded miss-level-parallelism
//! window.
//!
//! DAMOV's PIM cores are single-issue in-order with a small non-blocking
//! L1: a handful of outstanding misses overlap, then the core stalls on the
//! oldest. We model that with a FIFO window of `mlp` outstanding miss
//! completion times — issuing into a full window blocks the core until the
//! oldest miss returns.

use std::collections::VecDeque;

use crate::config::SimConfig;
use crate::coordinator::l1::L1Cache;
use crate::{CoreId, Cycle, VaultId};

/// One PIM core and its private state.
pub struct PimCore {
    pub id: CoreId,
    /// The vault this core is attached to (same index in our model).
    pub vault: VaultId,
    /// Core-local clock: when the core can issue its next operation.
    pub time: Cycle,
    pub l1: L1Cache,
    window: VecDeque<Cycle>,
    mlp: usize,
    /// Memory requests this core has issued past its L1.
    pub misses: u64,
    /// Total ops (including L1 hits) executed.
    pub ops: u64,
    /// True once the workload stream for this core is exhausted.
    pub finished: bool,
}

impl PimCore {
    pub fn new(id: CoreId, cfg: &SimConfig) -> Self {
        PimCore {
            id,
            vault: id,
            time: 0,
            l1: L1Cache::new(cfg.l1_bytes, cfg.l1_ways, cfg.l1_line),
            window: VecDeque::with_capacity(cfg.mlp as usize),
            mlp: cfg.mlp as usize,
            misses: 0,
            ops: 0,
            finished: false,
        }
    }

    /// Register an issued miss completing at `done`; if the MLP window is
    /// full the core stalls until the oldest outstanding miss retires.
    pub fn note_miss(&mut self, done: Cycle) {
        self.misses += 1;
        self.window.push_back(done);
        if self.window.len() > self.mlp {
            let oldest = self.window.pop_front().expect("window non-empty: len > mlp >= 0");
            self.time = self.time.max(oldest);
        }
    }

    /// Drain the window (end of simulation): core finishes when its last
    /// miss returns.
    pub fn drain(&mut self) {
        while let Some(t) = self.window.pop_front() {
            self.time = self.time.max(t);
        }
    }

    pub fn outstanding(&self) -> usize {
        self.window.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn core() -> PimCore {
        let mut cfg = SimConfig::hmc();
        cfg.mlp = 2;
        PimCore::new(3, &cfg)
    }

    #[test]
    fn vault_matches_id() {
        assert_eq!(core().vault, 3);
    }

    #[test]
    fn window_overlaps_up_to_mlp() {
        let mut c = core();
        c.note_miss(100);
        c.note_miss(200);
        assert_eq!(c.time, 0, "two misses in flight, no stall");
        c.note_miss(300);
        assert_eq!(c.time, 100, "third miss stalls on the oldest");
        assert_eq!(c.outstanding(), 2);
    }

    #[test]
    fn stall_never_rewinds_clock() {
        let mut c = core();
        c.time = 500;
        c.note_miss(100);
        c.note_miss(200);
        c.note_miss(300);
        assert_eq!(c.time, 500, "completed misses don't move time backwards");
    }

    #[test]
    fn drain_waits_for_last_miss() {
        let mut c = core();
        c.note_miss(100);
        c.note_miss(900);
        c.drain();
        assert_eq!(c.time, 900);
        assert_eq!(c.outstanding(), 0);
    }
}

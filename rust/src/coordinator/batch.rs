//! Data-oriented batching machinery for the simulation driver.
//!
//! Two structures, both struct-of-arrays, both bit-identity-preserving by
//! construction (the proofs are sketched inline and exercised by
//! `tests/batched_equivalence.rs`):
//!
//! * [`WindowQueue`] — replaces the driver's per-event `BinaryHeap` with
//!   cycle-window admission: per-core next-issue times live in flat
//!   arrays, and events inside the current admission window are drained
//!   from a small sorted batch. The pop sequence is *exactly* the
//!   `BinaryHeap<Reverse<(Cycle, CoreId)>>` order, so the simulation is
//!   unchanged observation-for-observation.
//! * [`Frame`] — replaces the per-request warmup-gated stats branches
//!   with unconditional pushes into flat component arrays, folded into
//!   [`SimStats`] by tight sum loops at window boundaries. Folding before
//!   the warmup `stats.reset()` makes the end state identical to the
//!   scalar gated accumulation (pre-warm contributions are wiped by the
//!   reset either way).
//!
//! ## Why window admission preserves event order
//!
//! The scalar driver pops the lexicographic minimum `(time, core)` event.
//! [`WindowQueue::admit`] moves every pending core whose next-issue time
//! is `< window_end = t_min + ADMIT_WINDOW` into a batch sorted
//! descending, popped from the tail — i.e. in `(time, core)` order. Any
//! core left outside has `next >= window_end`, strictly later than every
//! batched event, so the batch's minimum *is* the global minimum. When a
//! served core re-arms inside the window it is binary-inserted back into
//! the batch (keeping order); re-arms at or past `window_end` return to
//! the flat pending arrays and are reconsidered at the next admission.
//! Each core has at most one queued event, so `(time, core)` keys are
//! unique and the order is total.

use crate::memsys::ServedRequest;
use crate::stats::SimStats;
use crate::{CoreId, Cycle};

/// Admission-window width in cycles. Any positive value is
/// order-preserving (see the module docs); this one keeps the batch a few
/// hundred events at figure scale — large enough to amortize the
/// per-window scans, small enough that binary re-insertion stays cheap.
pub const ADMIT_WINDOW: Cycle = 4096;

/// Frame capacity: component arrays are folded into [`SimStats`] when
/// this many requests have accumulated (and at every window boundary).
pub const FRAME_CAPACITY: usize = 4096;

#[derive(Clone, Copy, PartialEq, Eq)]
enum CoreState {
    /// Next-issue time in the flat `next` array awaits admission.
    Pending,
    /// Event sits in the sorted admission batch (or was just popped and
    /// awaits `reissue`/`finish`).
    InWindow,
    /// Stream ended; the core schedules no further events.
    Done,
}

/// SoA event queue with cycle-window admission (see the module docs).
pub struct WindowQueue {
    /// Per-core next issue time; meaningful while `state` is `Pending`.
    next: Vec<Cycle>,
    state: Vec<CoreState>,
    /// Current admission batch, sorted descending by `(time, core)`;
    /// `pop` takes from the tail (the minimum).
    window: Vec<(Cycle, CoreId)>,
    /// Exclusive upper bound of the current admission window.
    window_end: Cycle,
    /// Cores not yet `Done`.
    live: usize,
}

impl WindowQueue {
    /// All `n` cores start pending at cycle 0 (the heap's initial state).
    pub fn new(n: usize) -> Self {
        WindowQueue {
            next: vec![0; n],
            state: vec![CoreState::Pending; n],
            window: Vec::with_capacity(n),
            window_end: 0,
            live: n,
        }
    }

    /// Pop the globally-earliest `(time, core)` event, refilling the
    /// admission window from the pending arrays when it runs dry.
    /// Returns `None` when every core is done.
    pub fn pop(&mut self) -> Option<(Cycle, CoreId)> {
        if self.window.is_empty() {
            self.admit()?;
        }
        self.window.pop()
    }

    /// Gather every pending event within `ADMIT_WINDOW` of the earliest
    /// one into the sorted batch.
    fn admit(&mut self) -> Option<()> {
        let t_min = self
            .state
            .iter()
            .zip(&self.next)
            .filter(|(s, _)| **s == CoreState::Pending)
            .map(|(_, t)| *t)
            .min()?;
        self.window_end = t_min.saturating_add(ADMIT_WINDOW);
        for c in 0..self.next.len() {
            if self.state[c] == CoreState::Pending && self.next[c] < self.window_end {
                self.state[c] = CoreState::InWindow;
                self.window.push((self.next[c], c as CoreId));
            }
        }
        // Descending sort; `pop` then yields ascending `(time, core)`.
        self.window.sort_unstable_by(|a, b| b.cmp(a));
        Some(())
    }

    /// Re-arm core `c` at time `t` after it executed an op. Events inside
    /// the live window are binary-inserted back into the batch; later
    /// ones return to the pending arrays for the next admission.
    pub fn reissue(&mut self, c: CoreId, t: Cycle) {
        debug_assert_eq!(self.state[c as usize], CoreState::InWindow);
        if !self.window.is_empty() && t < self.window_end {
            let key = (t, c);
            let pos = self.window.partition_point(|&e| e > key);
            self.window.insert(pos, key);
        } else {
            self.state[c as usize] = CoreState::Pending;
            self.next[c as usize] = t;
        }
    }

    /// Mark core `c`'s stream as ended.
    pub fn finish(&mut self, c: CoreId) {
        debug_assert_ne!(self.state[c as usize], CoreState::Done);
        self.state[c as usize] = CoreState::Done;
        self.live -= 1;
    }

    /// Cores that can still schedule events.
    pub fn live(&self) -> usize {
        self.live
    }
}

/// Flat per-request stat components, folded into [`SimStats`] in bulk.
///
/// The scalar driver's `issue_request` gates six stat accumulations on
/// `win.warmed` per request. The frame records every request
/// unconditionally (branch-free on the hot path) into parallel arrays;
/// [`Frame::fold_into`] reduces them with tight sum loops. Equivalence:
/// the driver folds the frame immediately *before* the warmup-boundary
/// `stats.reset()` and again at the end of the run, so pre-warm
/// contributions land in `SimStats` only to be wiped by the same reset
/// that wipes them in the scalar path.
pub struct Frame {
    network: Vec<u64>,
    queued: Vec<u64>,
    array: Vec<u64>,
    queued_net: Vec<u64>,
    queued_mem: Vec<u64>,
    /// L1 hits observed since the last fold (no per-hit warmup branch).
    l1_hits: u64,
}

impl Frame {
    pub fn with_capacity(cap: usize) -> Self {
        Frame {
            network: Vec::with_capacity(cap),
            queued: Vec::with_capacity(cap),
            array: Vec::with_capacity(cap),
            queued_net: Vec::with_capacity(cap),
            queued_mem: Vec::with_capacity(cap),
            l1_hits: 0,
        }
    }

    #[inline]
    pub fn record(&mut self, res: &ServedRequest) {
        self.network.push(res.network);
        self.queued.push(res.queued);
        self.array.push(res.array);
        self.queued_net.push(res.queued_net);
        self.queued_mem.push(res.queued_mem());
    }

    #[inline]
    pub fn record_l1_hit(&mut self) {
        self.l1_hits += 1;
    }

    pub fn is_full(&self) -> bool {
        self.network.len() >= FRAME_CAPACITY
    }

    /// Reduce the component arrays into `stats` and clear the frame.
    pub fn fold_into(&mut self, stats: &mut SimStats) {
        stats.latency.network += self.network.iter().sum::<u64>();
        stats.latency.queue += self.queued.iter().sum::<u64>();
        stats.latency.array += self.array.iter().sum::<u64>();
        stats.latency.requests += self.network.len() as u64;
        stats.queue_net += self.queued_net.iter().sum::<u64>();
        stats.queue_mem += self.queued_mem.iter().sum::<u64>();
        stats.requests += self.network.len() as u64;
        stats.l1_hits += self.l1_hits;
        self.network.clear();
        self.queued.clear();
        self.array.clear();
        self.queued_net.clear();
        self.queued_mem.clear();
        self.l1_hits = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    /// Deterministic LCG for the order-equivalence storm.
    struct Lcg(u64);
    impl Lcg {
        fn next(&mut self) -> u64 {
            self.0 = self
                .0
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            self.0 >> 16
        }
    }

    /// Drive the WindowQueue and a reference BinaryHeap with identical
    /// randomized re-arm schedules (gaps spanning far below and beyond
    /// ADMIT_WINDOW) and assert identical pop sequences, including core
    /// finishes.
    #[test]
    fn pop_order_matches_binary_heap() {
        let n: usize = 8;
        let mut rng = Lcg(42);
        let mut q = WindowQueue::new(n);
        let mut heap: BinaryHeap<Reverse<(Cycle, CoreId)>> =
            (0..n as CoreId).map(|c| Reverse((0, c))).collect();
        // Per-core op budgets so streams end at different times.
        let mut left: Vec<u64> = (0..n).map(|i| 200 + 37 * i as u64).collect();
        let mut popped = 0u64;
        loop {
            let a = q.pop();
            let b = heap.pop().map(|Reverse(e)| e);
            assert_eq!(a, b, "divergence after {popped} pops");
            let Some((t, c)) = a else { break };
            popped += 1;
            if left[c as usize] == 0 {
                q.finish(c);
                // The heap reference simply never re-pushes.
                continue;
            }
            left[c as usize] -= 1;
            // Gaps: mostly small (stay in-window), sometimes huge
            // (leave the window), sometimes zero (same-cycle re-arm).
            let gap = match rng.next() % 10 {
                0 => 0,
                1..=2 => ADMIT_WINDOW + rng.next() % 100_000,
                _ => rng.next() % 500,
            };
            q.reissue(c, t + gap);
            heap.push(Reverse((t + gap, c)));
        }
        assert_eq!(q.live(), 0);
        assert!(popped > 1000);
    }

    #[test]
    fn fold_matches_scalar_accumulation() {
        let mut frame = Frame::with_capacity(16);
        let mut batched = SimStats::new(4);
        let mut scalar = SimStats::new(4);
        let mut rng = Lcg(7);
        for _ in 0..100 {
            let queued_net = rng.next() % 50;
            let res = ServedRequest {
                network: rng.next() % 100,
                queued: queued_net + rng.next() % 80,
                queued_net,
                array: 14 + rng.next() % 24,
                ..Default::default()
            };
            frame.record(&res);
            scalar.latency.record(res.network, res.queued, res.array);
            scalar.queue_net += res.queued_net;
            scalar.queue_mem += res.queued_mem();
            scalar.requests += 1;
        }
        frame.record_l1_hit();
        scalar.l1_hits += 1;
        frame.fold_into(&mut batched);
        assert_eq!(batched.latency, scalar.latency);
        assert_eq!(batched.queue_net, scalar.queue_net);
        assert_eq!(batched.queue_mem, scalar.queue_mem);
        assert_eq!(batched.requests, scalar.requests);
        assert_eq!(batched.l1_hits, scalar.l1_hits);
        // Second fold is a no-op: the frame cleared itself.
        frame.fold_into(&mut batched);
        assert_eq!(batched.requests, scalar.requests);
    }

    #[test]
    fn same_cycle_rearm_pops_in_core_order() {
        let mut q = WindowQueue::new(3);
        assert_eq!(q.pop(), Some((0, 0)));
        q.reissue(0, 0); // zero-gap re-arm: still cycle 0
        // Core 0 re-arms at (0,0) but cores 1,2 are also at cycle 0 —
        // the heap order is (0,0), (0,1), (0,2).
        assert_eq!(q.pop(), Some((0, 0)));
        q.reissue(0, 5);
        assert_eq!(q.pop(), Some((0, 1)));
        q.reissue(1, 1);
        assert_eq!(q.pop(), Some((0, 2)));
        q.finish(2);
        assert_eq!(q.pop(), Some((1, 1)));
        q.finish(1);
        assert_eq!(q.pop(), Some((5, 0)));
        q.finish(0);
        assert_eq!(q.pop(), None);
        assert_eq!(q.live(), 0);
    }
}

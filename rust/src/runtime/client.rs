//! PJRT client: load HLO text, compile once, execute many times.
//!
//! Two builds:
//! * **default** — a stub backend. The `xla` crate cannot be vendored in
//!   this offline environment, so [`PjrtRuntime::cpu`] reports the backend
//!   unavailable; [`super::ArtifactStore`] then fails open-time and every
//!   consumer (the `repro artifacts` command, `tests/runtime_integration.rs`,
//!   the e2e example) degrades gracefully.
//! * **`--features pjrt`** — the real implementation over the `xla`
//!   crate's PJRT CPU client. Enabling the feature requires adding the
//!   `xla` dependency to `rust/Cargo.toml` on a networked machine.

use std::path::Path;

use crate::error::Result;
#[cfg(not(feature = "pjrt"))]
use crate::error::{err, Error};
#[cfg(feature = "pjrt")]
use crate::error::Context;

#[cfg(not(feature = "pjrt"))]
fn unavailable() -> Error {
    err!(
        "PJRT backend unavailable: this build uses the stub runtime; \
         rebuild with `--features pjrt` after adding the `xla` dependency"
    )
}

/// A compiled executable plus its human name.
pub struct Executable {
    pub name: String,
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with `f32` input buffers of the given shapes; returns the
    /// flattened `f32` outputs (the AOT pipeline lowers with
    /// `return_tuple=True`, so outputs arrive as one tuple).
    #[cfg(feature = "pjrt")]
    pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let lit = xla::Literal::vec1(data);
            let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
            literals.push(lit.reshape(&dims_i64).context("reshape input")?);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .context("execute")?[0][0]
            .to_literal_sync()
            .context("fetch result")?;
        let tuple = result.to_tuple().context("untuple result")?;
        let mut out = Vec::with_capacity(tuple.len());
        for lit in tuple {
            out.push(lit.to_vec::<f32>().context("read output")?);
        }
        Ok(out)
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn run_f32(&self, _inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        Err(unavailable())
    }
}

/// The process-wide PJRT runtime.
pub struct PjrtRuntime {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    #[cfg(not(feature = "pjrt"))]
    _priv: (),
}

impl PjrtRuntime {
    /// Create the CPU client (the only backend in this environment; real
    /// deployments swap in the TPU plugin here). The stub build errors
    /// here so artifact consumers skip cleanly.
    #[cfg(feature = "pjrt")]
    pub fn cpu() -> Result<Self> {
        Ok(PjrtRuntime { client: xla::PjRtClient::cpu().context("create PJRT CPU client")? })
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn cpu() -> Result<Self> {
        Err(unavailable())
    }

    #[cfg(feature = "pjrt")]
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn platform(&self) -> String {
        "unavailable (stub build)".to_string()
    }

    /// Load and compile an HLO-text artifact.
    #[cfg(feature = "pjrt")]
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))?;
        let name = path
            .file_name()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_default()
            .trim_end_matches(".hlo.txt")
            .to_string();
        Ok(Executable { name, exe })
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn load_hlo_text(&self, _path: &Path) -> Result<Executable> {
        Err(unavailable())
    }
}

// No unit tests here: exercising PJRT requires the artifacts, which are
// produced by `make artifacts`; see rust/tests/runtime_integration.rs.

//! Thin wrapper over the `xla` crate's PJRT CPU client: load HLO text,
//! compile once, execute many times.

use anyhow::{Context, Result};
use std::path::Path;

/// A compiled executable plus its human name.
pub struct Executable {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with `f32` input buffers of the given shapes; returns the
    /// flattened `f32` outputs (the AOT pipeline lowers with
    /// `return_tuple=True`, so outputs arrive as one tuple).
    pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let lit = xla::Literal::vec1(data);
            let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
            literals.push(lit.reshape(&dims_i64).context("reshape input")?);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .context("execute")?[0][0]
            .to_literal_sync()
            .context("fetch result")?;
        let tuple = result.to_tuple().context("untuple result")?;
        let mut out = Vec::with_capacity(tuple.len());
        for lit in tuple {
            out.push(lit.to_vec::<f32>().context("read output")?);
        }
        Ok(out)
    }
}

/// The process-wide PJRT CPU runtime.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

impl PjrtRuntime {
    /// Create the CPU client (the only backend in this environment; real
    /// deployments swap in the TPU plugin here).
    pub fn cpu() -> Result<Self> {
        Ok(PjrtRuntime { client: xla::PjRtClient::cpu().context("create PJRT CPU client")? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile an HLO-text artifact.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))?;
        let name = path
            .file_name()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_default()
            .trim_end_matches(".hlo.txt")
            .to_string();
        Ok(Executable { name, exe })
    }
}

// No unit tests here: exercising PJRT requires the artifacts, which are
// produced by `make artifacts`; see rust/tests/runtime_integration.rs.

//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust request path.
//!
//! Python is build-time only: `make artifacts` lowers the JAX/Pallas
//! compute graphs to HLO *text* (the interchange format that round-trips
//! through xla_extension 0.5.1 — serialized protos from jax ≥ 0.5 carry
//! 64-bit instruction ids it rejects), and this module compiles them once
//! on the PJRT CPU client and executes them with concrete buffers.

pub mod artifacts;
pub mod client;

pub use artifacts::ArtifactStore;
pub use client::{Executable, PjrtRuntime};

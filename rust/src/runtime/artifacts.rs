//! Artifact store: discovers, compiles, and caches the AOT HLO artifacts.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{bail, Context, Result};

use super::client::{Executable, PjrtRuntime};

/// Default artifact directory relative to the repo root.
pub const DEFAULT_DIR: &str = "artifacts";

/// Compile-once cache of every `*.hlo.txt` under the artifact directory.
pub struct ArtifactStore {
    runtime: PjrtRuntime,
    dir: PathBuf,
    cache: HashMap<String, Executable>,
}

impl ArtifactStore {
    pub fn open(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        if !dir.is_dir() {
            bail!(
                "artifact directory {} missing — run `make artifacts` first",
                dir.display()
            );
        }
        Ok(ArtifactStore { runtime: PjrtRuntime::cpu()?, dir, cache: HashMap::new() })
    }

    /// Locate the artifact dir from the current working directory or the
    /// repo root (so examples work from either).
    pub fn discover() -> Result<Self> {
        for base in [".", "..", "../.."] {
            let p = Path::new(base).join(DEFAULT_DIR);
            if p.is_dir() {
                return Self::open(p);
            }
        }
        bail!("no artifacts/ directory found — run `make artifacts`")
    }

    pub fn platform(&self) -> String {
        self.runtime.platform()
    }

    /// Names of available artifacts (without `.hlo.txt`).
    pub fn list(&self) -> Result<Vec<String>> {
        let mut names = Vec::new();
        for entry in std::fs::read_dir(&self.dir).context("read artifacts dir")? {
            let path = entry?.path();
            if let Some(name) = path.file_name().and_then(|n| n.to_str()) {
                if let Some(stem) = name.strip_suffix(".hlo.txt") {
                    names.push(stem.to_string());
                }
            }
        }
        names.sort();
        Ok(names)
    }

    /// Get (compiling on first use) the executable for `name`.
    pub fn get(&mut self, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let path = self.dir.join(format!("{name}.hlo.txt"));
            let exe = self.runtime.load_hlo_text(&path)?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }
}

//! Deterministic PRNG shared by the workload generators and the
//! property-test harness (the `rand` crate is unavailable offline).
//!
//! SplitMix64: tiny, fast, well-distributed, and — critically for the
//! paper's 5-run methodology — fully reproducible from a seed.

/// SplitMix64 PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`. `n` must be non-zero.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Multiply-shift range reduction (Lemire); bias negligible for
        // simulation purposes.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Power-law-ish (Zipf by repeated range quartering): returns a value
    /// in `[0, n)` where small indices are exponentially more likely. Used
    /// by the graph workloads to model hub vertices.
    pub fn zipfish(&mut self, n: u64) -> u64 {
        let mut hi = n;
        while hi > 1 && self.chance(0.75) {
            hi = (hi + 3) / 4;
        }
        self.below(hi.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn below_covers_support() {
        let mut r = Rng::new(4);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.below(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(5);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn zipfish_skews_low() {
        let mut r = Rng::new(6);
        let mut low = 0;
        let n = 1024;
        let trials = 10_000;
        for _ in 0..trials {
            if r.zipfish(n) < n / 8 {
                low += 1;
            }
        }
        // Uniform would put 12.5% below n/8; zipfish must far exceed that.
        assert!(low as f64 / trials as f64 > 0.4, "low share {low}/{trials}");
    }

    #[test]
    fn mean_is_centered() {
        let mut r = Rng::new(8);
        let mean: f64 = (0..100_000).map(|_| r.f64()).sum::<f64>() / 100_000.0;
        assert!((mean - 0.5).abs() < 0.01);
    }
}

//! # DL-PIM — Data-Locality-based Processing-in-Memory
//!
//! Full reproduction of *"DL-PIM: Improving Data Locality in
//! Processing-in-Memory Systems"* (Tian, Yousefijamarani, Alameldeen, 2025)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the PIM memory-system coordinator: a
//!   discrete-event, cycle-resolution model of an HMC / HBM vault mesh with
//!   the paper's subscription tables, subscription protocol, and adaptive
//!   subscription policies; plus the 31 DAMOV-representative workload
//!   traffic generators and the measurement harness that regenerates every
//!   figure in the paper's evaluation.
//! * **Layer 2 / Layer 1 (python/, build-time only)** — JAX compute graphs
//!   and Pallas kernels for the workloads' arithmetic hot-spots, AOT-lowered
//!   to HLO text and executed from Rust through the PJRT CPU client
//!   ([`runtime`]). Python never runs on the request path.
//!
//! ## Quick example
//!
//! ```no_run
//! use dlpim::config::SimConfig;
//! use dlpim::coordinator::driver::simulate;
//! use dlpim::policy::PolicyKind;
//! use dlpim::sweep::{Sweep, SweepPoint};
//! use dlpim::workloads::catalog;
//!
//! // One simulation, driven by hand:
//! let mut cfg = SimConfig::hmc();
//! cfg.policy = PolicyKind::Adaptive;
//! let wl = catalog::build("SPLRad", &cfg).unwrap();
//! let report = simulate(&cfg, wl);
//! println!("avg latency = {:.1} cycles", report.avg_latency());
//!
//! // Many points on the parallel sweep engine (what every figure runs on):
//! let points = vec![
//!     SweepPoint::new("SPLRad", SimConfig::hmc()),
//!     SweepPoint::new("PLYgemm", SimConfig::hmc()),
//! ];
//! for outcome in Sweep::new(points).run() {
//!     println!("{}: {:.0} cycles", outcome.workload, outcome.report().cycles());
//! }
//! ```

// Clippy policy (see rust/docs/LINTING.md): CI runs `-D warnings`, which
// promotes these to hard errors there while plain `cargo build` stays
// usable mid-refactor. `unwrap_used` is scoped to non-test code — tests
// unwrap freely; library code must `expect` with a reason or propagate.
#![warn(clippy::dbg_macro)]
#![warn(clippy::print_stdout)]
#![warn(clippy::print_stderr)]
#![cfg_attr(not(test), warn(clippy::unwrap_used))]

pub mod benchkit;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod exp;
pub mod figures;
pub mod lint;
pub mod memsys;
pub mod obs;
pub mod perf;
pub mod policy;
pub mod proptest_lite;
pub mod rng;
pub mod runtime;
pub mod sim;
pub mod stats;
pub mod subscription;
pub mod sweep;
pub mod trace;
pub mod workloads;

/// Simulation clock, in PIM-core cycles (2.4 GHz in the paper's testbed).
pub type Cycle = u64;
/// Byte address within the simulated physical address space.
pub type Addr = u64;
/// Index of a vault (HMC) or channel (HBM) — also the index of the PIM core
/// that lives on that vault's logic layer.
pub type VaultId = u16;
/// Index of a PIM core. One core per vault in this model, so `CoreId` and
/// [`VaultId`] coincide numerically, but the types are kept distinct for
/// clarity at call sites.
pub type CoreId = u16;

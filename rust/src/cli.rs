//! Hand-rolled CLI (clap is unavailable offline): subcommands + `--key
//! value` flags with help text.

use std::collections::HashMap;

/// Parsed command line: subcommand, positional args, and flags.
#[derive(Debug, Default)]
pub struct Cli {
    pub command: String,
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Cli {
    /// Parse `args` (without argv[0]). Flags are `--key value` or
    /// `--switch` (value "true").
    pub fn parse(args: &[String]) -> Result<Self, String> {
        let mut cli = Cli::default();
        let mut it = args.iter().peekable();
        if let Some(cmd) = it.next() {
            if cmd.starts_with("--") {
                return Err(format!("expected subcommand before {cmd}"));
            }
            cli.command = cmd.clone();
        }
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    return Err("bare `--` not supported".into());
                }
                let value = match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        it.next().expect("peeked value exists").clone()
                    }
                    _ => "true".to_string(),
                };
                cli.flags.insert(key.to_string(), value);
            } else {
                cli.positional.push(a.clone());
            }
        }
        Ok(cli)
    }

    pub fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn flag_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.flag(key).unwrap_or(default)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn flag_u64(&self, key: &str) -> Result<Option<u64>, String> {
        match self.flag(key) {
            None => Ok(None),
            Some(v) => v
                .replace('_', "")
                .parse()
                .map(Some)
                .map_err(|_| format!("--{key} expects a number, got {v:?}")),
        }
    }

    /// Reject any flag not in `known`, with a did-you-mean suggestion —
    /// `--workloed` must fail loudly instead of silently running the
    /// default workload.
    pub fn reject_unknown_flags(&self, known: &[&str]) -> Result<(), String> {
        let mut bad: Vec<&str> =
            self.flags.keys().map(|k| k.as_str()).filter(|k| !known.contains(k)).collect();
        bad.sort_unstable(); // deterministic messages (HashMap order isn't)
        match bad.first() {
            None => Ok(()),
            Some(flag) => {
                let hint = match suggest(flag, known.iter().copied()) {
                    Some(s) => format!("; did you mean --{s}?"),
                    None => String::new(),
                };
                Err(format!("unknown flag --{flag} for `{}`{hint}", self.command))
            }
        }
    }
}

/// Flags each subcommand accepts (`known_flags` below maps commands to
/// these). Shared config flags first.
pub mod flags {
    /// Flags understood by `config_from_cli` (shared by run/config/trace).
    pub const CONFIG: &[&str] = &[
        "config", "memory", "policy", "topology", "quick", "paper-scale", "warmup",
        "measure", "runs", "seed", "epoch", "trace",
    ];
    /// Observability flags shared by the simulating commands:
    /// `--metrics-out [FILE]` enables telemetry and exports the metrics
    /// snapshot (JSON + Prometheus sibling), `--quiet` / `--v` /
    /// `--verbose` pick the log level.
    pub const OBS: &[&str] = &["metrics-out", "quiet", "v", "verbose"];
    /// Sharded-execution flags shared by `figure` and `sweep`:
    /// `--worker` joins (or runs) a cooperative sharded sweep over the
    /// disk store, `--workers N` forks N local worker subprocesses,
    /// `--worker-id S` names this worker in claim leases, and
    /// `--lease-ttl-ms N` sets the stale-claim takeover threshold.
    pub const SHARD: &[&str] = &["worker", "workers", "worker-id", "lease-ttl-ms"];
    pub const RUN: &[&str] = &[
        "config", "memory", "policy", "topology", "quick", "paper-scale", "warmup",
        "measure", "runs", "seed", "epoch", "trace", "workload", "record", "no-loop",
        "threads", "metrics-out", "quiet", "v", "verbose",
    ];
    pub const TRACE_RECORD: &[&str] = &[
        "config", "memory", "policy", "topology", "quick", "paper-scale", "warmup",
        "measure", "runs", "seed", "epoch", "workload", "out",
    ];
    pub const TRACE_REPLAY: &[&str] = &[
        "config", "memory", "policy", "topology", "quick", "paper-scale", "warmup",
        "measure", "runs", "seed", "epoch", "no-loop",
    ];
    pub const TRACE_MIX: &[&str] = &["out", "weights", "cores"];
    pub const TRACE_DILATE: &[&str] = &["factor"];
    pub const TRACE_REMAP: &[&str] = &["vaults"];
    /// `repro figure`: `--list` enumerates the spec registry;
    /// `--no-disk-cache` keeps this invocation from reading/writing the
    /// persistent report cache.
    pub const FIGURE: &[&str] = &[
        "list", "no-disk-cache", "metrics-out", "quiet", "v", "verbose", "worker",
        "workers", "worker-id", "lease-ttl-ms",
    ];
    /// `repro all-figures`.
    pub const ALL_FIGURES: &[&str] =
        &["no-disk-cache", "metrics-out", "quiet", "v", "verbose"];
    /// `repro sweep`: `--spec FILE`, or the ad-hoc axis flags mirroring
    /// the spec-file keys (dashes for underscores).
    pub const SWEEP: &[&str] = &[
        "spec", "name", "title", "memory", "topology", "workloads", "policies",
        "baseline", "table-entries", "thresholds", "epochs", "trace", "trace-mix",
        "mixes", "warmup", "measure", "runs", "seed", "no-disk-cache", "metrics-out",
        "quiet", "v", "verbose", "worker", "workers", "worker-id", "lease-ttl-ms",
    ];
    /// `repro cache stats|clear|gc`: `--dir` overrides the store location
    /// (default: `REPRO_CACHE_DIR` or `target/repro/cache`).
    pub const CACHE: &[&str] = &["dir"];
    /// `repro bench`: the pinned perf trajectory. `--json` emits the
    /// BENCH_*.json document (to `--out FILE`, default
    /// target/repro/BENCH_8.json), `--check FILE` gates against a
    /// checked-in baseline at `--threshold` percent (default 10),
    /// `--promote` rewrites the checked-in baseline with fresh numbers.
    pub const BENCH: &[&str] = &["json", "out", "check", "threshold", "promote"];
    /// `repro lint`: `--json` emits the findings document, `--fix-allow`
    /// inserts placeholder `lint:allow` annotations at violation sites.
    pub const LINT: &[&str] = &["json", "fix-allow"];
    pub const NONE: &[&str] = &[];
}

/// The known-flag list for a (sub)command, or `None` for commands the CLI
/// does not recognize (the dispatcher reports those itself).
pub fn known_flags(command: &str, sub: Option<&str>) -> Option<&'static [&'static str]> {
    Some(match (command, sub) {
        ("run", _) => flags::RUN,
        ("config", _) => flags::CONFIG,
        ("figure", _) => flags::FIGURE,
        ("sweep", _) => flags::SWEEP,
        ("all-figures", _) => flags::ALL_FIGURES,
        ("workloads" | "artifacts", _) => flags::NONE,
        ("bench", _) => flags::BENCH,
        ("lint", _) => flags::LINT,
        ("cache", Some("stats" | "clear" | "gc") | None) => flags::CACHE,
        ("trace", Some("record")) => flags::TRACE_RECORD,
        ("trace", Some("replay")) => flags::TRACE_REPLAY,
        ("trace", Some("info")) => flags::NONE,
        ("trace", Some("mix")) => flags::TRACE_MIX,
        ("trace", Some("dilate")) => flags::TRACE_DILATE,
        ("trace", Some("remap")) => flags::TRACE_REMAP,
        _ => return None,
    })
}

/// Nearest candidate by edit distance, if close enough to be a plausible
/// typo (distance <= 2, or <= len/3 for long names). Shared by flag and
/// workload-name suggestions.
pub fn suggest<'a>(input: &str, candidates: impl IntoIterator<Item = &'a str>) -> Option<&'a str> {
    let mut best: Option<(usize, &str)> = None;
    for c in candidates {
        let d = levenshtein(&input.to_lowercase(), &c.to_lowercase());
        let better = match best {
            None => true,
            Some((bd, _)) => d < bd,
        };
        if better {
            best = Some((d, c));
        }
    }
    let (d, name) = best?;
    let budget = (input.len().max(name.len()) / 3).max(2);
    (d <= budget).then_some(name)
}

/// Classic two-row Levenshtein edit distance.
pub fn levenshtein(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// Top-level help text.
pub const HELP: &str = "\
dlpim repro — DL-PIM (Tian et al., 2025) reproduction harness

USAGE:
    repro <COMMAND> [FLAGS]

COMMANDS:
    run           Simulate one workload: --workload NAME [--memory hmc|hbm]
                  [--topology mesh|crossbar|ring]
                  [--policy never|always|adaptive|adaptive-hops|adaptive-latency]
                  [--measure N] [--warmup N] [--runs N] [--seed N] [--config FILE]
                  [--trace FILE] replay a recorded trace instead of a generator
                  [--record FILE] capture this run's traffic to a trace file
                  [--no-loop] end when a replayed trace runs out instead of looping
                  [--threads N] fan the runs across N kernel threads
                  (default REPRO_THREADS or 1; reports are bit-identical
                  at any thread count)
    figure        Regenerate one figure from the spec registry: figure <N>
                  (runs on the parallel sweep engine; writes target/repro/figNN.json)
                  figure --list prints every spec's name, axes and point count
    all-figures   Regenerate every registry figure (writes target/repro/*.json;
                  repeated figure targets reuse the sweep engine's report cache)
    sweep         Run an ad-hoc declarative sweep: sweep --spec FILE (TOML), or
                  axis flags: [--workloads all|selected|A,B] [--policies P,P]
                  [--topology T] [--memory hmc|hbm] [--baseline]
                  [--table-entries N,N] [--thresholds N,N] [--epochs N,N]
                  [--trace FILE | --trace-mix W,W [--mixes label:k,..]]
                  [--name S] [--warmup N] [--measure N] [--runs N] [--seed N]
                  Emits a long-form JSON artifact (one row per point)
    workloads     Print Table III (the 31 representative workloads)
    config        Print the resolved config: --memory hmc|hbm [--policy P]
                  [--topology mesh|crossbar|ring]
    trace         Record/replay/compose memory traces (DLPT v1 binary format):
                    trace record --workload NAME --out FILE [config flags]
                    trace replay FILE [config flags] [--no-loop]
                    trace info FILE
                    trace mix IN1 IN2 [IN...] --out FILE [--weights A,B,..] [--cores N]
                    trace dilate IN OUT --factor F
                    trace remap IN OUT --vaults N
    cache         Manage the persistent report cache shared by figure and
                  sweep runs (entries: target/repro/cache/<key>.json):
                    cache stats   entry counts, sizes, staleness, claims
                    cache clear   drop every entry (live claims survive)
                    cache gc      drop stale/corrupt entries, keep current
                                  and anything under a live claim lease
                  All accept --dir DIR to address another store.
    bench         Measure the pinned serve-throughput trajectory (fixed seed
                  and scale; see docs/BENCHMARKING.md):
                    bench                 print per-topology rows
                    bench --json [--out FILE]   also write BENCH_*.json
                                          (default target/repro/BENCH_8.json)
                    bench --check FILE [--threshold PCT]  fail if headline
                                          serve_ops_per_sec drops > PCT (10)
                    bench --promote [--check FILE]  rewrite the checked-in
                                          baseline (default BENCH_8.json)
                                          with this machine's fresh numbers
                  Env REPRO_BENCH_SKIP=1 skips entirely (noisy runners;
                  --promote refuses under it)
    artifacts     List figure JSON artifacts and the AOT artifacts (PJRT)
    lint          Run the determinism & invariant static-analysis pass over
                  rust/src (rules D1–D5; see docs/LINTING.md). Exits non-zero
                  on any unallowed finding, one line per finding sorted by
                  (file, line):
                    lint [PATH]      lint the repo at PATH (default: walk up
                                     from the current directory)
                    lint --json      emit the full findings document (incl.
                                     justified allows) as JSON on stdout
                    lint --fix-allow insert placeholder `lint:allow` comments
                                     at violation sites (stays red until the
                                     TODO justifications are written)
    help          This text

SCALE FLAGS (also env REPRO_WARMUP / REPRO_MEASURE / REPRO_RUNS / REPRO_EPOCH):
    --quick        small run (CI scale)
    --paper-scale  the paper's 1e6-cycle epochs / 1e6-request warmup (slow)

CACHE FLAGS (figure / all-figures / sweep):
    --no-disk-cache  compute every point; don't read or write the
                     persistent report cache (in-process reuse still applies)

SHARD FLAGS (figure / sweep; see docs/ARCHITECTURE.md \"Sharded sweeps\"):
    --worker         execute this sweep cooperatively through the store's
                     claim protocol; any number of such processes (on a
                     shared cache dir) split the points and each renders
                     the artifact when the grid completes — the bytes are
                     identical at any worker count
    --workers N      fork N local worker subprocesses and run one worker
                     in this process too (a one-command sharded sweep)
    --worker-id S    name this worker in claim leases (default: w<pid>)
    --lease-ttl-ms N stale-claim takeover threshold (default 30000; a
                     worker that stops heartbeating this long loses its
                     claims to the survivors)

OBSERVABILITY FLAGS (run / figure / all-figures / sweep):
    --metrics-out [FILE]  record telemetry and write the metrics snapshot
                     as exact-integer JSON (default target/repro/metrics.json)
                     plus a Prometheus text sibling (.prom). Passive: enabling
                     it never changes simulated cycles, cache keys or
                     artifact bytes (see docs/OBSERVABILITY.md)
    --quiet          suppress progress output (errors still print)
    --v, --verbose   extra diagnostics (the default prints exactly the
                     historic progress lines)

ENVIRONMENT:
    REPRO_THREADS        sweep worker threads (default: all cores) and the
                         run command's kernel threads (default: 1)
    REPRO_ARTIFACT_DIR   where figure JSON artifacts land (default: target/repro)
    REPRO_CACHE_DIR      where the persistent report cache lives
                         (default: target/repro/cache)
    REPRO_NO_DISK_CACHE  1|true disables the persistent report cache
    REPRO_TOPOLOGY       override the interconnect for every figure run
                         (mesh|crossbar|ring; default: the preset's topology)
    REPRO_LOG            quiet|info|debug (or 0|1|2) default log level;
                         --quiet / --v win when given
    REPRO_LEASE_TTL_MS   default stale-claim takeover threshold for
                         sharded sweeps (--lease-ttl-ms wins when given)
";

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_command_and_flags() {
        let c = Cli::parse(&args(&["run", "--workload", "SPLRad", "--quick"])).unwrap();
        assert_eq!(c.command, "run");
        assert_eq!(c.flag("workload"), Some("SPLRad"));
        assert!(c.has("quick"));
        assert_eq!(c.flag("quick"), Some("true"));
    }

    #[test]
    fn positional_args() {
        let c = Cli::parse(&args(&["figure", "11"])).unwrap();
        assert_eq!(c.command, "figure");
        assert_eq!(c.positional, vec!["11"]);
    }

    #[test]
    fn numeric_flags() {
        let c = Cli::parse(&args(&["run", "--measure", "10_000"])).unwrap();
        assert_eq!(c.flag_u64("measure").unwrap(), Some(10_000));
        assert!(Cli::parse(&args(&["run", "--measure", "ten"]))
            .unwrap()
            .flag_u64("measure")
            .is_err());
    }

    #[test]
    fn rejects_flag_first() {
        assert!(Cli::parse(&args(&["--oops", "run"])).is_err());
    }

    #[test]
    fn empty_is_ok() {
        let c = Cli::parse(&[]).unwrap();
        assert_eq!(c.command, "");
    }

    #[test]
    fn unknown_flag_rejected_with_suggestion() {
        let c = Cli::parse(&args(&["run", "--workloed", "SPLRad"])).unwrap();
        let err = c.reject_unknown_flags(flags::RUN).unwrap_err();
        assert!(err.contains("--workloed"), "{err}");
        assert!(err.contains("did you mean --workload"), "{err}");
    }

    #[test]
    fn known_flags_pass_validation() {
        let c = Cli::parse(&args(&["run", "--workload", "SPLRad", "--quick"])).unwrap();
        assert!(c.reject_unknown_flags(flags::RUN).is_ok());
    }

    #[test]
    fn wildly_wrong_flag_gets_no_suggestion() {
        let c = Cli::parse(&args(&["run", "--zzzzzzzzzz", "1"])).unwrap();
        let err = c.reject_unknown_flags(flags::RUN).unwrap_err();
        assert!(!err.contains("did you mean"), "{err}");
    }

    #[test]
    fn every_command_has_a_flag_list() {
        for cmd in [
            "run", "figure", "all-figures", "sweep", "workloads", "config", "artifacts",
            "cache", "bench", "lint",
        ]
        {
            assert!(known_flags(cmd, None).is_some(), "{cmd}");
        }
        for sub in ["record", "replay", "info", "mix", "dilate", "remap"] {
            assert!(known_flags("trace", Some(sub)).is_some(), "trace {sub}");
        }
        for sub in ["stats", "clear", "gc"] {
            assert!(known_flags("cache", Some(sub)).is_some(), "cache {sub}");
        }
        assert!(known_flags("bogus", None).is_none());
        assert!(known_flags("trace", Some("bogus")).is_none());
        assert!(known_flags("cache", Some("bogus")).is_none());
    }

    #[test]
    fn obs_flags_on_every_simulating_command() {
        for (cmd, list) in [
            ("run", flags::RUN),
            ("figure", flags::FIGURE),
            ("all-figures", flags::ALL_FIGURES),
            ("sweep", flags::SWEEP),
        ] {
            for f in flags::OBS {
                assert!(list.contains(f), "--{f} missing from `{cmd}`");
            }
        }
    }

    #[test]
    fn shard_flags_on_figure_and_sweep() {
        for (cmd, list) in [("figure", flags::FIGURE), ("sweep", flags::SWEEP)] {
            for f in flags::SHARD {
                assert!(list.contains(f), "--{f} missing from `{cmd}`");
            }
        }
    }

    #[test]
    fn levenshtein_basics() {
        assert_eq!(levenshtein("", "abc"), 3);
        assert_eq!(levenshtein("abc", "abc"), 0);
        assert_eq!(levenshtein("workloed", "workload"), 1);
        assert_eq!(levenshtein("wrokload", "workload"), 2); // transposition
        assert_eq!(levenshtein("SPLRod", "SPLRad"), 1);
    }

    #[test]
    fn suggest_finds_nearest_workload_style_name() {
        let names = ["SPLRad", "PHELinReg", "STRTriad"];
        assert_eq!(suggest("SPLRod", names), Some("SPLRad"));
        assert_eq!(suggest("phelinreg", names), Some("PHELinReg"));
        assert_eq!(suggest("qqqqqq", names), None);
    }
}

//! Hand-rolled CLI (clap is unavailable offline): subcommands + `--key
//! value` flags with help text.

use std::collections::HashMap;

/// Parsed command line: subcommand, positional args, and flags.
#[derive(Debug, Default)]
pub struct Cli {
    pub command: String,
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Cli {
    /// Parse `args` (without argv[0]). Flags are `--key value` or
    /// `--switch` (value "true").
    pub fn parse(args: &[String]) -> Result<Self, String> {
        let mut cli = Cli::default();
        let mut it = args.iter().peekable();
        if let Some(cmd) = it.next() {
            if cmd.starts_with("--") {
                return Err(format!("expected subcommand before {cmd}"));
            }
            cli.command = cmd.clone();
        }
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    return Err("bare `--` not supported".into());
                }
                let value = match it.peek() {
                    Some(v) if !v.starts_with("--") => it.next().unwrap().clone(),
                    _ => "true".to_string(),
                };
                cli.flags.insert(key.to_string(), value);
            } else {
                cli.positional.push(a.clone());
            }
        }
        Ok(cli)
    }

    pub fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn flag_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.flag(key).unwrap_or(default)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn flag_u64(&self, key: &str) -> Result<Option<u64>, String> {
        match self.flag(key) {
            None => Ok(None),
            Some(v) => v
                .replace('_', "")
                .parse()
                .map(Some)
                .map_err(|_| format!("--{key} expects a number, got {v:?}")),
        }
    }
}

/// Top-level help text.
pub const HELP: &str = "\
dlpim repro — DL-PIM (Tian et al., 2025) reproduction harness

USAGE:
    repro <COMMAND> [FLAGS]

COMMANDS:
    run           Simulate one workload: --workload NAME [--memory hmc|hbm]
                  [--topology mesh|crossbar|ring]
                  [--policy never|always|adaptive|adaptive-hops|adaptive-latency]
                  [--measure N] [--warmup N] [--runs N] [--seed N] [--config FILE]
    figure        Regenerate one figure: figure <1|2|3|4|9|10|11|12|13|14|15|16|17|18>
                  (runs on the parallel sweep engine; writes target/repro/figNN.json)
    all-figures   Regenerate every figure (writes target/repro/*.json; repeated
                  figure targets reuse the sweep engine's report cache)
    workloads     Print Table III (the 31 representative workloads)
    config        Print the resolved config: --memory hmc|hbm [--policy P]
                  [--topology mesh|crossbar|ring]
    artifacts     List figure JSON artifacts and the AOT artifacts (PJRT)
    help          This text

SCALE FLAGS (also env REPRO_WARMUP / REPRO_MEASURE / REPRO_RUNS / REPRO_EPOCH):
    --quick        small run (CI scale)
    --paper-scale  the paper's 1e6-cycle epochs / 1e6-request warmup (slow)

ENVIRONMENT:
    REPRO_THREADS       sweep worker threads (default: all cores)
    REPRO_ARTIFACT_DIR  where figure JSON artifacts land (default: target/repro)
    REPRO_TOPOLOGY      override the interconnect for every figure run
                        (mesh|crossbar|ring; default: the preset's topology)
";

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_command_and_flags() {
        let c = Cli::parse(&args(&["run", "--workload", "SPLRad", "--quick"])).unwrap();
        assert_eq!(c.command, "run");
        assert_eq!(c.flag("workload"), Some("SPLRad"));
        assert!(c.has("quick"));
        assert_eq!(c.flag("quick"), Some("true"));
    }

    #[test]
    fn positional_args() {
        let c = Cli::parse(&args(&["figure", "11"])).unwrap();
        assert_eq!(c.command, "figure");
        assert_eq!(c.positional, vec!["11"]);
    }

    #[test]
    fn numeric_flags() {
        let c = Cli::parse(&args(&["run", "--measure", "10_000"])).unwrap();
        assert_eq!(c.flag_u64("measure").unwrap(), Some(10_000));
        assert!(Cli::parse(&args(&["run", "--measure", "ten"]))
            .unwrap()
            .flag_u64("measure")
            .is_err());
    }

    #[test]
    fn rejects_flag_first() {
        assert!(Cli::parse(&args(&["--oops", "run"])).is_err());
    }

    #[test]
    fn empty_is_ok() {
        let c = Cli::parse(&[]).unwrap();
        assert_eq!(c.command, "");
    }
}

//! Minimal benchmarking harness (criterion is unavailable offline).
//!
//! Provides warmup + repeated timing with median / MAD statistics and a
//! uniform row printer so every `cargo bench` target emits the same
//! machine-greppable format:
//!
//! ```text
//! fig09 | SPLRad           | speedup 2.05 | ...
//! bench | serve_remote     | median 412ns | mad 3ns | n 100
//! ```

use std::time::Instant;

/// Timing summary of one benchmarked closure.
#[derive(Clone, Copy, Debug)]
pub struct Timing {
    pub median_ns: f64,
    pub mad_ns: f64,
    pub min_ns: f64,
    pub iters: usize,
}

/// Time `f` with `warmup` throwaway calls and `iters` measured calls.
pub fn time<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(f64::total_cmp);
    let median = samples[samples.len() / 2];
    let mut devs: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
    devs.sort_by(f64::total_cmp);
    Timing {
        median_ns: median,
        mad_ns: devs[devs.len() / 2],
        min_ns: samples[0],
        iters: samples.len(),
    }
}

/// Human-scale formatting for nanosecond values.
pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.2}s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.2}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.2}us", ns / 1e3)
    } else {
        format!("{ns:.0}ns")
    }
}

/// Print one benchmark row.
#[allow(clippy::print_stdout)] // bench output is this harness's product
pub fn report(target: &str, name: &str, t: &Timing) {
    println!(
        "bench | {:<28} | {:<20} | median {} | mad {} | min {} | n {}",
        target,
        name,
        fmt_ns(t.median_ns),
        fmt_ns(t.mad_ns),
        fmt_ns(t.min_ns),
        t.iters
    );
}

/// Print a figure-table row (figure benches share this shape).
#[allow(clippy::print_stdout)] // bench output is this harness's product
pub fn row(figure: &str, label: &str, cols: &[(&str, f64)]) {
    let mut line = format!("{figure} | {label:<12}");
    for (k, v) in cols {
        line.push_str(&format!(" | {k} {v:.4}"));
    }
    println!("{line}");
}

/// A tiny CSV writer for figure data (plotted offline if desired).
pub struct Csv {
    rows: Vec<String>,
}

impl Csv {
    pub fn new(header: &str) -> Self {
        Csv { rows: vec![header.to_string()] }
    }

    pub fn push(&mut self, cells: &[String]) {
        self.rows.push(cells.join(","));
    }

    pub fn write(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.rows.join("\n") + "\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_measures_something() {
        let t = time(1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(t.median_ns >= 0.0);
        assert_eq!(t.iters, 5);
        assert!(t.min_ns <= t.median_ns);
    }

    #[test]
    fn fmt_scales() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1500.0), "1.50us");
        assert_eq!(fmt_ns(2.5e6), "2.50ms");
        assert_eq!(fmt_ns(3.2e9), "3.20s");
    }

    #[test]
    fn csv_accumulates() {
        let mut c = Csv::new("a,b");
        c.push(&["1".into(), "2".into()]);
        assert_eq!(c.rows.len(), 2);
    }
}

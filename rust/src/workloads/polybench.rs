//! PolyBench: the dense linear-algebra and stencil kernels of Table III.
//!
//! The linear-algebra group is the paper's cautionary tale: GEMM-shaped
//! kernels re-read a *shared* B panel from every core, so always-subscribe
//! turns each panel block into a resubscription ping-pong ball — Fig 9
//! reports up to −17% for PLYgemm / PLY3mm. The adaptive policy's whole
//! job is to detect that and disable subscription (Fig 11). The stencils
//! are private-slab sweeps with modest neighbour reuse.

use super::engines::{SharedPanel, StencilSweep, TiledReuse};
use super::Workload;

/// Panel of 4096 blocks = 256 KiB: 8x the 32 KiB L1, so panel reuse is
/// post-L1 and visible to the subscription machinery.
const PANEL: u64 = 4096;

/// `C = alpha*A*B + beta*C` — shared B panel, streamed A/C rows, 2 FLOPs
/// per element between accesses.
pub fn gemm(n_cores: u16) -> Box<dyn Workload> {
    Box::new(SharedPanel::new("PLYgemm", PANEL, 4, 0.25, 10, 1 << 18, n_cores))
}

/// Three chained multiplies: E=A·B, F=C·D, G=E·F. Same shared-panel shape
/// as gemm with a bigger combined panel and more of the stream written
/// back (intermediates E, F).
pub fn mm3(n_cores: u16) -> Box<dyn Workload> {
    Box::new(SharedPanel::new("PLY3mm", PANEL * 2, 4, 0.4, 10, 1 << 18, n_cores))
}

/// Multi-resolution analysis kernel: `sum(r,q,p) += A[r][q][s]*C4[s][p]`.
/// Each core's r-slice re-reads its working block of the coefficient
/// tensor many times — per-core blocked reuse over evenly-interleaved
/// homes. The 640-block working set is why Fig 16 shows doitgen gaining
/// with larger subscription tables: it thrashes a 1024-entry table and
/// fits larger ones.
pub fn doitgen(n_cores: u16) -> Box<dyn Workload> {
    Box::new(TiledReuse::new("PLYDoitgen", 640, 6, 1, 32, 0.15, 8, 2, 0, n_cores))
}

/// gemver: `B = A + u1*v1' + u2*v2'; x = B'*y; w = B*x` — streaming matrix
/// sweeps plus re-read vectors. Vectors (per-core tiles, contiguous so
/// homes are balanced) carry the reuse.
pub fn gemver(n_cores: u16) -> Box<dyn Workload> {
    Box::new(TiledReuse::new("PLYgemver", 640, 3, 1, 32, 0.3, 8, 2, 0, n_cores))
}

/// Gram-Schmidt: repeated passes over the growing basis — per-core tiles
/// revisited many times, contiguous (balanced homes).
pub fn gramschmidt(n_cores: u16) -> Box<dyn Workload> {
    Box::new(TiledReuse::new("PLYGramSch", 768, 6, 1, 32, 0.2, 8, 2, 0, n_cores))
}

/// Symmetric multiply: triangular access re-reads both operand panels;
/// moderate shared reuse.
pub fn symm(n_cores: u16) -> Box<dyn Workload> {
    Box::new(SharedPanel::new("PLYSymm", PANEL, 3, 0.3, 10, 1 << 18, n_cores))
}

/// 2-D convolution: 3x3 stencil over a private slab. Row length of 768
/// blocks (48 KiB) exceeds L1, so the north/south neighbour rows are
/// re-fetched from memory on every sweep.
pub fn conv2d(n_cores: u16) -> Box<dyn Workload> {
    Box::new(StencilSweep::new("PLYcon2d", 768, 64, vec![-1, 0, 1], true, 8, n_cores))
}

/// 2-D FDTD: three field arrays swept with neighbour access — same slab
/// shape as conv2d with an extra row-delta and heavier writes.
pub fn fdtd2d(n_cores: u16) -> Box<dyn Workload> {
    Box::new(StencilSweep::new("PLYdtd", 768, 64, vec![-1, 0, 0, 1], true, 8, n_cores))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_reads_shared_panel_from_all_cores() {
        let mut w = gemm(4);
        w.reset(0);
        let mut shared = 0;
        for core in 0..4u16 {
            for _ in 0..50 {
                let op = w.next_op(core).unwrap();
                if op.addr < super::super::layout::core_region(0, 0) {
                    shared += 1;
                }
            }
        }
        assert!(shared > 100, "panel reads must dominate, got {shared}");
    }

    #[test]
    fn conv2d_touches_three_rows_per_block() {
        let mut w = conv2d(1);
        w.reset(0);
        let ops: Vec<_> = (0..4).map(|_| w.next_op(0).unwrap()).collect();
        let rows: std::collections::HashSet<u64> =
            ops.iter().take(3).map(|o| o.addr / (768 * 64)).collect();
        assert!(rows.len() >= 2, "stencil must span rows");
        assert!(ops[3].write);
    }
}

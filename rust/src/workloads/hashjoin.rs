//! Hashjoin kernels (Balkesen et al., Table III).
//!
//! * **NPO / ProbeHashTable** — no-partitioning join probe: every tuple
//!   hashes to a uniformly random bucket of a table far larger than L1.
//!   No reuse, perfectly balanced: subscription is pure overhead and the
//!   count-table ablation (fig17) uses this workload as its control.
//! * **PRH / HistogramJoin** — partitioned radix histogram build: tuples
//!   scatter into per-partition histograms whose pages alias onto a small
//!   group of vaults (power-of-two partition strides), giving the burst
//!   imbalance the paper observes.

use super::engines::{RandomTable, TiledReuse};
use super::Workload;

/// Probe table: 2^21 blocks = 128 MiB.
const TABLE_BLOCKS: u64 = 1 << 21;

/// NPO probe: uniform random bucket reads mixed with a streaming tuple
/// fetch per probe.
pub fn npo(n_cores: u16) -> Box<dyn Workload> {
    Box::new(RandomTable::new("HSJNPO", TABLE_BLOCKS, false, 0.05, 1, 8, n_cores))
}

/// PRH histogram build: per-core partitions of 512 blocks revisited as
/// tuples accumulate, with a 512-block tuple stream between passes,
/// strided so partition headers share home vaults (vault_spread = 8:
/// 4 cores x 512 = 2048 active entries per hot vault).
pub fn prh(n_cores: u16) -> Box<dyn Workload> {
    Box::new(TiledReuse::new("HSJPRH", 512, 3, 32, 8, 0.6, 6, 8, 512, n_cores))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn npo_probes_are_read_mostly() {
        let mut w = npo(2);
        w.reset(0);
        let writes = (0..1000).filter(|_| w.next_op(0).unwrap().write).count();
        assert!(writes < 100, "NPO probes mostly read, got {writes} writes");
    }

    #[test]
    fn prh_is_write_heavy() {
        let mut w = prh(2);
        w.reset(0);
        // Tile passes are 60% writes; the interleaved tuple stream is
        // read-only, so ~30% of all ops write — far above NPO's 5%.
        let writes = (0..1000).filter(|_| w.next_op(0).unwrap().write).count();
        assert!(writes > 200, "histogram build writes, got {writes}");
    }
}

//! The workload catalog: Table III of the paper, name → generator.

use super::{chai, darknet, hashjoin, ligra, phoenix, polybench, rodinia, splash, stream};
use super::Workload;
use crate::config::SimConfig;

/// One Table III row.
#[derive(Clone, Copy, Debug)]
pub struct CatalogEntry {
    pub suite: &'static str,
    pub benchmark: &'static str,
    pub function: &'static str,
    pub short: &'static str,
}

/// All 31 representative workloads, in Table III order.
pub const TABLE3: [CatalogEntry; 31] = [
    CatalogEntry { suite: "Chai", benchmark: "Bezier Surface", function: "Bezier", short: "CHABsBez" },
    CatalogEntry { suite: "Chai", benchmark: "Padding", function: "Padding", short: "CHAOpad" },
    CatalogEntry { suite: "Darknet", benchmark: "Yolo", function: "gemm_nn", short: "DRKYolo" },
    CatalogEntry { suite: "Hashjoin", benchmark: "NPO", function: "ProbeHashTable", short: "HSJNPO" },
    CatalogEntry { suite: "Hashjoin", benchmark: "PRH", function: "HistogramJoin", short: "HSJPRH" },
    CatalogEntry { suite: "Ligra", benchmark: "Betweenness Centrality", function: "EdgeMapSparse (USA)", short: "LIGBcEms" },
    CatalogEntry { suite: "Ligra", benchmark: "Breadth-First Search", function: "EdgeMapSparse (USA)", short: "LIGBfsEms" },
    CatalogEntry { suite: "Ligra", benchmark: "BFS Connected Components", function: "EdgeMapSparse (USA)", short: "LIGConCEms" },
    CatalogEntry { suite: "Ligra", benchmark: "PageRank", function: "EdgeMapDense (USA)", short: "LIGPrkEmd" },
    CatalogEntry { suite: "Ligra", benchmark: "Triangle", function: "EdgeMapDense (Rmat)", short: "LIGTriEmd" },
    CatalogEntry { suite: "Phoenix", benchmark: "Linear Regression", function: "linear_regression_map", short: "PHELinReg" },
    CatalogEntry { suite: "PolyBench", benchmark: "Linear Algebra", function: "3 Matrix Multiplications", short: "PLY3mm" },
    CatalogEntry { suite: "PolyBench", benchmark: "Linear Algebra", function: "Multi-resolution analysis kernel", short: "PLYDoitgen" },
    CatalogEntry { suite: "PolyBench", benchmark: "Linear Algebra", function: "C=alpha.A.B+beta.C", short: "PLYgemm" },
    CatalogEntry { suite: "PolyBench", benchmark: "Linear Algebra", function: "Vector Mult. and Matrix Addition", short: "PLYgemver" },
    CatalogEntry { suite: "PolyBench", benchmark: "Linear Algebra", function: "Gram-Schmidt decomposition", short: "PLYGramSch" },
    CatalogEntry { suite: "PolyBench", benchmark: "Linear Algebra", function: "Symmetric matrix-multiply", short: "PLYSymm" },
    CatalogEntry { suite: "PolyBench", benchmark: "Stencil", function: "2D Convolution", short: "PLYcon2d" },
    CatalogEntry { suite: "PolyBench", benchmark: "Stencil", function: "2-D Finite Different Time Domain", short: "PLYdtd" },
    CatalogEntry { suite: "Rodinia", benchmark: "BFS", function: "BFSGraph", short: "RODBfs" },
    CatalogEntry { suite: "Rodinia", benchmark: "Needleman-Wunsch", function: "runTest", short: "RODNw" },
    CatalogEntry { suite: "SPLASH2", benchmark: "FFT", function: "Reverse", short: "SPLFftRev" },
    CatalogEntry { suite: "SPLASH2", benchmark: "FFT", function: "Transpose", short: "SPLFftTra" },
    CatalogEntry { suite: "SPLASH2", benchmark: "Oceanncp", function: "jacobcalc", short: "SPLOcnpJac" },
    CatalogEntry { suite: "SPLASH2", benchmark: "Oceanncp", function: "laplaccalc", short: "SPLOcnpLap" },
    CatalogEntry { suite: "SPLASH2", benchmark: "Oceancp", function: "slave2", short: "SPLOcpSlave" },
    CatalogEntry { suite: "SPLASH2", benchmark: "Radix", function: "slave_sort", short: "SPLRad" },
    CatalogEntry { suite: "STREAM", benchmark: "Add", function: "Add", short: "STRAdd" },
    CatalogEntry { suite: "STREAM", benchmark: "Copy", function: "Copy", short: "STRCpy" },
    CatalogEntry { suite: "STREAM", benchmark: "Scale", function: "Scale", short: "STRSca" },
    CatalogEntry { suite: "STREAM", benchmark: "Triad", function: "Triad", short: "STRTriad" },
];

/// Short names only, in Table III order.
pub const ALL_NAMES: [&str; 31] = [
    "CHABsBez", "CHAOpad", "DRKYolo", "HSJNPO", "HSJPRH", "LIGBcEms", "LIGBfsEms",
    "LIGConCEms", "LIGPrkEmd", "LIGTriEmd", "PHELinReg", "PLY3mm", "PLYDoitgen",
    "PLYgemm", "PLYgemver", "PLYGramSch", "PLYSymm", "PLYcon2d", "PLYdtd", "RODBfs",
    "RODNw", "SPLFftRev", "SPLFftTra", "SPLOcnpJac", "SPLOcnpLap", "SPLOcpSlave",
    "SPLRad", "STRAdd", "STRCpy", "STRSca", "STRTriad",
];

/// The workloads the paper's Fig 11/12/14 focus on: "non-negligible data
/// reuse" (§IV-B1). Derived from our Fig 10 reuse measurements; kept in
/// sync by the `selected_have_reuse` integration test.
pub const SELECTED: [&str; 14] = [
    "CHABsBez", "DRKYolo", "LIGTriEmd", "PHELinReg", "PLY3mm", "PLYDoitgen",
    "PLYgemm", "PLYgemver", "PLYGramSch", "PLYSymm", "PLYcon2d", "PLYdtd", "RODNw",
    "SPLRad",
];

/// Build a workload generator by Table III short name.
pub fn build(short: &str, cfg: &SimConfig) -> Option<Box<dyn Workload>> {
    let n = cfg.n_vaults;
    Some(match short {
        "CHABsBez" => chai::bezier(n),
        "CHAOpad" => chai::padding(n),
        "DRKYolo" => darknet::yolo(n),
        "HSJNPO" => hashjoin::npo(n),
        "HSJPRH" => hashjoin::prh(n),
        "LIGBcEms" => ligra::bc_ems(n),
        "LIGBfsEms" => ligra::bfs_ems(n),
        "LIGConCEms" => ligra::components_ems(n),
        "LIGPrkEmd" => ligra::pagerank_emd(n),
        "LIGTriEmd" => ligra::triangle_emd(n),
        "PHELinReg" => phoenix::linreg(n),
        "PLY3mm" => polybench::mm3(n),
        "PLYDoitgen" => polybench::doitgen(n),
        "PLYgemm" => polybench::gemm(n),
        "PLYgemver" => polybench::gemver(n),
        "PLYGramSch" => polybench::gramschmidt(n),
        "PLYSymm" => polybench::symm(n),
        "PLYcon2d" => polybench::conv2d(n),
        "PLYdtd" => polybench::fdtd2d(n),
        "RODBfs" => rodinia::bfs(n),
        "RODNw" => rodinia::nw(n),
        "SPLFftRev" => splash::fft_reverse(n),
        "SPLFftTra" => splash::fft_transpose(n),
        "SPLOcnpJac" => splash::ocean_jacob(n),
        "SPLOcnpLap" => splash::ocean_laplace(n),
        "SPLOcpSlave" => splash::ocean_slave(n),
        "SPLRad" => splash::radix(n),
        "STRAdd" => stream::add(n),
        "STRCpy" => stream::copy(n),
        "STRSca" => stream::scale(n),
        "STRTriad" => stream::triad(n),
        _ => return None,
    })
}

/// Table III entry for a short name.
pub fn entry(short: &str) -> Option<&'static CatalogEntry> {
    TABLE3.iter().find(|e| e.short == short)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_table3_row_builds() {
        let cfg = SimConfig::hmc();
        for e in &TABLE3 {
            let w = build(e.short, &cfg);
            assert!(w.is_some(), "{} missing", e.short);
            assert_eq!(w.unwrap().name(), e.short);
        }
    }

    #[test]
    fn names_match_table() {
        assert_eq!(TABLE3.len(), 31);
        assert_eq!(ALL_NAMES.len(), 31);
        for (e, n) in TABLE3.iter().zip(ALL_NAMES.iter()) {
            assert_eq!(e.short, *n);
        }
    }

    #[test]
    fn selected_is_subset() {
        for s in SELECTED {
            assert!(ALL_NAMES.contains(&s), "{s} not in catalog");
        }
    }

    #[test]
    fn unknown_name_is_none() {
        assert!(build("NOPE", &SimConfig::hmc()).is_none());
    }

    #[test]
    fn builds_for_hbm_core_count() {
        let cfg = SimConfig::hbm();
        let mut w = build("SPLRad", &cfg).unwrap();
        w.reset(0);
        for c in 0..8u16 {
            assert!(w.next_op(c).is_some());
        }
    }
}

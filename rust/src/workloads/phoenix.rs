//! Phoenix linear regression (Table III): `linear_regression_map`.
//!
//! The paper's highest-CoV workload (Fig 3) and one of DL-PIM's biggest
//! winners. The map phase processes point chunks whose struct layout
//! strides align with the vault interleave, so each core's working chunk
//! homes onto a *single* vault — and all cores' chunks alias onto the same
//! few vaults. The hot vaults drown in queuing (70–80% of latency, Fig 1);
//! subscribing each core's chunk to its own vault both localizes the reuse
//! and flattens the CoV (Figs 12/13), which is why PHELinReg's traffic
//! actually *drops* below baseline under DL-PIM (Fig 14).

use super::engines::TiledReuse;
use super::Workload;

/// Map over point chunks: 224-block hot chunks revisited 5x (x, y, xx,
/// yy, xy accumulations) with a 448-block point-stream between passes
/// (the input scan, which also flushes the L1 so chunk reuse is post-L1).
/// Struct-stride aliasing homes every chunk on ONE vault (spread = 1):
/// 32 cores x 224 blocks = 7168 active entries — inside the hot vault's
/// 8192-entry table, as the real working set must be for DL-PIM to win.
pub fn linreg(n_cores: u16) -> Box<dyn Workload> {
    Box::new(TiledReuse::new("PHELinReg", 224, 5, 32, 1, 0.1, 6, 12, 448, n_cores))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::sim::AddressMap;

    #[test]
    fn all_accesses_alias_one_vault() {
        let cfg = SimConfig::hmc();
        let map = AddressMap::new(&cfg);
        let mut w = linreg(8);
        w.reset(0);
        let mut homes = std::collections::HashSet::new();
        for core in 0..8u16 {
            for _ in 0..100 {
                homes.insert(map.home_of(w.next_op(core).unwrap().addr));
            }
        }
        assert_eq!(homes.len(), 1, "PHELinReg must hammer one vault");
    }
}

//! The 31 DAMOV-representative workloads (Table III) as deterministic,
//! seeded memory-traffic generators.
//!
//! Each generator reproduces the *traffic properties* of its kernel's loop
//! nest — the properties the paper's results hinge on:
//!
//! * **stream vs. reuse** — how often a block returns after leaving the
//!   L1 (drives Fig 10 and who benefits in Fig 9);
//! * **sharing** — whether post-L1 reuse comes from one core (subscription
//!   wins) or many cores (resubscription thrash, the Fig 9 losers);
//! * **home-vault imbalance** — strided layouts that alias onto few vaults
//!   (drives the CoV of Figs 3/4 and the big winners SPLRad / CHABsBez /
//!   PHELinReg).
//!
//! Generators are infinite streams (the driver stops at the configured
//! request budget); `reset(seed)` restarts them for the 5-run methodology.

pub mod catalog;
pub mod engines;

pub mod chai;
pub mod darknet;
pub mod hashjoin;
pub mod ligra;
pub mod phoenix;
pub mod polybench;
pub mod rodinia;
pub mod splash;
pub mod stream;

use crate::CoreId;

/// One operation emitted by a workload for one core.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Op {
    /// Byte address touched.
    pub addr: u64,
    /// Store (true) or load (false).
    pub write: bool,
    /// Compute cycles the core spends *before* this access (models the
    /// kernel's arithmetic between memory operations).
    pub gap: u32,
}

impl Op {
    pub fn read(addr: u64, gap: u32) -> Self {
        Op { addr, write: false, gap }
    }

    pub fn store(addr: u64, gap: u32) -> Self {
        Op { addr, write: true, gap }
    }
}

/// A multi-core memory-traffic generator.
pub trait Workload: Send {
    /// Table III short name (e.g. "SPLRad").
    fn name(&self) -> &'static str;
    /// Next operation for `core`, or `None` if this core's stream ended.
    fn next_op(&mut self, core: CoreId) -> Option<Op>;
    /// Restart the stream for a new run with a new seed.
    fn reset(&mut self, seed: u64);
}

/// Boxed workloads forward the trait, so wrappers like
/// [`crate::trace::Recording`] can tee a `catalog::build` result without
/// knowing the concrete generator type.
impl Workload for Box<dyn Workload> {
    fn name(&self) -> &'static str {
        (**self).name()
    }

    fn next_op(&mut self, core: CoreId) -> Option<Op> {
        (**self).next_op(core)
    }

    fn reset(&mut self, seed: u64) {
        (**self).reset(seed)
    }
}

/// Unknown-workload error with a nearest-name suggestion from the
/// Table III catalog (so `--workload SPLRod` points at `SPLRad` instead
/// of failing bare).
pub fn unknown_workload_message(name: &str) -> String {
    let hint = match crate::cli::suggest(name, catalog::ALL_NAMES.iter().copied()) {
        Some(s) => format!("; did you mean {s:?}?"),
        None => String::new(),
    };
    format!("unknown workload {name:?}{hint} (run `repro workloads` for the Table III list)")
}

/// Build the traffic source for one run: the replayed trace when the
/// config names one (`cfg.trace`), otherwise the Table III generator
/// `name`. This is the single dispatch point the CLI and the sweep engine
/// share, so trace-backed jobs flow through every existing figure and
/// policy unchanged.
pub fn build_source(
    name: Option<&str>,
    cfg: &crate::config::SimConfig,
) -> Result<Box<dyn Workload>, String> {
    if let Some(path) = &cfg.trace {
        let data = crate::trace::TraceData::load(std::path::Path::new(path))?;
        if data.meta.n_cores != cfg.n_vaults {
            return Err(format!(
                "trace {path} was recorded for {} cores but the config has {} vaults; \
                 re-home it with `repro trace remap {path} OUT --vaults {}`",
                data.meta.n_cores, cfg.n_vaults, cfg.n_vaults
            ));
        }
        if data.meta.block_bytes != cfg.block_bytes {
            return Err(format!(
                "trace {path} uses {}-byte blocks but the config uses {} — block \
                 granularity must match for replay",
                data.meta.block_bytes, cfg.block_bytes
            ));
        }
        return Ok(Box::new(crate::trace::TraceWorkload::new(
            std::sync::Arc::new(data),
            cfg.trace_loop,
        )));
    }
    let name = name.ok_or("no traffic source: pass --workload NAME or --trace FILE")?;
    catalog::build(name, cfg).ok_or_else(|| unknown_workload_message(name))
}

/// Shared layout constants: per-structure base addresses spaced far apart
/// so structures never collide (the address space is virtual anyway — only
/// block→vault mapping matters).
pub mod layout {
    /// 1 GiB regions per logical array — large enough that an array
    /// partitioned across 32 cores (e.g. 32 x 16 MiB STREAM slices) never
    /// bleeds into the next region. The address space is virtual; only the
    /// block -> vault mapping matters.
    pub const REGION: u64 = 1 << 30;

    /// Region bases are staggered by one block per region index so that
    /// co-indexed elements of different arrays (a[i], b[i], c[i]) land on
    /// *different* home vaults — as real allocators' page offsets do —
    /// instead of conveying onto one vault per loop iteration.
    pub const fn region(i: u64) -> u64 {
        1 + i * (REGION + 64) // +1 keeps address 0 unused
    }

    /// Per-core private region `i` for core `c`.
    pub const fn core_region(c: u16, i: u64) -> u64 {
        region(64 + c as u64 * 8 + i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    /// Every catalog workload must be deterministic under reset and emit
    /// sane ops.
    #[test]
    fn all_workloads_deterministic_and_sane() {
        let cfg = SimConfig::hmc();
        for name in catalog::ALL_NAMES {
            let mut w1 = catalog::build(name, &cfg).unwrap();
            let mut w2 = catalog::build(name, &cfg).unwrap();
            w1.reset(42);
            w2.reset(42);
            for i in 0..2000 {
                let c = (i % cfg.n_vaults as u64) as u16;
                let a = w1.next_op(c);
                let b = w2.next_op(c);
                assert_eq!(a, b, "{name} nondeterministic at op {i}");
                if let Some(op) = a {
                    assert!(op.addr > 0, "{name} touched address 0");
                    assert!(op.gap < 100_000, "{name} absurd gap");
                }
            }
        }
    }

    #[test]
    fn reset_with_new_seed_changes_random_workloads() {
        let cfg = SimConfig::hmc();
        let mut w1 = catalog::build("HSJNPO", &cfg).unwrap();
        let mut w2 = catalog::build("HSJNPO", &cfg).unwrap();
        w1.reset(1);
        w2.reset(2);
        let a: Vec<_> = (0..100).map(|_| w1.next_op(0)).collect();
        let b: Vec<_> = (0..100).map(|_| w2.next_op(0)).collect();
        assert_ne!(a, b);
    }
}

//! Ligra graph kernels (Table III): sparse edge-map traversals on a
//! USA-road-shaped graph and dense iterations on an R-MAT graph.
//!
//! Road networks have near-uniform low degree — frontier expansion is
//! uniform random pointer chasing with almost no post-L1 reuse (the flat
//! Fig 9 middle). R-MAT graphs have hub vertices: triangle counting
//! re-reads hub adjacency lists constantly, concentrating demand on the
//! hubs' home vaults.

use super::engines::RandomTable;
use super::Workload;

/// USA-road vertex data: 2^22 blocks = 256 MiB spread over all vaults.
const ROAD_BLOCKS: u64 = 1 << 22;
/// R-MAT adjacency: smaller, hub-skewed.
const RMAT_BLOCKS: u64 = 1 << 18;

/// Betweenness centrality, EdgeMapSparse (USA): random vertex visits with
/// score writes.
pub fn bc_ems(n_cores: u16) -> Box<dyn Workload> {
    Box::new(RandomTable::new("LIGBcEms", ROAD_BLOCKS, false, 0.25, 1, 8, n_cores))
}

/// Breadth-first search, EdgeMapSparse (USA): visited-flag updates on a
/// uniform frontier.
pub fn bfs_ems(n_cores: u16) -> Box<dyn Workload> {
    Box::new(RandomTable::new("LIGBfsEms", ROAD_BLOCKS, false, 0.3, 1, 8, n_cores))
}

/// BFS-based connected components (USA): like BFS with heavier label
/// writes.
pub fn components_ems(n_cores: u16) -> Box<dyn Workload> {
    Box::new(RandomTable::new("LIGConCEms", ROAD_BLOCKS, false, 0.4, 1, 8, n_cores))
}

/// PageRank, EdgeMapDense (USA): every core streams its edge partition
/// while gathering from the shared rank vector — modelled as a zipf-less
/// random gather over a *smaller* vector with stream mix (the rank vector
/// is re-read every iteration: real, if scattered, reuse).
pub fn pagerank_emd(n_cores: u16) -> Box<dyn Workload> {
    Box::new(RandomTable::new("LIGPrkEmd", 1 << 14, false, 0.1, 2, 8, n_cores))
}

/// Triangle counting, EdgeMapDense (R-MAT): hub adjacency lists are
/// re-read from every core — zipf-hot blocks with real reuse.
pub fn triangle_emd(n_cores: u16) -> Box<dyn Workload> {
    Box::new(RandomTable::new("LIGTriEmd", RMAT_BLOCKS, true, 0.05, 1, 8, n_cores))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn road_kernels_have_negligible_block_reuse() {
        let mut w = bfs_ems(2);
        w.reset(7);
        let mut seen = std::collections::HashSet::new();
        let mut repeats = 0;
        for _ in 0..2000 {
            let op = w.next_op(0).unwrap();
            if !seen.insert(op.addr / 64) {
                repeats += 1;
            }
        }
        assert!(repeats < 20, "road graph should almost never repeat, got {repeats}");
    }

    #[test]
    fn triangle_reuses_hub_blocks() {
        let mut w = triangle_emd(2);
        w.reset(7);
        let mut seen = std::collections::HashSet::new();
        let mut repeats = 0;
        for _ in 0..4000 {
            let op = w.next_op(0).unwrap();
            if !seen.insert(op.addr / 64) {
                repeats += 1;
            }
        }
        assert!(repeats > 100, "hubs must repeat, got {repeats}");
    }
}

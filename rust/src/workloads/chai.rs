//! Chai heterogeneous kernels (Table III).
//!
//! * **Bezier Surface (CHABsBez)** — output tiles are computed from a
//!   block of control points re-read for every tile point; the control
//!   grid's row stride aliases onto a four-vault cluster. High CoV in
//!   Fig 3 and one of the workloads the paper calls out as benefiting from
//!   evenly-distributed demand (§III-D5).
//! * **Padding (CHAOpad)** — pure data relocation: read the source row,
//!   write the padded destination row. Streaming, no reuse, speedup ≈ 1.

use super::engines::{StreamArray, Streams, TiledReuse};
use super::Workload;

/// Bezier: 320-block control tiles revisited 6x (16 surface points per
/// control point at our block granularity) with a 384-block output-tile
/// stream between passes, aliased onto a 4-vault cluster (8 cores x 320 =
/// 2560 active entries per hot vault).
pub fn bezier(n_cores: u16) -> Box<dyn Workload> {
    Box::new(TiledReuse::new("CHABsBez", 320, 6, 32, 4, 0.15, 6, 8, 384, n_cores))
}

/// Padding: two disjoint streams, slightly different strides (the
/// destination rows are longer — that is the padding).
pub fn padding(n_cores: u16) -> Box<dyn Workload> {
    Box::new(Streams::new(
        "CHAOpad",
        vec![
            StreamArray { region: 4, stride: 64, write: false },
            StreamArray { region: 5, stride: 128, write: true },
        ],
        1 << 18,
        8,
        n_cores,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::sim::AddressMap;

    #[test]
    fn bezier_concentrates_on_four_vaults() {
        let cfg = SimConfig::hmc();
        let map = AddressMap::new(&cfg);
        let mut w = bezier(8);
        w.reset(0);
        let mut homes = std::collections::HashSet::new();
        for core in 0..8u16 {
            for _ in 0..200 {
                homes.insert(map.home_of(w.next_op(core).unwrap().addr));
            }
        }
        assert_eq!(homes.len(), 4);
    }

    #[test]
    fn padding_never_repeats_blocks() {
        let mut w = padding(2);
        w.reset(0);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..2000 {
            assert!(seen.insert(w.next_op(0).unwrap().addr));
        }
    }
}

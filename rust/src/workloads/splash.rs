//! SPLASH2 kernels (Table III).
//!
//! * **FFT Reverse (SPLFftRev)** — bit-reverse reorder: reads at
//!   bit-reversed indices (statistically uniform over the array), writes
//!   sequential. One touch per element: no reuse, balanced.
//! * **FFT Transpose (SPLFftTra)** — blocked transpose of a 2^k-square
//!   matrix: the column walk strides by a power-of-two row length, which
//!   aliases the entire column onto one vault — classic interleave
//!   pathology, high CoV with *zero* reuse (subscription cannot help;
//!   adaptive must bail).
//! * **Ocean ncp jacobcalc / laplacalc, Ocean cp slave2 (SPLOcnpJac /
//!   SPLOcnpLap / SPLOcpSlave)** — grid relaxations: 5-point stencils over
//!   private slabs with neighbour-row reuse.
//! * **Radix (SPLRad)** — `slave_sort`: per-core digit histograms + bucket
//!   scatter. The per-digit bucket arrays are page-strided so each core's
//!   buckets alias onto a two-vault cluster; counts are revisited for every
//!   key. The paper's single biggest winner (+105%, Fig 9).

use super::engines::{RandomTable, StencilSweep, StreamArray, Streams, TiledReuse};
use super::Workload;

/// FFT bit-reverse: statistically uniform reads over 2^20 blocks with
/// sequential writes — modelled as a uniform random read + streamed write
/// mix (one write per read via write_frac 0.5 on the probe stream).
pub fn fft_reverse(n_cores: u16) -> Box<dyn Workload> {
    Box::new(RandomTable::new("SPLFftRev", 1 << 20, false, 0.5, 1, 8, n_cores))
}

/// FFT transpose: column reads stride by the row length (2048 blocks ≡ 0
/// mod 32 ⇒ one vault per column walk), row writes sequential.
pub fn fft_transpose(n_cores: u16) -> Box<dyn Workload> {
    Box::new(Streams::new(
        "SPLFftTra",
        vec![
            // Column read: stride = one 2048-double row = 16 KiB = 256
            // blocks, a multiple of n_vaults: the column aliases one vault.
            StreamArray { region: 6, stride: 2048 * 8, write: false },
            // Row write: sequential.
            StreamArray { region: 7, stride: 64, write: true },
        ],
        1 << 16,
        8,
        n_cores,
    ))
}

/// Ocean jacobcalc: 5-point relaxation, long rows, read-heavy.
pub fn ocean_jacob(n_cores: u16) -> Box<dyn Workload> {
    Box::new(StencilSweep::new("SPLOcnpJac", 768, 64, vec![-1, 0, 1], true, 8, n_cores))
}

/// Ocean laplacalc: like jacobcalc with an extra in-row read pass.
pub fn ocean_laplace(n_cores: u16) -> Box<dyn Workload> {
    Box::new(StencilSweep::new("SPLOcnpLap", 768, 64, vec![-1, 0, 0, 1], true, 8, n_cores))
}

/// Ocean cp slave2: multi-grid worker — deeper stencil (two rows each
/// side), fewer writes.
pub fn ocean_slave(n_cores: u16) -> Box<dyn Workload> {
    Box::new(StencilSweep::new(
        "SPLOcpSlave",
        768,
        64,
        vec![-2, -1, 0, 1, 2],
        true,
        8,
        n_cores,
    ))
}

/// Radix slave_sort: per-core 320-block bucket tiles revisited 8x (digit
/// counting + scatter) with a 384-block key stream between passes,
/// page-strided onto a 2-vault cluster (16 cores x 320 = 5120 active
/// entries per hot vault — fits the 8192-entry table), write-heavy.
pub fn radix(n_cores: u16) -> Box<dyn Workload> {
    Box::new(TiledReuse::new("SPLRad", 320, 8, 32, 2, 0.5, 6, 4, 384, n_cores))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::sim::AddressMap;

    #[test]
    fn transpose_column_reads_alias_one_vault() {
        let cfg = SimConfig::hmc();
        let map = AddressMap::new(&cfg);
        let mut w = fft_transpose(2);
        w.reset(0);
        let mut read_homes = std::collections::HashSet::new();
        for _ in 0..200 {
            let op = w.next_op(0).unwrap();
            if !op.write {
                read_homes.insert(map.home_of(op.addr));
            }
        }
        assert_eq!(read_homes.len(), 1, "column walk must alias one vault");
    }

    #[test]
    fn radix_concentrates_on_two_vaults() {
        let cfg = SimConfig::hmc();
        let map = AddressMap::new(&cfg);
        let mut w = radix(8);
        w.reset(0);
        let mut homes = std::collections::HashSet::new();
        for core in 0..8u16 {
            for _ in 0..200 {
                homes.insert(map.home_of(w.next_op(core).unwrap().addr));
            }
        }
        assert_eq!(homes.len(), 2);
    }

    #[test]
    fn ocean_kernels_have_distinct_depths() {
        let mut j = ocean_jacob(1);
        let mut s = ocean_slave(1);
        j.reset(0);
        s.reset(0);
        let jr = (0..10).filter(|_| !j.next_op(0).unwrap().write).count();
        let sr = (0..10).filter(|_| !s.next_op(0).unwrap().write).count();
        assert!(sr > jr, "slave2 reads more neighbour rows");
    }
}

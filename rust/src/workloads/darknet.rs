//! Darknet YOLO (Table III): the `gemm_nn` inner loop of the conv layers.
//!
//! Same shared-B-panel shape as PLYgemm but with the smaller panel of a
//! conv-as-GEMM (kernel-patch matrix) and a higher compute gap (the FMA
//! chain per output element) — YOLO is more compute-bound, so its queuing
//! exposure is milder than PLYgemm's.

use super::engines::SharedPanel;
use super::Workload;

/// gemm_nn: 2048-block shared panel (128 KiB), 3 panel reads per stream
/// element, 20% writes (output feature maps), gap 12 (FMA chain).
pub fn yolo(n_cores: u16) -> Box<dyn Workload> {
    Box::new(SharedPanel::new("DRKYolo", 2048, 3, 0.2, 12, 1 << 18, n_cores))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn yolo_has_compute_gap() {
        let mut w = yolo(1);
        w.reset(0);
        assert_eq!(w.next_op(0).unwrap().gap, 12);
    }
}

//! STREAM (McCalpin): Add / Copy / Scale / Triad.
//!
//! Pure partitioned streaming — every block is touched exactly once per
//! sweep, so post-L1 reuse is zero and the interleaved layout spreads
//! demand perfectly. The paper's Fig 9 shows speedups ≈ 1.00 for all four:
//! subscription has nothing to exploit, and the adaptive policy must learn
//! to stay out of the way.

use super::engines::{StreamArray, Streams};
use super::Workload;

/// Elements per core per sweep (x 64 B ≈ 16 MiB/core: far beyond L1).
const ELEMS: u64 = 1 << 18;
/// Loads/stores plus index arithmetic between accesses (DAMOV in-order core).
const GAP: u32 = 8;

/// `c[i] = a[i] + b[i]`
pub fn add(n_cores: u16) -> Box<dyn Workload> {
    Box::new(Streams::new(
        "STRAdd",
        vec![
            StreamArray { region: 0, stride: 64, write: false },
            StreamArray { region: 1, stride: 64, write: false },
            StreamArray { region: 2, stride: 64, write: true },
        ],
        ELEMS,
        GAP,
        n_cores,
    ))
}

/// `c[i] = a[i]`
pub fn copy(n_cores: u16) -> Box<dyn Workload> {
    Box::new(Streams::new(
        "STRCpy",
        vec![
            StreamArray { region: 0, stride: 64, write: false },
            StreamArray { region: 2, stride: 64, write: true },
        ],
        ELEMS,
        GAP,
        n_cores,
    ))
}

/// `b[i] = s * c[i]`
pub fn scale(n_cores: u16) -> Box<dyn Workload> {
    Box::new(Streams::new(
        "STRSca",
        vec![
            StreamArray { region: 2, stride: 64, write: false },
            StreamArray { region: 1, stride: 64, write: true },
        ],
        ELEMS,
        GAP,
        n_cores,
    ))
}

/// `a[i] = b[i] + s * c[i]`
pub fn triad(n_cores: u16) -> Box<dyn Workload> {
    Box::new(Streams::new(
        "STRTriad",
        vec![
            StreamArray { region: 1, stride: 64, write: false },
            StreamArray { region: 2, stride: 64, write: false },
            StreamArray { region: 0, stride: 64, write: true },
        ],
        ELEMS,
        GAP,
        n_cores,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn triad_pattern_is_read_read_write() {
        let mut w = triad(2);
        w.reset(0);
        let ops: Vec<_> = (0..3).map(|_| w.next_op(0).unwrap()).collect();
        assert!(!ops[0].write && !ops[1].write && ops[2].write);
    }

    #[test]
    fn cores_are_partitioned() {
        let mut w = add(4);
        w.reset(0);
        let a = w.next_op(0).unwrap().addr;
        let b = w.next_op(1).unwrap().addr;
        assert!(a.abs_diff(b) >= ELEMS * 64 / 2, "slices must not overlap");
    }
}

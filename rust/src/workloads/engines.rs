//! Traffic-pattern engines: the five archetypes the 31 Table III workloads
//! instantiate.
//!
//! Every DAMOV-representative kernel reduces, for the purposes of this
//! paper's evaluation, to a combination of:
//!
//! * [`Streams`] — partitioned sequential sweeps (STREAM, padding, FFT
//!   permutations). Zero post-L1 reuse: subscription can neither help nor
//!   hurt much (the flat middle of Fig 9).
//! * [`TiledReuse`] — per-core working sets revisited several times, with
//!   a configurable *alias stride* and *vault spread* controlling how the
//!   tiles map onto home vaults. This is the archetype of the big DL-PIM
//!   winners (SPLRad, CHABsBez, PHELinReg): private reuse homed on a few
//!   overloaded vaults.
//! * [`SharedPanel`] — every core repeatedly walks one shared panel (GEMM's
//!   B matrix, PageRank's rank vector). Post-L1 reuse is *shared*, so
//!   always-subscribe bounces blocks between cores (resubscription thrash)
//!   — the Fig 9 losers (PLYgemm, PLY3mm).
//! * [`RandomTable`] — uniform or hub-skewed probes over a large table
//!   (hash joins, sparse graph traversals). Low reuse, balanced demand.
//! * [`StencilSweep`] — neighbour sweeps over a private slab (stencils,
//!   ocean, Needleman-Wunsch wavefronts). Post-L1 reuse between adjacent
//!   row sweeps.

use crate::rng::Rng;
use crate::workloads::{layout, Op, Workload};
use crate::CoreId;

const BLOCK: u64 = 64;

// ---------------------------------------------------------------------
// Streams
// ---------------------------------------------------------------------

/// One array of a streaming kernel.
#[derive(Clone, Copy, Debug)]
pub struct StreamArray {
    /// Region index (see [`layout::region`]).
    pub region: u64,
    /// Byte stride between consecutive elements (64 = one block per step;
    /// larger multiples of `n_vaults * 64` alias onto a single vault — the
    /// FFT-transpose pathology).
    pub stride: u64,
    pub write: bool,
}

/// Partitioned streaming: each core sweeps its own slice of each array,
/// touching the arrays round-robin at every position.
pub struct Streams {
    name: &'static str,
    arrays: Vec<StreamArray>,
    /// Positions per core before the sweep wraps.
    elems: u64,
    gap: u32,
    n_cores: u16,
    pos: Vec<u64>,
    arr: Vec<usize>,
}

impl Streams {
    pub fn new(
        name: &'static str,
        arrays: Vec<StreamArray>,
        elems: u64,
        gap: u32,
        n_cores: u16,
    ) -> Self {
        let n = n_cores as usize;
        Streams { name, arrays, elems, gap, n_cores, pos: vec![0; n], arr: vec![0; n] }
    }
}

impl Workload for Streams {
    fn name(&self) -> &'static str {
        self.name
    }

    fn next_op(&mut self, core: CoreId) -> Option<Op> {
        let c = core as usize;
        let a = self.arrays[self.arr[c]];
        let slice = self.elems * a.stride;
        // Wrap within the region: big-stride sweeps (FFT transpose columns)
        // legitimately revisit the same matrix, but must never walk into a
        // *different* array's region.
        let off = (core as u64 * slice + self.pos[c] * a.stride) % layout::REGION;
        let addr = layout::region(a.region) + off;
        self.arr[c] += 1;
        if self.arr[c] == self.arrays.len() {
            self.arr[c] = 0;
            self.pos[c] = (self.pos[c] + 1) % self.elems;
        }
        Some(Op { addr, write: a.write, gap: self.gap })
    }

    fn reset(&mut self, seed: u64) {
        // Desynchronize cores so lockstep vault convoys don't depend on the
        // seed being zero.
        let mut r = Rng::new(seed);
        for c in 0..self.n_cores as usize {
            self.pos[c] = r.below(self.elems);
            self.arr[c] = 0;
        }
    }
}

// ---------------------------------------------------------------------
// TiledReuse
// ---------------------------------------------------------------------

/// Per-core tiles revisited several times before moving on, optionally
/// interleaved with a private input stream between passes.
///
/// The pollution stream serves two purposes straight out of the real
/// kernels: it *is* the input scan (radix-sort keys, linear-regression
/// points), and it evicts the hot tile from the 32 KB L1 between passes so
/// the tile's reuse is post-L1 — visible to the subscription machinery —
/// without inflating the tile beyond the home vault's 8192-entry table
/// budget (tiles from all cores homed on one hot vault must fit it, or the
/// protocol thrashes on capacity unsubscriptions).
pub struct TiledReuse {
    name: &'static str,
    /// Blocks per tile.
    tile_blocks: u32,
    /// Sweeps over the tile before advancing to the next tile.
    revisits: u32,
    /// Spacing (in blocks) between consecutive blocks of a tile. A multiple
    /// of `n_vaults` homes the whole tile on a single vault.
    alias_stride: u64,
    /// How many distinct home vaults the per-core lanes spread across
    /// (1 = one global hot vault, `n_vaults` = balanced).
    vault_spread: u64,
    write_frac: f64,
    gap: u32,
    tiles_per_core: u64,
    /// Private streaming reads emitted after each tile pass (input scan /
    /// L1 pollution). `tile_blocks + pollute_blocks` > L1 blocks keeps the
    /// tile's inter-pass reuse in memory.
    pollute_blocks: u32,
    n_cores: u16,
    st: Vec<TrState>,
    rng: Vec<Rng>,
}

#[derive(Clone, Copy, Default)]
struct TrState {
    tile: u64,
    visit: u32,
    blk: u32,
    /// Remaining pollution ops in the current inter-pass stream burst.
    pollute_left: u32,
    /// Monotone cursor of the private input stream.
    stream_pos: u64,
}

impl TiledReuse {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: &'static str,
        tile_blocks: u32,
        revisits: u32,
        alias_stride: u64,
        vault_spread: u64,
        write_frac: f64,
        gap: u32,
        tiles_per_core: u64,
        pollute_blocks: u32,
        n_cores: u16,
    ) -> Self {
        let n = n_cores as usize;
        TiledReuse {
            name,
            tile_blocks,
            revisits,
            alias_stride,
            vault_spread: vault_spread.max(1),
            write_frac,
            gap,
            tiles_per_core: tiles_per_core.max(1),
            pollute_blocks,
            n_cores,
            st: vec![TrState::default(); n],
            rng: (0..n).map(|i| Rng::new(i as u64)).collect(),
        }
    }
}

impl Workload for TiledReuse {
    fn name(&self) -> &'static str {
        self.name
    }

    fn next_op(&mut self, core: CoreId) -> Option<Op> {
        let c = core as usize;
        // Inter-pass input stream (private, monotone: zero reuse).
        if self.st[c].pollute_left > 0 {
            let st = &mut self.st[c];
            st.pollute_left -= 1;
            let addr = layout::core_region(core, 3) + (st.stream_pos % (1 << 21)) * BLOCK;
            st.stream_pos += 1;
            return Some(Op::read(addr, self.gap));
        }
        let s = self.st[c];
        // Lane offset picks which home vault this core's tiles alias to.
        let lane = core as u64 % self.vault_spread;
        let logical = (core as u64 * self.tiles_per_core + s.tile) * self.tile_blocks as u64
            + s.blk as u64;
        let block = logical * self.alias_stride + lane;
        let addr = layout::region(8) + block * BLOCK;
        let write = self.rng[c].chance(self.write_frac);

        // Advance tile cursor.
        let st = &mut self.st[c];
        st.blk += 1;
        if st.blk == self.tile_blocks {
            st.blk = 0;
            st.visit += 1;
            st.pollute_left = self.pollute_blocks;
            if st.visit == self.revisits {
                st.visit = 0;
                st.tile = (st.tile + 1) % self.tiles_per_core;
            }
        }
        Some(Op { addr, write, gap: self.gap })
    }

    fn reset(&mut self, seed: u64) {
        let mut r = Rng::new(seed);
        for c in 0..self.n_cores as usize {
            self.st[c] = TrState {
                tile: r.below(self.tiles_per_core),
                visit: 0,
                blk: 0,
                pollute_left: 0,
                stream_pos: r.below(1 << 20),
            };
            self.rng[c] = Rng::new(seed ^ (c as u64) << 32);
        }
    }
}

// ---------------------------------------------------------------------
// SharedPanel
// ---------------------------------------------------------------------

/// GEMM-style traffic: stream private rows while repeatedly walking a
/// shared panel (matrix B / rank vector / coefficient table).
pub struct SharedPanel {
    name: &'static str,
    /// Shared panel size in blocks (must exceed L1 for post-L1 reuse).
    panel_blocks: u64,
    /// Panel reads between consecutive private-stream reads.
    panel_per_stream: u32,
    /// Fraction of private-stream accesses that are writes (matrix C).
    write_frac: f64,
    gap: u32,
    stream_elems: u64,
    n_cores: u16,
    stream_pos: Vec<u64>,
    panel_pos: Vec<u64>,
    phase: Vec<u32>,
    rng: Vec<Rng>,
}

impl SharedPanel {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: &'static str,
        panel_blocks: u64,
        panel_per_stream: u32,
        write_frac: f64,
        gap: u32,
        stream_elems: u64,
        n_cores: u16,
    ) -> Self {
        let n = n_cores as usize;
        SharedPanel {
            name,
            panel_blocks,
            panel_per_stream,
            write_frac,
            gap,
            stream_elems,
            n_cores,
            stream_pos: vec![0; n],
            panel_pos: vec![0; n],
            phase: vec![0; n],
            rng: (0..n).map(|i| Rng::new(i as u64)).collect(),
        }
    }
}

impl Workload for SharedPanel {
    fn name(&self) -> &'static str {
        self.name
    }

    fn next_op(&mut self, core: CoreId) -> Option<Op> {
        let c = core as usize;
        if self.phase[c] < self.panel_per_stream {
            // Shared panel walk (all cores touch the same region).
            let addr = layout::region(16) + (self.panel_pos[c] % self.panel_blocks) * BLOCK;
            self.panel_pos[c] += 1;
            self.phase[c] += 1;
            Some(Op::read(addr, self.gap))
        } else {
            // Private stream step (rows of A / C).
            self.phase[c] = 0;
            let addr = layout::core_region(core, 0) + (self.stream_pos[c] % self.stream_elems) * BLOCK;
            self.stream_pos[c] += 1;
            let write = self.rng[c].chance(self.write_frac);
            Some(Op { addr, write, gap: self.gap })
        }
    }

    fn reset(&mut self, seed: u64) {
        let mut r = Rng::new(seed);
        for c in 0..self.n_cores as usize {
            self.stream_pos[c] = r.below(self.stream_elems);
            self.panel_pos[c] = r.below(self.panel_blocks);
            self.phase[c] = 0;
            self.rng[c] = Rng::new(seed ^ 0xABCD ^ ((c as u64) << 24));
        }
    }
}

// ---------------------------------------------------------------------
// RandomTable
// ---------------------------------------------------------------------

/// Probe traffic over a large table, optionally hub-skewed (zipf-like),
/// mixed with a private input stream.
pub struct RandomTable {
    name: &'static str,
    table_blocks: u64,
    zipf: bool,
    write_frac: f64,
    /// Private streaming reads between probes (tuple fetches).
    stream_mix: u32,
    gap: u32,
    n_cores: u16,
    rng: Vec<Rng>,
    phase: Vec<u32>,
    stream_pos: Vec<u64>,
}

impl RandomTable {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: &'static str,
        table_blocks: u64,
        zipf: bool,
        write_frac: f64,
        stream_mix: u32,
        gap: u32,
        n_cores: u16,
    ) -> Self {
        let n = n_cores as usize;
        RandomTable {
            name,
            table_blocks,
            zipf,
            write_frac,
            stream_mix,
            gap,
            n_cores,
            rng: (0..n).map(|i| Rng::new(i as u64)).collect(),
            phase: vec![0; n],
            stream_pos: vec![0; n],
        }
    }
}

impl Workload for RandomTable {
    fn name(&self) -> &'static str {
        self.name
    }

    fn next_op(&mut self, core: CoreId) -> Option<Op> {
        let c = core as usize;
        if self.phase[c] < self.stream_mix {
            self.phase[c] += 1;
            let addr = layout::core_region(core, 1) + (self.stream_pos[c] % (1 << 20)) * BLOCK;
            self.stream_pos[c] += 1;
            return Some(Op::read(addr, self.gap));
        }
        self.phase[c] = 0;
        let r = &mut self.rng[c];
        let b = if self.zipf { r.zipfish(self.table_blocks) } else { r.below(self.table_blocks) };
        let write = r.chance(self.write_frac);
        Some(Op { addr: layout::region(32) + b * BLOCK, write, gap: self.gap })
    }

    fn reset(&mut self, seed: u64) {
        for c in 0..self.n_cores as usize {
            self.rng[c] = Rng::new(seed.wrapping_mul(0x9E37).wrapping_add(c as u64));
            self.phase[c] = 0;
            self.stream_pos[c] = self.rng[c].below(1 << 20);
        }
    }
}

// ---------------------------------------------------------------------
// StencilSweep
// ---------------------------------------------------------------------

/// Row sweeps over a private 2-D slab reading neighbour rows.
pub struct StencilSweep {
    name: &'static str,
    /// Blocks per row (≥ L1 blocks ⇒ vertical reuse reaches memory).
    row_blocks: u64,
    rows: u64,
    /// Row offsets read per cell-block (e.g. [-1, 0, 1] for a 5-point
    /// stencil collapsed to block granularity).
    deltas: Vec<i64>,
    /// Write the centre block after the reads.
    write_center: bool,
    gap: u32,
    n_cores: u16,
    st: Vec<StencilState>,
}

#[derive(Clone, Copy, Default)]
struct StencilState {
    row: u64,
    blk: u64,
    d: usize,
    wrote: bool,
}

impl StencilSweep {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: &'static str,
        row_blocks: u64,
        rows: u64,
        deltas: Vec<i64>,
        write_center: bool,
        gap: u32,
        n_cores: u16,
    ) -> Self {
        let n = n_cores as usize;
        StencilSweep {
            name,
            row_blocks,
            rows,
            deltas,
            write_center,
            gap,
            n_cores,
            st: vec![StencilState::default(); n],
        }
    }

    fn addr(&self, core: CoreId, row: i64, blk: u64) -> u64 {
        let row = row.rem_euclid(self.rows as i64) as u64;
        layout::core_region(core, 2) + (row * self.row_blocks + blk) * BLOCK
    }
}

impl Workload for StencilSweep {
    fn name(&self) -> &'static str {
        self.name
    }

    fn next_op(&mut self, core: CoreId) -> Option<Op> {
        let c = core as usize;
        let s = self.st[c];
        if s.d < self.deltas.len() {
            let addr = self.addr(core, s.row as i64 + self.deltas[s.d], s.blk);
            self.st[c].d += 1;
            return Some(Op::read(addr, self.gap));
        }
        if self.write_center && !s.wrote {
            let addr = self.addr(core, s.row as i64, s.blk);
            self.st[c].wrote = true;
            return Some(Op::store(addr, self.gap));
        }
        // Advance to the next block / row.
        let st = &mut self.st[c];
        st.d = 0;
        st.wrote = false;
        st.blk += 1;
        if st.blk == self.row_blocks {
            st.blk = 0;
            st.row = (st.row + 1) % self.rows;
        }
        self.next_op(core)
    }

    fn reset(&mut self, seed: u64) {
        let mut r = Rng::new(seed);
        for c in 0..self.n_cores as usize {
            self.st[c] =
                StencilState { row: r.below(self.rows), blk: 0, d: 0, wrote: false };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn streams_round_robin_arrays() {
        let mut w = Streams::new(
            "t",
            vec![
                StreamArray { region: 0, stride: 64, write: false },
                StreamArray { region: 1, stride: 64, write: true },
            ],
            1024,
            1,
            2,
        );
        w.reset(0);
        let a = w.next_op(0).unwrap();
        let b = w.next_op(0).unwrap();
        assert!(!a.write);
        assert!(b.write);
        assert_ne!(a.addr, b.addr);
    }

    #[test]
    fn streams_never_revisit_within_wrap() {
        let mut w = Streams::new(
            "t",
            vec![StreamArray { region: 0, stride: 64, write: false }],
            4096,
            1,
            1,
        );
        w.reset(0);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..4096 {
            assert!(seen.insert(w.next_op(0).unwrap().addr), "stream revisited");
        }
    }

    #[test]
    fn tiled_reuse_revisits_tile() {
        let mut w = TiledReuse::new("t", 16, 3, 1, 32, 0.0, 1, 4, 0, 2);
        w.reset(0);
        let first: Vec<u64> = (0..16).map(|_| w.next_op(0).unwrap().addr).collect();
        let second: Vec<u64> = (0..16).map(|_| w.next_op(0).unwrap().addr).collect();
        assert_eq!(first, second, "revisit must re-read the same blocks");
    }

    #[test]
    fn tiled_reuse_alias_stride_homes_one_vault() {
        // alias_stride = 32 = n_vaults, spread 1: every block ≡ lane mod 32.
        let mut w = TiledReuse::new("t", 8, 2, 32, 1, 0.0, 1, 4, 0, 4);
        w.reset(0);
        for core in 0..4u16 {
            for _ in 0..32 {
                let op = w.next_op(core).unwrap();
                assert_eq!((op.addr / 64) % 32, (layout::region(8) / 64) % 32);
            }
        }
    }

    #[test]
    fn tiled_reuse_spread_uses_n_lanes() {
        let mut w = TiledReuse::new("t", 8, 1, 32, 4, 0.0, 1, 4, 0, 8);
        w.reset(0);
        let mut lanes = std::collections::HashSet::new();
        for core in 0..8u16 {
            let op = w.next_op(core).unwrap();
            lanes.insert((op.addr / 64) % 32);
        }
        assert_eq!(lanes.len(), 4);
    }

    #[test]
    fn shared_panel_interleaves_shared_and_private() {
        let mut w = SharedPanel::new("t", 1024, 2, 0.5, 1, 4096, 2);
        w.reset(1);
        let ops: Vec<Op> = (0..6).map(|_| w.next_op(0).unwrap()).collect();
        // Pattern: panel, panel, stream, panel, panel, stream.
        let panel_base = layout::region(16);
        assert!(ops[0].addr >= panel_base && ops[0].addr < panel_base + 1024 * 64);
        assert!(ops[1].addr >= panel_base && ops[1].addr < panel_base + 1024 * 64);
        assert!(ops[2].addr >= layout::core_region(0, 0));
        assert!(!ops[0].write && !ops[1].write, "panel reads only");
    }

    #[test]
    fn shared_panel_is_shared_across_cores() {
        let mut w = SharedPanel::new("t", 64, 1, 0.0, 1, 4096, 2);
        w.reset(0);
        let a: std::collections::HashSet<u64> =
            (0..64).filter_map(|_| w.next_op(0)).map(|o| o.addr / 64).collect();
        let b: std::collections::HashSet<u64> =
            (0..64).filter_map(|_| w.next_op(1)).map(|o| o.addr / 64).collect();
        assert!(a.intersection(&b).count() > 0, "cores must share panel blocks");
    }

    #[test]
    fn random_table_stays_in_table() {
        let mut w = RandomTable::new("t", 1000, false, 0.2, 0, 1, 1);
        w.reset(0);
        let base = layout::region(32);
        for _ in 0..1000 {
            let op = w.next_op(0).unwrap();
            assert!(op.addr >= base && op.addr < base + 1000 * 64);
        }
    }

    #[test]
    fn zipf_table_skews_hot() {
        let mut w = RandomTable::new("t", 4096, true, 0.0, 0, 1, 1);
        w.reset(0);
        let mut low = 0;
        for _ in 0..2000 {
            let op = w.next_op(0).unwrap();
            if (op.addr - layout::region(32)) / 64 < 512 {
                low += 1;
            }
        }
        assert!(low > 700, "hubs must be hot, got {low}");
    }

    #[test]
    fn stencil_reads_neighbours_then_writes() {
        let mut w = StencilSweep::new("t", 8, 16, vec![-1, 0, 1], true, 1, 1);
        w.reset(0);
        let ops: Vec<Op> = (0..4).map(|_| w.next_op(0).unwrap()).collect();
        assert!(!ops[0].write && !ops[1].write && !ops[2].write);
        assert!(ops[3].write);
        // Centre read and write hit the same block.
        assert_eq!(ops[1].addr, ops[3].addr);
    }

    #[test]
    fn stencil_revisits_rows_across_sweeps() {
        let mut w = StencilSweep::new("t", 4, 4, vec![0, 1], false, 1, 1);
        w.reset(0);
        let mut addrs = Vec::new();
        for _ in 0..100 {
            addrs.push(w.next_op(0).unwrap().addr);
        }
        let unique: std::collections::HashSet<_> = addrs.iter().collect();
        assert!(unique.len() < addrs.len(), "rows must be revisited");
    }
}

//! Rodinia kernels (Table III).
//!
//! * **BFS (RODBfs)** — level-synchronous BFS over a uniform graph:
//!   random neighbour reads, frontier/cost writes, negligible reuse.
//! * **Needleman-Wunsch (RODNw)** — wavefront dynamic programming: each
//!   anti-diagonal cell reads its west/north/north-west neighbours. At
//!   block granularity that is a two-row stencil with real inter-sweep
//!   reuse on long rows.

use super::engines::{RandomTable, StencilSweep};
use super::Workload;

/// BFS over 2^21 graph blocks, 35% writes (cost + frontier updates).
pub fn bfs(n_cores: u16) -> Box<dyn Workload> {
    Box::new(RandomTable::new("RODBfs", 1 << 21, false, 0.35, 1, 8, n_cores))
}

/// NW wavefront: 640-block rows (40 KiB > L1), reads previous and current
/// row, writes the current cell block.
pub fn nw(n_cores: u16) -> Box<dyn Workload> {
    Box::new(StencilSweep::new("RODNw", 640, 48, vec![-1, 0], true, 8, n_cores))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nw_reads_two_rows() {
        let mut w = nw(1);
        w.reset(0);
        let a = w.next_op(0).unwrap();
        let b = w.next_op(0).unwrap();
        let c = w.next_op(0).unwrap();
        assert!(!a.write && !b.write && c.write);
        assert_ne!(a.addr / (640 * 64), b.addr / (640 * 64), "different rows");
    }
}

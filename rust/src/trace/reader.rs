//! Trace loading and replay: [`TraceData`] (a parsed, validated trace
//! file) and [`TraceWorkload`] (a [`Workload`] that replays it, so every
//! existing figure, policy and topology runs unchanged on recorded
//! traffic).

use std::path::Path;
use std::sync::Arc;

use super::varint;
use super::{intern, TraceMeta, MAGIC, VERSION};
use crate::workloads::{Op, Workload};
use crate::CoreId;

/// One core's encoded stream inside a loaded trace.
#[derive(Clone, Debug, Default)]
pub struct CoreTrace {
    pub ops: u64,
    bytes: Vec<u8>,
}

/// A parsed trace file: header metadata plus per-core encoded streams.
/// Every stream is fully decoded once at load time, so a malformed or
/// truncated file fails with a clear error here and replay-time decoding
/// cannot fail.
#[derive(Clone, Debug)]
pub struct TraceData {
    pub meta: TraceMeta,
    cores: Vec<CoreTrace>,
}

impl TraceData {
    /// Parse and validate a serialized trace.
    pub fn parse(bytes: &[u8]) -> Result<Self, String> {
        let mut pos = 0usize;
        let magic = take(bytes, &mut pos, 4, "magic")?;
        if magic != MAGIC {
            return Err(format!(
                "not a dlpim trace: bad magic {magic:02x?} (expected {MAGIC:02x?})"
            ));
        }
        // `take` returns exactly the requested byte count, so the array
        // conversions below cannot fail.
        let version =
            u16::from_le_bytes(take(bytes, &mut pos, 2, "version")?.try_into().expect("2 bytes"));
        if version != VERSION {
            return Err(format!(
                "unsupported trace version {version} (this build reads version {VERSION})"
            ));
        }
        let n_cores =
            u16::from_le_bytes(take(bytes, &mut pos, 2, "n_cores")?.try_into().expect("2 bytes"));
        let block_bytes = u32::from_le_bytes(
            take(bytes, &mut pos, 4, "block_bytes")?.try_into().expect("4 bytes"),
        );
        let config_hash = u64::from_le_bytes(
            take(bytes, &mut pos, 8, "config_hash")?.try_into().expect("8 bytes"),
        );
        let seed =
            u64::from_le_bytes(take(bytes, &mut pos, 8, "seed")?.try_into().expect("8 bytes"));
        let workload = read_str(bytes, &mut pos, "workload name")?;
        let mem = read_str(bytes, &mut pos, "memory kind")?;
        let topology = read_str(bytes, &mut pos, "topology")?;
        if n_cores == 0 {
            return Err("trace declares 0 cores".into());
        }

        let mut cores = Vec::with_capacity(n_cores as usize);
        for c in 0..n_cores {
            let ops = varint::read_u64(bytes, &mut pos)
                .map_err(|e| format!("core {c} op count: {e}"))?;
            let len = varint::read_u64(bytes, &mut pos)
                .map_err(|e| format!("core {c} stream length: {e}"))? as usize;
            let body = take(bytes, &mut pos, len, "core stream")
                .map_err(|e| format!("core {c}: {e}"))?;
            let core = CoreTrace { ops, bytes: body.to_vec() };
            // Validation decode: every op must decode and consume the
            // stream exactly, so replay never hits a codec error.
            let mut cur = Cursor::default();
            for i in 0..ops {
                decode_one(&core.bytes, &mut cur)
                    .map_err(|e| format!("core {c} op {i}: {e}"))?;
            }
            if cur.pos != core.bytes.len() {
                return Err(format!(
                    "core {c}: {} trailing bytes after {} ops",
                    core.bytes.len() - cur.pos,
                    ops
                ));
            }
            cores.push(core);
        }
        if pos != bytes.len() {
            return Err(format!("{} trailing bytes after last core section", bytes.len() - pos));
        }
        Ok(TraceData {
            meta: TraceMeta {
                workload,
                mem,
                topology,
                config_hash,
                seed,
                block_bytes,
                n_cores,
            },
            cores,
        })
    }

    /// Load and validate a trace file.
    pub fn load(path: &Path) -> Result<Self, String> {
        let bytes = std::fs::read(path).map_err(|e| format!("read {}: {e}", path.display()))?;
        Self::parse(&bytes).map_err(|e| format!("{}: {e}", path.display()))
    }

    pub fn n_cores(&self) -> u16 {
        self.meta.n_cores
    }

    /// Ops recorded for one core.
    pub fn core_ops(&self, core: u16) -> u64 {
        self.cores[core as usize].ops
    }

    /// Total ops across all cores.
    pub fn total_ops(&self) -> u64 {
        self.cores.iter().map(|c| c.ops).sum()
    }

    /// Decode one core's full stream (transforms and `trace info` use
    /// this; replay decodes incrementally instead).
    pub fn decode_core(&self, core: u16) -> Vec<Op> {
        let c = &self.cores[core as usize];
        let mut cur = Cursor::default();
        (0..c.ops)
            .map(|_| decode_one(&c.bytes, &mut cur).expect("validated at load"))
            .collect()
    }

    /// Serialized byte size (header excluded), for `trace info`.
    pub fn body_bytes(&self) -> usize {
        self.cores.iter().map(|c| c.bytes.len()).sum()
    }

    /// Serialize back to the on-disk format (streams are stored encoded,
    /// so this is a concatenation, not a re-encode).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64 + self.body_bytes() + self.cores.len() * 12);
        super::write_header(&mut out, &self.meta);
        for c in &self.cores {
            varint::write_u64(&mut out, c.ops);
            varint::write_u64(&mut out, c.bytes.len() as u64);
            out.extend_from_slice(&c.bytes);
        }
        out
    }

    /// Write to `path`, creating parent directories.
    pub fn save(&self, path: &Path) -> Result<(), String> {
        super::write_file(path, &self.to_bytes())
    }
}

fn take<'a>(bytes: &'a [u8], pos: &mut usize, n: usize, what: &str) -> Result<&'a [u8], String> {
    let end = pos.checked_add(n).filter(|&e| e <= bytes.len()).ok_or_else(|| {
        format!("truncated file: {what} needs {n} bytes at offset {pos}, file has {}", bytes.len())
    })?;
    let out = &bytes[*pos..end];
    *pos = end;
    Ok(out)
}

fn read_str(bytes: &[u8], pos: &mut usize, what: &str) -> Result<String, String> {
    let len =
        u16::from_le_bytes(take(bytes, pos, 2, what)?.try_into().expect("2 bytes")) as usize;
    let raw = take(bytes, pos, len, what)?;
    String::from_utf8(raw.to_vec()).map_err(|_| format!("{what} is not valid UTF-8"))
}

/// Incremental decode state of one core stream.
#[derive(Clone, Copy, Debug, Default)]
struct Cursor {
    pos: usize,
    last_addr: u64,
    emitted: u64,
}

fn decode_one(bytes: &[u8], cur: &mut Cursor) -> Result<Op, String> {
    let delta = varint::unzigzag(varint::read_u64(bytes, &mut cur.pos)?);
    let word = varint::read_u64(bytes, &mut cur.pos)?;
    let addr = cur.last_addr.wrapping_add(delta as u64);
    cur.last_addr = addr;
    cur.emitted += 1;
    let gap = word >> 1;
    if gap > u32::MAX as u64 {
        return Err(format!("gap {gap} overflows u32"));
    }
    Ok(Op { addr, write: word & 1 == 1, gap: gap as u32 })
}

/// A [`Workload`] that replays a loaded trace. Each core's cursor walks
/// its recorded stream; with `loop_around` the stream restarts when it
/// ends (delta base included), so a short trace can feed an arbitrarily
/// long measure window. `reset` rewinds to the beginning — the trace *is*
/// the randomness, so the seed is ignored and every run replays the
/// identical stream.
pub struct TraceWorkload {
    data: Arc<TraceData>,
    name: &'static str,
    cursors: Vec<Cursor>,
    loop_around: bool,
}

impl TraceWorkload {
    pub fn new(data: Arc<TraceData>, loop_around: bool) -> Self {
        let n = data.n_cores() as usize;
        TraceWorkload {
            name: intern(&format!("trace:{}", data.meta.workload)),
            data,
            cursors: vec![Cursor::default(); n],
            loop_around,
        }
    }

    /// Load a trace file into a boxed workload.
    pub fn open(path: &Path, loop_around: bool) -> Result<Box<dyn Workload>, String> {
        let data = TraceData::load(path)?;
        Ok(Box::new(TraceWorkload::new(Arc::new(data), loop_around)))
    }
}

impl Workload for TraceWorkload {
    fn name(&self) -> &'static str {
        self.name
    }

    fn next_op(&mut self, core: CoreId) -> Option<Op> {
        let c = core as usize;
        let stream = &self.data.cores[c];
        if self.cursors[c].emitted >= stream.ops {
            if !self.loop_around || stream.ops == 0 {
                return None;
            }
            self.cursors[c] = Cursor::default();
        }
        Some(decode_one(&stream.bytes, &mut self.cursors[c]).expect("validated at load"))
    }

    fn reset(&mut self, _seed: u64) {
        for c in &mut self.cursors {
            *c = Cursor::default();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::writer::TraceWriter;

    fn sample_writer() -> TraceWriter {
        let meta = TraceMeta {
            workload: "unit".into(),
            mem: "hmc".into(),
            topology: "mesh".into(),
            config_hash: 42,
            seed: 9,
            block_bytes: 64,
            n_cores: 2,
        };
        let mut w = TraceWriter::new(meta);
        for i in 0..100u64 {
            w.append(0, Op::read(4096 + i * 64, 8));
            w.append(1, Op { addr: 1 << 30, write: i % 3 == 0, gap: 2 });
        }
        w
    }

    #[test]
    fn write_parse_round_trips_ops_and_meta() {
        let w = sample_writer();
        let data = TraceData::parse(&w.finish()).unwrap();
        assert_eq!(data.meta.workload, "unit");
        assert_eq!(data.meta.seed, 9);
        assert_eq!(data.meta.config_hash, 42);
        assert_eq!(data.n_cores(), 2);
        assert_eq!(data.core_ops(0), 100);
        let ops = data.decode_core(0);
        assert_eq!(ops[0], Op::read(4096, 8));
        assert_eq!(ops[99], Op::read(4096 + 99 * 64, 8));
        let ops1 = data.decode_core(1);
        assert!(ops1[0].write && !ops1[1].write);
    }

    #[test]
    fn replay_matches_recorded_stream_and_ends() {
        let w = sample_writer();
        let data = Arc::new(TraceData::parse(&w.finish()).unwrap());
        let mut replay = TraceWorkload::new(data.clone(), false);
        for i in 0..100u64 {
            assert_eq!(replay.next_op(0), Some(Op::read(4096 + i * 64, 8)));
        }
        assert_eq!(replay.next_op(0), None, "non-looping stream must end");
        // Reset rewinds to the start, ignoring the seed.
        replay.reset(12345);
        assert_eq!(replay.next_op(0), Some(Op::read(4096, 8)));
    }

    #[test]
    fn loop_around_restarts_the_stream() {
        let w = sample_writer();
        let data = Arc::new(TraceData::parse(&w.finish()).unwrap());
        let mut replay = TraceWorkload::new(data, true);
        for _ in 0..100 {
            replay.next_op(0).unwrap();
        }
        assert_eq!(replay.next_op(0), Some(Op::read(4096, 8)), "wrap to op 0");
    }

    #[test]
    fn bad_magic_is_a_clear_error() {
        let err = TraceData::parse(b"NOPE....").unwrap_err();
        assert!(err.contains("bad magic"), "{err}");
    }

    #[test]
    fn future_version_is_rejected() {
        let mut bytes = sample_writer().finish();
        bytes[4] = 0xff; // version low byte
        let err = TraceData::parse(&bytes).unwrap_err();
        assert!(err.contains("unsupported trace version"), "{err}");
    }

    #[test]
    fn truncation_anywhere_is_an_error_not_a_panic() {
        let bytes = sample_writer().finish();
        for cut in [0, 3, 5, 10, 27, 30, bytes.len() / 2, bytes.len() - 1] {
            assert!(
                TraceData::parse(&bytes[..cut]).is_err(),
                "cut at {cut} must fail"
            );
        }
    }

    #[test]
    fn trailing_garbage_is_an_error() {
        let mut bytes = sample_writer().finish();
        bytes.extend_from_slice(b"junk");
        let err = TraceData::parse(&bytes).unwrap_err();
        assert!(err.contains("trailing"), "{err}");
    }
}

//! LEB128 varints + zigzag, the primitives of the trace body encoding.
//!
//! Addresses are stored as per-core deltas, and deltas of strided sweeps
//! are small signed numbers — zigzag folds them into small unsigned
//! numbers, and LEB128 stores those in one or two bytes instead of eight.

/// Append `v` as an LEB128 varint (7 data bits per byte, MSB = more).
#[inline]
pub fn write_u64(buf: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            buf.push(b);
            return;
        }
        buf.push(b | 0x80);
    }
}

/// Decode an LEB128 varint at `*pos`, advancing it. Errors (rather than
/// panicking) on truncation or a value overflowing 64 bits, so corrupt
/// trace files surface as messages.
pub fn read_u64(buf: &[u8], pos: &mut usize) -> Result<u64, String> {
    let mut v: u64 = 0;
    let mut shift = 0u32;
    loop {
        let Some(&b) = buf.get(*pos) else {
            return Err(format!("truncated varint at byte {}", *pos));
        };
        *pos += 1;
        if shift == 63 && b > 1 {
            return Err(format!("varint overflows u64 at byte {}", *pos - 1));
        }
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
        if shift > 63 {
            return Err(format!("varint longer than 10 bytes at byte {}", *pos - 1));
        }
    }
}

/// Zigzag-fold a signed delta so small negatives encode small.
#[inline]
pub fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

/// Invert [`zigzag`].
#[inline]
pub fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn varint_round_trips_edge_values() {
        for v in [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX - 1, u64::MAX] {
            let mut buf = Vec::new();
            write_u64(&mut buf, v);
            let mut pos = 0;
            assert_eq!(read_u64(&buf, &mut pos).unwrap(), v);
            assert_eq!(pos, buf.len(), "no trailing bytes for {v}");
        }
    }

    #[test]
    fn small_values_are_one_byte() {
        let mut buf = Vec::new();
        write_u64(&mut buf, 100);
        assert_eq!(buf.len(), 1);
    }

    #[test]
    fn truncated_varint_is_an_error() {
        let buf = [0x80u8, 0x80]; // continuation bits with no terminator
        let mut pos = 0;
        assert!(read_u64(&buf, &mut pos).is_err());
    }

    #[test]
    fn overlong_varint_is_an_error() {
        let buf = [0xffu8; 11];
        let mut pos = 0;
        assert!(read_u64(&buf, &mut pos).is_err());
    }

    #[test]
    fn zigzag_round_trips() {
        for v in [0i64, 1, -1, 64, -64, i64::MAX, i64::MIN, 12345, -98765] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
    }

    #[test]
    fn zigzag_keeps_small_deltas_small() {
        assert_eq!(zigzag(-1), 1);
        assert_eq!(zigzag(1), 2);
        assert_eq!(zigzag(-64), 127); // one varint byte
    }
}

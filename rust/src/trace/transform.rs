//! Trace transforms: compose recorded traces into new scenarios.
//!
//! * [`mix`] — interleave K traces as K tenants over one memory system:
//!   each output core is assigned a tenant (round-robin over a weighted
//!   pattern) and replays one of that tenant's recorded core streams, with
//!   the tenant's whole address space offset by a multiple of
//!   [`TENANT_OFFSET`] so tenants never share blocks while their home-vault
//!   *distributions* overlap — per-tenant hot vaults collide on the same
//!   physical vaults, which is exactly the contention a PIM serving many
//!   users sees and no single generator produces.
//! * [`dilate`] — scale compute gaps, modelling faster/slower cores over
//!   identical access sequences.
//! * [`remap`] — re-home blocks for a different vault count, folding or
//!   replicating core streams so a trace recorded on one geometry can
//!   drive another.

use super::reader::TraceData;
use super::writer::TraceWriter;
use super::TraceMeta;
use crate::workloads::Op;

/// Per-tenant address-space stride, bytes. A power of two far above any
/// generator's footprint: it keeps each tenant's block-index low bits —
/// and therefore its home-vault distribution — intact for any
/// power-of-two vault count.
pub const TENANT_OFFSET: u64 = 1 << 44;

/// Address salt for replicated streams in an upsizing [`remap`].
const CLONE_OFFSET: u64 = 1 << 52;

/// Encode per-core op streams under `meta` and re-parse: transforms build
/// their output through the real codec, so every produced trace is
/// guaranteed loadable.
fn rebuild(meta: TraceMeta, streams: Vec<Vec<Op>>) -> TraceData {
    debug_assert_eq!(meta.n_cores as usize, streams.len());
    let mut w = TraceWriter::new(meta);
    for (c, ops) in streams.iter().enumerate() {
        for &op in ops {
            w.append(c as u16, op);
        }
    }
    TraceData::parse(&w.finish()).expect("transform output must round-trip")
}

fn fnv(h: u64, v: u64) -> u64 {
    let mut h = h;
    for b in v.to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Interleave `inputs` as tenants. `weights[t]` is tenant `t`'s share of
/// the output cores (e.g. `[2, 1]` gives tenant 0 two cores out of every
/// three); `n_cores` is the output geometry's core/vault count. The `j`-th
/// output core assigned to tenant `t` replays the tenant's core
/// `j % tenant_cores` stream at offset `t * TENANT_OFFSET`.
pub fn mix(inputs: &[TraceData], weights: &[u64], n_cores: u16) -> Result<TraceData, String> {
    if inputs.len() < 2 {
        return Err(format!("mix needs at least 2 traces, got {}", inputs.len()));
    }
    if weights.len() != inputs.len() {
        return Err(format!(
            "{} weights for {} traces (need one per tenant)",
            weights.len(),
            inputs.len()
        ));
    }
    if weights.iter().any(|&w| w == 0) {
        return Err("tenant weights must be >= 1".into());
    }
    if n_cores == 0 {
        return Err("mix needs at least 1 output core".into());
    }
    let block_bytes = inputs[0].meta.block_bytes;
    for (t, i) in inputs.iter().enumerate() {
        if i.meta.block_bytes != block_bytes {
            return Err(format!(
                "tenant {t} has block_bytes {} but tenant 0 has {} — traces must share \
                 a block size to mix",
                i.meta.block_bytes, block_bytes
            ));
        }
    }

    // Weighted round-robin: conceptually the repeating pattern [0, 0, 1]
    // for weights [2, 1]; computed arithmetically so a huge weight cannot
    // allocate a huge pattern. u128 keeps the total overflow-proof.
    let total_weight: u128 = weights.iter().map(|&w| w as u128).sum();
    let tenant_of = |c: usize| -> usize {
        let mut slot = c as u128 % total_weight;
        for (t, &w) in weights.iter().enumerate() {
            if slot < w as u128 {
                return t;
            }
            slot -= w as u128;
        }
        unreachable!("slot < total_weight by construction")
    };

    let mut per_tenant_rank = vec![0u64; inputs.len()];
    let mut streams = Vec::with_capacity(n_cores as usize);
    for c in 0..n_cores as usize {
        let t = tenant_of(c);
        let j = per_tenant_rank[t];
        per_tenant_rank[t] += 1;
        let src = (j % inputs[t].n_cores() as u64) as u16;
        let offset = t as u64 * TENANT_OFFSET;
        let ops: Vec<Op> = inputs[t]
            .decode_core(src)
            .into_iter()
            .map(|op| Op { addr: op.addr + offset, ..op })
            .collect();
        streams.push(ops);
    }

    let name = format!(
        "mix({})",
        inputs.iter().map(|i| i.meta.workload.as_str()).collect::<Vec<_>>().join("+")
    );
    let mut hash = 0xcbf2_9ce4_8422_2325u64;
    for (i, w) in inputs.iter().zip(weights) {
        hash = fnv(fnv(hash, i.meta.config_hash), *w);
    }
    hash = fnv(hash, n_cores as u64);
    let meta = TraceMeta {
        workload: name,
        mem: inputs[0].meta.mem.clone(),
        topology: inputs[0].meta.topology.clone(),
        config_hash: hash,
        seed: inputs.iter().fold(0, |s, i| fnv(s, i.meta.seed)),
        block_bytes,
        n_cores,
    };
    Ok(rebuild(meta, streams))
}

/// Scale every compute gap by `factor` (rounded to the nearest cycle),
/// leaving addresses and r/w untouched.
pub fn dilate(input: &TraceData, factor: f64) -> Result<TraceData, String> {
    if !(factor.is_finite() && factor >= 0.0) {
        return Err(format!("dilate factor must be a finite number >= 0, got {factor}"));
    }
    let streams = (0..input.n_cores())
        .map(|c| {
            input
                .decode_core(c)
                .into_iter()
                .map(|op| {
                    let gap = (op.gap as f64 * factor).round();
                    Op { gap: gap.min(u32::MAX as f64) as u32, ..op }
                })
                .collect()
        })
        .collect();
    let mut meta = input.meta.clone();
    meta.workload = format!("dilate{factor}({})", meta.workload);
    meta.config_hash = fnv(meta.config_hash, factor.to_bits());
    Ok(rebuild(meta, streams))
}

/// Re-home a trace for `new_cores` vaults. Block indices are rewritten so
/// each block's home vault id scales onto the new geometry
/// (`home' = home % new`), preserving which streams collide. The rewrite
/// is a mixed-radix repack — injective, so distinct blocks never alias
/// into false sharing; when `new` divides `old` it is the identity. Core
/// streams fold round-robin when shrinking; when growing, the extra cores
/// replay clones of the original streams at a [`CLONE_OFFSET`] address
/// salt.
pub fn remap(input: &TraceData, new_cores: u16) -> Result<TraceData, String> {
    if new_cores == 0 {
        return Err("remap needs at least 1 core".into());
    }
    let old = input.n_cores();
    let old_n = old as u64;
    let new_n = new_cores as u64;
    let shift = input.meta.block_bytes.trailing_zeros();
    // block = q*old + h  ->  block' = (q*ceil(old/new) + h/new)*new + h%new:
    // home' = h % new, and (q, h) is recoverable from block', so the map
    // cannot collapse two blocks onto one.
    let homes_per_group = old_n.div_ceil(new_n);
    let rehome = |addr: u64| -> u64 {
        let block = addr >> shift;
        let within = addr & ((1u64 << shift) - 1);
        let (q, h) = (block / old_n, block % old_n);
        let block = (q * homes_per_group + h / new_n) * new_n + h % new_n;
        (block << shift) | within
    };

    let mut streams: Vec<Vec<Op>> = Vec::with_capacity(new_cores as usize);
    for c in 0..new_cores {
        if new_cores <= old {
            // Fold: new core c round-robin-interleaves old cores
            // c, c+new, c+2new, ... one op at a time.
            let sources: Vec<Vec<Op>> = (c..old)
                .step_by(new_cores as usize)
                .map(|s| input.decode_core(s))
                .collect();
            let total: usize = sources.iter().map(|s| s.len()).sum();
            let mut merged = Vec::with_capacity(total);
            let mut idx = vec![0usize; sources.len()];
            while merged.len() < total {
                for (s, i) in sources.iter().zip(idx.iter_mut()) {
                    if *i < s.len() {
                        let op = s[*i];
                        merged.push(Op { addr: rehome(op.addr), ..op });
                        *i += 1;
                    }
                }
            }
            streams.push(merged);
        } else {
            let src = c % old;
            let clone = (c / old) as u64;
            streams.push(
                input
                    .decode_core(src)
                    .into_iter()
                    .map(|op| Op { addr: rehome(op.addr) + clone * CLONE_OFFSET, ..op })
                    .collect(),
            );
        }
    }

    let mut meta = input.meta.clone();
    meta.workload = format!("remap{new_cores}({})", meta.workload);
    meta.config_hash = fnv(meta.config_hash, new_n);
    meta.n_cores = new_cores;
    Ok(rebuild(meta, streams))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::writer::TraceWriter;

    fn trace(name: &str, n_cores: u16, ops_per_core: u64) -> TraceData {
        let meta = TraceMeta {
            workload: name.into(),
            mem: "hmc".into(),
            topology: "mesh".into(),
            config_hash: name.len() as u64,
            seed: 1,
            block_bytes: 64,
            n_cores,
        };
        let mut w = TraceWriter::new(meta);
        for c in 0..n_cores {
            for i in 0..ops_per_core {
                w.append(c, Op::read(64 * (1 + c as u64 * 1000 + i), 4));
            }
        }
        TraceData::parse(&w.finish()).unwrap()
    }

    #[test]
    fn mix_offsets_tenant_address_spaces() {
        let a = trace("A", 4, 50);
        let b = trace("B", 4, 50);
        let m = mix(&[a, b], &[1, 1], 8).unwrap();
        assert_eq!(m.n_cores(), 8);
        assert_eq!(m.meta.workload, "mix(A+B)");
        // Even cores are tenant 0 (low addresses), odd cores tenant 1.
        assert!(m.decode_core(0).iter().all(|op| op.addr < TENANT_OFFSET));
        assert!(m.decode_core(1).iter().all(|op| op.addr >= TENANT_OFFSET));
        // Offset preserves the home vault for power-of-two vault counts.
        let base = trace("A", 4, 50).decode_core(0);
        for (orig, mixed) in base.iter().zip(m.decode_core(0).iter()) {
            assert_eq!(orig.addr, mixed.addr);
        }
        for (orig, mixed) in trace("B", 4, 50).decode_core(0).iter().zip(m.decode_core(1)) {
            assert_eq!((orig.addr / 64) % 32, (mixed.addr / 64) % 32, "same home vault");
        }
    }

    #[test]
    fn mix_weights_shape_the_core_assignment() {
        let a = trace("A", 2, 10);
        let b = trace("B", 2, 10);
        let m = mix(&[a, b], &[2, 1], 6).unwrap();
        // Pattern [0, 0, 1]: cores 0,1,3,4 tenant 0; cores 2,5 tenant 1.
        for c in [0u16, 1, 3, 4] {
            assert!(m.decode_core(c)[0].addr < TENANT_OFFSET, "core {c}");
        }
        for c in [2u16, 5] {
            assert!(m.decode_core(c)[0].addr >= TENANT_OFFSET, "core {c}");
        }
    }

    #[test]
    fn mix_handles_huge_weights_without_allocating() {
        let a = trace("A", 2, 4);
        let b = trace("B", 2, 4);
        // The weighted assignment is arithmetic, not a materialized
        // pattern — an absurd weight must neither OOM nor overflow.
        let m = mix(&[a, b], &[u64::MAX / 2, 1], 4).unwrap();
        for c in 0..4u16 {
            assert!(m.decode_core(c)[0].addr < TENANT_OFFSET, "core {c} is tenant 0");
        }
    }

    #[test]
    fn mix_rejects_mismatched_blocks_and_bad_weights() {
        let a = trace("A", 2, 4);
        let mut b = trace("B", 2, 4);
        b.meta.block_bytes = 128;
        assert!(mix(&[a.clone(), b], &[1, 1], 4).unwrap_err().contains("block size"));
        let b = trace("B", 2, 4);
        assert!(mix(&[a.clone(), b.clone()], &[1], 4).is_err(), "weight arity");
        assert!(mix(&[a.clone(), b.clone()], &[1, 0], 4).is_err(), "zero weight");
        assert!(mix(&[a], &[1], 4).unwrap_err().contains("at least 2"));
    }

    #[test]
    fn dilate_scales_gaps_only() {
        let t = trace("A", 2, 20);
        let d = dilate(&t, 2.5).unwrap();
        for (orig, dil) in t.decode_core(1).iter().zip(d.decode_core(1)) {
            assert_eq!(orig.addr, dil.addr);
            assert_eq!(orig.write, dil.write);
            assert_eq!(dil.gap, 10, "4 * 2.5");
        }
        assert!(dilate(&t, f64::NAN).is_err());
        assert!(dilate(&t, -1.0).is_err());
    }

    #[test]
    fn remap_shrink_folds_streams_and_rehomes() {
        let t = trace("A", 4, 10);
        let r = remap(&t, 2).unwrap();
        assert_eq!(r.n_cores(), 2);
        assert_eq!(r.total_ops(), t.total_ops(), "no op lost");
        // 2 divides 4, so the block rewrite is the identity: the remap
        // only folds streams, preserving the exact address multiset.
        let mut orig: Vec<u64> =
            (0..4u16).flat_map(|c| t.decode_core(c)).map(|op| op.addr).collect();
        let mut got: Vec<u64> =
            (0..2u16).flat_map(|c| r.decode_core(c)).map(|op| op.addr).collect();
        orig.sort_unstable();
        got.sort_unstable();
        assert_eq!(orig, got, "divisible rehome must be the identity");
    }

    #[test]
    fn remap_is_injective_and_scales_homes() {
        // 4 -> 3 does not divide: the mixed-radix rewrite must stay
        // injective (no false sharing) and set home' = home % 3. The
        // rewrite is strictly monotonic in the block index, so sorted
        // original and remapped addresses correspond pairwise.
        let t = trace("A", 4, 10);
        let r = remap(&t, 3).unwrap();
        let mut orig: Vec<u64> =
            (0..4u16).flat_map(|c| t.decode_core(c)).map(|op| op.addr).collect();
        let mut got: Vec<u64> =
            (0..3u16).flat_map(|c| r.decode_core(c)).map(|op| op.addr).collect();
        orig.sort_unstable();
        got.sort_unstable();
        assert_eq!(orig.len(), got.len());
        let distinct: std::collections::HashSet<u64> = got.iter().copied().collect();
        assert_eq!(distinct.len(), got.len(), "remap must not alias blocks");
        for (o, g) in orig.iter().zip(&got) {
            assert_eq!((g / 64) % 3, ((o / 64) % 4) % 3, "home must scale");
        }
    }

    #[test]
    fn remap_grow_replicates_with_salt() {
        let t = trace("A", 2, 10);
        let r = remap(&t, 4).unwrap();
        assert_eq!(r.n_cores(), 4);
        // Clones replay the same pattern in a disjoint address range.
        let orig = r.decode_core(0);
        let clone = r.decode_core(2);
        assert_eq!(orig.len(), clone.len());
        assert!(clone[0].addr > orig[0].addr);
        assert_eq!(
            clone[1].addr - clone[0].addr,
            orig[1].addr - orig[0].addr,
            "same stride"
        );
    }
}

//! Trace capture: the streaming [`TraceWriter`] encoder and the
//! [`Recording`] tee that captures any [`Workload`]'s op streams during a
//! normal simulation run.

use std::path::Path;
use std::sync::{Arc, Mutex};

use super::varint;
use super::TraceMeta;
use crate::workloads::{Op, Workload};
use crate::CoreId;

/// One core's encoded stream while recording.
#[derive(Clone, Default)]
struct CoreEncoder {
    bytes: Vec<u8>,
    ops: u64,
    last_addr: u64,
}

impl CoreEncoder {
    fn push(&mut self, op: Op) {
        let delta = op.addr.wrapping_sub(self.last_addr) as i64;
        varint::write_u64(&mut self.bytes, varint::zigzag(delta));
        varint::write_u64(&mut self.bytes, ((op.gap as u64) << 1) | op.write as u64);
        self.last_addr = op.addr;
        self.ops += 1;
    }
}

/// Streaming trace encoder: ops arrive interleaved across cores (the order
/// the driver consumes them); each core's stream is delta-encoded
/// incrementally, so memory held is proportional to the *encoded* trace,
/// not to the op count, and [`TraceWriter::finish`] just concatenates the
/// sections behind the header.
pub struct TraceWriter {
    meta: TraceMeta,
    cores: Vec<CoreEncoder>,
}

impl TraceWriter {
    pub fn new(meta: TraceMeta) -> Self {
        let n = meta.n_cores as usize;
        TraceWriter { meta, cores: vec![CoreEncoder::default(); n] }
    }

    /// Drop everything captured so far and restart for a new seed — the
    /// driver calls `Workload::reset` once per run, so a multi-run
    /// simulation leaves the *last* run's stream in the writer (recording
    /// runs pin `runs = 1` anyway).
    pub fn restart(&mut self, seed: u64) {
        self.meta.seed = seed;
        for c in &mut self.cores {
            *c = CoreEncoder::default();
        }
    }

    /// Record one op for one core, in consumption order.
    pub fn append(&mut self, core: CoreId, op: Op) {
        self.cores[core as usize].push(op);
    }

    /// Ops captured across all cores.
    pub fn total_ops(&self) -> u64 {
        self.cores.iter().map(|c| c.ops).sum()
    }

    /// Serialize the header + per-core sections.
    pub fn finish(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(
            64 + self.cores.iter().map(|c| c.bytes.len() + 12).sum::<usize>(),
        );
        super::write_header(&mut out, &self.meta);
        for c in &self.cores {
            varint::write_u64(&mut out, c.ops);
            varint::write_u64(&mut out, c.bytes.len() as u64);
            out.extend_from_slice(&c.bytes);
        }
        out
    }

    /// Serialize and write to `path`, creating parent directories.
    pub fn save(&self, path: &Path) -> Result<(), String> {
        super::write_file(path, &self.finish())
    }

    pub fn meta(&self) -> &TraceMeta {
        &self.meta
    }
}

/// A writer handle that survives `simulate` consuming the workload box:
/// the [`Recording`] tee holds one clone, the caller holds the other and
/// saves the file after the run returns.
pub type SharedTraceWriter = Arc<Mutex<TraceWriter>>;

/// Build a shared writer.
pub fn shared(meta: TraceMeta) -> SharedTraceWriter {
    Arc::new(Mutex::new(TraceWriter::new(meta)))
}

/// Tee workload: forwards every call to the inner generator and records
/// the ops it emits, so any of the 31 Table III generators (or a replayed
/// trace) can be captured during an ordinary [`simulate`] run without the
/// driver knowing.
///
/// [`simulate`]: crate::coordinator::driver::simulate
pub struct Recording<W: Workload> {
    inner: W,
    writer: SharedTraceWriter,
}

impl<W: Workload> Recording<W> {
    pub fn new(inner: W, writer: SharedTraceWriter) -> Self {
        Recording { inner, writer }
    }
}

impl<W: Workload> Workload for Recording<W> {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn next_op(&mut self, core: CoreId) -> Option<Op> {
        let op = self.inner.next_op(core);
        if let Some(op) = op {
            self.writer.lock().expect("trace writer mutex poisoned").append(core, op);
        }
        op
    }

    fn reset(&mut self, seed: u64) {
        self.inner.reset(seed);
        self.writer.lock().expect("trace writer mutex poisoned").restart(seed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(n_cores: u16) -> TraceMeta {
        TraceMeta {
            workload: "test".into(),
            mem: "hmc".into(),
            topology: "mesh".into(),
            config_hash: 0xABCD,
            seed: 7,
            block_bytes: 64,
            n_cores,
        }
    }

    #[test]
    fn header_starts_with_magic_and_version() {
        use crate::trace::{MAGIC, VERSION};
        let w = TraceWriter::new(meta(2));
        let bytes = w.finish();
        assert_eq!(&bytes[..4], MAGIC);
        assert_eq!(u16::from_le_bytes([bytes[4], bytes[5]]), VERSION);
    }

    #[test]
    fn restart_clears_streams_and_reseeds() {
        let mut w = TraceWriter::new(meta(1));
        w.append(0, Op::read(64, 1));
        assert_eq!(w.total_ops(), 1);
        w.restart(99);
        assert_eq!(w.total_ops(), 0);
        assert_eq!(w.meta().seed, 99);
    }

    #[test]
    fn strided_stream_encodes_compactly() {
        let mut w = TraceWriter::new(meta(1));
        for i in 0..1000u64 {
            w.append(0, Op::read(4096 + i * 64, 8));
        }
        // Constant 64-byte stride: zigzag(64) = 128 takes a 2-byte varint,
        // the gap word one byte — exactly 3 bytes/op, ~5x under the naive
        // 13-byte (u64 addr + bool + u32 gap) record.
        let body = w.cores[0].bytes.len();
        assert_eq!(body, 3_000, "encoded {body} bytes for 1000 ops");
    }

    #[test]
    fn recording_tee_is_transparent() {
        use crate::config::SimConfig;
        use crate::workloads::catalog;
        let cfg = SimConfig::hmc();
        let mut direct = catalog::build("STRAdd", &cfg).unwrap();
        let writer = shared(meta(cfg.n_vaults));
        let mut teed =
            Recording::new(catalog::build("STRAdd", &cfg).unwrap(), writer.clone());
        direct.reset(5);
        teed.reset(5);
        for i in 0..500u64 {
            let c = (i % 4) as u16;
            assert_eq!(direct.next_op(c), teed.next_op(c));
        }
        assert_eq!(writer.lock().unwrap().total_ops(), 500);
        assert_eq!(writer.lock().unwrap().meta().seed, 5);
    }
}

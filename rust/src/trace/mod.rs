//! Trace subsystem: record, replay and compose memory traces.
//!
//! The paper's results hinge on the *traffic properties* of its 31
//! Table III workloads, but generators alone cannot capture a run, rerun
//! it bit-identically across policies/topologies, or compose workloads
//! into new scenarios (multi-tenant mixes, dilated compute, re-homed
//! geometries). This module adds that trace-driven methodology:
//!
//! * **record** — [`Recording`] tees any [`Workload`] to a
//!   [`TraceWriter`] during a normal [`simulate`] run;
//! * **replay** — [`TraceWorkload`] implements [`Workload`] over a loaded
//!   [`TraceData`], so every figure, policy and topology runs unchanged
//!   on recorded traffic;
//! * **transform** — [`transform::mix`] / [`transform::dilate`] /
//!   [`transform::remap`] compose recorded traces into multi-tenant and
//!   sensitivity scenarios (`repro trace mix|dilate|remap`).
//!
//! # File format (`DLPT` version 1)
//!
//! All integers little-endian; `varint` is LEB128; `str` is a `u16`
//! length followed by UTF-8 bytes.
//!
//! ```text
//! magic       4 B   "DLPT"
//! version     u16   format version (this module reads exactly 1)
//! n_cores     u16   per-core stream count (= vault count at record time)
//! block_bytes u32   block size the recording config used
//! config_hash u64   sweep-cache hash of the recording config + workload
//! seed        u64   seed of the recorded run
//! workload    str   Table III short name (or transform expression)
//! mem         str   memory preset at record time ("hmc" | "hbm")
//! topology    str   interconnect at record time
//! then, for each core 0..n_cores:
//!   op_count  varint
//!   byte_len  varint   encoded stream length in bytes
//!   stream    byte_len bytes: per op,
//!               varint zigzag(addr - prev_addr)   (prev starts at 0)
//!               varint (gap << 1) | write_bit
//! ```
//!
//! **Versioning rules:** readers reject any version they were not built
//! for (no silent best-effort decode of future traces); additive changes
//! (new header fields, new op flags) bump the version; the magic never
//! changes. Every stream is decode-validated at load, so a malformed or
//! truncated file fails with a labelled error instead of a panic mid-run.
//!
//! [`Workload`]: crate::workloads::Workload
//! [`simulate`]: crate::coordinator::driver::simulate

pub mod reader;
pub mod transform;
pub mod varint;
pub mod writer;

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Mutex, OnceLock};

pub use reader::{TraceData, TraceWorkload};
pub use writer::{Recording, TraceWriter};

use crate::config::SimConfig;
use crate::coordinator::report::SimReport;
use crate::workloads::catalog;

/// File magic: "DL-PIM Trace".
pub const MAGIC: &[u8; 4] = b"DLPT";
/// Format version this build writes and reads.
pub const VERSION: u16 = 1;

/// Trace header metadata: enough to identify what was recorded and to
/// validate a replay config against it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceMeta {
    /// Table III short name, or a transform expression like
    /// `mix(SPLRad+PHELinReg)`.
    pub workload: String,
    /// Memory preset at record time ("hmc" | "hbm").
    pub mem: String,
    /// Interconnect at record time ("mesh" | "crossbar" | "ring").
    pub topology: String,
    /// Sweep-cache hash of the recording config (provenance, not enforced
    /// on replay: replaying under a different policy/topology is the whole
    /// point).
    pub config_hash: u64,
    /// Seed of the recorded run.
    pub seed: u64,
    /// Block size of the recording config; replay configs must match.
    pub block_bytes: u32,
    /// Per-core stream count (= `n_vaults` of the recording config).
    pub n_cores: u16,
}

impl TraceMeta {
    /// Header for a recording of `workload` under `cfg`.
    pub fn for_run(workload: &str, cfg: &SimConfig) -> Self {
        TraceMeta {
            workload: workload.to_string(),
            mem: cfg.mem.as_str().to_string(),
            topology: cfg.topology.as_str().to_string(),
            config_hash: crate::sweep::cache::config_key(workload, cfg),
            seed: cfg.seed,
            block_bytes: cfg.block_bytes,
            n_cores: cfg.n_vaults,
        }
    }

    /// The header [`record_run`] would write for `workload` under `cfg`,
    /// after the same normalization `record_run` applies (one run, no
    /// replay source). Callers compare this against an existing file's
    /// header to skip re-recording traffic that is already on disk.
    pub fn for_recording(workload: &str, cfg: &SimConfig) -> Self {
        let mut cfg = cfg.clone();
        cfg.runs = 1;
        cfg.trace = None;
        TraceMeta::for_run(workload, &cfg)
    }
}

/// Serialize the fixed header + metadata strings (shared by the writer
/// and [`TraceData::save`], so the two cannot drift).
pub(crate) fn write_header(out: &mut Vec<u8>, meta: &TraceMeta) {
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&meta.n_cores.to_le_bytes());
    out.extend_from_slice(&meta.block_bytes.to_le_bytes());
    out.extend_from_slice(&meta.config_hash.to_le_bytes());
    out.extend_from_slice(&meta.seed.to_le_bytes());
    write_str(out, &meta.workload);
    write_str(out, &meta.mem);
    write_str(out, &meta.topology);
}

pub(crate) fn write_str(out: &mut Vec<u8>, s: &str) {
    let bytes = s.as_bytes();
    let len = bytes.len().min(u16::MAX as usize);
    out.extend_from_slice(&(len as u16).to_le_bytes());
    out.extend_from_slice(&bytes[..len]);
}

/// Write `bytes` to `path`, creating parent directories. The write is
/// published atomically (same-dir temp + rename, unique per process *and*
/// writer — see `sweep::store::write_atomic`), so a concurrent reader
/// (two `repro` processes preparing the same tenant mixes against one
/// artifact dir) never loads a torn trace.
pub(crate) fn write_file(path: &Path, bytes: &[u8]) -> Result<(), String> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .map_err(|e| format!("create {}: {e}", parent.display()))?;
        }
    }
    crate::sweep::store::write_atomic(path, bytes)
        .map_err(|e| format!("write {}: {e}", path.display()))
}

/// Intern a trace display name so [`TraceWorkload`] can satisfy
/// `Workload::name(&self) -> &'static str` without leaking one allocation
/// per sweep job that opens the same file.
pub fn intern(name: &str) -> &'static str {
    static NAMES: OnceLock<Mutex<BTreeMap<String, &'static str>>> = OnceLock::new();
    let mut map = NAMES
        .get_or_init(|| Mutex::new(BTreeMap::new()))
        .lock()
        .expect("intern table mutex poisoned");
    if let Some(s) = map.get(name) {
        return *s;
    }
    let leaked: &'static str = Box::leak(name.to_string().into_boxed_str());
    map.insert(name.to_string(), leaked);
    leaked
}

/// Record `workload` under `cfg` to `path`: runs a normal [`simulate`]
/// with a [`Recording`] tee and saves the captured streams. Forces
/// `runs = 1` (the format stores one seed, one stream set). Returns the
/// run's report so callers can print or reuse it.
///
/// [`simulate`]: crate::coordinator::driver::simulate
pub fn record_run(cfg: &SimConfig, workload: &str, path: &Path) -> Result<SimReport, String> {
    // Keep this normalization in sync with [`TraceMeta::for_recording`],
    // which predicts the header without running anything.
    let meta = TraceMeta::for_recording(workload, cfg);
    let mut cfg = cfg.clone();
    cfg.runs = 1;
    cfg.trace = None; // record from the generator, even if a replay is configured
    let inner = catalog::build(workload, &cfg)
        .ok_or_else(|| crate::workloads::unknown_workload_message(workload))?;
    let writer = writer::shared(meta);
    let rec = Recording::new(inner, writer.clone());
    let report = crate::coordinator::driver::simulate(&cfg, Box::new(rec));
    let guard = writer.lock().expect("trace writer mutex poisoned");
    guard.save(path)?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intern_returns_one_static_per_name() {
        let a = intern("trace:unit-intern");
        let b = intern("trace:unit-intern");
        assert!(std::ptr::eq(a, b), "same interned pointer");
        assert_eq!(a, "trace:unit-intern");
    }

    #[test]
    fn record_run_writes_a_loadable_trace() {
        let mut cfg = SimConfig::hmc();
        cfg.warmup_requests = 100;
        cfg.measure_requests = 500;
        let dir = std::env::temp_dir()
            .join(format!("dlpim-trace-mod-{}", std::process::id()));
        let path = dir.join("stradd.dlpt");
        let report = record_run(&cfg, "STRAdd", &path).unwrap();
        assert!(report.runs[0].stats.requests >= 500);
        let data = TraceData::load(&path).unwrap();
        assert_eq!(data.meta.workload, "STRAdd");
        assert_eq!(data.meta.n_cores, 32);
        assert_eq!(data.meta.seed, cfg.seed);
        assert!(data.total_ops() > 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn record_run_rejects_unknown_workload_with_suggestion() {
        let cfg = SimConfig::hmc();
        let err = record_run(&cfg, "SPLRod", Path::new("/tmp/never-written.dlpt"))
            .unwrap_err();
        assert!(err.contains("SPLRad"), "did-you-mean: {err}");
    }
}

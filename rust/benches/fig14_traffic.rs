//! Fig 14: average network traffic (bytes/cycle) for always-subscribe and
//! adaptive vs baseline, including subscription-protocol packets.
//!
//! Paper: always-subscribe +88% average traffic; adaptive only +14%;
//! PHELinReg's traffic *drops* below baseline.

use dlpim::benchkit::Csv;
use dlpim::figures;

fn main() {
    let t0 = std::time::Instant::now();
    let rows = figures::fig14_traffic();
    let mut csv = Csv::new("workload,baseline,always,adaptive");
    let (mut sb, mut sa, mut sd) = (0.0, 0.0, 0.0);
    for (name, b, a, d) in &rows {
        println!("fig14 | {name:<12} | base {b:.2} | always {a:.2} | adaptive {d:.2}");
        csv.push(&[name.to_string(), format!("{b:.4}"), format!("{a:.4}"), format!("{d:.4}")]);
        sb += b;
        sa += a;
        sd += d;
    }
    println!(
        "fig14 | AVG increase: always {:+.0}% adaptive {:+.0}% (paper +88% / +14%) | wallclock {:.1}s",
        (sa / sb - 1.0) * 100.0,
        (sd / sb - 1.0) * 100.0,
        t0.elapsed().as_secs_f64()
    );
    csv.write("target/figures/fig14.csv").expect("write csv");
    let artifact = figures::emit_artifact("14").expect("known figure");
    println!("fig14 | artifact: {}", artifact.display());
}

//! Fig 19 (extension): adaptive DL-PIM under multi-tenant trace mixes.
//!
//! Records the four tenant workloads' baseline traffic, composes 2- and
//! 4-tenant mixed traces (per-tenant address offsets, interleaved core
//! assignment), and compares never/always/adaptive on the mixes. Tenants'
//! hot home vaults collide on the same physical vaults, stressing the
//! subscription protocol in a way no single Table III generator does.

use dlpim::benchkit::Csv;
use dlpim::figures;

fn main() {
    let t0 = std::time::Instant::now();
    let rows = figures::fig19_multi_tenant();
    let mut csv = Csv::new("scenario,tenants,always,adaptive,latency_improvement,base_cov,adaptive_cov");
    for r in &rows {
        println!(
            "fig19 | {:<10} | {} tenants | always {:.3} | adaptive {:.3} | latency impr {:.1}% | cov base {:.3} -> adaptive {:.3}",
            r.scenario,
            r.tenants,
            r.always_speedup,
            r.adaptive_speedup,
            r.latency_improvement * 100.0,
            r.base_cov,
            r.adaptive_cov
        );
        csv.push(&[
            r.scenario.to_string(),
            r.tenants.to_string(),
            format!("{:.4}", r.always_speedup),
            format!("{:.4}", r.adaptive_speedup),
            format!("{:.4}", r.latency_improvement),
            format!("{:.4}", r.base_cov),
            format!("{:.4}", r.adaptive_cov),
        ]);
    }
    println!(
        "fig19 | GEOMEAN adaptive speedup over mixes = {:.3} | wallclock {:.1}s",
        figures::geomean(rows.iter().map(|r| r.adaptive_speedup)),
        t0.elapsed().as_secs_f64()
    );
    csv.write("target/figures/fig19.csv").expect("write csv");
    let artifact = figures::emit_artifact("19").expect("known figure");
    println!("fig19 | artifact: {}", artifact.display());
}

//! Fig 16: adaptive speedup vs subscription-table size (total entries per
//! vault). Paper: gains grow with table size and flatten at 8192 entries
//! (the default, 0.125% state overhead).

use dlpim::benchkit::Csv;
use dlpim::figures;

fn main() {
    let t0 = std::time::Instant::now();
    let rows = figures::fig16_table_size();
    let mut csv = Csv::new("workload,entries,speedup");
    for (name, series) in &rows {
        let cols: Vec<String> = series.iter().map(|(e, s)| format!("{e}:{s:.3}")).collect();
        println!("fig16 | {name:<12} | {}", cols.join(" | "));
        for (e, s) in series {
            csv.push(&[name.to_string(), e.to_string(), format!("{s:.4}")]);
        }
    }
    // Flattening check: last doubling must add less than the first.
    for (name, series) in &rows {
        if series.len() >= 3 {
            let first_gain = series[1].1 - series[0].1;
            let last_gain = series[series.len() - 1].1 - series[series.len() - 2].1;
            println!(
                "fig16 | {name:<12} | first-doubling gain {first_gain:+.3} vs last {last_gain:+.3} (paper: flattens at 8192)"
            );
        }
    }
    println!("fig16 | wallclock {:.1}s", t0.elapsed().as_secs_f64());
    csv.write("target/figures/fig16.csv").expect("write csv");
    let artifact = figures::emit_artifact("16").expect("known figure");
    println!("fig16 | artifact: {}", artifact.display());
}

//! Fig 13: CoV of memory access distribution, baseline vs adaptive — HBM.

use dlpim::benchkit::Csv;
use dlpim::config::MemKind;
use dlpim::figures;

fn main() {
    let t0 = std::time::Instant::now();
    let rows = figures::fig_cov_policies(MemKind::Hbm, false);
    let mut csv = Csv::new("workload,baseline,adaptive");
    for (name, covs) in &rows {
        println!("fig13 | {name:<12} | base {:.3} | adaptive {:.3}", covs[0], covs[1]);
        csv.push(&[name.to_string(), format!("{:.4}", covs[0]), format!("{:.4}", covs[1])]);
    }
    println!("fig13 | wallclock {:.1}s", t0.elapsed().as_secs_f64());
    csv.write("target/figures/fig13.csv").expect("write csv");
    let artifact = figures::emit_artifact("13").expect("known figure");
    println!("fig13 | artifact: {}", artifact.display());
}

//! Microbenchmarks over the simulator's hot paths, used by the §Perf
//! optimization loop (EXPERIMENTS.md §Perf records before/after).
//!
//! Targets: interconnect transfer (legacy coordinate walk vs the memsys
//! precomputed route tables, plus the crossbar and ring topologies), DRAM
//! access, subscription-table lookup, full request service through the
//! `MemorySystem` facade, and end-to-end simulation throughput (simulated
//! requests per wall-second).

use dlpim::benchkit::{report, time};
use dlpim::config::SimConfig;
use dlpim::coordinator::driver::simulate_once;
use dlpim::memsys::{
    Access, CrossbarInterconnect, Interconnect, MemorySystem, MeshInterconnect,
    RingInterconnect,
};
use dlpim::policy::{PolicyKind, PolicyRuntime};
use dlpim::sim::network::LinkCal;
use dlpim::sim::{Mesh, VaultMem};
use dlpim::subscription::table::{Role, SubState, SubTable};
use dlpim::workloads::catalog;

fn main() {
    let cfg = SimConfig::hmc();

    // Mesh transfer, legacy on-the-fly XY walk: worst-case corner-to-corner.
    {
        let mut mesh = Mesh::new(&cfg);
        let mut t = 0u64;
        let timing = time(100, 1000, || {
            for _ in 0..100 {
                std::hint::black_box(mesh.transfer(0, 31, 5, t));
                t += 1;
            }
        });
        report("perf_hotpath", "mesh_transfer_x100", &timing);
    }

    // The same transfer stream over the memsys mesh interconnect: routes
    // and hop counts precomputed at construction. This is the §Perf
    // comparison the route-table refactor is verified against.
    {
        let mut net = MeshInterconnect::new(&cfg);
        let mut t = 0u64;
        let timing = time(100, 1000, || {
            for _ in 0..100 {
                std::hint::black_box(net.transfer(0, 31, 5, t));
                t += 1;
            }
        });
        report("perf_hotpath", "mesh_route_transfer_x100", &timing);
    }

    // The two new topologies' transfer paths, same traffic shape.
    {
        let mut net = CrossbarInterconnect::new(&SimConfig::hbm());
        let mut t = 0u64;
        let timing = time(100, 1000, || {
            for _ in 0..100 {
                std::hint::black_box(net.transfer(0, 7, 5, t));
                t += 1;
            }
        });
        report("perf_hotpath", "crossbar_transfer_x100", &timing);
    }
    {
        let mut net = RingInterconnect::new(&cfg);
        let mut t = 0u64;
        let timing = time(100, 1000, || {
            for _ in 0..100 {
                std::hint::black_box(net.transfer(0, 16, 5, t));
                t += 1;
            }
        });
        report("perf_hotpath", "ring_transfer_x100", &timing);
    }

    // LinkCal backfill under an out-of-order reservation storm: response
    // legs book far-future link slots while request legs backfill gaps
    // near "now", so most reserves take the slow path over a long
    // calendar. §Perf: the first-fit scan is seeded with partition_point
    // past the intervals ending before the reservation start.
    {
        let mut state = 0x1234_5678_u64;
        let mut rng = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        let timing = time(10, 200, || {
            let mut cal = LinkCal::default();
            let mut base = 0u64;
            for _ in 0..1000 {
                // A far-future booking stretches the calendar...
                std::hint::black_box(cal.reserve(base + 1_000 + rng() % 600, 5));
                // ...then a near-now reservation must backfill a gap.
                std::hint::black_box(cal.reserve(base + rng() % 400, 3));
                base += 2;
            }
        });
        report("perf_hotpath", "linkcal_backfill_x1000", &timing);
    }

    // DRAM bank access.
    {
        let mut mem = VaultMem::new(&cfg);
        let mut addr = 0u64;
        let mut t = 0u64;
        let timing = time(100, 1000, || {
            for _ in 0..100 {
                std::hint::black_box(mem.access(addr, t));
                addr = addr.wrapping_add(4096);
                t += 10;
            }
        });
        report("perf_hotpath", "dram_access_x100", &timing);
    }

    // Subscription-table lookup (hit path).
    {
        let mut table = SubTable::new(cfg.sub_table_sets, cfg.sub_table_ways);
        for b in 0..1000u64 {
            let set = (b % cfg.sub_table_sets as u64) as u32;
            if let Some(w) = table.free_way(set) {
                table.install(w, b, Role::Holder, 0, SubState::Subscribed, 0, 0);
            }
        }
        let mut b = 0u64;
        let timing = time(100, 1000, || {
            for _ in 0..100 {
                let set = (b % cfg.sub_table_sets as u64) as u32;
                std::hint::black_box(table.lookup(set, b, 1_000_000));
                b = (b + 1) % 1000;
            }
        });
        report("perf_hotpath", "subtable_lookup_x100", &timing);
    }

    // Full request service through the MemorySystem facade (remote read,
    // no subscription).
    {
        let mut cfgn = cfg.clone();
        cfgn.policy = PolicyKind::Never;
        let mut mem = MemorySystem::new(&cfgn);
        let policy = PolicyRuntime::new(&cfgn);
        let mut t = 0u64;
        let mut b = 0u64;
        let timing = time(100, 1000, || {
            for _ in 0..100 {
                std::hint::black_box(mem.serve(
                    Access { requester: (b % 32) as u16, block: b * 7 + 31, write: false },
                    t,
                    &policy,
                ));
                b += 1;
                t += 20;
            }
        });
        report("perf_hotpath", "serve_remote_x100", &timing);
    }

    // Sweep-engine scaling: the same 4x2 point matrix at 1 worker vs all
    // cores (cache disabled so both runs really compute).
    {
        use dlpim::sweep::{Sweep, SweepPoint};
        let points = || -> Vec<SweepPoint> {
            let mut base = cfg.clone();
            base.warmup_requests = 2_000;
            base.measure_requests = 20_000;
            let mut always = base.clone();
            always.policy = PolicyKind::Always;
            ["STRTriad", "SPLRad", "PLYgemm", "HSJNPO"]
                .iter()
                .flat_map(|w| {
                    [base.clone(), always.clone()]
                        .into_iter()
                        .map(move |c| SweepPoint::new(*w, c))
                })
                .collect()
        };
        let all_cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
        for threads in [1usize, all_cores] {
            let t0 = std::time::Instant::now();
            let out = Sweep::new(points()).use_cache(false).threads(threads).run();
            let dt = t0.elapsed().as_secs_f64();
            assert!(out.iter().all(|o| o.result.is_ok()));
            println!(
                "bench | perf_hotpath               | sweep_4x2_t{threads:<9} | {:.2}s wall | {} jobs",
                dt,
                out.len()
            );
        }
    }

    // End-to-end throughput: simulated requests / wall-second.
    for (wl, policy) in
        [("STRTriad", PolicyKind::Never), ("SPLRad", PolicyKind::Adaptive), ("PLYgemm", PolicyKind::Always)]
    {
        let mut c = cfg.clone();
        c.policy = policy;
        c.warmup_requests = 5_000;
        c.measure_requests = 50_000;
        let mut w = catalog::build(wl, &c).unwrap();
        w.reset(1);
        let t0 = std::time::Instant::now();
        let rep = simulate_once(&c, w.as_mut());
        let dt = t0.elapsed().as_secs_f64();
        let reqs = rep.stats.requests + c.warmup_requests;
        println!(
            "bench | perf_hotpath               | e2e_{wl}_{:<10} | {:.2}M req/s | {:.2}s wall",
            policy.as_str(),
            reqs as f64 / dt / 1e6,
            dt
        );
    }

    // The pinned perf trajectory (same measurement `repro bench` emits as
    // BENCH_*.json): end-to-end serve_ops_per_sec per topology/policy
    // point, at the pinned scale, plus the headline aggregate.
    {
        let rep = dlpim::perf::run_trajectory();
        for p in &rep.points {
            println!(
                "bench | perf_hotpath               | serve_ops_{}_{:<8} | {:.2}M ops/s | {:.0}ns/access",
                p.topology,
                p.policy,
                p.ops_per_sec() / 1e6,
                p.ns_per_access()
            );
        }
        println!(
            "bench | perf_hotpath               | serve_ops_per_sec     | {:.2}M ops/s | {:.0}ns/access",
            rep.serve_ops_per_sec() / 1e6,
            rep.ns_per_access()
        );
        for tp in &rep.threads {
            println!(
                "bench | perf_hotpath               | kernel_scale_t{:<7} | {:.2} sims/s | {} runs",
                tp.threads,
                tp.sims_per_sec(),
                tp.runs
            );
        }
    }
}

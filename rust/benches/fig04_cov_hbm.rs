//! Fig 4: CoV of per-channel demand — HBM baseline.
//! Paper: same skewed workloads stand out but overall CoV is lower than
//! HMC (8 channels vs 32 vaults).

use dlpim::benchkit::Csv;
use dlpim::config::MemKind;
use dlpim::figures;

fn main() {
    let t0 = std::time::Instant::now();
    let hbm = figures::fig_cov(MemKind::Hbm);
    let mut csv = Csv::new("workload,cov");
    for (name, cov) in &hbm {
        println!("fig04 | {name:<12} | cov {cov:.3}");
        csv.push(&[name.to_string(), format!("{cov:.4}")]);
    }
    let avg = hbm.iter().map(|(_, c)| c).sum::<f64>() / hbm.len() as f64;
    println!(
        "fig04 | AVG CoV = {avg:.3} (paper: lower than HMC overall) | wallclock {:.1}s",
        t0.elapsed().as_secs_f64()
    );
    csv.write("target/figures/fig04.csv").expect("write csv");
    let artifact = figures::emit_artifact("4").expect("known figure");
    println!("fig04 | artifact: {}", artifact.display());
}

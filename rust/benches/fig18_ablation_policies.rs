//! Ablation (§III-D): adaptive-policy variants — always-subscribe,
//! hops-based, latency-based (global), and the headline adaptive
//! (latency + leading-set sampling) — on winners, losers and a neutral
//! streaming workload.

use dlpim::benchkit::Csv;
use dlpim::figures;

fn main() {
    let t0 = std::time::Instant::now();
    let rows = figures::fig18_policy_ablation();
    let mut csv = Csv::new("workload,policy,speedup");
    for (name, series) in &rows {
        let cols: Vec<String> = series.iter().map(|(p, s)| format!("{p}:{s:.3}")).collect();
        println!("fig18 | {name:<12} | {}", cols.join(" | "));
        for (p, s) in series {
            csv.push(&[name.to_string(), p.to_string(), format!("{s:.4}")]);
        }
    }
    println!("fig18 | wallclock {:.1}s", t0.elapsed().as_secs_f64());
    csv.write("target/figures/fig18.csv").expect("write csv");
    let artifact = figures::emit_artifact("18").expect("known figure");
    println!("fig18 | artifact: {}", artifact.display());
}

//! Fig 12: CoV of the access distribution per vault for always-subscribe
//! and adaptive vs baseline — HMC. DL-PIM must flatten the high-CoV
//! workloads (PHELinReg, CHABsBez, SPLRad).

use dlpim::benchkit::Csv;
use dlpim::config::MemKind;
use dlpim::figures;

fn main() {
    let t0 = std::time::Instant::now();
    let rows = figures::fig_cov_policies(MemKind::Hmc, true);
    let mut csv = Csv::new("workload,baseline,always,adaptive");
    for (name, covs) in &rows {
        println!(
            "fig12 | {name:<12} | base {:.3} | always {:.3} | adaptive {:.3}",
            covs[0], covs[1], covs[2]
        );
        csv.push(&[
            name.to_string(),
            format!("{:.4}", covs[0]),
            format!("{:.4}", covs[1]),
            format!("{:.4}", covs[2]),
        ]);
    }
    println!("fig12 | wallclock {:.1}s", t0.elapsed().as_secs_f64());
    csv.write("target/figures/fig12.csv").expect("write csv");
    let artifact = figures::emit_artifact("12").expect("known figure");
    println!("fig12 | artifact: {}", artifact.display());
}

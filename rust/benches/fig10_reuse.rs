//! Fig 10: average local / remote accesses per subscription under
//! always-subscribe — the reuse profile that separates Fig 9's winners
//! from its flat middle.

use dlpim::benchkit::Csv;
use dlpim::figures;

fn main() {
    let t0 = std::time::Instant::now();
    let rows = figures::fig10_reuse();
    let mut csv = Csv::new("workload,local,remote");
    let mut near_zero = 0;
    for (name, l, r) in &rows {
        println!("fig10 | {name:<12} | local {l:.2} | remote {r:.2} | total {:.2}", l + r);
        csv.push(&[name.to_string(), format!("{l:.4}"), format!("{r:.4}")]);
        if l + r < 0.5 {
            near_zero += 1;
        }
    }
    println!(
        "fig10 | {near_zero}/{} workloads with near-zero reuse (paper: 'many') | wallclock {:.1}s",
        rows.len(),
        t0.elapsed().as_secs_f64()
    );
    csv.write("target/figures/fig10.csv").expect("write csv");
    let artifact = figures::emit_artifact("10").expect("known figure");
    println!("fig10 | artifact: {}", artifact.display());
}

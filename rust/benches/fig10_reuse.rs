//! Fig 10: reuse per subscription under always-subscribe — a thin shim: the
//! experiment itself is the "fig10" data entry in
//! `dlpim::exp::registry`; running, printing, CSV and the JSON artifact
//! all go through the generic `exp::run_named_figure` path.

fn main() {
    dlpim::exp::run_named_figure("fig10");
}

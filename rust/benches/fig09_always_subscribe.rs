//! Fig 9: performance gain of always-subscribe over baseline — HMC, all 31
//! workloads.
//!
//! Paper shape: SPLRad up to +105%, PLYgemm/PLY3mm down to −17%, a wide
//! flat middle at 1.00, average ≈ +6%.

use dlpim::benchkit::Csv;
use dlpim::figures;

fn main() {
    let t0 = std::time::Instant::now();
    let rows = figures::fig9_always_subscribe();
    let mut csv = Csv::new("workload,speedup,latency_improvement");
    for r in &rows {
        println!(
            "fig09 | {:<12} | speedup {:.3} | latency impr {:+.1}%",
            r.workload,
            r.speedup,
            r.latency_improvement * 100.0
        );
        csv.push(&[
            r.workload.to_string(),
            format!("{:.4}", r.speedup),
            format!("{:.4}", r.latency_improvement),
        ]);
    }
    let best = rows.iter().max_by(|a, b| a.speedup.partial_cmp(&b.speedup).unwrap()).unwrap();
    let worst = rows.iter().min_by(|a, b| a.speedup.partial_cmp(&b.speedup).unwrap()).unwrap();
    println!(
        "fig09 | GEOMEAN {:.3} (paper ~1.06) | best {} {:.2} (paper SPLRad 2.05) | worst {} {:.2} (paper PLYgemm/3mm 0.83) | wallclock {:.1}s",
        figures::geomean(rows.iter().map(|r| r.speedup)),
        best.workload,
        best.speedup,
        worst.workload,
        worst.speedup,
        t0.elapsed().as_secs_f64()
    );
    csv.write("target/figures/fig09.csv").expect("write csv");
    let artifact = figures::emit_artifact("9").expect("known figure");
    println!("fig09 | artifact: {}", artifact.display());
}

//! Fig 11: always vs adaptive speedups (bars) and adaptive memory-latency
//! improvement (orange line) on the non-negligible-reuse workloads — HMC.
//!
//! Paper: always ≈ +14%, adaptive ≈ +15% average; adaptive recovers the
//! workloads always-subscribe hurts; avg latency per request −54%.

use dlpim::benchkit::Csv;
use dlpim::figures;

fn main() {
    let t0 = std::time::Instant::now();
    let rows = figures::fig11_adaptive();
    let mut csv = Csv::new("workload,always,adaptive,latency_improvement");
    for r in &rows {
        println!(
            "fig11 | {:<12} | always {:.3} | adaptive {:.3} | latency impr {:+.1}%",
            r.workload,
            r.always_speedup,
            r.adaptive_speedup,
            r.latency_improvement * 100.0
        );
        csv.push(&[
            r.workload.to_string(),
            format!("{:.4}", r.always_speedup),
            format!("{:.4}", r.adaptive_speedup),
            format!("{:.4}", r.latency_improvement),
        ]);
    }
    println!(
        "fig11 | GEOMEAN always {:.3} adaptive {:.3} | AVG latency impr {:.1}% (paper ~1.14 / ~1.15 / 54%) | wallclock {:.1}s",
        figures::geomean(rows.iter().map(|r| r.always_speedup)),
        figures::geomean(rows.iter().map(|r| r.adaptive_speedup)),
        rows.iter().map(|r| r.latency_improvement).sum::<f64>() / rows.len() as f64 * 100.0,
        t0.elapsed().as_secs_f64()
    );
    csv.write("target/figures/fig11.csv").expect("write csv");
    let artifact = figures::emit_artifact("11").expect("known figure");
    println!("fig11 | artifact: {}", artifact.display());
}

//! Ablation (§III-A): the abandoned count-threshold subscription filter.
//! The paper found a 0-count threshold (subscribe on first access) matches
//! or beats positive thresholds on subscription-friendly workloads — which
//! is why DL-PIM carries no count table.

use dlpim::benchkit::Csv;
use dlpim::figures;

fn main() {
    let t0 = std::time::Instant::now();
    let rows = figures::fig17_threshold_ablation();
    let mut csv = Csv::new("workload,threshold,speedup");
    for (name, series) in &rows {
        let cols: Vec<String> = series.iter().map(|(th, s)| format!("thr{th}:{s:.3}")).collect();
        println!("fig17 | {name:<12} | {}", cols.join(" | "));
        for (th, s) in series {
            csv.push(&[name.to_string(), th.to_string(), format!("{s:.4}")]);
        }
    }
    println!("fig17 | wallclock {:.1}s", t0.elapsed().as_secs_f64());
    csv.write("target/figures/fig17.csv").expect("write csv");
    let artifact = figures::emit_artifact("17").expect("known figure");
    println!("fig17 | artifact: {}", artifact.display());
}

//! Fig 2: latency breakdown — HBM, baseline, all 31 workloads.
//! Paper headline: remote overhead ≈ 43% (lower than HMC's 53% thanks to
//! the smaller 4x2 mesh).

use dlpim::benchkit::Csv;
use dlpim::config::MemKind;
use dlpim::figures;

fn main() {
    let t0 = std::time::Instant::now();
    let rows = figures::fig_latency_breakdown(MemKind::Hbm);
    let mut csv = Csv::new("workload,network,queue,array,avg_latency");
    let mut overhead = 0.0;
    for r in &rows {
        println!(
            "fig02 | {:<12} | network {:.3} | queue {:.3} | array {:.3} | avg {:.1}",
            r.workload, r.network, r.queue, r.array, r.avg_latency
        );
        csv.push(&[
            r.workload.to_string(),
            format!("{:.4}", r.network),
            format!("{:.4}", r.queue),
            format!("{:.4}", r.array),
            format!("{:.2}", r.avg_latency),
        ]);
        overhead += r.network + r.queue;
    }
    println!(
        "fig02 | AVG remote overhead = {:.1}% (paper: ~43%) | wallclock {:.1}s",
        overhead / rows.len() as f64 * 100.0,
        t0.elapsed().as_secs_f64()
    );
    csv.write("target/figures/fig02.csv").expect("write csv");
    let artifact = figures::emit_artifact("2").expect("known figure");
    println!("fig02 | artifact: {}", artifact.display());
}

//! Fig 3: coefficient of variation of per-vault demand — HMC baseline.
//! Paper: PHELinReg, CHABsBez and SPLRad dominate; most others are low.

use dlpim::benchkit::Csv;
use dlpim::config::MemKind;
use dlpim::figures;

fn main() {
    let t0 = std::time::Instant::now();
    let rows = figures::fig_cov(MemKind::Hmc);
    let mut csv = Csv::new("workload,cov");
    for (name, cov) in &rows {
        println!("fig03 | {name:<12} | cov {cov:.3}");
        csv.push(&[name.to_string(), format!("{cov:.4}")]);
    }
    let top: Vec<&str> = {
        let mut sorted = rows.clone();
        sorted.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        sorted.iter().take(3).map(|(n, _)| *n).collect()
    };
    println!(
        "fig03 | top-3 CoV: {} (paper: PHELinReg, CHABsBez, SPLRad) | wallclock {:.1}s",
        top.join(", "),
        t0.elapsed().as_secs_f64()
    );
    csv.write("target/figures/fig03.csv").expect("write csv");
    let artifact = figures::emit_artifact("3").expect("known figure");
    println!("fig03 | artifact: {}", artifact.display());
}

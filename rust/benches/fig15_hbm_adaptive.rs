//! Fig 15: HBM memory access latency, baseline vs adaptive (bars) and
//! speedup percentage (orange line), all 31 workloads.
//!
//! Paper: ~50% average latency reduction; +3% speedup overall, +5% on
//! data-heavy workloads.

use dlpim::benchkit::Csv;
use dlpim::figures;
use dlpim::workloads::catalog;

fn main() {
    let t0 = std::time::Instant::now();
    let rows = figures::fig15_hbm_adaptive();
    let mut csv = Csv::new("workload,base_latency,adaptive_latency,speedup");
    let mut impr = Vec::new();
    for r in &rows {
        println!(
            "fig15 | {:<12} | base {:.1} | adaptive {:.1} | speedup {:.3}",
            r.workload, r.base_latency, r.adaptive_latency, r.speedup
        );
        csv.push(&[
            r.workload.to_string(),
            format!("{:.2}", r.base_latency),
            format!("{:.2}", r.adaptive_latency),
            format!("{:.4}", r.speedup),
        ]);
        if r.base_latency > 0.0 {
            impr.push(1.0 - r.adaptive_latency / r.base_latency);
        }
    }
    let sel_speedup = figures::geomean(
        rows.iter().filter(|r| catalog::SELECTED.contains(&r.workload)).map(|r| r.speedup),
    );
    println!(
        "fig15 | AVG latency impr {:.1}% (paper ~50%) | GEOMEAN speedup all {:.3} (paper ~1.03) selected {:.3} (paper ~1.05) | wallclock {:.1}s",
        impr.iter().sum::<f64>() / impr.len() as f64 * 100.0,
        figures::geomean(rows.iter().map(|r| r.speedup)),
        sel_speedup,
        t0.elapsed().as_secs_f64()
    );
    csv.write("target/figures/fig15.csv").expect("write csv");
    let artifact = figures::emit_artifact("15").expect("known figure");
    println!("fig15 | artifact: {}", artifact.display());
}

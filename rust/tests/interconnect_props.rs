//! Property tests (proptest-lite) for the `Interconnect` implementations:
//! the trait contract every topology must uphold, plus cross-topology
//! determinism of the full serve path.

use dlpim::config::{SimConfig, Topology};
use dlpim::memsys::{Access, build_interconnect, Interconnect, MemorySystem};
use dlpim::policy::{PolicyKind, PolicyRuntime};
use dlpim::proptest_lite::{gen, Runner};

const TOPOLOGIES: [Topology; 3] = [Topology::Mesh, Topology::Crossbar, Topology::Ring];

fn cfg_with(topology: Topology) -> SimConfig {
    let mut cfg = SimConfig::hmc(); // 32 vaults: valid for all three
    cfg.topology = topology;
    cfg
}

/// `hops(a, b) == hops(b, a)` and `hops(a, a) == 0`, every topology.
#[test]
fn prop_hops_symmetric_and_self_zero() {
    Runner::new(0x40B5).cases(60).run("hop-symmetry", |r| {
        for t in TOPOLOGIES {
            let net = build_interconnect(&cfg_with(t));
            for _ in 0..50 {
                let a = gen::u64_in(r, 0, 32) as u16;
                let b = gen::u64_in(r, 0, 32) as u16;
                if net.hops(a, b) != net.hops(b, a) {
                    return Err(format!(
                        "{t:?}: hops({a},{b}) = {} != hops({b},{a}) = {}",
                        net.hops(a, b),
                        net.hops(b, a)
                    ));
                }
                if net.hops(a, a) != 0 {
                    return Err(format!("{t:?}: hops({a},{a}) != 0"));
                }
            }
        }
        Ok(())
    });
}

/// Self-transfers are free and instantaneous on every topology.
#[test]
fn prop_self_transfer_is_zero_hop() {
    Runner::new(0x5E1F).cases(40).run("self-transfer", |r| {
        for t in TOPOLOGIES {
            let mut net = build_interconnect(&cfg_with(t));
            for _ in 0..30 {
                let a = gen::u64_in(r, 0, 32) as u16;
                let flits = gen::u64_in(r, 1, 10) as u32;
                let depart = gen::u64_in(r, 0, 1 << 30);
                let tr = net.transfer(a, a, flits, depart);
                if tr.arrive != depart || tr.hops != 0 || tr.network != 0 || tr.queued != 0
                {
                    return Err(format!("{t:?}: self-transfer not free: {tr:?}"));
                }
            }
        }
        Ok(())
    });
}

/// `transfer` never completes before `now`, the decomposition is exact
/// (`arrive == depart + network + queued`), and uncontended transfers cost
/// `flits * hops` — under arbitrary contention histories.
#[test]
fn prop_transfer_never_completes_early() {
    Runner::new(0xEA12).cases(40).run("no-early-completion", |r| {
        for t in TOPOLOGIES {
            let mut net = build_interconnect(&cfg_with(t));
            let mut now = 0u64;
            for _ in 0..200 {
                let a = gen::u64_in(r, 0, 32) as u16;
                let b = gen::u64_in(r, 0, 32) as u16;
                let flits = gen::u64_in(r, 1, 10) as u32;
                let depart = now + gen::u64_in(r, 0, 500);
                let tr = net.transfer(a, b, flits, depart);
                if tr.arrive < depart {
                    return Err(format!(
                        "{t:?}: transfer {a}->{b} completed at {} before depart {depart}",
                        tr.arrive
                    ));
                }
                if tr.arrive != depart + tr.network + tr.queued {
                    return Err(format!("{t:?}: decomposition inexact: {tr:?}"));
                }
                if tr.queued == 0
                    && tr.arrive != depart + flits as u64 * net.hops(a, b) as u64
                {
                    return Err(format!(
                        "{t:?}: uncontended cost model violated: {tr:?}"
                    ));
                }
                now += gen::u64_in(r, 0, 60);
            }
        }
        Ok(())
    });
}

/// Identical seeds produce identical `ServedRequest` streams on every
/// topology: the full serve path (directory, DRAM, interconnect) is a pure
/// function of the access history.
#[test]
fn prop_identical_seeds_give_identical_served_streams() {
    Runner::new(0xDE7E).cases(15).run("serve-determinism", |r| {
        for t in TOPOLOGIES {
            let mut cfg = cfg_with(t);
            cfg.policy = PolicyKind::Always;
            cfg.sub_table_sets = 64; // churn the directory too
            let policy = PolicyRuntime::new(&cfg);
            let mut mem_a = MemorySystem::new(&cfg);
            let mut mem_b = MemorySystem::new(&cfg);
            // One pre-drawn access stream, replayed into both systems.
            let mut now = 0u64;
            let stream: Vec<(Access, u64)> = (0..300)
                .map(|_| {
                    let acc = Access {
                        requester: gen::u64_in(r, 0, 32) as u16,
                        block: gen::u64_in(r, 0, 2048),
                        write: gen::bool_p(r, 0.3),
                    };
                    now += gen::u64_in(r, 1, 400);
                    (acc, now)
                })
                .collect();
            for (acc, at) in &stream {
                let ra = mem_a.serve(*acc, *at, &policy);
                let rb = mem_b.serve(*acc, *at, &policy);
                if ra != rb {
                    return Err(format!(
                        "{t:?}: served streams diverged at t={at}: {ra:?} vs {rb:?}"
                    ));
                }
            }
            if mem_a.total_parked() != mem_b.total_parked() {
                return Err(format!("{t:?}: directory state diverged"));
            }
        }
        Ok(())
    });
}

/// The serve path completes and decomposes exactly on the crossbar and
/// ring, not just the mesh (the facade analogue of the mesh-only latency
/// decomposition property).
#[test]
fn prop_serve_decomposition_exact_on_all_topologies() {
    Runner::new(0xACC3).cases(15).run("serve-decomposition", |r| {
        for t in TOPOLOGIES {
            let mut cfg = cfg_with(t);
            cfg.policy = PolicyKind::Always;
            let policy = PolicyRuntime::new(&cfg);
            let mut mem = MemorySystem::new(&cfg);
            let mut now = 0u64;
            for _ in 0..300 {
                let acc = Access {
                    requester: gen::u64_in(r, 0, 32) as u16,
                    block: gen::u64_in(r, 0, 100_000),
                    write: false,
                };
                let res = mem.serve(acc, now, &policy);
                if res.done != now + res.network + res.queued + res.array {
                    return Err(format!("{t:?}: decomposition inexact: {res:?}"));
                }
                now += gen::u64_in(r, 1, 200);
            }
        }
        Ok(())
    });
}

//! Trace round-trip fidelity: recording a run and replaying the trace
//! must reproduce the *identical* simulation — same `ServedRequest`
//! stream, hence bit-identical report statistics — across all three
//! topologies and both memory presets; transforms must run end-to-end;
//! corrupt files must fail with errors, not panics.

use std::path::PathBuf;

use dlpim::config::{SimConfig, Topology};
use dlpim::coordinator::driver::simulate;
use dlpim::coordinator::report::RunReport;
use dlpim::policy::PolicyKind;
use dlpim::trace::{record_run, transform, TraceData};
use dlpim::workloads::build_source;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dlpim-rt-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn quick(mut cfg: SimConfig, policy: PolicyKind) -> SimConfig {
    cfg.policy = policy;
    cfg.warmup_requests = 500;
    cfg.measure_requests = 3000;
    cfg.epoch_cycles = 5000;
    cfg.runs = 1;
    cfg
}

/// The full per-run evidence that two simulations served the identical
/// request stream: cycles, every scalar counter, the exact latency
/// decomposition, traffic, reuse, CoV, and the epoch-decision count.
fn assert_runs_identical(a: &RunReport, b: &RunReport, what: &str) {
    assert_eq!(a.cycles, b.cycles, "{what}: cycles");
    assert_eq!(a.stats.requests, b.stats.requests, "{what}: requests");
    assert_eq!(a.stats.latency, b.stats.latency, "{what}: latency breakdown");
    assert_eq!(a.stats.queue_net, b.stats.queue_net, "{what}: queue_net");
    assert_eq!(a.stats.queue_mem, b.stats.queue_mem, "{what}: queue_mem");
    assert_eq!(a.stats.l1_hits, b.stats.l1_hits, "{what}: l1_hits");
    assert_eq!(a.stats.local_requests, b.stats.local_requests, "{what}: local");
    assert_eq!(a.stats.subscriptions, b.stats.subscriptions, "{what}: subs");
    assert_eq!(a.stats.resubscriptions, b.stats.resubscriptions, "{what}: resubs");
    assert_eq!(a.stats.unsubscriptions, b.stats.unsubscriptions, "{what}: unsubs");
    assert_eq!(a.stats.sub_nacks, b.stats.sub_nacks, "{what}: nacks");
    assert_eq!(a.stats.traffic, b.stats.traffic, "{what}: traffic");
    assert_eq!(a.stats.reuse, b.stats.reuse, "{what}: reuse");
    assert_eq!(a.stats.demand.cov(), b.stats.demand.cov(), "{what}: cov");
    assert_eq!(a.decisions.len(), b.decisions.len(), "{what}: epoch decisions");
}

/// Record SPLRad, replay the file, and compare the full report — for
/// every topology on both memory presets (the acceptance grid).
#[test]
fn record_replay_is_bit_identical_across_topologies_and_presets() {
    let dir = tmp_dir("grid");
    for preset in ["hmc", "hbm"] {
        for topo in [Topology::Mesh, Topology::Crossbar, Topology::Ring] {
            let mut cfg = quick(SimConfig::preset(preset).unwrap(), PolicyKind::Adaptive);
            cfg.topology = topo;
            cfg.validate().unwrap();
            let path = dir.join(format!("splrad-{preset}-{}.dlpt", topo.as_str()));

            let direct = record_run(&cfg, "SPLRad", &path).unwrap();

            let mut replay_cfg = cfg.clone();
            replay_cfg.trace = Some(path.to_string_lossy().into_owned());
            let w = build_source(None, &replay_cfg).unwrap();
            let replayed = simulate(&replay_cfg, w);

            assert_runs_identical(
                &direct.runs[0],
                &replayed.runs[0],
                &format!("{preset}/{}", topo.as_str()),
            );
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Replay must be independent of the replay config's seed (the trace is
/// the randomness), while the generator run is not.
#[test]
fn replay_ignores_seed() {
    let dir = tmp_dir("seed");
    let cfg = quick(SimConfig::hmc(), PolicyKind::Never);
    let path = dir.join("seed.dlpt");
    record_run(&cfg, "HSJNPO", &path).unwrap();

    let mut a_cfg = cfg.clone();
    a_cfg.trace = Some(path.to_string_lossy().into_owned());
    let mut b_cfg = a_cfg.clone();
    b_cfg.seed = cfg.seed.wrapping_add(999);

    let a = simulate(&a_cfg, build_source(None, &a_cfg).unwrap());
    let b = simulate(&b_cfg, build_source(None, &b_cfg).unwrap());
    assert_runs_identical(&a.runs[0], &b.runs[0], "replay seeds");
    std::fs::remove_dir_all(&dir).ok();
}

/// A 2-tenant mix runs end-to-end through the ordinary driver under
/// every policy, with loop-around sustaining the measure window.
#[test]
fn mixed_trace_runs_end_to_end() {
    let dir = tmp_dir("mix");
    let cfg = quick(SimConfig::hmc(), PolicyKind::Never);
    let mut tenants = Vec::new();
    for name in ["SPLRad", "PHELinReg"] {
        let path = dir.join(format!("{name}.dlpt"));
        record_run(&cfg, name, &path).unwrap();
        tenants.push(TraceData::load(&path).unwrap());
    }
    let mixed = transform::mix(&tenants, &[1, 1], cfg.n_vaults).unwrap();
    let path = dir.join("mix2.dlpt");
    mixed.save(&path).unwrap();

    for policy in [PolicyKind::Never, PolicyKind::Adaptive] {
        let mut run_cfg = quick(SimConfig::hmc(), policy);
        run_cfg.trace = Some(path.to_string_lossy().into_owned());
        let rep = simulate(&run_cfg, build_source(None, &run_cfg).unwrap());
        assert!(
            rep.runs[0].stats.requests >= run_cfg.measure_requests,
            "{policy:?}: loop-around must sustain the measure window"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Geometry mismatches are caught with actionable errors, not panics —
/// and `remap` actually fixes them.
#[test]
fn replaying_on_wrong_geometry_is_a_clear_error() {
    let dir = tmp_dir("geom");
    let cfg = quick(SimConfig::hmc(), PolicyKind::Never); // 32 cores
    let path = dir.join("hmc.dlpt");
    record_run(&cfg, "STRAdd", &path).unwrap();

    let mut hbm = quick(SimConfig::hbm(), PolicyKind::Never); // 8 vaults
    hbm.trace = Some(path.to_string_lossy().into_owned());
    let err = build_source(None, &hbm).unwrap_err();
    assert!(err.contains("32 cores"), "{err}");
    assert!(err.contains("remap"), "should point at the fix: {err}");

    let remapped = transform::remap(&TraceData::load(&path).unwrap(), 8).unwrap();
    let rpath = dir.join("hbm8.dlpt");
    remapped.save(&rpath).unwrap();
    hbm.trace = Some(rpath.to_string_lossy().into_owned());
    let rep = simulate(&hbm, build_source(None, &hbm).unwrap());
    assert!(rep.runs[0].stats.requests >= hbm.measure_requests);
    std::fs::remove_dir_all(&dir).ok();
}

/// Malformed and truncated files fail with labelled errors, never panics.
#[test]
fn corrupt_trace_files_fail_cleanly() {
    let dir = tmp_dir("corrupt");

    let garbage = dir.join("garbage.dlpt");
    std::fs::write(&garbage, b"this is not a trace at all").unwrap();
    let err = TraceData::load(&garbage).unwrap_err();
    assert!(err.contains("bad magic"), "{err}");

    let cfg = quick(SimConfig::hmc(), PolicyKind::Never);
    let path = dir.join("ok.dlpt");
    record_run(&cfg, "STRCpy", &path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    let truncated = dir.join("truncated.dlpt");
    std::fs::write(&truncated, &bytes[..bytes.len() / 3]).unwrap();
    let err = TraceData::load(&truncated).unwrap_err();
    assert!(
        err.contains("truncated") || err.contains("trailing") || err.contains("core"),
        "unhelpful error: {err}"
    );

    // The same errors surface through the workload dispatch path.
    let mut run_cfg = cfg.clone();
    run_cfg.trace = Some(truncated.to_string_lossy().into_owned());
    assert!(build_source(None, &run_cfg).is_err());

    let missing = dir.join("nope.dlpt");
    run_cfg.trace = Some(missing.to_string_lossy().into_owned());
    let err = build_source(None, &run_cfg).unwrap_err();
    assert!(err.contains("nope.dlpt"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

/// `TraceData::to_bytes` is canonical: loading and re-serializing a
/// recorded file reproduces it byte for byte (transform outputs are
/// saved through this path).
#[test]
fn save_load_round_trips_bytes() {
    let dir = tmp_dir("bytes");
    let cfg = quick(SimConfig::hmc(), PolicyKind::Never);
    let path = dir.join("a.dlpt");
    record_run(&cfg, "STRSca", &path).unwrap();
    let original = std::fs::read(&path).unwrap();
    let data = TraceData::load(&path).unwrap();
    assert_eq!(data.to_bytes(), original, "serialization must be canonical");
    std::fs::remove_dir_all(&dir).ok();
}

/// The sweep engine runs trace-backed points, caches them by file
/// content, and reports generator typos with a suggestion.
#[test]
fn sweep_runs_trace_backed_points() {
    use dlpim::sweep::{Sweep, SweepPoint};
    let dir = tmp_dir("sweep");
    let cfg = quick(SimConfig::hmc(), PolicyKind::Never);
    let path = dir.join("s.dlpt");
    record_run(&cfg, "STRTriad", &path).unwrap();

    let mut tcfg = cfg.clone();
    tcfg.trace = Some(path.to_string_lossy().into_owned());
    let first = Sweep::new(vec![SweepPoint::new("trace-point", tcfg.clone())]).run();
    assert!(first[0].result.is_ok(), "{:?}", first[0].result);
    assert!(!first[0].from_cache, "unique trace file must miss the cache");
    let second = Sweep::new(vec![SweepPoint::new("trace-point", tcfg.clone())]).run();
    assert!(second[0].from_cache, "identical trace point must hit the cache");

    let bad = Sweep::new(vec![SweepPoint::new("SPLRod", cfg.clone())])
        .use_cache(false)
        .run();
    let err = bad[0].result.as_ref().unwrap_err();
    assert!(err.contains("SPLRad"), "did-you-mean through sweep: {err}");
    std::fs::remove_dir_all(&dir).ok();
}

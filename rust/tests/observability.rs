//! Observability passivity and determinism suite.
//!
//! Pins the three promises the `obs` module makes (the invariant rows in
//! `docs/ARCHITECTURE.md`):
//!
//! 1. **Passivity** — enabling telemetry and wiring the real
//!    `record_request` hook through the observed driver paths changes no
//!    report and no sweep outcome, byte for byte (`Debug` rendering).
//! 2. **Merge determinism** — the `_cycles` histograms and the request
//!    counter land on identical values whether a sweep ran on 1, 2, 4 or
//!    8 scheduler threads: log2 buckets + commutative `Relaxed` adds.
//! 3. **Exporter fidelity** — `write_files` emits both artifacts, the
//!    JSON is the exact `json()` rendering, and every Prometheus sample
//!    round-trips through `parse_samples` as an exact `u64`.
//!
//! The registry and the log level are process-global, so every check
//! that mutates them runs sequentially inside the single umbrella test;
//! the exporter tests build synthetic snapshots and never touch the
//! registry, so they are free to run in parallel with it.

use dlpim::config::SimConfig;
use dlpim::coordinator::driver::{simulate, simulate_observed};
use dlpim::obs::{self, export, HistSnapshot};
use dlpim::policy::PolicyKind;
use dlpim::sweep::{Sweep, SweepPoint};
use dlpim::workloads::catalog;

const WORKLOADS: [&str; 3] = ["SPLRad", "STRTriad", "PHELinReg"];

fn quick_cfg() -> SimConfig {
    let mut cfg = SimConfig::hmc().quick();
    cfg.policy = PolicyKind::Adaptive;
    cfg.warmup_requests = 200;
    cfg.measure_requests = 1_500;
    cfg.runs = 2;
    cfg
}

fn sweep_points(cfg: &SimConfig) -> Vec<SweepPoint> {
    WORKLOADS.iter().map(|w| SweepPoint::new(*w, cfg.clone())).collect()
}

/// The deterministic slice of the registry: simulated-time histograms
/// only (`_ns` wall-time histograms and the queue-depth gauge are
/// scheduling-dependent by design and deliberately excluded).
fn deterministic_hists() -> Vec<HistSnapshot> {
    vec![
        obs::REQUEST_TRANSFER_CYCLES.snap(),
        obs::REQUEST_QUEUE_NET_CYCLES.snap(),
        obs::REQUEST_QUEUE_MEM_CYCLES.snap(),
        obs::REQUEST_SERVICE_CYCLES.snap(),
        obs::SUBSCRIPTION_OCCUPANCY.snap(),
    ]
}

#[test]
fn telemetry_is_passive_and_merges_deterministically() {
    // ---- log level resolution (flags > REPRO_LOG > Info default) ----
    use dlpim::obs::log::{init, level, Level};
    std::env::remove_var("REPRO_LOG");
    init(false, false);
    assert_eq!(level(), Level::Info, "default level");
    init(false, true);
    assert_eq!(level(), Level::Debug, "--v selects Debug");
    init(true, true);
    assert_eq!(level(), Level::Quiet, "--quiet wins over --v");
    std::env::set_var("REPRO_LOG", "debug");
    init(false, false);
    assert_eq!(level(), Level::Debug, "REPRO_LOG honored without flags");
    std::env::set_var("REPRO_LOG", "bogus");
    init(false, false);
    assert_eq!(level(), Level::Info, "unparseable REPRO_LOG falls back to Info");
    std::env::remove_var("REPRO_LOG");
    init(false, false); // restore the default for the rest of the binary

    // ---- passivity: simulate vs simulate_observed, byte for byte ----
    let cfg = quick_cfg();
    let reference = simulate(&cfg, catalog::build("SPLRad", &cfg).unwrap());
    obs::enable();
    let observed = simulate_observed(&cfg, catalog::build("SPLRad", &cfg).unwrap(), |_, r| {
        obs::record_request(r.network, r.queued_net, r.queued_mem(), r.array)
    });
    assert_eq!(
        format!("{observed:?}"),
        format!("{reference:?}"),
        "the observed driver path perturbed the report"
    );
    assert!(obs::KERNEL_REQUESTS.get() > 0, "the request observer never fired");

    // ---- passivity: full sweep outcomes, telemetry off vs on ----
    // The cache is disabled on both legs so every point genuinely
    // re-simulates and the on-leg exercises the observed fork.
    obs::set_enabled(false);
    let off = Sweep::new(sweep_points(&cfg)).threads(4).use_cache(false).run();
    obs::enable();
    let on = Sweep::new(sweep_points(&cfg)).threads(4).use_cache(false).run();
    assert!(off.iter().all(|o| o.result.is_ok()), "off-leg sweep failed");
    assert_eq!(
        format!("{on:?}"),
        format!("{off:?}"),
        "sweep outcomes moved when telemetry was enabled"
    );

    // ---- merge determinism across scheduler thread counts ----
    let mut reference: Option<(Vec<HistSnapshot>, u64)> = None;
    for threads in [1usize, 2, 4, 8] {
        obs::reset();
        obs::enable();
        let outcomes =
            Sweep::new(sweep_points(&cfg)).threads(threads).use_cache(false).run();
        assert!(
            outcomes.iter().all(|o| o.result.is_ok()),
            "threads={threads}: sweep failed"
        );
        assert!(
            obs::SCHED_JOBS.get() >= WORKLOADS.len() as u64,
            "threads={threads}: scheduler counters never moved"
        );
        let snaps = deterministic_hists();
        let requests = obs::KERNEL_REQUESTS.get();
        assert!(requests > 0, "threads={threads}: no requests observed");
        match &reference {
            None => reference = Some((snaps, requests)),
            Some((ref_snaps, ref_requests)) => {
                assert_eq!(
                    &snaps, ref_snaps,
                    "threads={threads}: histogram merge is thread-count dependent"
                );
                assert_eq!(
                    requests, *ref_requests,
                    "threads={threads}: request count is thread-count dependent"
                );
            }
        }
    }
    obs::set_enabled(false);
}

/// `write_files` writes both artifacts (creating parents), the JSON is
/// the exact `json()` rendering, and every Prometheus sample survives a
/// parse round-trip as an exact integer. Synthetic snapshot only — the
/// global registry belongs to the umbrella test above.
#[test]
fn exporter_files_round_trip_on_disk() {
    use dlpim::obs::metrics::{Histogram, MetricPoint, Snapshot};

    let h = Histogram::new("request_like_cycles", "synthetic decomposition");
    h.observe(1);
    h.observe(900);
    h.observe(u64::MAX);
    let snap = Snapshot {
        counters: vec![
            MetricPoint { name: "store_hit", help: "hits", value: 5 },
            MetricPoint { name: "kernel_requests", help: "requests", value: u64::MAX },
        ],
        gauges: vec![MetricPoint { name: "sched_queue_depth_max", help: "depth", value: 3 }],
        hists: vec![h.snap()],
    };

    let dir = std::env::temp_dir().join(format!("dlpim-obs-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let json_path = dir.join("nested").join("metrics.json");
    let prom_path = export::write_files(&snap, &json_path).expect("write_files");
    assert_eq!(prom_path, json_path.with_extension("prom"), ".prom sibling path");

    let json_text = std::fs::read_to_string(&json_path).unwrap();
    assert_eq!(json_text, export::json(&snap), "on-disk JSON is the exact rendering");
    assert!(json_text.contains("\"store_hit\":5"));
    assert!(json_text.contains("\"kernel_requests\":18446744073709551615"));

    let prom_text = std::fs::read_to_string(&prom_path).unwrap();
    let samples = export::parse_samples(&prom_text);
    let get = |name: &str| -> u64 {
        samples
            .iter()
            .find(|(n, _)| n == name)
            .unwrap_or_else(|| panic!("missing sample {name}"))
            .1
    };
    assert_eq!(get("store_hit"), 5);
    assert_eq!(get("kernel_requests"), u64::MAX, "u64::MAX survives the text format");
    assert_eq!(get("sched_queue_depth_max"), 3);
    assert_eq!(get("request_like_cycles_count"), 3);
    assert_eq!(get("request_like_cycles_bucket{le=\"+Inf\"}"), 3);

    let _ = std::fs::remove_dir_all(&dir);
}

/// The keys CI greps out of `metrics.json` exist in the registry (names
/// only — values belong to whichever tests ran first in this binary).
#[test]
fn registry_json_carries_ci_grepped_keys() {
    let text = export::json(&obs::snapshot());
    for key in [
        "\"kernel_requests\":",
        "\"store_hit\":",
        "\"sched_jobs\":",
        "\"request_transfer_cycles\":",
        "\"request_queue_net_cycles\":",
        "\"request_queue_mem_cycles\":",
        "\"request_service_cycles\":",
    ] {
        assert!(text.contains(key), "metrics.json lost key {key}");
    }
}

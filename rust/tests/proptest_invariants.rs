//! Property tests (proptest-lite): protocol, routing and bookkeeping
//! invariants over thousands of randomized scenarios, driven through the
//! `MemorySystem` facade.

use dlpim::config::SimConfig;
use dlpim::memsys::{Access, MemorySystem};
use dlpim::policy::{PolicyKind, PolicyRuntime};
use dlpim::proptest_lite::{gen, Runner};
use dlpim::sim::{AddressMap, Mesh};

struct Rig {
    cfg: SimConfig,
    mem: MemorySystem,
    policy: PolicyRuntime,
}

fn rig(kind: PolicyKind, sets: u32) -> Rig {
    let mut cfg = SimConfig::hmc();
    cfg.policy = kind;
    cfg.sub_table_sets = sets;
    Rig { mem: MemorySystem::new(&cfg), policy: PolicyRuntime::new(&cfg), cfg }
}

/// Random protocol churn must never corrupt the distributed directory:
/// every committed subscription has exactly matching entries on both sides.
#[test]
fn prop_directory_consistency_under_churn() {
    Runner::new(0xD1EC).cases(40).run("directory-consistency", |r| {
        let mut rg = rig(PolicyKind::Always, 64); // small table = heavy churn
        let ops = gen::usize_in(r, 200, 800);
        let mut t = 0u64;
        for _ in 0..ops {
            let requester = gen::u64_in(r, 0, 32) as u16;
            let block = gen::u64_in(r, 0, 4096);
            let write = gen::bool_p(r, 0.3);
            rg.mem.serve(Access { requester, block, write }, t, &rg.policy);
            t += gen::u64_in(r, 1, 300);
        }
        let settle_at = t + 10_000_000;
        rg.mem.settle(settle_at);
        rg.mem.directory_consistent(settle_at)
    });
}

/// A block is parked in at most one reserved space at any time (DL-PIM
/// invalidates the original on subscription — no COMA-style multiplication).
#[test]
fn prop_single_copy_invariant() {
    Runner::new(0x51C0).cases(30).run("single-copy", |r| {
        let mut rg = rig(PolicyKind::Always, 128);
        let mut t = 0u64;
        // Hammer a small block set from many vaults to force resubscription.
        for _ in 0..600 {
            let requester = gen::u64_in(r, 0, 32) as u16;
            let block = gen::u64_in(r, 0, 64);
            rg.mem.serve(
                Access { requester, block, write: gen::bool_p(r, 0.2) },
                t,
                &rg.policy,
            );
            t += gen::u64_in(r, 50, 500);
        }
        let settle_at = t + 10_000_000;
        rg.mem.settle(settle_at);
        // Count holder entries per block across all vaults.
        let mut holders = std::collections::HashMap::new();
        let map = AddressMap::new(&rg.cfg);
        for v in 0..32u16 {
            let table = rg.mem.directory().table(v);
            for idx in 0..(table.num_sets() as usize * table.ways()) {
                let e = table.entry(idx);
                if !e.is_invalid()
                    && e.role == dlpim::subscription::Role::Holder
                    && e.state == dlpim::subscription::SubState::Subscribed
                {
                    *holders.entry(e.block).or_insert(0u32) += 1;
                    // And the holder must not be the home vault.
                    if map.home_of_block(e.block) == v {
                        return Err(format!("block {} parked at its own home", e.block));
                    }
                }
            }
        }
        for (b, n) in holders {
            if n > 1 {
                return Err(format!("block {b} has {n} holders"));
            }
        }
        Ok(())
    });
}

/// Latency component arithmetic: done == now + network + queued + array for
/// every read (the decomposition must be exact, not approximate).
#[test]
fn prop_latency_decomposition_is_exact() {
    Runner::new(0x1A7E).cases(30).run("latency-decomposition", |r| {
        let mut rg = rig(PolicyKind::Always, 2048);
        let mut t = 0u64;
        for _ in 0..400 {
            let requester = gen::u64_in(r, 0, 32) as u16;
            let block = gen::u64_in(r, 0, 100_000);
            let now = t;
            let res =
                rg.mem.serve(Access { requester, block, write: false }, now, &rg.policy);
            let reconstructed = now + res.network + res.queued + res.array;
            if res.done != reconstructed {
                return Err(format!(
                    "done {} != now {} + net {} + queue {} + array {}",
                    res.done, now, res.network, res.queued, res.array
                ));
            }
            t += gen::u64_in(r, 1, 200);
        }
        Ok(())
    });
}

/// Mesh link calendars never double-book: replaying any random transfer
/// sequence twice gives identical timings (pure function of history), and
/// backfilled reservations never start before their request time.
#[test]
fn prop_mesh_reservations_sane() {
    Runner::new(0x3E5B).cases(50).run("mesh-reservations", |r| {
        let cfg = SimConfig::hmc();
        let mut mesh = Mesh::new(&cfg);
        let mut t = 0u64;
        for _ in 0..300 {
            let a = gen::u64_in(r, 0, 32) as u16;
            let b = gen::u64_in(r, 0, 32) as u16;
            let flits = gen::u64_in(r, 1, 10) as u32;
            let depart = t + gen::u64_in(r, 0, 1000);
            let tr = mesh.transfer(a, b, flits, depart);
            if tr.arrive < depart {
                return Err("arrival before departure".into());
            }
            let ideal = depart + (flits as u64) * mesh.hops(a, b) as u64;
            if tr.queued == 0 && tr.arrive != ideal {
                return Err(format!(
                    "uncontended transfer arrive {} != ideal {ideal}",
                    tr.arrive
                ));
            }
            t += gen::u64_in(r, 0, 50);
        }
        Ok(())
    });
}

/// The LFU/LRU victim choice is always a committed, evictable entry.
#[test]
fn prop_victims_are_always_evictable() {
    Runner::new(0xF1C7).cases(30).run("victim-evictable", |r| {
        use dlpim::subscription::{Role, SubState, SubTable};
        let mut t = SubTable::new(16, 4);
        let mut now = 0u64;
        for _ in 0..300 {
            let set = gen::u64_in(r, 0, 16) as u32;
            match gen::usize_in(r, 0, 3) {
                0 => {
                    if let Some(w) = t.free_way(set) {
                        let state = if gen::bool_p(r, 0.7) {
                            SubState::Subscribed
                        } else {
                            SubState::PendingSub
                        };
                        t.install(
                            w,
                            gen::u64_in(r, 0, 1 << 20),
                            if gen::bool_p(r, 0.5) { Role::Home } else { Role::Holder },
                            gen::u64_in(r, 0, 32) as u16,
                            state,
                            now + gen::u64_in(r, 0, 500),
                            now,
                        );
                    }
                }
                1 => {
                    if let Some(v) = t.victim(set) {
                        if t.entry(v).state != SubState::Subscribed {
                            return Err("victimized a pending entry".into());
                        }
                        t.begin_unsub(v, now + gen::u64_in(r, 1, 300));
                    }
                }
                _ => {
                    // Random lookups drive lazy commits.
                    t.lookup(set, gen::u64_in(r, 0, 1 << 20), now);
                }
            }
            now += gen::u64_in(r, 1, 100);
        }
        Ok(())
    });
}

/// Policy runtime: whatever the request history, the leading sets never
/// change groups, and epoch decisions fire exactly once per boundary.
#[test]
fn prop_policy_epochs_and_leaders_stable() {
    Runner::new(0xE90C).cases(30).run("policy-epochs", |r| {
        let mut cfg = SimConfig::hmc();
        cfg.policy = PolicyKind::Adaptive;
        cfg.epoch_cycles = 1000;
        let mut p = PolicyRuntime::new(&cfg);
        let g0: Vec<_> = (0..64).map(|s| p.group(s)).collect();
        let mut t = 0u64;
        for _ in 0..200 {
            p.on_request(
                gen::u64_in(r, 0, 32) as u16,
                gen::u64_in(r, 0, 32) as u16,
                gen::bool_p(r, 0.5),
                gen::u64_in(r, 0, 40) as u32,
                gen::u64_in(r, 0, 10) as u32,
                gen::u64_in(r, 10, 4000),
                gen::u64_in(r, 0, 2048) as u32,
                t,
            );
            t += gen::u64_in(r, 1, 200);
            p.tick(t);
        }
        let expected_epochs = t / 1000;
        if p.epochs() != expected_epochs {
            return Err(format!("epochs {} != {expected_epochs}", p.epochs()));
        }
        let g1: Vec<_> = (0..64).map(|s| p.group(s)).collect();
        if g0 != g1 {
            return Err("leading-set groups drifted".into());
        }
        Ok(())
    });
}

/// Config files render->parse->render to a fixed point for random configs.
#[test]
fn prop_config_roundtrip() {
    Runner::new(0xC0F6).cases(100).run("config-roundtrip", |r| {
        let mut cfg = if gen::bool_p(r, 0.5) { SimConfig::hmc() } else { SimConfig::hbm() };
        cfg.sub_table_sets = 1 << gen::usize_in(r, 6, 13);
        cfg.epoch_cycles = gen::u64_in(r, 1000, 2_000_000);
        cfg.measure_requests = gen::u64_in(r, 1000, 1_000_000);
        cfg.mlp = gen::u64_in(r, 1, 16) as u16;
        let text = dlpim::config::presets::render(&cfg);
        let back = dlpim::config::parse::config_from_text(&text)
            .map_err(|e| format!("parse failed: {e}"))?;
        let text2 = dlpim::config::presets::render(&back);
        if text != text2 {
            return Err("render/parse not a fixed point".into());
        }
        Ok(())
    });
}

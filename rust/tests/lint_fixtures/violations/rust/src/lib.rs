//! Fixture crate root. This tree is *data* for `tests/lint_engine.rs`,
//! never compiled — `Repo::load` requires `rust/src/lib.rs` to accept a
//! directory as a repo root.

pub mod coordinator;
pub mod sim;

/// Referenced by an ARCHITECTURE.md invariant row (fn-ref resolution).
pub fn fixture_probe_works() {}

//! Fixture: report-accumulation path seeded with D2/D4 violations.

pub fn sample() -> u64 {
    let t0 = std::time::Instant::now();
    let x: f64 = 0.5;
    t0.elapsed().as_nanos() as u64 + x as u64
}

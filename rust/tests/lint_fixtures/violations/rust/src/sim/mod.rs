//! Fixture: determinism-critical module seeded with D1/D3/A0 violations.

use std::collections::HashMap;

static COUNTER: core::sync::atomic::AtomicU64 = core::sync::atomic::AtomicU64::new(0);

pub fn run() -> u64 {
    let mut m: HashMap<u64, u64> = HashMap::new();
    m.insert(1, 2);
    let t = COUNTER.fetch_add(1, core::sync::atomic::Ordering::Relaxed);
    let h: std::collections::HashSet<u64> = Default::default(); // lint:allow(D1)
    t + m.len() as u64 + h.len() as u64
}

pub fn other() {} // lint:allow(D9) -- not a real rule id

//! Fixture: an integration test no doc or CHANGES entry mentions (D5).

#[test]
fn probe() {
    assert_eq!(1 + 1, 2);
}

// lint:allow(D5) -- scratch fixture probe; intentionally undocumented

#[test]
fn probe_runs() {
    assert_eq!(2 + 2, 4);
}

//! Fixture: harness read-outs whose hazards are all justified.

pub fn elapsed_ns() -> u64 {
    // lint:allow(D2) -- progress telemetry only, never enters report state
    let t0 = std::time::Instant::now();
    t0.elapsed().as_nanos() as u64
}

// lint:allow(D4) -- derived read-out ratio, never accumulated back
pub fn ratio(a: u64, b: u64) -> f64 {
    // lint:allow(D4) -- same read-out as the signature
    a as f64 / b as f64
}

//! Fixture crate root. This tree is *data* for `tests/lint_engine.rs`,
//! never compiled — every seeded hazard below carries a justified allow,
//! so `repro lint` over this root reports zero violations.

pub mod coordinator;
pub mod sim;

//! Fixture: determinism-critical module whose hazards are all justified.

use std::collections::HashMap; // lint:allow(D1) -- drained in sorted order before any fold

static SEQ: core::sync::atomic::AtomicU64 = core::sync::atomic::AtomicU64::new(0);

pub fn sim_order_is_stable() -> u64 {
    // lint:allow(D3) -- ticket counter: claim order cannot affect results
    let t = SEQ.fetch_add(1, core::sync::atomic::Ordering::Relaxed);
    // lint:allow(D1) -- scratch map, drained through a sorted Vec below
    let m: HashMap<u64, u64> = Default::default();
    t + m.len() as u64
}

//! Differential tests for the unified event kernel.
//!
//! The kernel (`coordinator/kernel.rs`) replaces the batched driver's
//! inline loop with typed events and adds the deterministic parallel
//! fan-outs (run-level, epoch-barrier decay, hop-LUT fill). Nothing
//! observable may change: a kernel running with a multi-thread partition
//! width must produce `ServedRequest` streams identical request-by-request
//! to `simulate_once_scalar` across every topology, both presets and both
//! ends of the policy spectrum — and `simulate_runs` must produce
//! `RunReport`s identical byte-for-byte at every thread count.
//! `tests/batched_equivalence.rs` already pins the (kernel-backed)
//! `simulate_once` facade; this suite drives the kernel directly at
//! thread counts > 1 and storms its event ordering.

use dlpim::config::{SimConfig, Topology};
use dlpim::coordinator::driver::{simulate, simulate_once_scalar_observed};
use dlpim::coordinator::kernel::Kernel;
use dlpim::memsys::{Access, ServedRequest};
use dlpim::policy::PolicyKind;
use dlpim::workloads::{catalog, Op, Workload};
use dlpim::CoreId;

type Stream = Vec<(Access, ServedRequest)>;

/// Run the kernel (at `threads`) and the scalar reference on identical
/// seeds; assert stream equality with a pinpointed first-divergence
/// message and return both reports.
fn diff_kernel_vs_scalar(
    cfg: &SimConfig,
    workload: &mut dyn Workload,
    threads: usize,
    label: &str,
) -> (Stream, dlpim::coordinator::RunReport, dlpim::coordinator::RunReport) {
    let mut kernel_stream: Stream = Vec::new();
    workload.reset(cfg.seed);
    let rep_k = Kernel::new(threads)
        .run_once_observed(cfg, workload, |a, r| kernel_stream.push((a, *r)));

    let mut scalar: Stream = Vec::new();
    workload.reset(cfg.seed);
    let rep_s = simulate_once_scalar_observed(cfg, workload, |a, r| scalar.push((a, *r)));

    assert_eq!(
        kernel_stream.len(),
        scalar.len(),
        "{label}: request counts diverge (kernel {} vs scalar {})",
        kernel_stream.len(),
        scalar.len()
    );
    for (i, (k, s)) in kernel_stream.iter().zip(scalar.iter()).enumerate() {
        assert_eq!(k, s, "{label}: first divergence at request #{i}");
    }
    (kernel_stream, rep_s, rep_k)
}

/// The matrix the tentpole promises: the kernel at a multi-thread
/// partition width vs the scalar reference over every topology, both
/// presets, no-subscription baseline and the headline adaptive policy.
#[test]
fn kernel_and_scalar_streams_identical_across_matrix() {
    for preset in ["hmc", "hbm"] {
        for topology in [Topology::Mesh, Topology::Crossbar, Topology::Ring] {
            for policy in [PolicyKind::Never, PolicyKind::Adaptive] {
                let mut cfg = SimConfig::preset(preset).unwrap();
                cfg.topology = topology;
                cfg.policy = policy;
                cfg.warmup_requests = 500;
                cfg.measure_requests = 3_000;
                cfg.runs = 1;
                cfg.validate().unwrap_or_else(|e| {
                    panic!("{preset}/{}: {}", topology.as_str(), e.join("; "))
                });
                let label =
                    format!("{preset}/{}/{}", topology.as_str(), policy.as_str());
                let mut w = catalog::build("SPLRad", &cfg).unwrap();
                let (stream, rep_s, rep_k) = diff_kernel_vs_scalar(&cfg, w.as_mut(), 4, &label);
                assert!(!stream.is_empty(), "{label}: no requests captured");
                assert_eq!(rep_k, rep_s, "{label}: reports diverge");
            }
        }
    }
}

/// A randomized multi-core generator built to storm the kernel's event
/// ordering: per-core LCG streams mixing zero gaps (same-cycle re-arms
/// that must pop in core order), unit gaps, short random gaps and huge
/// gaps (admission-window edges), with random read/write mix over a
/// region far larger than the L1.
struct OrderingStorm {
    state: Vec<u64>,
    remaining: Vec<u64>,
    n: u16,
}

impl OrderingStorm {
    fn new(n: u16) -> Self {
        OrderingStorm { state: vec![0; n as usize], remaining: vec![0; n as usize], n }
    }

    fn next_u64(&mut self, c: usize) -> u64 {
        // SplitMix64 step: high-quality per-core streams from one seed.
        let mut z = self.state[c].wrapping_add(0x9E37_79B9_7F4A_7C15);
        self.state[c] = z;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

impl Workload for OrderingStorm {
    fn name(&self) -> &'static str {
        "OrderingStorm"
    }

    fn next_op(&mut self, core: CoreId) -> Option<Op> {
        let c = core as usize;
        if self.remaining[c] == 0 {
            return None;
        }
        self.remaining[c] -= 1;
        let x = self.next_u64(c);
        let addr = ((x >> 16) % 500_000) * 64; // ~30 MB region: misses dominate
        let write = x % 5 == 0;
        let gap = match (x >> 8) % 8 {
            0 | 1 | 2 => 0,              // same-cycle re-arm (core-order pops)
            3 | 4 => 1,                  // back-to-back
            5 | 6 => (x % 64) as u32,    // short random
            _ => 100_000 + (x % 7) as u32 * 50_000, // past the admission window
        };
        Some(Op { addr, write, gap })
    }

    fn reset(&mut self, seed: u64) {
        for c in 0..self.n as usize {
            self.state[c] = seed ^ (c as u64).wrapping_mul(0xA076_1D64_78BD_642F);
            self.remaining[c] = 1_500;
        }
    }
}

#[test]
fn randomized_ordering_storm_matches_scalar() {
    for seed in [1u64, 0xDEAD_BEEF, 0x1234_5678_9ABC_DEF0] {
        for policy in [PolicyKind::Never, PolicyKind::Adaptive] {
            let mut cfg = SimConfig::hmc();
            cfg.policy = policy;
            cfg.seed = seed;
            cfg.warmup_requests = 300;
            cfg.measure_requests = 5_000;
            cfg.runs = 1;
            let mut w = OrderingStorm::new(cfg.n_vaults);
            let label = format!("storm/{}/seed={seed:#x}", policy.as_str());
            let (stream, rep_s, rep_k) = diff_kernel_vs_scalar(&cfg, &mut w, 8, &label);
            assert_eq!(rep_k, rep_s, "{label}: reports diverge");
            assert!(!stream.is_empty(), "{label}: no requests captured");
        }
    }
}

/// The thread-count determinism matrix of the acceptance criteria: the
/// same multi-run simulation fanned across 1/2/4/8 kernel threads must
/// return `RunReport`s identical to the sequential `simulate` loop — not
/// just value-equal but identical in their full `Debug` rendering (every
/// field of every run, decision and stat, byte for byte).
#[test]
fn simulate_runs_identical_at_every_thread_count() {
    let mut cfg = SimConfig::hmc().quick();
    cfg.policy = PolicyKind::Adaptive;
    cfg.warmup_requests = 300;
    cfg.measure_requests = 2_000;
    cfg.runs = 4;
    let reference = simulate(&cfg, catalog::build("SPLRad", &cfg).unwrap());
    assert_eq!(reference.runs.len(), 4);
    let ref_bytes = format!("{reference:?}");

    for threads in [1usize, 2, 4, 8] {
        let rep = Kernel::new(threads).simulate_runs(&cfg, "SPLRad", || {
            catalog::build("SPLRad", &cfg).unwrap()
        });
        assert_eq!(rep, reference, "threads={threads}: reports diverge");
        assert_eq!(
            format!("{rep:?}"),
            ref_bytes,
            "threads={threads}: Debug renderings diverge"
        );
    }
}

/// The observability passivity bar: with telemetry enabled and the real
/// `record_request` hook wired into the observed fan-out, reports must
/// stay byte-identical (full `Debug` rendering) to the plain sequential
/// path — and the observed kernel stream must still match the scalar
/// reference. Enabling telemetry is process-global and deliberately left
/// on for whichever tests run after this one in the binary: every other
/// assertion here must hold regardless.
#[test]
fn metrics_recording_never_perturbs_reports_or_streams() {
    dlpim::obs::enable();
    let mut cfg = SimConfig::hmc().quick();
    cfg.policy = PolicyKind::Adaptive;
    cfg.warmup_requests = 300;
    cfg.measure_requests = 2_000;
    cfg.runs = 3;
    let reference = simulate(&cfg, catalog::build("SPLRad", &cfg).unwrap());
    let ref_bytes = format!("{reference:?}");

    for threads in [1usize, 4] {
        let rep = Kernel::new(threads).simulate_runs_observed(
            &cfg,
            "SPLRad",
            || catalog::build("SPLRad", &cfg).unwrap(),
            |_, r| dlpim::obs::record_request(r.network, r.queued_net, r.queued_mem(), r.array),
        );
        assert_eq!(
            format!("{rep:?}"),
            ref_bytes,
            "threads={threads}: metrics recording perturbed the report"
        );
    }
    // The hook really ran: both warmup and measured requests are observed.
    assert!(
        dlpim::obs::KERNEL_REQUESTS.get() >= 2 * 2_000,
        "observer never fired (kernel_requests = {})",
        dlpim::obs::KERNEL_REQUESTS.get()
    );

    // Stream equality kernel-vs-scalar holds with telemetry enabled too.
    let mut single = cfg.clone();
    single.runs = 1;
    let mut w = catalog::build("SPLRad", &single).unwrap();
    diff_kernel_vs_scalar(&single, w.as_mut(), 4, "metrics-on");
}

/// Same determinism bar for a workload whose per-run streams depend on
/// the seed (each run r reseeds with seed + r): parallel run claiming
/// must not perturb which seed drives which run slot.
#[test]
fn per_run_seeding_survives_parallel_claiming() {
    let mut cfg = SimConfig::hmc().quick();
    cfg.policy = PolicyKind::Never;
    cfg.warmup_requests = 100;
    cfg.measure_requests = 1_000;
    cfg.runs = 5; // odd count: uneven split across 2 and 4 workers
    let reference = simulate(&cfg, catalog::build("STRTriad", &cfg).unwrap());

    for threads in [2usize, 4, 8] {
        let rep = Kernel::new(threads).simulate_runs(&cfg, "STRTriad", || {
            catalog::build("STRTriad", &cfg).unwrap()
        });
        assert_eq!(rep, reference, "threads={threads}");
    }
}

//! Runtime integration: the AOT artifacts (built by `make artifacts`) must
//! load, compile and produce numerics matching Rust-side references.
//!
//! These tests are skipped (with a notice) when `artifacts/` is absent so
//! `cargo test` works from a fresh checkout; `make test` always builds the
//! artifacts first.

use dlpim::rng::Rng;
use dlpim::runtime::ArtifactStore;

fn store() -> Option<ArtifactStore> {
    match ArtifactStore::discover() {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e}");
            None
        }
    }
}

#[test]
fn all_expected_artifacts_compile() {
    let Some(mut s) = store() else { return };
    let names = s.list().unwrap();
    for expect in ["gemm", "gemm_tile", "stencil2d", "stream_triad", "linreg"] {
        assert!(names.iter().any(|n| n == expect), "missing artifact {expect}");
        s.get(expect).unwrap_or_else(|e| panic!("compile {expect}: {e:#}"));
    }
}

#[test]
fn gemm_tile_matches_rust_reference() {
    let Some(mut s) = store() else { return };
    let exe = s.get("gemm_tile").unwrap();
    let mut rng = Rng::new(42);
    let a: Vec<f32> = (0..64 * 64).map(|_| rng.f64() as f32 - 0.5).collect();
    let b: Vec<f32> = (0..64 * 64).map(|_| rng.f64() as f32 - 0.5).collect();
    let out = exe.run_f32(&[(&a, &[64, 64]), (&b, &[64, 64])]).unwrap();
    assert_eq!(out.len(), 1);
    let c = &out[0];
    // Spot-check a handful of entries against the naive product.
    for &(i, j) in &[(0usize, 0usize), (7, 3), (31, 63), (63, 0), (40, 40)] {
        let expect: f32 = (0..64).map(|k| a[i * 64 + k] * b[k * 64 + j]).sum();
        let got = c[i * 64 + j];
        assert!(
            (got - expect).abs() < 1e-3,
            "C[{i},{j}] = {got}, expected {expect}"
        );
    }
}

#[test]
fn stream_triad_matches_reference() {
    let Some(mut s) = store() else { return };
    let exe = s.get("stream_triad").unwrap();
    let n = 1 << 16;
    let mut rng = Rng::new(7);
    let b: Vec<f32> = (0..n).map(|_| rng.f64() as f32).collect();
    let c: Vec<f32> = (0..n).map(|_| rng.f64() as f32).collect();
    let out = exe.run_f32(&[(&b, &[n]), (&c, &[n])]).unwrap();
    for i in (0..n).step_by(4097) {
        let expect = b[i] + 3.0 * c[i];
        assert!((out[0][i] - expect).abs() < 1e-4, "a[{i}]");
    }
}

#[test]
fn linreg_recovers_known_line() {
    let Some(mut s) = store() else { return };
    let exe = s.get("linreg").unwrap();
    let n = 1 << 16;
    let mut rng = Rng::new(9);
    let x: Vec<f32> = (0..n).map(|_| rng.f64() as f32 - 0.5).collect();
    let y: Vec<f32> = x.iter().map(|&v| 2.5 * v + 1.25).collect();
    let out = exe.run_f32(&[(&x, &[n]), (&y, &[n])]).unwrap();
    assert_eq!(out.len(), 2, "slope + intercept");
    assert!((out[0][0] - 2.5).abs() < 1e-2, "slope {}", out[0][0]);
    assert!((out[1][0] - 1.25).abs() < 1e-2, "intercept {}", out[1][0]);
}

#[test]
fn stencil_interior_of_constant_field_is_identity() {
    let Some(mut s) = store() else { return };
    let exe = s.get("stencil2d").unwrap();
    let x = vec![2.0f32; 256 * 256];
    let out = exe.run_f32(&[(&x, &[256, 256])]).unwrap();
    // Interior: 0.5*2 + 4*0.125*2 = 2.0.
    let y = &out[0];
    assert!((y[128 * 256 + 128] - 2.0).abs() < 1e-5);
    // Corner (two zero neighbours): 0.5*2 + 2*0.125*2 = 1.5.
    assert!((y[0] - 1.5).abs() < 1e-5);
}

#[test]
fn executables_are_reusable_across_calls() {
    let Some(mut s) = store() else { return };
    let exe = s.get("gemm_tile").unwrap();
    let a = vec![1.0f32; 64 * 64];
    let b = vec![1.0f32; 64 * 64];
    let first = exe.run_f32(&[(&a, &[64, 64]), (&b, &[64, 64])]).unwrap();
    let second = exe.run_f32(&[(&a, &[64, 64]), (&b, &[64, 64])]).unwrap();
    assert_eq!(first[0], second[0]);
    assert!((first[0][0] - 64.0).abs() < 1e-4);
}

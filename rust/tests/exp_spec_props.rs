//! Property tests over [`dlpim::exp`] spec expansion: for every registry
//! figure and for thousands of randomized ad-hoc specs, expansion must be
//! deterministic, duplicate-free, and produce only configs that pass
//! `config::validate`; invalid axis combinations must be rejected with
//! the offending axis value in the message.

use dlpim::config::presets;
use dlpim::config::{MemKind, Topology};
use dlpim::exp::registry;
use dlpim::exp::spec::{ExperimentSpec, ScaleOverride, WorkloadSet};
use dlpim::policy::PolicyKind;
use dlpim::proptest_lite::{gen, Runner};
use dlpim::sweep::SweepPoint;

/// A stable fingerprint of one expansion: labels + fully rendered configs.
fn fingerprint(spec: &ExperimentSpec) -> Vec<(String, String)> {
    spec.expand()
        .unwrap_or_else(|e| panic!("{}: {e}", spec.name))
        .into_iter()
        .map(|p| (p.label, presets::render(&p.cfg)))
        .collect()
}

#[test]
fn registry_expansion_is_deterministic_and_valid() {
    for spec in registry::figures() {
        let a = fingerprint(&spec);
        let b = fingerprint(&spec);
        assert_eq!(a, b, "{}: expansion must be deterministic", spec.name);
        for p in spec.expand().unwrap() {
            p.cfg
                .validate()
                .unwrap_or_else(|e| panic!("{} {}: {e:?}", spec.name, p.label));
        }
    }
}

#[test]
fn registry_points_are_duplicate_free() {
    for spec in registry::figures() {
        let labels = spec.row_labels().unwrap();
        let configs = spec.expand().unwrap();
        let mut keys = std::collections::HashSet::new();
        for label in &labels {
            for p in &configs {
                let key = SweepPoint::new(label.clone(), p.cfg.clone()).key();
                assert!(
                    keys.insert(key),
                    "{}: duplicate sweep point ({label} x {})",
                    spec.name,
                    p.label
                );
            }
        }
        assert_eq!(keys.len(), spec.point_count().unwrap(), "{}", spec.name);
    }
}

#[test]
fn random_adhoc_specs_expand_cleanly() {
    const POLICY_POOL: [PolicyKind; 5] = [
        PolicyKind::Never,
        PolicyKind::Always,
        PolicyKind::Adaptive,
        PolicyKind::AdaptiveHops,
        PolicyKind::AdaptiveLatency,
    ];
    const WORKLOAD_POOL: [&str; 6] =
        ["SPLRad", "PHELinReg", "PLYgemm", "STRAdd", "HSJNPO", "CHABsBez"];
    const ENTRY_POOL: [u32; 4] = [1024, 2048, 4096, 8192];
    const THR_POOL: [u32; 4] = [0, 1, 4, 16];
    const EPOCH_POOL: [u64; 3] = [5_000, 20_000, 50_000];

    Runner::new(0xe59e_c5ec_d17a_0001).cases(400).run("adhoc spec expansion", |r| {
        let mut spec = ExperimentSpec::adhoc("prop");
        spec.mem = *gen::pick(r, &[MemKind::Hmc, MemKind::Hbm]);
        // Crossbar is valid for both presets (32 and 8 vaults are powers
        // of two), mesh and ring likewise; `None` keeps the preset.
        spec.topology = *gen::pick(
            r,
            &[None, Some(Topology::Mesh), Some(Topology::Crossbar), Some(Topology::Ring)],
        );
        // 1..=3 distinct policies (draw without replacement).
        let mut pool: Vec<PolicyKind> = POLICY_POOL.to_vec();
        let n_pol = gen::usize_in(r, 1, 4);
        spec.policies = (0..n_pol)
            .map(|_| pool.remove(gen::usize_in(r, 0, pool.len())))
            .collect();
        // A prepended baseline is a default-knob `never` config; drawing
        // it together with Never in the policy axis would (correctly) be
        // rejected as a duplicate when the knob axes are empty, so only
        // generate the legal combination here — the rejection itself is
        // pinned by `invalid_combinations_surface_offending_axis_value`.
        spec.baseline = gen::bool_p(r, 0.5) && !spec.policies.contains(&PolicyKind::Never);
        let mut wl_pool: Vec<&str> = WORKLOAD_POOL.to_vec();
        let n_wl = gen::usize_in(r, 1, 4);
        spec.workloads = WorkloadSet::Named(
            (0..n_wl)
                .map(|_| wl_pool.remove(gen::usize_in(r, 0, wl_pool.len())).to_string())
                .collect(),
        );
        if gen::bool_p(r, 0.4) {
            let k = gen::usize_in(r, 1, ENTRY_POOL.len() + 1);
            spec.table_entries = ENTRY_POOL[..k].to_vec();
        }
        if gen::bool_p(r, 0.4) {
            let k = gen::usize_in(r, 1, THR_POOL.len() + 1);
            spec.thresholds = THR_POOL[..k].to_vec();
        }
        if gen::bool_p(r, 0.3) {
            let k = gen::usize_in(r, 1, EPOCH_POOL.len() + 1);
            spec.epochs = EPOCH_POOL[..k].to_vec();
        }
        spec.scale = ScaleOverride {
            warmup: Some(gen::u64_in(r, 100, 1000)),
            measure: Some(gen::u64_in(r, 1000, 10_000)),
            runs: Some(1),
            seed: Some(gen::u64_in(r, 0, u64::MAX - 1)),
        };

        // Deterministic.
        let a = fingerprint(&spec);
        let b = fingerprint(&spec);
        if a != b {
            return Err("expansion not deterministic".into());
        }
        // Valid + duplicate-free.
        let configs = spec.expand().map_err(|e| format!("expand: {e}"))?;
        let expected =
            (usize::from(spec.baseline))
                + spec.policies.len()
                    * spec.table_entries.len().max(1)
                    * spec.thresholds.len().max(1)
                    * spec.epochs.len().max(1);
        if configs.len() != expected {
            return Err(format!("expected {expected} configs, got {}", configs.len()));
        }
        let mut seen = std::collections::HashSet::new();
        for p in &configs {
            p.cfg.validate().map_err(|e| format!("{}: {e:?}", p.label))?;
            if !seen.insert(presets::render(&p.cfg)) {
                return Err(format!("duplicate config {}", p.label));
            }
        }
        Ok(())
    });
}

#[test]
fn invalid_combinations_surface_offending_axis_value() {
    // Zero epoch: the axis value must appear in the error.
    let mut spec = ExperimentSpec::adhoc("bad-epoch");
    spec.epochs = vec![20_000, 0];
    let err = spec.expand().unwrap_err();
    assert!(err.contains("epoch=0") && err.contains("epoch_cycles"), "{err}");

    // Misaligned table entries.
    let mut spec = ExperimentSpec::adhoc("bad-entries");
    spec.table_entries = vec![1024, 1000];
    let err = spec.expand().unwrap_err();
    assert!(err.contains("table_entries=1000"), "{err}");

    // Duplicate axis values.
    let mut spec = ExperimentSpec::adhoc("dup-thr");
    spec.thresholds = vec![4, 4];
    let err = spec.expand().unwrap_err();
    assert!(err.contains("duplicate") && err.contains("4"), "{err}");

    // Unknown workload with a did-you-mean.
    let mut spec = ExperimentSpec::adhoc("bad-wl");
    spec.workloads = WorkloadSet::Named(vec!["PLYgem".into()]);
    let err = spec.row_labels().unwrap_err();
    assert!(err.contains("PLYgem") && err.contains("PLYgemm"), "{err}");

    // A baseline colliding with a default-knob `never` axis point.
    let mut spec = ExperimentSpec::adhoc("dup-baseline");
    spec.baseline = true;
    spec.policies = vec![PolicyKind::Never];
    let err = spec.expand().unwrap_err();
    assert!(err.contains("duplicate"), "{err}");
}

#[test]
fn expanded_seeds_follow_the_paired_methodology() {
    // Same workload across policy configs shares a derived seed; across
    // workloads it decorrelates — the sweep-point contract the figures'
    // paired comparisons rely on, now reachable through spec expansion.
    let mut spec = ExperimentSpec::adhoc("seeds");
    spec.workloads = WorkloadSet::Named(vec!["SPLRad".into(), "PLYgemm".into()]);
    spec.policies = vec![PolicyKind::Never, PolicyKind::Adaptive];
    let configs = spec.expand().unwrap();
    let seed = |wl: &str, i: usize| SweepPoint::new(wl, configs[i].cfg.clone()).job_cfg().seed;
    assert_eq!(seed("SPLRad", 0), seed("SPLRad", 1), "paired seeds");
    assert_ne!(seed("SPLRad", 0), seed("PLYgemm", 0), "decorrelated workloads");
}

//! Golden-artifact regression: every registry-driven figure JSON must be
//! **byte-identical** to the pre-refactor harness output at a fixed scale
//! and seed.
//!
//! The reference implementation below is the pre-registry `figures.rs`
//! per-figure code, vendored verbatim (modulo explicit scale/topology
//! injection instead of env vars) — it *is* the pinned fixture. The test
//! runs fig 1, fig 11, fig 15 and fig 19 across the mesh and crossbar
//! interconnects and asserts the registry path renders the exact same
//! artifact bytes. Everything runs in one `#[test]` so the environment
//! and the shared trace directory are touched sequentially.

use std::path::PathBuf;

use dlpim::config::{MemKind, SimConfig, Topology};
use dlpim::exp::{self, spec::ScaleOverride};
use dlpim::figures::run_matrix;
use dlpim::policy::PolicyKind;
use dlpim::sweep;
use dlpim::sweep::json::JsonValue;
use dlpim::workloads::catalog;

const WARMUP: u64 = 300;
const MEASURE: u64 = 2_000;

/// The pre-refactor `cfg_for` + `scaled`, with the scale and topology
/// pinned explicitly instead of read from `REPRO_*`.
fn cfg_ref(mem: MemKind, policy: PolicyKind, topo: Topology) -> SimConfig {
    let mut cfg = match mem {
        MemKind::Hmc => SimConfig::hmc(),
        MemKind::Hbm => SimConfig::hbm(),
    };
    cfg.policy = policy;
    cfg.topology = topo;
    cfg.warmup_requests = WARMUP;
    cfg.measure_requests = MEASURE;
    cfg.runs = 1;
    cfg
}

// ---- verbatim pre-refactor JSON assembly helpers ----

fn row_obj(workload: &str, cols: &[(&str, f64)]) -> JsonValue {
    let mut pairs = vec![("workload", JsonValue::str(workload))];
    pairs.extend(cols.iter().map(|(k, v)| (*k, JsonValue::num(*v))));
    JsonValue::obj(pairs)
}

fn figure_doc(name: &str, rows: Vec<JsonValue>) -> JsonValue {
    JsonValue::obj(vec![
        ("figure", JsonValue::str(name)),
        ("rows", JsonValue::Arr(rows)),
    ])
}

/// Pre-refactor Fig 1: latency breakdown per workload under the baseline.
fn reference_fig01(topo: Topology) -> JsonValue {
    let cfg = cfg_ref(MemKind::Hmc, PolicyKind::Never, topo);
    let reports = run_matrix(&catalog::ALL_NAMES, std::slice::from_ref(&cfg));
    let rows = catalog::ALL_NAMES
        .iter()
        .zip(reports)
        .map(|(name, mut r)| {
            let rep = r.remove(0);
            let (n, q, a) = rep.latency_fractions();
            row_obj(
                name,
                &[
                    ("network", n),
                    ("queue", q),
                    ("array", a),
                    ("avg_latency", rep.avg_latency()),
                ],
            )
        })
        .collect();
    figure_doc("fig01", rows)
}

/// Pre-refactor Fig 11: always vs adaptive on the reuse workloads (HMC).
fn reference_fig11(topo: Topology) -> JsonValue {
    let cfgs = [
        cfg_ref(MemKind::Hmc, PolicyKind::Never, topo),
        cfg_ref(MemKind::Hmc, PolicyKind::Always, topo),
        cfg_ref(MemKind::Hmc, PolicyKind::Adaptive, topo),
    ];
    let reports = run_matrix(&catalog::SELECTED, &cfgs);
    let rows = catalog::SELECTED
        .iter()
        .zip(reports)
        .map(|(name, r)| {
            row_obj(
                name,
                &[
                    ("always", r[1].speedup_vs(&r[0])),
                    ("adaptive", r[2].speedup_vs(&r[0])),
                    ("latency_improvement", r[2].latency_improvement_vs(&r[0])),
                ],
            )
        })
        .collect();
    figure_doc("fig11", rows)
}

/// Pre-refactor Fig 15: HBM latency baseline vs adaptive, all workloads.
fn reference_fig15(topo: Topology) -> JsonValue {
    let cfgs = [
        cfg_ref(MemKind::Hbm, PolicyKind::Never, topo),
        cfg_ref(MemKind::Hbm, PolicyKind::Adaptive, topo),
    ];
    let reports = run_matrix(&catalog::ALL_NAMES, &cfgs);
    let rows = catalog::ALL_NAMES
        .iter()
        .zip(reports)
        .map(|(name, r)| {
            row_obj(
                name,
                &[
                    ("base_latency", r[0].avg_latency()),
                    ("adaptive_latency", r[1].avg_latency()),
                    ("speedup", r[1].speedup_vs(&r[0])),
                ],
            )
        })
        .collect();
    figure_doc("fig15", rows)
}

/// Pre-refactor Fig 19: multi-tenant trace mixes (record the four tenant
/// baselines, mix 2- and 4-tenant scenarios, compare the three policies).
const FIG19_TENANTS: [&str; 4] = ["SPLRad", "PHELinReg", "CHABsBez", "PLYgemm"];

fn reference_fig19(topo: Topology) -> JsonValue {
    let dir = sweep::artifact::artifact_dir().join("traces");
    let rec_cfg = cfg_ref(MemKind::Hmc, PolicyKind::Never, topo);
    let tenants: Vec<dlpim::trace::TraceData> = FIG19_TENANTS
        .iter()
        .map(|name| {
            let path = dir.join(format!("{name}.dlpt"));
            dlpim::trace::record_run(&rec_cfg, name, &path)
                .unwrap_or_else(|e| panic!("record tenant {name}: {e}"));
            dlpim::trace::TraceData::load(&path).unwrap_or_else(|e| panic!("{e}"))
        })
        .collect();

    let rows = [("mix2", 2usize), ("mix4", 4usize)]
        .iter()
        .map(|&(label, k)| {
            let mixed =
                dlpim::trace::transform::mix(&tenants[..k], &vec![1; k], rec_cfg.n_vaults)
                    .unwrap_or_else(|e| panic!("{label}: {e}"));
            let path = dir.join(format!("{label}.dlpt"));
            mixed.save(&path).unwrap_or_else(|e| panic!("{label}: {e}"));
            let cfgs: Vec<SimConfig> = [PolicyKind::Never, PolicyKind::Always, PolicyKind::Adaptive]
                .iter()
                .map(|&p| {
                    let mut c = cfg_ref(MemKind::Hmc, p, topo);
                    c.trace = Some(path.to_string_lossy().into_owned());
                    c
                })
                .collect();
            let r = run_matrix(&[label], &cfgs).remove(0);
            row_obj(
                label,
                &[
                    ("tenants", k as f64),
                    ("always", r[1].speedup_vs(&r[0])),
                    ("adaptive", r[2].speedup_vs(&r[0])),
                    ("latency_improvement", r[2].latency_improvement_vs(&r[0])),
                    ("base_cov", r[0].cov()),
                    ("adaptive_cov", r[2].cov()),
                ],
            )
        })
        .collect();
    figure_doc("fig19", rows)
}

/// The registry path, pinned to the same scale + topology.
fn registry_json(id: &str, topo: Topology) -> String {
    let mut spec = exp::registry::by_figure(id).expect("registry figure");
    spec.topology = Some(topo);
    spec.scale = ScaleOverride {
        warmup: Some(WARMUP),
        measure: Some(MEASURE),
        runs: Some(1),
        seed: None,
    };
    let run = exp::run_spec(&spec).unwrap_or_else(|e| panic!("{id}: {e}"));
    exp::render_json(&spec, &run).render()
}

#[test]
fn registry_figures_match_prerefactor_bytes() {
    // Neutralize the env knobs so both sides see exactly the pinned
    // scale, and point the artifact/trace directory at a temp dir.
    for key in ["REPRO_WARMUP", "REPRO_MEASURE", "REPRO_RUNS", "REPRO_EPOCH", "REPRO_TOPOLOGY"] {
        std::env::remove_var(key);
    }
    let tmp: PathBuf =
        std::env::temp_dir().join(format!("dlpim-golden-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&tmp);
    std::fs::create_dir_all(&tmp).unwrap();
    std::env::set_var("REPRO_ARTIFACT_DIR", &tmp);

    for topo in [Topology::Mesh, Topology::Crossbar] {
        let cases: [(&str, JsonValue); 4] = [
            ("1", reference_fig01(topo)),
            ("11", reference_fig11(topo)),
            ("15", reference_fig15(topo)),
            ("19", reference_fig19(topo)),
        ];
        for (id, reference) in cases {
            let got = registry_json(id, topo);
            assert_eq!(
                got,
                reference.render(),
                "figure {id} over {} diverged from the pre-refactor bytes",
                topo.as_str()
            );
        }
    }

    // Metrics-on leg of the bit-identity invariant: with telemetry
    // enabled AND both report-cache levels emptied/disabled (so every
    // point genuinely re-simulates down the observed driver path), the
    // artifact bytes must not move.
    let reference = registry_json("1", Topology::Mesh); // warm: cached points
    sweep::cache::clear();
    sweep::cache::set_disk_cache_enabled(false);
    dlpim::obs::enable();
    let observed = registry_json("1", Topology::Mesh); // cold + observed
    assert_eq!(
        observed, reference,
        "fig 1 artifact bytes changed when metrics recording was enabled"
    );
    assert!(
        dlpim::obs::KERNEL_REQUESTS.get() > 0,
        "metrics-on leg never hit the request observer"
    );
    dlpim::obs::set_enabled(false);
    sweep::cache::set_disk_cache_enabled(true);

    std::env::remove_var("REPRO_ARTIFACT_DIR");
    let _ = std::fs::remove_dir_all(&tmp);
}
